"""Paper §7 (Fig. 9 + Table 2): end-to-end SIR particle filter on the UNGM
nonlinear system (eqs. 22-23) — mean RMSE, resample ratio, and the
RMSE-vs-resample-ratio budget model across B.

Fig. 9: B sweep for {Megopolis, Metropolis, C1-PS128, C2-PS128}.
Table 2: B in {16, 32, 64} + the unbiased multinomial/systematic baselines.
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from benchmarks.common import print_table, write_csv
from repro.core import (
    MegopolisSpec,
    MetropolisC1Spec,
    MetropolisC2Spec,
    MetropolisSpec,
    PrefixSumSpec,
)
from repro.pf.filter import ParticleFilter, run_filter_timed, simulate
from repro.pf.metrics import resample_ratio, rmse
from repro.pf.models import ungm

# Typed spec templates (DESIGN.md §9): the B sweep is spec.replace, and the
# per-algorithm hyperparameters live inside the spec — no kwargs tuples.
FIG9_ALGOS = {
    "megopolis": MegopolisSpec(),
    "metropolis": MetropolisSpec(),
    "c1_ps128": MetropolisC1Spec(partition_size_bytes=128),
    "c2_ps128": MetropolisC2Spec(partition_size_bytes=128),
}


def evaluate(algo: str, spec, b: int, *, particles: int, steps: int,
             mc_runs: int) -> dict:
    model = ungm()
    errs, ratios = [], []
    for run_i in range(mc_runs):
        key = jax.random.PRNGKey(run_i)
        k_sim, k_flt = jax.random.split(key)
        xs, zs = simulate(k_sim, model, steps)
        pf = ParticleFilter(model, particles, resampler=spec)
        ests, times = run_filter_timed(k_flt, pf, zs)
        errs.append(rmse(np.asarray(ests)[None], np.asarray(xs)))
        ratios.append(resample_ratio(times))
    return {"algo": algo, "B": b, "rmse": float(np.mean(errs)),
            "resample_ratio": float(np.mean(ratios))}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args(argv)
    particles = 1 << (20 if args.full else 13)
    steps = 100 if args.full else 25
    mc = 4 if not args.full else 16

    # Fig. 9: B sweep
    b_values = (5, 10, 20, 30) if not args.full else (5, 7, 10, 15, 20, 25, 30, 40)
    fig9 = []
    for iters in b_values:
        for algo, template in FIG9_ALGOS.items():
            fig9.append(evaluate(algo, template.replace(num_iters=iters), iters,
                                 particles=particles, steps=steps, mc_runs=mc))
    write_csv("fig9.csv", fig9)
    print("== Fig. 9 (B sweep) ==")
    print_table(fig9)

    # Table 2: fixed B + unbiased baselines
    table2 = []
    for algo in ("multinomial", "improved_systematic"):
        table2.append(evaluate(algo, PrefixSumSpec(kind=algo), 0,
                               particles=particles, steps=steps, mc_runs=mc))
    for iters in (16, 32, 64):
        for algo, template in FIG9_ALGOS.items():
            table2.append(evaluate(algo, template.replace(num_iters=iters), iters,
                                   particles=particles, steps=steps, mc_runs=mc))
    write_csv("table2.csv", table2)
    print("\n== Table 2 ==")
    print_table(table2)


if __name__ == "__main__":
    main()
