"""Analytic TPU-projected HBM model per cell.

``memory_analysis()`` on the XLA *CPU* backend is scheduler-pessimistic:
its list scheduler is memory-oblivious, so (a) rematerialisation does not
reduce reported liveness (measured: a 16-layer checkpointed MLP chain
reports MORE temp with remat than without — DESIGN.md §6.6) and (b) every
layer's backward residuals count as simultaneously live.  On the TPU
backend the memory-aware scheduler honours remat; this module projects the
per-chip HBM a TPU run needs, from first principles, and the dry-run
reports BOTH numbers.

Model (train):
    params(f32)/shards + compute-copy bf16 (dense: /tp; experts stay 2-D
    sharded) + moments + grad accumulator + L x per-layer activation
    checkpoint (one microbatch) + transient working set (largest layer's
    fwd+bwd live buffers, ~4x the biggest score/ffn block).
Decode/prefill: params + caches + transients.
"""

from __future__ import annotations


from repro.models import ModelConfig


def resample_step_bytes(num_particles: int, state_dim: int = 1, *,
                        fused: bool, batch: int = 1,
                        state_bytes: int = 4, weight_bytes: int = 4) -> dict:
    """Analytic peak HBM liveness of ONE resampling step (DESIGN.md §11).

    The unfused path (index generation + XLA gather) holds, simultaneously
    live at the gather: the pre-resample state, the gathered copy, the
    int32 ancestor vector and the weight buffer — and the scan carry keeps
    the dead pre-resample copy alive until the gather retires.  The fused
    ``Resampler.apply`` path drops the materialised ancestor vector (it
    never leaves VMEM) and writes the gathered state directly, so its peak
    is two state buffers + weights.  Used by tests/test_fused_apply.py to
    pin fused < unfused for every (N, state_dim).

    ``state_bytes``/``weight_bytes`` price the compressed-plane axis
    (DESIGN.md §14): bf16 tiles carry 2 bytes per word, halving the weight
    plane and float state terms; the int32 ancestor vector stays 4-byte.
    """
    state = float(batch * num_particles * state_dim * state_bytes)
    weights = float(batch * num_particles * weight_bytes)
    out = {
        "state_in": state,
        "state_out": state,
        "weights": weights,
    }
    if not fused:
        out["ancestors_i32"] = float(batch * num_particles * 4)
    out["total"] = float(sum(out.values()))
    return out


def smc_step_bytes(num_particles: int, state_dim: int = 1, *,
                   fused: bool, batch: int = 1,
                   state_bytes: int = 4, weight_bytes: int = 4) -> dict:
    """Analytic peak HBM liveness of ONE full SMC step (DESIGN.md §12):
    reweight → ESS → conditional resample → state copy.

    The composed path (normalise, ESS, branch and ``apply`` as separate XLA
    ops) holds, simultaneously live at the gather: both state buffers, the
    carried log-weight buffer, the materialised NORMALISED weight buffer the
    resampler consumes, and the int32 ancestor vector the where-select
    reads.  The fused ``Resampler.step`` computes normalised weights, ESS
    and the branch inside the kernel (the stats leave as two SMEM scalars)
    and selects ancestors on-chip, so its peak is two state buffers + the
    log-weight input — per population the fused step carries ``8 N`` fewer
    bytes (4 N normalised weights + 4 N ancestors) than the composition.
    Used by tests/test_step_fused.py to pin fused < composed for every
    (N, state_dim).

    ``state_bytes``/``weight_bytes`` price the compressed-plane axis
    (DESIGN.md §14): log-weight and normalised-weight planes scale with the
    plane word; the int32 ancestor vector stays 4-byte.
    """
    state = float(batch * num_particles * state_dim * state_bytes)
    log_weights = float(batch * num_particles * weight_bytes)
    out = {
        "state_in": state,
        "state_out": state,
        "log_weights": log_weights,
    }
    if not fused:
        out["weights_normalised"] = float(batch * num_particles * weight_bytes)
        out["ancestors_i32"] = float(batch * num_particles * 4)
    out["total"] = float(sum(out.values()))
    return out


def _layer_transient_train(cfg: ModelConfig, rows: int, seq: int, tp: int) -> float:
    """Peak transient bytes of ONE layer's fwd+bwd (f32 scores dominate)."""
    heads_loc = max(1, cfg.num_heads // tp)
    if cfg.window > 0:
        kspan = min(seq, 2 * cfg.window)
        qspan = min(seq, max(cfg.window, 128))
    else:
        kspan = seq
        qspan = min(seq, cfg.q_chunk)
    scores = rows * heads_loc * qspan * kspan * 4.0  # f32 scores
    probs = scores  # f32 probs
    ffn = rows * seq * max(cfg.ff_dense, cfg.d_ff) // tp * 4.0
    if cfg.ssm_state:
        d_inner = cfg.ssm_expand * cfg.d_model
        chunk = cfg.ssm_chunk
        nchunks = max(1, seq // max(chunk, 1))
        h_loc = max(1, (d_inner // max(cfg.ssm_head_dim, 1)) // tp)
        ssd = rows * h_loc * nchunks * chunk * chunk * 4.0  # decay blocks
        ffn = max(ffn, ssd)
    return 4.0 * max(scores + probs, ffn)


def projected_train_bytes(cfg: ModelConfig, *, global_batch: int, seq: int,
                          micro: int, dp: int, tp: int,
                          moment_bytes: int = 4) -> dict:
    n = cfg.num_params()
    n_dense = n - _expert_params(cfg)
    shards = dp * tp
    rows = max(1, global_batch // micro // dp)
    out = {
        "params_f32": 4.0 * n / shards,
        "compute_bf16": 2.0 * n_dense / tp + (2.0 * _expert_params(cfg) / shards),
        "moments": 2.0 * moment_bytes * n / shards,
        "grad_accum_f32": 4.0 * n / shards,
        "act_checkpoints": cfg.num_layers * rows * seq * cfg.d_model * 2.0,
        "transient": _layer_transient_train(cfg, rows, seq, tp),
        "logits_chunk": rows * min(cfg.loss_chunk, seq) * cfg.vocab_size // tp * 4.0 * 2,
    }
    out["total"] = float(sum(out.values()))
    return out


def _expert_params(cfg: ModelConfig) -> int:
    if not cfg.is_moe:
        return 0
    n_moe_layers = sum(1 for i in range(cfg.num_layers) if cfg.is_moe_layer(i))
    return n_moe_layers * cfg.num_experts * 3 * cfg.d_model * cfg.d_ff


def traffic_train_bytes(cfg: ModelConfig, *, global_batch: int, seq: int,
                        micro: int, dp: int, tp: int) -> float:
    """Fusion-aware per-chip HBM TRAFFIC per train step (bytes moved, not
    op-I/O).  cost_analysis' "bytes accessed" counts every HLO op's
    operands+results as if nothing fused — a ~30x overcount on this CPU
    backend; this model counts what a fused TPU program actually moves:

      weights  : read fwd + read bwd + grad write  (3 passes) per microbatch
      activs   : ~6 passes of the (rows, S, D) residual stream per layer
      scores   : ~4 passes of the f32 score block (banded for SWA)
      logits   : 3 passes of the (rows, chunk, V/tp) f32 chunk per seq chunk
      states   : optimizer read+write (f32 params + 2 moments)
    """
    n = cfg.num_params()
    n_exp = _expert_params(cfg)
    n_dense = n - n_exp
    rows = max(1, global_batch // micro // dp)
    nl = cfg.num_layers
    weights = 3.0 * (2.0 * n_dense / tp + 2.0 * n_exp / (dp * tp))
    act = 6.0 * nl * rows * seq * cfg.d_model * 2.0
    heads_loc = max(1, cfg.num_heads // tp)
    kspan = min(seq, 2 * cfg.window) if cfg.window else seq
    scores = 4.0 * nl * rows * heads_loc * seq * kspan * 4.0
    logits = 3.0 * rows * seq * cfg.vocab_size / tp * 4.0
    opt = (4.0 + 2 * 4.0) * 2.0 * n / (dp * tp)  # r+w of f32 params + moments
    return micro * (weights + act + scores + logits) + opt


def traffic_serve_bytes(cfg: ModelConfig, *, batch: int, seq: int, dp: int,
                        tp: int, kind: str) -> float:
    """Fusion-aware per-chip HBM traffic for one prefill or decode step."""
    rows = max(1, batch // dp)
    nl = cfg.num_layers
    n_active = cfg.num_active_params()
    cdt = 1.0  # cache dtype bytes handled by cfg.cache_dtype? default bf16=2
    cache_bytes = 0.0
    for i, k in enumerate(cfg.layer_kinds):
        if k == "mamba":
            d_inner = cfg.ssm_expand * cfg.d_model
            hloc = max(1, (d_inner // max(cfg.ssm_head_dim, 1)) // tp)
            cache_bytes += rows * hloc * cfg.ssm_head_dim * cfg.ssm_state * 4.0
        else:
            ring = seq if (k != "swa" or cfg.window == 0) else min(seq, cfg.window)
            kv_shard = tp if (cfg.num_kv_heads % tp == 0 or cfg.head_dim % tp == 0) else 1
            cache_bytes += 2.0 * rows * ring * cfg.num_kv_heads * cfg.head_dim * 2.0 / kv_shard
    if kind == "decode":
        weights = 2.0 * n_active / tp  # every active weight read once/token
        return weights + cache_bytes  # full cache read + O(1) write
    # prefill: fwd-only train-like traffic
    heads_loc = max(1, cfg.num_heads // tp)
    kspan = min(seq, 2 * cfg.window) if cfg.window else seq
    return (2.0 * (cfg.num_params() - _expert_params(cfg)) / tp
            + 2.0 * _expert_params(cfg) / (dp * tp)
            + 3.0 * nl * rows * seq * cfg.d_model * 2.0
            + 2.0 * nl * rows * heads_loc * seq * kspan * 4.0
            + cache_bytes)


def projected_serve_bytes(cfg: ModelConfig, *, batch: int, seq: int, dp: int,
                          tp: int, fsdp: bool, kind: str) -> dict:
    n = cfg.num_params()
    param_shards = (dp * tp) if fsdp else tp
    # caches: per layer KV (ring for swa) or SSM state; sharded over
    # min(batch, dp) * kv-shardable tp factor
    kv_bytes = 0.0
    for i, k in enumerate(cfg.layer_kinds):
        if k == "mamba":
            d_inner = cfg.ssm_expand * cfg.d_model
            kv_bytes += batch * (d_inner * cfg.ssm_state * 4.0 + 3 * 4 * d_inner * 2.0)
        else:
            ring = seq if (k != "swa" or cfg.window == 0) else min(seq, cfg.window)
            kv_bytes += 2.0 * batch * ring * cfg.num_kv_heads * cfg.head_dim * 2.0
    cache_shards = dp * (tp if (cfg.num_kv_heads % tp == 0 or cfg.head_dim % tp == 0) else 1)
    rows = max(1, batch // dp)
    if kind == "prefill":
        trans = _layer_transient_train(cfg, rows, seq, tp) / 4.0
    else:
        heads_loc = max(1, cfg.num_heads // tp)
        trans = 4.0 * rows * heads_loc * seq * 4.0  # decode scores f32 (q=1)
    out = {
        "compute_bf16": 2.0 * n / param_shards,
        "caches": kv_bytes / cache_shards,
        "transient": trans,
    }
    out["total"] = float(sum(out.values()))
    return out
