"""Rejection resampling — Pallas TPU kernel (Murray's unbiased baseline).

The paper positions Metropolis/Megopolis against rejection (§1): rejection
is unbiased but each particle's iteration count is a geometric random
variable — divergent control flow on SIMD hardware.  The kernel reproduces
that SIMD reality honestly: every lane runs the SAME fixed-trip proposal
loop (capped at ``max_iters``) with a ``done`` mask, so a tile pays for its
slowest lane — the divergence cost the paper describes, surfaced as wasted
masked work instead of warp serialisation.

Memory contract: proposals ``j ~ U{0, N-1}`` gather from the FULL weight
array, so like the Metropolis strawman the weights must stay VMEM-resident
(same cap, same scaling wall).  ``sup w`` is reduced in-register from the
resident array.  RNG lane layout matches the Metropolis kernel —
``hash_bits(seed, i, t)`` proposes, ``hash_uniform(seed, i + N, t)``
accepts — with ``t = 0`` reserved for the self-proposal round (particle i
first proposes itself, accepted w.p. ``w_i / sup w``), mirroring
``repro.core.resamplers.rejection``.

Grid = (num_tiles,) only: the proposal loop lives INSIDE the kernel body
(a ``fori_loop``), because unlike the Metropolis family there is no
carried cross-iteration memory schedule to coalesce — every iteration's
gather is random anyway.

Validated bit-exactly against ``ref.rejection_ref`` in interpret mode.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import (
    LANES,
    SUBLANES,
    gather_state,
    hash_bits,
    hash_uniform,
    step_select,
    step_stats,
    tile_lane_ids,
)

SEG = SUBLANES * LANES


def _rejection_loop(t, seed, w_max, w_full, w_own, max_iters: int):
    """The whole per-tile rejection chain (shared with nothing — rejection
    has no cross-iteration state beyond the done mask).  ``w_max`` (sup w)
    is scalar-prefetched: reduced ONCE by the wrapper, not once per grid
    step."""
    n_total = w_full.shape[0] * LANES
    i_global = tile_lane_ids(t)

    w_flat = w_full.reshape(n_total)

    # Round 0: particle i proposes itself (accept w.p. w_i / sup w).
    u0 = hash_uniform(seed, i_global + n_total, 0, dtype=w_own.dtype)
    done0 = u0 * w_max <= w_own
    k0 = i_global

    def body(tt, state):
        k, done = state
        j = (hash_bits(seed, i_global, tt) % jnp.uint32(n_total)).astype(jnp.int32)
        w_j = jnp.take(w_flat, j.reshape(-1), axis=0).reshape(SUBLANES, LANES)
        u = hash_uniform(seed, i_global + n_total, tt, dtype=w_j.dtype)
        accept = (~done) & (u * w_max <= w_j)
        return jnp.where(accept, j, k), done | accept

    k, _ = lax.fori_loop(1, max_iters + 1, body, (k0, done0))
    return k


def _make_kernel(max_iters: int):
    def _kernel(seed_ref, wmax_ref, w_full_ref, w_own_ref, k_ref):
        t = pl.program_id(0)
        k_ref[...] = _rejection_loop(
            t, seed_ref[0], wmax_ref[0], w_full_ref[...].astype(jnp.float32),
            w_own_ref[...].astype(jnp.float32), max_iters
        )

    return _kernel


def _make_kernel_batch(max_iters: int):
    def _kernel(seeds_ref, wmax_ref, w_full_ref, w_own_ref, k_ref):
        s = pl.program_id(0)
        t = pl.program_id(1)
        k_ref[0] = _rejection_loop(
            t, seeds_ref[s], wmax_ref[s], w_full_ref[0].astype(jnp.float32),
            w_own_ref[0].astype(jnp.float32), max_iters
        )

    return _kernel


def _make_kernel_fused(max_iters: int):
    def _kernel(seed_ref, wmax_ref, w_full_ref, w_own_ref, planes_ref, k_ref,
                out_ref):
        t = pl.program_id(0)
        k = _rejection_loop(
            t, seed_ref[0], wmax_ref[0], w_full_ref[...].astype(jnp.float32),
            w_own_ref[...].astype(jnp.float32), max_iters
        )
        k_ref[...] = k
        out_ref[...] = gather_state(planes_ref[...], k)

    return _kernel


def _make_kernel_fused_batch(max_iters: int):
    def _kernel(seeds_ref, wmax_ref, w_full_ref, w_own_ref, planes_ref, k_ref,
                out_ref):
        s = pl.program_id(0)
        t = pl.program_id(1)
        k = _rejection_loop(
            t, seeds_ref[s], wmax_ref[s], w_full_ref[0].astype(jnp.float32),
            w_own_ref[0].astype(jnp.float32), max_iters
        )
        k_ref[0] = k
        out_ref[0] = gather_state(planes_ref[0], k)

    return _kernel


def _make_kernel_step(max_iters: int):
    def _kernel(seed_ref, thr_ref, lw_full_ref, lw_own_ref, planes_ref,
                k_ref, out_ref, stats_ref, st_ref):
        """Fused STEP grid step (t,): the t == 0 prelude latches (m, do)
        AND ``sup w`` (an order-free max of ``exp(lw - m)``, so it equals
        the wrapper-side reduction of the composed path bitwise); each tile
        then runs the whole rejection chain on the normalised weights and
        commits selection or identity in the same grid step."""
        t = pl.program_id(0)
        n_total = lw_full_ref.shape[0] * LANES

        @pl.when(t == 0)
        def _prelude():
            m, ess_norm, incr, maxw, deg = step_stats(
                lw_full_ref[...].astype(jnp.float32).reshape(n_total), n_total
            )
            do = ess_norm < thr_ref[0]
            st_ref[0] = m
            st_ref[1] = jnp.where(do, jnp.float32(1.0), jnp.float32(0.0))
            w_all = jnp.exp(lw_full_ref[...].astype(jnp.float32) - m)
            w_all = jnp.where(deg, jnp.float32(1.0 / n_total), w_all)
            st_ref[2] = jnp.max(
                w_all.astype(lw_full_ref.dtype).astype(jnp.float32))
            st_ref[3] = jnp.where(deg, jnp.float32(1.0), jnp.float32(0.0))
            stats_ref[0] = ess_norm
            stats_ref[1] = jnp.where(do, incr, jnp.float32(0.0))
            stats_ref[2] = jnp.where(do, jnp.float32(1.0), jnp.float32(0.0))
            stats_ref[3] = maxw

        m = st_ref[0]
        do = st_ref[1] > 0.5
        deg = st_ref[3] > 0.5
        # Normalised weights re-land on the plane-dtype grid (the composed
        # path quantises at the public ``apply`` boundary); a no-op at f32.
        # The §16 degenerate latch substitutes the uniform bank first.
        w_full = jnp.exp(lw_full_ref[...].astype(jnp.float32) - m)
        w_own = jnp.exp(lw_own_ref[...].astype(jnp.float32) - m)
        w_full = jnp.where(deg, jnp.float32(1.0 / n_total), w_full)
        w_own = jnp.where(deg, jnp.float32(1.0 / n_total), w_own)
        w_full = w_full.astype(lw_full_ref.dtype).astype(jnp.float32)
        w_own = w_own.astype(lw_own_ref.dtype).astype(jnp.float32)
        k = _rejection_loop(t, seed_ref[0], st_ref[2], w_full, w_own, max_iters)
        k_sel = step_select(do, k, t)
        k_ref[...] = k_sel
        out_ref[...] = gather_state(planes_ref[...], k_sel)

    return _kernel


def _make_kernel_step_rows(max_iters: int):
    def _kernel(seeds_ref, thr_ref, lw_full_ref, lw_own_ref, planes_ref,
                k_ref, out_ref, stats_ref, st_ref):
        """Fused STEP over a bank, grid (s, t): per-row seeds; the prelude
        re-latches (m, do, sup w) at each row's t == 0 and writes that
        row's ``stats[s]``."""
        s = pl.program_id(0)
        t = pl.program_id(1)
        n_total = lw_full_ref.shape[1] * LANES

        @pl.when(t == 0)
        def _prelude():
            m, ess_norm, incr, maxw, deg = step_stats(
                lw_full_ref[0].astype(jnp.float32).reshape(n_total), n_total
            )
            do = ess_norm < thr_ref[0]
            st_ref[0] = m
            st_ref[1] = jnp.where(do, jnp.float32(1.0), jnp.float32(0.0))
            w_all = jnp.exp(lw_full_ref[0].astype(jnp.float32) - m)
            w_all = jnp.where(deg, jnp.float32(1.0 / n_total), w_all)
            st_ref[2] = jnp.max(
                w_all.astype(lw_full_ref.dtype).astype(jnp.float32))
            st_ref[3] = jnp.where(deg, jnp.float32(1.0), jnp.float32(0.0))
            stats_ref[s, 0] = ess_norm
            stats_ref[s, 1] = jnp.where(do, incr, jnp.float32(0.0))
            stats_ref[s, 2] = jnp.where(do, jnp.float32(1.0), jnp.float32(0.0))
            stats_ref[s, 3] = maxw

        m = st_ref[0]
        do = st_ref[1] > 0.5
        deg = st_ref[3] > 0.5
        w_full = jnp.exp(lw_full_ref[0].astype(jnp.float32) - m)
        w_own = jnp.exp(lw_own_ref[0].astype(jnp.float32) - m)
        w_full = jnp.where(deg, jnp.float32(1.0 / n_total), w_full)
        w_own = jnp.where(deg, jnp.float32(1.0 / n_total), w_own)
        w_full = w_full.astype(lw_full_ref.dtype).astype(jnp.float32)
        w_own = w_own.astype(lw_own_ref.dtype).astype(jnp.float32)
        k = _rejection_loop(t, seeds_ref[s], st_ref[2], w_full, w_own, max_iters)
        k_sel = step_select(do, k, t)
        k_ref[0] = k_sel
        out_ref[0] = gather_state(planes_ref[0], k_sel)

    return _kernel


@functools.partial(jax.jit, static_argnames=("max_iters", "interpret"))
def rejection_pallas_step(
    log_weights2d: jnp.ndarray,
    planes: jnp.ndarray,
    seed: jnp.ndarray,
    thr: jnp.ndarray,
    *,
    max_iters: int,
    interpret: bool = True,
):
    """Fused SMC-step pallas_call: normalise → ESS → conditional rejection
    chain → state copy, ONE launch.  ``log_weights2d``: f32[R, 128]
    UNNORMALISED; ``sup w`` is reduced IN-kernel from the resident array
    (order-free max — bit-identical to the composed wrapper's reduction).
    Returns ``(int32[R, 128], [d_pad, R, 128], f32[4] = (ess_norm, incr,
    resampled, max_weight))``."""
    rows, lanes = log_weights2d.shape
    assert lanes == LANES and rows % SUBLANES == 0
    d_pad = planes.shape[0]
    assert planes.shape[1:] == (rows, lanes)
    num_tiles = rows // SUBLANES

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # seed + f32 ESS threshold
        grid=(num_tiles,),
        in_specs=[
            pl.BlockSpec((rows, LANES), lambda t, seed, thr: (0, 0)),
            pl.BlockSpec((SUBLANES, LANES), lambda t, seed, thr: (t, 0)),
            pl.BlockSpec((d_pad, rows, LANES), lambda t, seed, thr: (0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((SUBLANES, LANES), lambda t, seed, thr: (t, 0)),
            pl.BlockSpec((d_pad, SUBLANES, LANES), lambda t, seed, thr: (0, t, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        scratch_shapes=[pltpu.SMEM((4,), jnp.float32)],  # (m, do, sup w, deg)
    )
    return pl.pallas_call(
        _make_kernel_step(max_iters),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((rows, lanes), jnp.int32),
            jax.ShapeDtypeStruct((d_pad, rows, lanes), planes.dtype),
            jax.ShapeDtypeStruct((4,), jnp.float32),
        ],
        interpret=interpret,
    )(seed, thr, log_weights2d, log_weights2d, planes)


@functools.partial(jax.jit, static_argnames=("max_iters", "interpret"))
def rejection_pallas_step_rows(
    log_weights3d: jnp.ndarray,
    planes4d: jnp.ndarray,
    seeds: jnp.ndarray,
    thr: jnp.ndarray,
    *,
    max_iters: int,
    interpret: bool = True,
):
    """Fused SMC-step bank launch; row s is bit-identical to
    ``rejection_pallas_step(log_weights3d[s], planes4d[s], seeds[s:s+1],
    thr, ...)``.  Returns ``(int32[Bz, R, 128], [Bz, d_pad, R, 128],
    f32[Bz, 4])``."""
    bsz, rows, lanes = log_weights3d.shape
    assert lanes == LANES and rows % SUBLANES == 0
    d_pad = planes4d.shape[1]
    assert planes4d.shape == (bsz, d_pad, rows, lanes)
    num_tiles = rows // SUBLANES

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(bsz, num_tiles),
        in_specs=[
            pl.BlockSpec((1, rows, LANES), lambda s, t, se, r: (s, 0, 0)),
            pl.BlockSpec((1, SUBLANES, LANES), lambda s, t, se, r: (s, t, 0)),
            pl.BlockSpec(
                (1, d_pad, rows, LANES), lambda s, t, se, r: (s, 0, 0, 0)
            ),
        ],
        out_specs=[
            pl.BlockSpec((1, SUBLANES, LANES), lambda s, t, se, r: (s, t, 0)),
            pl.BlockSpec(
                (1, d_pad, SUBLANES, LANES), lambda s, t, se, r: (s, 0, t, 0)
            ),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        scratch_shapes=[pltpu.SMEM((4,), jnp.float32)],
    )
    return pl.pallas_call(
        _make_kernel_step_rows(max_iters),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((bsz, rows, lanes), jnp.int32),
            jax.ShapeDtypeStruct((bsz, d_pad, rows, lanes), planes4d.dtype),
            jax.ShapeDtypeStruct((bsz, 4), jnp.float32),
        ],
        interpret=interpret,
    )(seeds, thr, log_weights3d, log_weights3d, planes4d)


@functools.partial(jax.jit, static_argnames=("max_iters", "interpret"))
def rejection_pallas_fused(
    weights2d: jnp.ndarray,
    planes: jnp.ndarray,
    seed: jnp.ndarray,
    *,
    max_iters: int,
    interpret: bool = True,
):
    """Fused resample+gather (DESIGN.md §11): the rejection chain runs
    entirely inside the kernel body, so the state copy follows it in the
    SAME grid step — rejection needs no last-iteration gating.  Ancestors
    identical to ``rejection_pallas``; returns ``(int32[R, 128],
    [d_pad, R, 128])``."""
    rows, lanes = weights2d.shape
    assert lanes == LANES and rows % SUBLANES == 0
    d_pad = planes.shape[0]
    assert planes.shape[1:] == (rows, lanes)
    num_tiles = rows // SUBLANES
    w_max = jnp.max(weights2d).astype(jnp.float32).reshape(1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(num_tiles,),
        in_specs=[
            pl.BlockSpec((rows, LANES), lambda t, seed, wmax: (0, 0)),
            pl.BlockSpec((SUBLANES, LANES), lambda t, seed, wmax: (t, 0)),
            pl.BlockSpec((d_pad, rows, LANES), lambda t, seed, wmax: (0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((SUBLANES, LANES), lambda t, seed, wmax: (t, 0)),
            pl.BlockSpec((d_pad, SUBLANES, LANES), lambda t, seed, wmax: (0, t, 0)),
        ],
    )
    return pl.pallas_call(
        _make_kernel_fused(max_iters),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((rows, lanes), jnp.int32),
            jax.ShapeDtypeStruct((d_pad, rows, lanes), planes.dtype),
        ],
        interpret=interpret,
    )(seed, w_max, weights2d, weights2d, planes)


@functools.partial(jax.jit, static_argnames=("max_iters", "interpret"))
def rejection_pallas_fused_batch(
    weights3d: jnp.ndarray,
    planes4d: jnp.ndarray,
    seeds: jnp.ndarray,
    *,
    max_iters: int,
    interpret: bool = True,
):
    """Fused bank launch (leading batch grid dim); row s is bit-identical to
    ``rejection_pallas_fused(weights3d[s], planes4d[s], seeds[s:s+1])``."""
    bsz, rows, lanes = weights3d.shape
    assert lanes == LANES and rows % SUBLANES == 0
    d_pad = planes4d.shape[1]
    assert planes4d.shape == (bsz, d_pad, rows, lanes)
    num_tiles = rows // SUBLANES
    w_max = jnp.max(weights3d, axis=(1, 2)).astype(jnp.float32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(bsz, num_tiles),
        in_specs=[
            pl.BlockSpec((1, rows, LANES), lambda s, t, seeds, wmax: (s, 0, 0)),
            pl.BlockSpec((1, SUBLANES, LANES), lambda s, t, seeds, wmax: (s, t, 0)),
            pl.BlockSpec(
                (1, d_pad, rows, LANES), lambda s, t, seeds, wmax: (s, 0, 0, 0)
            ),
        ],
        out_specs=[
            pl.BlockSpec((1, SUBLANES, LANES), lambda s, t, seeds, wmax: (s, t, 0)),
            pl.BlockSpec(
                (1, d_pad, SUBLANES, LANES), lambda s, t, seeds, wmax: (s, 0, t, 0)
            ),
        ],
    )
    return pl.pallas_call(
        _make_kernel_fused_batch(max_iters),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((bsz, rows, lanes), jnp.int32),
            jax.ShapeDtypeStruct((bsz, d_pad, rows, lanes), planes4d.dtype),
        ],
        interpret=interpret,
    )(seeds, w_max, weights3d, weights3d, planes4d)


@functools.partial(jax.jit, static_argnames=("max_iters", "interpret"))
def rejection_pallas(
    weights2d: jnp.ndarray,
    seed: jnp.ndarray,
    *,
    max_iters: int,
    interpret: bool = True,
) -> jnp.ndarray:
    """``weights2d``: f32[R, 128] with R % 8 == 0; ``seed``: uint32[1].
    Returns int32[R, 128] ancestors (last proposal kept past the cap)."""
    rows, lanes = weights2d.shape
    assert lanes == LANES and rows % SUBLANES == 0
    num_tiles = rows // SUBLANES
    w_max = jnp.max(weights2d).astype(jnp.float32).reshape(1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # seed + sup w (reduced once, host of the grid)
        grid=(num_tiles,),
        in_specs=[
            pl.BlockSpec((rows, LANES), lambda t, seed, wmax: (0, 0)),
            pl.BlockSpec((SUBLANES, LANES), lambda t, seed, wmax: (t, 0)),
        ],
        out_specs=pl.BlockSpec((SUBLANES, LANES), lambda t, seed, wmax: (t, 0)),
    )
    return pl.pallas_call(
        _make_kernel(max_iters),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((rows, lanes), jnp.int32),
        interpret=interpret,
    )(seed, w_max, weights2d, weights2d)


@functools.partial(jax.jit, static_argnames=("max_iters", "interpret"))
def rejection_pallas_batch(
    weights3d: jnp.ndarray,
    seeds: jnp.ndarray,
    *,
    max_iters: int,
    interpret: bool = True,
) -> jnp.ndarray:
    """Batched launch over a ``[Bz, R, 128]`` bank (leading batch grid dim);
    row s is bit-identical to ``rejection_pallas(weights3d[s], seeds[s:s+1])``."""
    bsz, rows, lanes = weights3d.shape
    assert lanes == LANES and rows % SUBLANES == 0
    num_tiles = rows // SUBLANES
    w_max = jnp.max(weights3d, axis=(1, 2)).astype(jnp.float32)  # per-row sup w

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(bsz, num_tiles),
        in_specs=[
            pl.BlockSpec((1, rows, LANES), lambda s, t, seeds, wmax: (s, 0, 0)),
            pl.BlockSpec((1, SUBLANES, LANES), lambda s, t, seeds, wmax: (s, t, 0)),
        ],
        out_specs=pl.BlockSpec((1, SUBLANES, LANES), lambda s, t, seeds, wmax: (s, t, 0)),
    )
    return pl.pallas_call(
        _make_kernel_batch(max_iters),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bsz, rows, lanes), jnp.int32),
        interpret=interpret,
    )(seeds, w_max, weights3d, weights3d)
