"""Crash-consistent long runs — checkpointed ``lax.scan`` (DESIGN.md §16).

A monolithic ``lax.scan`` applies its body sequentially, so a host loop
of scans over contiguous chunks of ``xs`` — threading the carry through
— produces bit-identical ``(carry, ys)``.  ``checkpointed_scan``
exploits exactly that: it chunks the scan at the ``CheckpointPolicy``
snapshot period, and after each chunk atomically persists
``{carry, ys-so-far}`` through ``repro.checkpoint`` (tmp-dir + fsync +
rename: a crash mid-save never corrupts the previous snapshot).

Resume is a pure prefix-skip: restore the last durable ``carry`` +
``ys`` prefix and continue the same host loop from that chunk — the
continuation is bit-identical to the uninterrupted run because each
chunk's inputs (carry bytes, xs rows, jitted scan body) are identical.

``fail_after`` is the kill-switch for the §16 kill-and-resume test: the
run raises the typed ``InjectedCrash`` in the chunk that CROSSES that
step boundary, strictly AFTER the snapshot is durably on disk — so a
resumed run never re-crashes and always completes.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.checkpoint import CheckpointManager, latest_step, restore_checkpoint
from repro.resilience.errors import InjectedCrash


@dataclasses.dataclass(frozen=True)
class CheckpointPolicy:
    """Where/how often a long scan snapshots its carry.

    ``directory``   snapshot root (``repro.checkpoint`` layout inside).
    ``every``       snapshot period, in scan steps (= the chunk length).
    ``keep``        retained snapshots (older ones GC'd after a newer
                    save completes — always one restorable on disk).
    ``resume``      pick up from the latest durable snapshot when one
                    exists (else start clean).
    ``fail_after``  chaos hook: raise ``InjectedCrash`` once the run
                    crosses this step boundary, AFTER that snapshot is
                    durable.  ``None`` disables.
    """

    directory: str
    every: int = 1
    keep: int = 3
    resume: bool = True
    fail_after: Optional[int] = None

    def __post_init__(self):
        if not self.directory:
            raise ValueError("CheckpointPolicy.directory must be non-empty")
        if isinstance(self.every, bool) or not isinstance(self.every, int) \
                or self.every < 1:
            raise ValueError(
                f"CheckpointPolicy.every must be a positive int; got {self.every!r}"
            )


def _concat_parts(parts):
    if len(parts) == 1:
        return parts[0]
    return jax.tree_util.tree_map(
        lambda *ls: jnp.concatenate([jnp.asarray(l) for l in ls], axis=0), *parts
    )


def checkpointed_scan(body, init, xs, policy: Optional[CheckpointPolicy]):
    """``lax.scan(body, init, xs)`` with periodic durable snapshots.

    ``policy=None`` IS the monolithic scan (no behavioural fork to
    maintain).  Otherwise the scan runs in ``policy.every``-step chunks;
    the returned ``(carry, ys)`` is bit-identical to the monolithic call
    whether or not the run resumed from a snapshot.
    """
    if policy is None:
        return lax.scan(body, init, xs)

    leaves = jax.tree_util.tree_leaves(xs)
    if not leaves:
        raise ValueError("checkpointed_scan: xs must carry at least one leaf")
    length = leaves[0].shape[0]
    scan_fn = jax.jit(lambda c, x: lax.scan(body, c, x))

    start, carry, ys_parts = 0, init, []
    if policy.resume:
        step = latest_step(policy.directory)
        if step is not None:
            t_done = min(int(step), length)
            xs_head = jax.tree_util.tree_map(lambda a: a[:t_done], xs)
            _, ys_shape = jax.eval_shape(scan_fn, init, xs_head)
            template = {"carry": init, "ys": ys_shape}
            snap, _ = restore_checkpoint(policy.directory, step,
                                         template=template)
            carry = jax.tree_util.tree_map(jnp.asarray, snap["carry"])
            if t_done:
                ys_parts.append(
                    jax.tree_util.tree_map(jnp.asarray, snap["ys"])
                )
            start = t_done

    mgr = CheckpointManager(policy.directory, keep=policy.keep)
    for t0 in range(start, length, policy.every):
        t1 = min(t0 + policy.every, length)
        xs_chunk = jax.tree_util.tree_map(lambda a: a[t0:t1], xs)
        carry, ys = scan_fn(carry, xs_chunk)
        jax.block_until_ready(carry)
        ys_parts.append(ys)
        snapshot = {"carry": carry, "ys": _concat_parts(ys_parts)}
        mgr.save(t1, snapshot, extra={"t_done": int(t1), "length": int(length)})
        if policy.fail_after is not None and t0 < policy.fail_after <= t1:
            raise InjectedCrash(
                f"injected crash after durable snapshot at step {t1} "
                f"(fail_after={policy.fail_after})"
            )

    if not ys_parts:  # length == 0
        _, ys_shape = jax.eval_shape(scan_fn, init, xs)
        return carry, jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype), ys_shape
        )
    return carry, _concat_parts(ys_parts)
