from repro.pf.filter import (  # noqa: F401
    ParticleFilter,
    StateSpaceModel,
    run_filter,
    run_filter_bank,
)
from repro.pf.models import ungm, ungm_family, ungm_theta  # noqa: F401
from repro.pf.metrics import rmse, resample_ratio  # noqa: F401
