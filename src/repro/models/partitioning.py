"""Logical-axis activation partitioning (MaxText-style rules).

GSPMD propagates weight shardings into activations, but propagation gives
up at reshapes whose sharded dim doesn't factor (GQA kv-proj flat dim ->
(kv_heads, head_dim)) and at conflicting uses — and then silently
REPLICATES, which is how a 0.6B model ends up with 174 GiB/device attention
buffers (global-batch scores).  The production answer is explicit logical
axes on activations:

    x = logical(x, "batch", "seq", "embed")

``rules`` maps logical names to mesh axes for the current step function;
they are installed by the step builders (launch/steps.py) INSIDE the traced
function, so the same model code lowers correctly for any mesh/topology.
Outside any rules context ``logical`` is the identity — single-device tests
and the pure-algorithm library never pay for it.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_STATE = threading.local()


def _current() -> Optional[dict]:
    return getattr(_STATE, "rules", None)


@contextlib.contextmanager
def rules(mesh, **name_to_axis):
    """Install logical-axis rules.  ``name_to_axis`` values are mesh axis
    names, tuples of axis names, or None (replicated)."""
    prev = _current()
    _STATE.rules = {"mesh": mesh, "map": dict(name_to_axis)}
    try:
        yield
    finally:
        _STATE.rules = prev


def axis_for(name: Optional[str]):
    st = _current()
    if st is None or name is None:
        return None
    return st["map"].get(name)


def logical(x, *names):
    """Constrain ``x`` to the sharding implied by logical axis ``names``
    (one per dim; None = replicated).  No-op outside a rules context."""
    st = _current()
    if st is None:
        return x
    assert len(names) == x.ndim, (names, x.shape)
    spec = P(*[st["map"].get(n) for n in names])
    return jax.lax.with_sharding_constraint(x, NamedSharding(st["mesh"], spec))


def tp_size() -> int:
    """Size of the tensor-parallel ('model') axis under the current rules
    (1 outside a context — keeps head-sharding decisions trivially true)."""
    st = _current()
    if st is None:
        return 1
    mesh = st["mesh"]
    return int(mesh.shape["model"]) if "model" in mesh.axis_names else 1
