"""Gemma-3 27B [hf:google/gemma-3-*-pt] — dense, 5:1 local:global, 128k ctx.

62L  d_model=5376  32H (GQA kv=16, head_dim=128)  d_ff=21504  vocab=262144.
Five sliding-window (1024) layers per global layer -> only ~1/6 of layers
hold full-length KV, so long_500k runs (ring caches keep SWA layers
O(window); global-layer KV shards seq over 'data').
"""

from repro.configs import ArchSpec
from repro.models import ModelConfig

ARCH = ArchSpec(
    name="gemma3-27b",
    family="dense",
    source="hf:google/gemma-3-1b-pt (family config)",
    model=ModelConfig(
        name="gemma3-27b",
        num_layers=62,
        d_model=5376,
        num_heads=32,
        num_kv_heads=16,
        head_dim=128,
        d_ff=21504,
        vocab_size=262144,
        mlp_type="geglu",
        qk_norm=True,
        layer_pattern=("swa", "swa", "swa", "swa", "swa", "attn"),
        window=1024,
        rope_theta=1_000_000.0,
        long_context_ok=True,
    ),
    smoke=ModelConfig(
        name="gemma3-smoke",
        num_layers=6,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=256,
        vocab_size=512,
        mlp_type="geglu",
        qk_norm=True,
        layer_pattern=("swa", "swa", "attn"),
        window=8,
        remat=False,
    ),
    microbatches=16,
    moment_dtype="bfloat16",
    notes="5:1 local:global; 1024-token sliding window; GeGLU; qk-norm",
)
