"""Atomic, async, elastic checkpointing (no orbax dependency — the substrate
is built here, per scope rules).

Layout (one directory per step)::

    ckpt_dir/
      step_000100/
        manifest.json       # step, data position, PRNG key, tree structure,
                            # mesh shape, config fingerprint, wall time
        arrays.npz          # flattened leaves keyed by tree path
      step_000200/ ...
      LATEST                # text file: the last COMPLETE step directory

Atomicity: write into ``<dir>.tmp``, fsync, ``os.rename`` (atomic on POSIX),
then update LATEST — a crash mid-save never corrupts the previous
checkpoint and never leaves a half checkpoint visible.

Async: ``CheckpointManager.save_async`` snapshots leaves to host memory
(``np.asarray`` blocks only for device->host copy), then a daemon thread
does the serialisation/fsync while training continues.  ``wait()`` joins —
called before the next save and at exit.

Elasticity: ``restore_checkpoint`` returns host numpy leaves + manifest; the
caller re-``device_put``s with NEW shardings — restoring a 512-chip
checkpoint onto any other mesh is a pure reshard (tested by reshaping
between virtual-device meshes in tests/test_checkpoint.py).
"""

from __future__ import annotations

import json
import os
import threading
import time
from typing import Optional

import jax
import numpy as np

from repro.compat import keystr_simple


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    items = [(keystr_simple(path), leaf) for path, leaf in flat]
    return items, treedef


def _tree_paths(treedef, items):
    return [k for k, _ in items]


def save_checkpoint(ckpt_dir: str, step: int, tree, *, extra: Optional[dict] = None) -> str:
    """Synchronous atomic save.  Returns the final checkpoint path."""
    items, _ = _flatten_with_paths(tree)
    host = {k: np.asarray(v) for k, v in items}
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    np.savez(os.path.join(tmp, "arrays.npz"), **host)
    manifest = {
        "step": int(step),
        "keys": sorted(host),
        "shapes": {k: list(v.shape) for k, v in host.items()},
        "dtypes": {k: str(v.dtype) for k, v in host.items()},
        "saved_unix_time": time.time(),
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):  # overwrite-resave of the same step
        os.rename(final, final + f".old.{int(time.time()*1e6)}")
    os.rename(tmp, final)
    with open(os.path.join(ckpt_dir, "LATEST.tmp"), "w") as f:
        f.write(os.path.basename(final))
        f.flush()
        os.fsync(f.fileno())
    os.rename(os.path.join(ckpt_dir, "LATEST.tmp"), os.path.join(ckpt_dir, "LATEST"))
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    marker = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(marker):
        return None
    with open(marker) as f:
        name = f.read().strip()
    if not os.path.isdir(os.path.join(ckpt_dir, name)):
        return None
    return int(name.split("_")[-1])


def restore_checkpoint(ckpt_dir: str, step: Optional[int] = None, *, template=None):
    """Returns (tree_or_dict, manifest).

    With ``template`` (a pytree of like-structured arrays/ShapeDtypeStructs)
    the host arrays are unflattened into that structure; otherwise a flat
    ``{path: ndarray}`` dict is returned.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(path, "arrays.npz")) as z:
        host = {k: z[k] for k in z.files}
    if template is None:
        return host, manifest
    items, treedef = _flatten_with_paths(template)
    leaves = []
    for key, tmpl in items:
        if key not in host:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = host[key]
        if tuple(arr.shape) != tuple(tmpl.shape):
            raise ValueError(f"shape mismatch for {key}: ckpt {arr.shape} vs template {tmpl.shape}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest


class CheckpointManager:
    """Async double-buffered writer with retention.

    One in-flight save at a time (``save_async`` joins the previous one
    first — back-pressure, never unbounded memory).  ``keep`` most recent
    checkpoints are retained; older ones are deleted only AFTER a newer
    save is complete, so there is always a restorable checkpoint on disk.
    """

    def __init__(self, ckpt_dir: str, *, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        os.makedirs(ckpt_dir, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save_async(self, step: int, tree, *, extra: Optional[dict] = None):
        self.wait()
        # Snapshot to host NOW (device buffers may be donated next step).
        items, _ = _flatten_with_paths(tree)
        host = {k: np.asarray(v) for k, v in items}

        def work():
            try:
                save_checkpoint(self.ckpt_dir, step, host, extra=extra)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def save(self, step: int, tree, *, extra: Optional[dict] = None) -> str:
        self.wait()
        path = save_checkpoint(self.ckpt_dir, step, tree, extra=extra)
        self._gc()
        return path

    def _gc(self):
        steps = sorted(
            d for d in os.listdir(self.ckpt_dir)
            if d.startswith("step_") and not d.endswith(".tmp") and ".old." not in d
        )
        for stale in steps[: -self.keep] if self.keep > 0 else []:
            full = os.path.join(self.ckpt_dir, stale)
            for root, dirs, files in os.walk(full, topdown=False):
                for f in files:
                    os.unlink(os.path.join(root, f))
                for d in dirs:
                    os.rmdir(os.path.join(root, d))
            os.rmdir(full)
