"""Llama-4 Maverick 400B-A17B [hf:meta-llama/Llama-4-*] — MoE 128e top-1.

48L  d_model=5120  40H (GQA kv=8, head_dim=128)  d_ff=8192 per expert,
vocab=202048, 128 experts top-1 + 1 shared expert, early fusion.
Interleaved chunked-local attention (3 local : 1 global, iRoPE-style) is
modelled as SWA(8192):global 3:1 -> long_500k runs.

Memory posture at 256 chips (16 GB HBM): 2-D sharded params (TP x FSDP)
+ bf16 optimizer moments + 16 microbatches (DESIGN.md §8).
"""

from repro.configs import ArchSpec
from repro.models import ModelConfig

ARCH = ArchSpec(
    name="llama4-maverick-400b-a17b",
    family="moe",
    source="hf:meta-llama/Llama-4-Scout-17B-16E (family config, Maverick sizes)",
    model=ModelConfig(
        name="llama4-maverick-400b-a17b",
        num_layers=48,
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=202048,
        mlp_type="swiglu",
        layer_pattern=("swa", "swa", "swa", "attn"),
        window=8192,  # chunked-local approximated as sliding window
        num_experts=128,
        top_k=1,
        num_shared_experts=1,
        moe_layer_period=2,  # interleaved MoE: every other layer routes
        d_ff_dense=16384,  # dense-layer FFN width (intermediate_size_mlp)
        rope_theta=500_000.0,
        long_context_ok=True,
    ),
    smoke=ModelConfig(
        name="llama4-smoke",
        num_layers=4,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=128,
        vocab_size=512,
        mlp_type="swiglu",
        layer_pattern=("swa", "attn"),
        window=8,
        num_experts=8,
        top_k=1,
        num_shared_experts=1,
        moe_layer_period=2,
        d_ff_dense=256,
        remat=False,
    ),
    microbatches=16,
    moment_dtype="bfloat16",
    notes="128e top-1 + shared expert; 3:1 chunked-local:global; "
          "EP = 8 experts/chip at TP16",
)
