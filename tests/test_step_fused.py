"""Fused SMC step (``Resampler.step``) quality gate (DESIGN.md §12).

Contract under test, over the FULL family × backend matrix:

  1. **composition parity** — ``step(key, log_w, p, thr)`` is bit-identical
     to the normalise → ESS → branch → ``apply`` composition on the SAME
     backend, for single and explicit-key rows forms, at thresholds that
     take both branches;
  2. **no-op branch** — when ``ess_norm >= thr`` the particles come back
     bit-identical, ancestors are the identity permutation, the logZ
     increment is zero, and the output does not depend on the key (the key
     is consumed, but only the taken branch's draws are selected);
  3. **threshold edges** — ``thr=0.0`` never fires (strict ``<``),
     ``thr=1.0`` does not fire on uniform weights (ess_norm == 1 exactly),
     and a population EXACTLY at threshold does not fire;
  4. **degenerate weights** (hypothesis, pinned-grid fallback) — all mass
     on one particle, all-equal, -inf-except-one and subnormal log-weights
     produce finite normalised weights / ESS / increment on every backend,
     with step ≡ composition throughout;
  5. **single launch** — on the pallas backend the WHOLE step traces to
     exactly ONE ``pallas_call`` for every family (the tentpole claim);
  6. **consumers** — the filter/AIS/decode resample paths contain no
     ``lax.cond`` around the resampler and ride ``step``/``step_rows``;
     the analytic memory model says fused < composed.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import count_pallas_calls
from repro.core.metrics import (
    degenerate_log_weights,
    effective_sample_size,
    log_mean_weight,
    log_weights_from_linear,
    max_normalised_weight,
    normalise_log_weights,
    unique_ancestor_count,
)
from repro.obs.stats import stats_from_vector
from repro.core.resamplers.batched import split_batch_keys
from repro.core.spec import spec_for_backend
from repro.kernels.common import MAX_VMEM_STATE, STATE_PLANE_TILE, TILE

N = 2 * TILE
BATCH = 3
ITERS = 8
MAX_ITERS = 24

FAMILIES = (
    "megopolis",
    "metropolis",
    "metropolis_c1",
    "metropolis_c2",
    "rejection",
    "multinomial",
    "systematic",
    "improved_systematic",
    "stratified",
    "residual",
)
BACKENDS = ("reference", "xla", "pallas_interpret")
#: The DESIGN.md §14 compression axis the parity tests sweep.
PLANE_DTYPES_TESTED = ("float32", "bfloat16")


def _build(name, backend, num_iters=ITERS, plane_dtype="float32"):
    return spec_for_backend(name, backend, num_iters=num_iters,
                            max_iters=MAX_ITERS, plane_dtype=plane_dtype).build()


@pytest.fixture(scope="module")
def lw_spread():
    """Concentrated log-weights: ess_norm ≈ 0.07, so mid thresholds fire."""
    return jax.random.normal(jax.random.PRNGKey(11), (N,)) * 2.0


@pytest.fixture(scope="module")
def lw_flat():
    """Near-uniform log-weights: ess_norm ≈ 1, so mid thresholds do NOT fire."""
    return jax.random.normal(jax.random.PRNGKey(12), (N,)) * 0.01


@pytest.fixture(scope="module")
def lw_bank():
    return jax.random.normal(jax.random.PRNGKey(13), (BATCH, N)) * 2.0


@pytest.fixture(scope="module")
def p_single():
    return jax.random.normal(jax.random.PRNGKey(14), (N, 4))


@pytest.fixture(scope="module")
def p_bank():
    return jax.random.normal(jax.random.PRNGKey(15), (BATCH, N, 4))


def _assert_equal(a, b):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _assert_tree_equal(got, exp):
    """Bit-exact over every leaf (particles, ancestors, StepStats)."""
    for g, e in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(exp)):
        _assert_equal(g, e)


def _composed_step(r, key, log_w, particles, thr):
    """The oracle: normalise → ESS → branch → apply, from shared metrics
    helpers and the SAME backend's fused apply — what ``step`` must equal
    bit for bit, including the §15 ``StepStats`` record.  Inputs land on
    the plane-dtype grid first (DESIGN.md §14, identity at f32);
    ``r.apply`` re-lands the normalised weights on the same grid,
    matching the fused step's in-kernel requantise."""
    log_w = r.quantise(log_w)
    particles = r.quantise(particles)
    n = log_w.shape[-1]
    ess_n = effective_sample_size(log_w) / jnp.float32(n)
    do = ess_n < thr
    w = normalise_log_weights(log_w)
    p_res, a_res = r.apply(key, w, particles)
    ancestors = jnp.where(do, a_res, jnp.arange(n, dtype=jnp.int32))
    p_out = jnp.where(do, p_res, particles)
    incr = jnp.where(do, log_mean_weight(log_w), jnp.float32(0.0))
    stats4 = jnp.stack([
        ess_n,
        incr,
        jnp.where(do, jnp.float32(1.0), jnp.float32(0.0)),
        max_normalised_weight(log_w),
    ])
    return p_out, ancestors, stats_from_vector(
        stats4, unique_ancestor_count(ancestors), degenerate_log_weights(log_w)
    )


# ------------------------------------------------- 1. composition parity
@pytest.mark.parametrize("plane_dtype", PLANE_DTYPES_TESTED)
@pytest.mark.parametrize("thr", (0.0, 0.7, 2.0))
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("name", FAMILIES)
def test_step_single_matches_composition(name, backend, thr, plane_dtype,
                                         lw_spread, p_single, base_key):
    r = _build(name, backend, plane_dtype=plane_dtype)
    exp = _composed_step(r, base_key, lw_spread, p_single, thr)
    got = r.step(base_key, lw_spread, p_single, thr)
    _assert_tree_equal(got, exp)


@pytest.mark.parametrize("plane_dtype", PLANE_DTYPES_TESTED)
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("name", FAMILIES)
def test_step_rows_matches_single(name, backend, plane_dtype, lw_bank, p_bank,
                                  base_key):
    """step_rows row b == step(keys[b], ...) — the filter-bank contract;
    each row takes its OWN branch."""
    r = _build(name, backend, plane_dtype=plane_dtype)
    keys = split_batch_keys(base_key, BATCH)
    got = r.step_rows(keys, lw_bank, p_bank, 0.7)
    for b in range(BATCH):
        exp = r.step(keys[b], lw_bank[b], p_bank[b], 0.7)
        for g, e in zip(jax.tree_util.tree_leaves(got),
                        jax.tree_util.tree_leaves(exp)):
            _assert_equal(g[b], e)


@pytest.mark.parametrize("name", ("megopolis", "metropolis", "residual"))
def test_step_rows_mixed_branches(name, p_bank, base_key):
    """A bank whose rows straddle the threshold: concentrated rows resample,
    the flat row comes back identity — in the SAME launch."""
    lw = jnp.stack([
        jax.random.normal(jax.random.PRNGKey(31), (N,)) * 2.0,
        jax.random.normal(jax.random.PRNGKey(32), (N,)) * 0.01,
        jax.random.normal(jax.random.PRNGKey(33), (N,)) * 2.0,
    ])
    r = _build(name, "pallas_interpret")
    keys = split_batch_keys(base_key, BATCH)
    p_out, anc, stats = r.step_rows(keys, lw, p_bank, 0.7)
    fired = np.asarray(stats.ess_norm) < 0.7
    assert list(fired) == [True, False, True]
    assert list(np.asarray(stats.resampled)) == [1.0, 0.0, 1.0]
    _assert_equal(anc[1], jnp.arange(N, dtype=jnp.int32))
    _assert_equal(p_out[1], p_bank[1])
    assert float(stats.log_evidence_incr[1]) == 0.0
    assert int(stats.survivors[1]) == N  # identity ancestors: all survive
    assert not np.array_equal(np.asarray(anc[0]), np.arange(N))
    assert int(stats.survivors[0]) < N  # a real resample drops particles


# ------------------------------------------------------- 2. no-op branch
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("name", FAMILIES)
def test_step_noop_branch(name, backend, lw_flat, p_single, base_key):
    """ess_norm >= thr: particles bit-identical, identity ancestors,
    incr == 0, and the result is key-independent (the key is consumed but
    the untaken branch's draws are discarded)."""
    r = _build(name, backend)
    p_out, anc, stats = r.step(base_key, lw_flat, p_single, 0.5)
    assert float(stats.ess_norm) >= 0.5
    _assert_equal(p_out, p_single)
    _assert_equal(anc, jnp.arange(N, dtype=jnp.int32))
    assert float(stats.log_evidence_incr) == 0.0
    assert float(stats.resampled) == 0.0
    assert int(stats.survivors) == N
    other = r.step(jax.random.PRNGKey(999), lw_flat, p_single, 0.5)
    _assert_tree_equal(other, (p_out, anc, stats))


# ---------------------------------------------------- 3. threshold edges
@pytest.mark.parametrize("backend", ("reference", "pallas_interpret"))
@pytest.mark.parametrize("name", ("megopolis", "rejection", "systematic"))
def test_step_threshold_edges(name, backend, lw_spread, p_single, base_key):
    r = _build(name, backend)
    # thr = 0.0 never fires: ess_norm > 0 and the trigger is strict <
    p_out, anc, stats = r.step(base_key, lw_spread, p_single, 0.0)
    _assert_equal(p_out, p_single)
    _assert_equal(anc, jnp.arange(N, dtype=jnp.int32))
    assert float(stats.log_evidence_incr) == 0.0
    # thr = 1.0 on exactly-uniform weights: ess_norm == 1.0 exactly (f32
    # integer sums are exact at this N), strict < does not fire
    lw_uniform = jnp.zeros((N,), jnp.float32)
    p_out, anc, stats = r.step(base_key, lw_uniform, p_single, 1.0)
    assert float(stats.ess_norm) == 1.0
    _assert_equal(p_out, p_single)
    _assert_equal(anc, jnp.arange(N, dtype=jnp.int32))
    # exactly AT threshold: strict < does not fire
    ess_thr = effective_sample_size(lw_spread) / jnp.float32(N)
    p_out, anc, _ = r.step(base_key, lw_spread, p_single, ess_thr)
    _assert_equal(p_out, p_single)
    # nudge one ulp above: fires
    above = jnp.nextafter(ess_thr, jnp.float32(2.0))
    _, anc_fire, stats_fire = r.step(base_key, lw_spread, p_single, above)
    assert not np.array_equal(np.asarray(anc_fire), np.arange(N))
    assert float(stats_fire.log_evidence_incr) != 0.0
    assert float(stats_fire.resampled) == 1.0


# ------------------------------------------------- 'auto' num_iters rows
@pytest.mark.parametrize("name", ("megopolis", "metropolis", "metropolis_c1"))
def test_step_auto_iters_rows(name, lw_bank, p_bank, base_key):
    """num_iters='auto' resolves eq. (3) PER ROW from each row's normalised
    weights; rows stay bit-identical to the single 'auto' step."""
    r = _build(name, "pallas_interpret", num_iters="auto")
    keys = split_batch_keys(base_key, BATCH)
    got = r.step_rows(keys, lw_bank, p_bank, 0.7)
    for b in range(BATCH):
        exp = r.step(keys[b], lw_bank[b], p_bank[b], 0.7)
        for g, e in zip(jax.tree_util.tree_leaves(got),
                        jax.tree_util.tree_leaves(exp)):
            _assert_equal(g[b], e)


# ------------------------------------------- 4. degenerate-weight safety
def _degenerate_cases(n):
    one_hot = jnp.full((n,), -jnp.inf).at[n // 3].set(0.0)
    return {
        "all_mass_on_one": jnp.full((n,), -100.0).at[7].set(0.0),
        "all_equal": jnp.full((n,), -3.5),
        "inf_except_one": one_hot,
        "subnormal": jnp.full((n,), -1e-40),
    }


@pytest.mark.parametrize("case", sorted(_degenerate_cases(4)))
def test_metrics_degenerate_weights_finite(case):
    """The shared normalise/ESS helpers directly: every degenerate pattern
    yields finite normalised weights, ESS in [1, N], finite log-mean."""
    lw = _degenerate_cases(N)[case]
    w = normalise_log_weights(lw)
    assert bool(jnp.all(jnp.isfinite(w)))
    assert float(jnp.max(w)) == 1.0  # the argmax weight survives exactly
    ess = effective_sample_size(lw)
    assert bool(jnp.isfinite(ess))
    assert 1.0 - 1e-4 <= float(ess) <= N * (1 + 1e-6)
    assert bool(jnp.isfinite(log_mean_weight(lw)))


def test_log_weights_from_linear_guards_zero():
    """The centralised linear→log guard: zero and subnormal weights floor
    at 1e-30 (f32 normal range) instead of producing -inf / flushed logs."""
    w = jnp.array([0.0, 1e-38, 1.0], jnp.float32)
    lw = log_weights_from_linear(w)
    assert bool(jnp.all(jnp.isfinite(lw)))
    assert float(lw[2]) == 0.0
    ess = effective_sample_size(lw)
    assert bool(jnp.isfinite(ess))


def _check_degenerate_step(name, backend, case, thr):
    lw = _degenerate_cases(N)[case]
    p = jax.random.normal(jax.random.PRNGKey(41), (N, 2))
    r = _build(name, backend)
    key = jax.random.PRNGKey(42)
    p_out, anc, stats = r.step(key, lw, p, thr)
    assert bool(jnp.isfinite(stats.ess_norm))
    assert bool(jnp.isfinite(stats.log_evidence_incr))
    assert bool(jnp.isfinite(stats.max_weight))
    assert bool(jnp.all(jnp.isfinite(p_out)))
    exp = _composed_step(r, key, lw, p, thr)
    _assert_tree_equal((p_out, anc, stats), exp)


_DEGEN_FAMILIES = ("megopolis", "metropolis", "rejection", "systematic", "residual")


# The §16 COLLAPSED signatures: non-finite max, so the uniform fallback
# engages (kernel-side deg latch ≡ host normalise_log_weights fallback);
# the fused step must STILL match the composed oracle bit for bit,
# including a truthful non-finite evidence increment when the resample
# fires, and must set StepStats.degenerate.
def _collapsed_cases(n):
    return {
        "all_nan": jnp.full((n,), jnp.nan),
        "all_neg_inf": jnp.full((n,), -jnp.inf),
        "pos_inf_entry": jnp.zeros((n,)).at[11].set(jnp.inf),
    }


@pytest.mark.parametrize("case", sorted(_collapsed_cases(4)))
@pytest.mark.parametrize("thr", (0.5, 2.0))
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("name", _DEGEN_FAMILIES)
def test_step_collapsed_banks_match_composition(name, backend, thr, case,
                                                base_key):
    lw = _collapsed_cases(N)[case]
    p = jax.random.normal(jax.random.PRNGKey(43), (N, 2))
    r = _build(name, backend)
    got = r.step(base_key, lw, p, thr)
    exp = _composed_step(r, base_key, lw, p, thr)
    _assert_tree_equal(got, exp)
    _, anc, stats = got
    assert bool(jnp.asarray(stats.degenerate))
    # the fallback bank is uniform: ESS pegs at 1, max weight at 1/N
    assert float(stats.ess_norm) == 1.0
    assert float(stats.max_weight) == np.float32(1.0 / N)
    assert bool(jnp.all((anc >= 0) & (anc < N)))


@pytest.mark.parametrize("case", sorted(_collapsed_cases(4)))
@pytest.mark.parametrize("name", ("megopolis", "systematic"))
def test_step_collapsed_banks_bf16_plane(name, case, base_key):
    """The §14 compressed plane composes with the §16 fallback: the
    substitution precedes the requantise in kernel and host alike."""
    lw = _collapsed_cases(N)[case]
    p = jax.random.normal(jax.random.PRNGKey(44), (N, 2))
    r = _build(name, "pallas_interpret", plane_dtype="bfloat16")
    got = r.step(base_key, lw, p, 2.0)
    exp = _composed_step(r, base_key, lw, p, 2.0)
    _assert_tree_equal(got, exp)
    assert bool(jnp.asarray(got[2].degenerate))

try:
    from hypothesis import given, settings, strategies as st

    @given(
        name=st.sampled_from(_DEGEN_FAMILIES),
        backend=st.sampled_from(BACKENDS),
        case=st.sampled_from(sorted(_degenerate_cases(4))),
        thr=st.sampled_from([0.0, 0.5, 1.0]),
    )
    @settings(max_examples=25, deadline=None)
    def test_step_degenerate_weights(name, backend, case, thr):
        _check_degenerate_step(name, backend, case, thr)

except ImportError:
    # hypothesis absent (CI installs it): pinned grid instead.
    @pytest.mark.parametrize("case", sorted(_degenerate_cases(4)))
    @pytest.mark.parametrize("backend", BACKENDS)
    @pytest.mark.parametrize("name", _DEGEN_FAMILIES)
    def test_step_degenerate_weights(name, backend, case):
        _check_degenerate_step(name, backend, case, 0.5)


# ------------------------------------------------------ 5. single launch
@pytest.mark.parametrize("plane_dtype", PLANE_DTYPES_TESTED)
@pytest.mark.parametrize("name", FAMILIES)
def test_step_is_single_launch(name, plane_dtype, lw_spread, p_single, base_key):
    """THE tentpole gate: on the pallas backend the whole reweight → ESS →
    conditional resample → state copy step traces to exactly ONE
    pallas_call — including the prefix-sum family, whose composed apply
    alone is 2 launches (4 for residual) plus host glue.  Compression
    narrows the tiles, never adds a launch (DESIGN.md §14)."""
    r = _build(name, "pallas_interpret", plane_dtype=plane_dtype)
    jaxpr = jax.make_jaxpr(lambda k, lw, p: r.step(k, lw, p, 0.5))(
        base_key, lw_spread, p_single
    )
    assert count_pallas_calls(jaxpr) == 1


@pytest.mark.parametrize("name", ("megopolis", "metropolis", "rejection"))
def test_step_rows_is_single_launch(name, lw_bank, p_bank, base_key):
    """The bank form on the leading-batch-grid families is ONE launch too."""
    r = _build(name, "pallas_interpret")
    keys = split_batch_keys(base_key, BATCH)
    jaxpr = jax.make_jaxpr(lambda k, lw, p: r.step_rows(k, lw, p, 0.5))(
        keys, lw_bank, p_bank
    )
    assert count_pallas_calls(jaxpr) == 1


# ------------------------------------------------- validation + residency
@pytest.mark.parametrize("backend", ("reference", "pallas_interpret"))
def test_step_rows_rejects_short_key_array(backend, lw_bank, p_bank, base_key):
    r = _build("megopolis", backend)
    keys = split_batch_keys(base_key, BATCH - 1)
    with pytest.raises(ValueError, match="one key per row"):
        r.step_rows(keys, lw_bank, p_bank, 0.5)


def test_step_state_residency_cap(base_key):
    d = MAX_VMEM_STATE // N // STATE_PLANE_TILE * STATE_PLANE_TILE + STATE_PLANE_TILE
    p = jnp.zeros((N, d), jnp.float32)
    lw = jnp.zeros((N,), jnp.float32)
    r = _build("megopolis", "pallas_interpret")
    with pytest.raises(ValueError, match="VMEM"):
        r.step(base_key, lw, p, 0.5)


# ----------------------------------------------------------- 6. consumers
@pytest.mark.parametrize(
    "consumer",
    (
        "ais.run_smc_sampler",
        "ais.run_smc_sampler_bank",
        "pf.step_conditional",
        "pf.run_filter_bank",
    ),
)
def test_consumer_resample_paths_use_fused_step(consumer):
    """No host-side cond around the resampler, no ancestor round-trip, and
    exactly ONE launch (which only the fused step/step_rows path can
    achieve): checked on the consumers' traced jaxprs by the DESIGN.md §13
    analyzer, not by grepping their source."""
    from repro.analysis import audit_consumers

    (rep,) = audit_consumers(names=[consumer])
    assert rep.ok, rep.violations
    assert rep.launches == 1
    assert rep.cond_count == 0
    assert rep.tainted_gathers == 0


def test_decode_resample_path_is_fused():
    """smc_decode: one launch, no host cond; its cache gathers ARE
    ancestor-indexed (mixed-dtype KV pytree) — allowed by its contract and
    priced, not forbidden."""
    from repro.analysis import audit_consumers

    (rep,) = audit_consumers(names=["smc.decode"])
    assert rep.ok, rep.violations
    assert rep.launches == 1 and rep.cond_count == 0
    assert rep.tainted_gathers > 0


def test_memmodel_fused_step_beats_composed():
    from repro.launch.memmodel import smc_step_bytes

    for n in (1 << 10, 1 << 16, 1 << 20):
        for d in (1, 4, 32):
            fused = smc_step_bytes(n, d, fused=True)
            composed = smc_step_bytes(n, d, fused=False)
            assert fused["total"] < composed["total"]
            # the normalised-weight buffer + the ancestor vector
            assert composed["total"] - fused["total"] == n * 8


def test_conditional_filter_step_matches_manual_replay(base_key):
    """End-to-end: a conditional-SIR ParticleFilter on the pallas backend
    steps through the fused path and equals a manual replay through the
    composed normalise → ESS → branch → apply arithmetic."""
    from repro.core.spec import MegopolisSpec
    from repro.pf import ParticleFilter, ungm

    pf = ParticleFilter(
        model=ungm(),
        num_particles=TILE,
        resampler=MegopolisSpec(num_iters=ITERS, segment=1024,
                                backend="pallas_interpret"),
        ess_threshold=0.5,
    )
    particles = pf.model.init(jax.random.PRNGKey(51), TILE)
    log_w0 = jnp.zeros((TILE,), jnp.float32)
    z, t = jnp.float32(0.3), jnp.float32(1.0)
    x_bar, log_w1, est, stats = pf.step_conditional(base_key, particles, log_w0, z, t)
    # manual replay
    k_pred, k_res = jax.random.split(base_key)
    x = pf.model.transition(k_pred, particles, t)
    lw = log_w0 + log_weights_from_linear(pf.model.likelihood(z, x, t))
    exp = _composed_step(pf._built, k_res, lw, x, 0.5)
    _assert_equal(x_bar, exp[0])
    _assert_tree_equal(stats, exp[2])
    wn = normalise_log_weights(lw)
    _assert_equal(est, jnp.sum(wn * x) / jnp.sum(wn))
    fired = bool(stats.ess_norm < 0.5)
    _assert_equal(log_w1, jnp.zeros_like(lw) if fired else lw)
