"""Nested profiler spans over dispatch boundaries (DESIGN.md §15).

``span(name)`` wraps a region in BOTH ``jax.profiler.TraceAnnotation`` (so
host-side work lands on the profiler timeline under ``name``) and
``jax.named_scope`` (so the traced ops carry ``name`` into the jaxpr/HLO
metadata and XLA traces attribute device time to it).  ``Resampler``
dispatch opens one per public entry, named::

    family/backend/entry/plane_dtype     e.g. megopolis/pallas/step/bfloat16

Disabled (the default) it is an identity context manager — no profiler
import, no named_scope, zero trace-time cost — so the §12/§13 structural
gates (identical-jaxpr comparisons, launch-count audits) see the exact
same program whether or not a profiler ever attaches.  Enable with
``REPRO_TRACE=1`` in the environment or ``enable_tracing()`` in code.
"""

from __future__ import annotations

import contextlib
import os

_enabled = os.environ.get("REPRO_TRACE", "0") not in ("", "0", "false", "no")


def enable_tracing(on: bool = True) -> None:
    """Turn span emission on/off process-wide (overrides ``REPRO_TRACE``)."""
    global _enabled
    _enabled = bool(on)


def tracing_enabled() -> bool:
    return _enabled


@contextlib.contextmanager
def span(name: str):
    """Profiler + named_scope span around a region; identity when disabled."""
    if not _enabled:
        yield
        return
    import jax

    with jax.profiler.TraceAnnotation(name), jax.named_scope(name):
        yield


def dispatch_span(family: str, backend: str, entry: str, plane_dtype="float32"):
    """The canonical dispatch span: ``family/backend/entry/plane_dtype``."""
    return span(f"{family}/{backend}/{entry}/{plane_dtype}")
