"""Resilience layer (DESIGN.md §16): degeneracy guards, backend fallback
chains, deterministic fault injection, and crash-consistent long runs.

Import discipline: ``kernels/common`` imports the error taxonomy from this
package, and ``core/spec`` imports the guard-event recorder — so only the
import-light leaves (``errors``, ``guards``) load eagerly here.  The heavy
modules (``fallback`` builds specs, ``faults``/``checkpointing`` pull in
consumers) resolve lazily through PEP 562 ``__getattr__`` to keep the
kernels → resilience → spec → kernels cycle broken.
"""

from __future__ import annotations

from repro.resilience.errors import (
    BackendUnavailable,
    CorruptAncestorsError,
    InjectedCrash,
    KernelLoweringError,
    ResilienceError,
    VmemBudgetExceeded,
)
from repro.resilience.guards import (
    GUARD_POLICIES,
    ResilienceEvent,
    guard_events_enabled,
    maybe_emit_guard_event,
    record_resilience_events,
)

_LAZY = {
    "DEFAULT_LADDER": "repro.resilience.fallback",
    "build_with_fallback": "repro.resilience.fallback",
    "classify_backend_error": "repro.resilience.fallback",
    "CheckpointPolicy": "repro.resilience.checkpointing",
    "checkpointed_scan": "repro.resilience.checkpointing",
    "FAULT_CLASSES": "repro.resilience.faults",
    "all_nan_bank": "repro.resilience.faults",
    "all_neg_inf_bank": "repro.resilience.faults",
    "bitflip_states": "repro.resilience.faults",
    "inject_inf_weights": "repro.resilience.faults",
    "inject_nan_weights": "repro.resilience.faults",
    "near_collapse_bank": "repro.resilience.faults",
    "one_hot_bank": "repro.resilience.faults",
    "poison_ancestors": "repro.resilience.faults",
    "validate_ancestors": "repro.resilience.faults",
}


def __getattr__(name: str):
    module = _LAZY.get(name)
    if module is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib

    return getattr(importlib.import_module(module), name)


__all__ = [
    "BackendUnavailable",
    "CorruptAncestorsError",
    "GUARD_POLICIES",
    "InjectedCrash",
    "KernelLoweringError",
    "ResilienceError",
    "ResilienceEvent",
    "VmemBudgetExceeded",
    "guard_events_enabled",
    "maybe_emit_guard_event",
    "record_resilience_events",
    *sorted(_LAZY),
]
