"""Fused resample+gather (`apply`) vs index + ``jnp.take`` (DESIGN.md §11).

    PYTHONPATH=src:. python benchmarks/fused_gather_bench.py [--quick|--smoke]

Three result surfaces per (family × backend × state_dim) cell:

  * **wall time** — ``apply`` vs the index + ``jnp.take`` composition, both
    jitted, on the CPU backends.  On reference/xla the fused call IS the
    composition (bit-identical oracle), so these cells pin "no slower" by
    construction and measure harness noise.  ``pallas_interpret`` wall
    times are reported but NOT perf-gated: interpret mode is a Python-level
    kernel simulator that re-fetches the resident state planes every grid
    step, a cost the hardware pipeline does not pay (the plane stack's
    block index is constant — one fetch per launch); see EXPERIMENTS.md
    §Fused-gather.
  * **parity** — every cell (including every interpret cell) asserts
    ``apply`` == take(particles, __call__) bit-exactly.  This is the CI
    perf-smoke gate (--smoke): it fails on mismatch, never on timing.
  * **HBM transaction model** — the paper's own methodology (§5): bytes
    moved per resample step with and without the fused gather, from
    ``launch/memmodel.resample_step_bytes`` — the expected hardware win.

Writes ``out/fused_gather.csv`` + ``out/BENCH_fused_gather.json`` (folded
into ``benchmarks/run.py --json`` trajectories).
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

import time

from benchmarks.common import OUT_DIR, ensure_out, print_table, write_csv
from repro.core.spec import spec_for_backend
from repro.kernels.common import plane_itemsize
from repro.launch.memmodel import resample_step_bytes

#: The DESIGN.md §14 compression axis swept by default.
PLANE_DTYPES = ("float32", "bfloat16")

FAMILIES = (
    "megopolis",
    "metropolis",
    "metropolis_c1",
    "metropolis_c2",
    "rejection",
    "systematic",
    "residual",
)
BACKENDS = ("reference", "xla", "pallas_interpret")
STATE_DIMS = (1, 4, 32)
# CPU cells held to the "no slower" gate: the composition-oracle backends.
TIMED_GATE_BACKENDS = ("reference", "xla")


def _time_pair(fused, unfused, *args, repeats: int):
    """Best-of-``repeats`` wall seconds for the two closures, measured in
    INTERLEAVED rounds with ALTERNATING order — on this CPU whichever
    program runs second in a back-to-back pair reads ~10% faster (cache
    position bias), so a fixed order would systematically skew the ratio.
    On the composition backends the two closures trace to the IDENTICAL
    jaxpr, and alternating min-of-pairs is what makes that read as ~1.0x
    instead of scheduler noise."""
    for _ in range(2):
        jax.block_until_ready(fused(*args))
        jax.block_until_ready(unfused(*args))
    t_f, t_u = [], []
    for i in range(repeats):
        first, second = (fused, unfused) if i % 2 == 0 else (unfused, fused)
        t0 = time.perf_counter()
        jax.block_until_ready(first(*args))
        t1 = time.perf_counter()
        jax.block_until_ready(second(*args))
        t2 = time.perf_counter()
        if i % 2 == 0:
            t_f.append(t1 - t0)
            t_u.append(t2 - t1)
        else:
            t_u.append(t1 - t0)
            t_f.append(t2 - t1)
    return float(np.min(t_f)), float(np.min(t_u))


def _cell(name, backend, state_dim, *, n, num_iters, max_iters, repeats,
          chain: int, plane_dtype: str = "float32"):
    r = spec_for_backend(name, backend, num_iters=num_iters,
                         max_iters=max_iters, plane_dtype=plane_dtype).build()
    key = jax.random.PRNGKey(7)
    w = jax.random.uniform(jax.random.PRNGKey(1), (n,)) + 1e-3
    shape = (n,) if state_dim == 1 else (n, state_dim)
    p = jax.random.normal(jax.random.PRNGKey(2), shape)
    keys = jax.random.split(key, chain)

    # Timed surface: a CHAIN of `chain` resample steps under one jitted
    # lax.scan, each step's output particles feeding the next — the
    # consumer pattern (filter/sampler scans), and enough work per call
    # that sub-millisecond CPU scheduler noise amortises out.
    def fused_chain(p0):
        return jax.lax.scan(lambda q, k: (r.apply(k, w, q)[0], None), p0, keys)[0]

    def unfused_chain(p0):
        def step(q, k):
            a = r(k, w)  # index round-trip + XLA gather
            return jnp.take(q, a, axis=0), None

        return jax.lax.scan(step, p0, keys)[0]

    fused = jax.jit(fused_chain)
    unfused = jax.jit(unfused_chain)

    # Parity first — the CI gate (bit-exact, both outputs), on the EAGER
    # Resampler surface: `apply` composes the very same single/batch
    # callables as the index path there, so this pins the data-path
    # contract.  (Two separately jitted closures are NOT compared for
    # bitness: XLA may constant-fold the prefix-sum family's f32 cumsum
    # differently across programs and legitimately shift a searchsorted
    # boundary by one.)
    got_p, got_a = r.apply(key, w, p)
    want_a = r(key, w)
    np.testing.assert_array_equal(np.asarray(got_a), np.asarray(want_a))
    # Compressed cells gather the QUANTISED plane (DESIGN.md §14): the
    # oracle is take over r.quantise(p) — a no-op at f32.
    np.testing.assert_array_equal(
        np.asarray(got_p), np.asarray(jnp.take(r.quantise(p), want_a, axis=0))
    )

    # "No slower" on the composition backends is proven STRUCTURALLY: the
    # fused and unfused chains must trace to the identical jaxpr (same
    # program => same wall time, deterministically — wall clocks on this
    # class of shared CPU box swing ±30% between identical programs, so a
    # timing gate would only measure the scheduler).  f32 cells only: the
    # compressed fused chain quantises the carried particles each step,
    # which the take-composition above deliberately does not.
    perf_gated = backend in TIMED_GATE_BACKENDS and plane_dtype == "float32"
    identical_program = False
    if perf_gated:
        identical_program = str(jax.make_jaxpr(fused_chain)(p)) == str(
            jax.make_jaxpr(unfused_chain)(p)
        )

    t_fused, t_unfused = _time_pair(fused, unfused, p, repeats=repeats)
    t_fused, t_unfused = t_fused / chain, t_unfused / chain
    wb = plane_itemsize(plane_dtype)
    model_fused = resample_step_bytes(
        n, state_dim, fused=True, state_bytes=wb, weight_bytes=wb)["total"]
    model_unfused = resample_step_bytes(
        n, state_dim, fused=False, state_bytes=wb, weight_bytes=wb)["total"]
    return {
        "family": name,
        "backend": backend,
        "state_dim": state_dim,
        "plane_dtype": plane_dtype,
        "n": n,
        "fused_ms": t_fused * 1e3,
        "unfused_ms": t_unfused * 1e3,
        "speedup": t_unfused / t_fused,
        "model_bytes_fused": model_fused,
        "model_bytes_unfused": model_unfused,
        "model_speedup": model_unfused / model_fused,
        "parity": True,
        "perf_gated": perf_gated,
        "identical_program": identical_program,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="CI scale")
    ap.add_argument("--smoke", action="store_true",
                    help="toy sizes, parity gate only (the perf-smoke CI job)")
    ap.add_argument("--n", type=int, default=None)
    ap.add_argument("--dtypes", type=lambda v: tuple(x for x in v.split(",") if x),
                    default=PLANE_DTYPES,
                    help="comma-separated plane dtypes to sweep "
                         "(default: float32,bfloat16)")
    args = ap.parse_args(argv)

    if args.smoke:
        n, num_iters, max_iters, repeats, chain = 2048, 4, 16, 1, 2
    elif args.quick:
        n, num_iters, max_iters, repeats, chain = 4096, 16, 32, 21, 8
    else:
        n, num_iters, max_iters, repeats, chain = 8192, 16, 64, 25, 12
    if args.n:
        n = args.n

    rows = []
    for dtype in args.dtypes:
        for name in FAMILIES:
            for backend in BACKENDS:
                for d in STATE_DIMS:
                    rows.append(_cell(name, backend, d, n=n, num_iters=num_iters,
                                      max_iters=max_iters, repeats=repeats,
                                      chain=chain, plane_dtype=dtype))
                    print(f"[fused_gather] {name}/{backend}/d={d}@{dtype}: "
                          f"fused {rows[-1]['fused_ms']:.2f}ms "
                          f"unfused {rows[-1]['unfused_ms']:.2f}ms "
                          f"(model {rows[-1]['model_speedup']:.2f}x)")

    print_table(rows, cols=["family", "backend", "state_dim", "plane_dtype",
                            "fused_ms", "unfused_ms", "speedup",
                            "model_speedup"])
    write_csv("fused_gather.csv", rows)
    ensure_out()
    with open(os.path.join(OUT_DIR, "BENCH_fused_gather.json"), "w") as f:
        json.dump({"config": {"n": n, "num_iters": num_iters,
                              "max_iters": max_iters, "repeats": repeats,
                              "chain": chain, "smoke": args.smoke,
                              "plane_dtypes": list(args.dtypes)},
                   "rows": rows}, f, indent=2)

    # The "no slower" gate on the composition-oracle CPU cells: the fused
    # chain must be the IDENTICAL program (deterministic), or — if a
    # backend ever diverges structurally — measurably no slower.
    if not args.smoke:
        slow = [r for r in rows
                if r["perf_gated"] and not r["identical_program"]
                and r["speedup"] < 0.85]
        if slow:
            print("FAILED no-slower gate:",
                  [(r["family"], r["backend"], r["state_dim"], round(r["speedup"], 2))
                   for r in slow])
            raise SystemExit(1)
        n_ident = sum(1 for r in rows if r["identical_program"])
        n_gated = sum(1 for r in rows if r["perf_gated"])
        print(f"no-slower gate: {n_ident}/{n_gated} composition cells are "
              "the identical program (no slower by construction)")
    print("fused_gather: all parity cells bit-exact"
          + ("" if args.smoke else "; no-slower gate passed"))


if __name__ == "__main__":
    main()
