"""SIR / bootstrap particle filter (paper Algorithms 1 and 6).

The modified SIR filter (Alg. 6) drops weight normalisation — the
Metropolis-family resamplers only use weight *ratios* — and estimates the
state as the post-resampling particle mean (uniform weights).

Two execution modes:
  * ``run_filter``: fully jitted ``lax.scan`` over time steps (production).
  * ``run_filter_timed``: per-stage host timing (predict+update / resample /
    estimate) for the paper's Resample-Ratio metric (eq. 25).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import get_resampler


@dataclasses.dataclass(frozen=True)
class StateSpaceModel:
    transition: Callable  # (key, x[N], t) -> x[N]
    observe: Callable  # (key, x[], t) -> z[]       (for ground-truth sim)
    likelihood: Callable  # (z, x[N], t) -> w[N]       (unnormalised)
    init: Callable  # (key, n) -> x[N]
    name: str = "model"


@dataclasses.dataclass(frozen=True)
class ParticleFilter:
    model: StateSpaceModel
    num_particles: int
    resampler: str = "megopolis"
    num_iters: int = 30  # B — fixed application prior (paper §7)
    resampler_kwargs: tuple = ()

    def _resample(self, key, weights):
        fn = get_resampler(self.resampler)
        return fn(key, weights, self.num_iters, **dict(self.resampler_kwargs))

    def step(self, key, particles, z, t):
        """One SIR step (Alg. 6): returns (particles', estimate, weights)."""
        k_pred, k_res = jax.random.split(key)
        # Stage 1: predict + update
        x = self.model.transition(k_pred, particles, t)
        w = self.model.likelihood(z, x, t)
        # Stage 2: resample
        ancestors = self._resample(k_res, w)
        x_bar = jnp.take(x, ancestors, axis=0)
        # Stage 3: estimate (uniform post-resampling weights)
        return x_bar, jnp.mean(x_bar), w


def simulate(key, model: StateSpaceModel, num_steps: int):
    """Ground-truth trajectory + observations."""

    def body(carry, t):
        x, k = carry
        k, k1, k2 = jax.random.split(k, 3)
        x = model.transition(k1, x, t)
        z = model.observe(k2, x, t)
        return (x, k), (x, z)

    k0, key = jax.random.split(key)
    x0 = model.init(k0, 1)[0]
    _, (xs, zs) = jax.lax.scan(body, (x0, key), jnp.arange(1, num_steps + 1, dtype=jnp.float32))
    return xs, zs


def run_filter(key, pf: ParticleFilter, observations: jnp.ndarray):
    """Jitted scan over time; returns estimates f32[T]."""

    def body(carry, inp):
        particles, k = carry
        t, z = inp
        k, ks = jax.random.split(k)
        particles, est, _ = pf.step(ks, particles, z, t)
        return (particles, k), est

    k0, key = jax.random.split(key)
    particles = pf.model.init(k0, pf.num_particles)
    ts = jnp.arange(1, observations.shape[0] + 1, dtype=jnp.float32)
    _, ests = jax.lax.scan(body, (particles, key), (ts, observations))
    return ests


def run_filter_timed(key, pf: ParticleFilter, observations, warmup: int = 2):
    """Per-stage wall timing for the Resample-Ratio metric (paper eq. 25).

    Stages are jitted separately and block_until_ready'd so the split is
    honest; the first ``warmup`` steps are excluded (compile time).
    """
    model = pf.model

    @jax.jit
    def stage1(k, x, z, t):
        x = model.transition(k, x, t)
        return x, model.likelihood(z, x, t)

    @jax.jit
    def stage2(k, x, w):
        a = pf._resample(k, w)
        return jnp.take(x, a, axis=0)

    @jax.jit
    def stage3(x):
        return jnp.mean(x)

    k0, key = jax.random.split(key)
    particles = model.init(k0, pf.num_particles)
    times = {"predict_update": 0.0, "resample": 0.0, "estimate": 0.0}
    ests = []
    for i, z in enumerate(observations):
        key, k1, k2 = jax.random.split(key, 3)
        t = jnp.float32(i + 1)
        t0 = time.perf_counter()
        x, w = stage1(k1, particles, z, t)
        jax.block_until_ready(w)
        t1 = time.perf_counter()
        particles = stage2(k2, x, w)
        jax.block_until_ready(particles)
        t2 = time.perf_counter()
        est = stage3(particles)
        jax.block_until_ready(est)
        t3 = time.perf_counter()
        if i >= warmup:
            times["predict_update"] += t1 - t0
            times["resample"] += t2 - t1
            times["estimate"] += t3 - t2
        ests.append(float(est))
    return jnp.asarray(ests), times
