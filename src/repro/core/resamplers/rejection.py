"""Rejection resampling (Murray): unbiased, needs sup(w), variable time.

Included because the paper positions Metropolis/Megopolis against it (§1):
rejection is unbiased but its per-particle iteration count is a geometric
random variable — divergent control flow on SIMD hardware.  We cap the loop
at ``max_iters`` (exceeding it keeps the last proposal) and report the cap
so callers can validate it is never the binding constraint in benchmarks.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.resamplers.batched import batch_via_vmap


def rejection(
    key: jax.Array,
    weights: jnp.ndarray,
    num_iters: int = 0,
    *,
    max_iters: int = 1024,
) -> jnp.ndarray:
    """Returns int32 ancestors.  ``num_iters`` ignored (API uniformity)."""
    del num_iters
    n = weights.shape[0]
    w_max = jnp.max(weights)
    i = jnp.arange(n, dtype=jnp.int32)

    def cond(state):
        _, done, t = state
        return (~jnp.all(done)) & (t < max_iters)

    key_init, key_loop = jax.random.split(key)

    def body(state):
        k, done, t = state
        kt = jax.random.fold_in(key_loop, t)
        kj, ku = jax.random.split(kt)
        j = jax.random.randint(kj, (n,), 0, n, dtype=jnp.int32)
        u = jax.random.uniform(ku, (n,), weights.dtype)
        accept = (~done) & (u * w_max <= weights[j])
        k = jnp.where(accept, j, k)
        return k, done | accept, t + 1

    # Initial proposal: particle i proposes itself (accept w.p. w_i / w_max).
    u0 = jax.random.uniform(key_init, (n,), weights.dtype)
    done0 = u0 * w_max <= weights[i]
    k, _, _ = jax.lax.while_loop(cond, body, (i, done0, jnp.int32(0)))
    return k


# Batched entry point (DESIGN.md §4).  Under vmap the while_loop runs until
# the LAST row converges with per-row ``done`` masking — the batch-level
# analogue of rejection's divergent-execution-time weakness (§1): one slow
# row stalls the bank, which the bank benchmark makes visible.
rejection_batch = batch_via_vmap(rejection)
