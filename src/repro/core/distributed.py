"""Distributed Megopolis — the paper's coalescing contract at chip level.

The paper coalesces at the warp/segment level; we add one more level of the
same decomposition for a sharded particle population (DESIGN.md §3):

    o  ~ U{0, N-1}           (one global offset per iteration, as in Alg. 5)
    o  = o_shard * L + o_local          (L = particles per shard)
    j  = ((s + o_shard) mod D) * L  +  megopolis_local(i_local, o_local)

Properties preserved: (i) per-iteration ``i -> j`` is a bijection (shard
rotation x within-shard Megopolis bijection); (ii) ``j | o`` is uniform over
[0, N) (``(o_shard, o_local)`` uniform over D x L).  Proposition 1 therefore
carries over verbatim — same B, same convergence rate.

Communication per iteration is ONE contiguous block exchange (the inter-chip
analogue of a coalesced transaction):

  * ``schedule="static"``  — the shard-level offsets are derived from a
    host-known seed at trace time, so each iteration lowers to a single
    ``ppermute`` (1x block traffic).  The within-shard offset stays runtime-
    random.  Theory note: uniformity of ``j`` then holds over the schedule
    draw rather than per-trace; MSE/bias parity is verified empirically.
  * ``schedule="dynamic"`` — shard offsets are runtime-random; the dynamic
    rotation is routed as a hypercube composition of log2(D) conditional
    static ppermutes (exact Proposition-1 uniformity, log2(D)x traffic).

Ancestor payloads: ``gather_ancestors`` (exact, all-gather) or
``island_exchange`` (local resampling + periodic ring mixing, Vergé et al.).
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.compat import axis_size, shard_map
from repro.core.resamplers.megopolis import megopolis_indices
from repro.kernels.common import hash_uniform, key_to_seed, murmur3_fmix


def _rotate_blocks_dynamic(x, shift, axis_name: str, n_shards: int):
    """Rotate shard-local blocks by a *traced* shift: value at shard s ends
    up at shard (s - shift) mod D.  Hypercube: log2(D) conditional hops."""
    assert n_shards & (n_shards - 1) == 0, "shard count must be a power of two"
    bit = 0
    step = 1
    while step < n_shards:
        perm = [(src, (src - step) % n_shards) for src in range(n_shards)]
        x_shifted = lax.ppermute(x, axis_name, perm)
        take = ((shift >> bit) & 1) == 1
        x = jnp.where(take, x_shifted, x)
        bit += 1
        step <<= 1
    return x


def _rotate_blocks_static(x, shift: int, axis_name: str, n_shards: int):
    shift = int(shift) % n_shards
    if shift == 0:
        return x
    perm = [(src, (src - shift) % n_shards) for src in range(n_shards)]
    return lax.ppermute(x, axis_name, perm)


def _static_shard_schedule(seed: int, num_iters: int, n_shards: int) -> list[int]:
    """Host-side deterministic shard-offset schedule (trace-time ints)."""
    out = []
    x = np.uint32(seed)
    for b in range(num_iters):
        x = np.asarray(murmur3_fmix(jnp.uint32(int(x) + b + 1)))
        out.append(int(x) % n_shards)
    return out


def megopolis_shard(
    seed: jnp.ndarray,
    offsets_local: jnp.ndarray,
    offsets_shard,
    local_weights: jnp.ndarray,
    *,
    axis_name: str,
    num_iters: int,
    segment: int = 1024,
    schedule: str = "static",
) -> jnp.ndarray:
    """Runs INSIDE shard_map.  Returns int32[L] GLOBAL ancestor indices.

    ``offsets_local``: int32[B] traced, uniform over [0, L).
    ``offsets_shard``: list[int] (static mode) or int32[B] traced (dynamic).
    """
    n_local = local_weights.shape[0]
    n_shards = axis_size(axis_name)
    s = lax.axis_index(axis_name)
    i_local = jnp.arange(n_local, dtype=jnp.int32)
    i_global = s * n_local + i_local

    k = i_global
    wk = local_weights
    rotated: dict = {}  # static schedule: distinct shard offsets <= D, so
    # rotations dedupe — with B > D this cuts ppermute traffic ~B/D-fold
    # (§Perf iteration: hypothesis confirmed, EXPERIMENTS.md)
    for b in range(num_iters):
        o_l = offsets_local[b]
        if schedule == "static":
            o_s = int(offsets_shard[b]) % int(n_shards)
            if o_s not in rotated:
                rotated[o_s] = _rotate_blocks_static(
                    local_weights, o_s, axis_name, int(n_shards))
            w_blk = rotated[o_s]
            src_shard = (s + o_s) % n_shards
        else:
            o_s = offsets_shard[b]
            w_blk = _rotate_blocks_dynamic(local_weights, o_s, axis_name, int(n_shards))
            src_shard = (s + o_s) % n_shards
        j_local = megopolis_indices(i_local, o_l, segment, n_local).astype(jnp.int32)
        w_j = jnp.take(w_blk, j_local, axis=0)
        j_global = src_shard.astype(jnp.int32) * n_local + j_local
        u = hash_uniform(seed, i_global, b, dtype=local_weights.dtype)
        accept = u * wk <= w_j
        k = jnp.where(accept, j_global, k)
        wk = jnp.where(accept, w_j, wk)
    return k


def gather_ancestors(x_local: jnp.ndarray, ancestors_global: jnp.ndarray, *, axis_name: str):
    """Exact cross-shard payload gather (all-gather strategy).

    Fine for PF-scale payloads (the paper's states are scalars/small
    vectors); for LM KV caches use island mode instead.
    """
    x_all = lax.all_gather(x_local, axis_name, axis=0, tiled=True)
    return jnp.take(x_all, ancestors_global, axis=0)


def island_exchange(x_local: jnp.ndarray, *, axis_name: str, fraction: float = 0.25):
    """Ring-mix a leading fraction of local particles with the next shard
    (island-model particle exchange; Vergé et al. [46])."""
    n_shards = axis_size(axis_name)
    m = max(1, int(x_local.shape[0] * fraction))
    perm = [(src, (src + 1) % n_shards) for src in range(int(n_shards))]
    head = lax.ppermute(x_local[:m], axis_name, perm)
    return jnp.concatenate([head, x_local[m:]], axis=0)


def effective_sample_size(local_weights: jnp.ndarray, *, axis_name: str):
    """Global ESS = (sum w)^2 / sum w^2 via psum (resampling trigger)."""
    s1 = lax.psum(jnp.sum(local_weights), axis_name)
    s2 = lax.psum(jnp.sum(local_weights**2), axis_name)
    return s1 * s1 / jnp.maximum(s2, 1e-30)


def make_distributed_resampler(
    mesh,
    *,
    spec=None,
    axis_name: str = "data",
    num_iters: int = 32,
    segment: int = 1024,
    schedule: str = "static",
    static_seed: int = 0xA5A5,
):
    """Build a jitted global-array resampler over ``mesh``.

    ``spec`` (a ``MegopolisSpec``, DESIGN.md §9) supplies ``num_iters`` and
    ``segment`` in one typed object, overriding the loose kwargs; the
    distributed-only knobs (``axis_name``, ``schedule``, ``static_seed``)
    stay here — they configure the chip-level decomposition, not the
    algorithm family.  ``num_iters`` must be concrete (the per-iteration
    ppermute schedule is built at trace time), so ``num_iters='auto'``
    specs are rejected eagerly.

    Returns ``fn(key, weights_global) -> ancestors_global`` where weights are
    sharded ``P(axis_name)`` and ancestors come back with the same sharding.
    """
    if spec is not None:
        from repro.core.spec import MegopolisSpec

        if not isinstance(spec, MegopolisSpec):
            raise TypeError(
                f"make_distributed_resampler takes a MegopolisSpec; got {type(spec).__name__} "
                "(the hierarchical decomposition is Alg. 5 specific)"
            )
        if not isinstance(spec.num_iters, int):
            raise ValueError(
                "make_distributed_resampler needs a concrete num_iters (the "
                "shard-offset schedule is built per iteration at trace time); "
                f"got num_iters={spec.num_iters!r}"
            )
        if spec.backend not in ("reference", "xla"):
            raise ValueError(
                "make_distributed_resampler runs its own shard_map decomposition, "
                f"not the single-chip Pallas kernel; got backend={spec.backend!r} "
                "(use backend='reference')"
            )
        num_iters, segment = spec.num_iters, spec.segment
    if schedule not in ("static", "dynamic"):
        raise ValueError(f"schedule must be 'static' or 'dynamic'; got {schedule!r}")
    n_shards = int(np.prod([mesh.shape[a] for a in (axis_name,)]))
    shard_sched = _static_shard_schedule(static_seed, num_iters, n_shards)

    def impl(seed, offsets_local, offsets_shard_dyn, weights):
        offsets_shard = shard_sched if schedule == "static" else offsets_shard_dyn
        return megopolis_shard(
            seed,
            offsets_local,
            offsets_shard,
            weights,
            axis_name=axis_name,
            num_iters=num_iters,
            segment=segment,
            schedule=schedule,
        )

    shard_fn = shard_map(
        impl,
        mesh=mesh,
        in_specs=(P(), P(), P(), P(axis_name)),
        out_specs=P(axis_name),
    )

    @jax.jit
    def resample(key, weights):
        n = weights.shape[0]
        n_local = n // n_shards
        k_seed, k_loc, k_shard = jax.random.split(key, 3)
        seed = key_to_seed(k_seed)
        offsets_local = jax.random.randint(k_loc, (num_iters,), 0, n_local, jnp.int32)
        offsets_shard_dyn = jax.random.randint(k_shard, (num_iters,), 0, n_shards, jnp.int32)
        return shard_fn(seed, offsets_local, offsets_shard_dyn, weights)

    return resample


def megopolis_hier_ref(
    seed,
    offsets_local,
    offsets_shard: Sequence[int],
    weights: jnp.ndarray,
    *,
    n_shards: int,
    num_iters: int,
    segment: int = 1024,
) -> jnp.ndarray:
    """Single-device oracle of the hierarchical index map (for exactness
    tests against the shard_map implementation)."""
    n = weights.shape[0]
    n_local = n // n_shards
    i = jnp.arange(n, dtype=jnp.int32)
    s = i // n_local
    i_local = i % n_local
    k = i
    wk = weights
    for b in range(num_iters):
        o_s = int(offsets_shard[b]) if not isinstance(offsets_shard, jnp.ndarray) else offsets_shard[b]
        j_local = megopolis_indices(i_local, offsets_local[b], segment, n_local).astype(jnp.int32)
        j_global = ((s + o_s) % n_shards) * n_local + j_local
        w_j = weights[j_global]
        u = hash_uniform(seed, i, b, dtype=weights.dtype)
        accept = u * wk <= w_j
        k = jnp.where(accept, j_global, k)
        wk = jnp.where(accept, w_j, wk)
    return k
