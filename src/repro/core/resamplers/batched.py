"""Batched (multi-population) resampling — the scenario axis (DESIGN.md §4).

A fleet of particle filters (one per scenario / request / hypothesis bank)
wants ONE device launch per resampling step, not a Python loop of B
launches.  Every resampler in the registry therefore gains a batched entry
point::

    ancestors = resample_batch(key, weights, num_iters, **kw)   # int32[B, N]

with one contract, uniform across the registry (DESIGN.md §4):

  * ``weights`` is ``f32[B, N]`` — B independent, unnormalised populations;
  * the key is split ONCE along the batch axis, ``keys = split(key, B)``,
    and row ``b`` of the output is bit-identical to the single-population
    call ``resampler(keys[b], weights[b], num_iters, **kw)``;
  * consequently rows are statistically independent and per-row
    deterministic — growing or permuting the batch never changes the
    result of a row that kept its key.

For most families the batched form is derived here by ``jax.vmap`` (the
per-row randomness is already expressed with counter-style ``fold_in`` /
``split``, so vmap is bit-exact and fuses the whole bank into one XLA
launch).  Megopolis additionally has a hand-batched shared-offset mode
(``repro.core.resamplers.megopolis.megopolis_batch``) exploiting Alg. 5's
structure: the global offset draw is one scalar table shared by every row,
so the comparison-index map — and hence the gather pattern — is identical
across the batch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def split_batch_keys(key: jax.Array, batch: int) -> jax.Array:
    """The ONE key-splitting convention of the batched API (DESIGN.md §4)."""
    return jax.random.split(key, batch)


def batch_rows(fn, keys, weights, num_iters=0, **kwargs):
    """vmap ``fn`` over explicit per-row keys.

    Bit-identical to ``[fn(keys[b], weights[b], num_iters, **kwargs) for b]``
    — all per-row randomness in the registry is counter-based (``fold_in`` /
    ``split``), which vmap maps elementwise.  Exposed separately so callers
    that already carry per-row key chains (``run_filter_bank``) can join the
    batched launch without re-deriving keys.
    """
    if weights.ndim != 2:
        raise ValueError(f"batched resampling expects weights[B, N]; got shape {weights.shape}")
    return jax.vmap(lambda k, w: fn(k, w, num_iters, **kwargs))(keys, weights)


def batch_via_vmap(fn):
    """Derive the standard batched entry point from a single-population
    resampler (the trivial-to-batch families: Metropolis, prefix-sum,
    rejection)."""

    @functools.wraps(fn)
    def resample_batch(key: jax.Array, weights: jnp.ndarray, num_iters: int = 0, **kwargs):
        keys = split_batch_keys(key, weights.shape[0])
        return batch_rows(fn, keys, weights, num_iters, **kwargs)

    resample_batch.__name__ = f"{fn.__name__}_batch"
    resample_batch.__qualname__ = f"{fn.__name__}_batch"
    resample_batch.__doc__ = (
        f"Batched {fn.__name__}: one launch over weights[B, N]; row b is "
        f"bit-identical to {fn.__name__}(split(key, B)[b], weights[b], ...)."
    )
    return resample_batch
