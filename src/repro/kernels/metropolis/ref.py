"""Pure-jnp bit-exact oracles for the Metropolis-family Pallas kernels."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import TILE, hash_bits, hash_uniform

SEG = TILE  # 1024 — the c1c2 kernels' partition size, must match


@functools.partial(jax.jit, static_argnames=("num_iters",))
def metropolis_ref(
    weights: jnp.ndarray,
    seed: jnp.ndarray,
    *,
    num_iters: int,
) -> jnp.ndarray:
    n = weights.shape[0]
    i = jnp.arange(n, dtype=jnp.int32)
    seed = jnp.asarray(seed).reshape(-1)[0]
    # Selection arithmetic is ALWAYS f32 (DESIGN.md §14); no-op at f32.
    weights = weights.astype(jnp.float32)

    def body(b, state):
        k, wk = state
        j = (hash_bits(seed, i, b) % jnp.uint32(n)).astype(jnp.int32)
        w_j = weights[j]
        u = hash_uniform(seed, i + n, b, dtype=jnp.float32)
        accept = u * wk <= w_j
        return jnp.where(accept, j, k), jnp.where(accept, w_j, wk)

    k, _ = jax.lax.fori_loop(0, num_iters, body, (i, weights))
    return k


def _partition_body(weights, i, seed, p_tile_of_b):
    """Shared C1/C2 oracle sweep: ``p_tile_of_b(b)`` names each particle's
    partition tile at iteration b (C1: constant in b; C2: fresh per b)."""
    n = weights.shape[0]
    weights = weights.astype(jnp.float32)  # §14: selection stays f32

    def body(b, state):
        k, wk = state
        p = p_tile_of_b(b)
        j_local = (hash_bits(seed, i, b) % jnp.uint32(SEG)).astype(jnp.int32)
        j = p * SEG + j_local
        w_j = weights[j]
        u = hash_uniform(seed, i + n, b, dtype=jnp.float32)
        accept = u * wk <= w_j
        return jnp.where(accept, j, k), jnp.where(accept, w_j, wk)

    return body


@functools.partial(jax.jit, static_argnames=("num_iters",))
def metropolis_c1_ref(
    weights: jnp.ndarray,
    partitions: jnp.ndarray,
    seed: jnp.ndarray,
    *,
    num_iters: int,
) -> jnp.ndarray:
    """``partitions``: int32[num_tiles], tile t's fixed partition tile."""
    n = weights.shape[0]
    i = jnp.arange(n, dtype=jnp.int32)
    seed = jnp.asarray(seed).reshape(-1)[0]
    p_i = partitions[i // SEG]  # constant across iterations (Alg. 3)
    body = _partition_body(weights, i, seed, lambda b: p_i)
    k, _ = jax.lax.fori_loop(0, num_iters, body, (i, weights))
    return k


@functools.partial(jax.jit, static_argnames=("num_iters",))
def metropolis_c2_ref(
    weights: jnp.ndarray,
    partitions: jnp.ndarray,
    seed: jnp.ndarray,
    *,
    num_iters: int,
) -> jnp.ndarray:
    """``partitions``: int32[num_tiles * num_iters], row-major by tile —
    particle i's partition at iteration b is ``partitions[(i // SEG) *
    num_iters + b]`` (fresh per iteration, Alg. 4)."""
    n = weights.shape[0]
    i = jnp.arange(n, dtype=jnp.int32)
    seed = jnp.asarray(seed).reshape(-1)[0]
    tile_of_i = i // SEG
    body = _partition_body(
        weights, i, seed, lambda b: partitions[tile_of_i * num_iters + b]
    )
    k, _ = jax.lax.fori_loop(0, num_iters, body, (i, weights))
    return k
