"""Megopolis resampling (paper Algorithm 5) — reference JAX implementation.

The key structural idea: the ``B`` random comparison indices are drawn ONCE,
globally, as offsets ``o[b] ~ U{0, N-1}`` shared by all particles.  At
iteration ``b`` particle ``i`` compares its current ancestor ``k`` against

    j = (aligned(i) + aligned(o[b]) + (i + o[b]) mod S) mod N

where ``S`` is the coalescing segment size (32 on the paper's GPU warps;
1024 = one (8,128) f32 VMEM tile for the TPU kernel in
``repro.kernels.megopolis``).  For each fixed ``o[b]`` the map ``i -> j`` is
a segment-aligned global rotation — a bijection — so every particle is
exposed exactly once per iteration, which is what drives Megopolis' lower
offspring variance (paper §6.1).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.resamplers.batched import split_batch_keys

DEFAULT_SEGMENT = 32  # paper-faithful warp size; TPU kernel uses 1024.


def megopolis_indices(i: jnp.ndarray, offset, segment: int, n: int) -> jnp.ndarray:
    """The Megopolis comparison-index map (Alg. 5 lines 7-11), vectorised.

    Exposed separately so the Pallas kernel's ``ref.py``, the distributed
    shard_map version, and property tests all share one definition.
    """
    i_aligned = i - (i % segment)
    o_aligned = offset - (offset % segment)
    o_unaligned = (i + offset) % segment
    return (i_aligned + o_aligned + o_unaligned) % n


def megopolis(
    key: jax.Array,
    weights: jnp.ndarray,
    num_iters: int,
    *,
    segment: int = DEFAULT_SEGMENT,
    offsets: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Resample; returns int32 ancestor indices (paper Algorithm 5).

    Args:
      key: PRNG key.
      weights: ``f32[N]`` unnormalised, non-negative particle weights.
      num_iters: ``B`` — accept/reject iterations (see ``select_iterations``).
      segment: coalescing segment size ``S``; any ``S >= 1`` is valid
        (Proposition 1 needs only bijectivity + uniformity, both independent
        of ``S``).
      offsets: optional pre-drawn ``int[num_iters]`` global offsets.  When
        given they replace the internal draw (the accept/reject uniforms are
        unchanged — the key is split identically either way); this is the
        injection point the shared-offset batched mode builds on.
    """
    n = weights.shape[0]
    key_off, key_u = jax.random.split(key)
    if offsets is None:
        offsets = jax.random.randint(key_off, (num_iters,), 0, n)
    i = jnp.arange(n, dtype=jnp.int32)

    def body(b, k):
        j = megopolis_indices(i, offsets[b], segment, n).astype(jnp.int32)
        u = jax.random.uniform(jax.random.fold_in(key_u, b), (n,), weights.dtype)
        # u <= w[j] / w[k]  <=>  u * w[k] <= w[j]   (division-free, w >= 0)
        accept = u * weights[k] <= weights[j]
        return jnp.where(accept, j, k)

    return jax.lax.fori_loop(0, num_iters, body, i)


def megopolis_batch(
    key: jax.Array,
    weights: jnp.ndarray,
    num_iters: int,
    *,
    segment: int = DEFAULT_SEGMENT,
    shared_offsets: bool = False,
) -> jnp.ndarray:
    """Batched Megopolis over ``weights[B, N]`` — one launch (DESIGN.md §4).

    ``shared_offsets=False`` (registry default): the standard batched
    contract — row ``b`` is bit-identical to
    ``megopolis(split(key, B)[b], weights[b], ...)``; every row draws its
    own offset table.

    ``shared_offsets=True`` (hand-batched, Alg. 5's structure): the global
    offsets ``o[1..num_iters]`` are drawn ONCE and shared by every row, so
    per iteration the comparison map ``i -> j`` is one index vector for the
    whole bank and the ``w[:, j]`` gather is a single batch-uniform pattern
    — the batch-axis analogue of the paper's warp-shared offset (and what
    the batched Pallas kernel scalar-prefetches).  Row ``b`` then equals
    ``megopolis(split(key, B)[b], weights[b], ..., offsets=offsets)``;
    accept/reject uniforms stay per-row independent.
    """
    if weights.ndim != 2:
        raise ValueError(f"megopolis_batch expects weights[B, N]; got shape {weights.shape}")
    bsz, n = weights.shape
    keys = split_batch_keys(key, bsz)
    if not shared_offsets:
        return jax.vmap(lambda k, w: megopolis(k, w, num_iters, segment=segment))(keys, weights)

    # One global offset table for the whole bank (drawn from key, not from
    # any row key, so no row's uniform stream is correlated with it).
    offsets = jax.random.randint(jax.random.fold_in(key, num_iters), (num_iters,), 0, n)
    keys_u = jax.vmap(lambda k: jax.random.split(k)[1])(keys)
    i = jnp.arange(n, dtype=jnp.int32)

    def body(b, k):
        j = megopolis_indices(i, offsets[b], segment, n).astype(jnp.int32)
        u = jax.vmap(
            lambda kk: jax.random.uniform(jax.random.fold_in(kk, b), (n,), weights.dtype)
        )(keys_u)
        w_k = jnp.take_along_axis(weights, k, axis=1)
        w_j = weights[:, j]  # shared j: one gather pattern bank-wide
        accept = u * w_k <= w_j
        return jnp.where(accept, j[None, :], k)

    return jax.lax.fori_loop(0, num_iters, body, jnp.broadcast_to(i, (bsz, n)))
