"""Benchmark orchestrator: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--skip NAME ...]

CI scale by default (~minutes on CPU); ``--full`` restores paper sizes.
The dry-run / roofline pipeline is separate (launch/dryrun.py) because it
re-initialises jax with 512 virtual devices.
"""

from __future__ import annotations

import argparse
import sys
import time

SUITES = [
    ("transactions", "benchmarks.transactions_bench", []),
    ("kernel", "benchmarks.kernel_bench", []),
    ("fig6", "benchmarks.fig6_quality_speed", []),
    ("fig7", "benchmarks.fig7_partition_sweep", []),
    ("fig8", "benchmarks.fig8_prefix_sum", []),
    ("fig10", "benchmarks.fig10_gamma", []),
    ("table2", "benchmarks.table2_e2e_pf", []),
    ("filter_bank", "benchmarks.filter_bank_bench", ["--quick"]),
    ("smc", "benchmarks.smc_decode_bench", ["--particles", "32", "--new-tokens", "8",
                                            "--archs", "qwen3-0.6b"]),
]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--skip", nargs="*", default=[])
    ap.add_argument("--only", nargs="*", default=[])
    args = ap.parse_args(argv)

    failures = []
    for name, module, extra in SUITES:
        if name in args.skip or (args.only and name not in args.only):
            continue
        print(f"\n======== {name} ({module}) ========")
        t0 = time.time()
        argv_m = list(extra) + (["--full"] if args.full and name not in ("transactions", "kernel", "smc") else [])
        try:
            mod = __import__(module, fromlist=["main"])
            mod.main(argv_m)
            print(f"[{name}] OK in {time.time()-t0:.1f}s")
        except SystemExit as e:
            if e.code not in (0, None):
                failures.append(name)
        except Exception as e:
            import traceback
            traceback.print_exc()
            failures.append(name)
    if failures:
        print(f"\nFAILED suites: {failures}")
        sys.exit(1)
    print("\nall benchmark suites passed")


if __name__ == "__main__":
    main()
