"""Resampling quality metrics (paper §5.1, eqs. 14-21).

All metrics operate on offspring vectors ``o_k[i]`` = number of offspring of
particle ``i`` in Monte Carlo run ``k`` (derived from ancestors with
``offspring_counts``).
"""

from __future__ import annotations

import jax.numpy as jnp


def effective_sample_size(log_w: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    """ESS = (Σw)² / Σw² from log-weights, shift-by-max stabilised.

    THE single-host ESS helper (the resampling trigger of `smc/decode.py`,
    `pf/filter.py` diagnostics, and the `ais/` sampler).  Weights need not
    be normalised — ESS depends only on ratios, the same property the
    Metropolis-family resamplers rely on.  The multi-host psum form lives
    in ``repro.core.distributed.effective_sample_size``.
    """
    w = jnp.exp(log_w - jnp.max(log_w, axis=axis, keepdims=True))
    s1 = jnp.sum(w, axis=axis)
    s2 = jnp.sum(w * w, axis=axis)
    return jnp.square(s1) / jnp.maximum(s2, 1e-30)


def offspring_counts(ancestors: jnp.ndarray, n: int) -> jnp.ndarray:
    """o[i] = #{j : ancestors[j] == i}."""
    return jnp.bincount(ancestors, length=n)


def expected_offspring(weights: jnp.ndarray) -> jnp.ndarray:
    """N * w_i / sum(w) (the target of eq. 14)."""
    n = weights.shape[0]
    return n * weights / jnp.sum(weights)


def squared_error(offspring: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    """SE(o_k), eq. (14)."""
    return jnp.sum((offspring - expected_offspring(weights)) ** 2)


def mse(offsprings: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    """MSE over K runs, eq. (15).  ``offsprings``: int[K, N]."""
    target = expected_offspring(weights)
    return jnp.mean(jnp.sum((offsprings - target) ** 2, axis=-1))


def bias_variance(offsprings: jnp.ndarray, weights: jnp.ndarray):
    """Decomposition eqs. (16)-(20): returns (var, bias_sq, mse).

    ``offsprings``: int[K, N] over K Monte Carlo runs of one weight vector.
    """
    k = offsprings.shape[0]
    target = expected_offspring(weights)
    o_hat = jnp.mean(offsprings.astype(jnp.float32), axis=0)  # eq. 19
    var = jnp.sum(jnp.sum((offsprings - o_hat) ** 2, axis=0) / (k - 1))  # eqs. 17/20
    bias_sq = jnp.sum((o_hat - target) ** 2)  # eq. 18
    return var, bias_sq, var + bias_sq  # eq. 16


def bias_contribution(offsprings: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    """||Bias||^2 / MSE, eq. (21)."""
    var, bias_sq, total = bias_variance(offsprings, weights)
    return bias_sq / jnp.maximum(total, 1e-30)
