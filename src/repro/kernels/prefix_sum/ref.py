"""Oracles for the prefix-sum kernels.

``prefix_sum_ref`` is the plain ``jnp.cumsum`` (numerically close but not
bit-identical to the tiled scan); ``prefix_sum_tiled_ref`` replays the
kernel's exact arithmetic — per-1024-tile ``jnp.cumsum`` plus a scalar
carry accumulated in tile order — and IS bit-identical in interpret mode.
``prefix_resample_ref`` is the pure-jnp oracle for the kernel-lane
resamplers: tiled scan + ``jnp.searchsorted`` over the identical draws
(``kind_draws`` is imported from ``ops.py`` so the streams can never
drift).
"""

import functools

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels.common import TILE


@jax.jit
def prefix_sum_ref(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.cumsum(x)


@jax.jit
def prefix_sum_tiled_ref(x: jnp.ndarray) -> jnp.ndarray:
    """Bit-exact replay of the block-scan kernel: local cumsum per (8,128)
    tile + sequential scalar carry, f32 adds in the same order."""
    n = x.shape[0]
    assert n % TILE == 0

    def scan_tile(carry, tile):
        local = jnp.cumsum(tile)
        return carry + local[-1], local + carry

    _, out = lax.scan(scan_tile, jnp.zeros((), x.dtype), x.reshape(-1, TILE))
    return out.reshape(n)


@functools.partial(jax.jit, static_argnames=("kind",))
def prefix_resample_ref(
    key: jax.Array, weights: jnp.ndarray, *, kind: str = "systematic"
) -> jnp.ndarray:
    """int32[N] ancestors; must equal ``prefix_resample_tpu`` exactly."""
    from repro.kernels.prefix_sum.ops import kind_draws

    n = weights.shape[0]
    if kind == "residual":
        total = prefix_sum_tiled_ref(weights)[-1]
        w = weights / total
        counts = jnp.floor(n * w)
        n_det = jnp.sum(counts).astype(jnp.int32)
        resid = n * w - counts
        cc = prefix_sum_tiled_ref(counts)
        c = prefix_sum_tiled_ref(resid)
        slots = jnp.arange(n, dtype=jnp.int32)
        det = jnp.searchsorted(cc, slots.astype(weights.dtype), side="right")
        u = jax.random.uniform(key, (n,), weights.dtype) * c[-1]
        rnd = jnp.searchsorted(c, u, side="right")
        k = jnp.where(slots < n_det, jnp.minimum(det, n - 1), jnp.minimum(rnd, n - 1))
    else:
        c = prefix_sum_tiled_ref(weights)
        u, side = kind_draws(key, n, c[-1], weights.dtype, kind)
        k = jnp.minimum(jnp.searchsorted(c, u, side=side), n - 1)
    return k.astype(jnp.int32)
