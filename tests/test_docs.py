"""Docs can never dangle (DESIGN.md §7): every ``DESIGN.md §n`` /
``EXPERIMENTS.md [§Section]`` citation in the source tree must resolve to
an existing file and an existing section header."""

import re
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
SCAN_DIRS = ("src", "benchmarks", "examples", "tests")

# "DESIGN.md §6.4" -> section number "6.4"
DESIGN_RE = re.compile(r"DESIGN\.md\s*§(\d+(?:\.\d+)?)")
# "EXPERIMENTS.md §Dry-run" / "EXPERIMENTS §Perf" / bare "EXPERIMENTS.md"
EXPERIMENTS_RE = re.compile(r"EXPERIMENTS(?:\.md)?(?:\s*§([A-Za-z][\w-]*))?")


def _citations(regex):
    cites = []
    for d in SCAN_DIRS:
        for f in sorted((REPO / d).rglob("*.py")):
            for m in regex.finditer(f.read_text(encoding="utf-8")):
                cites.append((str(f.relative_to(REPO)), m.group(1)))
    return cites


def _markdown_headers(name):
    path = REPO / name
    assert path.is_file(), f"{name} is cited from source but does not exist"
    return [
        line for line in path.read_text(encoding="utf-8").splitlines()
        if line.startswith("#")
    ]


def _assert_section(headers, name, anchor, cited_from):
    # boundary: §6 must not be satisfied by a §6.3 header, §Perf not by §Perfx
    pat = re.compile(rf"§{re.escape(anchor)}(?![\w.])")
    assert any(pat.search(h) for h in headers), (
        f"{cited_from} cites {name} §{anchor}, but no markdown header in "
        f"{name} contains §{anchor}"
    )


def test_design_citations_resolve():
    cites = _citations(DESIGN_RE)
    assert cites, "expected DESIGN.md citations in the source tree"
    headers = _markdown_headers("DESIGN.md")
    for src, section in cites:
        _assert_section(headers, "DESIGN.md", section, src)


def test_experiments_citations_resolve():
    cites = _citations(EXPERIMENTS_RE)
    assert cites, "expected EXPERIMENTS.md citations in the source tree"
    headers = _markdown_headers("EXPERIMENTS.md")
    for src, section in cites:
        if section is not None:  # bare "EXPERIMENTS.md" only asserts the file
            _assert_section(headers, "EXPERIMENTS.md", section, src)


def test_design_documents_batched_engine_semantics():
    """The batched engine's contract (key splitting, per-row determinism)
    is load-bearing API documentation — pin that §4 actually states it."""
    text = (REPO / "DESIGN.md").read_text(encoding="utf-8")
    sec = re.search(r"^## §4\b.*?(?=^## §)", text, re.S | re.M)
    assert sec, "DESIGN.md must have a §4 section for the batched engine"
    body = sec.group(0)
    for needle in ("split", "bit-identical", "run_filter_bank"):
        assert needle in body, f"DESIGN.md §4 must document {needle!r}"


def test_readme_exists_with_verify_command():
    text = (REPO / "README.md").read_text(encoding="utf-8")
    assert "python -m pytest -x -q" in text  # the ROADMAP tier-1 verify line
    assert "examples/" in text
