"""RNG-discipline lint (DESIGN.md §13, pass 3).

The Murray–Lee–Jacob survey's warning is that parallel resamplers fail
*silently* through RNG misuse — correlated streams bias the resampled
population without any crash.  PR 6 guarded one instance by hand ("the key
is consumed in BOTH branches"); this pass mechanises the whole class at
the jaxpr level:

  * **key-reuse** — one PRNG key var consumed by two or more random
    primitives (``random_bits``/``random_split``/``random_fold_in``).
    Multiple ``fold_in``s of the same key are exempt unless two data
    operands are provably equal (same var / equal literals) — deriving
    subkeys by folding distinct data is the documented idiom.  Old-style
    raw ``uint32[2]`` keys are tracked through their ``random_wrap``
    lifts and through call boundaries, so wrapping the same raw key twice
    (e.g. two ``jax.random`` calls on the same key) is still reuse.
  * **branch-drop** — a key operand of ``lax.cond`` consumed in one branch
    but not even used in another: whether the stream advances becomes
    data-dependent, so downstream draws diverge between branches (the §12
    rule is that the key must be consumed in BOTH branches).
  * **loop-invariant-key** — a loop-constant key consumed by ``bits``/
    ``split`` inside a ``scan``/``while`` body (or ``fold_in`` with
    loop-invariant data): every iteration draws the SAME randoms.

Consumption counts through call boundaries: passing a key into a ``pjit``/
``scan``/``cond`` whose body consumes it is ONE consumption at the caller
(reuse *inside* the callee is reported when its own scope is linted).
Data operands of ``fold_in`` are translated across the boundary so the
distinct-data exemption survives jitted helpers; data that cannot be
resolved to a caller var or literal is treated permissively as distinct.
"""

from __future__ import annotations

from typing import Optional

from jax.extend import core as jex_core

import jax.dtypes
import jax.numpy as jnp

from repro.analysis.walker import Finding, JaxprLike, subjaxprs, unwrap

#: Primitives that advance/consume a PRNG key (key is operand 0).
CONSUMING = ("random_bits", "random_split", "random_fold_in", "random_gamma")

#: A consumption descriptor: (primitive kind, fold_in data id or None).
#: Data ids are ("lit", repr) | ("var", Var) | ("invar", pos) | None.
Desc = tuple[str, Optional[tuple]]


def _aval(v):
    return getattr(v, "aval", None)


def _is_key_dtype(aval) -> bool:
    dtype = getattr(aval, "dtype", None)
    return dtype is not None and jax.dtypes.issubdtype(dtype, jax.dtypes.prng_key)


def _is_single_key(v) -> bool:
    """A scalar typed key — the unit whose reuse the lint tracks.  Keys
    with leading batch dims are arrays of distinct keys; consuming two
    different slices of one is not reuse, and each slice is re-tracked."""
    aval = _aval(v)
    return aval is not None and _is_key_dtype(aval) and getattr(aval, "shape", None) == ()


def _is_raw_key(v) -> bool:
    """Old-style ``uint32[2]`` key candidate.  Cheap shape test only — a
    non-key uint32 pair simply collects zero consumers and is skipped."""
    aval = _aval(v)
    return (
        aval is not None
        and getattr(aval, "shape", None) == (2,)
        and getattr(aval, "dtype", None) == jnp.dtype(jnp.uint32)
    )


def _is_keyish(v) -> bool:
    return not isinstance(v, jex_core.Literal) and (_is_single_key(v) or _is_raw_key(v))


def _call_invar_maps(eqn):
    """Yield ``(subjaxpr, caller_pos -> callee_pos)`` for a call-like eqn,
    mirroring the positional conventions in ``walker._TaintScope``."""
    name = eqn.primitive.name
    params = eqn.params
    n = len(eqn.invars)
    if name == "scan":
        yield unwrap(params["jaxpr"]), {i: i for i in range(n)}
    elif name == "while":
        cond_n = params["cond_nconsts"]
        body_n = params["body_nconsts"]
        yield unwrap(params["cond_jaxpr"]), {
            **{i: i for i in range(cond_n)},
            **{cond_n + body_n + i: cond_n + i for i in range(n - cond_n - body_n)},
        }
        yield unwrap(params["body_jaxpr"]), {cond_n + i: i for i in range(n - cond_n)}
    elif name == "cond":
        for br in params["branches"]:
            yield unwrap(br), {i: i - 1 for i in range(1, n)}
    elif name == "pjit" and "jaxpr" in params:
        yield unwrap(params["jaxpr"]), {i: i for i in range(n)}
    elif "call_jaxpr" in params:
        yield unwrap(params["call_jaxpr"]), {i: i for i in range(n)}


def _lit_id(v) -> Optional[tuple]:
    if isinstance(v, jex_core.Literal):
        return ("lit", repr(v.val))
    return None


def _direct_desc(eqn, jaxpr) -> Desc:
    """Descriptor for a direct consuming primitive; fold_in data resolved
    to a literal, an invar position of ``jaxpr``, a local var, or None."""
    name = eqn.primitive.name
    if name != "random_fold_in" or len(eqn.invars) < 2:
        return (name, None)
    data = eqn.invars[1]
    lit = _lit_id(data)
    if lit is not None:
        return (name, lit)
    for j, iv in enumerate(jaxpr.invars):
        if iv is data:
            return (name, ("invar", j))
    return (name, ("var", data))


def _invar_descs(jaxpr, pos: int, memo: dict) -> list[Desc]:
    """Consumption descriptors for invar ``pos`` of ``jaxpr``, with fold_in
    data ids expressed relative to ``jaxpr``'s own invars."""
    key = (id(jaxpr), pos)
    if key in memo:
        return memo[key]
    memo[key] = []  # cycle guard
    descs: list[Desc] = []
    for _, desc in _var_consumers(jaxpr, jaxpr.invars[pos], memo):
        # local ("var", v) data can't be translated past this scope
        kind, data = desc
        if data is not None and data[0] == "var":
            data = None
        descs.append((kind, data))
    memo[key] = descs
    return descs


def _collapse_call(eqn, jaxpr, positions, memo) -> Optional[Desc]:
    """One descriptor for a call-like eqn that consumes the key passed at
    ``positions`` (an eqn executes once, so it is ONE consumption; reuse
    inside the callee is reported when that scope is linted)."""
    sub_descs: list[Desc] = []
    for sub, posmap in _call_invar_maps(eqn):
        inv = {callee: caller for caller, callee in posmap.items()}
        for i in positions:
            if i not in posmap:
                continue
            for kind, data in _invar_descs(sub, posmap[i], memo):
                if data is not None and data[0] == "invar":
                    caller_pos = inv.get(data[1])
                    src = eqn.invars[caller_pos] if caller_pos is not None else None
                    if src is None:
                        data = None
                    elif isinstance(src, jex_core.Literal):
                        data = _lit_id(src)
                    else:
                        data = ("var", src)
                sub_descs.append((kind, data))
    if not sub_descs:
        return None
    if len(sub_descs) == 1:
        return sub_descs[0]
    kinds = {k for k, _ in sub_descs}
    if kinds == {"random_fold_in"}:
        datas = {d for _, d in sub_descs if d is not None}
        return ("random_fold_in", datas.pop() if len(datas) == 1 else None)
    return (eqn.primitive.name, None)


def _var_consumers(jaxpr, var, memo) -> list[tuple[int, Desc]]:
    """All consumption events of ``var`` in this scope, as ``(eqn_id,
    descriptor)``; follows ``random_wrap`` lifts as aliases."""
    out: list[tuple[int, Desc]] = []
    for eqn in jaxpr.eqns:
        positions = [i for i, v in enumerate(eqn.invars) if v is var]
        if not positions:
            continue
        name = eqn.primitive.name
        if name == "random_wrap":
            out.extend(_var_consumers(jaxpr, eqn.outvars[0], memo))
        elif name in CONSUMING and positions[0] == 0:
            out.append((id(eqn), _direct_desc(eqn, jaxpr)))
        else:
            desc = _collapse_call(eqn, jaxpr, positions, memo)
            if desc is not None:
                out.append((id(eqn), desc))
    return out


def _is_violation(descs: list[Desc]) -> bool:
    """>=2 consumptions violate unless all are fold_in with no provably
    equal data (unresolvable data is permissively assumed distinct)."""
    if len(descs) < 2:
        return False
    if any(kind != "random_fold_in" for kind, _ in descs):
        return True
    seen = set()
    for _, data in descs:
        if data is not None and data in seen:
            return True
        if data is not None:
            seen.add(data)
    return False


def _fmt(descs: list[Desc]) -> str:
    return ", ".join(sorted(kind for kind, _ in descs))


def _lint_scope(jaxpr, path, memo, findings, seen):
    tracked = []
    for v in jaxpr.invars:
        if _is_keyish(v):
            tracked.append(v)
    for eqn in jaxpr.eqns:
        for v in eqn.outvars:
            if _is_keyish(v):
                tracked.append(v)

    for v in tracked:
        events = _var_consumers(jaxpr, v, memo)
        if _is_violation([d for _, d in events]):
            dedupe = (path, frozenset(eid for eid, _ in events))
            if dedupe in seen:  # raw key + its wrap lift share consumers
                continue
            seen.add(dedupe)
            findings.append(
                Finding(
                    "rng",
                    "key-reuse",
                    path,
                    f"PRNG key {v} consumed by {len(events)} random "
                    f"primitives ({_fmt([d for _, d in events])})",
                )
            )

    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        child = f"{path}/{name}" if path else name
        if name == "cond":
            _lint_cond_branches(eqn, child, memo, findings)
        if name in ("scan", "while"):
            _lint_loop_keys(eqn, child, memo, findings)
        for _, sub in subjaxprs(eqn):
            _lint_scope(sub, child, memo, findings, seen)


def _lint_cond_branches(eqn, path, memo, findings):
    branches = [unwrap(b) for b in eqn.params["branches"]]
    for i, op in enumerate(eqn.invars[1:]):
        if not _is_keyish(op):
            continue
        consumed = [bool(_invar_descs(b, i, memo)) for b in branches]
        used = [
            any(any(iv is b.invars[i] for iv in e.invars) for e in b.eqns)
            or any(ov is b.invars[i] for ov in b.outvars)
            for b in branches
        ]
        if any(consumed) and not all(used):
            findings.append(
                Finding(
                    "rng",
                    "branch-drop",
                    path,
                    f"cond operand {i} is a PRNG key consumed in "
                    f"{sum(consumed)}/{len(branches)} branches but unused in "
                    f"{len(used) - sum(used)} — streams diverge across the branch",
                )
            )


def _loop_varying_vars(body, const_count: int) -> set:
    """Vars in a loop body derived from carry/xs (change per iteration)."""
    varying = set(body.invars[const_count:])
    for eqn in body.eqns:
        if any(
            not isinstance(v, jex_core.Literal) and v in varying for v in eqn.invars
        ):
            varying.update(eqn.outvars)
    return varying


def _lint_loop_keys(eqn, path, memo, findings):
    if eqn.primitive.name == "scan":
        body = unwrap(eqn.params["jaxpr"])
        const_count = eqn.params["num_consts"]
    else:
        body = unwrap(eqn.params["body_jaxpr"])
        const_count = eqn.params["body_nconsts"]
    varying = _loop_varying_vars(body, const_count)
    for pos in range(const_count):
        var = body.invars[pos]
        if not _is_keyish(var):
            continue
        for _, (kind, data) in _var_consumers(body, var, memo):
            if kind == "random_fold_in":
                if data is None:  # unresolvable data: assume per-iteration
                    continue
                if data[0] == "var" and data[1] in varying:
                    continue
                if data[0] == "invar" and body.invars[data[1]] in varying:
                    continue
            findings.append(
                Finding(
                    "rng",
                    "loop-invariant-key",
                    path,
                    f"loop-constant key {var} consumed by {kind} inside the "
                    "loop body — every iteration draws the same randoms",
                )
            )


def rng_findings(jaxpr: JaxprLike) -> list[Finding]:
    """Run the full RNG lint over a traced program."""
    findings: list[Finding] = []
    memo: dict = {}
    seen: set = set()
    _lint_scope(unwrap(jaxpr), "", memo, findings, seen)
    return findings
