"""Observability subsystem (DESIGN.md §15): the flight recorder.

Four small layers, strictly ordered by distance from the kernels:

- ``obs.stats``      — ``StepStats``, the fixed per-step diagnostic record
  every ``Resampler.step``/``step_rows`` returns (in-kernel on the pallas
  backends, composed from ``core.metrics`` bitwise-identically elsewhere).
- ``obs.telemetry``  — ``Telemetry``, the scan-carried trajectory record the
  consumers (`run_filter`, `run_smc_sampler`, `smc_decode`) return when
  asked; structurally absent from the jaxpr when off.
- ``obs.trace``      — nested profiler spans naming every dispatch
  ``family/backend/entry/plane_dtype``; a no-op unless enabled.
- ``obs.sink``       — JSONL event emitter for the benchmark harness.

The invariant tying them together: telemetry NEVER changes what a program
computes — same launch counts, same ancestor stream, bit-identical
estimates with it on or off (analyzer pass 6, ``analysis/telemetry.py``).
"""

from repro.obs.sink import JsonlSink
from repro.obs.stats import StepStats, stats_from_vector
from repro.obs.telemetry import Telemetry
from repro.obs.trace import dispatch_span, enable_tracing, span, tracing_enabled

__all__ = [
    "JsonlSink",
    "StepStats",
    "Telemetry",
    "dispatch_span",
    "enable_tracing",
    "span",
    "stats_from_vector",
    "tracing_enabled",
]
