"""End-to-end SIR particle filter on the univariate nonlinear growth model
(paper §7, eqs. 22-23): tracks a simulated trajectory, reports RMSE and the
Resample Ratio (eq. 25) for Megopolis vs alternatives.

    PYTHONPATH=src python examples/particle_filter.py [--particles 16384]

``--bank S`` instead runs a SCENARIO BANK (DESIGN.md §4): S differently
parameterised UNGM instances filtered side by side in one jitted scan —
one batched resampling launch per step instead of S — and prints the
per-scenario RMSE plus the bank-vs-naive-loop speedup.

    PYTHONPATH=src python examples/particle_filter.py --bank 8
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    MegopolisSpec,
    MetropolisC1Spec,
    MetropolisSpec,
    PrefixSumSpec,
)
from repro.pf.filter import (
    ParticleFilter,
    run_filter,
    run_filter_bank,
    run_filter_timed,
    simulate,
)
from repro.pf.metrics import resample_ratio, rmse
from repro.pf.models import ungm, ungm_family, ungm_theta


def run_bank_demo(args):
    model = ungm_family()
    scenarios = [
        ungm_theta(amp=4.0 + 8.0 * s / max(args.bank - 1, 1), obs_var=0.5 + 0.25 * s)
        for s in range(args.bank)
    ]
    thetas = jax.tree.map(lambda *xs: jnp.stack(xs), *scenarios)
    truths, obs = [], []
    for s, th in enumerate(scenarios):
        xs, zs = simulate(jax.random.PRNGKey(100 + s), model, args.steps, theta=th)
        truths.append(np.asarray(xs))
        obs.append(zs)
    obs = jnp.stack(obs)

    pf = ParticleFilter(model, args.particles, resampler=MegopolisSpec(num_iters=args.iters))
    key = jax.random.PRNGKey(42)

    bank = jax.jit(lambda k: run_filter_bank(k, pf, obs, thetas=thetas))
    jax.block_until_ready(bank(key))  # compile
    t0 = time.perf_counter()
    ests = jax.block_until_ready(bank(key))
    t_bank = time.perf_counter() - t0

    keys = jax.random.split(key, args.bank)
    loop = jax.jit(lambda k, z, th: run_filter(k, pf, z, theta=th))
    jax.block_until_ready(loop(keys[0], obs[0], scenarios[0]))  # compile
    t0 = time.perf_counter()
    for s in range(args.bank):
        jax.block_until_ready(loop(keys[s], obs[s], scenarios[s]))
    t_loop = time.perf_counter() - t0

    print(f"UNGM scenario bank: S={args.bank}, {args.particles} particles, "
          f"{args.steps} steps, B={args.iters} (megopolis)\n")
    print(f"{'scenario':>8s} {'amp':>6s} {'obs_var':>8s} {'RMSE':>8s}")
    for s, th in enumerate(scenarios):
        err = rmse(np.asarray(ests[s])[None], truths[s])
        print(f"{s:8d} {float(th['amp']):6.2f} {float(th['obs_var']):8.2f} {err:8.3f}")
    print(f"\nbank: {t_bank*1e3:8.1f} ms   naive loop: {t_loop*1e3:8.1f} ms   "
          f"speedup: {t_loop / t_bank:5.2f}x")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--particles", type=int, default=1 << 14)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--iters", type=int, default=30, help="B (paper §7 baseline)")
    ap.add_argument("--bank", type=int, default=0,
                    help="run S scenarios as one batched filter bank instead")
    args = ap.parse_args()
    if args.bank:
        return run_bank_demo(args)

    model = ungm()
    key = jax.random.PRNGKey(42)
    k_sim, k_flt = jax.random.split(key)
    truth, obs = simulate(k_sim, model, args.steps)

    print(f"UNGM, {args.particles} particles, {args.steps} steps, B={args.iters}\n")
    print(f"{'resampler':22s} {'RMSE':>8s} {'resample ratio':>15s}")
    # Each competitor is one typed spec — hyperparameters travel inside it
    # (DESIGN.md §9), so there is no per-algorithm kwargs plumbing here.
    for spec in (MegopolisSpec(num_iters=args.iters),
                 MetropolisSpec(num_iters=args.iters),
                 MetropolisC1Spec(num_iters=args.iters, partition_size_bytes=128),
                 PrefixSumSpec(kind="improved_systematic")):
        pf = ParticleFilter(model, args.particles, resampler=spec)
        ests, times = run_filter_timed(k_flt, pf, obs)
        err = rmse(np.asarray(ests)[None], np.asarray(truth))
        print(f"{spec.name:22s} {err:8.3f} {resample_ratio(times):15.3f}")


if __name__ == "__main__":
    main()
