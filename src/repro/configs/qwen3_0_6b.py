"""Qwen3 0.6B [hf:Qwen/Qwen3-0.6B] — dense, qk-norm, GQA.

28L  d_model=1024  16H (GQA kv=8, head_dim=128)  d_ff=3072  vocab=151936.
Pure full attention -> long_500k skipped.
"""

from repro.configs import ArchSpec
from repro.models import ModelConfig

ARCH = ArchSpec(
    name="qwen3-0.6b",
    family="dense",
    source="hf:Qwen/Qwen3-8B (family config, 0.6B sizes)",
    model=ModelConfig(
        name="qwen3-0.6b",
        num_layers=28,
        d_model=1024,
        num_heads=16,
        num_kv_heads=8,
        head_dim=128,
        d_ff=3072,
        vocab_size=151936,
        mlp_type="swiglu",
        qk_norm=True,
        layer_pattern=("attn",),
        rope_theta=1_000_000.0,
        long_context_ok=False,
    ),
    smoke=ModelConfig(
        name="qwen3-smoke",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=256,
        vocab_size=512,
        mlp_type="swiglu",
        qk_norm=True,
        layer_pattern=("attn",),
        remat=False,
    ),
    microbatches=16,
    notes="qk-norm; head_dim 128 > d_model/heads (decoupled head width)",
)
