"""Production meshes (functions, never module-level constants — importing
this module must not initialise the jax backend).

Axes:
  * ``pod``   — hierarchical DP across pods (2 pods in the dry-run; scales
                to any pod count: gradient reduce-scatter intra-pod,
                all-reduce across pods);
  * ``data``  — within-pod data parallelism + FSDP/ZeRO param sharding;
  * ``model`` — tensor/expert parallelism (Megatron-style).

16x16 = 256 chips per pod (TPU v5e pod slice); 2x16x16 = 512 chips for the
multi-pod pass.
"""

from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(*, model: int = 1):
    """Development/test mesh over whatever devices exist (CPU: 1 device
    unless the caller set --xla_force_host_platform_device_count)."""
    n = len(jax.devices())
    assert n % model == 0, (n, model)
    return jax.make_mesh((n // model, model), ("data", "model"))


def dp_degree(mesh) -> int:
    """Total data-parallel replicas = product of non-'model' axes."""
    return int(np.prod([mesh.shape[a] for a in mesh.axis_names if a != "model"]))


def batch_axes(mesh):
    """Mesh axes the global batch dim is sharded over."""
    axes = tuple(a for a in mesh.axis_names if a != "model")
    return axes if len(axes) > 1 else axes[0]
