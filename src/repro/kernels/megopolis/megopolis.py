"""Megopolis resampling — Pallas TPU kernel (the paper's Alg. 5, TPU-native).

Memory-access contract (DESIGN.md §2):

  * particle weights live in HBM as ``f32[R, 128]`` (R = N/128 rows);
  * the coalescing segment is one (8, 128) f32 VMEM tile (SEG = 1024
    particles, the TPU analogue of the paper's 32-thread warp segment);
  * grid = (num_tiles, B), iteration axis innermost.  For grid step
    (t, b) the *comparison* block index is computed from a scalar-prefetched
    offset table: ``(t + o[b] // SEG) mod num_tiles`` — so every load the
    kernel ever issues is a whole, aligned, contiguous tile (the paper's
    Fig. 4b "wrapped sequential" pattern, 0 wasted words);
  * the intra-segment wrap ``(i + o[b]) mod SEG`` is a register-level flat
    roll of the tile — no extra memory traffic;
  * per-(particle, iteration) uniforms come from a stateless counter hash
    (no CURAND state loads/stores — beyond-paper win, see EXPERIMENTS.md §Perf);
  * the current ancestor's weight ``w[k]`` is carried by VALUE in a VMEM
    scratch accumulator (never re-fetched), exactly like the register-carried
    ``w_k`` in the CUDA original.

Validated in ``interpret=True`` mode bit-exactly against ``ref.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import (
    TILE,
    flat_roll,
    gather_state,
    hash_uniform,
    step_select,
    step_stats,
    tile_lane_ids,
)

SUBLANES = 8
LANES = 128
SEG = TILE  # 1024 particles = one (8,128) f32 tile


def _sweep(t, b, o, seed, w_own, w_cmp, k_prev, wk_prev, n_total):
    """One accept/reject sweep of one (8,128) tile (Alg. 5 lines 5-14).

    Shared verbatim by the single-bank and batched kernel bodies so the two
    can never drift arithmetically; ``k_prev``/``wk_prev`` are the carried
    ancestor/weight values (ignored at b == 0, where k <- i and w[k] is
    seeded from the tile's own weights)."""
    i_global = tile_lane_ids(t)  # particle index (Alg. 5 line 5)

    k = jnp.where(b == 0, i_global, k_prev)  # k <- i      (Alg. 5 line 6)
    wk = jnp.where(b == 0, w_own, wk_prev)  # w[k] by value (register carry)

    # j = i_aligned + o_aligned + (i + o) mod SEG   (Alg. 5 lines 7-11)
    # block fetch already applied i_aligned + o_aligned; flat-roll applies
    # the intra-segment wrap.
    w_j = flat_roll(w_cmp, o % SEG)
    o_aligned = o - (o % SEG)
    j_global = (t * SEG + o_aligned + (i_global + o) % SEG) % n_total

    u = hash_uniform(seed, i_global, b, dtype=w_j.dtype)
    accept = u * wk <= w_j  # u <= w[j]/w[k]  (line 13)
    return jnp.where(accept, j_global, k), jnp.where(accept, w_j, wk)


def _kernel(offsets_ref, seed_ref, w_own_ref, w_cmp_ref, k_ref, wk_ref):
    """Grid step (t, b): one accept/reject sweep of tile t at iteration b."""
    t = pl.program_id(0)
    b = pl.program_id(1)
    n_total = pl.num_programs(0) * SEG
    k_new, wk_new = _sweep(
        t, b, offsets_ref[b], seed_ref[0],
        w_own_ref[...].astype(jnp.float32), w_cmp_ref[...].astype(jnp.float32),
        k_ref[...], wk_ref[...], n_total,
    )
    k_ref[...] = k_new
    wk_ref[...] = wk_new


def _kernel_batch(offsets_ref, seeds_ref, w_own_ref, w_cmp_ref, k_ref, wk_ref):
    """Grid step (s, t, b): row s of the bank, tile t, iteration b.

    The offset table is scalar-prefetched ONCE for the whole bank (the
    batch-axis analogue of Alg. 5's globally shared offset); rows decorrelate
    through their per-row RNG seed ``seeds[s]`` only.  Block shapes carry a
    leading 1 for the batch axis."""
    s = pl.program_id(0)
    t = pl.program_id(1)
    b = pl.program_id(2)
    n_total = pl.num_programs(1) * SEG
    k_new, wk_new = _sweep(
        t, b, offsets_ref[b], seeds_ref[s],
        w_own_ref[0].astype(jnp.float32), w_cmp_ref[0].astype(jnp.float32),
        k_ref[0], wk_ref[...], n_total,
    )
    k_ref[0] = k_new
    wk_ref[...] = wk_new


def _kernel_fused(offsets_ref, seed_ref, w_own_ref, w_cmp_ref, planes_ref,
                  k_ref, out_ref, wk_ref):
    """Fused resample+gather grid step (t, b): the Alg. 5 sweep, then — at
    the LAST iteration only — the ancestor's state tile is copied from the
    resident plane stack straight to the output ref (DESIGN.md §11).  The
    ancestor index never round-trips through HBM between selection and
    copy; it is the VMEM carry ``k_ref`` itself."""
    t = pl.program_id(0)
    b = pl.program_id(1)
    n_total = pl.num_programs(0) * SEG
    k_new, wk_new = _sweep(
        t, b, offsets_ref[b], seed_ref[0],
        w_own_ref[...].astype(jnp.float32), w_cmp_ref[...].astype(jnp.float32),
        k_ref[...], wk_ref[...], n_total,
    )
    k_ref[...] = k_new
    wk_ref[...] = wk_new

    @pl.when(b == pl.num_programs(1) - 1)
    def _copy_state():
        out_ref[...] = gather_state(planes_ref[...], k_new)


def _kernel_fused_rows(offsets_ref, seeds_ref, w_own_ref, w_cmp_ref,
                       planes_ref, k_ref, out_ref, wk_ref):
    """Fused grid step (s, t, b) over a bank: per-row offset TABLE rows
    ``offsets[s]`` + per-row seed, so row s is bit-identical to the fused
    single kernel with that row's table (passing identical rows recovers
    the shared-offset bank contract of ``_kernel_batch``)."""
    s = pl.program_id(0)
    t = pl.program_id(1)
    b = pl.program_id(2)
    n_total = pl.num_programs(1) * SEG
    k_new, wk_new = _sweep(
        t, b, offsets_ref[s, b], seeds_ref[s],
        w_own_ref[0].astype(jnp.float32), w_cmp_ref[0].astype(jnp.float32),
        k_ref[0], wk_ref[...], n_total,
    )
    k_ref[0] = k_new
    wk_ref[...] = wk_new

    @pl.when(b == pl.num_programs(2) - 1)
    def _copy_state():
        out_ref[0] = gather_state(planes_ref[0], k_new)


def _kernel_step(offsets_ref, seed_ref, thr_ref, lw_own_ref, lw_cmp_ref,
                 lw_full_ref, planes_ref, k_ref, out_ref, stats_ref,
                 wk_ref, st_ref):
    """Fused STEP grid step (t, b): the whole SMC resample decision on-chip.

    At (0, 0) a prelude reduces the resident log-weight array to the step
    statistics (normalisation shift m, normalised ESS, log-evidence
    increment) and latches the resample decision ``ess_norm < threshold``
    into SMEM scratch.  Every sweep then runs on ``exp(lw - m)`` — the SAME
    normalised weights the composed path hands to ``apply`` — and the last
    iteration's epilogue either commits the selected ancestors or the
    identity permutation (state copy becomes a self-gather no-op)."""
    t = pl.program_id(0)
    b = pl.program_id(1)
    n_total = pl.num_programs(0) * SEG

    @pl.when((t == 0) & (b == 0))
    def _prelude():
        m, ess_norm, incr, maxw, deg = step_stats(
            lw_full_ref[...].astype(jnp.float32).reshape(n_total), n_total)
        do = ess_norm < thr_ref[0]
        st_ref[0] = m
        st_ref[1] = jnp.where(do, jnp.float32(1.0), jnp.float32(0.0))
        st_ref[2] = jnp.where(deg, jnp.float32(1.0), jnp.float32(0.0))
        stats_ref[0] = ess_norm
        stats_ref[1] = jnp.where(do, incr, jnp.float32(0.0))
        stats_ref[2] = jnp.where(do, jnp.float32(1.0), jnp.float32(0.0))
        stats_ref[3] = maxw

    m = st_ref[0]
    do = st_ref[1] > 0.5
    deg = st_ref[2] > 0.5
    # Normalised weights re-land on the plane-dtype grid (the composed path
    # quantises at the public ``apply`` boundary); a no-op at f32.  The §16
    # degenerate latch substitutes the uniform bank BEFORE the requantise —
    # the same value ``normalise_log_weights`` hands the composed path.
    w_own = jnp.exp(lw_own_ref[...].astype(jnp.float32) - m)
    w_cmp = jnp.exp(lw_cmp_ref[...].astype(jnp.float32) - m)
    w_own = jnp.where(deg, jnp.float32(1.0 / n_total), w_own)
    w_cmp = jnp.where(deg, jnp.float32(1.0 / n_total), w_cmp)
    w_own = w_own.astype(lw_own_ref.dtype).astype(jnp.float32)
    w_cmp = w_cmp.astype(lw_cmp_ref.dtype).astype(jnp.float32)
    k_new, wk_new = _sweep(
        t, b, offsets_ref[b], seed_ref[0],
        w_own, w_cmp, k_ref[...], wk_ref[...], n_total,
    )
    k_ref[...] = k_new
    wk_ref[...] = wk_new

    @pl.when(b == pl.num_programs(1) - 1)
    def _commit():
        k_sel = step_select(do, k_new, t)
        k_ref[...] = k_sel
        out_ref[...] = gather_state(planes_ref[...], k_sel)


def _kernel_step_rows(offsets_ref, seeds_ref, thr_ref, lw_own_ref, lw_cmp_ref,
                      lw_full_ref, planes_ref, k_ref, out_ref, stats_ref,
                      wk_ref, st_ref):
    """Fused STEP over a bank, grid (s, t, b): per-row offset tables and
    seeds as in ``_kernel_fused_rows``; the prelude re-runs at each row's
    (t, b) == (0, 0) so the SMEM (m, do) latch and the per-row stats row
    ``stats[s]`` are that row's own decision."""
    s = pl.program_id(0)
    t = pl.program_id(1)
    b = pl.program_id(2)
    n_total = pl.num_programs(1) * SEG

    @pl.when((t == 0) & (b == 0))
    def _prelude():
        m, ess_norm, incr, maxw, deg = step_stats(
            lw_full_ref[0].astype(jnp.float32).reshape(n_total), n_total)
        do = ess_norm < thr_ref[0]
        st_ref[0] = m
        st_ref[1] = jnp.where(do, jnp.float32(1.0), jnp.float32(0.0))
        st_ref[2] = jnp.where(deg, jnp.float32(1.0), jnp.float32(0.0))
        stats_ref[s, 0] = ess_norm
        stats_ref[s, 1] = jnp.where(do, incr, jnp.float32(0.0))
        stats_ref[s, 2] = jnp.where(do, jnp.float32(1.0), jnp.float32(0.0))
        stats_ref[s, 3] = maxw

    m = st_ref[0]
    do = st_ref[1] > 0.5
    deg = st_ref[2] > 0.5
    w_own = jnp.exp(lw_own_ref[0].astype(jnp.float32) - m)
    w_cmp = jnp.exp(lw_cmp_ref[0].astype(jnp.float32) - m)
    w_own = jnp.where(deg, jnp.float32(1.0 / n_total), w_own)
    w_cmp = jnp.where(deg, jnp.float32(1.0 / n_total), w_cmp)
    w_own = w_own.astype(lw_own_ref.dtype).astype(jnp.float32)
    w_cmp = w_cmp.astype(lw_cmp_ref.dtype).astype(jnp.float32)
    k_new, wk_new = _sweep(
        t, b, offsets_ref[s, b], seeds_ref[s],
        w_own, w_cmp, k_ref[0], wk_ref[...], n_total,
    )
    k_ref[0] = k_new
    wk_ref[...] = wk_new

    @pl.when(b == pl.num_programs(2) - 1)
    def _commit():
        k_sel = step_select(do, k_new, t)
        k_ref[0] = k_sel
        out_ref[0] = gather_state(planes_ref[0], k_sel)


@functools.partial(jax.jit, static_argnames=("num_iters", "interpret"))
def megopolis_pallas(
    weights2d: jnp.ndarray,
    offsets: jnp.ndarray,
    seed: jnp.ndarray,
    *,
    num_iters: int,
    interpret: bool = True,
) -> jnp.ndarray:
    """Raw pallas_call. ``weights2d``: f32[R, 128] with R % 8 == 0;
    ``offsets``: int32[B]; ``seed``: uint32[1].  Returns int32[R, 128]."""
    rows, lanes = weights2d.shape
    assert lanes == LANES and rows % SUBLANES == 0
    num_tiles = rows // SUBLANES

    def _cmp_index(t, b, offs, seed):
        # aligned block chosen by the shared offset (wraps mod num_tiles)
        return (t + offs[b] // SEG) % num_tiles, 0

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # offsets + seed live in SMEM, prefetched
        grid=(num_tiles, num_iters),
        in_specs=[
            # own tile: block index constant in b -> fetched once per t
            pl.BlockSpec((SUBLANES, LANES), lambda t, b, offs, seed: (t, 0)),
            pl.BlockSpec((SUBLANES, LANES), _cmp_index),
        ],
        out_specs=pl.BlockSpec((SUBLANES, LANES), lambda t, b, offs, seed: (t, 0)),
        scratch_shapes=[pltpu.VMEM((SUBLANES, LANES), jnp.float32)],
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((rows, lanes), jnp.int32),
        interpret=interpret,
    )(offsets, seed, weights2d, weights2d)


@functools.partial(jax.jit, static_argnames=("num_iters", "interpret"))
def megopolis_pallas_batch(
    weights3d: jnp.ndarray,
    offsets: jnp.ndarray,
    seeds: jnp.ndarray,
    *,
    num_iters: int,
    interpret: bool = True,
) -> jnp.ndarray:
    """Batched pallas_call: a whole ``[Bz, R, 128]`` weight bank in ONE launch.

    Grid grows a LEADING batch dimension (Bz, num_tiles, num_iters) — the
    iteration axis stays innermost so the VMEM ``w[k]`` carry still runs the
    full accept/reject chain per (row, tile) before moving on.  ``offsets``:
    int32[num_iters], ONE table shared by every row (Alg. 5's global offset,
    lifted to the bank — the comparison block index is then identical across
    rows, so the scalar-prefetched schedule is row-invariant); ``seeds``:
    uint32[Bz], one stateless-RNG stream per row.  Returns int32[Bz, R, 128];
    row s is bit-identical to ``megopolis_pallas(weights3d[s], offsets,
    seeds[s:s+1], ...)`` (asserted in tests/test_batched.py).
    """
    bsz, rows, lanes = weights3d.shape
    assert lanes == LANES and rows % SUBLANES == 0
    num_tiles = rows // SUBLANES

    def _own_index(s, t, b, offs, seeds):
        return s, t, 0

    def _cmp_index(s, t, b, offs, seeds):
        # aligned block chosen by the bank-shared offset (wraps mod num_tiles)
        return s, (t + offs[b] // SEG) % num_tiles, 0

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # shared offsets + per-row seeds in SMEM
        grid=(bsz, num_tiles, num_iters),
        in_specs=[
            pl.BlockSpec((1, SUBLANES, LANES), _own_index),
            pl.BlockSpec((1, SUBLANES, LANES), _cmp_index),
        ],
        out_specs=pl.BlockSpec((1, SUBLANES, LANES), _own_index),
        scratch_shapes=[pltpu.VMEM((SUBLANES, LANES), jnp.float32)],
    )
    return pl.pallas_call(
        _kernel_batch,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bsz, rows, lanes), jnp.int32),
        interpret=interpret,
    )(offsets, seeds, weights3d, weights3d)


@functools.partial(jax.jit, static_argnames=("num_iters", "interpret"))
def megopolis_pallas_fused(
    weights2d: jnp.ndarray,
    planes: jnp.ndarray,
    offsets: jnp.ndarray,
    seed: jnp.ndarray,
    *,
    num_iters: int,
    interpret: bool = True,
):
    """Fused resample+gather pallas_call (DESIGN.md §11).  ``planes``:
    particle state as a ``[d_pad, R, 128]`` plane stack (VMEM-resident);
    other arguments as for ``megopolis_pallas``.  Returns ``(ancestors
    int32[R, 128], state [d_pad, R, 128])`` — the ancestor stream is
    identical to the unfused kernel's (same sweep arithmetic, same RNG)."""
    rows, lanes = weights2d.shape
    assert lanes == LANES and rows % SUBLANES == 0
    d_pad = planes.shape[0]
    assert planes.shape[1:] == (rows, lanes)
    num_tiles = rows // SUBLANES

    def _cmp_index(t, b, offs, seed):
        return (t + offs[b] // SEG) % num_tiles, 0

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(num_tiles, num_iters),
        in_specs=[
            pl.BlockSpec((SUBLANES, LANES), lambda t, b, offs, seed: (t, 0)),
            pl.BlockSpec((SUBLANES, LANES), _cmp_index),
            # whole state plane stack resident; block index constant in
            # (t, b) -> fetched once per launch
            pl.BlockSpec((d_pad, rows, LANES), lambda t, b, offs, seed: (0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((SUBLANES, LANES), lambda t, b, offs, seed: (t, 0)),
            pl.BlockSpec((d_pad, SUBLANES, LANES), lambda t, b, offs, seed: (0, t, 0)),
        ],
        scratch_shapes=[pltpu.VMEM((SUBLANES, LANES), jnp.float32)],
    )
    return pl.pallas_call(
        _kernel_fused,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((rows, lanes), jnp.int32),
            jax.ShapeDtypeStruct((d_pad, rows, lanes), planes.dtype),
        ],
        interpret=interpret,
    )(offsets, seed, weights2d, weights2d, planes)


@functools.partial(jax.jit, static_argnames=("num_iters", "interpret"))
def megopolis_pallas_fused_rows(
    weights3d: jnp.ndarray,
    planes4d: jnp.ndarray,
    offsets2d: jnp.ndarray,
    seeds: jnp.ndarray,
    *,
    num_iters: int,
    interpret: bool = True,
):
    """Fused bank launch: grid (Bz, num_tiles, num_iters) with PER-ROW
    offset tables ``offsets2d`` int32[Bz, num_iters] and per-row seeds.

    Row s is bit-identical to ``megopolis_pallas_fused(weights3d[s],
    planes4d[s], offsets2d[s], seeds[s:s+1], ...)`` — the explicit-key bank
    path (``apply_rows``).  Passing identical table rows recovers the
    shared-offset ``apply``-bank contract (one scalar-prefetch schedule,
    row-invariant comparison blocks).  Returns ``(int32[Bz, R, 128],
    [Bz, d_pad, R, 128])``."""
    bsz, rows, lanes = weights3d.shape
    assert lanes == LANES and rows % SUBLANES == 0
    d_pad = planes4d.shape[1]
    assert planes4d.shape == (bsz, d_pad, rows, lanes)
    num_tiles = rows // SUBLANES

    def _own_index(s, t, b, offs, seeds):
        return s, t, 0

    def _cmp_index(s, t, b, offs, seeds):
        return s, (t + offs[s, b] // SEG) % num_tiles, 0

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(bsz, num_tiles, num_iters),
        in_specs=[
            pl.BlockSpec((1, SUBLANES, LANES), _own_index),
            pl.BlockSpec((1, SUBLANES, LANES), _cmp_index),
            pl.BlockSpec(
                (1, d_pad, rows, LANES), lambda s, t, b, offs, seeds: (s, 0, 0, 0)
            ),
        ],
        out_specs=[
            pl.BlockSpec((1, SUBLANES, LANES), _own_index),
            pl.BlockSpec(
                (1, d_pad, SUBLANES, LANES), lambda s, t, b, offs, seeds: (s, 0, t, 0)
            ),
        ],
        scratch_shapes=[pltpu.VMEM((SUBLANES, LANES), jnp.float32)],
    )
    return pl.pallas_call(
        _kernel_fused_rows,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((bsz, rows, lanes), jnp.int32),
            jax.ShapeDtypeStruct((bsz, d_pad, rows, lanes), planes4d.dtype),
        ],
        interpret=interpret,
    )(offsets2d, seeds, weights3d, weights3d, planes4d)


@functools.partial(jax.jit, static_argnames=("num_iters", "interpret"))
def megopolis_pallas_step(
    log_weights2d: jnp.ndarray,
    planes: jnp.ndarray,
    offsets: jnp.ndarray,
    seed: jnp.ndarray,
    thr: jnp.ndarray,
    *,
    num_iters: int,
    interpret: bool = True,
):
    """Fused SMC-step pallas_call (DESIGN.md §12): normalise → ESS →
    conditional resample → state copy, ONE launch.  ``log_weights2d``:
    f32[R, 128] UNNORMALISED log-weights (streamed per tile AND kept
    whole-array resident for the on-chip reduction — the step form
    inherits the whole-weights VMEM cap); ``thr``: f32[1] ESS/N trigger.
    Returns ``(ancestors int32[R, 128], state [d_pad, R, 128], stats f32[4]
    = (ess_norm, log_evidence_incr, resampled, max_weight) — the in-kernel
    StepStats vector of DESIGN.md §15)``."""
    rows, lanes = log_weights2d.shape
    assert lanes == LANES and rows % SUBLANES == 0
    d_pad = planes.shape[0]
    assert planes.shape[1:] == (rows, lanes)
    num_tiles = rows // SUBLANES

    def _cmp_index(t, b, offs, seed, thr):
        return (t + offs[b] // SEG) % num_tiles, 0

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,  # offsets + seed + f32 ESS threshold
        grid=(num_tiles, num_iters),
        in_specs=[
            pl.BlockSpec((SUBLANES, LANES), lambda t, b, o, s, r: (t, 0)),
            pl.BlockSpec((SUBLANES, LANES), _cmp_index),
            # whole log-weight array resident for the (0,0) stats prelude
            pl.BlockSpec((rows, LANES), lambda t, b, o, s, r: (0, 0)),
            pl.BlockSpec((d_pad, rows, LANES), lambda t, b, o, s, r: (0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((SUBLANES, LANES), lambda t, b, o, s, r: (t, 0)),
            pl.BlockSpec((d_pad, SUBLANES, LANES), lambda t, b, o, s, r: (0, t, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        scratch_shapes=[
            pltpu.VMEM((SUBLANES, LANES), jnp.float32),
            pltpu.SMEM((3,), jnp.float32),  # (m, do, deg) latch across grid steps
        ],
    )
    return pl.pallas_call(
        _kernel_step,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((rows, lanes), jnp.int32),
            jax.ShapeDtypeStruct((d_pad, rows, lanes), planes.dtype),
            jax.ShapeDtypeStruct((4,), jnp.float32),
        ],
        interpret=interpret,
    )(offsets, seed, thr, log_weights2d, log_weights2d, log_weights2d, planes)


@functools.partial(jax.jit, static_argnames=("num_iters", "interpret"))
def megopolis_pallas_step_rows(
    log_weights3d: jnp.ndarray,
    planes4d: jnp.ndarray,
    offsets2d: jnp.ndarray,
    seeds: jnp.ndarray,
    thr: jnp.ndarray,
    *,
    num_iters: int,
    interpret: bool = True,
):
    """Fused SMC-step bank launch: row s is bit-identical to
    ``megopolis_pallas_step(log_weights3d[s], planes4d[s], offsets2d[s],
    seeds[s:s+1], thr, ...)`` — each row takes its OWN resample decision.
    Returns ``(int32[Bz, R, 128], [Bz, d_pad, R, 128], f32[Bz, 4])``."""
    bsz, rows, lanes = log_weights3d.shape
    assert lanes == LANES and rows % SUBLANES == 0
    d_pad = planes4d.shape[1]
    assert planes4d.shape == (bsz, d_pad, rows, lanes)
    num_tiles = rows // SUBLANES

    def _own_index(s, t, b, offs, seeds, thr):
        return s, t, 0

    def _cmp_index(s, t, b, offs, seeds, thr):
        return s, (t + offs[s, b] // SEG) % num_tiles, 0

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(bsz, num_tiles, num_iters),
        in_specs=[
            pl.BlockSpec((1, SUBLANES, LANES), _own_index),
            pl.BlockSpec((1, SUBLANES, LANES), _cmp_index),
            pl.BlockSpec((1, rows, LANES), lambda s, t, b, o, se, r: (s, 0, 0)),
            pl.BlockSpec(
                (1, d_pad, rows, LANES), lambda s, t, b, o, se, r: (s, 0, 0, 0)
            ),
        ],
        out_specs=[
            pl.BlockSpec((1, SUBLANES, LANES), _own_index),
            pl.BlockSpec(
                (1, d_pad, SUBLANES, LANES), lambda s, t, b, o, se, r: (s, 0, t, 0)
            ),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        scratch_shapes=[
            pltpu.VMEM((SUBLANES, LANES), jnp.float32),
            pltpu.SMEM((3,), jnp.float32),
        ],
    )
    return pl.pallas_call(
        _kernel_step_rows,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((bsz, rows, lanes), jnp.int32),
            jax.ShapeDtypeStruct((bsz, d_pad, rows, lanes), planes4d.dtype),
            jax.ShapeDtypeStruct((bsz, 4), jnp.float32),
        ],
        interpret=interpret,
    )(offsets2d, seeds, thr, log_weights3d, log_weights3d, log_weights3d, planes4d)
