"""Adaptive SMC sampler — the paper's AIS workload (DESIGN.md §10).

The canonical adaptive-importance-sampling consumer of a resampler (Syed
et al., *Optimised Annealed SMC*): N particles anneal from a normalised
base π0 to an unnormalised target γ along the geometric path, with the
classic reweight → (ESS-triggered) resample → MCMC-move step per
temperature, all inside ONE jitted ``lax.scan``.  The resampling stage is
ANY ``ResamplerSpec`` on any backend (DESIGN.md §9) — which is the point:
the sampler's logZ estimate has an analytic ground truth on the
``ais/targets.py`` families, so resampler quality (bias/variance of logZ,
cf. Murray, Lee & Jacob) is finally SCORED, not eyeballed
(benchmarks/ais_bench.py, EXPERIMENTS.md §AIS).

``run_smc_sampler_bank`` lifts the whole sampler onto the §4 scenario
axis: S independent targets (a theta family of posteriors) run under one
jitted scan with a single batched resampler launch per temperature —
row ``b`` is bit-identical to the single-scenario call with split key
``b`` (the DESIGN.md §4 contract, gated by tests/test_ais.py).
"""

from __future__ import annotations

import dataclasses
import difflib
from typing import Optional, Union

import jax
import jax.numpy as jnp

from repro.ais.moves import MOVES, TARGET_ACCEPT, adapt_step_size
from repro.ais.schedule import geometric_schedule, next_temperature
from repro.ais.targets import Target
from repro.core.metrics import log_mean_weight
from repro.core.resamplers.batched import split_batch_keys
from repro.core.spec import ResamplerSpec, coerce_spec
from repro.obs.telemetry import Telemetry

SCHEDULES = ("geometric", "adaptive")


def _check_choice(value, choices, field: str):
    if value not in choices:
        hint = difflib.get_close_matches(str(value), choices, n=1)
        did_you_mean = f" — did you mean {hint[0]!r}?" if hint else ""
        raise ValueError(
            f"SMCSamplerConfig.{field} must be one of {sorted(choices)}; "
            f"got {value!r}{did_you_mean}"
        )


@dataclasses.dataclass(frozen=True)
class SMCSamplerConfig:
    """Annealed-SMC configuration.  ``resampler`` accepts a registry name or
    a typed ``ResamplerSpec`` (DESIGN.md §9); with a spec, ``num_iters``
    below is not consulted.  ``schedule='adaptive'`` selects the next
    temperature by CESS bisection at each step (``ais/schedule.py``), with
    ``num_temps`` as the cap — once β saturates at 1 the remaining steps
    are pure rejuvenation at the target (Δβ = 0 contributes nothing to
    logZ)."""

    num_particles: int
    num_temps: int = 24
    schedule: str = "geometric"  # 'geometric' | 'adaptive'
    beta_min: float = 1e-2  # geometric ladder start
    target_cess: float = 0.9  # adaptive: conditional-ESS fraction per step
    resampler: Union[str, ResamplerSpec] = "megopolis"
    num_iters: Union[int, str] = 16  # B (paper eq. 3; fixed application prior)
    ess_threshold: float = 0.5  # resample when normalised ESS < threshold
    move: str = "rwm"  # 'rwm' | 'mala'
    num_move_steps: int = 2
    step_size: float = 0.5  # initial ε, adapted per temperature
    target_accept: Optional[float] = None  # None -> per-move optimal scaling
    adapt_rate: float = 0.5

    def __post_init__(self):
        _check_choice(self.schedule, SCHEDULES, "schedule")
        _check_choice(self.move, tuple(MOVES), "move")
        if self.num_temps < 1:
            raise ValueError(
                f"SMCSamplerConfig.num_temps must be >= 1; got {self.num_temps}"
            )
        if self.num_particles < 1:
            raise ValueError(
                f"SMCSamplerConfig.num_particles must be >= 1; got {self.num_particles}"
            )
        if self.num_move_steps < 1:
            raise ValueError(
                "SMCSamplerConfig.num_move_steps must be >= 1 (the rejuvenation "
                f"sweep is what keeps the anneal mixing); got {self.num_move_steps}"
            )
        if not 0.0 < self.ess_threshold <= 1.0:
            raise ValueError(
                "SMCSamplerConfig.ess_threshold must be in (0, 1]; "
                f"got {self.ess_threshold}"
            )
        if not 0.0 < self.target_cess < 1.0:
            raise ValueError(
                "SMCSamplerConfig.target_cess must be in (0, 1); "
                f"got {self.target_cess}"
            )

    def resampler_spec(self) -> ResamplerSpec:
        if isinstance(self.resampler, ResamplerSpec):
            return self.resampler
        return coerce_spec(self.resampler, num_iters=self.num_iters)

    def resolved_target_accept(self) -> float:
        return (
            TARGET_ACCEPT[self.move]
            if self.target_accept is None
            else self.target_accept
        )


def _call(fn, *args, theta=None):
    """Invoke a target callable, appending ``theta`` only when given (the
    pf/filter.py scenario idiom)."""
    return fn(*args) if theta is None else fn(*args, theta)


def _logz_increment(log_w: jnp.ndarray, n: int) -> jnp.ndarray:
    """log( (1/N) Σ exp(log_w) ) over the particle axis — the normalising
    constant absorbed at each resample (and at the end).  Delegates to the
    shared ``repro.core.metrics.log_mean_weight`` helper — the SAME
    arithmetic the fused ``Resampler.step`` kernels latch on-chip, so the
    in-scan increments (which now come from ``step``) and this final
    absorption agree bit-for-bit on every backend."""
    del n  # the particle axis length is read off log_w itself
    return log_mean_weight(log_w, axis=-1)


def run_smc_sampler(
    key, target: Target, cfg: SMCSamplerConfig, theta=None, telemetry=False,
    checkpoint=None,
):
    """Anneal π0 → γ; returns a dict pytree:

    * ``particles`` f32[N, d] — final-temperature particle system;
    * ``log_w`` f32[N] — residual (since-last-resample) log-weights;
    * ``log_z`` f32[] — the logZ = log ∫γ estimate;
    * ``betas`` / ``ess`` / ``accept`` f32[T] — per-temperature schedule,
      normalised pre-resampling ESS, and move acceptance;
    * ``num_resamples`` i32[].

    ``telemetry=True`` (DESIGN.md §15) returns ``(result, Telemetry)``
    instead: ``Telemetry.steps`` carries the full per-temperature
    ``StepStats`` trajectory (fields ``[T]``), ``accept`` the move
    acceptance rates and ``betas`` the β ladder actually visited — all
    values this scan computes anyway, so the flag adds zero launches and
    leaves the result dict bit-identical (analyzer pass 6 audits this).

    Fully jittable (wrap in ``jax.jit``; the config and target are closed
    over as static).  ``theta`` selects a scenario of a theta-family
    target and is what ``run_smc_sampler_bank`` maps over.

    ``checkpoint`` (a ``repro.resilience.CheckpointPolicy``) chunks the
    temperature scan at the snapshot period with durable carry snapshots
    between chunks — kill-and-resume returns the bit-identical result
    (DESIGN.md §16; host-loop chunking, so pair it with eager use, not an
    outer ``jax.jit``).
    """
    n = cfg.num_particles
    resampler = cfg.resampler_spec().build()
    move = MOVES[cfg.move]
    target_accept = cfg.resolved_target_accept()
    adaptive = cfg.schedule == "adaptive"
    betas_in = (
        jnp.zeros((cfg.num_temps,), jnp.float32)
        if adaptive
        else geometric_schedule(cfg.num_temps, cfg.beta_min)
    )

    def body(carry, beta_in):
        x, log_w, log_z, beta_prev, step_size, k, n_res = carry
        k, ks = jax.random.split(k)
        k_res, k_move = jax.random.split(ks)
        # 1. reweight: geometric-path tilt at the current particles
        delta = _call(target.log_target, x, theta=theta) - _call(
            target.log_base, x, theta=theta
        )
        if adaptive:
            beta = next_temperature(log_w, delta, beta_prev, cfg.target_cess)
        else:
            beta = beta_in
        log_w = log_w + (beta - beta_prev) * delta
        # 2. ESS-triggered resample (absorbs the running logZ increment):
        #    the FUSED step (Resampler.step, DESIGN.md §12) — normalise,
        #    ESS, branch, resample+gather and the logZ increment in ONE
        #    launch on kernel backends; no host-side cond around the
        #    resampler any more.  The no-op branch returns x bit-identical
        #    with incr = 0, so log_z/log_w advance exactly as the old
        #    host-branched composition did.
        x, _, stats = resampler.step(k_res, log_w, x, cfg.ess_threshold)
        ess_norm = stats.ess_norm
        did = (ess_norm < cfg.ess_threshold).astype(jnp.int32)
        log_z = log_z + stats.log_evidence_incr
        log_w = jnp.where(did.astype(bool), jnp.zeros_like(log_w), log_w)
        # 3. rejuvenate against π_β, then adapt the step size
        def log_prob(y):
            return (1.0 - beta) * _call(target.log_base, y, theta=theta) + (
                beta
            ) * _call(target.log_target, y, theta=theta)

        x, accept = move(k_move, x, log_prob, step_size, cfg.num_move_steps)
        step_size = adapt_step_size(
            step_size, accept, target_accept, cfg.adapt_rate
        )
        carry = (x, log_w, log_z, beta, step_size, k, n_res + did)
        ys = (beta, ess_norm, accept)
        if telemetry:  # Python-static: absent from the trace when off
            ys = ys + (stats,)
        return carry, ys

    k0, key = jax.random.split(key)
    x0 = _call(target.sample_base, k0, n, theta=theta)
    carry0 = (
        x0,
        jnp.zeros((n,), jnp.float32),
        jnp.float32(0.0),
        jnp.float32(0.0),
        jnp.float32(cfg.step_size),
        key,
        jnp.int32(0),
    )
    if checkpoint is None:
        carry, ys = jax.lax.scan(body, carry0, betas_in)
    else:
        from repro.resilience.checkpointing import checkpointed_scan

        carry, ys = checkpointed_scan(body, carry0, betas_in, checkpoint)
    betas, ess_hist, accepts = ys[:3]
    x, log_w, log_z, _, _, _, n_res = carry
    result = {
        "particles": x,
        "log_w": log_w,
        "log_z": log_z + _logz_increment(log_w, n),
        "betas": betas,
        "ess": ess_hist,
        "accept": accepts,
        "num_resamples": n_res,
    }
    if telemetry:
        return result, Telemetry(steps=ys[3], accept=accepts, betas=betas)
    return result


def run_smc_sampler_bank(
    key,
    target: Target,
    cfg: SMCSamplerConfig,
    thetas=None,
    num_scenarios: Optional[int] = None,
    telemetry=False,
):
    """S independent samplers under ONE jitted scan (the §4 scenario axis).

    ``thetas`` is a pytree whose leaves carry a leading [S] axis of
    per-scenario target parameters (see ``targets.gaussian_theta``); pass
    ``num_scenarios`` instead for S i.i.d. repeats of a fixed target (the
    Monte-Carlo axis of benchmarks/ais_bench.py).  The key is split once
    along the scenario axis, every stage is vmapped, and resampling is a
    SINGLE batched launch per temperature (``Resampler.batch_rows``), so
    row ``b`` of every output equals ``run_smc_sampler(split(key, S)[b],
    target, cfg, theta=thetas[b])`` bit-for-bit — the same contract as
    ``run_filter_bank``.  Returns the ``run_smc_sampler`` dict with a
    leading [S] axis on every leaf; ``telemetry=True`` returns
    ``(result, Telemetry)`` with every trajectory field laid out ``[S, T]``
    (matching the dict's ``betas``/``ess``/``accept``).
    """
    if thetas is None and num_scenarios is None:
        raise ValueError(
            "run_smc_sampler_bank: pass per-scenario `thetas` (leading [S] "
            "leaves) or `num_scenarios` for i.i.d. repeats"
        )
    if thetas is not None:
        num_s = jax.tree.leaves(thetas)[0].shape[0]
        if num_scenarios is not None and num_scenarios != num_s:
            raise ValueError(
                f"run_smc_sampler_bank: num_scenarios={num_scenarios} disagrees "
                f"with the thetas leading axis [{num_s}]"
            )
    else:
        num_s = num_scenarios
    n = cfg.num_particles
    resampler = cfg.resampler_spec().build()
    move = MOVES[cfg.move]
    target_accept = cfg.resolved_target_accept()
    adaptive = cfg.schedule == "adaptive"
    betas_in = (
        jnp.zeros((cfg.num_temps,), jnp.float32)
        if adaptive
        else geometric_schedule(cfg.num_temps, cfg.beta_min)
    )
    theta_axes = None if thetas is None else jax.tree.map(lambda _: 0, thetas)
    keys = split_batch_keys(key, num_s)

    def init_one(k, th):
        k0, kc = jax.random.split(k)
        return _call(target.sample_base, k0, n, theta=th), kc

    x0, carry_keys = jax.vmap(init_one, in_axes=(0, theta_axes))(keys, thetas)

    def body(carry, beta_in):
        xs, log_w, log_z, beta_prev, step_size, ks, n_res = carry
        step = jax.vmap(jax.random.split)(ks)
        ks_next, step_keys = step[:, 0], step[:, 1]
        rr = jax.vmap(jax.random.split)(step_keys)
        k_res, k_move = rr[:, 0], rr[:, 1]
        # 1. reweight (vmapped tilt; per-row adaptive β via the batched
        #    while_loop — converged rows hold their carry, so each row's
        #    bisection equals its unbatched run)
        delta = jax.vmap(
            lambda x, th: _call(target.log_target, x, theta=th)
            - _call(target.log_base, x, theta=th),
            in_axes=(0, theta_axes),
        )(xs, thetas)
        if adaptive:
            beta = jax.vmap(next_temperature, in_axes=(0, 0, 0, None))(
                log_w, delta, beta_prev, cfg.target_cess
            )
        else:
            beta = jnp.full((num_s,), beta_in, jnp.float32)
        log_w = log_w + (beta - beta_prev)[:, None] * delta
        # 2. ONE batched FUSED step launch (step_rows, DESIGN.md §12): each
        #    row takes its OWN resample-or-not branch on-chip, so the
        #    per-row where-selects of the old apply_rows composition are
        #    gone — row b is bit-identical to the single path's step.
        xs, _, stats = resampler.step_rows(
            k_res, log_w, xs, cfg.ess_threshold
        )
        ess_norm = stats.ess_norm
        trigger = ess_norm < cfg.ess_threshold
        log_z = log_z + stats.log_evidence_incr
        log_w = jnp.where(trigger[:, None], 0.0, log_w)
        # 3. rejuvenate + adapt, per row
        def move_one(k, x, sz, b, th):
            def log_prob(y):
                return (1.0 - b) * _call(target.log_base, y, theta=th) + (
                    b
                ) * _call(target.log_target, y, theta=th)

            return move(k, x, log_prob, sz, cfg.num_move_steps)

        xs, accept = jax.vmap(move_one, in_axes=(0, 0, 0, 0, theta_axes))(
            k_move, xs, step_size, beta, thetas
        )
        step_size = adapt_step_size(
            step_size, accept, target_accept, cfg.adapt_rate
        )
        carry = (
            xs,
            log_w,
            log_z,
            beta,
            step_size,
            ks_next,
            n_res + trigger.astype(jnp.int32),
        )
        ys = (beta, ess_norm, accept)
        if telemetry:  # Python-static: absent from the trace when off
            ys = ys + (stats,)
        return carry, ys

    carry0 = (
        x0,
        jnp.zeros((num_s, n), jnp.float32),
        jnp.zeros((num_s,), jnp.float32),
        jnp.zeros((num_s,), jnp.float32),
        jnp.full((num_s,), cfg.step_size, jnp.float32),
        carry_keys,
        jnp.zeros((num_s,), jnp.int32),
    )
    carry, ys = jax.lax.scan(body, carry0, betas_in)
    betas, ess_hist, accepts = ys[:3]
    xs, log_w, log_z, _, _, _, n_res = carry
    result = {
        "particles": xs,
        "log_w": log_w,
        "log_z": log_z + _logz_increment(log_w, n),
        "betas": betas.T,
        "ess": ess_hist.T,
        "accept": accepts.T,
        "num_resamples": n_res,
    }
    if telemetry:
        steps = jax.tree.map(jnp.transpose, ys[3])  # [T, S] -> [S, T]
        return result, Telemetry(steps=steps, accept=accepts.T, betas=betas.T)
    return result
