"""Inclusive prefix sum — Pallas TPU kernel (block scan + sequential carry).

Backs the prefix-sum resamplers (multinomial Alg. 7, systematic Alg. 8)
the paper compares against in §6.5.  The TPU grid is sequential, so the
cross-block carry is a single SMEM scalar threaded through grid steps —
no second pass, no atomics (contrast the GPU's Blelloch two-phase scan).

The f32 numerical-instability story the paper tells (§1) is reproducible
with this kernel: summing 2^22 weights in f32 loses ~2-3 digits vs f64,
which is what inflates multinomial/systematic bias at large N (Fig. 8).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

SUBLANES = 8
LANES = 128
SEG = SUBLANES * LANES


def _kernel(x_ref, y_ref, carry_ref):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        carry_ref[0] = jnp.zeros((), jnp.float32)

    # Operand tiles may arrive compressed (DESIGN.md §14); the scan itself —
    # and the CDF it emits — is always f32, so bisection boundaries match the
    # f32 kernels bitwise.
    flat = x_ref[...].astype(jnp.float32).reshape(SEG)
    local = jnp.cumsum(flat)
    y_ref[...] = (local + carry_ref[0]).reshape(SUBLANES, LANES)
    carry_ref[0] = carry_ref[0] + local[-1]


def scan_tiles(x2d: jnp.ndarray) -> jnp.ndarray:
    """In-VALUE replica of ``_kernel``'s grid walk, for use INSIDE other
    kernel bodies (the fused step): cumsum per (8, 128) tile flattened to
    SEG lanes, scalar carry across tiles.  The per-tile arithmetic is
    ``_kernel``'s line for line, so the resulting CDF is bit-identical to
    ``prefix_sum_pallas`` on the same input — the property the fused-step
    parity gate rests on."""
    rows = x2d.shape[0]
    num_tiles = rows // SUBLANES

    def body(carry, tile):
        local = jnp.cumsum(tile.astype(jnp.float32).reshape(SEG))
        y = local + carry
        return carry + local[-1], y.reshape(SUBLANES, LANES)

    _, ys = jax.lax.scan(
        body, jnp.zeros((), jnp.float32), x2d.reshape(num_tiles, SUBLANES, LANES)
    )
    return ys.reshape(x2d.shape)


@functools.partial(jax.jit, static_argnames=("interpret",))
def prefix_sum_pallas(x2d: jnp.ndarray, *, interpret: bool = True) -> jnp.ndarray:
    rows, lanes = x2d.shape
    assert lanes == LANES and rows % SUBLANES == 0
    num_tiles = rows // SUBLANES
    return pl.pallas_call(
        _kernel,
        grid=(num_tiles,),
        in_specs=[pl.BlockSpec((SUBLANES, LANES), lambda t: (t, 0))],
        out_specs=pl.BlockSpec((SUBLANES, LANES), lambda t: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, lanes), jnp.float32),
        scratch_shapes=[pltpu.SMEM((1,), jnp.float32)],
        interpret=interpret,
    )(x2d)
