"""The typed spec API's contract (DESIGN.md §9), enforced end to end:

  1. static safety: specs are hashable, usable as jit static args, and
     ``jax.tree`` round-trips return the same object;
  2. eager validation: bad segment / backend / kind / num_iters raise at
     construction, not at trace time;
  3. ``num_iters='auto'`` routes through eq. (3) at call time (jit-safe);
  4. name parity: every registry name builds via ``spec_from_name`` and its
     single/batch paths are bit-identical to the legacy string lookups;
  5. backend dispatch: 'xla' is bit-identical to 'reference'; the pallas
     pair reproduces the kernel wrappers;
  6. the legacy surfaces (``get_resampler`` KeyError hints,
     ``ParticleFilter.resampler_kwargs``) degrade gracefully.
"""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    MegopolisSpec,
    MetropolisC1Spec,
    MetropolisC2Spec,
    MetropolisSpec,
    PrefixSumSpec,
    RejectionSpec,
    coerce_spec,
    get_resampler,
    get_resampler_batch,
    list_resamplers,
    metropolis,
    select_iterations,
    spec_from_name,
)
from repro.core.spec import AUTO_MAX_ITERS, Resampler

ALL = list_resamplers()
N = 512
BATCH = 3
ITERS = 12


def _weights(key, n=N):
    return jax.random.uniform(key, (n,)) + 1e-3


def _bank(key, batch=BATCH, n=N):
    return jax.random.uniform(key, (batch, n)) + 1e-3


# ------------------------------------------------------------ static safety
def test_specs_are_hashable_and_comparable():
    assert hash(MegopolisSpec(num_iters=8)) == hash(MegopolisSpec(num_iters=8))
    assert MegopolisSpec(num_iters=8) == MegopolisSpec(num_iters=8)
    assert MegopolisSpec(num_iters=8) != MegopolisSpec(num_iters=9)
    # usable as dict keys (e.g. a sweep-result table keyed by spec)
    table = {MetropolisC1Spec(partition_size_bytes=ps): ps for ps in (128, 2048)}
    assert table[MetropolisC1Spec(partition_size_bytes=128)] == 128


def test_spec_as_jit_static_argument(base_key):
    w = _weights(jax.random.fold_in(base_key, 1))

    @jax.jit
    def run(spec, key, weights):
        return spec.build()(key, weights)

    # registered static: the spec rides in the treedef, no static_argnums needed
    a = run(MegopolisSpec(num_iters=ITERS), base_key, w)
    assert a.shape == (N,) and a.dtype == jnp.int32

    from functools import partial

    @partial(jax.jit, static_argnums=0)
    def run2(spec, key, weights):
        return spec.build()(key, weights)

    np.testing.assert_array_equal(
        np.asarray(run2(MegopolisSpec(num_iters=ITERS), base_key, w)), np.asarray(a)
    )


@pytest.mark.parametrize("name", ALL)
def test_tree_util_round_trip(name):
    spec = spec_from_name(name)
    leaves, treedef = jax.tree.flatten(spec)
    assert leaves == []  # fully static: no traced content
    assert jax.tree.unflatten(treedef, leaves) == spec


def test_replace_sweeps_revalidate():
    base = MetropolisC2Spec(num_iters=4)
    sweep = [base.replace(partition_size_bytes=ps) for ps in (128, 256, 512)]
    assert [s.partition_size_bytes for s in sweep] == [128, 256, 512]
    assert all(s.num_iters == 4 for s in sweep)
    with pytest.raises(ValueError, match="partition_size_bytes"):
        base.replace(partition_size_bytes=0)


# ---------------------------------------------------------- eager validation
@pytest.mark.parametrize(
    "ctor, match",
    [
        (lambda: MegopolisSpec(num_iters=0), "num_iters"),
        (lambda: MegopolisSpec(num_iters=2.5), "num_iters"),
        (lambda: MegopolisSpec(segment=0), "segment"),
        (lambda: MegopolisSpec(backend="cuda"), "backend"),
        (lambda: MegopolisSpec(num_iters=4, backend="pallas_interpret"), "segment=1024"),
        (lambda: MetropolisC1Spec(partition_size_bytes=-1), "partition_size_bytes"),
        # C1/C2 pallas kernels partition at one VMEM tile: the spec must say so
        (lambda: MetropolisC1Spec(backend="pallas"), "4096"),
        (lambda: MetropolisC2Spec(backend="pallas_interpret", partition_size_bytes=2048), "4096"),
        (lambda: RejectionSpec(max_iters=0), "max_iters"),
        (lambda: PrefixSumSpec(kind="sistematic"), "systematic"),
        (lambda: PrefixSumSpec(backend="cuda"), "backend"),
    ],
)
def test_validation_is_eager(ctor, match):
    with pytest.raises(ValueError, match=match):
        ctor()


def test_spec_from_name_rejects_unknown_kwargs():
    with pytest.raises(TypeError, match="partition_size_bytes"):
        spec_from_name("megopolis", partition_size_bytes=128)
    # legacy API uniformity: iteration-free families tolerate num_iters
    assert spec_from_name("systematic", num_iters=30) == PrefixSumSpec(kind="systematic")
    assert spec_from_name("rejection", num_iters=30) == RejectionSpec()


def test_get_resampler_keyerror_suggests_nearest_name():
    with pytest.raises(KeyError, match="did you mean 'megopolis'"):
        get_resampler("megapolis")
    with pytest.raises(KeyError, match="did you mean 'systematic'"):
        get_resampler_batch("systemattic")
    with pytest.raises(KeyError, match="choices"):
        spec_from_name("not_even_close_xyz")


# ------------------------------------------------------------- 'auto' iters
def test_auto_iterations_match_eq3_for_metropolis(base_key):
    """num_iters only feeds the loop bound + fold_in counter, so the 'auto'
    (traced) count is bit-identical to the same static count."""
    w = jnp.full((N,), 1e-7).at[137].set(1.0)
    b = int(select_iterations(w, 0.01))
    assert b < AUTO_MAX_ITERS  # the clamp is not binding here
    got = MetropolisSpec().build()(base_key, w)
    want = metropolis(base_key, w, b)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_auto_megopolis_resolves_degeneracy_and_jits(base_key):
    w = jnp.full((N,), 1e-7).at[137].set(1.0)
    r = MegopolisSpec().build()  # the headline no-tuning call
    a = r(base_key, w)
    assert float(jnp.mean(a == 137)) > 0.95
    a_jit = jax.jit(r)(base_key, w)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(a_jit))
    bank = r.batch(base_key, jnp.stack([w, w]))
    assert bank.shape == (2, N)
    assert float(jnp.mean(bank == 137)) > 0.95


def test_auto_with_pallas_backend_needs_concrete_weights(base_key):
    spec = MegopolisSpec(segment=1024, backend="pallas_interpret")
    w = jax.random.uniform(base_key, (1024,)) + 1e-3
    with pytest.raises(TypeError, match="concrete"):
        jax.jit(spec.build())(base_key, w)


# ------------------------------------------------- name parity vs the legacy
@pytest.mark.parametrize("name", ALL)
def test_spec_single_matches_legacy_registry(name, base_key):
    w = _weights(jax.random.fold_in(base_key, 61))
    key = jax.random.fold_in(base_key, 62)
    r = coerce_spec(name, num_iters=ITERS).build()
    assert isinstance(r, Resampler) and r.name == name
    np.testing.assert_array_equal(
        np.asarray(r(key, w)), np.asarray(get_resampler(name)(key, w, ITERS))
    )


@pytest.mark.parametrize("name", ALL)
def test_spec_batch_matches_legacy_batch_registry(name, base_key):
    w = _bank(jax.random.fold_in(base_key, 63))
    key = jax.random.fold_in(base_key, 64)
    got = coerce_spec(name, num_iters=ITERS).build().batch(key, w)
    want = get_resampler_batch(name)(key, w, ITERS)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_resampler_rejects_wrong_rank(base_key):
    r = MegopolisSpec(num_iters=4).build()
    with pytest.raises(ValueError, match=r"\.batch"):
        r(base_key, jnp.ones((2, N)))
    with pytest.raises(ValueError, match=r"\[B, N\]"):
        r.batch(base_key, jnp.ones((N,)))


# ----------------------------------------------------------- backend dispatch
@pytest.mark.parametrize("name", ["megopolis", "metropolis", "systematic", "rejection"])
def test_xla_backend_bit_identical_to_reference(name, base_key):
    w = _weights(jax.random.fold_in(base_key, 65))
    ref = coerce_spec(name, num_iters=ITERS).build()
    xla = coerce_spec(name, num_iters=ITERS).replace(backend="xla").build()
    np.testing.assert_array_equal(np.asarray(ref(base_key, w)), np.asarray(xla(base_key, w)))
    wb = _bank(jax.random.fold_in(base_key, 66))
    np.testing.assert_array_equal(
        np.asarray(ref.batch(base_key, wb)), np.asarray(xla.batch(base_key, wb))
    )


def test_pallas_interpret_backend_matches_kernel_wrappers(base_key):
    from repro.kernels.megopolis.ops import megopolis_tpu, megopolis_tpu_batch

    n = 1024
    w = jax.random.uniform(jax.random.fold_in(base_key, 67), (n,)) + 1e-3
    r = MegopolisSpec(num_iters=4, segment=1024, backend="pallas_interpret").build()
    np.testing.assert_array_equal(
        np.asarray(r(base_key, w)), np.asarray(megopolis_tpu(base_key, w, 4))
    )
    wb = jax.random.uniform(jax.random.fold_in(base_key, 68), (2, n)) + 1e-3
    np.testing.assert_array_equal(
        np.asarray(r.batch(base_key, wb)), np.asarray(megopolis_tpu_batch(base_key, wb, 4))
    )


# --------------------------------------------------- ParticleFilter frontier
def test_particle_filter_accepts_spec_and_string(base_key):
    from repro.pf import ParticleFilter, run_filter, ungm
    from repro.pf.filter import simulate

    _, zs = simulate(jax.random.fold_in(base_key, 70), ungm(), 5)
    by_name = ParticleFilter(ungm(), 256, resampler="megopolis", num_iters=8)
    by_spec = ParticleFilter(ungm(), 256, resampler=MegopolisSpec(num_iters=8))
    assert by_name.spec == by_spec.spec == MegopolisSpec(num_iters=8)
    k = jax.random.fold_in(base_key, 71)
    np.testing.assert_array_equal(
        np.asarray(run_filter(k, by_name, zs)), np.asarray(run_filter(k, by_spec, zs))
    )


def test_particle_filter_resampler_kwargs_compat_shim(base_key):
    from repro.pf import ParticleFilter, ungm

    with pytest.warns(DeprecationWarning, match="resampler_kwargs"):
        pf = ParticleFilter(ungm(), 256, resampler="metropolis_c1", num_iters=8,
                            resampler_kwargs=(("partition_size_bytes", 2048),))
    assert pf.spec == MetropolisC1Spec(num_iters=8, partition_size_bytes=2048)
    with pytest.raises(ValueError, match="inside the ResamplerSpec"):
        ParticleFilter(ungm(), 256, resampler=MegopolisSpec(num_iters=8),
                       resampler_kwargs=(("segment", 64),))
    # a half-migrated call must fail loudly, not silently drop num_iters
    with pytest.raises(ValueError, match="inside the spec"):
        ParticleFilter(ungm(), 256, resampler=MegopolisSpec(), num_iters=8)
    # string names keep the paper §7 default prior when num_iters is unset
    assert ParticleFilter(ungm(), 256).spec == MegopolisSpec(num_iters=30)


def test_particle_filter_validates_eagerly():
    from repro.pf import ParticleFilter, ungm

    with pytest.raises(KeyError, match="did you mean"):
        ParticleFilter(ungm(), 256, resampler="megapolis")
    with pytest.raises(ValueError, match="num_iters"):
        ParticleFilter(ungm(), 256, resampler="megopolis", num_iters=0)


def test_smc_config_resolves_spec():
    from repro.smc import SMCDecodeConfig

    cfg = SMCDecodeConfig(num_particles=8, max_new_tokens=4, resampler="megopolis",
                          num_iters=7, segment=16)
    assert cfg.resampler_spec() == MegopolisSpec(num_iters=7, segment=16)
    # segment/num_iters don't leak into families that lack them
    cfg2 = SMCDecodeConfig(num_particles=8, max_new_tokens=4, resampler="systematic")
    assert cfg2.resampler_spec() == PrefixSumSpec(kind="systematic")
    spec = MetropolisSpec(num_iters=3)
    cfg3 = SMCDecodeConfig(num_particles=8, max_new_tokens=4, resampler=spec)
    assert cfg3.resampler_spec() is spec


def test_distributed_resampler_spec_validation():
    from repro.core.distributed import make_distributed_resampler

    with pytest.raises(TypeError, match="MegopolisSpec"):
        make_distributed_resampler(None, spec=MetropolisSpec(num_iters=4))
    with pytest.raises(ValueError, match="concrete num_iters"):
        make_distributed_resampler(None, spec=MegopolisSpec())  # num_iters='auto'
    with pytest.raises(ValueError, match="backend"):
        make_distributed_resampler(
            None, spec=MegopolisSpec(num_iters=4, segment=1024, backend="pallas"))
    with pytest.raises(ValueError, match="schedule"):
        make_distributed_resampler(None, spec=MegopolisSpec(num_iters=4, segment=1024),
                                   schedule="bogus")
