from repro.kernels.megopolis.ops import megopolis_tpu  # noqa: F401
from repro.kernels.megopolis.ref import megopolis_ref  # noqa: F401
