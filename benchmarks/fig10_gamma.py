"""Paper Fig. 10 (Appendix A): same protocol as Fig. 6 with Gamma(alpha, 1)
weight sequences, alpha in {0.5, 2, 3, 10, 50}."""

from __future__ import annotations

import argparse

import jax

from benchmarks.common import print_table
from benchmarks.fig6_quality_speed import run
from repro.core.iterations import select_iterations
from repro.core.weightgen import gamma_weights


def _b_for_alpha(alpha: float) -> int:
    # estimate eq. (3) B from one large sample of the gamma family
    w = gamma_weights(jax.random.PRNGKey(0), 1 << 14, alpha)
    return int(select_iterations(w, 0.01))


def main(argv=None):
    from repro.core.spec import BACKENDS

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--backend", choices=BACKENDS, default="reference")
    args = ap.parse_args(argv)
    rows = run(full=args.full, weight_gen=gamma_weights,
               grid=(0.5, 2.0, 3.0, 10.0, 50.0), param_name="alpha",
               csv_name="fig10.csv", b_for=_b_for_alpha, backend=args.backend)
    print_table([r for r in rows if r["n"] == max(x["n"] for x in rows)])


if __name__ == "__main__":
    main()
