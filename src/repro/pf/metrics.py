"""Filtering metrics: RMSE (paper eq. 24) and resample ratio (eq. 25)."""

from __future__ import annotations

import numpy as np


def rmse(estimates: np.ndarray, truth: np.ndarray) -> float:
    """Paper eq. (24) for a [K, T] batch of runs vs [T] truth (or [T] vs [T])."""
    est = np.asarray(estimates, np.float64)
    tru = np.asarray(truth, np.float64)
    if est.ndim == 1:
        est = est[None]
    # sqrt over the K Monte-Carlo axis first, then average over time.
    per_t = np.sqrt(np.mean((est - tru[None, :]) ** 2, axis=0))
    return float(np.mean(per_t))


def resample_ratio(times: dict) -> float:
    """tau_s2 / (tau_s1 + tau_s2 + tau_s3), eq. (25)."""
    total = times["predict_update"] + times["resample"] + times["estimate"]
    return times["resample"] / max(total, 1e-12)
