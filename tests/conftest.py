"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches must
see the single real CPU device; only launch/dryrun.py forces 512 devices."""

import jax
import pytest


@pytest.fixture(scope="session")
def base_key():
    return jax.random.PRNGKey(0)


@pytest.fixture(autouse=True, scope="module")
def _drop_compiled_executables_per_module():
    """Release each module's compiled executables when it finishes.

    A full single-process run compiles thousands of XLA programs; every
    loaded executable holds mmapped regions, and boxes with the default
    ``vm.max_map_count`` (65530) run out mid-suite — XLA then SEGFAULTS on
    the next compile instead of raising.  Clearing the caches at module
    teardown keeps the map count bounded; modules stay fast internally and
    only pay recompiles across module boundaries.
    """
    yield
    jax.clear_caches()
