from repro.kernels.prefix_sum.ops import prefix_sum_tpu  # noqa: F401
from repro.kernels.prefix_sum.ref import prefix_sum_ref  # noqa: F401
