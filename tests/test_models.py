"""Model-stack correctness: SSD-vs-recurrence, decode-vs-prefill parity,
q-chunking exactness, window masks, MoE routing semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import (
    ModelConfig,
    decode_step,
    forward,
    init_cache,
    init_params,
    loss_fn,
    prefill,
)

F32 = dict(dtype=jnp.float32, remat=False)


def tiny_cfg(**kw):
    base = dict(
        name="tiny",
        num_layers=4,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=128,
        vocab_size=97,
        loss_chunk=8,
        q_chunk=64,
    )
    base.update(kw)
    return ModelConfig(**base)


# ----------------------------------------------------------- SSD correctness
def _naive_ssm(x, log_da, b_ssm, c_ssm):
    """Direct per-step recurrence h = h*exp(dA) + dtx (x) B ; y = C . h."""
    bsz, s, h, p = x.shape
    n = b_ssm.shape[-1]
    state = np.zeros((bsz, h, p, n))
    ys = []
    for t in range(s):
        da = np.exp(np.asarray(log_da[:, t]))  # (b,h)
        state = state * da[..., None, None] + np.einsum(
            "bhp,bn->bhpn", np.asarray(x[:, t]), np.asarray(b_ssm[:, t])
        )
        ys.append(np.einsum("bhpn,bn->bhp", state, np.asarray(c_ssm[:, t])))
    return np.stack(ys, axis=1), state


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_ssd_chunked_equals_naive_recurrence(chunk):
    from repro.models.mamba2 import _ssd_chunked

    key = jax.random.PRNGKey(0)
    bsz, s, h, p, n = 2, 16, 3, 4, 5
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (bsz, s, h, p))
    log_da = -jax.nn.softplus(jax.random.normal(ks[1], (bsz, s, h)))
    b_ssm = jax.random.normal(ks[2], (bsz, s, n))
    c_ssm = jax.random.normal(ks[3], (bsz, s, n))
    y, final = _ssd_chunked(x, log_da, b_ssm, c_ssm, chunk)
    y_ref, final_ref = _naive_ssm(x, log_da, b_ssm, c_ssm)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(final), final_ref, rtol=2e-4, atol=2e-5)


def test_mamba_decode_matches_block():
    """Recurrent decode must reproduce the chunked training forward."""
    from repro.models.mamba2 import init_mamba, init_mamba_cache, mamba_block, mamba_decode_step

    cfg = tiny_cfg(layer_pattern=("mamba",), ssm_state=8, ssm_head_dim=16, ssm_chunk=4, **F32)
    p = init_mamba(jax.random.PRNGKey(1), cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 8, cfg.d_model), jnp.float32)
    y_train, _ = mamba_block(p, cfg, x, chunk=4)
    cache = init_mamba_cache(cfg, 2, jnp.float32)
    ys = []
    for t in range(8):
        y_t, cache = mamba_decode_step(p, cfg, x[:, t : t + 1], cache)
        ys.append(y_t)
    y_dec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_train), rtol=2e-3, atol=2e-4)


# ------------------------------------------------------ attention invariants
def test_q_chunking_is_exact():
    cfg_1 = tiny_cfg(q_chunk=4, **F32)
    cfg_2 = tiny_cfg(q_chunk=64, **F32)
    params = init_params(jax.random.PRNGKey(3), cfg_1)
    tokens = jax.random.randint(jax.random.PRNGKey(4), (2, 16), 0, 97)
    h1 = forward(params, cfg_1, tokens)
    h2 = forward(params, cfg_2, tokens)
    # exact in math; fp32 reassociation across chunk shapes leaves ~2e-6 noise
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=1e-4, atol=1e-5)


def test_window_ge_seq_equals_global():
    cfg_swa = tiny_cfg(layer_pattern=("swa",), window=64, **F32)
    cfg_glb = tiny_cfg(layer_pattern=("attn",), **F32)
    params = init_params(jax.random.PRNGKey(5), cfg_swa)
    tokens = jax.random.randint(jax.random.PRNGKey(6), (2, 16), 0, 97)
    h1 = forward(params, cfg_swa, tokens)
    h2 = forward(params, cfg_glb, tokens)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=1e-5, atol=1e-6)


def test_window_blocks_long_range():
    """A token beyond the window must not influence attention output."""
    from repro.models.attention import attention, init_attention

    cfg = tiny_cfg(**F32)
    p = init_attention(jax.random.PRNGKey(7), cfg)
    x = jax.random.normal(jax.random.PRNGKey(8), (1, 12, cfg.d_model), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(12, dtype=jnp.int32), (1, 12))
    out1, _ = attention(p, cfg, x, pos, window=4)
    x2 = x.at[:, 0].add(10.0)  # perturb a token > window away from the tail
    out2, _ = attention(p, cfg, x2, pos, window=4)
    np.testing.assert_allclose(
        np.asarray(out1[:, -1]), np.asarray(out2[:, -1]), rtol=1e-5, atol=1e-6
    )
    assert not np.allclose(np.asarray(out1[:, 0]), np.asarray(out2[:, 0]))


# -------------------------------------------------- decode == prefill parity
@pytest.mark.parametrize(
    "pattern,extra",
    [
        (("attn",), {}),
        (("swa",), {"window": 4}),
        (("mamba",), {"ssm_state": 8, "ssm_head_dim": 16, "ssm_chunk": 4}),
        (("mamba", "shared_attn"), {"ssm_state": 8, "ssm_head_dim": 16, "ssm_chunk": 4}),
        (("attn",), {"num_experts": 4, "top_k": 2}),
    ],
    ids=["attn", "swa", "mamba", "zamba", "moe"],
)
def test_decode_matches_forward(pattern, extra):
    cfg = tiny_cfg(layer_pattern=pattern, qk_norm=True, **extra, **F32)
    params = init_params(jax.random.PRNGKey(9), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(10), (2, 12), 0, 97)
    max_seq = 16

    # full forward logits at the last prefill position
    from repro.models.transformer import logits_fn

    h = forward(params, cfg, tokens)
    ref_last = logits_fn(params, cfg, h[:, -1])

    logits_p, caches = prefill(params, cfg, tokens, max_seq=max_seq)
    np.testing.assert_allclose(
        np.asarray(logits_p), np.asarray(ref_last), rtol=2e-3, atol=2e-4
    )

    # decode two more tokens; compare against forward on the extended seq
    nxt = jax.random.randint(jax.random.PRNGKey(11), (2, 2), 0, 97)
    full = jnp.concatenate([tokens, nxt], axis=1)
    h_full = forward(params, cfg, full)
    lg, caches = decode_step(params, cfg, nxt[:, :1], caches, jnp.int32(12))
    np.testing.assert_allclose(
        np.asarray(lg),
        np.asarray(logits_fn(params, cfg, h_full[:, 12])),
        rtol=2e-3,
        atol=2e-4,
    )
    lg, caches = decode_step(params, cfg, nxt[:, 1:2], caches, jnp.int32(13))
    np.testing.assert_allclose(
        np.asarray(lg),
        np.asarray(logits_fn(params, cfg, h_full[:, 13])),
        rtol=2e-3,
        atol=2e-4,
    )


def test_ring_cache_stays_bounded():
    """SWA ring cache must be O(window), not O(seq)."""
    cfg = tiny_cfg(layer_pattern=("swa",), window=4, **F32)
    caches = init_cache(cfg, batch=2, max_seq=1024)
    assert caches[0]["kv"][0].shape[1] == 4


# ----------------------------------------------------------------- MoE logic
def test_moe_top1_matches_dense_expert_choice():
    """With top-1 routing and ample capacity, MoE == per-token expert MLP."""
    from repro.models.moe import init_moe, moe

    cfg = tiny_cfg(num_experts=4, top_k=1, mlp_type="swiglu", **F32)
    p = init_moe(jax.random.PRNGKey(12), cfg)
    x = jax.random.normal(jax.random.PRNGKey(13), (2, 8, cfg.d_model), jnp.float32)
    out = moe(p, cfg, x, capacity_factor=4.0)

    # dense reference: every token through its argmax expert
    logits = x.reshape(-1, cfg.d_model) @ p["router"]["w"]
    eid = np.asarray(jnp.argmax(logits, -1))
    x2 = np.asarray(x.reshape(-1, cfg.d_model))
    ref = np.zeros_like(x2)
    for t in range(x2.shape[0]):
        e = eid[t]
        h = x2[t] @ np.asarray(p["w1"]["w"][e])
        g = x2[t] @ np.asarray(p["w3"]["w"][e])
        act = (g / (1 + np.exp(-g))) * h
        ref[t] = act @ np.asarray(p["w2"]["w"][e])
    np.testing.assert_allclose(
        np.asarray(out).reshape(-1, cfg.d_model), ref, rtol=2e-3, atol=2e-4
    )


def test_moe_capacity_drops_tokens_gracefully():
    from repro.models.moe import init_moe, moe

    cfg = tiny_cfg(num_experts=2, top_k=1, **F32)
    p = init_moe(jax.random.PRNGKey(14), cfg)
    x = jax.random.normal(jax.random.PRNGKey(15), (1, 16, cfg.d_model), jnp.float32)
    out = moe(p, cfg, x, capacity_factor=0.25)  # force drops
    assert np.isfinite(np.asarray(out)).all()


def test_loss_grad_finite_all_kinds():
    cfg = tiny_cfg(
        layer_pattern=("mamba", "swa", "attn", "shared_attn"),
        window=4,
        num_experts=4,
        top_k=2,
        ssm_state=8,
        ssm_head_dim=16,
        ssm_chunk=4,
        qk_norm=True,
        remat=True,
    )
    params = init_params(jax.random.PRNGKey(16), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(17), (2, 16), 0, 97)
    batch = {"inputs": tokens, "targets": tokens}
    loss, grads = jax.value_and_grad(lambda p: loss_fn(p, cfg, batch))(params)
    assert np.isfinite(float(loss))
    leaves = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in leaves)


def test_fp8_kv_cache_close_to_bf16():
    """cache_dtype=fp8_e4m3 (decode memory-roofline halver) must stay close
    to the full-precision decode path."""
    import dataclasses

    cfg = tiny_cfg(**F32)
    cfg8 = dataclasses.replace(cfg, cache_dtype=jnp.float8_e4m3fn)
    params = init_params(jax.random.PRNGKey(21), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(22), (2, 6), 0, 97)
    logits, caches = prefill(params, cfg, toks, max_seq=8)
    logits8, caches8 = prefill(params, cfg8, toks, max_seq=8)
    nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    l1, _ = decode_step(params, cfg, nxt, caches, jnp.int32(6))
    l8, _ = decode_step(params, cfg8, nxt, caches8, jnp.int32(6))
    # fp8 quantisation noise on K/V: logits agree to ~1e-1 and the argmax
    # token almost always matches
    assert float(jnp.mean(jnp.abs(l1 - l8))) < 0.15
    assert float(jnp.mean((jnp.argmax(l1, -1) == jnp.argmax(l8, -1)).astype(jnp.float32))) >= 0.5
