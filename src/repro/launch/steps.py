"""Per-cell step builders: (arch x shape x mesh) -> jitted fn + input specs.

Every dry-run cell, benchmark and driver goes through ``plan_cell`` so the
shardings, microbatching and input ShapeDtypeStructs are defined in exactly
one place.

Cell kinds:
  * ``train``   — ``train_step(state, batch)``: microbatch-scanned grads
                  (memory), AdamW(+ZeRO-1), donated state.
  * ``prefill`` — ``prefill_step(params, inputs)``: full-seq forward that
                  returns last-token logits + populated decode caches.
  * ``decode``  — ``serve_step(params, caches, tokens, pos)``: one new token
                  against a ``seq_len``-deep cache (the assigned decode_32k /
                  long_500k cells).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ArchSpec, ShapeSpec, SHAPES, get_arch
from repro.launch.mesh import batch_axes, dp_degree
from repro.launch.sharding import fsdp_axes, model_pspecs, named
from repro.models import (
    ModelConfig,
    cache_pspecs,
    init_cache,
    init_params,
    loss_fn,
    prefill,
    decode_step,
)
from repro.models import partitioning
from repro.models.mamba2 import mamba_dims
from repro.optim import AdamWConfig, adamw_init, adamw_update, opt_state_pspecs

# Decode keeps params TP-only while they fit (FSDP all-gather per token is
# pure overhead); archs whose bf16 params exceed this per-chip budget at
# TP16 get 2-D sharding even at decode.
DECODE_FSDP_BYTES = 8 << 30


@dataclasses.dataclass
class CellPlan:
    arch: ArchSpec
    shape: ShapeSpec
    mesh: Any
    kind: str
    fn: Callable  # jitted, ready to .lower(*specs)
    in_specs: tuple  # ShapeDtypeStructs (sharded) for .lower()
    microbatches: int = 1
    notes: str = ""

    def lower(self):
        return self.fn.lower(*self.in_specs)


def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=NamedSharding(mesh, spec))


def _token_specs(cfg: ModelConfig, mesh, rows: int, seq: int, row_spec):
    if cfg.embeds_input:
        return _sds((rows, seq, cfg.d_model), cfg.dtype, mesh, P(*row_spec, None, None))
    return _sds((rows, seq), jnp.int32, mesh, P(*row_spec, None))


def _rules_for(cfg: ModelConfig, mesh, kind: str, *, batch_shardable: bool = True,
               context_parallel: bool = False) -> dict:
    """Logical-axis map for one cell (see models/partitioning.py).

    Head/TP divisibility decides attention strategy:
      * num_heads % tp == 0   -> Megatron head sharding;
      * otherwise             -> sequence-TP for train/prefill (q_seq over
                                 'model'), head_dim sharding for decode.
    KV heads shard over 'model' only when they divide tp (else Megatron-GQA
    replication; danube replicates the cache entirely — 120 head_dim).
    """
    tp = int(mesh.shape["model"])
    baxes = batch_axes(mesh)
    heads_div = cfg.num_heads % tp == 0
    kv_div = cfg.num_kv_heads % tp == 0
    hd_div = cfg.head_dim % tp == 0
    d_inner, ssm_heads, _ = mamba_dims(cfg)
    r = dict(
        batch=baxes if batch_shardable else None,
        seq=None,
        embed=None,
        vocab="model",
        attn_out="model" if heads_div else None,
        d_inner="model" if d_inner % tp == 0 else None,
        ssm_heads="model" if (cfg.ssm_head_dim and ssm_heads % tp == 0) else None,
    )
    if kind == "decode":
        r.update(
            heads="model" if (heads_div and kv_div) else None,
            kv_heads="model" if kv_div else None,
            head_dim="model" if (not kv_div and hd_div) else None,
            q_seq=None,
            kv_seq="data" if context_parallel else None,
        )
    else:
        r.update(
            heads="model" if heads_div else None,
            q_seq=None if heads_div else "model",
            kv_heads="model" if kv_div else None,
            head_dim=None,
            kv_seq=None,
        )
    return r


def _params_specs(cfg: ModelConfig, mesh, *, fsdp: bool):
    pspecs = model_pspecs(cfg, mesh, fsdp=fsdp)
    shapes = jax.eval_shape(lambda k: init_params(k, cfg), jax.random.PRNGKey(0))
    specs = jax.tree.map(
        lambda sh, sp: _sds(sh.shape, sh.dtype, mesh, sp),
        shapes,
        pspecs,
        is_leaf=lambda x: isinstance(x, (jax.ShapeDtypeStruct, P)),
    )
    return specs, pspecs


# ------------------------------------------------------------------ train
def make_train_plan(arch: ArchSpec, shape: ShapeSpec, mesh) -> CellPlan:
    cfg = arch.model
    dp = dp_degree(mesh)
    baxes = batch_axes(mesh)
    baxes_t = baxes if isinstance(baxes, tuple) else (baxes,)
    gb = shape.global_batch
    # microbatches: arch ask, bounded so each microbatch still spans DP
    micro = min(arch.microbatches, max(1, gb // dp))
    rows = gb // micro
    assert rows * micro == gb and rows % dp == 0, (gb, micro, dp)

    param_specs, pspecs = _params_specs(cfg, mesh, fsdp=True)
    shapes = jax.eval_shape(lambda k: init_params(k, cfg), jax.random.PRNGKey(0))
    f_axes, f_size = fsdp_axes(mesh)
    opt_pspecs = opt_state_pspecs(
        pspecs, shapes, data_axis=f_axes, data_size=f_size, zero1=True
    )
    moment_dtype = jnp.dtype(arch.moment_dtype)
    opt_shapes = jax.eval_shape(functools.partial(adamw_init, moment_dtype=moment_dtype), shapes)
    opt_specs = jax.tree.map(
        lambda sh, sp: _sds(sh.shape, sh.dtype, mesh, sp),
        opt_shapes,
        opt_pspecs,
        is_leaf=lambda x: isinstance(x, (jax.ShapeDtypeStruct, P)),
    )
    state_specs = {"params": param_specs, "opt": opt_specs}

    # batch arrives pre-split: (micro, rows, S) — axis 1 sharded over DP
    if cfg.embeds_input:
        inp = _sds((micro, rows, shape.seq_len, cfg.d_model), cfg.dtype, mesh,
                   P(None, baxes, None, None))
    else:
        inp = _sds((micro, rows, shape.seq_len), jnp.int32, mesh, P(None, baxes, None))
    tgt = _sds((micro, rows, shape.seq_len), jnp.int32, mesh, P(None, baxes, None))
    batch_specs = {"inputs": inp, "targets": tgt}

    opt_cfg = AdamWConfig(moment_dtype=arch.moment_dtype)
    rules_kw = _rules_for(cfg, mesh, "train")

    def train_step(state, batch):
        with partitioning.rules(mesh, **rules_kw):
            return _train_step_body(state, batch)

    def _train_step_body(state, batch):
        params = state["params"]
        # Weights-stationary compute copy: cast the f32 master params to the
        # compute dtype ONCE, on their sharded layout, before any use.  The
        # FSDP all-gather then moves bf16 (half the wire bytes) and happens
        # once per STEP, not once per layer use — the gathered compute
        # weights resident per chip are 2N/tp bytes, which fits every dense
        # arch (expert weights never gather at all: moe_sharded contracts
        # them 2-D-sharded with activation psums instead).
        params_c = jax.tree.map(
            lambda w: w.astype(cfg.dtype) if w.ndim >= 2 else w, params)

        def loss_of(p, mb):
            return loss_fn(p, cfg, mb)

        if micro == 1:
            mb = jax.tree.map(lambda x: x[0], batch)
            loss, grads = jax.value_and_grad(loss_of)(params_c, mb)
        else:
            def body(carry, mb):
                l_acc, g_acc = carry
                loss_mb, g = jax.value_and_grad(loss_of)(params_c, mb)
                return (l_acc + loss_mb, jax.tree.map(lambda a, b: a + b.astype(a.dtype), g_acc, g)), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32), zeros), batch)
            inv = 1.0 / micro
            loss = loss * inv
            grads = jax.tree.map(lambda g: g * inv, grads)

        new_params, new_opt, metrics = adamw_update(opt_cfg, params, grads, state["opt"])
        metrics["loss"] = loss
        return {"params": new_params, "opt": new_opt}, metrics

    fn = jax.jit(
        train_step,
        in_shardings=(jax.tree.map(lambda s: s.sharding, state_specs),
                      jax.tree.map(lambda s: s.sharding, batch_specs)),
        out_shardings=(jax.tree.map(lambda s: s.sharding, state_specs), None),
        donate_argnums=(0,),
    )
    return CellPlan(arch, shape, mesh, "train", fn, (state_specs, batch_specs),
                    microbatches=micro,
                    notes=f"micro={micro} rows/micro={rows} fsdp=on zero1=on "
                          f"moments={arch.moment_dtype}")


# ---------------------------------------------------------------- prefill
def make_prefill_plan(arch: ArchSpec, shape: ShapeSpec, mesh) -> CellPlan:
    cfg = arch.model
    baxes = batch_axes(mesh)
    param_specs, _ = _params_specs(cfg, mesh, fsdp=_decode_needs_fsdp(cfg, mesh))
    # baxes may itself be a tuple (('pod','data')) — it is ONE dim entry
    inp = _token_specs(cfg, mesh, shape.global_batch, shape.seq_len, (baxes,))

    rules_kw = _rules_for(cfg, mesh, "prefill")

    def prefill_step(params, inputs):
        with partitioning.rules(mesh, **rules_kw):
            logits, caches = prefill(params, cfg, inputs, shape.seq_len)
            return logits, caches

    cspecs = cache_pspecs(cfg, batch_axis=baxes, model_axis_size=int(mesh.shape["model"]))
    fn = jax.jit(
        prefill_step,
        in_shardings=(jax.tree.map(lambda s: s.sharding, param_specs),
                      inp.sharding),
        out_shardings=(NamedSharding(mesh, P(baxes, "model")), named(mesh, cspecs)),
    )
    return CellPlan(arch, shape, mesh, "prefill", fn, (param_specs, inp))


# ----------------------------------------------------------------- decode
def _decode_needs_fsdp(cfg: ModelConfig, mesh) -> bool:
    n_bytes = 2 * cfg.num_params()  # bf16
    return n_bytes / int(mesh.shape["model"]) > DECODE_FSDP_BYTES


def make_decode_plan(arch: ArchSpec, shape: ShapeSpec, mesh) -> CellPlan:
    cfg = arch.model
    dp = dp_degree(mesh)
    baxes = batch_axes(mesh)
    b = shape.global_batch
    fsdp = _decode_needs_fsdp(cfg, mesh)
    param_specs, _ = _params_specs(cfg, mesh, fsdp=fsdp)

    if b % dp == 0 and b >= dp:
        batch_axis, seq_axis = baxes, None  # decode_32k: shard the batch
    else:
        batch_axis, seq_axis = None, "data"  # long_500k (B=1): context parallel

    cspecs = cache_pspecs(cfg, batch_axis=batch_axis, seq_axis=seq_axis,
                          model_axis_size=int(mesh.shape["model"]))
    cache_shapes = jax.eval_shape(lambda: init_cache(cfg, b, shape.seq_len))
    cache_sds = jax.tree.map(
        lambda sh, sp: _sds(sh.shape, sh.dtype, mesh, sp),
        cache_shapes,
        cspecs,
        is_leaf=lambda x: isinstance(x, (jax.ShapeDtypeStruct, P)),
    )
    tok = _token_specs(cfg, mesh, b, 1, (batch_axis,))
    pos = _sds((), jnp.int32, mesh, P())

    rules_kw = _rules_for(cfg, mesh, "decode",
                          batch_shardable=seq_axis is None,
                          context_parallel=seq_axis is not None)

    def serve_step(params, caches, tokens, pos):
        with partitioning.rules(mesh, **rules_kw):
            logits, caches = decode_step(params, cfg, tokens, caches, pos)
            # greedy argmax keeps the cell self-contained; samplers live in smc/
            next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return next_tok, logits, caches

    fn = jax.jit(
        serve_step,
        in_shardings=(
            jax.tree.map(lambda s: s.sharding, param_specs),
            jax.tree.map(lambda s: s.sharding, cache_sds),
            tok.sharding,
            pos.sharding,
        ),
        out_shardings=(
            NamedSharding(mesh, P(batch_axis)),
            NamedSharding(mesh, P(batch_axis, "model")),
            jax.tree.map(lambda s: s.sharding, cache_sds),
        ),
        donate_argnums=(1,),
    )
    return CellPlan(arch, shape, mesh, "decode", fn,
                    (param_specs, cache_sds, tok, pos),
                    notes=f"fsdp={'on' if fsdp else 'off'} "
                          f"cache={'batch' if seq_axis is None else 'seq(context-parallel)'}-sharded")


def plan_cell(arch_name: str, shape_name: str, mesh) -> CellPlan:
    arch = get_arch(arch_name)
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        return make_train_plan(arch, shape, mesh)
    if shape.kind == "prefill":
        return make_prefill_plan(arch, shape, mesh)
    return make_decode_plan(arch, shape, mesh)
