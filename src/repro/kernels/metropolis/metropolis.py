"""Metropolis resampling — Pallas TPU kernel (the paper's Alg. 2 strawman).

A faithful port of Metropolis needs a random per-(particle, iteration)
gather over the FULL weight array: the uncoalesced pattern of the paper's
Fig. 2.  On TPU the only way to honour those semantics is to keep the whole
weight array VMEM-resident and gather in-register, which caps N at the VMEM
budget (~1M f32 = 4 MB comfortably).  That cap is itself the finding: the
random-access algorithm does not scale on TPU, while Megopolis streams
aligned tiles from HBM at any N.  The benchmark suite reports this next to
the transaction-model numbers.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import hash_bits, hash_uniform

SUBLANES = 8
LANES = 128
SEG = SUBLANES * LANES


def _kernel(seed_ref, w_full_ref, w_own_ref, k_ref, wk_ref):
    t = pl.program_id(0)
    b = pl.program_id(1)
    seed = seed_ref[0]

    row = lax.broadcasted_iota(jnp.int32, (SUBLANES, LANES), 0)
    col = lax.broadcasted_iota(jnp.int32, (SUBLANES, LANES), 1)
    i_global = t * SEG + row * LANES + col

    @pl.when(b == 0)
    def _init():
        k_ref[...] = i_global
        wk_ref[...] = w_own_ref[...]

    n_total = w_full_ref.shape[0] * LANES
    # Alg. 2 line 5: j ~ U{0, N-1} per (particle, iteration) — random gather.
    j = (hash_bits(seed, i_global, b) % jnp.uint32(n_total)).astype(jnp.int32)
    w_flat = w_full_ref[...].reshape(n_total)
    w_j = jnp.take(w_flat, j.reshape(-1), axis=0).reshape(SUBLANES, LANES)

    u = hash_uniform(seed, i_global + n_total, b, dtype=w_j.dtype)
    accept = u * wk_ref[...] <= w_j
    k_ref[...] = jnp.where(accept, j, k_ref[...])
    wk_ref[...] = jnp.where(accept, w_j, wk_ref[...])


@functools.partial(jax.jit, static_argnames=("num_iters", "interpret"))
def metropolis_pallas(
    weights2d: jnp.ndarray,
    seed: jnp.ndarray,
    *,
    num_iters: int,
    interpret: bool = True,
) -> jnp.ndarray:
    rows, lanes = weights2d.shape
    assert lanes == LANES and rows % SUBLANES == 0
    num_tiles = rows // SUBLANES

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(num_tiles, num_iters),
        in_specs=[
            # whole weight array resident (the uncoalesced strawman's cost)
            pl.BlockSpec((rows, LANES), lambda t, b, seed: (0, 0)),
            pl.BlockSpec((SUBLANES, LANES), lambda t, b, seed: (t, 0)),
        ],
        out_specs=pl.BlockSpec((SUBLANES, LANES), lambda t, b, seed: (t, 0)),
        scratch_shapes=[pltpu.VMEM((SUBLANES, LANES), weights2d.dtype)],
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((rows, lanes), jnp.int32),
        interpret=interpret,
    )(seed, weights2d, weights2d)
