"""End-to-end training driver example: trains a small LM of any assigned
architecture on the deterministic synthetic stream with checkpointing,
heartbeat, straggler detection and exact resume.

Smoke scale by default (seconds on CPU).  A ~100M-parameter run (qwen3
family at width 512) for a few hundred steps:

    PYTHONPATH=src python examples/train_lm.py --arch qwen3-0.6b \
        --steps 300 --global-batch 8 --seq-len 256 --width 512 --layers 12

Interrupt it and re-run with --resume: the loss trajectory continues
exactly where it stopped (tests/test_train_driver.py asserts this).
"""

import argparse
import dataclasses

import jax.numpy as jnp

from repro.configs import get_arch
from repro.launch.train import TrainRun, run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--width", type=int, default=0, help="override d_model")
    ap.add_argument("--layers", type=int, default=0, help="override num_layers")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compress", action="store_true",
                    help="top-k + error-feedback DP gradient compression")
    args = ap.parse_args()

    tr = TrainRun(arch=args.arch, steps=args.steps, global_batch=args.global_batch,
                  seq_len=args.seq_len, smoke=True, ckpt_dir=args.ckpt_dir,
                  ckpt_every=max(10, args.steps // 5), resume=args.resume,
                  compress=args.compress)
    if args.width or args.layers:
        # patch the smoke config in-place via a custom runner
        arch = get_arch(args.arch)
        smoke = arch.smoke
        kw = {}
        if args.width:
            kw.update(d_model=args.width,
                      d_ff=4 * args.width if smoke.d_ff else 0)
        if args.layers:
            kw["num_layers"] = args.layers
        import repro.configs as configs_mod
        patched = dataclasses.replace(arch, smoke=dataclasses.replace(smoke, **kw))
        configs_mod._ALIASES  # registry untouched; monkeypatch get_arch result
        import repro.launch.train as train_mod
        train_mod.get_arch = lambda name: patched  # this process only
    out = run(tr)
    n_done = out["steps_run"]
    if n_done:
        print(f"loss {out['losses'][0]:.4f} -> {out['final_loss']:.4f} "
              f"over {n_done} steps (ckpts in {args.ckpt_dir})")
    else:
        print("nothing to do (already trained to --steps; try a higher --steps)")


if __name__ == "__main__":
    main()
