"""Pallas TPU kernels for the paper's compute hot spots.

  megopolis/   — the paper's contribution with tile-coalesced access
  metropolis/  — the random-access strawman (VMEM-resident baseline)
  prefix_sum/  — sequential-grid block scan (for multinomial/systematic)

Each package ships ``ops.py`` (jit'd public wrapper) and ``ref.py``
(pure-jnp oracle, bit-exact vs the kernel).
"""

from repro.kernels.megopolis.ops import megopolis_tpu, megopolis_tpu_batch  # noqa: F401
from repro.kernels.metropolis.ops import metropolis_tpu  # noqa: F401
from repro.kernels.prefix_sum.ops import prefix_sum_tpu  # noqa: F401
