"""Primitive layers — pure functions over param dicts (no framework deps).

Params are plain nested dicts of ``f32`` arrays (master copies); compute
happens in the model's activation dtype (bf16 by default) with f32
accumulation where it matters (norms, softmax, losses).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def init_linear(key, d_in: int, d_out: int, *, scale: float | None = None):
    scale = scale if scale is not None else d_in**-0.5
    return {"w": jax.random.normal(key, (d_in, d_out), jnp.float32) * scale}


def linear(p, x, dtype):
    return x.astype(dtype) @ p["w"].astype(dtype)


def init_rmsnorm(d: int):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(p, x, eps: float = 1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * p["scale"]).astype(dt)


def init_embedding(key, vocab: int, d: int):
    return {"table": jax.random.normal(key, (vocab, d), jnp.float32) * (d**-0.5)}


def embed(p, ids, dtype):
    return jnp.take(p["table"].astype(dtype), ids, axis=0)


def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., seq, heads, head_dim); positions: (..., seq) int."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)  # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., s, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]  # (..., s, 1, hd/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
