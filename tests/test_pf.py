"""End-to-end SIR particle filter tests on the paper's UNGM system (§7)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.pf import ParticleFilter, run_filter, ungm
from repro.pf.filter import run_filter_timed, simulate
from repro.pf.metrics import resample_ratio, rmse

T = 50
N_PARTICLES = 4096


@pytest.fixture(scope="module")
def trajectory():
    xs, zs = simulate(jax.random.PRNGKey(1), ungm(), T)
    return np.asarray(xs), np.asarray(zs)


@pytest.mark.parametrize("resampler", ["megopolis", "metropolis", "systematic", "multinomial"])
def test_filter_tracks_ungm(resampler, trajectory):
    xs, zs = trajectory
    pf = ParticleFilter(ungm(), N_PARTICLES, resampler=resampler, num_iters=30)
    ests = run_filter(jax.random.PRNGKey(2), pf, jnp.asarray(zs))
    assert ests.shape == (T,)
    assert np.isfinite(np.asarray(ests)).all()
    err = rmse(np.asarray(ests), xs)
    # The paper's Table 2 RMSE ~ 2.9-3.2 at 2^20 particles over 100 steps;
    # small-scale CPU runs land in the same band.
    assert err < 6.0, f"{resampler}: RMSE {err}"


def test_megopolis_rmse_close_to_unbiased(trajectory):
    """Paper Table 2: Megopolis B=32 RMSE within ~2% of systematic's."""
    xs, zs = trajectory
    runs_m, runs_s = [], []
    for k in range(4):
        key = jax.random.PRNGKey(10 + k)
        pf_m = ParticleFilter(ungm(), N_PARTICLES, resampler="megopolis", num_iters=32)
        pf_s = ParticleFilter(ungm(), N_PARTICLES, resampler="systematic")
        runs_m.append(np.asarray(run_filter(key, pf_m, jnp.asarray(zs))))
        runs_s.append(np.asarray(run_filter(key, pf_s, jnp.asarray(zs))))
    r_m = rmse(np.stack(runs_m), xs)
    r_s = rmse(np.stack(runs_s), xs)
    assert r_m < 1.25 * r_s, (r_m, r_s)


def test_resample_ratio_metric(trajectory):
    xs, zs = trajectory
    pf = ParticleFilter(ungm(), 2048, resampler="megopolis", num_iters=16)
    ests, times = run_filter_timed(jax.random.PRNGKey(3), pf, jnp.asarray(zs)[:10])
    ratio = resample_ratio(times)
    assert 0.0 < ratio < 1.0
    assert np.isfinite(np.asarray(ests)).all()


def test_filter_resampler_is_pluggable(trajectory):
    """Every registered resampler must run inside the jitted filter."""
    from repro.core import list_resamplers

    xs, zs = trajectory
    for name in list_resamplers():
        pf = ParticleFilter(ungm(), 1024, resampler=name, num_iters=8)
        ests = run_filter(jax.random.PRNGKey(4), pf, jnp.asarray(zs)[:5])
        assert np.isfinite(np.asarray(ests)).all(), name
