"""Deterministic, shard-aware, checkpointable synthetic LM token stream.

Every token is a pure function of ``(seed, step, global_row, position)``
through the murmur3 finalizer — the same stateless-counter design the
Megopolis TPU kernel uses for its uniforms (repro.kernels.common).  That
buys three production properties for free:

  * **shard-aware**: a host owning rows [lo, hi) materialises exactly its
    slice — no data redistribution collective, no shared filesystem;
  * **checkpointable**: the stream position IS the step integer in the
    checkpoint manifest — resume is trivially exact;
  * **elastic**: after re-meshing, new hosts compute their new row ranges
    from the same (seed, step) — repartitioning is a no-op.

Targets are next-token (inputs shifted by one within the same generated
row of length seq_len + 1).

Token distribution: a deterministic head-heavy mixture — with probability
3/4 a token from the 16-token "head", else uniform over the full vocab.
A uniform stream has NOTHING to learn (expected loss is pinned at
ln(vocab) and "loss decreased" integration checks reduce to coin flips);
the mixture gives next-token prediction a ~2-nat learnable gap between
the random-init loss (~ln V) and the unigram entropy, so short smoke
trains decrease monotonically-in-expectation while every counter-stream
property above is preserved (tokens are still a pure function of
``(seed, step, global_row, position)``).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.common import hash_bits

HEAD_TOKENS = 16  # support of the high-probability head
HEAD_WEIGHT = 12  # head probability = HEAD_WEIGHT / 16 (= 3/4)


def _mixture_tokens(bits, vocab_size: int):
    """Map hash bits to head-heavy tokens (jnp in, jnp out; np-compatible).

    Uses disjoint bit ranges for the branch choice (top 4 bits), the head
    token (bits 16..) and the tail token (low bits) so the three are
    independent streams of the same counter draw.
    """
    head = (bits >> np.uint32(16)) % np.uint32(min(HEAD_TOKENS, vocab_size))
    tail = bits % np.uint32(vocab_size)
    pick_head = (bits >> np.uint32(28)) < np.uint32(HEAD_WEIGHT)
    return jnp.where(pick_head, head, tail).astype(jnp.int32)


@dataclasses.dataclass(frozen=True)
class SyntheticLMStream:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0x5EED

    def batch(self, step: int, row_lo: int = 0, row_hi: int | None = None):
        """Rows [row_lo, row_hi) of the global batch at ``step`` (host numpy).

        Returns {"inputs": i32[rows, S], "targets": i32[rows, S]}.
        """
        row_hi = self.global_batch if row_hi is None else row_hi
        rows = np.arange(row_lo, row_hi, dtype=np.uint32)
        pos = np.arange(self.seq_len + 1, dtype=np.uint32)
        # lane index = global_row * (S+1) + position; iteration = step
        lane = rows[:, None] * np.uint32(self.seq_len + 1) + pos[None, :]
        bits = hash_bits(jnp.uint32(self.seed), jnp.asarray(lane), jnp.uint32(step))
        toks = np.asarray(_mixture_tokens(bits, self.vocab_size))
        return {"inputs": toks[:, :-1], "targets": toks[:, 1:]}

    def jax_batch(self, step, row_lo: int, row_hi: int):
        """Traceable variant (same values) for fully-jitted input pipelines."""
        rows = jnp.arange(row_lo, row_hi, dtype=jnp.uint32)
        pos = jnp.arange(self.seq_len + 1, dtype=jnp.uint32)
        lane = rows[:, None] * jnp.uint32(self.seq_len + 1) + pos[None, :]
        bits = hash_bits(jnp.uint32(self.seed), lane, jnp.asarray(step, jnp.uint32))
        toks = _mixture_tokens(bits, self.vocab_size)
        return {"inputs": toks[:, :-1], "targets": toks[:, 1:]}


def batch_specs(global_batch: int, seq_len: int, *, embeds_dim: int = 0,
                dtype=jnp.bfloat16):
    """ShapeDtypeStruct stand-ins for one training batch (dry-run inputs).

    ``embeds_dim > 0`` emits the modality-frontend stub (audio/vlm archs):
    precomputed frame/patch embeddings instead of int tokens.
    """
    if embeds_dim:
        inputs = jax.ShapeDtypeStruct((global_batch, seq_len, embeds_dim), dtype)
    else:
        inputs = jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32)
    return {
        "inputs": inputs,
        "targets": jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32),
    }
