"""Optimizer substrate: AdamW math, schedule, clipping, ZeRO specs,
compression with error feedback, microbatch-accumulation equivalence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.optim import (
    AdamWConfig,
    CompressionConfig,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    compress_and_correct,
    compress_init,
    cosine_schedule,
    global_norm,
    microbatch_grads,
    opt_state_pspecs,
)


def _params(key):
    k1, k2 = jax.random.split(key)
    return {"w": jax.random.normal(k1, (8, 16)), "b": jnp.zeros((16,))}


def test_adamw_decreases_quadratic_loss():
    cfg = AdamWConfig(peak_lr=0.1, warmup_steps=1, total_steps=200, weight_decay=0.0)
    params = _params(jax.random.PRNGKey(0))
    target = _params(jax.random.PRNGKey(1))
    state = adamw_init(params)

    def loss(p):
        return sum(jnp.sum((a - b) ** 2) for a, b in
                   zip(jax.tree.leaves(p), jax.tree.leaves(target)))

    l0 = float(loss(params))
    for _ in range(100):
        grads = jax.grad(loss)(params)
        params, state, _ = adamw_update(cfg, params, grads, state)
    assert float(loss(params)) < 0.2 * l0


def test_schedule_warmup_and_decay():
    cfg = AdamWConfig(peak_lr=1.0, end_lr=0.1, warmup_steps=10, total_steps=100)
    assert float(cosine_schedule(cfg, jnp.int32(0))) == 0.0
    assert abs(float(cosine_schedule(cfg, jnp.int32(10))) - 1.0) < 1e-6
    assert abs(float(cosine_schedule(cfg, jnp.int32(100))) - 0.1) < 1e-6
    mid = float(cosine_schedule(cfg, jnp.int32(55)))
    assert 0.1 < mid < 1.0


def test_clipping_bounds_norm():
    g = {"a": jnp.full((100,), 10.0)}
    clipped, pre = clip_by_global_norm(g, 1.0)
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
    assert float(pre) > 99.0


def test_moment_dtype_bf16():
    params = _params(jax.random.PRNGKey(0))
    state = adamw_init(params, jnp.bfloat16)
    assert state["mu"]["w"].dtype == jnp.bfloat16
    cfg = AdamWConfig(moment_dtype="bfloat16")
    grads = jax.tree.map(jnp.ones_like, params)
    p2, s2, _ = adamw_update(cfg, params, grads, state)
    assert s2["nu"]["w"].dtype == jnp.bfloat16
    assert p2["w"].dtype == params["w"].dtype


def test_zero1_specs_shard_first_free_divisible_dim():
    pspecs = {"w": P(None, "model"), "b": P()}
    shapes = {"w": jax.ShapeDtypeStruct((32, 64), jnp.float32),
              "b": jax.ShapeDtypeStruct((64,), jnp.float32)}
    out = opt_state_pspecs(pspecs, shapes, data_axis="data", data_size=16)
    assert out["mu"]["w"] == P("data", "model")
    assert out["mu"]["b"] == P("data")  # 1-D but divisible -> ZeRO-sharded
    # params already FSDP-sharded inherit unchanged
    pspecs2 = {"w": P("data", "model")}
    out2 = opt_state_pspecs(pspecs2, {"w": shapes["w"]}, data_axis="data", data_size=16)
    assert out2["nu"]["w"] == P("data", "model")


def test_compression_error_feedback_preserves_mass():
    """Across steps, sent + residual == accumulated gradient exactly (in
    f32): nothing is lost, only delayed — the error-feedback invariant."""
    cfg = CompressionConfig(ratio=0.1, min_size=8, wire_dtype="float32")
    g = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8) - 20.0}
    resid = compress_init(g)
    wire, resid2 = compress_and_correct(cfg, g, resid)
    np.testing.assert_allclose(
        np.asarray(wire["w"], np.float32) + np.asarray(resid2["w"]),
        np.asarray(g["w"]), rtol=1e-6)
    # top-k actually sparsifies
    nnz = int(jnp.sum(wire["w"] != 0))
    assert nnz <= 8  # 10% of 64 rounded up + ties


def test_compression_small_tensors_stay_dense():
    cfg = CompressionConfig(ratio=0.01, min_size=1000)
    g = {"b": jnp.ones((10,))}
    wire, resid = compress_and_correct(cfg, g, compress_init(g))
    assert int(jnp.sum(wire["b"] != 0)) == 10
    assert float(jnp.sum(jnp.abs(resid["b"]))) == 0.0


@pytest.mark.parametrize("micro", [1, 2, 4])
def test_microbatch_grads_equal_full_batch(micro):
    key = jax.random.PRNGKey(3)
    params = _params(key)
    batch = {"x": jax.random.normal(jax.random.fold_in(key, 1), (8, 8)),
             "y": jax.random.normal(jax.random.fold_in(key, 2), (8, 16))}

    def loss(p, b):
        return jnp.mean((b["x"] @ p["w"] + p["b"] - b["y"]) ** 2)

    l_full, g_full = jax.value_and_grad(loss)(params, batch)
    l_m, g_m = microbatch_grads(loss, params, batch, micro)
    np.testing.assert_allclose(float(l_m), float(l_full), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(g_m), jax.tree.leaves(g_full)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-6)
