"""Coalesced binary search over a resident CDF — Pallas TPU kernel.

Second stage of the prefix-sum resamplers (paper §6.5, Algs. 7-8): after
the block-scan kernel has produced the inclusive CDF, every output slot
``i`` finds its ancestor by bisecting the CDF for its draw ``u_i``.

Memory contract: the search positions are data-dependent, so the CDF stays
VMEM-resident (same residency cap as the Metropolis strawman — the
prefix-sum family's own scaling wall on this hardware); the ``u`` draws
stream through in aligned (8, 128) tiles, one grid step per tile, and the
output ancestors store coalesced.  Each of the ``ceil(log2(N+1))``
bisection steps is one in-register gather across the tile's 1024 lanes —
no HBM traffic after the single CDF fetch.

``side`` follows ``jnp.searchsorted``: 'left' returns the first index with
``c[idx] >= u`` (systematic/stratified), 'right' the first with
``c[idx] > u`` (multinomial/residual).  Results are clipped to N-1 so they
are always valid ancestor indices even for ``u >= c[-1]`` edge draws.

Validated bit-exactly against ``jnp.searchsorted`` in ``ref.py``.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import gather_state, tile_lane_ids

SUBLANES = 8
LANES = 128
SEG = SUBLANES * LANES


def _bisect_any(c_flat, u, side: str, n_total: int):
    """Shape-generic bisection core: ``u`` may be any 2-D tile (the search
    kernels pass (8, 128) blocks; the fused step kernel passes the whole
    (R, 128) array).  Each lane's trajectory depends only on its own
    ``u`` value and the shared CDF — same loop count either way — so a
    full-array call is bit-identical per lane to the per-tile calls."""
    n_steps = max(1, math.ceil(math.log2(n_total + 1)))
    lo = jnp.zeros(u.shape, jnp.int32)
    hi = jnp.full(u.shape, n_total, jnp.int32)

    def step(_, state):
        lo, hi = state
        active = lo < hi
        mid = (lo + hi) // 2
        cm = jnp.take(c_flat, mid.reshape(-1), axis=0).reshape(u.shape)
        pred = (cm < u) if side == "left" else (cm <= u)
        lo = jnp.where(active & pred, mid + 1, lo)
        hi = jnp.where(active & ~pred, mid, hi)
        return lo, hi

    lo, _ = jax.lax.fori_loop(0, n_steps, step, (lo, hi))
    return jnp.minimum(lo, n_total - 1)


def _bisect(c_flat, u, side: str, n_total: int):
    """The tile-parallel bisection every search kernel shares: int32[8, 128]
    first index with ``c[idx] >= u`` ('left') / ``c[idx] > u`` ('right'),
    clipped to N-1.  One in-register gather per step."""
    return _bisect_any(c_flat, u, side, n_total)


def _make_kernel(n_total: int, side: str):
    def _kernel(c_ref, u_ref, k_ref):
        c_flat = c_ref[...].reshape(n_total)
        k_ref[...] = _bisect(c_flat, u_ref[...], side, n_total)

    return _kernel


@functools.partial(jax.jit, static_argnames=("side", "interpret"))
def searchsorted_pallas(
    cdf2d: jnp.ndarray,
    u2d: jnp.ndarray,
    *,
    side: str = "left",
    interpret: bool = True,
) -> jnp.ndarray:
    """``cdf2d``: non-decreasing f32[R, 128] (flat row-major CDF);
    ``u2d``: f32[R, 128] of search values.  Returns int32[R, 128] indices
    (clipped to N-1)."""
    assert side in ("left", "right")
    rows, lanes = cdf2d.shape
    assert lanes == LANES and rows % SUBLANES == 0
    assert u2d.shape == (rows, lanes)
    num_tiles = rows // SUBLANES
    n_total = rows * lanes

    return pl.pallas_call(
        _make_kernel(n_total, side),
        grid=(num_tiles,),
        in_specs=[
            # whole CDF resident; fetched once (block index constant in t)
            pl.BlockSpec((rows, LANES), lambda t: (0, 0)),
            pl.BlockSpec((SUBLANES, LANES), lambda t: (t, 0)),
        ],
        out_specs=pl.BlockSpec((SUBLANES, LANES), lambda t: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, lanes), jnp.int32),
        interpret=interpret,
    )(cdf2d, u2d)


def _make_kernel_fused(n_total: int, side: str):
    def _kernel(c_ref, u_ref, planes_ref, k_ref, out_ref):
        c_flat = c_ref[...].reshape(n_total)
        k = _bisect(c_flat, u_ref[...], side, n_total)
        k_ref[...] = k
        out_ref[...] = gather_state(planes_ref[...], k)

    return _kernel


@functools.partial(jax.jit, static_argnames=("side", "interpret"))
def searchsorted_gather_pallas(
    cdf2d: jnp.ndarray,
    u2d: jnp.ndarray,
    planes: jnp.ndarray,
    *,
    side: str = "left",
    interpret: bool = True,
):
    """Fused search+gather (DESIGN.md §11): the bisection result indexes the
    resident state plane stack in the SAME grid step — the prefix-sum
    family's ancestor indices never leave VMEM.  Returns ``(int32[R, 128],
    [d_pad, R, 128])``; indices identical to ``searchsorted_pallas``."""
    assert side in ("left", "right")
    rows, lanes = cdf2d.shape
    assert lanes == LANES and rows % SUBLANES == 0
    assert u2d.shape == (rows, lanes)
    d_pad = planes.shape[0]
    assert planes.shape[1:] == (rows, lanes)
    num_tiles = rows // SUBLANES
    n_total = rows * lanes

    return pl.pallas_call(
        _make_kernel_fused(n_total, side),
        grid=(num_tiles,),
        in_specs=[
            pl.BlockSpec((rows, LANES), lambda t: (0, 0)),
            pl.BlockSpec((SUBLANES, LANES), lambda t: (t, 0)),
            pl.BlockSpec((d_pad, rows, LANES), lambda t: (0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((SUBLANES, LANES), lambda t: (t, 0)),
            pl.BlockSpec((d_pad, SUBLANES, LANES), lambda t: (0, t, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, lanes), jnp.int32),
            jax.ShapeDtypeStruct((d_pad, rows, lanes), planes.dtype),
        ],
        interpret=interpret,
    )(cdf2d, u2d, planes)


def _make_kernel_residual_fused(n_total: int):
    def _kernel(ndet_ref, cc_ref, c_ref, u_ref, planes_ref, k_ref, out_ref):
        t = pl.program_id(0)
        slots = tile_lane_ids(t)
        cc_flat = cc_ref[...].reshape(n_total)
        c_flat = c_ref[...].reshape(n_total)
        # Both searches of the residual composition run in ONE grid step:
        # deterministic copies bisect the counts CDF at the slot index,
        # stochastic slots bisect the residual CDF at their draw.
        det = _bisect(cc_flat, slots.astype(c_flat.dtype), "right", n_total)
        rnd = _bisect(c_flat, u_ref[...], "right", n_total)
        k = jnp.where(slots < ndet_ref[0], det, rnd)
        k_ref[...] = k
        out_ref[...] = gather_state(planes_ref[...], k)

    return _kernel


@functools.partial(jax.jit, static_argnames=("interpret",))
def residual_select_gather_pallas(
    cc2d: jnp.ndarray,
    c2d: jnp.ndarray,
    u2d: jnp.ndarray,
    n_det: jnp.ndarray,
    planes: jnp.ndarray,
    *,
    interpret: bool = True,
):
    """Fused residual tail (DESIGN.md §11): deterministic-copy search,
    residual search, slot select and state gather in one kernel.  ``cc2d``:
    the deterministic-count CDF; ``c2d``: the residual CDF; ``u2d``: the
    residual draws (already scaled by the CDF total); ``n_det``: int32[1]
    deterministic slot count (scalar-prefetched).  Index arithmetic is
    bit-identical to the two-``searchsorted_pallas`` + ``jnp.where``
    composition in ``ops._residual_tpu``."""
    rows, lanes = cc2d.shape
    assert lanes == LANES and rows % SUBLANES == 0
    assert c2d.shape == (rows, lanes) and u2d.shape == (rows, lanes)
    d_pad = planes.shape[0]
    assert planes.shape[1:] == (rows, lanes)
    num_tiles = rows // SUBLANES
    n_total = rows * lanes

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(num_tiles,),
        in_specs=[
            pl.BlockSpec((rows, LANES), lambda t, nd: (0, 0)),
            pl.BlockSpec((rows, LANES), lambda t, nd: (0, 0)),
            pl.BlockSpec((SUBLANES, LANES), lambda t, nd: (t, 0)),
            pl.BlockSpec((d_pad, rows, LANES), lambda t, nd: (0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((SUBLANES, LANES), lambda t, nd: (t, 0)),
            pl.BlockSpec((d_pad, SUBLANES, LANES), lambda t, nd: (0, t, 0)),
        ],
    )
    return pl.pallas_call(
        _make_kernel_residual_fused(n_total),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((rows, lanes), jnp.int32),
            jax.ShapeDtypeStruct((d_pad, rows, lanes), planes.dtype),
        ],
        interpret=interpret,
    )(n_det, cc2d, c2d, u2d, planes)
