"""Resampling algorithms (the paper's Algorithms 2-5, 7, 8 + extras).

Every resampler shares one signature::

    ancestors = resampler(key, weights, **kwargs)   # int32[N]

``ancestors[i]`` is the index of the particle replacing particle ``i``
(the paper's ancestor formulation).  Offspring counts are
``jnp.bincount(ancestors, length=N)``.  Weights need NOT be normalised for
the Metropolis family (only ratios are used) nor for the prefix-sum family
(the running total is used as the upper edge).

Every resampler also has a batched entry point (DESIGN.md §4)::

    ancestors = get_resampler_batch(name)(key, weights, **kwargs)  # int32[B, N]

over ``weights[B, N]`` — row ``b`` is bit-identical to the single-population
call with key ``jax.random.split(key, B)[b]`` (see ``batched.py``).
"""

from repro.core.resamplers.batched import (
    batch_rows,
    batch_via_vmap,
    split_batch_keys,
)
from repro.core.resamplers.megopolis import megopolis, megopolis_batch
from repro.core.resamplers.metropolis import (
    metropolis,
    metropolis_batch,
    metropolis_c1,
    metropolis_c1_batch,
    metropolis_c2,
    metropolis_c2_batch,
)
from repro.core.resamplers.prefix_sum import (
    multinomial,
    multinomial_batch,
    systematic,
    systematic_batch,
    improved_systematic,
    improved_systematic_batch,
    stratified,
    stratified_batch,
    residual,
    residual_batch,
)
from repro.core.resamplers.rejection import rejection, rejection_batch

_REGISTRY = {
    "megopolis": megopolis,
    "metropolis": metropolis,
    "metropolis_c1": metropolis_c1,
    "metropolis_c2": metropolis_c2,
    "multinomial": multinomial,
    "systematic": systematic,
    "improved_systematic": improved_systematic,
    "stratified": stratified,
    "residual": residual,
    "rejection": rejection,
}

# Batch axis first-class: one batched launch per registered resampler, all
# honouring the split-key bit-identity contract (megopolis_batch's hand-
# batched shared-offset mode is an explicit opt-in kwarg, not the registry
# default — the registry path is vmap-derived for every family).
_BATCH_REGISTRY = {
    "megopolis": megopolis_batch,
    "metropolis": metropolis_batch,
    "metropolis_c1": metropolis_c1_batch,
    "metropolis_c2": metropolis_c2_batch,
    "multinomial": multinomial_batch,
    "systematic": systematic_batch,
    "improved_systematic": improved_systematic_batch,
    "stratified": stratified_batch,
    "residual": residual_batch,
    "rejection": rejection_batch,
}

assert set(_BATCH_REGISTRY) == set(_REGISTRY)


def get_resampler(name: str):
    """Look up a resampler by name; raises KeyError with choices on miss."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown resampler {name!r}; choices: {sorted(_REGISTRY)}") from None


def get_resampler_batch(name: str):
    """Batched counterpart of ``get_resampler`` (weights[B, N] -> int32[B, N])."""
    try:
        return _BATCH_REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown resampler {name!r}; choices: {sorted(_BATCH_REGISTRY)}") from None


def list_resamplers():
    return sorted(_REGISTRY)
