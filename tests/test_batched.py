"""The batched engine's contract (DESIGN.md §4), enforced end to end:

  1. registry: row ``b`` of every ``resample_batch`` is bit-identical to
     the single-population call with the matching split key;
  2. hand-batched Megopolis: the shared-offset mode equals singles with
     the shared table injected;
  3. kernel: the batched Pallas launch equals the vmapped ``ref.py``
     oracle (interpret mode) AND per-row single-bank launches;
  4. filter bank: each bank row reproduces ``run_filter`` exactly.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    get_resampler,
    get_resampler_batch,
    list_resamplers,
    megopolis,
    megopolis_batch,
)
from repro.core.resamplers.batched import split_batch_keys
from repro.kernels.common import TILE, key_to_seed
from repro.kernels.megopolis.megopolis import megopolis_pallas, megopolis_pallas_batch
from repro.kernels.megopolis.ops import megopolis_tpu_batch
from repro.kernels.megopolis.ref import megopolis_ref
from repro.pf import ParticleFilter, run_filter, run_filter_bank, ungm, ungm_family, ungm_theta
from repro.pf.filter import simulate

ALL = list_resamplers()
BATCH = 3
N = 512
ITERS = 12


def _bank(key, batch=BATCH, n=N):
    return jax.random.uniform(key, (batch, n)) + 1e-3


# ------------------------------------------------------------- registry
@pytest.mark.parametrize("name", ALL)
def test_batch_rows_bit_identical_to_singles(name, base_key):
    w = _bank(jax.random.fold_in(base_key, 11))
    key = jax.random.fold_in(base_key, 12)
    got = get_resampler_batch(name)(key, w, ITERS)
    assert got.shape == (BATCH, N) and got.dtype == jnp.int32
    keys = split_batch_keys(key, BATCH)
    single = get_resampler(name)
    for b in range(BATCH):
        np.testing.assert_array_equal(
            np.asarray(got[b]), np.asarray(single(keys[b], w[b], ITERS)),
            err_msg=f"{name} row {b}",
        )


@pytest.mark.parametrize("name", ALL)
def test_batch_is_jittable_and_valid(name, base_key):
    w = _bank(jax.random.fold_in(base_key, 13))
    fn = jax.jit(get_resampler_batch(name), static_argnums=2)
    a = fn(jax.random.fold_in(base_key, 14), w, 8)
    assert bool(jnp.all((a >= 0) & (a < N)))


def test_batch_rejects_single_population_shape(base_key):
    w = jnp.ones((N,))
    with pytest.raises(ValueError, match=r"\[B, N\]"):
        get_resampler_batch("systematic")(base_key, w, 0)


# ------------------------------------------- hand-batched megopolis mode
def test_megopolis_shared_offsets_rows_equal_singles(base_key):
    w = _bank(jax.random.fold_in(base_key, 15))
    key = jax.random.fold_in(base_key, 16)
    got = megopolis_batch(key, w, ITERS, shared_offsets=True)
    # the bank-shared table megopolis_batch draws internally:
    offsets = jax.random.randint(jax.random.fold_in(key, ITERS), (ITERS,), 0, N)
    keys = split_batch_keys(key, BATCH)
    for b in range(BATCH):
        want = megopolis(keys[b], w[b], ITERS, offsets=offsets)
        np.testing.assert_array_equal(np.asarray(got[b]), np.asarray(want))


def test_megopolis_shared_offsets_still_resamples_degenerate(base_key):
    from repro.core import select_iterations

    w = jnp.full((BATCH, N), 1e-7).at[:, 137].set(1.0)
    num_iters = int(select_iterations(w[0], 0.01))  # eq. 3's B for this bank
    a = megopolis_batch(jax.random.fold_in(base_key, 17), w, num_iters, shared_offsets=True)
    assert float(jnp.mean(a == 137)) > 0.95


# ------------------------------------------------------- batched kernel
@pytest.mark.parametrize("n_tiles", [1, 2])
@pytest.mark.parametrize("num_iters", [1, 7])
def test_megopolis_kernel_batch_matches_vmapped_ref(n_tiles, num_iters, base_key):
    n = n_tiles * TILE
    bsz = 3
    w = jax.random.uniform(jax.random.fold_in(base_key, 21), (bsz, n)) + 1e-3
    offsets = jax.random.randint(jax.random.fold_in(base_key, 22), (num_iters,), 0, n, jnp.int32)
    seeds = key_to_seed(jax.random.split(jax.random.fold_in(base_key, 23), bsz))
    got = megopolis_pallas_batch(
        w.reshape(bsz, -1, 128), offsets, seeds, num_iters=num_iters, interpret=True
    ).reshape(bsz, n)
    want = jax.vmap(
        lambda wr, s: megopolis_ref(wr, offsets, s.reshape(1), num_iters=num_iters)
    )(w, seeds)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_megopolis_kernel_batch_rows_match_single_bank_kernel(base_key):
    n, bsz, num_iters = 2 * TILE, 2, 9
    w = jax.random.uniform(jax.random.fold_in(base_key, 24), (bsz, n)) + 1e-3
    offsets = jax.random.randint(jax.random.fold_in(base_key, 25), (num_iters,), 0, n, jnp.int32)
    seeds = key_to_seed(jax.random.split(jax.random.fold_in(base_key, 26), bsz))
    got = megopolis_pallas_batch(
        w.reshape(bsz, -1, 128), offsets, seeds, num_iters=num_iters, interpret=True
    )
    for s in range(bsz):
        single = megopolis_pallas(
            w[s].reshape(-1, 128), offsets, seeds[s].reshape(1),
            num_iters=num_iters, interpret=True,
        )
        np.testing.assert_array_equal(np.asarray(got[s]), np.asarray(single))


def test_megopolis_tpu_batch_public_api(base_key):
    n, bsz = 2 * TILE, 3
    w = jax.random.uniform(jax.random.fold_in(base_key, 27), (bsz, n)) + 1e-3
    a = megopolis_tpu_batch(jax.random.fold_in(base_key, 28), w, 16)
    assert a.shape == (bsz, n) and a.dtype == jnp.int32
    assert bool(jnp.all((a >= 0) & (a < n)))
    with pytest.raises(ValueError, match="VMEM tile"):
        megopolis_tpu_batch(base_key, w[:, : n - 3], 16)
    with pytest.raises(ValueError, match=r"\[B, N\]"):
        megopolis_tpu_batch(base_key, w[0], 16)


# ---------------------------------------------------------- filter bank
@pytest.mark.parametrize("resampler", ["megopolis", "systematic"])
def test_filter_bank_rows_match_single_filters(resampler, base_key):
    num_s, steps, particles = 3, 6, 256
    model = ungm_family()
    scenarios = [ungm_theta(amp=6.0 + 2.0 * s, obs_var=0.5 + 0.5 * s) for s in range(num_s)]
    thetas = jax.tree.map(lambda *xs: jnp.stack(xs), *scenarios)
    obs = jnp.stack([
        simulate(jax.random.fold_in(base_key, 30 + s), model, steps, theta=th)[1]
        for s, th in enumerate(scenarios)
    ])
    pf = ParticleFilter(model, particles, resampler=resampler, num_iters=8)
    key = jax.random.fold_in(base_key, 40)
    bank = run_filter_bank(key, pf, obs, thetas=thetas)
    assert bank.shape == (num_s, steps)
    keys = split_batch_keys(key, num_s)
    for s in range(num_s):
        single = run_filter(keys[s], pf, obs[s], theta=scenarios[s])
        np.testing.assert_array_equal(
            np.asarray(bank[s]), np.asarray(single), err_msg=f"scenario {s}"
        )


def test_filter_bank_theta_less_model(base_key):
    """Plain (key, x, t) models join a bank unchanged — theta is optional."""
    steps, num_s = 5, 2
    _, zs = simulate(jax.random.fold_in(base_key, 50), ungm(), steps)
    obs = jnp.stack([zs] * num_s)
    pf = ParticleFilter(ungm(), 256, resampler="megopolis", num_iters=8)
    key = jax.random.fold_in(base_key, 51)
    bank = run_filter_bank(key, pf, obs)
    keys = split_batch_keys(key, num_s)
    for s in range(num_s):
        np.testing.assert_array_equal(
            np.asarray(bank[s]), np.asarray(run_filter(keys[s], pf, obs[s]))
        )
    # identical observations but distinct split keys -> rows must differ
    assert not np.array_equal(np.asarray(bank[0]), np.asarray(bank[1]))
