"""Elastic re-meshing: restore a checkpoint onto a DIFFERENT topology.

Resharding is a pure function of (checkpoint, new mesh): the manifest
stores logical shapes only; ``reshard_state`` re-derives PartitionSpecs for
the new mesh from the same config and ``jax.device_put``s each restored
host array.  Combined with the hash-based data stream (whose shard slices
are position-independent, data/synthetic.py) an elastic restart needs no
coordination beyond agreeing on the new mesh.

    state, step = elastic_restore(ckpt_dir, cfg, new_mesh)
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import NamedSharding

from repro.checkpoint import restore_checkpoint
from repro.launch.sharding import fsdp_axes, model_pspecs
from repro.models import ModelConfig, init_params
from repro.optim import opt_state_pspecs


def state_pspecs(cfg: ModelConfig, mesh, *, fsdp: bool = True, zero1: bool = True,
                 moment_dtype=np.float32):
    pspecs = model_pspecs(cfg, mesh, fsdp=fsdp)
    shapes = jax.eval_shape(lambda k: init_params(k, cfg), jax.random.PRNGKey(0))
    axes, size = fsdp_axes(mesh) if "data" in mesh.axis_names else (None, 1)
    opt = opt_state_pspecs(pspecs, shapes, data_axis=axes or "data",
                           data_size=size, zero1=zero1)
    return {"params": pspecs, "opt": opt}


def reshard_state(host_state, cfg: ModelConfig, mesh, **kw):
    """Place restored host arrays onto ``mesh`` with freshly derived specs."""
    specs = state_pspecs(cfg, mesh, **kw)

    def put(x, spec):
        return jax.device_put(x, NamedSharding(mesh, spec))

    return jax.tree.map(put, host_state, specs,
                        is_leaf=lambda x: isinstance(x, (np.ndarray, jax.Array)))


def elastic_restore(ckpt_dir: str, cfg: ModelConfig, mesh, *, template, **kw):
    """Restore the latest checkpoint and reshard it for ``mesh``.
    Returns (sharded_state, next_step)."""
    host, manifest = restore_checkpoint(ckpt_dir, template=template)
    state = reshard_state(host, cfg, mesh, **kw)
    return state, int(manifest["extra"].get("next_step", manifest["step"]))
