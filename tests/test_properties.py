"""Property-based tests on the system's invariants.

The paper's correctness rests on structural properties of the Megopolis
index map; the framework substrate rests on determinism/conservation
invariants.  Each is asserted over generated inputs when hypothesis is
installed; without it every test still RUNS over a pinned representative
grid (edge + bulk examples) instead of skipping — this module was the
suite's one perpetual skip on hypothesis-less images (see CHANGES.md).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core.resamplers.megopolis import megopolis, megopolis_indices
from repro.core.iterations import select_iterations
from repro.core.metrics import offspring_counts
from repro.data import SyntheticLMStream
from repro.kernels.common import flat_roll, hash_uniform
from repro.optim import CompressionConfig, compress_and_correct, compress_init

SETTINGS = dict(max_examples=25, deadline=None)


def property_test(strategy_fn, pinned):
    """hypothesis ``@given`` when available; otherwise parametrize over the
    ``pinned`` example dicts (edges + bulk) so the invariant is exercised
    either way.  ``strategy_fn`` receives the strategies module lazily so
    this file imports cleanly without hypothesis."""

    def deco(fn):
        if HAVE_HYPOTHESIS:
            return settings(**SETTINGS)(given(**strategy_fn(strategies))(fn))
        names = list(pinned[0])
        rows = [tuple(p[k] for k in names) for p in pinned]
        return pytest.mark.parametrize(",".join(names), rows)(fn)

    return deco


# ---------------------------------------------------- Megopolis index map
@property_test(
    lambda st: dict(
        n_seg=st.integers(1, 64),
        segment=st.sampled_from([1, 4, 32, 128]),
        offset=st.integers(0, 2**31 - 1),
    ),
    pinned=[
        dict(n_seg=1, segment=1, offset=0),
        dict(n_seg=1, segment=128, offset=2**31 - 1),
        dict(n_seg=64, segment=128, offset=977),
        dict(n_seg=7, segment=32, offset=12345),
        dict(n_seg=33, segment=4, offset=2**30 + 1),
    ],
)
def test_megopolis_map_is_bijection(n_seg, segment, offset):
    """For any segment size dividing N and any offset, i -> j is a
    bijection (Proposition 1's requirement (a))."""
    n = n_seg * segment
    i = jnp.arange(n)
    j = np.asarray(megopolis_indices(i, offset % n, segment, n))
    assert sorted(j.tolist()) == list(range(n))


@property_test(
    lambda st: dict(segment=st.sampled_from([4, 32]), n_seg=st.integers(2, 16)),
    pinned=[
        dict(segment=4, n_seg=2),
        dict(segment=4, n_seg=16),
        dict(segment=32, n_seg=3),
    ],
)
def test_megopolis_map_uniform_over_offsets(segment, n_seg):
    """For fixed i, j is uniform over [0, N) across all offsets
    (requirement (b)): every j is hit exactly once as o sweeps [0, N)."""
    n = n_seg * segment
    i = jnp.full((n,), 3, jnp.int32)
    hits = np.zeros(n, np.int64)
    for o in range(n):
        j = int(np.asarray(megopolis_indices(jnp.asarray([3]), o, segment, n))[0])
        hits[j] += 1
    assert hits.min() == hits.max() == 1


@property_test(
    lambda st: dict(
        n=st.sampled_from([64, 256]),
        b=st.integers(1, 24),
        seed=st.integers(0, 2**30),
    ),
    pinned=[
        dict(n=64, b=1, seed=0),
        dict(n=64, b=24, seed=2**30),
        dict(n=256, b=8, seed=31),
        dict(n=256, b=24, seed=7),
    ],
)
def test_resampler_outputs_valid_ancestors(n, b, seed):
    """Ancestors are in range and offspring counts conserve N for any
    weights (conservation invariant of every resampler)."""
    key = jax.random.PRNGKey(seed)
    w = jax.random.uniform(jax.random.fold_in(key, 1), (n,)) + 1e-6
    anc = megopolis(key, w, b)
    a = np.asarray(anc)
    assert a.min() >= 0 and a.max() < n
    assert int(offspring_counts(anc, n).sum()) == n


@property_test(
    lambda st: dict(seed=st.integers(0, 2**30), n=st.sampled_from([128, 1024])),
    pinned=[
        dict(seed=0, n=128),
        dict(seed=12, n=1024),
        dict(seed=2**30, n=128),
    ],
)
def test_zero_weight_particles_never_survive_with_positive_alternatives(seed, n):
    """A particle with zero weight must never be selected as an ancestor
    once B >= 1 comparison hits a positive-weight particle; with large B
    the zero-weight index disappears entirely (u*w[k] <= w[j] with
    w[k]=0 always accepts)."""
    key = jax.random.PRNGKey(seed)
    w = jnp.ones((n,)).at[0].set(0.0)
    anc = megopolis(key, w, 64)
    assert 0 not in np.asarray(anc).tolist()


# ----------------------------------------------------------- kernel utils
@property_test(
    lambda st: dict(
        rows=st.sampled_from([8, 16]),
        shift=st.integers(0, 10_000),
        seed=st.integers(0, 2**30),
    ),
    pinned=[
        dict(rows=8, shift=0, seed=0),
        dict(rows=8, shift=10_000, seed=5),
        dict(rows=16, shift=1023, seed=2**30),
        dict(rows=16, shift=2048, seed=77),
    ],
)
def test_flat_roll_matches_numpy_roll(rows, shift, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (rows, 128))
    got = np.asarray(flat_roll(x, shift)).reshape(-1)
    want = np.roll(np.asarray(x).reshape(-1), -(shift % (rows * 128)))
    np.testing.assert_array_equal(got, want)


@property_test(
    lambda st: dict(seed=st.integers(0, 2**31 - 1)),
    pinned=[dict(seed=0), dict(seed=1), dict(seed=2**31 - 1), dict(seed=987654321)],
)
def test_hash_uniform_range_and_determinism(seed):
    lanes = jnp.arange(4096)
    u1 = np.asarray(hash_uniform(seed, lanes, 3))
    u2 = np.asarray(hash_uniform(seed, lanes, 3))
    np.testing.assert_array_equal(u1, u2)
    assert u1.min() >= 0.0 and u1.max() < 1.0
    assert abs(u1.mean() - 0.5) < 0.05  # crude uniformity


# ------------------------------------------------------------- iterations
@property_test(
    lambda st: dict(eps=st.floats(1e-4, 0.5), scale=st.floats(0.1, 100.0)),
    pinned=[
        dict(eps=1e-4, scale=0.1),
        dict(eps=0.5, scale=100.0),
        dict(eps=0.01, scale=1.0),
        dict(eps=0.25, scale=3.7),
    ],
)
def test_iteration_count_scale_invariant(eps, scale):
    """B (eq. 3) depends only on weight RATIOS — rescaling all weights
    must not change it (the paper's unnormalised-weights property)."""
    w = jnp.asarray([0.1, 0.5, 1.0, 2.0, 4.0] * 10)
    b1 = int(select_iterations(w, eps))
    b2 = int(select_iterations(w * scale, eps))
    assert b1 == b2
    assert b1 >= 1


# ------------------------------------------------------------------- data
@property_test(
    lambda st: dict(
        step=st.integers(0, 1000), lo=st.integers(0, 6), width=st.integers(1, 2)
    ),
    pinned=[
        dict(step=0, lo=0, width=1),
        dict(step=1000, lo=6, width=2),
        dict(step=17, lo=3, width=2),
    ],
)
def test_stream_shard_slices_agree(step, lo, width):
    s = SyntheticLMStream(vocab_size=31, seq_len=8, global_batch=8, seed=5)
    full = s.batch(step)
    part = s.batch(step, row_lo=lo, row_hi=lo + width)
    np.testing.assert_array_equal(full["inputs"][lo:lo + width], part["inputs"])


# ------------------------------------------------------------ compression
@property_test(
    lambda st: dict(seed=st.integers(0, 2**30), ratio=st.floats(0.01, 0.9)),
    pinned=[
        dict(seed=0, ratio=0.01),
        dict(seed=2**30, ratio=0.9),
        dict(seed=1234, ratio=0.5),
    ],
)
def test_error_feedback_conserves_gradient_mass(seed, ratio):
    cfg = CompressionConfig(ratio=ratio, min_size=4, wire_dtype="float32")
    g = {"w": jax.random.normal(jax.random.PRNGKey(seed), (16, 16))}
    resid = compress_init(g)
    wire, resid = compress_and_correct(cfg, g, resid)
    np.testing.assert_allclose(np.asarray(wire["w"]) + np.asarray(resid["w"]),
                               np.asarray(g["w"]), rtol=1e-5, atol=1e-6)
