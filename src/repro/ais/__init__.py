# AIS — the paper's adaptive-importance-sampling workload (DESIGN.md §10):
# annealed SMC over jittable tempered targets with analytic logZ ground
# truth, resampling through ANY ResamplerSpec on any backend.

from repro.ais.moves import (  # noqa: F401
    MOVES,
    TARGET_ACCEPT,
    adapt_step_size,
    mala,
    random_walk_metropolis,
)
from repro.ais.sampler import (  # noqa: F401
    SMCSamplerConfig,
    run_smc_sampler,
    run_smc_sampler_bank,
)
from repro.ais.schedule import (  # noqa: F401
    conditional_ess,
    geometric_schedule,
    next_temperature,
)
from repro.ais.targets import (  # noqa: F401
    Target,
    banana,
    correlated_gaussian,
    gaussian_family,
    gaussian_mixture,
    gaussian_theta,
    isotropic_gaussian,
    logistic_regression,
)
