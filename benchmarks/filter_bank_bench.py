"""Filter-bank throughput: batched scenario axis vs the naive Python loop.

The serving case (ROADMAP north star; EXPERIMENTS.md §Perf): many concurrent
particle filters — one per scenario / user / hypothesis bank.  The naive
implementation loops ``run_filter`` S times (S jitted launches per pipeline
stage, S dispatch round-trips per step); ``run_filter_bank`` runs the whole
bank under one ``lax.scan`` whose resampling stage is a single batched
launch (DESIGN.md §4).  Reported metric is per-filter throughput
(particle-steps/s/filter) — a flat bank curve means scenarios are ~free
until the device saturates, while the loop's per-launch overhead eats it.

    PYTHONPATH=src python -m benchmarks.filter_bank_bench [--quick]

Writes ``filter_bank.csv`` + ``BENCH_filter_bank.json`` into ``BENCH_OUT``
(default benchmarks/out/) — accrete the JSON into EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp

from benchmarks.common import ensure_out, print_table, time_fn, write_csv
from repro.core import coerce_spec
from repro.pf.filter import ParticleFilter, run_filter, run_filter_bank, simulate
from repro.pf.models import ungm_family, ungm_theta


def bench_one(resampler: str, num_scenarios: int, particles: int, steps: int,
              num_iters: int) -> dict:
    model = ungm_family()
    scenarios = [
        ungm_theta(amp=4.0 + s % 8, obs_var=0.5 + 0.25 * (s % 4))
        for s in range(num_scenarios)
    ]
    thetas = jax.tree.map(lambda *xs: jnp.stack(xs), *scenarios)
    obs = jnp.stack([
        simulate(jax.random.PRNGKey(100 + s), model, steps, theta=th)[1]
        for s, th in enumerate(scenarios)
    ])
    # One spec per swept resampler; coerce_spec drops the iteration count for
    # the prefix-sum entries (DESIGN.md §9).
    pf = ParticleFilter(model, particles,
                        resampler=coerce_spec(resampler, num_iters=num_iters))
    key = jax.random.PRNGKey(0)
    keys = jax.random.split(key, num_scenarios)

    bank = jax.jit(lambda k: run_filter_bank(k, pf, obs, thetas=thetas))
    t_bank = time_fn(bank, key)

    single = jax.jit(lambda k, z, th: run_filter(k, pf, z, theta=th))

    def loop(_):
        outs = [single(keys[s], obs[s], scenarios[s]) for s in range(num_scenarios)]
        return jnp.stack(outs)

    t_loop = time_fn(loop, key)

    particle_steps = num_scenarios * steps * particles
    return {
        "resampler": resampler,
        "scenarios": num_scenarios,
        "particles": particles,
        "steps": steps,
        "bank_s": t_bank,
        "loop_s": t_loop,
        "speedup": t_loop / t_bank,
        "bank_psteps_per_s_per_filter": particle_steps / t_bank / num_scenarios,
        "loop_psteps_per_s_per_filter": particle_steps / t_loop / num_scenarios,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="small sweep for CI smoke")
    ap.add_argument("--particles", type=int, default=0, help="override particle count")
    ap.add_argument("--steps", type=int, default=0)
    ap.add_argument("--iters", type=int, default=16)
    args = ap.parse_args(argv)

    if args.quick:
        particles, steps, sweep = 1024, 10, (1, 4, 8)
        resamplers = ("megopolis", "systematic")
    else:
        particles, steps, sweep = 8192, 25, (1, 4, 16, 64)
        resamplers = ("megopolis", "metropolis", "systematic")
    particles = args.particles or particles
    steps = args.steps or steps

    rows = []
    for resampler in resamplers:
        for num_s in sweep:
            rows.append(bench_one(resampler, num_s, particles, steps, args.iters))
            print_table(rows[-1:])

    csv_path = write_csv("filter_bank.csv", rows)
    json_path = os.path.join(ensure_out(), "BENCH_filter_bank.json")
    with open(json_path, "w") as f:
        json.dump({"config": {"particles": particles, "steps": steps,
                              "num_iters": args.iters},
                   "rows": rows}, f, indent=2)
    print(f"\nwrote {csv_path} and {json_path}")
    best = max(rows, key=lambda r: r["speedup"])
    print(f"best bank speedup: {best['speedup']:.2f}x "
          f"({best['resampler']}, S={best['scenarios']})")


if __name__ == "__main__":
    main()
