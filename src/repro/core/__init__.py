# Core — the paper's primary contribution: memory-coalesced resampling.
#
# ``resamplers`` hosts every algorithm from the paper (Megopolis, Metropolis,
# C1, C2) plus the prefix-sum baselines it compares against (multinomial,
# improved systematic) and the classical extras (stratified, residual,
# rejection).  ``distributed`` lifts Megopolis' coalescing contract to the
# chip level with shard_map + ppermute.  ``transactions`` is the paper's
# memory-transaction cost model (Figs. 1-4) evaluated analytically.

from repro.core.resamplers import (  # noqa: F401
    MegopolisSpec,
    MetropolisC1Spec,
    MetropolisC2Spec,
    MetropolisSpec,
    PrefixSumSpec,
    RejectionSpec,
    Resampler,
    ResamplerSpec,
    coerce_spec,
    get_resampler,
    get_resampler_batch,
    list_resamplers,
    spec_for_backend,
    spec_from_name,
    megopolis,
    megopolis_batch,
    metropolis,
    metropolis_c1,
    metropolis_c2,
    multinomial,
    systematic,
    improved_systematic,
    stratified,
    residual,
    rejection,
)
from repro.core.iterations import select_iterations  # noqa: F401
