"""SMC / particle LM decoding — the paper's resampler as a serving feature.

This is the §Arch-applicability integration point (DESIGN.md §5): particles
are concurrent decode hypotheses on the batch axis; weights come from the
proposal/target likelihood ratio (or a user twist function); resampling
prunes/duplicates hypotheses.  Resampling itself is ANY registered
algorithm from the paper — Megopolis by default — running over the
particle axis, followed by an ancestor gather of every KV/SSM cache leaf.

The paper's algorithmic properties carry over directly:
  * weights need NOT be normalised (Metropolis-family uses only ratios) —
    we keep log-weights and shift-by-max for the ratio computation;
  * resampling is ESS-triggered (the SMC standard) — the Resample-Ratio
    economics of paper §7 apply per decode step;
  * the ancestor-gather cost model differs by family: O(layers*seq*kv) for
    attention caches vs O(layers*d_inner*state) for SSM archs — zamba2 and
    mamba2 resample orders of magnitude cheaper at long context (measured
    in benchmarks/smc_decode_bench.py).

Fully jittable: ``lax.scan`` over steps; the per-step reweight → ESS →
conditional resample is ONE fused ``Resampler.step`` call (DESIGN.md §12).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional, Union

import jax
import jax.numpy as jnp

from repro.core.metrics import effective_sample_size
from repro.core.spec import ResamplerSpec, coerce_spec
from repro.models import ModelConfig, decode_step
from repro.obs.telemetry import Telemetry


@dataclasses.dataclass(frozen=True)
class SMCDecodeConfig:
    """``resampler`` accepts a registry name or a typed ``ResamplerSpec``
    (DESIGN.md §9).  With a spec, ``num_iters`` / ``segment`` below are not
    consulted — the spec carries its own hyperparameters and backend."""

    num_particles: int
    max_new_tokens: int
    resampler: Union[str, ResamplerSpec] = "megopolis"
    num_iters: int = 16  # B (paper eq. 3; fixed application prior, §7)
    ess_threshold: float = 0.5  # resample when ESS < threshold * N
    proposal_temp: float = 1.0
    target_temp: float = 0.7  # weights tilt samples toward the sharper target
    segment: int = 32  # Megopolis coalescing segment

    def resampler_spec(self) -> ResamplerSpec:
        if isinstance(self.resampler, ResamplerSpec):
            return self.resampler
        return coerce_spec(self.resampler, num_iters=self.num_iters, segment=self.segment)


# Kept as the module's public name; the implementation is the shared
# repro.core.metrics helper (used identically by pf/filter.py and ais/).
ess = effective_sample_size


def _default_twist(logits: jnp.ndarray, token: jnp.ndarray, cfg: SMCDecodeConfig):
    """log-weight increment = log target(token) - log proposal(token).

    Proposal samples at ``proposal_temp``; the target density is the model
    at ``target_temp`` — classic tempered-SMC decoding."""
    logp = jax.nn.log_softmax(logits / cfg.proposal_temp, axis=-1)
    logt = jax.nn.log_softmax(logits / cfg.target_temp, axis=-1)
    tok = token[:, None]
    lp = jnp.take_along_axis(logp, tok, axis=-1)[:, 0]
    lt = jnp.take_along_axis(logt, tok, axis=-1)[:, 0]
    return lt - lp


def smc_decode(
    params,
    model_cfg: ModelConfig,
    smc_cfg: SMCDecodeConfig,
    caches,
    first_tokens: jnp.ndarray,  # (N,) int32 — last prompt token per particle
    start_pos,  # scalar int32 — position of first_tokens
    key,
    twist: Optional[Callable] = None,
    telemetry: bool = False,
):
    """Returns (tokens (N, T), log_weights (N,), stats dict).

    ``caches`` must be prefilled for ``start_pos`` (see models.prefill);
    particle i's hypothesis extends ``first_tokens[i]``.

    ``telemetry=True`` (DESIGN.md §15) returns
    ``(tokens, log_weights, stats, Telemetry)`` with ``Telemetry.steps``
    carrying one ``StepStats`` per generated token (fields ``[T]``) — all
    values the decode scan already computes, so the flag adds zero
    launches and leaves the first three outputs bit-identical.
    """
    n = smc_cfg.num_particles
    twist_fn = twist or partial(_default_twist, cfg=smc_cfg)
    resampler = smc_cfg.resampler_spec().build()

    def maybe_resample(k, log_w, caches, tokens_so_far):
        # The FUSED SMC step (Resampler.step, DESIGN.md §12): normalise,
        # ESS, the resample-or-not branch and the token-buffer copy in ONE
        # launch on kernel backends — no host-side branch around the
        # resampler.  The KV/SSM cache pytree — mixed dtypes/shapes per
        # leaf — is gathered with the ancestors the step returns; when the
        # branch doesn't fire those are the identity permutation, so the
        # gather is a no-op copy and every output is bit-identical to the
        # untriggered path.  (Trigger is ess/N < threshold — same fraction
        # as the old ess < threshold*N form, now computed on-chip.)
        new_tokens, ancestors, step_stats = resampler.step(
            k, log_w, tokens_so_far, smc_cfg.ess_threshold
        )
        trigger = step_stats.ess_norm < smc_cfg.ess_threshold
        new_caches = jax.tree.map(lambda c: jnp.take(c, ancestors, axis=0), caches)
        log_w = jnp.where(trigger, jnp.zeros_like(log_w), log_w)
        return log_w, new_caches, new_tokens, trigger.astype(jnp.int32), step_stats

    def step(carry, step_key):
        tokens_prev, pos, log_w, caches, out_buf, n_resamples, t = carry
        k_samp, k_res = jax.random.split(step_key)
        logits, caches = decode_step(params, model_cfg, tokens_prev[:, None], caches, pos)
        logits = logits.astype(jnp.float32)
        next_tok = jax.random.categorical(
            k_samp, logits / smc_cfg.proposal_temp, axis=-1
        ).astype(jnp.int32)
        log_w = log_w + twist_fn(logits, next_tok)
        out_buf = out_buf.at[:, t].set(next_tok)
        log_w, caches, out_buf, did, step_stats = maybe_resample(
            k_res, log_w, caches, out_buf
        )
        ys = (ess(log_w),)
        if telemetry:  # Python-static: absent from the trace when off
            ys = ys + (step_stats,)
        return (next_tok, pos + 1, log_w, caches, out_buf, n_resamples + did, t + 1), ys

    out_buf = jnp.zeros((n, smc_cfg.max_new_tokens), jnp.int32)
    log_w0 = jnp.zeros((n,), jnp.float32)
    keys = jax.random.split(key, smc_cfg.max_new_tokens)
    carry0 = (first_tokens, jnp.asarray(start_pos, jnp.int32), log_w0, caches,
              out_buf, jnp.int32(0), jnp.int32(0))
    carry, ys = jax.lax.scan(step, carry0, keys)
    _, _, log_w, caches, out_buf, n_resamples, _ = carry
    stats = {"ess_history": ys[0], "num_resamples": n_resamples}
    if telemetry:
        return out_buf, log_w, stats, Telemetry(steps=ys[1])
    return out_buf, log_w, stats
