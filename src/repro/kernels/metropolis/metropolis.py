"""Metropolis resampling — Pallas TPU kernel (the paper's Alg. 2 strawman).

A faithful port of Metropolis needs a random per-(particle, iteration)
gather over the FULL weight array: the uncoalesced pattern of the paper's
Fig. 2.  On TPU the only way to honour those semantics is to keep the whole
weight array VMEM-resident and gather in-register, which caps N at the VMEM
budget (~1M f32 = 4 MB comfortably).  That cap is itself the finding: the
random-access algorithm does not scale on TPU, while Megopolis streams
aligned tiles from HBM at any N.  The benchmark suite reports this next to
the transaction-model numbers.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import (
    LANES,
    SUBLANES,
    gather_state,
    hash_bits,
    hash_uniform,
    step_select,
    step_stats,
    tile_lane_ids,
)

SEG = SUBLANES * LANES


def _sweep(t, b, seed, w_full, w_own, k_prev, wk_prev):
    """One Alg. 2 accept/reject sweep of one (8,128) tile.

    Shared by the single and batched kernel bodies (same discipline as the
    Megopolis ``_sweep``) so the two can never drift arithmetically."""
    i_global = tile_lane_ids(t)
    k = jnp.where(b == 0, i_global, k_prev)
    wk = jnp.where(b == 0, w_own, wk_prev)

    n_total = w_full.shape[0] * LANES
    # Alg. 2 line 5: j ~ U{0, N-1} per (particle, iteration) — random gather.
    j = (hash_bits(seed, i_global, b) % jnp.uint32(n_total)).astype(jnp.int32)
    w_flat = w_full.reshape(n_total)
    w_j = jnp.take(w_flat, j.reshape(-1), axis=0).reshape(SUBLANES, LANES)

    u = hash_uniform(seed, i_global + n_total, b, dtype=w_j.dtype)
    accept = u * wk <= w_j
    return jnp.where(accept, j, k), jnp.where(accept, w_j, wk)


def _kernel(seed_ref, w_full_ref, w_own_ref, k_ref, wk_ref):
    t = pl.program_id(0)
    b = pl.program_id(1)
    k_new, wk_new = _sweep(
        t, b, seed_ref[0], w_full_ref[...].astype(jnp.float32),
        w_own_ref[...].astype(jnp.float32), k_ref[...], wk_ref[...]
    )
    k_ref[...] = k_new
    wk_ref[...] = wk_new


def _kernel_batch(seeds_ref, w_full_ref, w_own_ref, k_ref, wk_ref):
    """Grid step (s, t, b): row s of the bank, tile t, iteration b.

    One whole ``[B, R, 128]`` bank per pallas_call; each row keeps its own
    VMEM-resident weight copy (the strawman's cost, paid per row) and its
    own stateless-RNG seed ``seeds[s]``, so row s is bit-identical to the
    single-bank kernel run with that seed."""
    s = pl.program_id(0)
    t = pl.program_id(1)
    b = pl.program_id(2)
    k_new, wk_new = _sweep(
        t, b, seeds_ref[s], w_full_ref[0].astype(jnp.float32),
        w_own_ref[0].astype(jnp.float32), k_ref[0], wk_ref[...]
    )
    k_ref[0] = k_new
    wk_ref[...] = wk_new


def _kernel_fused(seed_ref, w_full_ref, w_own_ref, planes_ref, k_ref, out_ref,
                  wk_ref):
    """Fused grid step (t, b): Alg. 2 sweep + last-iteration state copy from
    the resident plane stack (DESIGN.md §11) — the weights AND the state
    are both VMEM-resident here (the strawman's residency cost, now paid
    once for selection and copy together)."""
    t = pl.program_id(0)
    b = pl.program_id(1)
    k_new, wk_new = _sweep(
        t, b, seed_ref[0], w_full_ref[...].astype(jnp.float32),
        w_own_ref[...].astype(jnp.float32), k_ref[...], wk_ref[...]
    )
    k_ref[...] = k_new
    wk_ref[...] = wk_new

    @pl.when(b == pl.num_programs(1) - 1)
    def _copy_state():
        out_ref[...] = gather_state(planes_ref[...], k_new)


def _kernel_fused_batch(seeds_ref, w_full_ref, w_own_ref, planes_ref, k_ref,
                        out_ref, wk_ref):
    """Fused grid step (s, t, b): row s of the bank, per-row seed — row s is
    bit-identical to the fused single kernel with ``seeds[s]``."""
    s = pl.program_id(0)
    t = pl.program_id(1)
    b = pl.program_id(2)
    k_new, wk_new = _sweep(
        t, b, seeds_ref[s], w_full_ref[0].astype(jnp.float32),
        w_own_ref[0].astype(jnp.float32), k_ref[0], wk_ref[...]
    )
    k_ref[0] = k_new
    wk_ref[...] = wk_new

    @pl.when(b == pl.num_programs(2) - 1)
    def _copy_state():
        out_ref[0] = gather_state(planes_ref[0], k_new)


def _kernel_step(seed_ref, thr_ref, lw_full_ref, lw_own_ref, planes_ref,
                 k_ref, out_ref, stats_ref, wk_ref, st_ref):
    """Fused STEP grid step (t, b): the (0, 0) prelude latches (m, do) from
    the resident log-weights; every sweep runs on ``exp(lw - m)`` — the
    same normalised weights the composed path hands to ``apply`` — and the
    last-iteration epilogue commits either the selection or the identity."""
    t = pl.program_id(0)
    b = pl.program_id(1)
    n_total = lw_full_ref.shape[0] * LANES

    @pl.when((t == 0) & (b == 0))
    def _prelude():
        m, ess_norm, incr, maxw, deg = step_stats(
            lw_full_ref[...].astype(jnp.float32).reshape(n_total), n_total)
        do = ess_norm < thr_ref[0]
        st_ref[0] = m
        st_ref[1] = jnp.where(do, jnp.float32(1.0), jnp.float32(0.0))
        st_ref[2] = jnp.where(deg, jnp.float32(1.0), jnp.float32(0.0))
        stats_ref[0] = ess_norm
        stats_ref[1] = jnp.where(do, incr, jnp.float32(0.0))
        stats_ref[2] = jnp.where(do, jnp.float32(1.0), jnp.float32(0.0))
        stats_ref[3] = maxw

    m = st_ref[0]
    do = st_ref[1] > 0.5
    deg = st_ref[2] > 0.5
    # Normalised weights re-land on the plane-dtype grid (the composed path
    # quantises at the public ``apply`` boundary); a no-op at f32.  The §16
    # degenerate latch substitutes the uniform bank BEFORE the requantise.
    w_full = jnp.exp(lw_full_ref[...].astype(jnp.float32) - m)
    w_own = jnp.exp(lw_own_ref[...].astype(jnp.float32) - m)
    w_full = jnp.where(deg, jnp.float32(1.0 / n_total), w_full)
    w_own = jnp.where(deg, jnp.float32(1.0 / n_total), w_own)
    w_full = w_full.astype(lw_full_ref.dtype).astype(jnp.float32)
    w_own = w_own.astype(lw_own_ref.dtype).astype(jnp.float32)
    k_new, wk_new = _sweep(
        t, b, seed_ref[0], w_full, w_own, k_ref[...], wk_ref[...]
    )
    k_ref[...] = k_new
    wk_ref[...] = wk_new

    @pl.when(b == pl.num_programs(1) - 1)
    def _commit():
        k_sel = step_select(do, k_new, t)
        k_ref[...] = k_sel
        out_ref[...] = gather_state(planes_ref[...], k_sel)


def _kernel_step_rows(seeds_ref, thr_ref, lw_full_ref, lw_own_ref, planes_ref,
                      k_ref, out_ref, stats_ref, wk_ref, st_ref):
    """Fused STEP over a bank, grid (s, t, b): per-row seeds; the prelude
    re-latches (m, do) at each row's (t, b) == (0, 0) and writes that row's
    ``stats[s]``."""
    s = pl.program_id(0)
    t = pl.program_id(1)
    b = pl.program_id(2)
    n_total = lw_full_ref.shape[1] * LANES

    @pl.when((t == 0) & (b == 0))
    def _prelude():
        m, ess_norm, incr, maxw, deg = step_stats(
            lw_full_ref[0].astype(jnp.float32).reshape(n_total), n_total)
        do = ess_norm < thr_ref[0]
        st_ref[0] = m
        st_ref[1] = jnp.where(do, jnp.float32(1.0), jnp.float32(0.0))
        st_ref[2] = jnp.where(deg, jnp.float32(1.0), jnp.float32(0.0))
        stats_ref[s, 0] = ess_norm
        stats_ref[s, 1] = jnp.where(do, incr, jnp.float32(0.0))
        stats_ref[s, 2] = jnp.where(do, jnp.float32(1.0), jnp.float32(0.0))
        stats_ref[s, 3] = maxw

    m = st_ref[0]
    do = st_ref[1] > 0.5
    deg = st_ref[2] > 0.5
    w_full = jnp.exp(lw_full_ref[0].astype(jnp.float32) - m)
    w_own = jnp.exp(lw_own_ref[0].astype(jnp.float32) - m)
    w_full = jnp.where(deg, jnp.float32(1.0 / n_total), w_full)
    w_own = jnp.where(deg, jnp.float32(1.0 / n_total), w_own)
    w_full = w_full.astype(lw_full_ref.dtype).astype(jnp.float32)
    w_own = w_own.astype(lw_own_ref.dtype).astype(jnp.float32)
    k_new, wk_new = _sweep(
        t, b, seeds_ref[s], w_full, w_own, k_ref[0], wk_ref[...]
    )
    k_ref[0] = k_new
    wk_ref[...] = wk_new

    @pl.when(b == pl.num_programs(2) - 1)
    def _commit():
        k_sel = step_select(do, k_new, t)
        k_ref[0] = k_sel
        out_ref[0] = gather_state(planes_ref[0], k_sel)


@functools.partial(jax.jit, static_argnames=("num_iters", "interpret"))
def metropolis_pallas(
    weights2d: jnp.ndarray,
    seed: jnp.ndarray,
    *,
    num_iters: int,
    interpret: bool = True,
) -> jnp.ndarray:
    rows, lanes = weights2d.shape
    assert lanes == LANES and rows % SUBLANES == 0
    num_tiles = rows // SUBLANES

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(num_tiles, num_iters),
        in_specs=[
            # whole weight array resident (the uncoalesced strawman's cost)
            pl.BlockSpec((rows, LANES), lambda t, b, seed: (0, 0)),
            pl.BlockSpec((SUBLANES, LANES), lambda t, b, seed: (t, 0)),
        ],
        out_specs=pl.BlockSpec((SUBLANES, LANES), lambda t, b, seed: (t, 0)),
        scratch_shapes=[pltpu.VMEM((SUBLANES, LANES), jnp.float32)],
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((rows, lanes), jnp.int32),
        interpret=interpret,
    )(seed, weights2d, weights2d)


@functools.partial(jax.jit, static_argnames=("num_iters", "interpret"))
def metropolis_pallas_batch(
    weights3d: jnp.ndarray,
    seeds: jnp.ndarray,
    *,
    num_iters: int,
    interpret: bool = True,
) -> jnp.ndarray:
    """Batched pallas_call: a ``[Bz, R, 128]`` weight bank in ONE launch.

    Same leading batch-grid dimension as the Megopolis bank kernel —
    grid (Bz, num_tiles, num_iters), iteration axis innermost so the VMEM
    ``w[k]`` carry runs the full chain per (row, tile).  ``seeds``:
    uint32[Bz], one stateless-RNG stream per row.  Returns int32[Bz, R, 128];
    row s is bit-identical to ``metropolis_pallas(weights3d[s],
    seeds[s:s+1], ...)``.
    """
    bsz, rows, lanes = weights3d.shape
    assert lanes == LANES and rows % SUBLANES == 0
    num_tiles = rows // SUBLANES

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(bsz, num_tiles, num_iters),
        in_specs=[
            # row s's whole weight array resident (per-row strawman cost)
            pl.BlockSpec((1, rows, LANES), lambda s, t, b, seeds: (s, 0, 0)),
            pl.BlockSpec((1, SUBLANES, LANES), lambda s, t, b, seeds: (s, t, 0)),
        ],
        out_specs=pl.BlockSpec((1, SUBLANES, LANES), lambda s, t, b, seeds: (s, t, 0)),
        scratch_shapes=[pltpu.VMEM((SUBLANES, LANES), jnp.float32)],
    )
    return pl.pallas_call(
        _kernel_batch,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bsz, rows, lanes), jnp.int32),
        interpret=interpret,
    )(seeds, weights3d, weights3d)


@functools.partial(jax.jit, static_argnames=("num_iters", "interpret"))
def metropolis_pallas_fused(
    weights2d: jnp.ndarray,
    planes: jnp.ndarray,
    seed: jnp.ndarray,
    *,
    num_iters: int,
    interpret: bool = True,
):
    """Fused resample+gather pallas_call: ancestors identical to
    ``metropolis_pallas``; ``planes`` ``[d_pad, R, 128]`` resident.  Returns
    ``(int32[R, 128], [d_pad, R, 128])``."""
    rows, lanes = weights2d.shape
    assert lanes == LANES and rows % SUBLANES == 0
    d_pad = planes.shape[0]
    assert planes.shape[1:] == (rows, lanes)
    num_tiles = rows // SUBLANES

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(num_tiles, num_iters),
        in_specs=[
            pl.BlockSpec((rows, LANES), lambda t, b, seed: (0, 0)),
            pl.BlockSpec((SUBLANES, LANES), lambda t, b, seed: (t, 0)),
            pl.BlockSpec((d_pad, rows, LANES), lambda t, b, seed: (0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((SUBLANES, LANES), lambda t, b, seed: (t, 0)),
            pl.BlockSpec((d_pad, SUBLANES, LANES), lambda t, b, seed: (0, t, 0)),
        ],
        scratch_shapes=[pltpu.VMEM((SUBLANES, LANES), jnp.float32)],
    )
    return pl.pallas_call(
        _kernel_fused,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((rows, lanes), jnp.int32),
            jax.ShapeDtypeStruct((d_pad, rows, lanes), planes.dtype),
        ],
        interpret=interpret,
    )(seed, weights2d, weights2d, planes)


@functools.partial(jax.jit, static_argnames=("num_iters", "interpret"))
def metropolis_pallas_fused_batch(
    weights3d: jnp.ndarray,
    planes4d: jnp.ndarray,
    seeds: jnp.ndarray,
    *,
    num_iters: int,
    interpret: bool = True,
):
    """Fused bank launch: one leading-batch-grid pallas_call; row s is
    bit-identical to ``metropolis_pallas_fused(weights3d[s], planes4d[s],
    seeds[s:s+1], ...)``.  Returns ``(int32[Bz, R, 128], [Bz, d_pad, R, 128])``."""
    bsz, rows, lanes = weights3d.shape
    assert lanes == LANES and rows % SUBLANES == 0
    d_pad = planes4d.shape[1]
    assert planes4d.shape == (bsz, d_pad, rows, lanes)
    num_tiles = rows // SUBLANES

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(bsz, num_tiles, num_iters),
        in_specs=[
            pl.BlockSpec((1, rows, LANES), lambda s, t, b, seeds: (s, 0, 0)),
            pl.BlockSpec((1, SUBLANES, LANES), lambda s, t, b, seeds: (s, t, 0)),
            pl.BlockSpec(
                (1, d_pad, rows, LANES), lambda s, t, b, seeds: (s, 0, 0, 0)
            ),
        ],
        out_specs=[
            pl.BlockSpec((1, SUBLANES, LANES), lambda s, t, b, seeds: (s, t, 0)),
            pl.BlockSpec(
                (1, d_pad, SUBLANES, LANES), lambda s, t, b, seeds: (s, 0, t, 0)
            ),
        ],
        scratch_shapes=[pltpu.VMEM((SUBLANES, LANES), jnp.float32)],
    )
    return pl.pallas_call(
        _kernel_fused_batch,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((bsz, rows, lanes), jnp.int32),
            jax.ShapeDtypeStruct((bsz, d_pad, rows, lanes), planes4d.dtype),
        ],
        interpret=interpret,
    )(seeds, weights3d, weights3d, planes4d)


@functools.partial(jax.jit, static_argnames=("num_iters", "interpret"))
def metropolis_pallas_step(
    log_weights2d: jnp.ndarray,
    planes: jnp.ndarray,
    seed: jnp.ndarray,
    thr: jnp.ndarray,
    *,
    num_iters: int,
    interpret: bool = True,
):
    """Fused SMC-step pallas_call: normalise → ESS → conditional Alg. 2
    resample → state copy, ONE launch.  ``log_weights2d``: f32[R, 128]
    UNNORMALISED (already whole-array resident here — the strawman's
    residency is exactly what the step prelude needs anyway).  Returns
    ``(int32[R, 128], [d_pad, R, 128], f32[4] = (ess_norm, incr,
    resampled, max_weight))``."""
    rows, lanes = log_weights2d.shape
    assert lanes == LANES and rows % SUBLANES == 0
    d_pad = planes.shape[0]
    assert planes.shape[1:] == (rows, lanes)
    num_tiles = rows // SUBLANES

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # seed + f32 ESS threshold
        grid=(num_tiles, num_iters),
        in_specs=[
            pl.BlockSpec((rows, LANES), lambda t, b, seed, thr: (0, 0)),
            pl.BlockSpec((SUBLANES, LANES), lambda t, b, seed, thr: (t, 0)),
            pl.BlockSpec((d_pad, rows, LANES), lambda t, b, seed, thr: (0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((SUBLANES, LANES), lambda t, b, seed, thr: (t, 0)),
            pl.BlockSpec((d_pad, SUBLANES, LANES), lambda t, b, seed, thr: (0, t, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        scratch_shapes=[
            pltpu.VMEM((SUBLANES, LANES), jnp.float32),
            pltpu.SMEM((3,), jnp.float32),
        ],
    )
    return pl.pallas_call(
        _kernel_step,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((rows, lanes), jnp.int32),
            jax.ShapeDtypeStruct((d_pad, rows, lanes), planes.dtype),
            jax.ShapeDtypeStruct((4,), jnp.float32),
        ],
        interpret=interpret,
    )(seed, thr, log_weights2d, log_weights2d, planes)


@functools.partial(jax.jit, static_argnames=("num_iters", "interpret"))
def metropolis_pallas_step_rows(
    log_weights3d: jnp.ndarray,
    planes4d: jnp.ndarray,
    seeds: jnp.ndarray,
    thr: jnp.ndarray,
    *,
    num_iters: int,
    interpret: bool = True,
):
    """Fused SMC-step bank launch: row s is bit-identical to
    ``metropolis_pallas_step(log_weights3d[s], planes4d[s], seeds[s:s+1],
    thr, ...)``.  Returns ``(int32[Bz, R, 128], [Bz, d_pad, R, 128],
    f32[Bz, 4])``."""
    bsz, rows, lanes = log_weights3d.shape
    assert lanes == LANES and rows % SUBLANES == 0
    d_pad = planes4d.shape[1]
    assert planes4d.shape == (bsz, d_pad, rows, lanes)
    num_tiles = rows // SUBLANES

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(bsz, num_tiles, num_iters),
        in_specs=[
            pl.BlockSpec((1, rows, LANES), lambda s, t, b, se, r: (s, 0, 0)),
            pl.BlockSpec((1, SUBLANES, LANES), lambda s, t, b, se, r: (s, t, 0)),
            pl.BlockSpec(
                (1, d_pad, rows, LANES), lambda s, t, b, se, r: (s, 0, 0, 0)
            ),
        ],
        out_specs=[
            pl.BlockSpec((1, SUBLANES, LANES), lambda s, t, b, se, r: (s, t, 0)),
            pl.BlockSpec(
                (1, d_pad, SUBLANES, LANES), lambda s, t, b, se, r: (s, 0, t, 0)
            ),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        scratch_shapes=[
            pltpu.VMEM((SUBLANES, LANES), jnp.float32),
            pltpu.SMEM((3,), jnp.float32),
        ],
    )
    return pl.pallas_call(
        _kernel_step_rows,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((bsz, rows, lanes), jnp.int32),
            jax.ShapeDtypeStruct((bsz, d_pad, rows, lanes), planes4d.dtype),
            jax.ShapeDtypeStruct((bsz, 4), jnp.float32),
        ],
        interpret=interpret,
    )(seeds, thr, log_weights3d, log_weights3d, planes4d)
