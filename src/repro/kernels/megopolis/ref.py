"""Pure-jnp oracle for the Megopolis Pallas kernel — bit-exact.

Mirrors the kernel's arithmetic (same hash RNG, same SEG=1024 index map,
same value-carried ``w[k]``) without any Pallas machinery.  The *quality*
of this variant (MSE/bias) is separately validated against the
``jax.random``-based ``repro.core.megopolis`` in tests.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.resamplers.megopolis import megopolis_indices
from repro.kernels.common import TILE, hash_uniform

SEG = TILE  # 1024 — must match the kernel


@functools.partial(jax.jit, static_argnames=("num_iters",))
def megopolis_ref(
    weights: jnp.ndarray,
    offsets: jnp.ndarray,
    seed: jnp.ndarray,
    *,
    num_iters: int,
) -> jnp.ndarray:
    """int32[N] ancestors; must equal the kernel output exactly."""
    n = weights.shape[0]
    i = jnp.arange(n, dtype=jnp.int32)
    seed = jnp.asarray(seed).reshape(-1)[0]
    # Selection arithmetic is ALWAYS f32, whatever dtype the weight plane
    # arrives in (DESIGN.md §14: the kernel upcasts compressed operands on
    # load) — a no-op for the f32 golden streams.
    weights = weights.astype(jnp.float32)

    def body(b, state):
        k, wk = state
        j = megopolis_indices(i, offsets[b], SEG, n).astype(jnp.int32)
        w_j = weights[j]
        u = hash_uniform(seed, i, b, dtype=jnp.float32)
        accept = u * wk <= w_j
        return jnp.where(accept, j, k), jnp.where(accept, w_j, wk)

    k, _ = jax.lax.fori_loop(0, num_iters, body, (i, weights))
    return k
