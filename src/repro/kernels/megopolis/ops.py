"""Public jit'd wrapper for the Megopolis TPU kernel.

Accepts the same ``(key, weights, num_iters)`` signature as the reference
resamplers in ``repro.core``.  Alignment contract: ``N % 1024 == 0`` (one
f32 VMEM tile); production particle counts are powers of two well above
this (the paper sweeps 2^6..2^22), and the wrapper raises a clear error
otherwise rather than silently padding (padding would perturb the
uniform-offset distribution over [0, N)).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.common import TILE, key_to_seed
from repro.kernels.megopolis.megopolis import LANES, megopolis_pallas, megopolis_pallas_batch


def megopolis_tpu(
    key: jax.Array,
    weights: jnp.ndarray,
    num_iters: int,
    *,
    interpret: bool = True,
) -> jnp.ndarray:
    """Resample with the Pallas Megopolis kernel; returns int32[N] ancestors.

    ``interpret=True`` (default here) runs the kernel body on CPU for
    validation; pass ``interpret=False`` on real TPU hardware.
    """
    n = weights.shape[0]
    if n % TILE != 0:
        raise ValueError(
            f"megopolis_tpu requires N % {TILE} == 0 (one f32 VMEM tile); got N={n}. "
            "Use repro.core.megopolis for unaligned N."
        )
    key_off, key_seed = jax.random.split(key)
    offsets = jax.random.randint(key_off, (num_iters,), 0, n, dtype=jnp.int32)
    seed = key_to_seed(key_seed).reshape(1)
    w2 = weights.reshape(n // LANES, LANES)
    k2 = megopolis_pallas(w2, offsets, seed, num_iters=num_iters, interpret=interpret)
    return k2.reshape(n)


def megopolis_tpu_batch(
    key: jax.Array,
    weights: jnp.ndarray,
    num_iters: int,
    *,
    interpret: bool = True,
) -> jnp.ndarray:
    """Resample a ``[B, N]`` weight bank in one kernel launch (DESIGN.md §4).

    The global offset table is drawn ONCE and shared by every row (the
    bank-level lift of Alg. 5's shared offset — one scalar-prefetch schedule
    for the whole launch); each row gets its own stateless-RNG seed, so rows
    stay statistically independent.  Returns int32[B, N] ancestors.
    """
    if weights.ndim != 2:
        raise ValueError(f"megopolis_tpu_batch expects weights[B, N]; got {weights.shape}")
    bsz, n = weights.shape
    if n % TILE != 0:
        raise ValueError(
            f"megopolis_tpu_batch requires N % {TILE} == 0 (one f32 VMEM tile); got N={n}. "
            "Use repro.core.megopolis_batch for unaligned N."
        )
    key_off, key_rows = jax.random.split(key)
    offsets = jax.random.randint(key_off, (num_iters,), 0, n, dtype=jnp.int32)
    seeds = key_to_seed(jax.random.split(key_rows, bsz))
    w3 = weights.reshape(bsz, n // LANES, LANES)
    k3 = megopolis_pallas_batch(w3, offsets, seeds, num_iters=num_iters, interpret=interpret)
    return k3.reshape(bsz, n)
