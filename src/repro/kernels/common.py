"""Shared kernel utilities: counter-based hash RNG + tile flat-roll.

Both are defined ONCE here and imported by the Pallas kernel bodies *and*
the ``ref.py`` oracles so kernel-vs-ref comparisons are bit-exact.

RNG rationale (DESIGN.md §2): the paper pays coalesced loads/stores for
CURAND XORWOW state.  A counter-based hash (murmur3 finalizer over
``(seed, lane, iteration)``) is stateless — zero memory traffic — and is
TPU-friendly (integer mul/xor/shift on the VPU).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
from jax import lax

# NOTE: all scalar constants below are *numpy* scalars so they inline as
# jaxpr literals — Pallas kernel bodies may not close over device constants.
_GOLDEN = np.uint32(0x9E3779B9)
LANES = 128
SUBLANES = 8
_LANE = LANES
_SUBLANES = SUBLANES
TILE = SUBLANES * LANES  # 1024 particles per (8,128) f32 VMEM tile


def tile_lane_ids(t) -> jnp.ndarray:
    """Global particle index of every lane of tile ``t``: int32[8, 128] with
    flat row-major value ``t * 1024 + row * 128 + col`` — the ONE lane->
    particle map every kernel body shares."""
    row = lax.broadcasted_iota(jnp.int32, (SUBLANES, LANES), 0)
    col = lax.broadcasted_iota(jnp.int32, (SUBLANES, LANES), 1)
    return t * TILE + row * LANES + col

# Residency budget for kernels that keep a whole f32[N] array VMEM-resident
# (the Metropolis/rejection random gather, the search kernel's CDF): ~4 MB,
# comfortably inside a 16 MB VMEM core.  ONE definition — DESIGN.md §2
# cites it, three ops modules enforce it.
MAX_VMEM_PARTICLES = 1 << 20


def check_tile_aligned(n: int, who: str):
    """Raise unless N is whole (8, 128) f32 VMEM tiles."""
    if n % TILE != 0:
        raise ValueError(f"{who} requires N % {TILE} == 0; got {n}")


def check_vmem_resident(
    n: int,
    who: str,
    what: str = "weight array",
    remedy: str = "Use megopolis_tpu (streams tiles at any N).",
):
    """Raise when a whole-array-resident kernel exceeds the VMEM budget."""
    if n > MAX_VMEM_PARTICLES:
        raise ValueError(
            f"{who} keeps the whole {what} VMEM-resident and caps N at "
            f"{MAX_VMEM_PARTICLES} — the scaling wall the paper's coalescing "
            f"removes. {remedy}"
        )


def murmur3_fmix(x: jnp.ndarray) -> jnp.ndarray:
    """murmur3 32-bit finalizer; full-avalanche integer hash."""
    x = x.astype(jnp.uint32)
    x = x ^ (x >> np.uint32(16))
    x = x * np.uint32(0x85EBCA6B)
    x = x ^ (x >> np.uint32(13))
    x = x * np.uint32(0xC2B2AE35)
    x = x ^ (x >> np.uint32(16))
    return x


def hash_bits(seed, lane_index, iteration) -> jnp.ndarray:
    """uint32 stream indexed by (seed, lane, iteration) — order-free."""
    if isinstance(iteration, (int, np.integer)):
        # wrap in Python ints to avoid numpy overflow RuntimeWarnings
        inc = np.uint32((int(iteration) * int(_GOLDEN)) & 0xFFFFFFFF)
    else:
        inc = jnp.asarray(iteration).astype(jnp.uint32) * _GOLDEN
    if isinstance(seed, (int, np.integer)) and isinstance(inc, np.uint32):
        s = np.uint32((int(seed) + int(inc)) & 0xFFFFFFFF)
    else:
        s = _as_u32(seed) + inc
    return murmur3_fmix(murmur3_fmix(s) ^ (lane_index.astype(jnp.uint32) * _GOLDEN))


def _as_u32(x):
    if isinstance(x, (int, np.integer)):
        return np.uint32(x)
    return jnp.asarray(x).astype(jnp.uint32)


def hash_uniform(seed, lane_index, iteration, dtype=jnp.float32) -> jnp.ndarray:
    """U[0,1) with 24 bits of mantissa entropy."""
    bits = hash_bits(seed, lane_index, iteration)
    return (bits >> np.uint32(8)).astype(dtype) * (1.0 / (1 << 24))


def hash_randint(seed, lane_index, iteration, bound) -> jnp.ndarray:
    """uint32 in [0, bound) via modulo (bias < 2^-20 for bound <= 2^12)."""
    return (hash_bits(seed, lane_index, iteration) % _as_u32(bound)).astype(jnp.int32)


def flat_roll(x: jnp.ndarray, shift) -> jnp.ndarray:
    """Roll a (rows, 128) tile by ``shift`` in FLAT row-major order:
    ``out.flat[p] = x.flat[(p + shift) % size]``.

    Decomposed into two row-rolls + two lane-rolls + a lane-mask select so
    every constituent op is a register-level vector rotate (the in-VMEM
    analogue of the paper's intra-segment wrap, Alg. 5 line 10).
    """
    rows, lanes = x.shape
    shift = jnp.asarray(shift) % (rows * lanes)
    a = shift // lanes
    b = shift % lanes
    hi = jnp.roll(x, -a, axis=0)  # rows shifted by floor(shift/lanes)
    lo = jnp.roll(x, -(a + 1), axis=0)  # .. and one further for wrapped lanes
    hi = jnp.roll(hi, -b, axis=1)
    lo = jnp.roll(lo, -b, axis=1)
    col = lax.broadcasted_iota(jnp.int32, (rows, lanes), 1)
    return jnp.where(col < (lanes - b).astype(jnp.int32), hi, lo)


def key_to_seed(key) -> jnp.ndarray:
    """Derive a uint32 seed from a JAX PRNG key (stable, documented)."""
    import jax

    data = jax.random.key_data(key).astype(jnp.uint32)
    return murmur3_fmix(data[..., 0] ^ (data[..., 1] * _GOLDEN))
