"""Aggregate analyzer report (DESIGN.md §13).

One call produces the whole machine-readable audit: the family × backend ×
entry matrix, the large-N footprint pricing, the consumer contracts, the
adaptive-reference RNG sweep, and the §2.4 transaction table.  The CLI
(``python -m repro.analysis``) and the benchmark harness
(``benchmarks/analysis_bench.py``) both serialise exactly this object, so
"what CI enforces" and "what the paper tables report" cannot drift.
"""

from __future__ import annotations

from repro.analysis import consumers as consumers_mod
from repro.analysis import contracts as contracts_mod
from repro.analysis import guards as guards_mod
from repro.analysis import telemetry as telemetry_mod
from repro.core.transactions import (
    MEGOPOLIS_EXACT,
    measured_transaction_stats,
)

#: Families priced by the §2.4 transaction model (the iterate-and-compare
#: GPU families the paper counts; prefix-sum/rejection have no
#: comparison-index stream to price).
TRANSACTION_FAMILIES = ("megopolis", "metropolis", "metropolis_c1", "metropolis_c2")


def transaction_report(*, n: int = 4096, num_iters: int = 32,
                       word_bytes: int = 4) -> dict:
    """Measured vs declared §2.4 transactions per warp-iteration; each
    family entry carries ``ok`` (measured max within the declared bound;
    Megopolis additionally max == mean == the exact coalesced count — 4 at
    f32 words, 2 at bf16's ``word_bytes=2``, DESIGN.md §14)."""
    out = {}
    exact = (MEGOPOLIS_EXACT * word_bytes) // 4
    for name in TRANSACTION_FAMILIES:
        stats = measured_transaction_stats(
            name, n=n, num_iters=num_iters, word_bytes=word_bytes
        )
        ok = stats["max"] <= stats["bound"]
        if name == "megopolis":
            ok = ok and stats["max"] == exact and stats["mean"] == float(exact)
        out[name] = {**stats, "ok": ok}
    return out


def build_report(
    *,
    families=None,
    backends=None,
    entries=None,
    consumers: bool = True,
    large_n: bool = True,
    transactions: bool = True,
    telemetry: bool = True,
    resilience: bool = True,
    plane_dtypes=("float32", "bfloat16"),
) -> dict:
    """Run every audit and return one JSON-serialisable report.

    ``report["ok"]`` is the single bit CI gates on: every cell honest,
    every consumer honest, no unwaived RNG finding, every measured
    transaction count within its declared §2.4 bound, and telemetry free
    (pass 6, DESIGN.md §15: flipping ``telemetry=True`` adds zero launches
    and leaves the DCE'd estimates program identical on every cell).
    Pass 7 (``resilience``, DESIGN.md §16) audits the guard axis the same
    way: ``guard='flag'`` identical-jaxpr to ``'off'``, ``'recover'``
    launch-parity + clean-input bit-identity + degenerate-input recovery.
    ``plane_dtypes`` spans the DESIGN.md §14 compression axis: compressed
    cells are audited against the SAME launch budgets, and the transaction
    table is re-priced per word size (``transactions@bfloat16`` at
    ``word_bytes=2``).
    """
    matrix = [
        rep.as_dict()
        for rep in contracts_mod.audit_matrix(
            families, backends, entries, plane_dtypes=plane_dtypes
        )
    ]
    report: dict = {
        "matrix": matrix,
        "matrix_cells": len(matrix),
        "matrix_violations": [c for c in matrix if not c["ok"]],
    }

    if large_n:
        big = [rep.as_dict() for rep in contracts_mod.audit_large_n_footprints(families)]
        report["large_n"] = big
        report["large_n_violations"] = [c for c in big if not c["ok"]]

    if consumers:
        cons = [rep.as_dict() for rep in consumers_mod.audit_consumers()]
        auto = [
            {
                "cell": cell,
                "ok": not kept,
                "findings": [f.as_dict() for f in kept],
                "waived": waived,
            }
            for cell, kept, waived in consumers_mod.auto_reference_rng()
        ]
        report["consumers"] = cons
        report["consumer_violations"] = [c for c in cons if not c["ok"]]
        report["auto_reference_rng"] = auto
        report["auto_reference_violations"] = [a for a in auto if not a["ok"]]

    if telemetry:
        tel = list(
            telemetry_mod.audit_telemetry(
                families, backends, plane_dtypes=plane_dtypes
            )
        )
        report["telemetry"] = tel
        report["telemetry_violations"] = [c for c in tel if not c["ok"]]

    if resilience:
        res = list(
            guards_mod.audit_guards(
                families, backends, plane_dtypes=plane_dtypes
            )
        )
        report["resilience"] = res
        report["resilience_violations"] = [c for c in res if not c["ok"]]

    if transactions:
        tx = transaction_report()
        report["transactions"] = tx
        report["transaction_violations"] = {
            k: v for k, v in tx.items() if not v["ok"]
        }
        for dtype, wb in (("bfloat16", 2), ("float16", 2)):
            if dtype not in plane_dtypes:
                continue
            txc = transaction_report(word_bytes=wb)
            report[f"transactions@{dtype}"] = txc
            report["transaction_violations"].update({
                f"{k}@{dtype}": v for k, v in txc.items() if not v["ok"]
            })

    report["ok"] = not (
        report["matrix_violations"]
        or report.get("large_n_violations")
        or report.get("consumer_violations")
        or report.get("auto_reference_violations")
        or report.get("telemetry_violations")
        or report.get("resilience_violations")
        or report.get("transaction_violations")
    )
    return report


def summarise(report: dict) -> str:
    """Human-readable digest of ``build_report``'s output."""
    lines = [
        f"matrix: {report['matrix_cells']} cells, "
        f"{len(report['matrix_violations'])} violation(s)"
    ]
    if "large_n" in report:
        lines.append(
            f"large-N footprints: {len(report['large_n'])} cells, "
            f"{len(report['large_n_violations'])} violation(s)"
        )
    if "consumers" in report:
        lines.append(
            f"consumers: {len(report['consumers'])} programs, "
            f"{len(report['consumer_violations'])} violation(s); "
            f"auto-reference rng: {len(report['auto_reference_violations'])} "
            "violation(s)"
        )
        waived = sum(len(c["waived"]) for c in report["consumers"]) + sum(
            len(a["waived"]) for a in report["auto_reference_rng"]
        )
        if waived:
            lines.append(f"waivers applied: {waived}")
    if "telemetry" in report:
        lines.append(
            f"telemetry neutrality: {len(report['telemetry'])} cells, "
            f"{len(report['telemetry_violations'])} violation(s)"
        )
    if "resilience" in report:
        lines.append(
            f"guard neutrality: {len(report['resilience'])} cells, "
            f"{len(report['resilience_violations'])} violation(s)"
        )
    if "transactions" in report:
        tx = report["transactions"]
        parts = ", ".join(
            f"{k}: max {v['max']}/bound {v['bound']}" for k, v in tx.items()
        )
        lines.append(f"transactions per warp-iteration: {parts}")
    for section in (
        "matrix_violations",
        "large_n_violations",
        "consumer_violations",
    ):
        for cell in report.get(section, []):
            for v in cell["violations"]:
                lines.append(f"  VIOLATION {cell['cell']}: {v}")
    for a in report.get("auto_reference_violations", []):
        for f in a["findings"]:
            lines.append(f"  VIOLATION {a['cell']}: [{f['pass_name']}:{f['code']}] {f['detail']}")
    for section in ("telemetry_violations", "resilience_violations"):
        for cell in report.get(section, []):
            for v in cell["violations"]:
                lines.append(f"  VIOLATION {cell['cell']}: {v}")
    for k, v in report.get("transaction_violations", {}).items():
        lines.append(f"  VIOLATION transactions/{k}: max {v['max']} > bound {v['bound']}")
    lines.append("OK" if report["ok"] else "FAILED")
    return "\n".join(lines)