"""§Roofline: render the three-term roofline table from dry-run JSON.

The dry-run (launch/dryrun.py --all --both-meshes --out <json>) records
per-cell cost/memory/collective analysis; this module formats the §Roofline
table for EXPERIMENTS.md and ranks cells by bottleneck for the §Perf
hillclimb selection.

    python -m benchmarks.roofline --in experiments/dryrun.json [--md]
"""

from __future__ import annotations

import argparse
import json

from benchmarks.common import print_table, write_csv


def rows_from(records: list[dict]) -> list[dict]:
    rows = []
    for r in records:
        if not r.get("ok"):
            rows.append({"arch": r["arch"], "shape": r["shape"], "mesh": r.get("mesh"),
                         "bottleneck": "FAILED", "error": r.get("error", "")[:60]})
            continue
        roof = r["roofline"]
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "kind": r["kind"],
            "GiB/dev": round(r["bytes_per_device"]["peak_estimate"] / 2**30, 2),
            "t_comp_ms": round(roof["t_compute_s"] * 1e3, 2),
            "t_mem_ms": round(roof["t_memory_s"] * 1e3, 2),
            "t_coll_ms": round(roof["t_collective_s"] * 1e3, 2),
            "bottleneck": roof["bottleneck"],
            "useful_ratio": round(roof["useful_flops_ratio"], 3),
            "roofline_frac": round(roof["roofline_fraction"], 4),
        })
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--in", dest="inp", default="experiments/dryrun.json")
    ap.add_argument("--md", action="store_true", help="emit a markdown table")
    args = ap.parse_args(argv)
    with open(args.inp) as f:
        records = json.load(f)
    rows = rows_from(records)
    write_csv("roofline.csv", rows)
    if args.md:
        cols = list(rows[0].keys())
        print("| " + " | ".join(cols) + " |")
        print("|" + "|".join("---" for _ in cols) + "|")
        for r in rows:
            print("| " + " | ".join(str(r.get(c, "")) for c in cols) + " |")
    else:
        print_table(rows)
    ok = [r for r in rows if r["bottleneck"] != "FAILED" and r["shape"] == "train_4k"]
    if ok:
        worst = min(ok, key=lambda r: r["roofline_frac"] or 1)
        coll = max(ok, key=lambda r: r["t_coll_ms"])
        print(f"\nhillclimb candidates: worst-fraction={worst['arch']}x{worst['shape']}, "
              f"most-collective-bound={coll['arch']}x{coll['shape']}")


if __name__ == "__main__":
    main()
