"""Fused resample+gather (``Resampler.apply``) quality gate (DESIGN.md §11).

Contract under test, over the FULL family × backend matrix:

  1. **composition parity** — ``apply(key, w, p)`` is bit-identical to
     ``(take(p, r(key, w)), r(key, w))`` on the SAME backend, for single,
     bank (``apply_batch`` vs ``batch``) and explicit-key rows
     (``apply_rows`` vs ``batch_rows``) forms;
  2. **state layout** — scalar ``[N]`` states, trailing multi-dim states,
     a ``state_dim`` NOT divisible by the plane tile (padding path), and
     4-byte integer states all gather exactly;
  3. **state-column equivariance** (hypothesis) — permuting state columns
     commutes with ``apply`` (pins that plane packing/padding never mixes
     components);
  4. **residency** — the fused kernels enforce the VMEM state budget with
     a clear error;
  5. **consumers** — the resample paths of ``ParticleFilter.step``,
     ``run_filter_bank`` and the AIS sampler contain no ``jnp.take`` (the
     HBM index round-trip the fused path exists to remove), and the
     analytic memory model says fused < unfused.
"""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.resamplers.batched import split_batch_keys
from repro.core.spec import spec_for_backend
from repro.kernels.common import (
    MAX_VMEM_STATE,
    STATE_PLANE_TILE,
    TILE,
    pack_state_planes,
    pad_state_dim,
    unpack_state_planes,
)

N = 2 * TILE
BATCH = 3
ITERS = 8
MAX_ITERS = 24

FAMILIES = (
    "megopolis",
    "metropolis",
    "metropolis_c1",
    "metropolis_c2",
    "rejection",
    "multinomial",
    "systematic",
    "improved_systematic",
    "stratified",
    "residual",
)
BACKENDS = ("reference", "xla", "pallas_interpret")
#: The DESIGN.md §14 compression axis the parity tests sweep.
PLANE_DTYPES_TESTED = ("float32", "bfloat16")


def _build(name, backend, plane_dtype="float32"):
    return spec_for_backend(name, backend, num_iters=ITERS, max_iters=MAX_ITERS,
                            plane_dtype=plane_dtype).build()


@pytest.fixture(scope="module")
def w_single():
    return jax.random.uniform(jax.random.PRNGKey(11), (N,)) + 1e-3


@pytest.fixture(scope="module")
def w_bank():
    return jax.random.uniform(jax.random.PRNGKey(12), (BATCH, N)) + 1e-3


@pytest.fixture(scope="module")
def p_single():
    return jax.random.normal(jax.random.PRNGKey(13), (N, 4))


@pytest.fixture(scope="module")
def p_bank():
    return jax.random.normal(jax.random.PRNGKey(14), (BATCH, N, 4))


def _assert_equal(a, b):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------- 1. composition parity
@pytest.mark.parametrize("plane_dtype", PLANE_DTYPES_TESTED)
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("name", FAMILIES)
def test_apply_single_matches_take(name, backend, plane_dtype, w_single,
                                   p_single, base_key):
    r = _build(name, backend, plane_dtype)
    ancestors = r(base_key, w_single)
    got_p, got_a = r.apply(base_key, w_single, p_single)
    _assert_equal(got_a, ancestors)
    # Compressed cells gather the QUANTISED plane (DESIGN.md §14); at f32
    # ``quantise`` is the identity and this is the original oracle.
    _assert_equal(got_p, jnp.take(r.quantise(p_single), ancestors, axis=0))


@pytest.mark.parametrize("plane_dtype", PLANE_DTYPES_TESTED)
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("name", FAMILIES)
def test_apply_batch_matches_take(name, backend, plane_dtype, w_bank, p_bank,
                                  base_key):
    r = _build(name, backend, plane_dtype)
    ancestors = r.batch(base_key, w_bank)
    got_p, got_a = r.apply_batch(base_key, w_bank, p_bank)
    _assert_equal(got_a, ancestors)
    _assert_equal(
        got_p,
        jax.vmap(lambda p, a: jnp.take(p, a, axis=0))(
            r.quantise(p_bank), ancestors
        ),
    )


@pytest.mark.parametrize("plane_dtype", PLANE_DTYPES_TESTED)
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("name", FAMILIES)
def test_apply_rows_matches_rows(name, backend, plane_dtype, w_bank, p_bank,
                                 base_key):
    """apply_rows row b == apply(keys[b], w[b], p[b]) — the filter-bank
    contract — and its ancestors == batch_rows."""
    r = _build(name, backend, plane_dtype)
    keys = split_batch_keys(base_key, BATCH)
    got_p, got_a = r.apply_rows(keys, w_bank, p_bank)
    _assert_equal(got_a, r.batch_rows(keys, w_bank))
    for b in range(BATCH):
        pb, ab = r.apply(keys[b], w_bank[b], p_bank[b])
        _assert_equal(got_a[b], ab)
        _assert_equal(got_p[b], pb)


# ------------------------------------------ 1b. degenerate-weight parity
# The §12 entry-consistency cells extended to collapsed WEIGHT banks
# (DESIGN.md §16, satellite S3): under guard='recover', every degenerate
# signature resamples exactly like the uniform bank, and the fused
# entries stay mutually consistent (__call__ == apply ancestors,
# apply_rows row b == apply row b) — family × backend × plane dtype.
def _degenerate_weight_cases(n):
    uni = jnp.full((n,), 1.0 / n, jnp.float32)
    return {
        "all_nan": jnp.full((n,), jnp.nan, jnp.float32),
        "all_zero": jnp.zeros((n,), jnp.float32),
        "pos_inf_entry": uni.at[5].set(jnp.inf),
        "subnormal": jnp.full((n,), 1e-40, jnp.float32),
        "one_hot": jnp.zeros((n,), jnp.float32).at[n // 3].set(1.0),
    }


@pytest.mark.parametrize("plane_dtype", PLANE_DTYPES_TESTED)
@pytest.mark.parametrize("case", sorted(_degenerate_weight_cases(4)))
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("name", ("megopolis", "rejection", "systematic"))
def test_degenerate_weights_entry_consistency(name, backend, case,
                                              plane_dtype, p_single, p_bank,
                                              base_key):
    w = _degenerate_weight_cases(N)[case]
    r = spec_for_backend(name, backend, num_iters=ITERS,
                         max_iters=MAX_ITERS, plane_dtype=plane_dtype,
                         guard="recover").build()
    ancestors = r(base_key, w)
    assert bool(jnp.all((ancestors >= 0) & (ancestors < N)))
    got_p, got_a = r.apply(base_key, w, p_single)
    _assert_equal(got_a, ancestors)
    _assert_equal(got_p, jnp.take(r.quantise(p_single), ancestors, axis=0))
    keys = split_batch_keys(base_key, BATCH)
    w_bank = jnp.stack([w] * BATCH)
    rows_p, rows_a = r.apply_rows(keys, w_bank, p_bank)
    for b in range(BATCH):
        pb, ab = r.apply(keys[b], w_bank[b], p_bank[b])
        _assert_equal(rows_a[b], ab)
        _assert_equal(rows_p[b], pb)


@pytest.mark.parametrize("backend", BACKENDS)
def test_degenerate_weights_recover_equals_uniform(backend, base_key):
    """The recover contract on the weights entries: collapsed banks draw
    the SAME ancestors as the uniform bank with the same key."""
    r = spec_for_backend("systematic", backend, guard="recover").build()
    uni = jnp.full((N,), 1.0 / N, jnp.float32)
    exp = r(base_key, uni)
    for case in ("all_nan", "all_zero", "pos_inf_entry"):
        _assert_equal(r(base_key, _degenerate_weight_cases(N)[case]), exp)


# ------------------------------------------------------- 2. state layouts
@pytest.mark.parametrize("backend", ("reference", "pallas_interpret"))
@pytest.mark.parametrize("name", ("megopolis", "rejection", "systematic"))
def test_apply_scalar_state(name, backend, w_single, base_key):
    p = jax.random.normal(jax.random.PRNGKey(21), (N,))
    r = _build(name, backend)
    got_p, got_a = r.apply(base_key, w_single, p)
    assert got_p.shape == (N,)
    _assert_equal(got_p, jnp.take(p, got_a, axis=0))


@pytest.mark.parametrize("name", FAMILIES)
def test_apply_padded_state_dim(name, w_single, base_key):
    """state_dim = 5 is not divisible by the plane tile (8): the kernel
    lane must pad, gather and unpad without touching real components."""
    assert 5 % STATE_PLANE_TILE != 0
    p = jax.random.normal(jax.random.PRNGKey(22), (N, 5))
    r = _build(name, "pallas_interpret")
    got_p, got_a = r.apply(base_key, w_single, p)
    _assert_equal(got_p, jnp.take(p, got_a, axis=0))


@pytest.mark.parametrize("name", ("megopolis", "metropolis"))
def test_apply_multidim_and_int_state(name, w_single, base_key):
    r = _build(name, "pallas_interpret")
    p3 = jax.random.normal(jax.random.PRNGKey(23), (N, 2, 3))
    got_p, got_a = r.apply(base_key, w_single, p3)
    _assert_equal(got_p, jnp.take(p3, got_a, axis=0))
    pi = jax.random.randint(jax.random.PRNGKey(24), (N, 3), 0, 1 << 20)
    got_pi, got_ai = r.apply(base_key, w_single, pi)
    assert got_pi.dtype == pi.dtype
    _assert_equal(got_pi, jnp.take(pi, got_ai, axis=0))


def test_pack_unpack_roundtrip():
    for shape in [(N,), (N, 1), (N, 4), (N, 5), (N, 2, 3)]:
        p = jax.random.normal(jax.random.PRNGKey(25), shape)
        planes, state_shape = pack_state_planes(p)
        d = int(np.prod(shape[1:])) if len(shape) > 1 else 1
        assert planes.shape[0] == pad_state_dim(d)
        _assert_equal(unpack_state_planes(planes, state_shape), p)


# --------------------------------------- 3. state-column equivariance
def _check_column_permutation(seed: int):
    """apply(key, w, p[:, perm]) == apply(key, w, p)[:, perm]: the fused
    plane packing must never mix state components, padded or not."""
    k = jax.random.PRNGKey(seed)
    d = 1 + seed % 11  # covers padded (d % 8 != 0) and unpadded dims
    w = jax.random.uniform(jax.random.fold_in(k, 0), (N,)) + 1e-3
    p = jax.random.normal(jax.random.fold_in(k, 1), (N, d))
    perm = jax.random.permutation(jax.random.fold_in(k, 2), d)
    r = _build("megopolis", "pallas_interpret")
    key = jax.random.fold_in(k, 3)
    out, _ = r.apply(key, w, p)
    out_perm, _ = r.apply(key, w, p[:, perm])
    _assert_equal(out_perm, out[:, perm])


try:
    from hypothesis import given, settings, strategies as st

    @given(seed=st.integers(0, 2**30))
    @settings(max_examples=10, deadline=None)
    def test_apply_state_column_permutation_equivariance(seed):
        _check_column_permutation(seed)

except ImportError:
    # hypothesis absent (CI installs it): pinned seed grid instead.
    @pytest.mark.parametrize("seed", [0, 3, 7, 12, 31])
    def test_apply_state_column_permutation_equivariance(seed):
        _check_column_permutation(seed)


@pytest.mark.parametrize("backend", ("reference", "pallas_interpret"))
@pytest.mark.parametrize("name", ("megopolis", "metropolis"))
def test_apply_rows_rejects_short_key_array(name, backend, w_bank, p_bank, base_key):
    """A keys array shorter than the bank must raise — the fused bank
    kernels size their grid from weights and would otherwise read
    out-of-bounds seeds."""
    r = _build(name, backend)
    keys = split_batch_keys(base_key, BATCH - 1)
    with pytest.raises(ValueError, match="one key per row"):
        r.apply_rows(keys, w_bank, p_bank)


# ----------------------------------- 1b. cross-dtype ancestor bit-parity
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("name", FAMILIES)
def test_compressed_ancestors_bit_identical_to_f32(name, backend, w_single,
                                                   base_key):
    """The DESIGN.md §14 headline claim: compressing the planes never
    perturbs the ancestor stream.  ``r_bf16(key, w)`` equals
    ``r_f32(key, r_bf16.quantise(w))`` ancestor-for-ancestor, because
    selection arithmetic, RNG and bisection all stay f32 on-chip — only
    the stored operand values move to the bf16 grid."""
    rb = _build(name, backend, "bfloat16")
    rf = _build(name, backend, "float32")
    _assert_equal(rb(base_key, w_single), rf(base_key, rb.quantise(w_single)))


# ------------------------------------------------------- 4. residency cap
def test_apply_state_residency_cap(base_key):
    d = MAX_VMEM_STATE // N // STATE_PLANE_TILE * STATE_PLANE_TILE + STATE_PLANE_TILE
    p = jnp.zeros((N, d), jnp.float32)
    w = jnp.ones((N,), jnp.float32)
    r = _build("megopolis", "pallas_interpret")
    with pytest.raises(ValueError, match="VMEM"):
        r.apply(base_key, w, p)


def test_f16_residency_edge_admits_wider_state(base_key):
    """The eq.(3) residency edge re-derives from the plane itemsize
    (DESIGN.md §14): at N=1024 a padded state of 2064 components overflows
    the 4-byte f32 byte budget but fits in half-width f16 planes."""
    n, d = 1024, 2056  # pad_state_dim(2056) == 2064
    assert n * pad_state_dim(d) > MAX_VMEM_STATE          # f32: over budget
    assert n * pad_state_dim(d) * 2 <= MAX_VMEM_STATE * 4  # f16: within bytes
    w = jnp.ones((n,), jnp.float32)
    p = jnp.zeros((n, d), jnp.float32)
    with pytest.raises(ValueError, match="VMEM"):
        _build("megopolis", "pallas_interpret").apply(base_key, w, p)
    r16 = _build("megopolis", "pallas_interpret", "float16")
    got_p, got_a = r16.apply(base_key, w, p)
    assert got_p.shape == (n, d)
    _assert_equal(got_p, jnp.take(r16.quantise(p), got_a, axis=0))


# ----------------------------------------------------------- 5. consumers
@pytest.mark.parametrize(
    "consumer",
    (
        "pf.step",
        "pf.run_filter_bank",
        "ais.run_smc_sampler",
        "ais.run_smc_sampler_bank",
    ),
)
def test_resample_paths_contain_no_take(consumer):
    """The acceptance gate of the fused data path: ancestors never leave a
    kernel to index an HBM gather — asserted on the consumers' traced
    jaxprs by the DESIGN.md §13 taint pass, not by grepping their source."""
    from repro.analysis import audit_consumers

    (rep,) = audit_consumers(names=[consumer])
    assert rep.ok, rep.violations
    assert rep.tainted_gathers == 0


def test_memmodel_fused_beats_unfused():
    from repro.launch.memmodel import resample_step_bytes

    for n in (1 << 10, 1 << 16, 1 << 20):
        for d in (1, 4, 32):
            fused = resample_step_bytes(n, d, fused=True)
            unfused = resample_step_bytes(n, d, fused=False)
            assert fused["total"] < unfused["total"]
            assert unfused["total"] - fused["total"] == n * 4  # the index vector


def test_filter_step_is_fused_and_matches_reference(base_key):
    """End-to-end: a ParticleFilter on the pallas_interpret backend steps
    through apply and equals the manual index+take composition."""
    from repro.core.spec import MegopolisSpec
    from repro.pf import ParticleFilter, ungm

    pf = ParticleFilter(
        model=ungm(),
        num_particles=TILE,
        resampler=MegopolisSpec(num_iters=ITERS, segment=1024,
                                backend="pallas_interpret"),
    )
    particles = pf.model.init(jax.random.PRNGKey(30), TILE)
    z = jnp.float32(0.3)
    x_bar, est, w, _ = pf.step(base_key, particles, z, jnp.float32(1.0))
    # replay the step manually through the index path
    k_pred, k_res = jax.random.split(base_key)
    x = pf.model.transition(k_pred, particles, jnp.float32(1.0))
    w_ref = pf.model.likelihood(z, x, jnp.float32(1.0))
    anc = pf._built(k_res, w_ref)
    _assert_equal(x_bar, jnp.take(x, anc, axis=0))
    _assert_equal(w, w_ref)
