"""SMC particle-decoding benchmark (the paper's technique as a serving
feature, DESIGN.md §5): tokens/s and resample overhead across resamplers
and particle counts on a smoke-scale arch; also contrasts the
ancestor-gather cost of attention-cache vs SSM-state archs."""

from __future__ import annotations

import argparse

from benchmarks.common import print_table, write_csv
from repro.launch.serve import serve_once


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--archs", nargs="*", default=["qwen3-0.6b", "mamba2-1.3b"])
    ap.add_argument("--particles", type=int, nargs="*", default=[32, 128])
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--resamplers", nargs="*",
                    default=["megopolis", "metropolis", "improved_systematic"])
    args = ap.parse_args(argv)

    rows = []
    for arch in args.archs:
        for n in args.particles:
            for res in args.resamplers:
                out = serve_once(arch, smoke=True, num_particles=n,
                                 new_tokens=args.new_tokens, resampler=res)
                rows.append({"arch": arch, "particles": n, "resampler": res,
                             "tok_per_s": out["tok_per_s"],
                             "num_resamples": out["num_resamples"],
                             "decode_s": out["decode_s"]})
    write_csv("smc_decode.csv", rows)
    print_table(rows)


if __name__ == "__main__":
    main()
