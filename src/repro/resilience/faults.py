"""Deterministic fault injection — the §16 chaos harness's ammunition.

Every injector is PRNG-keyed and pure: the same key produces the same
fault plane on every backend and every run, so a chaos cell that fails
replays bit-for-bit.  Two kinds of primitive live here:

* **Injectors** take a clean array and a key and corrupt it —
  ``inject_nan_weights`` / ``inject_inf_weights`` (weight planes),
  ``bitflip_states`` (raw mantissa/exponent bit-flips in f32 state
  planes), ``poison_ancestors`` (out-of-range ancestor indices).
* **Generators** build whole adversarial log-weight banks from scratch —
  all-NaN, all-``-inf``, one-hot, near-collapse — the §12/§16 degenerate
  signatures, enumerated in ``FAULT_CLASSES`` so the chaos suite and the
  CI lane sweep the same vocabulary.

``validate_ancestors`` is the consumer-side tripwire: a host-side range
check that raises the typed ``CorruptAncestorsError`` instead of letting
a poisoned gather scatter garbage.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.resilience.errors import CorruptAncestorsError

# ----------------------------------------------------------------- injectors


def inject_nan_weights(key, w: jnp.ndarray, rate: float = 0.1) -> jnp.ndarray:
    """Seed NaNs into a weight/log-weight plane at ``rate`` (Bernoulli per
    element, keyed)."""
    mask = jax.random.bernoulli(key, rate, jnp.shape(w))
    return jnp.where(mask, jnp.float32(jnp.nan), w)


def inject_inf_weights(key, w: jnp.ndarray, rate: float = 0.1,
                       sign: int = 1) -> jnp.ndarray:
    """Seed ±inf into a weight/log-weight plane at ``rate`` (keyed)."""
    mask = jax.random.bernoulli(key, rate, jnp.shape(w))
    return jnp.where(mask, jnp.float32(sign) * jnp.float32(jnp.inf), w)


def bitflip_states(key, planes: jnp.ndarray, rate: float = 0.01) -> jnp.ndarray:
    """Flip one uniformly-chosen bit in each selected f32 element.

    The radiation-storm model: elements are selected Bernoulli(``rate``),
    each selected element gets exactly one of its 32 bits inverted —
    mantissa flips perturb values, exponent/sign flips can mint NaN/inf,
    so downstream guards must cope with BOTH.  Pure bitcast arithmetic;
    no host round-trip.
    """
    planes = jnp.asarray(planes, jnp.float32)
    k_sel, k_bit = jax.random.split(key)
    sel = jax.random.bernoulli(k_sel, rate, planes.shape)
    bit = jax.random.randint(k_bit, planes.shape, 0, 32, dtype=jnp.int32)
    bits = lax.bitcast_convert_type(planes, jnp.uint32)
    flipped = bits ^ (jnp.uint32(1) << bit.astype(jnp.uint32))
    out = lax.bitcast_convert_type(jnp.where(sel, flipped, bits), jnp.float32)
    return out


def poison_ancestors(key, ancestors: jnp.ndarray, n: int,
                     rate: float = 0.05) -> jnp.ndarray:
    """Replace a keyed Bernoulli subset of ancestor indices with
    out-of-range values (negative or ``>= n``) — the corrupted-index
    plane ``validate_ancestors`` must catch."""
    k_sel, k_val = jax.random.split(key)
    sel = jax.random.bernoulli(k_sel, rate, jnp.shape(ancestors))
    bad = jax.random.randint(k_val, jnp.shape(ancestors), n, 2 * n + 1,
                             dtype=jnp.int32)
    sign = jnp.where(jax.random.bernoulli(k_val, 0.5, jnp.shape(ancestors)),
                     jnp.int32(1), jnp.int32(-1))
    return jnp.where(sel, sign * bad, ancestors)


def validate_ancestors(ancestors, n: int) -> jnp.ndarray:
    """Host-side range tripwire: every index must lie in ``[0, n)``.

    Returns the (concrete) ancestors unchanged when clean; raises the
    typed ``CorruptAncestorsError`` — never silent garbage — when any
    index is out of range.  Concrete-only by design: the chaos harness
    checks evidence host-side, the hot path never pays for it.
    """
    a = np.asarray(ancestors)
    bad = (a < 0) | (a >= n)
    if bool(bad.any()):
        count = int(bad.sum())
        worst = a[bad].ravel()
        raise CorruptAncestorsError(
            f"ancestor vector holds {count} out-of-range indices "
            f"(n={n}; e.g. {worst[:4].tolist()})"
        )
    return ancestors


# ---------------------------------------------------------------- generators


def all_nan_bank(n: int) -> jnp.ndarray:
    """f32[n] log-weight bank of NaNs — total information loss."""
    return jnp.full((n,), jnp.nan, jnp.float32)


def all_neg_inf_bank(n: int) -> jnp.ndarray:
    """f32[n] log-weight bank of ``-inf`` — every particle impossible."""
    return jnp.full((n,), -jnp.inf, jnp.float32)


def one_hot_bank(n: int, hot: int = 0) -> jnp.ndarray:
    """All mass on one particle (``-inf`` everywhere else): NOT degenerate
    under the §16 predicate — finite max — but ESS sits at its 1/N floor."""
    return jnp.where(jnp.arange(n) == hot, jnp.float32(0.0),
                     jnp.float32(-jnp.inf)).astype(jnp.float32)


def near_collapse_bank(n: int, scale: float = 80.0) -> jnp.ndarray:
    """Steep finite geometric decay — numerically near one-hot without any
    non-finite entry; exercises the exp/shift path at the underflow edge."""
    return (-jnp.float32(scale) * jnp.arange(n, dtype=jnp.float32))


#: name → log-weight-bank generator (f32[n]); the chaos suite's sweep axis.
FAULT_CLASSES = {
    "all_nan": all_nan_bank,
    "all_neg_inf": all_neg_inf_bank,
    "one_hot": one_hot_bank,
    "near_collapse": near_collapse_bank,
}
