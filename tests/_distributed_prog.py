"""Subprocess program for distributed tests — run with 8 virtual devices.

Invoked by tests/test_distributed.py via subprocess so the main pytest
process keeps its single real CPU device (jax locks device count at init).
Prints 'OK <name>' per passing check; any exception fails the subprocess.
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 " + os.environ.get("XLA_FLAGS", "")
)

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.core.distributed import (  # noqa: E402
    _static_shard_schedule,
    effective_sample_size,
    gather_ancestors,
    island_exchange,
    make_distributed_resampler,
    megopolis_hier_ref,
)
from repro.core.metrics import mse, offspring_counts  # noqa: E402
from repro.core.weightgen import gaussian_weights  # noqa: E402
from repro.core import megopolis as core_megopolis, select_iterations  # noqa: E402
from repro.kernels.common import key_to_seed  # noqa: E402
from repro.compat import make_mesh, shard_map  # noqa: E402


def main():
    assert jax.device_count() == 8, jax.device_count()
    mesh = make_mesh((8,), ("data",), devices=jax.devices())
    n = 8 * 2048
    num_iters = 24
    key = jax.random.PRNGKey(0)
    w = gaussian_weights(key, n, y=2.0)

    # ---- exactness: shard_map static schedule == single-device hier oracle
    res = make_distributed_resampler(mesh, axis_name="data", num_iters=num_iters, schedule="static")
    k_call = jax.random.PRNGKey(42)
    a_dist = np.asarray(res(k_call, w))
    k_seed, k_loc, k_shard = jax.random.split(k_call, 3)
    seed = key_to_seed(k_seed)
    offs_local = jax.random.randint(k_loc, (num_iters,), 0, n // 8, jnp.int32)
    sched = _static_shard_schedule(0xA5A5, num_iters, 8)
    a_ref = np.asarray(
        megopolis_hier_ref(seed, offs_local, sched, w, n_shards=8, num_iters=num_iters)
    )
    np.testing.assert_array_equal(a_dist, a_ref)
    print("OK static_exactness")

    # ---- exactness: dynamic (hypercube) schedule == oracle w/ same offsets
    res_d = make_distributed_resampler(
        mesh, axis_name="data", num_iters=num_iters, schedule="dynamic"
    )
    a_dyn = np.asarray(res_d(k_call, w))
    offs_shard = jax.random.randint(jax.random.split(k_call, 3)[2], (num_iters,), 0, 8, jnp.int32)
    a_ref_d = np.asarray(
        megopolis_hier_ref(seed, offs_local, offs_shard, w, n_shards=8, num_iters=num_iters)
    )
    np.testing.assert_array_equal(a_dyn, a_ref_d)
    print("OK dynamic_exactness")

    # ---- quality parity vs single-device megopolis (MSE within 40%)
    b_needed = int(select_iterations(w, 0.01))
    res_q = make_distributed_resampler(mesh, axis_name="data", num_iters=b_needed)
    runs_d, runs_s = [], []
    for t in range(16):
        kk = jax.random.fold_in(key, 100 + t)
        runs_d.append(np.asarray(offspring_counts(res_q(kk, w), n)))
        runs_s.append(np.asarray(offspring_counts(core_megopolis(kk, w, b_needed), n)))
    m_d = float(mse(jnp.asarray(np.stack(runs_d)), w)) / n
    m_s = float(mse(jnp.asarray(np.stack(runs_s)), w)) / n
    assert abs(m_d - m_s) < 0.4 * m_s, (m_d, m_s)
    print("OK quality_parity", round(m_d, 4), round(m_s, 4))

    # ---- payload gather: distributed gather == take on global arrays
    x = jax.random.normal(jax.random.PRNGKey(7), (n, 3))
    anc = res(k_call, w)
    gathered = jax.jit(
        shard_map(
            lambda xl, al: gather_ancestors(xl, al, axis_name="data"),
            mesh=mesh,
            in_specs=(P("data"), P("data")),
            out_specs=P("data"),
        )
    )(x, anc)
    np.testing.assert_allclose(np.asarray(gathered), np.asarray(jnp.take(x, anc, axis=0)), rtol=0)
    print("OK gather")

    # ---- island exchange: preserves multiset of particles
    mixed = jax.jit(
        shard_map(
            lambda xl: island_exchange(xl, axis_name="data", fraction=0.25),
            mesh=mesh,
            in_specs=(P("data"),),
            out_specs=P("data"),
        )
    )(x)
    np.testing.assert_allclose(
        np.sort(np.asarray(mixed).ravel()), np.sort(np.asarray(x).ravel()), rtol=0
    )
    print("OK island")

    # ---- ESS psum
    ess = jax.jit(
        shard_map(
            lambda wl: effective_sample_size(wl, axis_name="data"),
            mesh=mesh,
            in_specs=(P("data"),),
            out_specs=P(),
        )
    )(w)
    ess_ref = float(jnp.sum(w) ** 2 / jnp.sum(w**2))
    assert abs(float(ess) - ess_ref) / ess_ref < 1e-5
    print("OK ess")

    # ---- collective accounting: static mode must lower to exactly B
    # collective-permutes; dynamic mode to B * log2(8).
    import re

    def n_permutes(fn):
        txt = jax.jit(fn).lower(k_call, w).compile().as_text()
        # Count instruction call sites only ("collective-permute(" — the
        # async start/done forms spell "collective-permute-start(").  A bare
        # name match over-counts: HLO text repeats each instruction name at
        # every operand reference, which varies by XLA version.
        return len(re.findall(r"\bcollective-permute\(", txt))

    cp_static = n_permutes(res)
    cp_dynamic = n_permutes(res_d)
    assert cp_static <= num_iters + 2, cp_static
    # hypercube = 3 hops/iter, but hop 1 rotates the loop-invariant weight
    # block so XLA CSE dedupes it across iterations: 2B + 1 expected.
    assert 2 * num_iters <= cp_dynamic <= 3 * num_iters + 2, (cp_dynamic, num_iters)
    assert cp_dynamic > cp_static
    print("OK collective_counts", cp_static, cp_dynamic)

    print("ALL_OK")


if __name__ == "__main__":
    main()
