"""Public wrappers for the rejection TPU kernel (VMEM-resident baseline)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.resamplers.batched import split_batch_keys
from repro.kernels.common import (
    check_state_resident,
    check_tile_aligned,
    check_vmem_resident,
    compress_plane,
    key_to_seed,
    pack_state_planes,
    plane_itemsize,
    run_fused_bank,
    run_step_bank,
    state_dim_of,
    state_itemsize,
    unpack_state_planes,
)
from repro.kernels.rejection.rejection import (
    LANES,
    rejection_pallas,
    rejection_pallas_batch,
    rejection_pallas_fused,
    rejection_pallas_fused_batch,
    rejection_pallas_step,
    rejection_pallas_step_rows,
)


def _check(n: int, who: str, plane_dtype="float32"):
    # Same residency cap as the Metropolis strawman (random full-array gather).
    check_tile_aligned(n, who)
    check_vmem_resident(n, who, itemsize=plane_itemsize(plane_dtype))


def rejection_tpu(
    key: jax.Array,
    weights: jnp.ndarray,
    *,
    max_iters: int = 1024,
    interpret: bool = True,
    plane_dtype="float32",
) -> jnp.ndarray:
    n = weights.shape[0]
    _check(n, "rejection_tpu", plane_dtype)
    seed = key_to_seed(key).reshape(1)
    w2 = compress_plane(weights.reshape(n // LANES, LANES), plane_dtype)
    k2 = rejection_pallas(w2, seed, max_iters=max_iters, interpret=interpret)
    return k2.reshape(n)


def rejection_tpu_batch(
    key: jax.Array,
    weights: jnp.ndarray,
    *,
    max_iters: int = 1024,
    interpret: bool = True,
    plane_dtype="float32",
) -> jnp.ndarray:
    """One ``[B, R, 128]`` launch; row b == ``rejection_tpu(split(key,B)[b],
    weights[b])`` bit-exactly (the §4 split-key contract, held on-kernel)."""
    if weights.ndim != 2:
        raise ValueError(f"rejection_tpu_batch expects weights[B, N]; got {weights.shape}")
    bsz, n = weights.shape
    _check(n, "rejection_tpu_batch", plane_dtype)
    seeds = key_to_seed(split_batch_keys(key, bsz))
    w3 = compress_plane(weights.reshape(bsz, n // LANES, LANES), plane_dtype)
    k3 = rejection_pallas_batch(w3, seeds, max_iters=max_iters, interpret=interpret)
    return k3.reshape(bsz, n)


def rejection_tpu_apply(
    key: jax.Array,
    weights: jnp.ndarray,
    particles: jnp.ndarray,
    *,
    max_iters: int = 1024,
    interpret: bool = True,
    plane_dtype="float32",
):
    """Fused resample+gather (DESIGN.md §11): ancestors identical to
    ``rejection_tpu``.  Returns ``(particles', ancestors)``."""
    n = weights.shape[0]
    _check(n, "rejection_tpu_apply", plane_dtype)
    check_state_resident(
        n, state_dim_of(particles, n, "rejection_tpu_apply"), "rejection_tpu_apply",
        itemsize=state_itemsize(particles, plane_dtype),
    )
    seed = key_to_seed(key).reshape(1)
    w2 = compress_plane(weights.reshape(n // LANES, LANES), plane_dtype)
    planes, state_shape = pack_state_planes(particles)
    planes = compress_plane(planes, plane_dtype)
    k2, out = rejection_pallas_fused(
        w2, planes, seed, max_iters=max_iters, interpret=interpret
    )
    out = out.astype(particles.dtype)
    return unpack_state_planes(out, state_shape), k2.reshape(n)


def _rejection_apply_bank(seeds, weights, particles, *, max_iters, interpret,
                          who, plane_dtype="float32"):
    _check(weights.shape[1], who, plane_dtype)
    return run_fused_bank(
        lambda w3, planes: rejection_pallas_fused_batch(
            w3, planes, seeds, max_iters=max_iters, interpret=interpret
        ),
        weights, particles, who, plane_dtype=plane_dtype,
    )


def rejection_tpu_apply_batch(
    key: jax.Array,
    weights: jnp.ndarray,
    particles: jnp.ndarray,
    *,
    max_iters: int = 1024,
    interpret: bool = True,
    plane_dtype="float32",
):
    """Fused bank launch under the §4 split-key contract; row b ==
    ``rejection_tpu_apply(split(key, B)[b], ...)`` bit-exactly."""
    if weights.ndim != 2:
        raise ValueError(
            f"rejection_tpu_apply_batch expects weights[B, N]; got {weights.shape}"
        )
    seeds = key_to_seed(split_batch_keys(key, weights.shape[0]))
    return _rejection_apply_bank(
        seeds, weights, particles, max_iters=max_iters, interpret=interpret,
        who="rejection_tpu_apply_batch", plane_dtype=plane_dtype,
    )


def rejection_tpu_step(
    key: jax.Array,
    log_weights: jnp.ndarray,
    particles: jnp.ndarray,
    ess_threshold,
    *,
    max_iters: int = 1024,
    interpret: bool = True,
    plane_dtype="float32",
):
    """Fused SMC step (DESIGN.md §12): normalise → ESS → conditional
    rejection chain → state copy in ONE launch; the resample branch is
    bit-identical to ``apply(key, normalise_log_weights(log_weights), ...)``.
    Returns ``(particles', ancestors, stats f32[4])`` with ``stats`` =
    (ess_norm, log_evidence_incr, resampled, max_weight) — DESIGN.md §15."""
    n = log_weights.shape[0]
    _check(n, "rejection_tpu_step", plane_dtype)
    check_state_resident(
        n, state_dim_of(particles, n, "rejection_tpu_step"), "rejection_tpu_step",
        itemsize=state_itemsize(particles, plane_dtype),
    )
    seed = key_to_seed(key).reshape(1)
    thr = jnp.asarray(ess_threshold, jnp.float32).reshape(1)
    lw2 = compress_plane(log_weights.reshape(n // LANES, LANES), plane_dtype)
    planes, state_shape = pack_state_planes(particles)
    planes = compress_plane(planes, plane_dtype)
    k2, out, stats = rejection_pallas_step(
        lw2, planes, seed, thr, max_iters=max_iters, interpret=interpret
    )
    out = out.astype(particles.dtype)
    return unpack_state_planes(out, state_shape), k2.reshape(n), stats


def rejection_tpu_step_rows(
    keys: jax.Array,
    log_weights: jnp.ndarray,
    particles: jnp.ndarray,
    ess_threshold,
    *,
    max_iters: int = 1024,
    interpret: bool = True,
    plane_dtype="float32",
):
    """Fused SMC-step bank over EXPLICIT per-row keys; row b ==
    ``rejection_tpu_step(keys[b], ...)`` bit-exactly, ONE launch.
    Returns ``(particles'[B, N, ...], ancestors, stats f32[B, 4])``."""
    if log_weights.ndim != 2:
        raise ValueError(
            f"rejection_tpu_step_rows expects log_weights[B, N]; got {log_weights.shape}"
        )
    _check(log_weights.shape[1], "rejection_tpu_step_rows", plane_dtype)
    seeds = key_to_seed(keys)
    thr = jnp.asarray(ess_threshold, jnp.float32).reshape(1)
    return run_step_bank(
        lambda lw3, planes: rejection_pallas_step_rows(
            lw3, planes, seeds, thr, max_iters=max_iters, interpret=interpret
        ),
        log_weights, particles, "rejection_tpu_step_rows",
        plane_dtype=plane_dtype,
    )


def rejection_tpu_apply_rows(
    keys: jax.Array,
    weights: jnp.ndarray,
    particles: jnp.ndarray,
    *,
    max_iters: int = 1024,
    interpret: bool = True,
    plane_dtype="float32",
):
    """Fused bank launch over EXPLICIT per-row keys; row b ==
    ``rejection_tpu_apply(keys[b], ...)`` bit-exactly, ONE launch."""
    if weights.ndim != 2:
        raise ValueError(
            f"rejection_tpu_apply_rows expects weights[B, N]; got {weights.shape}"
        )
    return _rejection_apply_bank(
        key_to_seed(keys), weights, particles, max_iters=max_iters,
        interpret=interpret, who="rejection_tpu_apply_rows",
        plane_dtype=plane_dtype,
    )
