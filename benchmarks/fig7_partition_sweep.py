"""Paper Fig. 7: MSE and execution time of C1/C2 across partition sizes
{128, 256, 512, 1024, 2048} vs the Megopolis reference lines, at the
largest N with y = 4 (weights concentrated — the degeneracy regime).

``--backend`` runs the sweep on any backend; the pallas kernels partition
at one fixed (8, 128) VMEM tile, so under a pallas backend the partition
axis collapses to the single kernel-legal point (4096 bytes) — the sweep
degenerates by construction, which is itself the TPU finding: tile-fixed
coalescing removes C1/C2's tuning axis along with its pathology.
"""

from __future__ import annotations

import argparse

import jax

from benchmarks.common import offsprings_for, print_table, time_fn, write_csv
from repro.core import MegopolisSpec, MetropolisC1Spec, MetropolisC2Spec
from repro.core.iterations import gaussian_weight_iterations
from repro.core.metrics import bias_variance
from repro.core.spec import BACKENDS, KERNEL_PARTITION_BYTES, KERNEL_SEGMENT
from repro.core.weightgen import gaussian_weights

PARTITIONS = (128, 256, 512, 1024, 2048)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--y", type=float, default=4.0)
    ap.add_argument("--backend", choices=BACKENDS, default="reference")
    args = ap.parse_args(argv)
    pallas = args.backend in ("pallas", "pallas_interpret")
    n = 1 << (22 if args.full else 12 if pallas else 14)
    runs = 256 if args.full else 8 if pallas else 16
    iters = gaussian_weight_iterations(args.y, 0.01)
    key = jax.random.PRNGKey(11)
    w = gaussian_weights(key, n, args.y)

    # The partition sweep is a spec.replace sweep (DESIGN.md §9): one
    # validated template per family, varied along its tuning axis — the
    # Megopolis reference line has no such axis, which is the point.
    templates = {
        "megopolis": MegopolisSpec(
            num_iters=iters, backend=args.backend,
            segment=KERNEL_SEGMENT if pallas else 32,
        ),
        "metropolis_c1": MetropolisC1Spec(num_iters=iters, backend=args.backend,
                                          partition_size_bytes=KERNEL_PARTITION_BYTES
                                          if pallas else 128),
        "metropolis_c2": MetropolisC2Spec(num_iters=iters, backend=args.backend,
                                          partition_size_bytes=KERNEL_PARTITION_BYTES
                                          if pallas else 128),
    }
    partitions = (KERNEL_PARTITION_BYTES,) if pallas else PARTITIONS
    rows = []
    for algo, template in templates.items():
        sizes = (0,) if algo == "megopolis" else partitions
        for ps in sizes:
            spec = template if ps == 0 else template.replace(partition_size_bytes=ps)
            resample = spec.build()
            off = offsprings_for(resample, jax.random.fold_in(key, 1), w, runs)
            var, bias_sq, total = bias_variance(off, w)
            t = time_fn(jax.jit(resample), jax.random.PRNGKey(5), w)
            rows.append({"algo": algo, "partition_bytes": ps, "B": iters,
                         "backend": args.backend,
                         "mse_over_n": float(total) / n, "time_s": t})
    write_csv("fig7.csv", rows)
    print_table(rows)
    mego = next(r for r in rows if r["algo"] == "megopolis")
    worst_c1 = max(r["mse_over_n"] for r in rows if r["algo"] == "metropolis_c1")
    print(f"\nC1 worst-partition MSE is {worst_c1 / mego['mse_over_n']:.1f}x Megopolis "
          f"(paper reports ~15x at PS=128, y=4)")


if __name__ == "__main__":
    main()
