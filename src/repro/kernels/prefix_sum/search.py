"""Coalesced binary search over a resident CDF — Pallas TPU kernel.

Second stage of the prefix-sum resamplers (paper §6.5, Algs. 7-8): after
the block-scan kernel has produced the inclusive CDF, every output slot
``i`` finds its ancestor by bisecting the CDF for its draw ``u_i``.

Memory contract: the search positions are data-dependent, so the CDF stays
VMEM-resident (same residency cap as the Metropolis strawman — the
prefix-sum family's own scaling wall on this hardware); the ``u`` draws
stream through in aligned (8, 128) tiles, one grid step per tile, and the
output ancestors store coalesced.  Each of the ``ceil(log2(N+1))``
bisection steps is one in-register gather across the tile's 1024 lanes —
no HBM traffic after the single CDF fetch.

``side`` follows ``jnp.searchsorted``: 'left' returns the first index with
``c[idx] >= u`` (systematic/stratified), 'right' the first with
``c[idx] > u`` (multinomial/residual).  Results are clipped to N-1 so they
are always valid ancestor indices even for ``u >= c[-1]`` edge draws.

Validated bit-exactly against ``jnp.searchsorted`` in ``ref.py``.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

SUBLANES = 8
LANES = 128
SEG = SUBLANES * LANES


def _make_kernel(n_total: int, side: str):
    n_steps = max(1, math.ceil(math.log2(n_total + 1)))

    def _kernel(c_ref, u_ref, k_ref):
        c_flat = c_ref[...].reshape(n_total)
        u = u_ref[...]
        lo = jnp.zeros((SUBLANES, LANES), jnp.int32)
        hi = jnp.full((SUBLANES, LANES), n_total, jnp.int32)

        def step(_, state):
            lo, hi = state
            active = lo < hi
            mid = (lo + hi) // 2
            cm = jnp.take(c_flat, mid.reshape(-1), axis=0).reshape(SUBLANES, LANES)
            pred = (cm < u) if side == "left" else (cm <= u)
            lo = jnp.where(active & pred, mid + 1, lo)
            hi = jnp.where(active & ~pred, mid, hi)
            return lo, hi

        lo, _ = jax.lax.fori_loop(0, n_steps, step, (lo, hi))
        k_ref[...] = jnp.minimum(lo, n_total - 1)

    return _kernel


@functools.partial(jax.jit, static_argnames=("side", "interpret"))
def searchsorted_pallas(
    cdf2d: jnp.ndarray,
    u2d: jnp.ndarray,
    *,
    side: str = "left",
    interpret: bool = True,
) -> jnp.ndarray:
    """``cdf2d``: non-decreasing f32[R, 128] (flat row-major CDF);
    ``u2d``: f32[R, 128] of search values.  Returns int32[R, 128] indices
    (clipped to N-1)."""
    assert side in ("left", "right")
    rows, lanes = cdf2d.shape
    assert lanes == LANES and rows % SUBLANES == 0
    assert u2d.shape == (rows, lanes)
    num_tiles = rows // SUBLANES
    n_total = rows * lanes

    return pl.pallas_call(
        _make_kernel(n_total, side),
        grid=(num_tiles,),
        in_specs=[
            # whole CDF resident; fetched once (block index constant in t)
            pl.BlockSpec((rows, LANES), lambda t: (0, 0)),
            pl.BlockSpec((SUBLANES, LANES), lambda t: (t, 0)),
        ],
        out_specs=pl.BlockSpec((SUBLANES, LANES), lambda t: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, lanes), jnp.int32),
        interpret=interpret,
    )(cdf2d, u2d)
