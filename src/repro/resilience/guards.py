"""Degeneracy-guard policy + the structured resilience-event recorder
(DESIGN.md §16).

``GuardPolicy`` is a spec axis (``ResamplerSpec.guard``), not a runtime
switch:

  * ``'off'``     — the pre-§16 program, byte for byte.
  * ``'flag'``    — the SAME computation (identical jaxpr: the degenerate
                    flag is already composed into ``StepStats`` for every
                    policy), plus a host-side ``ResilienceEvent`` when a
                    collapsed bank passes through a guarded entry — and
                    only while a recorder is active at TRACE time, so the
                    default program carries zero extra equations.
  * ``'recover'`` — degenerate banks are substituted with the uniform
                    bank BEFORE dispatch (``jnp.where`` — an exact bitwise
                    passthrough on clean inputs), so every backend runs
                    the same recovered resample with the same key: RNG is
                    consumed branch-independently and the outputs are
                    finite whatever was fed in.

The recorder mirrors the §15 telemetry discipline: enabling it is a
Python-static decision (``record_resilience_events``), so the structural
jaxpr gates (single-launch, pass 6, pass 7) never see the callback
unless a test asked for evidence.
"""

from __future__ import annotations

import dataclasses
import difflib
from contextlib import contextmanager
from typing import Any, Dict, Optional, Tuple

import numpy as np

#: The spec-axis vocabulary, validated eagerly by every spec __post_init__.
GUARD_POLICIES = ("off", "flag", "recover")


def check_guard_policy(value, who: str) -> None:
    """Eager spec validation (same UX as the backend/plane-dtype checks)."""
    if value not in GUARD_POLICIES:
        hint = difflib.get_close_matches(str(value), GUARD_POLICIES, n=1)
        did_you_mean = f" — did you mean {hint[0]!r}?" if hint else ""
        raise ValueError(
            f"{who}.guard must be one of {list(GUARD_POLICIES)}; "
            f"got {value!r}{did_you_mean}"
        )


@dataclasses.dataclass(frozen=True)
class ResilienceEvent:
    """One structured resilience occurrence for the JSONL flight recorder.

    ``kind`` is the taxonomy key: ``guard_degenerate`` (a collapsed bank
    hit a guarded entry), ``backend_demotion`` (the fallback ladder moved
    down a rung), ``fault_injected`` (the chaos harness seeded a fault).
    """

    kind: str
    family: str = ""
    backend: str = ""
    entry: str = ""
    policy: str = ""
    detail: Tuple[Tuple[str, Any], ...] = ()

    def as_dict(self) -> Dict[str, Any]:
        d = {
            "kind": self.kind,
            "family": self.family,
            "backend": self.backend,
            "entry": self.entry,
            "policy": self.policy,
        }
        d.update(dict(self.detail))
        return d


# Active recorders, LIFO.  A recorder is anything with ``.emit(event,
# **fields)`` (the obs JsonlSink) or ``.append(dict)`` (a plain list in
# tests).  Module-level, not a contextvar: trace-time staticness is the
# point — the flag is read when the consumer is TRACED, like telemetry=.
_RECORDERS: list = []


@contextmanager
def record_resilience_events(recorder):
    """Enable resilience-event emission for the dynamic extent.  Consumers
    traced inside this context stage a ``jax.debug.callback`` per guarded
    entry; consumers traced outside it compile the exact unguarded
    program."""
    _RECORDERS.append(recorder)
    try:
        yield recorder
    finally:
        _RECORDERS.remove(recorder)


def guard_events_enabled() -> bool:
    return bool(_RECORDERS)


def emit_event(event: ResilienceEvent) -> None:
    """Deliver one event to every active recorder (host-side)."""
    payload = event.as_dict()
    for rec in list(_RECORDERS):
        emit = getattr(rec, "emit", None)
        if emit is not None:
            fields = dict(payload)
            emit(fields.pop("kind"), **fields)
        else:
            rec.append(payload)


def maybe_emit_guard_event(
    family: str, backend: str, entry: str, policy: str, degenerate
) -> None:
    """Stage the guard's flight-recorder evidence, trace-time statically.

    No-op (zero jaxpr equations) unless a recorder is active when the
    guarded entry is traced.  When active, a ``jax.debug.callback``
    inspects the degenerate flag at run time and emits one
    ``guard_degenerate`` event per call that actually saw a collapsed
    bank — clean steps stay silent."""
    if not _RECORDERS:
        return
    import jax

    def _cb(deg):
        deg = np.asarray(deg)
        count = int(deg.sum()) if deg.ndim else int(bool(deg))
        if count:
            emit_event(ResilienceEvent(
                kind="guard_degenerate", family=family, backend=backend,
                entry=entry, policy=policy,
                detail=(("degenerate_rows", count),
                        ("bank_rows", int(deg.size))),
            ))

    jax.debug.callback(_cb, degenerate)


def classify_step_stats(stats, n: int) -> Dict[str, bool]:
    """Host-side degeneracy classification of one concrete ``StepStats``
    record — the three §16 collapse signatures the guard watches:

      * ``degenerate``   — non-finite bank (all-``-inf``/nan/±inf);
      * ``ess_floor``    — ESS at its 1/N floor (mass on one particle);
      * ``single_survivor`` — the ancestor vector kept one lineage.
    """
    ess_norm = float(np.asarray(stats.ess_norm))
    survivors = int(np.asarray(stats.survivors))
    degenerate = bool(np.asarray(stats.degenerate))
    return {
        "degenerate": degenerate,
        "ess_floor": ess_norm <= (1.0 + 1e-6) / n,
        "single_survivor": survivors <= 1,
        "any": degenerate or ess_norm <= (1.0 + 1e-6) / n or survivors <= 1,
    }


def demotion_event(family: str, from_backend: str, to_backend: Optional[str],
                   error: BaseException) -> ResilienceEvent:
    """The fallback ladder's per-rung evidence (``backend_demotion``)."""
    return ResilienceEvent(
        kind="backend_demotion", family=family, backend=from_backend,
        entry="build",
        detail=(("to_backend", to_backend or ""),
                ("error_type", type(error).__name__),
                ("error", str(error)[:500])),
    )
