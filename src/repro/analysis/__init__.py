"""Static kernel-contract auditor (DESIGN.md §13).

Traces every registered resampler entry point — and every stack consumer —
to a jaxpr and checks the counted invariants the repo's speed argument
rests on: launch budgets, forbidden host-side ``cond``/gather, RNG
discipline, static VMEM footprints and the paper's §2.4 transaction
counts.  CLI: ``python -m repro.analysis --check``.
"""

from repro.analysis.consumers import audit_consumers, auto_reference_rng
from repro.analysis.contracts import (
    CellReport,
    Contract,
    Waiver,
    audit_jaxpr,
    audit_matrix,
    trace_cell,
)
from repro.analysis.guards import (
    audit_guard_cell,
    audit_guards,
    compare_guard_traces,
)
from repro.analysis.report import build_report, summarise, transaction_report
from repro.analysis.rng import rng_findings
from repro.analysis.vmem import kernel_footprints, vmem_findings
from repro.analysis.walker import (
    Finding,
    ancestor_roundtrips,
    count_pallas_calls,
    count_primitive,
    iter_eqns,
    primitive_census,
)

__all__ = [
    "CellReport",
    "Contract",
    "Finding",
    "Waiver",
    "ancestor_roundtrips",
    "audit_consumers",
    "audit_guard_cell",
    "audit_guards",
    "audit_jaxpr",
    "audit_matrix",
    "auto_reference_rng",
    "compare_guard_traces",
    "build_report",
    "count_pallas_calls",
    "count_primitive",
    "iter_eqns",
    "kernel_footprints",
    "primitive_census",
    "rng_findings",
    "summarise",
    "trace_cell",
    "transaction_report",
    "vmem_findings",
]