"""Annealed SMC sampling with analytic logZ ground truth (DESIGN.md §10).

The paper's AIS workload end-to-end: anneal N particles from a broad
Gaussian base to each target family in ``repro.ais.targets``, resampling
with a chosen ``ResamplerSpec``, and compare the estimated log-normalising
constant against the closed form — the first workload in the repo where
resampler quality is scored against an exact answer.

    PYTHONPATH=src python examples/ais_sampler.py [--particles 4096]
    PYTHONPATH=src python examples/ais_sampler.py --schedule adaptive --move mala

``--bank S`` instead runs a SCENARIO BANK (DESIGN.md §4): S differently
parameterised Gaussian posteriors annealed side by side in one jitted
scan — a single batched resampler launch per temperature — with per-row
analytic logZ.

    PYTHONPATH=src python examples/ais_sampler.py --bank 8
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ais import (
    SMCSamplerConfig,
    banana,
    correlated_gaussian,
    gaussian_family,
    gaussian_mixture,
    gaussian_theta,
    isotropic_gaussian,
    logistic_regression,
    run_smc_sampler,
    run_smc_sampler_bank,
)


def run_bank_demo(args):
    fam = gaussian_family(dim=2)
    scenarios = [
        gaussian_theta(mean=0.5 * s, sigma=0.75 + 0.25 * s) for s in range(args.bank)
    ]
    thetas = jax.tree.map(lambda *xs: jnp.stack(xs), *scenarios)
    cfg = SMCSamplerConfig(
        num_particles=args.particles, num_temps=args.temps,
        resampler=args.resampler, schedule=args.schedule, move=args.move,
    )
    key = jax.random.PRNGKey(args.seed)

    bank = jax.jit(lambda k: run_smc_sampler_bank(k, fam, cfg, thetas=thetas))
    jax.block_until_ready(bank(key))  # compile
    t0 = time.perf_counter()
    out = jax.block_until_ready(bank(key))
    t_bank = time.perf_counter() - t0

    print(f"Gaussian-family bank: S={args.bank}, {args.particles} particles, "
          f"{args.temps} temps, {args.resampler} / {args.schedule} / {args.move}\n")
    print(f"{'scenario':>8s} {'sigma':>6s} {'logZ est':>10s} {'logZ true':>10s} "
          f"{'|err|':>8s} {'resamples':>10s}")
    for s, th in enumerate(scenarios):
        true = float(fam.log_z_fn(th))
        est = float(out["log_z"][s])
        print(f"{s:8d} {float(th['sigma']):6.2f} {est:10.4f} {true:10.4f} "
              f"{abs(est - true):8.4f} {int(out['num_resamples'][s]):10d}")
    print(f"\nbank wall: {t_bank * 1e3:.1f} ms "
          f"(one batched resampler launch per temperature)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--particles", type=int, default=1 << 12)
    ap.add_argument("--temps", type=int, default=24)
    ap.add_argument("--resampler", default="megopolis")
    ap.add_argument("--schedule", default="geometric", choices=("geometric", "adaptive"))
    ap.add_argument("--move", default="rwm", choices=("rwm", "mala"))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--bank", type=int, default=0,
                    help="run S Gaussian scenarios as one batched sampler bank")
    args = ap.parse_args()
    if args.bank:
        return run_bank_demo(args)

    cfg = SMCSamplerConfig(
        num_particles=args.particles, num_temps=args.temps,
        resampler=args.resampler, schedule=args.schedule, move=args.move,
    )
    key = jax.random.PRNGKey(args.seed)
    print(f"annealed SMC: {args.particles} particles, {args.temps} temps, "
          f"{args.resampler} / {args.schedule} / {args.move}\n")
    print(f"{'target':24s} {'logZ est':>10s} {'logZ true':>10s} {'|err|':>8s} "
          f"{'resamples':>10s} {'accept':>7s} {'wall':>8s}")
    for target in (isotropic_gaussian(), correlated_gaussian(), gaussian_mixture(),
                   banana(), logistic_regression()):
        run = jax.jit(lambda k, t=target: run_smc_sampler(k, t, cfg))
        jax.block_until_ready(run(key))  # compile
        t0 = time.perf_counter()
        out = jax.block_until_ready(run(key))
        wall = time.perf_counter() - t0
        est = float(out["log_z"])
        true_s = f"{target.log_z:10.4f}" if target.log_z is not None else "       n/a"
        err_s = (f"{abs(est - target.log_z):8.4f}"
                 if target.log_z is not None else "     n/a")
        print(f"{target.name:24s} {est:10.4f} {true_s} {err_s} "
              f"{int(out['num_resamples']):10d} "
              f"{float(np.mean(np.asarray(out['accept']))):7.2f} {wall * 1e3:6.1f}ms")


if __name__ == "__main__":
    main()
