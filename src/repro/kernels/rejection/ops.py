"""Public wrappers for the rejection TPU kernel (VMEM-resident baseline)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.resamplers.batched import split_batch_keys
from repro.kernels.common import check_tile_aligned, check_vmem_resident, key_to_seed
from repro.kernels.rejection.rejection import (
    LANES,
    rejection_pallas,
    rejection_pallas_batch,
)


def _check(n: int, who: str):
    # Same residency cap as the Metropolis strawman (random full-array gather).
    check_tile_aligned(n, who)
    check_vmem_resident(n, who)


def rejection_tpu(
    key: jax.Array,
    weights: jnp.ndarray,
    *,
    max_iters: int = 1024,
    interpret: bool = True,
) -> jnp.ndarray:
    n = weights.shape[0]
    _check(n, "rejection_tpu")
    seed = key_to_seed(key).reshape(1)
    w2 = weights.reshape(n // LANES, LANES)
    k2 = rejection_pallas(w2, seed, max_iters=max_iters, interpret=interpret)
    return k2.reshape(n)


def rejection_tpu_batch(
    key: jax.Array,
    weights: jnp.ndarray,
    *,
    max_iters: int = 1024,
    interpret: bool = True,
) -> jnp.ndarray:
    """One ``[B, R, 128]`` launch; row b == ``rejection_tpu(split(key,B)[b],
    weights[b])`` bit-exactly (the §4 split-key contract, held on-kernel)."""
    if weights.ndim != 2:
        raise ValueError(f"rejection_tpu_batch expects weights[B, N]; got {weights.shape}")
    bsz, n = weights.shape
    _check(n, "rejection_tpu_batch")
    seeds = key_to_seed(split_batch_keys(key, bsz))
    w3 = weights.reshape(bsz, n // LANES, LANES)
    k3 = rejection_pallas_batch(w3, seeds, max_iters=max_iters, interpret=interpret)
    return k3.reshape(bsz, n)
