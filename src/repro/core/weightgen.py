"""Weight-sequence generators for the paper's experimental regime (§5).

Method 1 (Murray et al., paper eq. 12): Gaussian-likelihood weights
``w = exp(-(x - y)^2 / 2) / sqrt(2*pi)`` with ``x ~ N(0,1)``; increasing
``y`` concentrates weight on few particles (simulated degeneracy).

Method 2 (Dülger et al., paper eq. 13): Gamma(alpha, beta=1) samples.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

GAUSSIAN_Y_GRID = (0.0, 1.0, 2.0, 3.0, 4.0)
GAMMA_ALPHA_GRID = (0.5, 2.0, 3.0, 10.0, 50.0)


def gaussian_weights(key: jax.Array, n: int, y: float, dtype=jnp.float32) -> jnp.ndarray:
    x = jax.random.normal(key, (n,), dtype)
    return jnp.exp(-0.5 * (x - y) ** 2) / jnp.sqrt(2.0 * jnp.pi).astype(dtype)


def gamma_weights(
    key: jax.Array, n: int, alpha: float, beta: float = 1.0, dtype=jnp.float32
) -> jnp.ndarray:
    return jax.random.gamma(key, alpha, (n,), dtype) / beta
