"""SMC particle decoding of a language model — the paper's resampler as a
first-class serving feature (DESIGN.md §5).

Decodes a batch of particles from a (randomly initialised, smoke-scale)
model of a chosen architecture, with ESS-triggered Megopolis resampling of
the hypothesis population and ancestor-gathered KV/SSM caches.  Works for
every assigned arch; SSM archs show the cheap O(state) ancestor gather.

    PYTHONPATH=src python examples/smc_lm_decoding.py --arch zamba2-2.7b
"""

import argparse

from repro.launch.serve import serve_once


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="zamba2-2.7b")
    ap.add_argument("--num-particles", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--resampler", default="megopolis")
    ap.add_argument("--target-temp", type=float, default=0.7)
    args = ap.parse_args()

    out = serve_once(args.arch, smoke=True, num_particles=args.num_particles,
                     new_tokens=args.new_tokens, resampler=args.resampler,
                     target_temp=args.target_temp)
    print(f"arch={args.arch} particles={args.num_particles} "
          f"resampler={args.resampler}")
    print(f"  prefill {out['prefill_s']*1e3:.0f} ms; decode {out['decode_s']*1e3:.0f} ms "
          f"({out['tok_per_s']:.0f} tok/s)")
    print(f"  ESS-triggered resamples: {out['num_resamples']}; "
          f"final ESS {out['final_ess']:.1f}")
    print(f"  best-weight particle tokens: {out['tokens'][0][:16].tolist()} ...")


if __name__ == "__main__":
    main()
