import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# Compile-speed flags for the CPU stand-in backend (1.8x faster, analyses
# unchanged — verified): LLVM expensive passes contribute nothing to the
# lower/compile coherence proof this dry-run exists for.
os.environ["XLA_FLAGS"] += (" --xla_llvm_disable_expensive_passes=true"
                            " --xla_backend_optimization_level=0")

"""Multi-pod dry-run: lower + compile EVERY (arch x shape x mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: 512 placeholder CPU devices stand in for 2 TPU v5e pods
(2 x 16 x 16).  For each cell we:

  1. build the jitted step via ``plan_cell`` (shardings included),
  2. ``.lower(**ShapeDtypeStructs)`` — no allocation,
  3. ``.compile()``  — sharding mismatches / unsupported collectives /
     compile-time OOM surface HERE and are bugs in our system,
  4. print ``memory_analysis()`` (fits-in-HBM proof) and
     ``cost_analysis()`` + parsed collective bytes (roofline §).

Usage::

    python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod] [--out out.json]
    python -m repro.launch.dryrun --all --both-meshes --out experiments/dryrun.json
"""

import argparse
import json
import sys
import time
import traceback


def run_cell(arch_name: str, shape_name: str, *, multi_pod: bool, verbose: bool = True):
    import jax  # noqa: F401  (deferred side-effect: XLA_FLAGS must be set first)

    from repro.configs import SHAPES, get_arch
    from repro.launch import hlo, memmodel
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import plan_cell, _decode_needs_fsdp

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    t0 = time.time()
    plan = plan_cell(arch_name, shape_name, mesh)
    lowered = plan.lower()
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    arch = get_arch(arch_name)
    shape = SHAPES[shape_name]
    tokens = shape.global_batch * (shape.seq_len if plan.kind != "decode" else 1)
    n_active = arch.model.num_active_params()
    mult = 6.0 if plan.kind == "train" else 2.0  # fwd+bwd vs fwd-only
    model_flops = mult * n_active * tokens
    roof = hlo.analyze(compiled, chips=chips, trips=plan.microbatches,
                       model_flops=model_flops)

    per_chip_hbm = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                    + mem.temp_size_in_bytes - mem.alias_size_in_bytes)
    # TPU-projected HBM (memmodel.py): the CPU scheduler is memory-
    # oblivious (remat not honoured), so we report the analytic projection
    # alongside the backend number.
    dp = int(mesh.shape["data"]) * (int(mesh.shape["pod"]) if multi_pod else 1)
    tp = int(mesh.shape["model"])
    if plan.kind == "train":
        proj = memmodel.projected_train_bytes(
            arch.model, global_batch=shape.global_batch, seq=shape.seq_len,
            micro=plan.microbatches, dp=dp, tp=tp,
            moment_bytes=2 if arch.moment_dtype == "bfloat16" else 4)
    else:
        proj = memmodel.projected_serve_bytes(
            arch.model, batch=shape.global_batch, seq=shape.seq_len, dp=dp, tp=tp,
            fsdp=_decode_needs_fsdp(arch.model, mesh), kind=plan.kind)
    rec = {
        "arch": arch_name,
        "shape": shape_name,
        "kind": plan.kind,
        "mesh": "2x16x16(512)" if multi_pod else "16x16(256)",
        "chips": chips,
        "ok": True,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "bytes_per_device": {
            "arguments": mem.argument_size_in_bytes,
            "output": mem.output_size_in_bytes,
            "temp": mem.temp_size_in_bytes,
            "aliased": mem.alias_size_in_bytes,
            "peak_estimate": per_chip_hbm,
        },
        "hbm_projected": proj,
        "collectives": roof.coll_detail,
        "roofline": roof.row(),
        "notes": plan.notes,
    }
    if verbose:
        print(f"[{rec['mesh']}] {arch_name} x {shape_name} ({plan.kind}): "
              f"compile OK in {t_compile:.1f}s; "
              f"peak/device = {per_chip_hbm/2**30:.2f} GiB "
              f"(TPU-projected {proj['total']/2**30:.2f} GiB); "
              f"bottleneck = {roof.bottleneck}; "
              f"roofline_fraction = {roof.roofline_fraction:.3f}")
        print(f"  memory_analysis: args={mem.argument_size_in_bytes/2**30:.2f}GiB "
              f"temp={mem.temp_size_in_bytes/2**30:.2f}GiB "
              f"aliased={mem.alias_size_in_bytes/2**30:.2f}GiB")
        ca = compiled.cost_analysis()
        print(f"  cost_analysis: flops/chip={ca.get('flops', 0):.3e} "
              f"bytes/chip={ca.get('bytes accessed', 0):.3e}")
    return rec


def iter_cells():
    from repro.configs import SHAPES, applicable_shapes, get_arch, list_archs

    cells = []
    for arch_name in list_archs():
        for shape_name in applicable_shapes(get_arch(arch_name)):
            cells.append((arch_name, shape_name))
    # cheap kinds first (decode < prefill < train) so a time-bounded sweep
    # completes the most cells; within a kind, keep arch order
    cost = {"decode": 0, "prefill": 1, "train": 2}
    cells.sort(key=lambda c: cost[SHAPES[c[1]].kind])
    return cells


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", help="write JSON records here")
    ap.add_argument("--resume", action="store_true",
                    help="skip cells already present (ok) in <out>l sidecar")
    args = ap.parse_args(argv)

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    records, failures = [], []
    cells = list(iter_cells()) if args.all else [(args.arch, args.shape)]
    jsonl = (args.out + "l") if args.out else None  # incremental sidecar
    done = set()
    if args.resume and jsonl and os.path.exists(jsonl):
        with open(jsonl) as f:
            for line in f:
                r = json.loads(line)
                if r.get("ok"):
                    done.add((r["arch"], r["shape"], r["mesh"]))
                    records.append(r)
    for mp in meshes:  # single-pod pass completes first (roofline source)
        for arch_name, shape_name in cells:
            mesh_name = "2x16x16(512)" if mp else "16x16(256)"
            if (arch_name, shape_name, mesh_name) in done:
                continue
            try:
                rec = run_cell(arch_name, shape_name, multi_pod=mp)
            except Exception as e:  # a failing cell is a bug — report, keep going
                traceback.print_exc()
                failures.append((arch_name, shape_name, mp, repr(e)))
                rec = {"arch": arch_name, "shape": shape_name,
                       "mesh": "2x16x16(512)" if mp else "16x16(256)",
                       "ok": False, "error": repr(e)}
            records.append(rec)
            if jsonl:
                with open(jsonl, "a") as f:
                    f.write(json.dumps(rec) + "\n")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1)
        print(f"wrote {len(records)} records to {args.out}")
    print(f"\n{sum(1 for r in records if r.get('ok'))}/{len(records)} cells compiled")
    if failures:
        print("FAILURES:")
        for f in failures:
            print("  ", f)
        sys.exit(1)


if __name__ == "__main__":
    main()
