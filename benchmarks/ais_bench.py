"""AIS quality × speed: logZ bias/variance per resampler family × backend.

The sampler workload (DESIGN.md §10) is the first suite where resampler
quality has an ANALYTIC answer: annealed SMC on a closed-form target
estimates logZ, and the estimator's bias/variance over Monte-Carlo
repeats is the quality metric (Murray, Lee & Jacob's framing — resampler
noise shows up directly in the normalising constant).  The repeats run as
ONE sampler bank (`run_smc_sampler_bank`, the §4 scenario axis), so each
(family, backend) cell is a single jitted scan with one batched resample
launch per temperature.

    PYTHONPATH=src python -m benchmarks.ais_bench [--quick] [--backend pallas_interpret]

Writes ``ais_bench.csv`` + ``BENCH_ais.json`` into ``BENCH_OUT`` (default
benchmarks/out/) — `benchmarks/run.py --json` folds the JSON's logZ stats
into the per-run trajectory file (EXPERIMENTS.md §AIS).
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import numpy as np

from benchmarks.common import ensure_out, print_table, time_fn, write_csv
from repro.ais import SMCSamplerConfig, gaussian_mixture, isotropic_gaussian, run_smc_sampler_bank
from repro.core.spec import spec_for_backend

FAMILIES = ("megopolis", "metropolis", "rejection", "systematic")


def bench_one(name: str, backend: str, target, repeats: int, particles: int,
              temps: int, num_iters: int, timing_repeats: int) -> dict:
    cfg = SMCSamplerConfig(num_particles=particles, num_temps=temps,
                           resampler=spec_for_backend(name, backend,
                                                      num_iters=num_iters))
    key = jax.random.PRNGKey(0)
    bank = jax.jit(
        lambda k: run_smc_sampler_bank(k, target, cfg, num_scenarios=repeats)
    )
    wall = time_fn(bank, key, warmup=1, repeats=timing_repeats)
    out = bank(key)
    logz = np.asarray(out["log_z"], np.float64)
    bias = float(np.mean(logz) - target.log_z)
    # ddof=1 std is undefined (NaN) for a single repeat; keep the JSON
    # strictly parseable under --repeats 1.
    std = float(np.std(logz, ddof=1)) if logz.size > 1 else 0.0
    return {
        "resampler": name,
        "backend": backend,
        "target": target.name,
        "repeats": repeats,
        "particles": particles,
        "temps": temps,
        "wall_s": wall,
        "wall_per_run_s": wall / repeats,
        "logz_true": float(target.log_z),
        "logz_mean": float(np.mean(logz)),
        "logz_bias": bias,
        "logz_std": std,
        "logz_rmse": float(np.sqrt(np.mean((logz - target.log_z) ** 2))),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="small sweep for CI smoke")
    ap.add_argument("--full", action="store_true", help="paper-scale sweep")
    ap.add_argument("--backend", default="reference",
                    choices=("reference", "xla", "pallas_interpret", "pallas"),
                    help="resampler backend for the whole sweep")
    ap.add_argument("--repeats", type=int, default=0, help="override MC repeats")
    ap.add_argument("--iters", type=int, default=16)
    args = ap.parse_args(argv)

    if args.full:
        particles, temps, repeats = 4096, 24, 32
        families = FAMILIES
    elif args.quick:
        particles, temps, repeats = 1024, 10, 8
        families = ("megopolis", "systematic")
    else:
        particles, temps, repeats = 2048, 16, 16
        families = FAMILIES
    if args.backend in ("pallas", "pallas_interpret"):
        # kernel tile contract: N % 1024 == 0 (already true above); keep the
        # interpret-mode sweep tractable
        repeats = min(repeats, 8)
    repeats = args.repeats or repeats
    timing_repeats = 2 if args.backend in ("pallas", "pallas_interpret") else 5

    targets = [isotropic_gaussian(dim=2), gaussian_mixture()]
    if args.quick:
        targets = targets[:1]

    rows = []
    for target in targets:
        for name in families:
            rows.append(bench_one(name, args.backend, target, repeats,
                                  particles, temps, args.iters, timing_repeats))
            print_table(rows[-1:])

    csv_path = write_csv("ais_bench.csv", rows)
    json_path = os.path.join(ensure_out(), "BENCH_ais.json")
    with open(json_path, "w") as f:
        json.dump({"config": {"particles": particles, "temps": temps,
                              "repeats": repeats, "num_iters": args.iters,
                              "backend": args.backend},
                   "rows": rows}, f, indent=2)
    print(f"\nwrote {csv_path} and {json_path}")
    worst = max(rows, key=lambda r: abs(r["logz_bias"]))
    print(f"largest |logZ bias|: {abs(worst['logz_bias']):.4f} "
          f"({worst['resampler']} on {worst['target']})")


if __name__ == "__main__":
    main()
