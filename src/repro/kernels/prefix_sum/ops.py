"""Public wrappers: prefix-sum scan + the kernel-lane prefix-sum resamplers.

``prefix_sum_tpu`` is the raw 1-D inclusive scan.  ``prefix_resample_tpu``
composes the family's two memory-bound stages — block-scan CDF, then
coalesced binary search (``search.py``) — into the five registry kinds
(multinomial / systematic / improved_systematic / stratified / residual).

Randomness placement: the family's uniforms are drawn OUTSIDE the kernels
with ``jax.random``, by the *identical formulas* as the reference
implementations in ``repro.core.resamplers.prefix_sum`` (same key usage,
same strata arithmetic).  The kernels accelerate the O(N) memory-bound
stages; the draw is O(N) compute-bound and already fused by XLA.  The
kernel lane therefore differs from the reference lane only through the
tiled scan's f32 rounding — and is bit-exact against the ``ref.py`` oracle,
which replays that tiled scan.

``improved_systematic`` (paper Alg. 8) provably equals ``systematic``'s
searchsorted form (asserted for the reference pair in the test suite); the
bidirectional walk is a GPU warp-access pattern with no TPU analogue, so
its kernel lane IS the systematic search kernel with the same draws.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.common import (
    TILE,
    check_state_resident,
    check_vmem_resident,
    compress_plane,
    pack_state_planes,
    state_dim_of,
    state_itemsize,
    unpack_state_planes,
)
from repro.kernels.prefix_sum.prefix_sum import LANES, prefix_sum_pallas
from repro.kernels.prefix_sum.search import (
    residual_select_gather_pallas,
    searchsorted_gather_pallas,
    searchsorted_pallas,
)
from repro.kernels.prefix_sum.step import prefix_pallas_step

PREFIX_KINDS = (
    "multinomial",
    "systematic",
    "improved_systematic",
    "stratified",
    "residual",
)


def prefix_sum_tpu(x: jnp.ndarray, *, interpret: bool = True) -> jnp.ndarray:
    n = x.shape[0]
    if n % TILE != 0:
        raise ValueError(f"prefix_sum_tpu requires N % {TILE} == 0; got {n}")
    y2 = prefix_sum_pallas(x.reshape(n // LANES, LANES), interpret=interpret)
    return y2.reshape(n)


def searchsorted_tpu(
    cdf: jnp.ndarray, u: jnp.ndarray, *, side: str = "left", interpret: bool = True
) -> jnp.ndarray:
    n = cdf.shape[0]
    if n % TILE != 0 or u.shape != (n,):
        raise ValueError(
            f"searchsorted_tpu requires matching N % {TILE} == 0 shapes; "
            f"got cdf {cdf.shape}, u {u.shape}"
        )
    check_vmem_resident(
        n, "searchsorted_tpu", what="CDF",
        remedy="Use backend='reference'/'xla' for this family at larger N.",
    )
    k2 = searchsorted_pallas(
        cdf.reshape(n // LANES, LANES), u.reshape(n // LANES, LANES),
        side=side, interpret=interpret,
    )
    return k2.reshape(n)


def kind_draws(key: jax.Array, n: int, total, dtype, kind: str):
    """The family's uniform draws + search side, shared verbatim with the
    ``ref.py`` oracle.  Formulas match ``repro.core.resamplers.prefix_sum``
    exactly (same key usage, same strata arithmetic); ``total`` is the
    CDF's last element from whichever scan produced it."""
    if kind == "multinomial":
        return jax.random.uniform(key, (n,), dtype) * total, "right"
    if kind in ("systematic", "improved_systematic"):
        u0 = jax.random.uniform(key, (), dtype)
        return (jnp.arange(n, dtype=dtype) + u0) * (total / n), "left"
    if kind == "stratified":
        u = jax.random.uniform(key, (n,), dtype)
        return (jnp.arange(n, dtype=dtype) + u) * (total / n), "left"
    raise ValueError(f"no independent draw formula for kind {kind!r}")


def prefix_resample_tpu(
    key: jax.Array,
    weights: jnp.ndarray,
    kind: str = "systematic",
    *,
    interpret: bool = True,
    plane_dtype="float32",
) -> jnp.ndarray:
    """Resample via the scan + search kernels; returns int32[N] ancestors."""
    if kind not in PREFIX_KINDS:
        raise ValueError(f"kind must be one of {PREFIX_KINDS}; got {kind!r}")
    n = weights.shape[0]
    if n % TILE != 0:
        raise ValueError(
            f"prefix_resample_tpu requires N % {TILE} == 0 (one f32 VMEM tile); "
            f"got N={n}. Use the reference backend for unaligned N."
        )
    # The search stage keeps the CDF VMEM-resident (DESIGN.md §2) — check
    # here so the clear error comes before three scan launches.
    check_vmem_resident(
        n, "prefix_resample_tpu", what="CDF",
        remedy="Use backend='reference'/'xla' for this family at larger N.",
    )
    if kind == "residual":
        return _residual_tpu(key, weights, interpret=interpret,
                             plane_dtype=plane_dtype)
    # Only the scan INPUT travels compressed (DESIGN.md §14); the CDF the
    # scan emits — and hence every bisection boundary — is always f32.
    c = prefix_sum_tpu(compress_plane(weights, plane_dtype), interpret=interpret)
    u, side = kind_draws(key, n, c[-1], weights.dtype, kind)
    return searchsorted_tpu(c, u, side=side, interpret=interpret)


def prefix_resample_tpu_apply(
    key: jax.Array,
    weights: jnp.ndarray,
    particles: jnp.ndarray,
    kind: str = "systematic",
    *,
    interpret: bool = True,
    plane_dtype="float32",
):
    """Fused resample+gather for the prefix-sum family (DESIGN.md §11): the
    final search kernel also copies each slot's ancestor state from the
    resident plane stack — indices identical to ``prefix_resample_tpu``.
    ``particles``: ``[N]`` or ``[N, ...]``.  Returns ``(particles',
    ancestors)``."""
    if kind not in PREFIX_KINDS:
        raise ValueError(f"kind must be one of {PREFIX_KINDS}; got {kind!r}")
    n = weights.shape[0]
    if n % TILE != 0:
        raise ValueError(
            f"prefix_resample_tpu_apply requires N % {TILE} == 0 (one f32 VMEM "
            f"tile); got N={n}. Use the reference backend for unaligned N."
        )
    check_vmem_resident(
        n, "prefix_resample_tpu_apply", what="CDF",
        remedy="Use backend='reference'/'xla' for this family at larger N.",
    )
    check_state_resident(
        n, state_dim_of(particles, n, "prefix_resample_tpu_apply"),
        "prefix_resample_tpu_apply",
        itemsize=state_itemsize(particles, plane_dtype),
    )
    planes, state_shape = pack_state_planes(particles)
    planes = compress_plane(planes, plane_dtype)
    if kind == "residual":
        k2, out = _residual_tpu_fused(key, weights, planes, interpret=interpret,
                                      plane_dtype=plane_dtype)
    else:
        c = prefix_sum_tpu(compress_plane(weights, plane_dtype), interpret=interpret)
        u, side = kind_draws(key, n, c[-1], weights.dtype, kind)
        k2, out = searchsorted_gather_pallas(
            c.reshape(n // LANES, LANES), u.reshape(n // LANES, LANES), planes,
            side=side, interpret=interpret,
        )
    out = out.astype(particles.dtype)
    return unpack_state_planes(out, state_shape), k2.reshape(n)


def prefix_resample_tpu_step(
    key: jax.Array,
    log_weights: jnp.ndarray,
    particles: jnp.ndarray,
    ess_threshold,
    kind: str = "systematic",
    *,
    interpret: bool = True,
    plane_dtype="float32",
):
    """Fused SMC step for the prefix-sum family (DESIGN.md §12): normalise →
    ESS → conditional scan+search+gather in ONE launch — the family's
    biggest launch-count win (the composed residual path alone is five).
    The resample branch is bit-identical to ``prefix_resample_tpu_apply(key,
    normalise_log_weights(log_weights), particles, kind)``: the key-only
    draw bases below replicate ``kind_draws``'s key usage exactly, and the
    CDF-dependent scale is applied in-kernel over a bit-identical in-kernel
    scan.  Returns ``(particles', ancestors, stats f32[4])`` with ``stats``
    = (ess_norm, log_evidence_incr, resampled, max_weight) — DESIGN.md §15."""
    if kind not in PREFIX_KINDS:
        raise ValueError(f"kind must be one of {PREFIX_KINDS}; got {kind!r}")
    n = log_weights.shape[0]
    if n % TILE != 0:
        raise ValueError(
            f"prefix_resample_tpu_step requires N % {TILE} == 0 (one f32 VMEM "
            f"tile); got N={n}. Use the reference backend for unaligned N."
        )
    check_vmem_resident(
        n, "prefix_resample_tpu_step", what="CDF",
        remedy="Compose Resampler.step on the reference/xla backend above this size.",
    )
    check_state_resident(
        n, state_dim_of(particles, n, "prefix_resample_tpu_step"),
        "prefix_resample_tpu_step",
        itemsize=state_itemsize(particles, plane_dtype),
    )
    dtype = log_weights.dtype
    # Key-only halves of kind_draws, with IDENTICAL key usage per kind.
    if kind in ("systematic", "improved_systematic"):
        u0 = jax.random.uniform(key, (), dtype).reshape(1)
        ubase = jnp.zeros((n,), dtype)
    else:  # multinomial / stratified / residual: uniform(key, (n,))
        u0 = jnp.zeros((1,), dtype)
        ubase = jax.random.uniform(key, (n,), dtype)
    thr = jnp.asarray(ess_threshold, jnp.float32).reshape(1)
    planes, state_shape = pack_state_planes(particles)
    planes = compress_plane(planes, plane_dtype)
    lw2 = compress_plane(log_weights.reshape(n // LANES, LANES), plane_dtype)
    k2, out, stats = prefix_pallas_step(
        lw2, planes,
        ubase.reshape(n // LANES, LANES), u0, thr,
        kind=kind, interpret=interpret,
    )
    out = out.astype(particles.dtype)
    return unpack_state_planes(out, state_shape), k2.reshape(n), stats


def _residual_tpu_fused(key: jax.Array, weights: jnp.ndarray, planes, *,
                        interpret, plane_dtype="float32"):
    """The fused form of ``_residual_tpu``: same three block-scans, then ONE
    kernel runs both searches, the slot select and the state gather.  Only
    the FIRST scan's input compresses; counts and residual CDFs are derived
    f32 quantities (DESIGN.md §14)."""
    n = weights.shape[0]
    total = prefix_sum_tpu(compress_plane(weights, plane_dtype),
                           interpret=interpret)[-1]
    w = weights / total
    counts = jnp.floor(n * w)
    n_det = jnp.sum(counts).astype(jnp.int32).reshape(1)
    resid = n * w - counts

    cc = prefix_sum_tpu(counts, interpret=interpret)
    c = prefix_sum_tpu(resid, interpret=interpret)
    u = jax.random.uniform(key, (n,), weights.dtype) * c[-1]
    return residual_select_gather_pallas(
        cc.reshape(n // LANES, LANES), c.reshape(n // LANES, LANES),
        u.reshape(n // LANES, LANES), n_det, planes, interpret=interpret,
    )


def _residual_tpu(key: jax.Array, weights: jnp.ndarray, *, interpret: bool,
                  plane_dtype="float32") -> jnp.ndarray:
    """Residual resampling on the kernel lane (mirrors the reference's
    "deterministic offsets into the cumsum" form, Alg. of §6.5 extras).

    All three O(N) scans (normalising total, deterministic-copy counts,
    residual CDF) run on the block-scan kernel; both searches run on the
    search kernel.  Counts are scanned as f32 — exact for N <= 2^24."""
    n = weights.shape[0]
    total = prefix_sum_tpu(compress_plane(weights, plane_dtype),
                           interpret=interpret)[-1]
    w = weights / total
    counts = jnp.floor(n * w)  # f32 integer values
    n_det = jnp.sum(counts).astype(jnp.int32)
    resid = n * w - counts

    cc = prefix_sum_tpu(counts, interpret=interpret)
    c = prefix_sum_tpu(resid, interpret=interpret)
    slots = jnp.arange(n, dtype=jnp.int32)
    det = searchsorted_tpu(cc, slots.astype(weights.dtype), side="right", interpret=interpret)
    u = jax.random.uniform(key, (n,), weights.dtype) * c[-1]
    rnd = searchsorted_tpu(c, u, side="right", interpret=interpret)
    return jnp.where(slots < n_det, det, rnd)
