"""Backend fallback chains — the §16 demotion ladder.

``build_with_fallback(spec)`` tries the spec's own backend first, then
demotes rung by rung down the ladder (default ``pallas →
pallas_interpret → xla → reference``), emitting one structured
``backend_demotion`` ``ResilienceEvent`` per failed rung, and raises
``BackendUnavailable`` — carrying every per-rung cause — only when the
whole ladder is exhausted.  Demotion is strictly downward: a spec built
for ``xla`` never silently promotes to a kernel backend.

Failures are classified into the typed taxonomy before they travel:
VMEM-budget rejections become ``VmemBudgetExceeded``, Mosaic/pallas
lowering and trace failures become ``KernelLoweringError``; anything
else is wrapped as-is in the exhaustion error.  The optional concrete
PROBE (a tiny uniform-bank resample) catches backends that construct
fine but die at first launch — the common shape of "pallas on a host
without a TPU".
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.resilience.errors import (
    BackendUnavailable,
    KernelLoweringError,
    ResilienceError,
    VmemBudgetExceeded,
)
from repro.resilience.guards import demotion_event, emit_event

#: The demotion order — fastest surface first, pure-jnp reference last.
DEFAULT_LADDER = ("pallas", "pallas_interpret", "xla", "reference")

#: Probe geometry: one kernel segment's worth of lanes, so every family's
#: tile-fixed pallas kernel accepts the bank (KERNEL_SEGMENT = 1024).
_PROBE_N = 2048

_LOWERING_MARKERS = (
    "mosaic", "pallas", "lowering", "unimplemented", "not implemented",
    "unsupported", "tpu",
)
_VMEM_MARKERS = ("vmem", "scratch", "budget")


def classify_backend_error(error: BaseException) -> ResilienceError:
    """Map a raw build/probe failure onto the §16 typed taxonomy.

    Already-typed errors pass through; VMEM-budget messages become
    ``VmemBudgetExceeded``; lowering/trace-surface failures become
    ``KernelLoweringError``; anything else is wrapped as
    ``KernelLoweringError`` too — from the ladder's point of view every
    non-resource failure is "this rung cannot lower/run here".
    """
    if isinstance(error, ResilienceError):
        return error
    msg = str(error)
    low = msg.lower()
    if any(m in low for m in _VMEM_MARKERS):
        wrapped = VmemBudgetExceeded(msg)
    elif any(m in low for m in _LOWERING_MARKERS):
        wrapped = KernelLoweringError(msg)
    else:
        wrapped = KernelLoweringError(f"{type(error).__name__}: {msg}")
    wrapped.__cause__ = error
    return wrapped


def _ladder_for(backend: str, ladder: Optional[Sequence[str]]) -> Tuple[str, ...]:
    """The rungs to try: the spec's backend, then every DEFAULT_LADDER rung
    strictly below it (or the caller's explicit ladder, verbatim)."""
    if ladder is not None:
        rungs = tuple(ladder)
        if not rungs:
            raise ValueError("build_with_fallback: ladder must be non-empty")
        return rungs
    if backend not in DEFAULT_LADDER:
        return (backend,)
    return DEFAULT_LADDER[DEFAULT_LADDER.index(backend):]


def _probe(resampler) -> None:
    """One tiny concrete resample — forces compilation/launch on the rung.

    Uniform weights over ``_PROBE_N`` lanes with a fixed key: clean input
    (never trips the degeneracy guard), deterministic, and block-until-
    ready so launch-time failures surface here rather than at first use.
    """
    key = jax.random.PRNGKey(0)
    w = jnp.full((_PROBE_N,), 1.0 / _PROBE_N, jnp.float32)
    jax.block_until_ready(resampler(key, w))


def build_with_fallback(spec, *, ladder=None, recorder=None, probe: bool = True):
    """Build ``spec`` with backend demotion (DESIGN.md §16).

    Returns the first rung's ``Resampler`` that builds (and, with
    ``probe=True``, survives a concrete launch).  Every failed rung emits
    a ``backend_demotion`` event to ``recorder`` (``.emit``/``.append``
    duck-typed, like the guard recorder) AND to any active
    ``record_resilience_events`` context.  Exhaustion raises
    ``BackendUnavailable`` whose ``.failures`` holds each
    ``(backend, typed_error)`` pair in demotion order.
    """
    rungs = _ladder_for(getattr(spec, "backend", "reference"), ladder)
    failures = []
    for i, rung in enumerate(rungs):
        nxt = rungs[i + 1] if i + 1 < len(rungs) else None
        try:
            candidate = spec if getattr(spec, "backend", None) == rung \
                else spec.replace(backend=rung)
            resampler = candidate.build()
            if probe:
                _probe(resampler)
            return resampler
        except Exception as err:  # noqa: BLE001 — classified + re-raised typed
            typed = classify_backend_error(err)
            failures.append((rung, typed))
            event = demotion_event(spec.name, rung, nxt, typed)
            if recorder is not None:
                emit_fn = getattr(recorder, "emit", None)
                if emit_fn is not None:
                    fields = event.as_dict()
                    emit_fn(fields.pop("kind"), **fields)
                else:
                    recorder.append(event.as_dict())
            emit_event(event)
    lines = "; ".join(f"{b}: {type(e).__name__}: {e}" for b, e in failures)
    raise BackendUnavailable(
        f"{spec.name}: every backend rung failed ({lines})",
        failures=tuple(failures),
    )
