"""Observability (DESIGN.md §15): StepStats, Telemetry, spans, sink.

Contract under test:

  1. **survivor count** — ``unique_ancestor_count`` on hand-built ancestor
     vectors (identity → N, collapse → 1, known duplicates), batched rows,
     and the no-scatter discipline the §13 census pass depends on.
  2. **StepStats plumbing** — ``stats_from_vector`` unpacks the kernel's
     f32[..., 4] SMEM row (single and batched) into the named record.
  3. **oracle parity** — the fused step's in-kernel stats equal the
     ``core.metrics`` host composition bitwise, both branches of the
     trigger, on the kernel lane.
  4. **telemetry neutrality** — every consumer (``run_filter``/``_bank``,
     ``run_smc_sampler``/``_bank``, ``smc_decode``) returns bit-identical
     primary outputs with telemetry on vs off, and the record's layout
     matches the estimate layout ([T] single, [S, T] banks).
  5. **with_ess shim** — the deprecated diagnostic still returns the old
     ``(estimates, ess_norm)`` pair bit-identically, warns, and refuses to
     combine with ``telemetry=True``.
  6. **spans + sink** — disabled spans are identity at trace time (the
     structural gates depend on it); the JSONL sink round-trips events in
     order and stringifies rather than drops odd values.
"""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.metrics import (
    effective_sample_size,
    log_mean_weight,
    max_normalised_weight,
    unique_ancestor_count,
)
from repro.core.spec import spec_for_backend
from repro.obs import (
    JsonlSink,
    StepStats,
    Telemetry,
    dispatch_span,
    enable_tracing,
    span,
    stats_from_vector,
    tracing_enabled,
)
from repro.pf import ParticleFilter, run_filter, run_filter_bank, ungm

N = 2048  # whole VMEM tiles — the pallas lanes require N % 1024 == 0


def _tree_equal(got, want):
    got_l, want_l = jax.tree.leaves(got), jax.tree.leaves(want)
    assert len(got_l) == len(want_l)
    for g, w in zip(got_l, want_l):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


# ------------------------------------------------------- 1. survivor count
def test_unique_ancestor_count_hand_built():
    n = 8
    assert int(unique_ancestor_count(jnp.arange(n))) == n  # identity
    assert int(unique_ancestor_count(jnp.full((n,), 3))) == 1  # collapse
    # known duplicates: {0, 1, 2, 3, 7} survive
    anc = jnp.array([0, 0, 1, 2, 3, 3, 3, 7], jnp.int32)
    assert int(unique_ancestor_count(anc)) == 5
    # order-independence: a permutation of the same multiset
    perm = jnp.array([7, 3, 0, 3, 2, 1, 0, 3], jnp.int32)
    assert int(unique_ancestor_count(perm)) == 5


def test_unique_ancestor_count_batched_rows():
    rows = jnp.stack([
        jnp.arange(16),
        jnp.zeros((16,), jnp.int32),
        jnp.repeat(jnp.arange(4), 4),
    ])
    np.testing.assert_array_equal(
        np.asarray(unique_ancestor_count(rows, axis=-1)), [16, 1, 4]
    )


def test_unique_ancestor_count_is_scatter_free():
    """The census pass (DESIGN.md §13) flags scatter-adds over
    kernel-tainted indices; the survivor count must stay on the sort-diff
    formulation so telemetry never trips it."""
    jaxpr = str(jax.make_jaxpr(unique_ancestor_count)(jnp.arange(32)))
    assert "scatter" not in jaxpr


# --------------------------------------------------- 2. StepStats plumbing
def test_stats_from_vector_unpacks_row():
    row = jnp.array([0.25, -1.5, 1.0, 0.75], jnp.float32)
    s = stats_from_vector(row, jnp.int32(17))
    assert isinstance(s, StepStats)
    assert float(s.ess_norm) == 0.25
    assert float(s.log_evidence_incr) == -1.5
    assert float(s.resampled) == 1.0
    assert float(s.max_weight) == 0.75
    assert int(s.survivors) == 17


def test_stats_from_vector_batched():
    rows = jnp.arange(8, dtype=jnp.float32).reshape(2, 4)
    s = stats_from_vector(rows, jnp.array([3, 5], jnp.int32))
    np.testing.assert_array_equal(np.asarray(s.ess_norm), [0.0, 4.0])
    np.testing.assert_array_equal(np.asarray(s.max_weight), [3.0, 7.0])
    np.testing.assert_array_equal(np.asarray(s.survivors), [3, 5])


# ------------------------------------------------------- 3. oracle parity
@pytest.mark.parametrize("name", ("megopolis", "systematic"))
@pytest.mark.parametrize("threshold", (0.995, 0.0))
def test_step_stats_match_metrics_oracle(name, threshold, base_key):
    """The kernel's SMEM stats row must equal the host composition from
    ``core.metrics`` bitwise — weight-side fields from the input
    log-weights, survivors from the launch's own ancestors."""
    r = spec_for_backend(name, "pallas_interpret", num_iters=8).build()
    lw = jax.random.normal(jax.random.PRNGKey(11), (N,)) * 1.5
    p = jax.random.normal(jax.random.PRNGKey(12), (N, 3))
    _, anc, stats = r.step(base_key, lw, p, threshold)
    ess_norm = effective_sample_size(lw) / jnp.float32(N)
    fired = bool(ess_norm < threshold)
    np.testing.assert_array_equal(np.asarray(stats.ess_norm),
                                  np.asarray(ess_norm))
    np.testing.assert_array_equal(np.asarray(stats.max_weight),
                                  np.asarray(max_normalised_weight(lw)))
    assert float(stats.resampled) == (1.0 if fired else 0.0)
    want_incr = log_mean_weight(lw) if fired else jnp.float32(0.0)
    np.testing.assert_array_equal(np.asarray(stats.log_evidence_incr),
                                  np.asarray(want_incr))
    want_survivors = len(np.unique(np.asarray(anc)))
    assert int(stats.survivors) == want_survivors
    if not fired:
        assert want_survivors == N  # identity ancestors on the skip branch


# ------------------------------------------- 4. telemetry neutrality (bit)
def _pf(backend, ess_threshold=None):
    return ParticleFilter(
        model=ungm(),
        num_particles=N,
        resampler=spec_for_backend("megopolis", backend, num_iters=8),
        ess_threshold=ess_threshold,
    )


@pytest.mark.parametrize("backend", ("reference", "pallas_interpret"))
@pytest.mark.parametrize("ess_threshold", (None, 0.5))
def test_run_filter_telemetry_is_neutral(backend, ess_threshold, base_key):
    pf = _pf(backend, ess_threshold)
    zs = jax.random.normal(jax.random.PRNGKey(21), (6,))
    ests_off = run_filter(base_key, pf, zs)
    ests_on, tel = run_filter(base_key, pf, zs, telemetry=True)
    np.testing.assert_array_equal(np.asarray(ests_on), np.asarray(ests_off))
    assert isinstance(tel, Telemetry) and tel.accept is None
    for leaf in jax.tree.leaves(tel.steps):
        assert leaf.shape == (6,)
    resampled = np.asarray(tel.steps.resampled)
    survivors = np.asarray(tel.steps.survivors)
    assert set(resampled.tolist()) <= {0.0, 1.0}
    assert (survivors >= 1).all() and (survivors <= N).all()
    if ess_threshold is None:
        assert (resampled == 1.0).all()  # Alg. 6 resamples every step
    else:
        # a skipped resample leaves the identity ancestors: survivors == N
        assert (survivors[resampled == 0.0] == N).all()


def test_run_filter_bank_telemetry_is_neutral(base_key):
    pf = _pf("reference", ess_threshold=0.5)
    zs = jax.random.normal(jax.random.PRNGKey(22), (3, 5))
    ests_off = run_filter_bank(base_key, pf, zs)
    ests_on, tel = run_filter_bank(base_key, pf, zs, telemetry=True)
    np.testing.assert_array_equal(np.asarray(ests_on), np.asarray(ests_off))
    for leaf in jax.tree.leaves(tel.steps):
        assert leaf.shape == (3, 5)  # [S, T] — the estimate layout
    # row s of the bank record is the single filter's record (§4 contract)
    from repro.core.resamplers.batched import split_batch_keys

    keys = split_batch_keys(base_key, 3)
    for s in range(3):
        _, tel_s = run_filter(keys[s], pf, zs[s], telemetry=True)
        _tree_equal(jax.tree.map(lambda f: f[s], tel.steps), tel_s.steps)


def test_run_smc_sampler_telemetry_is_neutral(base_key):
    from repro.ais import SMCSamplerConfig, isotropic_gaussian, run_smc_sampler

    target = isotropic_gaussian(dim=2)
    cfg = SMCSamplerConfig(num_particles=256, num_temps=6, num_iters=4)
    out_off = run_smc_sampler(base_key, target, cfg)
    out_on, tel = run_smc_sampler(base_key, target, cfg, telemetry=True)
    _tree_equal(out_on, out_off)
    # the record is the scan's own values, re-exposed
    np.testing.assert_array_equal(np.asarray(tel.betas),
                                  np.asarray(out_off["betas"]))
    np.testing.assert_array_equal(np.asarray(tel.accept),
                                  np.asarray(out_off["accept"]))
    np.testing.assert_array_equal(np.asarray(tel.steps.ess_norm),
                                  np.asarray(out_off["ess"]))
    assert int(np.asarray(tel.steps.resampled).sum()) == int(
        out_off["num_resamples"]
    )


def test_run_smc_sampler_bank_telemetry_is_neutral(base_key):
    from repro.ais import (
        SMCSamplerConfig,
        isotropic_gaussian,
        run_smc_sampler_bank,
    )

    target = isotropic_gaussian(dim=2)
    cfg = SMCSamplerConfig(num_particles=256, num_temps=5, num_iters=4)
    out_off = run_smc_sampler_bank(base_key, target, cfg, num_scenarios=2)
    out_on, tel = run_smc_sampler_bank(
        base_key, target, cfg, num_scenarios=2, telemetry=True
    )
    _tree_equal(out_on, out_off)
    for leaf in jax.tree.leaves(tel.steps):
        assert leaf.shape == (2, 5)  # [S, T], matching the dict layout
    np.testing.assert_array_equal(np.asarray(tel.betas),
                                  np.asarray(out_off["betas"]))
    np.testing.assert_array_equal(np.asarray(tel.accept),
                                  np.asarray(out_off["accept"]))


def test_smc_decode_telemetry_is_neutral():
    import dataclasses

    from repro.configs import get_arch
    from repro.models import init_params, prefill
    from repro.smc import SMCDecodeConfig, smc_decode

    cfg = dataclasses.replace(
        get_arch("qwen3-0.6b").smoke, dtype=jnp.float32, remat=False
    )
    key = jax.random.PRNGKey(5)
    params = init_params(key, cfg)
    prompts = jax.random.randint(
        jax.random.fold_in(key, 1), (8, 4), 0, cfg.vocab_size, jnp.int32
    )
    new = 5
    smc = SMCDecodeConfig(num_particles=8, max_new_tokens=new,
                          target_temp=0.5, ess_threshold=0.9)
    _, caches = prefill(params, cfg, prompts, max_seq=4 + new)
    args = (params, cfg, smc, caches, prompts[:, -1], 4,
            jax.random.fold_in(key, 2))
    tokens_off, log_w_off, stats_off = smc_decode(*args)
    tokens_on, log_w_on, stats_on, tel = smc_decode(*args, telemetry=True)
    _tree_equal((tokens_on, log_w_on, stats_on),
                (tokens_off, log_w_off, stats_off))
    for leaf in jax.tree.leaves(tel.steps):
        assert leaf.shape == (new,)
    assert int(np.asarray(tel.steps.resampled).sum()) == int(
        stats_off["num_resamples"]
    )


# ----------------------------------------------------- 5. the with_ess shim
def test_with_ess_shim_warns_and_matches_telemetry(base_key):
    pf = _pf("reference", ess_threshold=0.5)
    zs = jax.random.normal(jax.random.PRNGKey(23), (4,))
    with pytest.warns(DeprecationWarning, match="telemetry=True"):
        ests_old, ess_old = run_filter(base_key, pf, zs, with_ess=True)
    ests_new, tel = run_filter(base_key, pf, zs, telemetry=True)
    np.testing.assert_array_equal(np.asarray(ests_old), np.asarray(ests_new))
    np.testing.assert_array_equal(np.asarray(ess_old),
                                  np.asarray(tel.steps.ess_norm))
    with pytest.raises(ValueError, match="not both"):
        run_filter(base_key, pf, zs, telemetry=True, with_ess=True)


# --------------------------------------------------------- 6. spans + sink
def test_span_disabled_is_trace_identity():
    """Disabled spans must leave the jaxpr untouched — the §12/§13
    identical-program gates compare traces across dispatches that open
    spans against compositions that don't."""
    assert not tracing_enabled()  # default-off (REPRO_TRACE unset in CI)

    def plain(x):
        return jnp.sum(x * 2.0)

    def spanned(x):
        with dispatch_span("megopolis", "reference", "step"):
            return jnp.sum(x * 2.0)

    x = jnp.arange(8, dtype=jnp.float32)
    assert str(jax.make_jaxpr(plain)(x)) == str(jax.make_jaxpr(spanned)(x))
    np.testing.assert_array_equal(np.asarray(plain(x)),
                                  np.asarray(spanned(x)))


def test_span_enabled_still_computes():
    enable_tracing(True)
    try:
        assert tracing_enabled()
        with span("obs-test/enabled"):
            out = float(jnp.sum(jnp.ones(4)))
        assert out == 4.0
    finally:
        enable_tracing(False)
    assert not tracing_enabled()


def test_jsonl_sink_round_trips_in_order(tmp_path):
    path = tmp_path / "sub" / "events.jsonl"  # parent dir auto-created
    sink = JsonlSink(str(path))
    sink.emit("run_start", git_sha="abc1234")
    sink.emit("suite_end", suite="step", ok=True, wall_s=1.25)
    sink.emit("odd_value", arr=jnp.arange(3))  # stringified, never dropped
    lines = [json.loads(l) for l in path.read_text().splitlines()]
    assert [r["event"] for r in lines] == ["run_start", "suite_end", "odd_value"]
    assert lines[0]["git_sha"] == "abc1234"
    assert lines[1]["ok"] is True and lines[1]["wall_s"] == 1.25
    assert isinstance(lines[2]["arr"], str)
    assert all("ts" in r for r in lines)
