"""Pass 7 — guard neutrality (DESIGN.md §16).

The §16 resilience contract is that degeneracy guards are FREE until the
moment they fire:

  * ``guard='flag'`` must be the IDENTICAL program to ``guard='off'`` —
    not merely launch-equal: the degenerate flag is composed into
    ``StepStats`` under every policy, and the event recorder is
    trace-time static, so the two traces must print the same jaxpr.
  * ``guard='recover'`` may add the host-side ``jnp.where`` substitution
    but must keep the ``pallas_call`` census EQUAL to ``'off'`` (the
    recovery is pre-dispatch, never a second launch), return
    bit-identical outputs on CLEAN inputs (``jnp.where(False, ...)`` is
    an exact passthrough), and return FINITE, in-range outputs on a
    fully collapsed bank — recovered, not garbage.

Structural checks run on every backend (tracing needs no device);
concrete value checks run wherever the cell can execute — every backend
except compiled ``pallas`` on a host without the accelerator.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import walker
from repro.core.spec import BACKENDS, list_resamplers, spec_for_backend

#: Probe geometry — mirrors pass 6: kernel-legal on every backend.
GUARD_N = 2048
GUARD_NUM_ITERS = 16
GUARD_MAX_ITERS = 64
#: ess_norm of the recovered uniform bank is exactly 1.0, so this
#: threshold forces the resample branch — the recovery must RESAMPLE.
GUARD_THRESHOLD = 2.0

#: Backends whose cells can execute on a plain CPU host (compiled
#: ``pallas`` traces fine but needs the accelerator to run).
CONCRETE_BACKENDS = ("reference", "xla", "pallas_interpret")


def _build(name: str, backend: str, guard: str, plane_dtype: str):
    return spec_for_backend(
        name, backend, num_iters=GUARD_NUM_ITERS, max_iters=GUARD_MAX_ITERS,
        plane_dtype=plane_dtype, guard=guard,
    ).build()


def _probe_inputs():
    key = jax.random.PRNGKey(7)
    kw, kp = jax.random.split(key)
    lw = jax.random.normal(kw, (GUARD_N,), jnp.float32)
    particles = jax.random.normal(kp, (GUARD_N,), jnp.float32)
    return key, lw, particles


def _step_jaxpr(r, lw, particles):
    key, _, _ = _probe_inputs()
    return jax.make_jaxpr(
        lambda k, w, p: r.step(k, w, p, GUARD_THRESHOLD)
    )(key, lw, particles)


def _leaves(tree):
    return [np.asarray(l) for l in jax.tree_util.tree_leaves(tree)]


def compare_guard_traces(cell: str, r_off, r_flag, r_recover,
                         concrete: bool) -> dict:
    """Grade one (family, backend[, plane_dtype]) cell for §16 guard
    neutrality; ``concrete`` additionally executes the clean/degenerate
    probes (host-runnable backends only)."""
    key, lw, particles = _probe_inputs()
    violations = []

    jaxpr_off = str(_step_jaxpr(r_off, lw, particles))
    jaxpr_flag = str(_step_jaxpr(r_flag, lw, particles))
    flag_match = jaxpr_flag == jaxpr_off
    if not flag_match:
        violations.append(
            "guard='flag' changed the step program: the degenerate flag is "
            "composed for every policy and the recorder is trace-time "
            "static, so flag-vs-off must print the identical jaxpr "
            "(DESIGN.md §16)"
        )

    closed_off = _step_jaxpr(r_off, lw, particles)
    closed_rec = _step_jaxpr(r_recover, lw, particles)
    launches_off = walker.count_pallas_calls(closed_off)
    launches_rec = walker.count_pallas_calls(closed_rec)
    if launches_rec != launches_off:
        violations.append(
            f"guard='recover' changed the pallas_call census: "
            f"{launches_off} launches off vs {launches_rec} recover (the "
            "uniform-bank substitution is pre-dispatch, never a second "
            "launch, DESIGN.md §16)"
        )

    clean_ok = degenerate_ok = None
    if concrete:
        out_off = r_off.step(key, lw, particles, GUARD_THRESHOLD)
        out_rec = r_recover.step(key, lw, particles, GUARD_THRESHOLD)
        clean_ok = all(
            np.array_equal(a, b, equal_nan=True)
            for a, b in zip(_leaves(out_off), _leaves(out_rec))
        )
        if not clean_ok:
            violations.append(
                "guard='recover' perturbed a CLEAN step: outputs must be "
                "bit-identical to guard='off' when no bank is degenerate "
                "(jnp.where(False, ...) is an exact passthrough, "
                "DESIGN.md §16)"
            )
        bad = jnp.full((GUARD_N,), jnp.nan, jnp.float32)
        p_out, ancestors, stats = r_recover.step(
            key, bad, particles, GUARD_THRESHOLD
        )
        anc = np.asarray(ancestors)
        degenerate_ok = (
            bool(np.isfinite(np.asarray(p_out)).all())
            and bool((anc >= 0).all() and (anc < GUARD_N).all())
            and bool(np.asarray(stats.degenerate))
            and bool(np.isfinite(np.asarray(stats.log_evidence_incr)))
            and float(np.asarray(stats.resampled)) == 1.0
        )
        if not degenerate_ok:
            violations.append(
                "guard='recover' failed to recover an all-NaN bank: the "
                "step must resample from the uniform fallback with finite "
                "outputs, in-range ancestors and degenerate=True "
                "(DESIGN.md §16)"
            )

    return {
        "cell": cell,
        "ok": not violations,
        "flag_jaxpr_match": flag_match,
        "launches_off": launches_off,
        "launches_recover": launches_rec,
        "clean_bit_identical": clean_ok,
        "degenerate_recovered": degenerate_ok,
        "violations": violations,
    }


def audit_guard_cell(name: str, backend: str,
                     plane_dtype: str = "float32") -> dict:
    """Audit one (family, backend, plane_dtype) cell for guard neutrality."""
    suffix = "" if plane_dtype == "float32" else f"@{plane_dtype}"
    cell = f"{name}/{backend}/step{suffix}"
    r_off = _build(name, backend, "off", plane_dtype)
    r_flag = _build(name, backend, "flag", plane_dtype)
    r_rec = _build(name, backend, "recover", plane_dtype)
    return compare_guard_traces(
        cell, r_off, r_flag, r_rec, concrete=backend in CONCRETE_BACKENDS
    )


def audit_guards(families=None, backends=None, plane_dtypes=("float32",)):
    """Audit guard neutrality across the registry matrix; yields cell
    dicts (pass-6 shape: ``cell``/``ok``/``violations`` + evidence)."""
    for dtype in plane_dtypes:
        for name in families if families is not None else list_resamplers():
            for backend in backends if backends is not None else BACKENDS:
                yield audit_guard_cell(name, backend, plane_dtype=dtype)
