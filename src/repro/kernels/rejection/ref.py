"""Pure-jnp bit-exact oracle for the rejection Pallas kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import hash_bits, hash_uniform


@functools.partial(jax.jit, static_argnames=("max_iters",))
def rejection_ref(
    weights: jnp.ndarray,
    seed: jnp.ndarray,
    *,
    max_iters: int,
) -> jnp.ndarray:
    n = weights.shape[0]
    i = jnp.arange(n, dtype=jnp.int32)
    seed = jnp.asarray(seed).reshape(-1)[0]
    # Selection arithmetic is ALWAYS f32 (DESIGN.md §14); no-op at f32.
    weights = weights.astype(jnp.float32)
    w_max = jnp.max(weights)

    u0 = hash_uniform(seed, i + n, 0, dtype=jnp.float32)
    done0 = u0 * w_max <= weights
    k0 = i

    def body(t, state):
        k, done = state
        j = (hash_bits(seed, i, t) % jnp.uint32(n)).astype(jnp.int32)
        w_j = weights[j]
        u = hash_uniform(seed, i + n, t, dtype=jnp.float32)
        accept = (~done) & (u * w_max <= w_j)
        return jnp.where(accept, j, k), done | accept

    k, _ = jax.lax.fori_loop(1, max_iters + 1, body, (k0, done0))
    return k
