"""JSONL event sink for the benchmark harness (DESIGN.md §15).

One event per line — ``{"event": <name>, "ts": <unix seconds>, ...fields}``
— appended so concurrent suites interleave without clobbering each other.
``benchmarks/run.py`` emits ``suite_start``/``suite_end``/``run_end`` events
here and CI uploads the file as the observability artifact; anything that
reads it gets an ordered, replayable record of what a bench run actually
did (the "flight recorder" half of the subsystem name).
"""

from __future__ import annotations

import json
import os
import time


class JsonlSink:
    """Append-only JSONL event writer.  Values must be JSON-serialisable;
    non-serialisable values are stringified rather than dropped, so an odd
    numpy scalar can never kill a bench run."""

    def __init__(self, path: str):
        self.path = path
        parent = os.path.dirname(path)
        if parent:
            os.makedirs(parent, exist_ok=True)

    def emit(self, event: str, **fields) -> None:
        record = {"event": event, "ts": round(time.time(), 3)}
        for k, v in fields.items():
            try:
                json.dumps(v)
            except (TypeError, ValueError):
                v = str(v)
            record[k] = v
        with open(self.path, "a") as f:
            f.write(json.dumps(record) + "\n")
