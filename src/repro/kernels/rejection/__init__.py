from repro.kernels.rejection.ops import rejection_tpu, rejection_tpu_batch  # noqa: F401
from repro.kernels.rejection.ref import rejection_ref  # noqa: F401
