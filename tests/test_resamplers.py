"""Functional correctness of every resampler: valid outputs, determinism,
degenerate-weight behaviour, and the Alg.8 == searchsorted equivalence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import get_resampler, list_resamplers, select_iterations
from repro.core.metrics import offspring_counts
from repro.core.resamplers.megopolis import megopolis_indices

ALL = list_resamplers()
N = 512
B = 24


def _weights(key, n=N):
    return jax.random.uniform(key, (n,)) + 1e-3


@pytest.mark.parametrize("name", ALL)
def test_ancestors_valid_and_deterministic(name, base_key):
    w = _weights(jax.random.fold_in(base_key, 1))
    fn = get_resampler(name)
    a1 = fn(jax.random.fold_in(base_key, 2), w, B)
    a2 = fn(jax.random.fold_in(base_key, 2), w, B)
    assert a1.shape == (N,)
    assert a1.dtype == jnp.int32
    assert bool(jnp.all((a1 >= 0) & (a1 < N)))
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))


@pytest.mark.parametrize("name", ALL)
def test_total_offspring_is_n(name, base_key):
    w = _weights(jax.random.fold_in(base_key, 3))
    a = get_resampler(name)(jax.random.fold_in(base_key, 4), w, B)
    assert int(offspring_counts(a, N).sum()) == N


@pytest.mark.parametrize("name", [n for n in ALL if n not in ("metropolis_c1", "rejection")])
def test_degenerate_single_heavy_particle(name, base_key):
    """One particle holds ~all weight -> nearly all ancestors point at it."""
    w = jnp.full((N,), 1e-7).at[137].set(1.0)
    num_iters = int(select_iterations(w, 0.01))
    a = get_resampler(name)(jax.random.fold_in(base_key, 5), w, num_iters)
    frac = float(jnp.mean(a == 137))
    assert frac > 0.95, f"{name}: only {frac:.2%} selected the heavy particle"


def test_rejection_degenerate_needs_geometric_tail(base_key):
    """Rejection's per-particle iteration count is geometric with mean
    max(w)/E(w) ~ N here — the variable-execution-time weakness the paper
    cites (§1).  With a cap ~8x the mean it must still converge."""
    from repro.core import rejection

    w = jnp.full((N,), 1e-7).at[137].set(1.0)
    a = rejection(jax.random.fold_in(base_key, 5), w, 0, max_iters=8 * N)
    assert float(jnp.mean(a == 137)) > 0.95


def test_c1_partition_bias_vs_megopolis(base_key):
    """Paper Fig. 6: C1 (PS128) is badly biased under degeneracy — warps whose
    fixed partition misses the heavy particle can never select it, unlike
    Megopolis which exposes every particle each iteration."""
    from repro.core import megopolis, metropolis_c1

    w = jnp.full((N,), 1e-7).at[137].set(1.0)
    num_iters = int(select_iterations(w, 0.01))
    frac_c1, frac_mego = 0.0, 0.0
    trials = 8
    for t in range(trials):
        k = jax.random.fold_in(base_key, 300 + t)
        frac_c1 += float(jnp.mean(metropolis_c1(k, w, num_iters) == 137)) / trials
        frac_mego += float(jnp.mean(megopolis(k, w, num_iters) == 137)) / trials
    assert frac_mego > 0.95
    assert frac_c1 < 0.5 * frac_mego, (frac_c1, frac_mego)


@pytest.mark.parametrize("name", ALL)
def test_uniform_weights_low_selfmove(name, base_key):
    """With uniform weights every ancestor choice is accepted; output must
    still be a valid resample (jit-compatible too)."""
    w = jnp.ones((N,))
    fn = jax.jit(get_resampler(name), static_argnums=2)
    a = fn(jax.random.fold_in(base_key, 6), w, B)
    assert bool(jnp.all((a >= 0) & (a < N)))


def test_improved_systematic_equals_searchsorted(base_key):
    from repro.core import improved_systematic, systematic

    for trial in range(5):
        k = jax.random.fold_in(base_key, 100 + trial)
        w = _weights(k, 257)  # non-power-of-2 on purpose
        a_ref = systematic(k, w)
        a_alg8 = improved_systematic(k, w)
        np.testing.assert_array_equal(np.asarray(a_ref), np.asarray(a_alg8))


def test_megopolis_index_map_is_bijection():
    """Per-iteration i->j must be a bijection for ANY offset/segment (the
    heart of Proposition 1's variance argument)."""
    for n, seg in [(256, 32), (256, 64), (1024, 128), (96, 32)]:
        i = jnp.arange(n)
        for o in [0, 1, 31, 32, 33, n - 1, n // 2]:
            j = np.asarray(megopolis_indices(i, o, seg, n))
            if n % seg == 0:
                assert len(set(j.tolist())) == n, (n, seg, o)
            assert ((j >= 0) & (j < n)).all()


def test_megopolis_uniform_exposure():
    """Over many offsets, each particle i must see ~uniform j (bias arg)."""
    n, seg = 128, 32
    i = jnp.arange(n)
    counts = np.zeros((n,), np.int64)
    for o in range(n):  # exhaustive offsets
        j = np.asarray(megopolis_indices(i, o, seg, n))
        counts += np.bincount(j, minlength=n)
    # exhaustive o in [0,n) must expose every j exactly n times
    assert (counts == n).all()


def test_select_iterations_matches_closed_form():
    from repro.core.iterations import gaussian_weight_iterations

    # eq. 3 with the eq. 12 family: E(w)/max(w) = exp(-y^2/4)/sqrt(2)
    for y, eps in [(0.0, 0.01), (2.0, 0.01), (4.0, 0.1)]:
        b = gaussian_weight_iterations(y, eps)
        assert b >= 1
    assert gaussian_weight_iterations(0.0, 0.01) <= 10
    assert gaussian_weight_iterations(4.0, 0.01) > gaussian_weight_iterations(1.0, 0.01)


def test_rejection_unbiased_mean(base_key):
    from repro.core import rejection

    w = _weights(base_key, 256)
    counts = np.zeros(256)
    for t in range(64):
        a = rejection(jax.random.fold_in(base_key, 200 + t), w, 0)
        counts += np.bincount(np.asarray(a), minlength=256)
    emp = counts / counts.sum()
    tgt = np.asarray(w / w.sum())
    assert np.abs(emp - tgt).max() < 0.02
