"""Pass 6 — telemetry neutrality (DESIGN.md §15).

The flight-recorder contract is that observability is FREE: flipping
``telemetry=True`` on a consumer must not add a single kernel launch, and
must leave the computation of the estimates (and hence the ancestor
stream feeding them) untouched.  This pass re-derives both halves of that
claim from jaxprs instead of trusting the docstrings:

  * **launch parity** — ``run_filter`` is traced telemetry-off and
    telemetry-on for every (family, backend[, plane_dtype]) cell; the
    ``pallas_call`` census of the two traces must be EQUAL (not merely
    within budget — equal);
  * **estimate-stream parity** — the telemetry-on trace is dead-code
    eliminated down to just its estimates output.  What survives must be
    the SAME program as the telemetry-off trace (compared on the printed
    jaxpr, which is deterministic for structurally identical programs).
    This is the strong form of "the record is built from values the scan
    already computes": anything telemetry-only (the survivor sort, the
    StepStats stacking) must vanish under DCE, and nothing the estimates
    depend on may have moved.

The conditional-SIR ``run_filter`` is the probe because it exercises the
fused ``Resampler.step`` — the one entry whose stats vector feeds both
the resample decision (load-bearing, must survive DCE) and the telemetry
record (free, must not).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax._src.interpreters.partial_eval import dce_jaxpr

from repro.analysis import walker
from repro.core.spec import BACKENDS, list_resamplers, spec_for_backend

#: Probe geometry — small enough to trace the whole matrix in seconds,
#: kernel-legal on every backend (N is one VMEM tile pair).
NEUTRALITY_N = 2048
NEUTRALITY_STEPS = 3
NEUTRALITY_NUM_ITERS = 16
NEUTRALITY_MAX_ITERS = 64


def _probe_filter(name: str, backend: str, plane_dtype: str):
    from repro.pf.filter import ParticleFilter
    from repro.pf.models import ungm

    spec = spec_for_backend(
        name, backend, num_iters=NEUTRALITY_NUM_ITERS,
        max_iters=NEUTRALITY_MAX_ITERS, plane_dtype=plane_dtype,
    )
    return ParticleFilter(
        model=ungm(), num_particles=NEUTRALITY_N, resampler=spec,
        ess_threshold=0.5,
    )


def _traces(pf):
    """(off trace, on trace, used-output mask for the on trace's estimates)."""
    from repro.pf.filter import run_filter

    key = jax.random.PRNGKey(0)
    obs = jnp.zeros((NEUTRALITY_STEPS,), jnp.float32)
    off = jax.make_jaxpr(lambda k, z: run_filter(k, pf, z))(key, obs)
    on, on_shape = jax.make_jaxpr(
        lambda k, z: run_filter(k, pf, z, telemetry=True), return_shape=True
    )(key, obs)
    n_est = len(jax.tree_util.tree_leaves(on_shape[0]))
    n_all = len(jax.tree_util.tree_leaves(on_shape))
    used = [True] * n_est + [False] * (n_all - n_est)
    return off, on, used


def _estimates_fingerprint(closed, used) -> str:
    """Pretty-printed jaxpr of ``closed`` DCE'd to ``used`` outputs —
    deterministic for structurally identical programs."""
    pruned, _ = dce_jaxpr(closed.jaxpr, used)
    return str(pruned)


def compare_traces(cell: str, off, on, used) -> dict:
    """Grade an (off, on) trace pair for neutrality.  ``used`` marks which
    flat outputs of the on trace are the estimates (everything the off
    trace also returns); the rest is the telemetry record."""
    launches_off = walker.count_pallas_calls(off)
    launches_on = walker.count_pallas_calls(on)
    fp_off = _estimates_fingerprint(off, [True] * len(off.jaxpr.outvars))
    fp_on = _estimates_fingerprint(on, used)
    violations = []
    if launches_on != launches_off:
        violations.append(
            f"telemetry=True changed the pallas_call census: "
            f"{launches_off} launches off vs {launches_on} on (the record "
            "must be composed from values the scan already computes, "
            "DESIGN.md §15)"
        )
    if fp_on != fp_off:
        violations.append(
            "telemetry=True perturbed the estimates program: the DCE "
            "projection of the telemetry-on trace onto its estimates "
            "output differs from the telemetry-off trace (the ancestor/"
            "estimate stream must be byte-identical, DESIGN.md §15)"
        )
    return {
        "cell": cell,
        "ok": not violations,
        "launches_off": launches_off,
        "launches_on": launches_on,
        "estimates_jaxpr_match": fp_on == fp_off,
        "violations": violations,
    }


def audit_telemetry_cell(name: str, backend: str,
                         plane_dtype: str = "float32") -> dict:
    """Audit one (family, backend, plane_dtype) cell for neutrality."""
    suffix = "" if plane_dtype == "float32" else f"@{plane_dtype}"
    cell = f"{name}/{backend}/run_filter{suffix}"
    pf = _probe_filter(name, backend, plane_dtype)
    off, on, used = _traces(pf)
    return compare_traces(cell, off, on, used)


def audit_telemetry(families=None, backends=None,
                    plane_dtypes=("float32",)):
    """Audit neutrality across the registry matrix; yields cell dicts."""
    for dtype in plane_dtypes:
        for name in families if families is not None else list_resamplers():
            for backend in backends if backends is not None else BACKENDS:
                yield audit_telemetry_cell(name, backend, plane_dtype=dtype)
