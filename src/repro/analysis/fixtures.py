"""Deliberately-broken programs that prove each analyzer pass fires.

A static checker that has never caught anything is indistinguishable from
one that checks nothing, so every pass ships with a program violating
exactly its invariant (and honouring the others).  ``tests/test_analysis.py``
asserts the one-finding-per-fixture mapping, and the CLI's ``--selftest``
re-runs it in CI.

All fixtures trace in interpret mode on any host; the oversized-VMEM one
is TRACE-ONLY (the whole point is a footprint no core could hold).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.analysis.contracts import Contract, audit_jaxpr

_N = 2048


def _copy_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def _copy_launch(x, *, interpret=True):
    return pl.pallas_call(
        _copy_kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x)


def extra_launch(w):
    """Budget says ONE launch; this stages the copy through a second
    kernel — the classic unfused two-pass shape the launch auditor exists
    to catch."""
    return _copy_launch(_copy_launch(w))


def _iota_kernel(o_ref):
    o_ref[...] = jax.lax.broadcasted_iota(jnp.int32, o_ref.shape, 1)


def hbm_roundtrip(w, state):
    """Ancestors leave a kernel and index a host-side ``jnp.take`` — the
    §11 HBM round-trip the fused apply/step paths eliminated."""
    idx = pl.pallas_call(
        _iota_kernel,
        out_shape=jax.ShapeDtypeStruct((1, w.shape[0]), jnp.int32),
        interpret=True,
    )()[0]
    return jnp.take(state, idx, axis=0)


def reused_key(key, w):
    """The same PRNG key drawn from twice — correlated streams, the
    silent-failure mode the RNG survey warns about."""
    u = jax.random.uniform(key, w.shape)
    g = jax.random.normal(key, w.shape)
    return w + u + g


def key_dropped_in_branch(key, w, flag):
    """A key consumed in one ``lax.cond`` branch and ignored in the other:
    whether the stream advances becomes data-dependent.  (The fixture's
    contract allows the cond itself so only the RNG pass fires.)"""
    return jax.lax.cond(
        flag,
        lambda k, ww: ww + jax.random.uniform(k, ww.shape),
        lambda k, ww: ww,
        key,
        w,
    )


def oversized_vmem(x):
    """A whole-array kernel over 8M f32 — 32 MiB resident input alone,
    past any residency budget.  Trace-only."""
    return _copy_launch(x, interpret=False)


#: fixture name -> (trace thunk, contract, the pass expected to fire).
FIXTURES = {
    "extra_launch": (
        lambda: jax.make_jaxpr(extra_launch)(jnp.zeros((_N,), jnp.float32)),
        Contract(max_launches=1),
        "launches",
    ),
    "hbm_roundtrip": (
        lambda: jax.make_jaxpr(hbm_roundtrip)(
            jnp.zeros((_N,), jnp.float32), jnp.zeros((_N, 4), jnp.float32)
        ),
        Contract(max_launches=1),
        "census",
    ),
    "reused_key": (
        lambda: jax.make_jaxpr(reused_key)(
            jax.random.PRNGKey(0), jnp.zeros((_N,), jnp.float32)
        ),
        Contract(max_launches=0),
        "rng",
    ),
    "key_dropped_in_branch": (
        lambda: jax.make_jaxpr(key_dropped_in_branch)(
            jax.random.PRNGKey(0), jnp.zeros((_N,), jnp.float32), True
        ),
        Contract(max_launches=0, allow_cond=True),
        "rng",
    ),
    "oversized_vmem": (
        lambda: jax.make_jaxpr(oversized_vmem)(
            jnp.zeros((1 << 23,), jnp.float32)
        ),
        Contract(max_launches=1),
        "vmem",
    ),
}


def leaky_telemetry():
    """The pass-6 anti-fixture: a 'consumer' whose telemetry flag is NOT
    free — enabling it stages the weights through an extra kernel launch
    AND threads the record back into the estimate, so both halves of the
    neutrality check (launch parity, DCE'd-estimates parity) must fire."""

    def fn(telemetry=False):
        def run(k, z):
            w = z + jax.random.uniform(k, z.shape)
            est = jnp.mean(w)
            if telemetry:
                record = _copy_launch(w)  # an extra launch just for the record
                est = est + 0.0 * record[0]  # ...that leaks into the estimate
                return est, record
            return est

        return run

    key = jax.random.PRNGKey(0)
    z = jnp.zeros((_N,), jnp.float32)
    off = jax.make_jaxpr(fn(telemetry=False))(key, z)
    on, shape = jax.make_jaxpr(fn(telemetry=True), return_shape=True)(key, z)
    n_est = len(jax.tree_util.tree_leaves(shape[0]))
    used = [True] * n_est + [False] * (
        len(jax.tree_util.tree_leaves(shape)) - n_est
    )
    return off, on, used


def telemetry_selftest() -> list[str]:
    """Pass 6 must flag the leaky fixture (both violations) and pass a
    real cell; returns problems, empty when healthy."""
    from repro.analysis.telemetry import audit_telemetry_cell, compare_traces

    problems = []
    rep = compare_traces("fixture:leaky_telemetry", *leaky_telemetry())
    if rep["ok"]:
        problems.append(
            "leaky_telemetry: expected neutrality violations, got none"
        )
    else:
        if rep["launches_on"] == rep["launches_off"]:
            problems.append(
                "leaky_telemetry: expected the launch-parity check to fire"
            )
        if rep["estimates_jaxpr_match"]:
            problems.append(
                "leaky_telemetry: expected the DCE'd-estimates check to fire"
            )
    good = audit_telemetry_cell("megopolis", "pallas_interpret")
    if not good["ok"]:
        problems.append(
            f"telemetry pass flags a healthy cell: {good['violations']}"
        )
    return problems


def leaky_guard():
    """The pass-7 anti-fixture: a 'resampler' whose guard axis is NOT
    neutral — ``'flag'`` adds equations to the step program, and
    ``'recover'`` stages the state through an extra launch AND emits NaN
    state on a degenerate bank, so all three §16 checks (flag-jaxpr
    identity, recover launch parity, degenerate recovery) must fire."""
    from types import SimpleNamespace

    from repro.obs.stats import StepStats

    def make(mode):
        def step(key, lw, p, thr):
            n = lw.shape[0]
            deg = ~jnp.isfinite(jnp.max(lw))
            ancestors = jnp.arange(n, dtype=jnp.int32)
            p_out = p
            if mode == "flag_leak":
                p_out = p + 0.0 * jnp.float32(1.0)  # a visible extra op
            if mode == "recover_leak":
                p_out = _copy_launch(p)  # an extra launch just to recover
                p_out = jnp.where(deg, jnp.float32(jnp.nan), p_out)  # garbage
            stats = StepStats(
                ess_norm=jnp.float32(1.0),
                log_evidence_incr=jnp.float32(0.0),
                resampled=jnp.float32(1.0),
                max_weight=jnp.float32(1.0 / n),
                survivors=jnp.int32(n),
                degenerate=deg,
            )
            return p_out, ancestors, stats

        return SimpleNamespace(step=step)

    return make("off"), make("flag_leak"), make("recover_leak")


def guard_selftest() -> list[str]:
    """Pass 7 must flag the leaky fixture (all three violations) and pass
    a real cell; returns problems, empty when healthy."""
    from repro.analysis.guards import audit_guard_cell, compare_guard_traces

    problems = []
    rep = compare_guard_traces(
        "fixture:leaky_guard", *leaky_guard(), concrete=True
    )
    if rep["ok"]:
        problems.append("leaky_guard: expected §16 violations, got none")
    else:
        if rep["flag_jaxpr_match"]:
            problems.append(
                "leaky_guard: expected the flag-jaxpr-identity check to fire"
            )
        if rep["launches_recover"] == rep["launches_off"]:
            problems.append(
                "leaky_guard: expected the recover launch-parity check to fire"
            )
        if rep["degenerate_recovered"]:
            problems.append(
                "leaky_guard: expected the degenerate-recovery check to fire"
            )
    good = audit_guard_cell("megopolis", "pallas_interpret")
    if not good["ok"]:
        problems.append(
            f"guard pass flags a healthy cell: {good['violations']}"
        )
    return problems


def audit_fixtures():
    """Audit every fixture; yields ``(name, expected_pass, CellReport)``."""
    for name, (tracer, contract, expected) in FIXTURES.items():
        yield name, expected, audit_jaxpr(f"fixture:{name}", tracer(), contract)


def selftest() -> list[str]:
    """Returns a list of problems; empty means every pass catches its
    fixture (and nothing else fires)."""
    problems = []
    for name, expected, rep in audit_fixtures():
        if rep.ok:
            problems.append(f"{name}: expected a {expected} violation, got none")
            continue
        matched = {
            "launches": any("launches exceed" in v for v in rep.violations),
            "census": any("ancestor-roundtrip" in v for v in rep.violations),
            "rng": any("[rng:" in v for v in rep.violations),
            "vmem": any("[vmem:" in v for v in rep.violations),
        }
        if not matched[expected]:
            problems.append(
                f"{name}: expected the {expected} pass to fire, got {rep.violations}"
            )
        others = [k for k, hit in matched.items() if hit and k != expected]
        if others:
            problems.append(f"{name}: unexpected extra findings from {others}")
    problems.extend(telemetry_selftest())
    problems.extend(guard_selftest())
    return problems