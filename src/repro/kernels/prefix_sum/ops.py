"""Public wrapper: 1-D inclusive prefix sum via the block-scan kernel."""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.common import TILE
from repro.kernels.prefix_sum.prefix_sum import LANES, prefix_sum_pallas


def prefix_sum_tpu(x: jnp.ndarray, *, interpret: bool = True) -> jnp.ndarray:
    n = x.shape[0]
    if n % TILE != 0:
        raise ValueError(f"prefix_sum_tpu requires N % {TILE} == 0; got {n}")
    y2 = prefix_sum_pallas(x.reshape(n // LANES, LANES), interpret=interpret)
    return y2.reshape(n)
