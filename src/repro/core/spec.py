"""Typed resampler specs — one object per family, one build surface (DESIGN.md §9).

The paper's headline claim is that Megopolis needs *no tuning parameter*
beyond the eq. (3) iteration count, yet the pre-spec API forced every call
site to hand-thread ``num_iters`` and per-algorithm kwargs.  A
``ResamplerSpec`` is the typed replacement: a frozen, hashable dataclass —
one per algorithm family — that carries every hyperparameter the family
has, validates it EAGERLY (bad segment / backend / kind errors at
construction, not at trace time), and builds a uniform callable::

    spec = MegopolisSpec(num_iters=24, segment=32)
    r = spec.build()            # -> Resampler
    anc  = r(key, weights)      # int32[N]      (single population)
    bank = r.batch(key, w_bank) # int32[B, N]   (weights[B, N], split-key rows)

Properties:

  * **Static-safe.**  Specs are registered as static pytree nodes
    (``jax.tree_util.register_static``): hashable, usable as ``jit`` static
    arguments, storable inside other frozen configs (``ParticleFilter``,
    ``SMCDecodeConfig``), and ``jax.tree`` round-trips return the same
    object.
  * **Sweepable.**  ``spec.replace(partition_size_bytes=2048)`` returns a
    validated variant — benchmark sweeps are spec transformations.
  * **No tuning parameter.**  ``num_iters='auto'`` (the Metropolis-family
    default) routes through ``select_iterations`` (paper eq. 3) at call
    time, so the no-tuning story is first-class: ``MegopolisSpec().build()``
    resamples any weight vector without the caller ever choosing ``B``.
  * **Backend dispatch.**  ``backend='reference' | 'xla' | 'pallas_interpret'
    | 'pallas'`` selects the execution surface in the spec: ``reference``
    is the pure-jnp algorithm, ``xla`` the same jit-wrapped, and the
    ``pallas*`` pair the TPU kernel (interpret mode validates on CPU).
    EVERY family builds on every backend — the kernel matrix is complete
    (Megopolis, Metropolis, C1/C2, rejection, and all five prefix-sum
    kinds); kernels whose geometry is tile-fixed require the matching
    spec fields (``segment=1024`` for Megopolis, ``partition_size_bytes=
    4096`` for C1/C2) so the coalescing contract stays explicit.

``spec_from_name(name, **kw)`` maps the 10 registry names onto spec
instances (with a difflib nearest-match hint on unknown names);
``get_resampler`` / ``get_resampler_batch`` remain as thin legacy shims
over the same family table.
"""

from __future__ import annotations

import dataclasses
import difflib
from typing import Any, Callable, ClassVar, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.iterations import select_iterations
from repro.core.metrics import (
    degenerate_log_weights,
    degenerate_weights,
    effective_sample_size,
    log_mean_weight,
    max_normalised_weight,
    normalise_log_weights,
    unique_ancestor_count,
)
from repro.obs.stats import stats_from_vector
from repro.obs.trace import dispatch_span
from repro.resilience.guards import check_guard_policy, maybe_emit_guard_event
from repro.core.resamplers.batched import split_batch_keys
from repro.core.resamplers.megopolis import DEFAULT_SEGMENT, megopolis, megopolis_batch
from repro.core.resamplers.metropolis import (
    WARP,
    metropolis,
    metropolis_batch,
    metropolis_c1,
    metropolis_c1_batch,
    metropolis_c2,
    metropolis_c2_batch,
)
from repro.core.resamplers.prefix_sum import (
    improved_systematic,
    improved_systematic_batch,
    multinomial,
    multinomial_batch,
    residual,
    residual_batch,
    stratified,
    stratified_batch,
    systematic,
    systematic_batch,
)
from repro.core.resamplers.rejection import rejection, rejection_batch
from repro.kernels.common import PLANE_DTYPES, quantise_plane

AUTO = "auto"
BACKENDS = ("reference", "xla", "pallas_interpret", "pallas")
# Kernel coalescing segment: one (8, 128) f32 VMEM tile (DESIGN.md §2).
KERNEL_SEGMENT = 1024
# The C1/C2 kernels' partition is that same tile, in the papers' byte units.
KERNEL_PARTITION_BYTES = KERNEL_SEGMENT * 4
PALLAS_BACKENDS = ("pallas_interpret", "pallas")
# Loop-bound cap when num_iters='auto' resolves under trace: eq. (3) yields a
# traced B, so offset tables are drawn at this static size and the
# accept/reject loop runs the traced bound (clamped).  4096 covers every
# weight family in the paper's sweeps (y <= 4 needs B <= ~210; the
# one-heavy-particle torture case at N=512 needs ~2.4k).
AUTO_MAX_ITERS = 4096


def _is_traced(x) -> bool:
    return isinstance(x, jax.core.Tracer)


def _check_positive_int(value, field: str, cls: str):
    if isinstance(value, bool) or not isinstance(value, int) or value < 1:
        raise ValueError(f"{cls}.{field} must be a positive int; got {value!r}")


def _check_num_iters(value, cls: str):
    if value == AUTO:
        return
    if isinstance(value, bool) or not isinstance(value, int) or value < 1:
        raise ValueError(
            f"{cls}.num_iters must be a positive int or {AUTO!r} (eq. 3 selection); "
            f"got {value!r}"
        )


def _check_backend(value, cls: str):
    if value not in BACKENDS:
        raise ValueError(f"{cls}.backend must be one of {BACKENDS}; got {value!r}")


def _check_plane_dtype(value, cls: str):
    if value not in PLANE_DTYPES:
        raise ValueError(
            f"{cls}.plane_dtype must be one of {PLANE_DTYPES}; got {value!r}"
        )


def _take_rows(particles: jnp.ndarray, ancestors: jnp.ndarray) -> jnp.ndarray:
    """Row-wise ancestor gather: ``out[b] = particles[b][ancestors[b]]``."""
    return jax.vmap(lambda p, a: jnp.take(p, a, axis=0))(particles, ancestors)


class Resampler:
    """A built resampler: the ONE callable surface every family shares.

    Constructed by ``ResamplerSpec.build()``; hyperparameters and backend
    are baked in, so call sites never thread kwargs::

        r(key, weights)            # int32[N]     over f32[N]
        r.batch(key, weights)      # int32[B, N]  over f32[B, N]
        r.batch_rows(keys, weights)  # explicit per-row keys (filter banks)
        r.apply(key, weights, particles)        # -> (particles', ancestors)
        r.apply_batch(key, weights, particles)  # bank form of apply
        r.apply_rows(keys, weights, particles)  # explicit per-row keys
        r.step(key, log_w, particles, ess_threshold)   # fused SMC step
        r.step_rows(keys, log_w, particles, ess_threshold)  # bank form
        r.name, r.spec             # registry name / originating spec

    ``batch`` follows the DESIGN.md §4 contract: the key is split once
    along the batch axis and row ``b`` is bit-identical to the single call
    with ``split(key, B)[b]`` (the pallas batched Megopolis kernel instead
    shares the offset table bank-wide — its own documented contract).

    ``apply`` is the fused resample+gather data path (DESIGN.md §11):
    select ancestors AND copy each ancestor's particle state in one step,
    ``particles`` being ``[N]``/``[N, ...]`` (``[B, N, ...]`` for the bank
    forms).  On the reference/xla backends it IS the index + ``jnp.take``
    composition (the bit-identical oracle); on the pallas backends the
    state copy happens inside the kernel — the ancestor vector never
    round-trips through HBM between selection and gather.  Every form
    returns ``(particles', ancestors)`` with ancestors bit-identical to the
    corresponding index-only call.

    ``step`` is the fused SMC step (DESIGN.md §12): normalise log-weights,
    compute ESS, take the resample-or-not branch, and copy state, returning
    ``(particles', ancestors, stats)`` with ``stats`` a ``StepStats``
    record (ess_norm, log_evidence_incr, resampled, max_weight, survivors
    — DESIGN.md §15).  The resample branch (``ess_norm < ess_threshold``,
    strict) is bit-identical to ``apply(key, normalise_log_weights(log_w),
    particles)``; the no-op branch returns the particles bit-identical with
    identity ancestors and ``incr = 0``.  Randomness is consumed
    unconditionally in BOTH branches (where-select, not cond), so key
    chains advance identically whether or not a resample fires.  On the
    pallas backends the whole step is ONE kernel launch with the first four
    stats fields reduced in-kernel; on reference/xla it IS the normalise →
    ESS → branch → ``apply`` composition (the bit-identical oracle).
    ``survivors`` (the distinct-ancestor count) is composed from the
    returned ancestors on every backend.
    """

    def __init__(
        self,
        spec: "ResamplerSpec",
        single: Callable,
        batch: Callable,
        *,
        apply: Callable = None,
        apply_batch: Callable = None,
        apply_rows: Callable = None,
        step: Callable = None,
        step_rows: Callable = None,
    ):
        self.spec = spec
        self.name = spec.name
        # The plane-compression axis (DESIGN.md §14).  Quantisation happens
        # HERE — once, at the public entry — for EVERY backend, so the
        # reference lane is the bit-exact oracle of the compressed kernels.
        self.plane_dtype = getattr(spec, "plane_dtype", "float32")
        # The §16 degeneracy-guard axis: 'off' | 'flag' | 'recover'.
        self.guard = getattr(spec, "guard", "off")
        self._single = single
        self._batch = batch

        # Derived (reference/xla) apply forms compose the SAME single/batch
        # callables the index path runs — deliberately NOT re-jitted as one
        # program: a separately compiled composition may constant-fold the
        # prefix-sum family's f32 cumsum differently and shift a searchsorted
        # boundary, breaking the bit-identical-oracle contract.  Callers
        # wanting one fused XLA program jit the call site (consumers do:
        # the filter/sampler scans are jitted wholesale).
        if apply is None:
            def apply(key, w, p):
                ancestors = single(key, w)
                return jnp.take(p, ancestors, axis=0), ancestors

        if apply_batch is None:
            def apply_batch(key, w, p):
                ancestors = batch(key, w)
                return _take_rows(p, ancestors), ancestors

        if apply_rows is None:
            inner = apply

            def apply_rows(keys, w, p):
                return jax.vmap(inner)(keys, w, p)

        self._apply = apply
        self._apply_batch = apply_batch
        self._apply_rows = apply_rows

        # Composed step default: the SAME (possibly fused) apply callable,
        # wrapped in the normalise → ESS → branch glue.  Not re-jitted, for
        # the same reason as the apply defaults above — this composition is
        # the oracle the fused step kernels are gated against.
        if step is None:
            apply_fn = apply
            plane_dtype = self.plane_dtype

            def step(key, log_w, particles, ess_threshold):
                n = log_w.shape[-1]
                ess_n = effective_sample_size(log_w) / jnp.float32(n)
                do = ess_n < ess_threshold
                # Normalised weights re-land on the plane-dtype grid — the
                # value the fused step kernels' in-body requantise matches.
                # A no-op at f32.
                w = quantise_plane(normalise_log_weights(log_w), plane_dtype)
                p_res, a_res = apply_fn(key, w, particles)
                ancestors = jnp.where(do, a_res, jnp.arange(n, dtype=jnp.int32))
                p_out = jnp.where(do, p_res, particles)
                incr = jnp.where(do, log_mean_weight(log_w), jnp.float32(0.0))
                stats4 = jnp.stack([
                    ess_n,
                    incr,
                    jnp.where(do, jnp.float32(1.0), jnp.float32(0.0)),
                    max_normalised_weight(log_w),
                ])
                return p_out, ancestors, stats4

        if step_rows is None:
            step_fn = step

            def step_rows(keys, log_w, particles, ess_threshold):
                return jax.vmap(step_fn, in_axes=(0, 0, 0, None))(
                    keys, log_w, particles, ess_threshold
                )

        self._step = step
        self._step_rows = step_rows
        self.__name__ = f"{self.name}_resampler"
        self.__qualname__ = self.__name__

    def quantise(self, x: jnp.ndarray) -> jnp.ndarray:
        """Round a float array onto the spec's plane-dtype grid — the value
        the compressed tiles represent on the wire (DESIGN.md §14).
        Identity at ``plane_dtype='float32'`` and for non-float arrays.
        Applied by every public entry, so ``r_bf16(key, w)`` equals
        ``r_f32(key, r_bf16.quantise(w))`` ancestor-for-ancestor."""
        return quantise_plane(x, self.plane_dtype)

    def _span(self, entry: str):
        """The dispatch trace span (DESIGN.md §15):
        ``family/backend/entry/plane_dtype``.  Identity unless tracing is
        enabled, so the structural jaxpr gates never see it."""
        return dispatch_span(
            self.name, getattr(self.spec, "backend", "reference"), entry,
            self.plane_dtype,
        )

    def _guard_weights(self, w: jnp.ndarray, entry: str) -> jnp.ndarray:
        """§16 guard for the linear-weight entries: at ``guard='recover'``,
        degenerate rows (``metrics.degenerate_weights``: zero/nan/±inf
        mass) are substituted with the uniform bank before dispatch — an
        exact bitwise passthrough on clean rows; at ``'flag'`` the weights
        run untouched and a ``ResilienceEvent`` is staged (only while a
        recorder is active at trace time).  ``'off'`` returns ``w``
        unchanged with zero extra equations."""
        if self.guard == "off":
            return w
        deg = degenerate_weights(w, axis=-1)
        if self.guard == "recover":
            n = w.shape[-1]
            w = jnp.where(
                jnp.expand_dims(deg, -1), jnp.full_like(w, 1.0 / n), w
            )
        maybe_emit_guard_event(
            self.name, getattr(self.spec, "backend", "reference"), entry,
            self.guard, deg,
        )
        return w

    def _guard_log_weights(self, lw: jnp.ndarray, entry: str):
        """§16 guard for the fused step: returns ``(lw_run, degenerate)``.

        ``degenerate`` (``metrics.degenerate_log_weights``) is composed
        into ``StepStats`` under EVERY policy — the flag itself is free
        telemetry, so 'off' and 'flag' trace to the identical jaxpr.  At
        ``'recover'`` degenerate rows are replaced by the all-zeros
        log-weight bank (uniform weights) before dispatch, so the kernel
        runs a clean-input program with the same key: RNG is consumed
        branch-independently and every output is finite."""
        deg = degenerate_log_weights(lw, axis=-1)
        if self.guard == "recover":
            lw = jnp.where(jnp.expand_dims(deg, -1), jnp.zeros_like(lw), lw)
        if self.guard != "off":
            maybe_emit_guard_event(
                self.name, getattr(self.spec, "backend", "reference"), entry,
                self.guard, deg,
            )
        return lw, deg

    def __call__(self, key: jax.Array, weights: jnp.ndarray) -> jnp.ndarray:
        if weights.ndim != 1:
            raise ValueError(
                f"{self.name}: expected weights[N]; got shape {weights.shape} "
                "(use .batch for weights[B, N])"
            )
        with self._span("single"):
            return self._single(
                key, self._guard_weights(self.quantise(weights), "single")
            )

    def batch(self, key: jax.Array, weights: jnp.ndarray) -> jnp.ndarray:
        if weights.ndim != 2:
            raise ValueError(
                f"{self.name}.batch: expected weights[B, N]; got shape {weights.shape}"
            )
        with self._span("batch"):
            return self._batch(
                key, self._guard_weights(self.quantise(weights), "batch")
            )

    def batch_rows(self, keys: jax.Array, weights: jnp.ndarray) -> jnp.ndarray:
        """vmap the single-population call over explicit per-row keys.

        The filter-bank path: callers that already carry per-row key chains
        (``run_filter_bank``) join the batched launch without re-deriving
        keys.  Row ``b`` is bit-identical to ``self(keys[b], weights[b])``.
        """
        if weights.ndim != 2:
            raise ValueError(
                f"{self.name}.batch_rows: expected weights[B, N]; got shape {weights.shape}"
            )
        with self._span("batch_rows"):
            return jax.vmap(self._single)(
                keys, self._guard_weights(self.quantise(weights), "batch_rows")
            )

    def _check_state(self, weights, particles, who: str, lead: int = 1):
        if particles.ndim < lead or particles.shape[:lead] != weights.shape[:lead]:
            raise ValueError(
                f"{self.name}.{who}: particles must lead with the "
                f"{'[B, N]' if lead == 2 else '[N]'} axes of weights; got "
                f"particles {particles.shape} for weights {weights.shape}"
            )

    def apply(self, key: jax.Array, weights: jnp.ndarray, particles: jnp.ndarray):
        """Fused resample+gather: ``(particles', ancestors)`` over one
        population (DESIGN.md §11).  ``particles'[i] = particles[a[i]]``
        with ``a`` bit-identical to ``self(key, weights)``."""
        if weights.ndim != 1:
            raise ValueError(
                f"{self.name}.apply: expected weights[N]; got shape {weights.shape} "
                "(use .apply_batch for weights[B, N])"
            )
        self._check_state(weights, particles, "apply")
        with self._span("apply"):
            return self._apply(
                key, self._guard_weights(self.quantise(weights), "apply"),
                self.quantise(particles),
            )

    def apply_batch(self, key: jax.Array, weights: jnp.ndarray, particles: jnp.ndarray):
        """Bank form of ``apply`` under the §4 split-key contract."""
        if weights.ndim != 2:
            raise ValueError(
                f"{self.name}.apply_batch: expected weights[B, N]; got shape "
                f"{weights.shape}"
            )
        self._check_state(weights, particles, "apply_batch", lead=2)
        with self._span("apply_batch"):
            return self._apply_batch(
                key, self._guard_weights(self.quantise(weights), "apply_batch"),
                self.quantise(particles),
            )

    def apply_rows(self, keys: jax.Array, weights: jnp.ndarray, particles: jnp.ndarray):
        """``apply`` over explicit per-row keys (the filter-bank path): row
        ``b`` is bit-identical to ``self.apply(keys[b], weights[b],
        particles[b])``; on kernel backends with a leading-batch-grid fused
        kernel (Megopolis, Metropolis, rejection) this is ONE launch."""
        if weights.ndim != 2:
            raise ValueError(
                f"{self.name}.apply_rows: expected weights[B, N]; got shape "
                f"{weights.shape}"
            )
        if keys.shape[0] != weights.shape[0]:
            # The fused bank kernels size their grid from weights; a short
            # key array would read out-of-bounds seeds instead of failing
            # like the vmap-derived batch_rows does — check here, once,
            # for every backend.
            raise ValueError(
                f"{self.name}.apply_rows: expected one key per row; got "
                f"{keys.shape[0]} keys for weights[{weights.shape[0]}, ...]"
            )
        self._check_state(weights, particles, "apply_rows", lead=2)
        with self._span("apply_rows"):
            return self._apply_rows(
                keys, self._guard_weights(self.quantise(weights), "apply_rows"),
                self.quantise(particles),
            )

    def step(
        self,
        key: jax.Array,
        log_weights: jnp.ndarray,
        particles: jnp.ndarray,
        ess_threshold,
    ):
        """Fused SMC step over one population (DESIGN.md §12): returns
        ``(particles', ancestors, stats)`` with ``stats`` a ``StepStats``
        record (DESIGN.md §15).  Resamples iff ``ess_norm < ess_threshold``
        (strict: a threshold of 0 never fires, a population exactly at
        threshold does not fire); the resample branch is bit-identical to
        ``self.apply(key, normalise_log_weights(log_weights), particles)``,
        the no-op branch returns ``particles`` unchanged with identity
        ancestors and ``incr = 0``.  The key is consumed either way.  The
        stats vector comes straight out of the (single) kernel launch on
        the pallas backends; ``survivors`` is composed here from the
        returned ancestors — consumers that drop the record compile the
        exact pre-telemetry program (analyzer pass 6)."""
        if log_weights.ndim != 1:
            raise ValueError(
                f"{self.name}.step: expected log_weights[N]; got shape "
                f"{log_weights.shape} (use .step_rows for log_weights[B, N])"
            )
        self._check_state(log_weights, particles, "step")
        with self._span("step"):
            lw_run, deg = self._guard_log_weights(
                self.quantise(log_weights), "step"
            )
            p_out, ancestors, stats4 = self._step(
                key, lw_run, self.quantise(particles), ess_threshold,
            )
            stats = stats_from_vector(
                stats4, unique_ancestor_count(ancestors), deg
            )
        return p_out, ancestors, stats

    def step_rows(
        self,
        keys: jax.Array,
        log_weights: jnp.ndarray,
        particles: jnp.ndarray,
        ess_threshold,
    ):
        """``step`` over explicit per-row keys (the filter-bank path): row
        ``b`` is bit-identical to ``self.step(keys[b], log_weights[b],
        particles[b], ess_threshold)`` — each row takes its OWN branch and
        the returned ``StepStats`` record is batched ``[B]`` per field.  On
        kernel backends with a leading-batch-grid step kernel (Megopolis,
        Metropolis, rejection) this is ONE launch."""
        if log_weights.ndim != 2:
            raise ValueError(
                f"{self.name}.step_rows: expected log_weights[B, N]; got shape "
                f"{log_weights.shape}"
            )
        if keys.shape[0] != log_weights.shape[0]:
            raise ValueError(
                f"{self.name}.step_rows: expected one key per row; got "
                f"{keys.shape[0]} keys for log_weights[{log_weights.shape[0]}, ...]"
            )
        self._check_state(log_weights, particles, "step_rows", lead=2)
        with self._span("step_rows"):
            lw_run, deg = self._guard_log_weights(
                self.quantise(log_weights), "step_rows"
            )
            p_out, ancestors, stats4 = self._step_rows(
                keys, lw_run, self.quantise(particles), ess_threshold,
            )
            stats = stats_from_vector(
                stats4, unique_ancestor_count(ancestors), deg
            )
        return p_out, ancestors, stats

    def __repr__(self):
        return f"Resampler({self.spec!r})"


@dataclasses.dataclass(frozen=True)
class ResamplerSpec:
    """Base class: frozen, hashable, static-safe spec of one resampler family."""

    _NAME: ClassVar[str] = ""

    @property
    def name(self) -> str:
        return self._NAME

    def replace(self, **changes) -> "ResamplerSpec":
        """Return a validated copy with ``changes`` applied (sweep-friendly)."""
        return dataclasses.replace(self, **changes)

    def build(self) -> Resampler:
        raise NotImplementedError

    def build_resilient(self, *, ladder=None, recorder=None, probe=True) -> Resampler:
        """Build with the §16 backend fallback ladder: try this spec's
        backend, demoting rung by rung (default pallas → pallas_interpret →
        xla → reference) on typed build/probe failures, emitting one
        ``backend_demotion`` ``ResilienceEvent`` per rung into ``recorder``.
        Raises ``BackendUnavailable`` (with per-rung causes) only when every
        rung fails."""
        from repro.resilience.fallback import build_with_fallback

        return build_with_fallback(
            self, ladder=ladder, recorder=recorder, probe=probe
        )


def _resolve_iters_dynamic(num_iters, weights):
    """Trace-safe iteration count: eq. (3) when 'auto', else the static int."""
    if num_iters == AUTO:
        return jnp.minimum(select_iterations(weights), AUTO_MAX_ITERS)
    return num_iters


def _resolve_iters_static(num_iters, weights, name: str) -> int:
    """Concrete iteration count for kernel grids (pallas backends)."""
    if num_iters != AUTO:
        return num_iters
    if _is_traced(weights):
        raise TypeError(
            f"{name}: num_iters='auto' under a pallas backend needs concrete "
            "weights (B sets the kernel grid); pass an int num_iters to use "
            "this spec inside jit."
        )
    return int(select_iterations(weights))


def _per_row_auto_batch(spec, single):
    """Pallas ``.batch`` under ``num_iters='auto'``: eq. (3) must see EACH
    row's weights — resolving one bank-level B would silently under-iterate
    concentrated rows — and the §4 contract (row b bit-identical to the
    single call with split key b) must survive, so the rows are launched
    individually with their own static B.  Needs concrete weights (host
    loop); inside jit pass an int ``num_iters``."""

    def batch(key, w):
        if _is_traced(w):
            raise TypeError(
                f"{spec.name}: num_iters='auto' under a pallas backend needs "
                "concrete weights (eq. 3 resolves per row); pass an int "
                "num_iters to use .batch inside jit."
            )
        keys = split_batch_keys(key, w.shape[0])
        return jnp.stack([single(keys[b], w[b]) for b in range(w.shape[0])])

    return batch


def _per_row_auto_apply(spec, apply_single, *, explicit_keys: bool):
    """The ``apply`` analogue of ``_per_row_auto_batch``: eq. (3) resolves
    per row, so 'auto' bank applies launch row-by-row over concrete
    weights; inside jit pass an int ``num_iters``."""

    def fn(key_or_keys, w, p):
        if _is_traced(w):
            raise TypeError(
                f"{spec.name}: num_iters='auto' under a pallas backend needs "
                "concrete weights (eq. 3 resolves per row); pass an int "
                "num_iters to use the bank apply forms inside jit."
            )
        keys = key_or_keys if explicit_keys else split_batch_keys(key_or_keys, w.shape[0])
        outs = [apply_single(keys[b], w[b], p[b]) for b in range(w.shape[0])]
        return jnp.stack([o[0] for o in outs]), jnp.stack([o[1] for o in outs])

    return fn


def _per_row_auto_step(spec, step_single):
    """The ``step`` analogue of ``_per_row_auto_apply``: eq. (3) resolves
    per row from each row's normalised weights, so 'auto' bank steps launch
    row-by-row over concrete log-weights; inside jit pass an int
    ``num_iters``."""

    def fn(keys, log_w, p, thr):
        if _is_traced(log_w):
            raise TypeError(
                f"{spec.name}: num_iters='auto' under a pallas backend needs "
                "concrete log-weights (eq. 3 resolves per row); pass an int "
                "num_iters to use step_rows inside jit."
            )
        outs = [step_single(keys[b], log_w[b], p[b], thr) for b in range(log_w.shape[0])]
        return tuple(jnp.stack([o[i] for o in outs]) for i in range(3))

    return fn


def _maybe_jit(single, batch, backend: str):
    """backend='xla' is the reference algorithm jit-wrapped (bit-identical)."""
    if backend == "xla":
        return jax.jit(single), jax.jit(batch)
    return single, batch


def _vmap_batch(single):
    """Derive the standard DESIGN.md §4 batched form: split keys + vmap."""

    def batch(key, weights):
        keys = split_batch_keys(key, weights.shape[0])
        return jax.vmap(single)(keys, weights)

    return batch


@dataclasses.dataclass(frozen=True)
class MegopolisSpec(ResamplerSpec):
    """The paper's contribution (Alg. 5): segment-coalesced Metropolis.

    ``segment`` is the coalescing segment size S of the reference path; the
    pallas backends run the TPU kernel, whose S is fixed at one VMEM tile
    (``KERNEL_SEGMENT`` = 1024) — constructing a pallas spec therefore
    requires ``segment=1024`` so the coalescing contract stays explicit.
    """

    num_iters: Union[int, str] = AUTO
    segment: int = DEFAULT_SEGMENT
    backend: str = "reference"
    plane_dtype: str = "float32"
    guard: str = "off"

    _NAME: ClassVar[str] = "megopolis"

    def __post_init__(self):
        _check_num_iters(self.num_iters, "MegopolisSpec")
        _check_positive_int(self.segment, "segment", "MegopolisSpec")
        _check_backend(self.backend, "MegopolisSpec")
        _check_plane_dtype(self.plane_dtype, "MegopolisSpec")
        check_guard_policy(self.guard, "MegopolisSpec")
        if self.backend in ("pallas", "pallas_interpret") and self.segment != KERNEL_SEGMENT:
            raise ValueError(
                f"MegopolisSpec: the pallas kernel coalesces at segment="
                f"{KERNEL_SEGMENT} (one f32 VMEM tile); got segment={self.segment}. "
                "Set segment=1024 or use backend='reference'/'xla'."
            )

    def build(self) -> Resampler:
        if self.backend in ("pallas", "pallas_interpret"):
            # Lazy import: kernels are only a dependency of pallas specs.
            from repro.kernels.megopolis.ops import (
                megopolis_tpu,
                megopolis_tpu_apply,
                megopolis_tpu_apply_batch,
                megopolis_tpu_apply_rows,
                megopolis_tpu_batch,
                megopolis_tpu_step,
                megopolis_tpu_step_rows,
            )

            interpret = self.backend == "pallas_interpret"
            pd = self.plane_dtype

            def single(key, w):
                b = _resolve_iters_static(self.num_iters, w, self.name)
                return megopolis_tpu(key, w, b, interpret=interpret, plane_dtype=pd)

            def batch(key, w):
                b = _resolve_iters_static(self.num_iters, w, self.name)
                return megopolis_tpu_batch(key, w, b, interpret=interpret,
                                           plane_dtype=pd)

            def apply(key, w, p):
                b = _resolve_iters_static(self.num_iters, w, self.name)
                return megopolis_tpu_apply(key, w, p, b, interpret=interpret,
                                           plane_dtype=pd)

            def apply_batch(key, w, p):
                # Same bank-level resolve + shared-offset contract as .batch,
                # so apply_batch ancestors == .batch ancestors under 'auto'.
                b = _resolve_iters_static(self.num_iters, w, self.name)
                return megopolis_tpu_apply_batch(key, w, p, b, interpret=interpret,
                                                 plane_dtype=pd)

            def step(key, lw, p, thr):
                # eq. (3) sees the SAME normalised weights the composed
                # path hands to apply — fused/composed 'auto' agree on B.
                b = _resolve_iters_static(
                    self.num_iters, normalise_log_weights(lw), self.name
                )
                return megopolis_tpu_step(key, lw, p, b, thr, interpret=interpret,
                                          plane_dtype=pd)

            if self.num_iters == AUTO:
                # batch_rows' per-row contract needs eq. (3) PER ROW.
                apply_rows = _per_row_auto_apply(self, apply, explicit_keys=True)
                step_rows = _per_row_auto_step(self, step)
            else:

                def apply_rows(keys, w, p):
                    return megopolis_tpu_apply_rows(
                        keys, w, p, self.num_iters, interpret=interpret,
                        plane_dtype=pd,
                    )

                def step_rows(keys, lw, p, thr):
                    return megopolis_tpu_step_rows(
                        keys, lw, p, self.num_iters, thr, interpret=interpret,
                        plane_dtype=pd,
                    )

            return Resampler(self, single, batch, apply=apply,
                             apply_batch=apply_batch, apply_rows=apply_rows,
                             step=step, step_rows=step_rows)

        seg = self.segment

        if self.num_iters == AUTO:

            def single(key, w):
                # eq. (3) resolves at call time; the loop runs the (possibly
                # traced) selected bound over an offset table drawn at the
                # static cap.  NB: a (AUTO_MAX_ITERS,) draw shares no prefix
                # with a (B,) draw, so 'auto' is a distinct random stream
                # from the same spec with num_iters=B pinned (unlike the
                # Metropolis family, where the two are bit-identical).
                b = _resolve_iters_dynamic(AUTO, w)
                key_off, _ = jax.random.split(key)
                offsets = jax.random.randint(key_off, (AUTO_MAX_ITERS,), 0, w.shape[0])
                return megopolis(key, w, b, segment=seg, offsets=offsets)

        else:

            def single(key, w):
                return megopolis(key, w, self.num_iters, segment=seg)

        single_fn, batch_fn = _maybe_jit(single, _vmap_batch(single), self.backend)
        return Resampler(self, single_fn, batch_fn)


def _metropolis_family_build(spec, fn, extra_kwargs: dict) -> Resampler:
    """Shared build for the fixed-point accept/reject loops (Algs. 2-4):
    ``num_iters`` is only a loop bound + fold_in counter, so the 'auto'
    (traced) count is bit-identical to the same static count."""

    def single(key, w):
        b = _resolve_iters_dynamic(spec.num_iters, w)
        return fn(key, w, b, **extra_kwargs)

    single_fn, batch_fn = _maybe_jit(single, _vmap_batch(single), spec.backend)
    return Resampler(spec, single_fn, batch_fn)


@dataclasses.dataclass(frozen=True)
class MetropolisSpec(ResamplerSpec):
    """Paper Alg. 2: the random-access Metropolis baseline."""

    num_iters: Union[int, str] = AUTO
    backend: str = "reference"
    plane_dtype: str = "float32"
    guard: str = "off"

    _NAME: ClassVar[str] = "metropolis"

    def __post_init__(self):
        _check_num_iters(self.num_iters, "MetropolisSpec")
        _check_backend(self.backend, "MetropolisSpec")
        _check_plane_dtype(self.plane_dtype, "MetropolisSpec")
        check_guard_policy(self.guard, "MetropolisSpec")

    def build(self) -> Resampler:
        if self.backend in PALLAS_BACKENDS:
            from repro.kernels.metropolis.ops import (
                metropolis_tpu,
                metropolis_tpu_apply,
                metropolis_tpu_apply_batch,
                metropolis_tpu_apply_rows,
                metropolis_tpu_batch,
                metropolis_tpu_step,
                metropolis_tpu_step_rows,
            )

            interpret = self.backend == "pallas_interpret"
            pd = self.plane_dtype

            def single(key, w):
                b = _resolve_iters_static(self.num_iters, w, self.name)
                return metropolis_tpu(key, w, b, interpret=interpret, plane_dtype=pd)

            def apply(key, w, p):
                b = _resolve_iters_static(self.num_iters, w, self.name)
                return metropolis_tpu_apply(key, w, p, b, interpret=interpret,
                                            plane_dtype=pd)

            def step(key, lw, p, thr):
                b = _resolve_iters_static(
                    self.num_iters, normalise_log_weights(lw), self.name
                )
                return metropolis_tpu_step(key, lw, p, b, thr, interpret=interpret,
                                           plane_dtype=pd)

            if self.num_iters == AUTO:
                batch = _per_row_auto_batch(self, single)
                apply_batch = _per_row_auto_apply(self, apply, explicit_keys=False)
                apply_rows = _per_row_auto_apply(self, apply, explicit_keys=True)
                step_rows = _per_row_auto_step(self, step)
            else:

                def batch(key, w):
                    # One [B, R, 128] launch; row b bit-identical to the
                    # single kernel with split(key, B)[b] (held on-kernel,
                    # DESIGN.md §4).
                    return metropolis_tpu_batch(
                        key, w, self.num_iters, interpret=interpret, plane_dtype=pd
                    )

                def apply_batch(key, w, p):
                    return metropolis_tpu_apply_batch(
                        key, w, p, self.num_iters, interpret=interpret,
                        plane_dtype=pd,
                    )

                def apply_rows(keys, w, p):
                    return metropolis_tpu_apply_rows(
                        keys, w, p, self.num_iters, interpret=interpret,
                        plane_dtype=pd,
                    )

                def step_rows(keys, lw, p, thr):
                    return metropolis_tpu_step_rows(
                        keys, lw, p, self.num_iters, thr, interpret=interpret,
                        plane_dtype=pd,
                    )

            return Resampler(self, single, batch, apply=apply,
                             apply_batch=apply_batch, apply_rows=apply_rows,
                             step=step, step_rows=step_rows)
        return _metropolis_family_build(self, metropolis, {})


def _check_kernel_partition(spec, cls: str):
    """The C1/C2 kernels' partition is one (8,128) f32 VMEM tile: pallas
    specs must say so (same explicitness rule as MegopolisSpec.segment)."""
    if spec.backend in PALLAS_BACKENDS and spec.partition_size_bytes != KERNEL_PARTITION_BYTES:
        raise ValueError(
            f"{cls}: the pallas kernel's partition is one f32 VMEM tile = "
            f"{KERNEL_PARTITION_BYTES} bytes; got partition_size_bytes="
            f"{spec.partition_size_bytes}. Set partition_size_bytes=4096 or "
            "use backend='reference'/'xla'."
        )


def _c1c2_pallas_build(spec, tpu_fn, tpu_apply_fn, tpu_step_fn) -> Resampler:
    """Shared pallas build for the segment-local variants: single kernel
    call, batch via lax.map over split keys (row b == single with key b —
    the same §4 contract the reference lane derives by vmap).  'auto'
    batches resolve eq. (3) per row (see ``_per_row_auto_batch``: lax.map
    would hand ``single`` traced rows and a bank-level B would be wrong).
    The fused ``apply``/``step`` forms compose the same way: C1/C2 have no
    leading-batch-grid kernel, so the bank forms map the fused single."""

    interpret = spec.backend == "pallas_interpret"
    pd = spec.plane_dtype

    def single(key, w):
        b = _resolve_iters_static(spec.num_iters, w, spec.name)
        return tpu_fn(key, w, b, interpret=interpret, plane_dtype=pd)

    def apply(key, w, p):
        b = _resolve_iters_static(spec.num_iters, w, spec.name)
        return tpu_apply_fn(key, w, p, b, interpret=interpret, plane_dtype=pd)

    def step(key, lw, p, thr):
        b = _resolve_iters_static(
            spec.num_iters, normalise_log_weights(lw), spec.name
        )
        return tpu_step_fn(key, lw, p, b, thr, interpret=interpret, plane_dtype=pd)

    if spec.num_iters == AUTO:
        batch = _per_row_auto_batch(spec, single)
        apply_batch = _per_row_auto_apply(spec, apply, explicit_keys=False)
        apply_rows = _per_row_auto_apply(spec, apply, explicit_keys=True)
        step_rows = _per_row_auto_step(spec, step)
    else:

        def batch(key, w):
            keys = split_batch_keys(key, w.shape[0])
            return jax.lax.map(lambda kw: single(kw[0], kw[1]), (keys, w))

        def apply_batch(key, w, p):
            keys = split_batch_keys(key, w.shape[0])
            return jax.lax.map(lambda kwp: apply(*kwp), (keys, w, p))

        def apply_rows(keys, w, p):
            return jax.lax.map(lambda kwp: apply(*kwp), (keys, w, p))

        def step_rows(keys, lw, p, thr):
            return jax.lax.map(
                lambda klp: step(klp[0], klp[1], klp[2], thr), (keys, lw, p)
            )

    return Resampler(spec, single, batch, apply=apply,
                     apply_batch=apply_batch, apply_rows=apply_rows,
                     step=step, step_rows=step_rows)


@dataclasses.dataclass(frozen=True)
class MetropolisC1Spec(ResamplerSpec):
    """Paper Alg. 3 (Dülger C1): one warp-shared partition, all iterations.

    The pallas kernel shares the partition at tile granularity (its "warp"
    is the whole 1024-lane tile; ``warp`` is a reference-lane knob) and
    requires ``partition_size_bytes=4096`` — one f32 VMEM tile.
    """

    num_iters: Union[int, str] = AUTO
    partition_size_bytes: int = 128
    warp: int = WARP
    backend: str = "reference"
    plane_dtype: str = "float32"
    guard: str = "off"

    _NAME: ClassVar[str] = "metropolis_c1"

    def __post_init__(self):
        _check_num_iters(self.num_iters, "MetropolisC1Spec")
        _check_positive_int(self.partition_size_bytes, "partition_size_bytes", "MetropolisC1Spec")
        _check_positive_int(self.warp, "warp", "MetropolisC1Spec")
        _check_backend(self.backend, "MetropolisC1Spec")
        _check_kernel_partition(self, "MetropolisC1Spec")
        _check_plane_dtype(self.plane_dtype, "MetropolisC1Spec")
        check_guard_policy(self.guard, "MetropolisC1Spec")

    def build(self) -> Resampler:
        if self.backend in PALLAS_BACKENDS:
            from repro.kernels.metropolis.ops import (
                metropolis_c1_tpu,
                metropolis_c1_tpu_apply,
                metropolis_c1_tpu_step,
            )

            return _c1c2_pallas_build(
                self, metropolis_c1_tpu, metropolis_c1_tpu_apply,
                metropolis_c1_tpu_step,
            )
        return _metropolis_family_build(
            self,
            metropolis_c1,
            {"partition_size_bytes": self.partition_size_bytes, "warp": self.warp},
        )


@dataclasses.dataclass(frozen=True)
class MetropolisC2Spec(ResamplerSpec):
    """Paper Alg. 4 (Dülger C2): fresh warp-shared partition per iteration.

    Pallas geometry as for C1: tile-granular sharing,
    ``partition_size_bytes=4096`` required.
    """

    num_iters: Union[int, str] = AUTO
    partition_size_bytes: int = 128
    warp: int = WARP
    backend: str = "reference"
    plane_dtype: str = "float32"
    guard: str = "off"

    _NAME: ClassVar[str] = "metropolis_c2"

    def __post_init__(self):
        _check_num_iters(self.num_iters, "MetropolisC2Spec")
        _check_positive_int(self.partition_size_bytes, "partition_size_bytes", "MetropolisC2Spec")
        _check_positive_int(self.warp, "warp", "MetropolisC2Spec")
        _check_backend(self.backend, "MetropolisC2Spec")
        _check_kernel_partition(self, "MetropolisC2Spec")
        _check_plane_dtype(self.plane_dtype, "MetropolisC2Spec")
        check_guard_policy(self.guard, "MetropolisC2Spec")

    def build(self) -> Resampler:
        if self.backend in PALLAS_BACKENDS:
            from repro.kernels.metropolis.ops import (
                metropolis_c2_tpu,
                metropolis_c2_tpu_apply,
                metropolis_c2_tpu_step,
            )

            return _c1c2_pallas_build(
                self, metropolis_c2_tpu, metropolis_c2_tpu_apply,
                metropolis_c2_tpu_step,
            )
        return _metropolis_family_build(
            self,
            metropolis_c2,
            {"partition_size_bytes": self.partition_size_bytes, "warp": self.warp},
        )


@dataclasses.dataclass(frozen=True)
class RejectionSpec(ResamplerSpec):
    """Murray's rejection resampler (§1 context): unbiased, capped loop."""

    max_iters: int = 1024
    backend: str = "reference"
    plane_dtype: str = "float32"
    guard: str = "off"

    _NAME: ClassVar[str] = "rejection"

    def __post_init__(self):
        _check_positive_int(self.max_iters, "max_iters", "RejectionSpec")
        _check_backend(self.backend, "RejectionSpec")
        _check_plane_dtype(self.plane_dtype, "RejectionSpec")
        check_guard_policy(self.guard, "RejectionSpec")

    def build(self) -> Resampler:
        if self.backend in PALLAS_BACKENDS:
            from repro.kernels.rejection.ops import (
                rejection_tpu,
                rejection_tpu_apply,
                rejection_tpu_apply_batch,
                rejection_tpu_apply_rows,
                rejection_tpu_batch,
                rejection_tpu_step,
                rejection_tpu_step_rows,
            )

            interpret = self.backend == "pallas_interpret"
            pd = self.plane_dtype

            def single(key, w):
                return rejection_tpu(key, w, max_iters=self.max_iters,
                                     interpret=interpret, plane_dtype=pd)

            def batch(key, w):
                return rejection_tpu_batch(
                    key, w, max_iters=self.max_iters, interpret=interpret,
                    plane_dtype=pd,
                )

            def apply(key, w, p):
                return rejection_tpu_apply(
                    key, w, p, max_iters=self.max_iters, interpret=interpret,
                    plane_dtype=pd,
                )

            def apply_batch(key, w, p):
                return rejection_tpu_apply_batch(
                    key, w, p, max_iters=self.max_iters, interpret=interpret,
                    plane_dtype=pd,
                )

            def apply_rows(keys, w, p):
                return rejection_tpu_apply_rows(
                    keys, w, p, max_iters=self.max_iters, interpret=interpret,
                    plane_dtype=pd,
                )

            def step(key, lw, p, thr):
                return rejection_tpu_step(
                    key, lw, p, thr, max_iters=self.max_iters, interpret=interpret,
                    plane_dtype=pd,
                )

            def step_rows(keys, lw, p, thr):
                return rejection_tpu_step_rows(
                    keys, lw, p, thr, max_iters=self.max_iters, interpret=interpret,
                    plane_dtype=pd,
                )

            return Resampler(self, single, batch, apply=apply,
                             apply_batch=apply_batch, apply_rows=apply_rows,
                             step=step, step_rows=step_rows)

        def single(key, w):
            return rejection(key, w, max_iters=self.max_iters)

        single_fn, batch_fn = _maybe_jit(single, _vmap_batch(single), self.backend)
        return Resampler(self, single_fn, batch_fn)


_PREFIX_SUM_KINDS = {
    "multinomial": multinomial,
    "systematic": systematic,
    "improved_systematic": improved_systematic,
    "stratified": stratified,
    "residual": residual,
}


@dataclasses.dataclass(frozen=True)
class PrefixSumSpec(ResamplerSpec):
    """The prefix-sum family (§6.5): Algs. 7/8 + classical extras.

    ``kind`` selects the algorithm; none takes an iteration count (the
    family's whole point — one cumsum, one search)."""

    kind: str = "systematic"
    backend: str = "reference"
    plane_dtype: str = "float32"
    guard: str = "off"

    def __post_init__(self):
        if self.kind not in _PREFIX_SUM_KINDS:
            hint = difflib.get_close_matches(str(self.kind), _PREFIX_SUM_KINDS, n=1)
            did_you_mean = f" — did you mean {hint[0]!r}?" if hint else ""
            raise ValueError(
                f"PrefixSumSpec.kind must be one of {sorted(_PREFIX_SUM_KINDS)}; "
                f"got {self.kind!r}{did_you_mean}"
            )
        _check_backend(self.backend, "PrefixSumSpec")
        _check_plane_dtype(self.plane_dtype, "PrefixSumSpec")
        check_guard_policy(self.guard, "PrefixSumSpec")

    @property
    def name(self) -> str:
        return self.kind

    def build(self) -> Resampler:
        if self.backend in PALLAS_BACKENDS:
            from repro.kernels.prefix_sum.ops import (
                prefix_resample_tpu,
                prefix_resample_tpu_apply,
                prefix_resample_tpu_step,
            )

            interpret = self.backend == "pallas_interpret"
            kind = self.kind
            pd = self.plane_dtype

            def single(key, w):
                return prefix_resample_tpu(key, w, kind, interpret=interpret,
                                           plane_dtype=pd)

            def batch(key, w):
                # Scan + search per row under lax.map (row b == single with
                # split(key, B)[b], the §4 contract).
                keys = split_batch_keys(key, w.shape[0])
                return jax.lax.map(lambda kw: single(kw[0], kw[1]), (keys, w))

            def apply(key, w, p):
                return prefix_resample_tpu_apply(key, w, p, kind, interpret=interpret,
                                                 plane_dtype=pd)

            def apply_batch(key, w, p):
                keys = split_batch_keys(key, w.shape[0])
                return jax.lax.map(lambda kwp: apply(*kwp), (keys, w, p))

            def apply_rows(keys, w, p):
                return jax.lax.map(lambda kwp: apply(*kwp), (keys, w, p))

            def step(key, lw, p, thr):
                return prefix_resample_tpu_step(
                    key, lw, p, thr, kind, interpret=interpret, plane_dtype=pd
                )

            def step_rows(keys, lw, p, thr):
                # No leading-batch-grid step kernel for this family yet:
                # the bank form maps the single-launch step (same shape as
                # apply_rows above).
                return jax.lax.map(
                    lambda klp: step(klp[0], klp[1], klp[2], thr), (keys, lw, p)
                )

            return Resampler(self, single, batch, apply=apply,
                             apply_batch=apply_batch, apply_rows=apply_rows,
                             step=step, step_rows=step_rows)

        fn = _PREFIX_SUM_KINDS[self.kind]

        def single(key, w):
            return fn(key, w)

        single_fn, batch_fn = _maybe_jit(single, _vmap_batch(single), self.backend)
        return Resampler(self, single_fn, batch_fn)


for _cls in (
    MegopolisSpec,
    MetropolisSpec,
    MetropolisC1Spec,
    MetropolisC2Spec,
    RejectionSpec,
    PrefixSumSpec,
):
    jax.tree_util.register_static(_cls)


# ----------------------------------------------------------------------------
# The ONE family table: registry name -> (spec constructor kwargs, legacy fns).
# Everything name-keyed (spec_from_name, get_resampler, get_resampler_batch,
# list_resamplers) derives from this single surface.
# ----------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class _Family:
    spec_cls: type
    spec_fixed: Tuple[Tuple[str, Any], ...]  # kwargs frozen into the name
    legacy_single: Callable
    legacy_batch: Callable


_FAMILIES = {
    "megopolis": _Family(MegopolisSpec, (), megopolis, megopolis_batch),
    "metropolis": _Family(MetropolisSpec, (), metropolis, metropolis_batch),
    "metropolis_c1": _Family(MetropolisC1Spec, (), metropolis_c1, metropolis_c1_batch),
    "metropolis_c2": _Family(MetropolisC2Spec, (), metropolis_c2, metropolis_c2_batch),
    "rejection": _Family(RejectionSpec, (), rejection, rejection_batch),
    **{
        kind: _Family(
            PrefixSumSpec,
            (("kind", kind),),
            _PREFIX_SUM_KINDS[kind],
            {
                "multinomial": multinomial_batch,
                "systematic": systematic_batch,
                "improved_systematic": improved_systematic_batch,
                "stratified": stratified_batch,
                "residual": residual_batch,
            }[kind],
        )
        for kind in _PREFIX_SUM_KINDS
    },
}


def _unknown_name_error(name: str) -> KeyError:
    choices = sorted(_FAMILIES)
    hint = difflib.get_close_matches(str(name), choices, n=1)
    did_you_mean = f" — did you mean {hint[0]!r}?" if hint else ""
    return KeyError(f"unknown resampler {name!r}{did_you_mean}; choices: {choices}")


def _family(name: str) -> _Family:
    try:
        return _FAMILIES[name]
    except KeyError:
        raise _unknown_name_error(name) from None


def spec_from_name(name: str, **kwargs) -> ResamplerSpec:
    """Build the typed spec for a registry name: ``spec_from_name('megopolis',
    num_iters=24)`` == ``MegopolisSpec(num_iters=24)``.

    For legacy API uniformity a ``num_iters`` kwarg is tolerated (and
    dropped) on iteration-free families — the prefix-sum and rejection
    entries always ignored it.  Any other unknown kwarg raises eagerly.
    """
    fam = _family(name)
    fields = {f.name for f in dataclasses.fields(fam.spec_cls)}
    if "num_iters" not in fields:
        kwargs.pop("num_iters", None)
    unknown = sorted(set(kwargs) - fields)
    if unknown:
        raise TypeError(
            f"{name}: unknown spec argument(s) {unknown}; "
            f"{fam.spec_cls.__name__} fields are {sorted(fields)}"
        )
    return fam.spec_cls(**dict(fam.spec_fixed), **kwargs)


def spec_for_backend(
    name: str, backend: str, *, num_iters: Union[int, str] = 16,
    max_iters: int = 64, plane_dtype: str = "float32", guard: str = "off",
) -> ResamplerSpec:
    """A kernel-legal spec for any (family, backend) cell of the matrix.

    Sweep-driver convenience: fills in the tile-fixed geometry the pallas
    kernels require (``segment=KERNEL_SEGMENT`` for Megopolis,
    ``partition_size_bytes=KERNEL_PARTITION_BYTES`` for C1/C2) so drivers
    iterating family × backend (benchmarks/ais_bench.py, tests/test_ais.py)
    don't each re-encode the legality table.  ``tests/test_backend_parity.py``
    deliberately keeps its own copy — the parity gate pins the contract
    independently of this helper.
    """
    pallas = backend in PALLAS_BACKENDS
    fam = _family(name)
    if fam.spec_cls is MegopolisSpec:
        return MegopolisSpec(num_iters=num_iters,
                             segment=KERNEL_SEGMENT if pallas else DEFAULT_SEGMENT,
                             backend=backend, plane_dtype=plane_dtype,
                             guard=guard)
    if fam.spec_cls in (MetropolisC1Spec, MetropolisC2Spec):
        return fam.spec_cls(
            num_iters=num_iters,
            partition_size_bytes=KERNEL_PARTITION_BYTES if pallas else 128,
            backend=backend, plane_dtype=plane_dtype, guard=guard,
        )
    if fam.spec_cls is RejectionSpec:
        return RejectionSpec(max_iters=max_iters, backend=backend,
                             plane_dtype=plane_dtype, guard=guard)
    if fam.spec_cls is MetropolisSpec:
        return MetropolisSpec(num_iters=num_iters, backend=backend,
                              plane_dtype=plane_dtype, guard=guard)
    return PrefixSumSpec(kind=name, backend=backend, plane_dtype=plane_dtype,
                         guard=guard)


def coerce_spec(resampler: Union[str, ResamplerSpec], /, **defaults) -> ResamplerSpec:
    """Normalise ``str | ResamplerSpec`` to a spec, applying ``defaults`` only
    where the family actually has the field.

    The uniform-call-site helper: ``coerce_spec(name_or_spec, num_iters=b,
    segment=s)`` configures Megopolis/Metropolis variants and leaves the
    prefix-sum family untouched — no per-algorithm conditionals at call
    sites.  A spec passed in is returned with the same field filtering, so
    explicit specs can still be bulk-configured by a sweep driver.
    """
    spec = spec_from_name(resampler) if isinstance(resampler, str) else resampler
    if not isinstance(spec, ResamplerSpec):
        raise TypeError(
            f"expected a registry name or ResamplerSpec; got {type(resampler).__name__}"
        )
    fields = {f.name for f in dataclasses.fields(spec)}
    applicable = {k: v for k, v in defaults.items() if k in fields}
    return spec.replace(**applicable) if applicable else spec


def list_resamplers() -> list:
    return sorted(_FAMILIES)


def get_resampler(name: str) -> Callable:
    """Legacy lookup: ``fn(key, weights, num_iters, **kw) -> int32[N]``.

    .. deprecated:: prefer ``spec_from_name(name, **kw).build()`` — the spec
       carries hyperparameters and backend, so call sites stop threading
       ``num_iters``/kwargs.  This shim resolves through the same family
       table and returns the reference implementation unchanged.
    """
    return _family(name).legacy_single


def get_resampler_batch(name: str) -> Callable:
    """Legacy batched lookup (weights[B, N] -> int32[B, N]).

    .. deprecated:: prefer ``spec_from_name(name, **kw).build().batch`` —
       same family table, same reference implementation.
    """
    return _family(name).legacy_batch


# ---------------------------------------------------------------------------
# Static contracts (DESIGN.md §13)
#
# The declared per-cell invariants the analyzer (repro.analysis) audits the
# traced jaxprs against.  They live HERE — next to the registry — so adding
# a family forces the author to declare its launch budget in the same
# commit, and the analyzer can never drift from the registry's cell set.
# ---------------------------------------------------------------------------

#: Every registered entry point of a built ``Resampler``, audited per cell.
ENTRY_POINTS = (
    "call",
    "batch",
    "batch_rows",
    "apply",
    "apply_batch",
    "apply_rows",
    "step",
    "step_rows",
)

# Launch budgets on the pallas backends, per family shape (DESIGN.md §2/§11/
# §12).  Direct families (Megopolis/Metropolis/C1/C2/rejection) are ONE
# launch everywhere.  The prefix-sum family pays a normalise+cumsum launch
# before the search launch, except ``step``/``step_rows`` — the fused SMC
# step folds everything into one launch for EVERY family (the §12 tentpole).
# Residual additionally pays the deterministic-copy + count launches.
_DIRECT_BUDGET = {entry: 1 for entry in ENTRY_POINTS}
_PREFIX_BUDGET = {entry: 2 for entry in ENTRY_POINTS} | {"step": 1, "step_rows": 1}
_RESIDUAL_BUDGET = {
    "call": 5,
    "batch": 5,
    "batch_rows": 5,
    "apply": 4,
    "apply_batch": 4,
    "apply_rows": 4,
    "step": 1,
    "step_rows": 1,
}

LAUNCH_BUDGETS = {
    "megopolis": _DIRECT_BUDGET,
    "metropolis": _DIRECT_BUDGET,
    "metropolis_c1": _DIRECT_BUDGET,
    "metropolis_c2": _DIRECT_BUDGET,
    "rejection": _DIRECT_BUDGET,
    "multinomial": _PREFIX_BUDGET,
    "systematic": _PREFIX_BUDGET,
    "improved_systematic": _PREFIX_BUDGET,
    "stratified": _PREFIX_BUDGET,
    "residual": _RESIDUAL_BUDGET,
}


def launch_budget(name: str, backend: str, entry: str) -> int:
    """Declared max ``pallas_call`` count for one (family, backend, entry)
    cell.  The reference/xla backends are pure XLA by construction: 0."""
    if entry not in ENTRY_POINTS:
        raise KeyError(f"unknown entry point {entry!r}; choices: {ENTRY_POINTS}")
    if backend not in BACKENDS:
        raise KeyError(f"unknown backend {backend!r}; choices: {BACKENDS}")
    if backend not in PALLAS_BACKENDS:
        return 0
    try:
        return LAUNCH_BUDGETS[name][entry]
    except KeyError:
        raise KeyError(
            f"family {name!r} has no declared launch budget — every family in "
            "_FAMILIES must have a LAUNCH_BUDGETS row (DESIGN.md §13)"
        ) from None


def contract_cells(families=None, backends=None, entries=None):
    """Enumerate the audited (family, backend, entry) cells.

    The analyzer's cell source — driven off the same ``_FAMILIES`` registry
    as ``spec_for_backend`` so a newly registered family is audited (and
    must declare budgets) automatically.
    """
    for name in families if families is not None else list_resamplers():
        _family(name)  # raise (with the registry's nearest-match hint) early
        for backend in backends if backends is not None else BACKENDS:
            for entry in entries if entries is not None else ENTRY_POINTS:
                yield name, backend, entry
