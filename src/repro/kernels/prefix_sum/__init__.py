from repro.kernels.prefix_sum.ops import (  # noqa: F401
    prefix_resample_tpu,
    prefix_sum_tpu,
    searchsorted_tpu,
)
from repro.kernels.prefix_sum.ref import (  # noqa: F401
    prefix_resample_ref,
    prefix_sum_ref,
    prefix_sum_tiled_ref,
)
