"""DBRX 132B [hf:databricks/dbrx-base] — fine-grained MoE, 16 experts top-4.

40L  d_model=6144  48H (GQA kv=8, head_dim=128)  d_ff=10752 per expert,
vocab=100352, 16e top-4.  Experts shard 1/chip over the 16-way 'model' axis
(expert parallelism).  Pure full attention -> long_500k skipped.
"""

from repro.configs import ArchSpec
from repro.models import ModelConfig

ARCH = ArchSpec(
    name="dbrx-132b",
    family="moe",
    source="hf:databricks/dbrx-base",
    model=ModelConfig(
        name="dbrx-132b",
        num_layers=40,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        head_dim=128,
        d_ff=10752,
        vocab_size=100352,
        mlp_type="swiglu",
        layer_pattern=("attn",),
        num_experts=16,
        top_k=4,
        rope_theta=500_000.0,
        long_context_ok=False,
    ),
    smoke=ModelConfig(
        name="dbrx-smoke",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=128,
        vocab_size=512,
        mlp_type="swiglu",
        layer_pattern=("attn",),
        num_experts=4,
        top_k=2,
        remat=False,
    ),
    microbatches=16,
    moment_dtype="bfloat16",
    notes="16 experts top-4 (fine-grained); EP = 1 expert/chip at TP16",
)
