from repro.models.transformer import (  # noqa: F401
    ModelConfig,
    init_params,
    forward,
    loss_fn,
    prefill,
    decode_step,
    init_cache,
    param_pspecs,
    cache_pspecs,
)
