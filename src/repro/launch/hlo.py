"""Compiled-HLO analysis: collective byte accounting + roofline terms.

Semantics verified empirically on this jax/XLA build (see DESIGN.md §6):

  * ``compiled.cost_analysis()`` reports the PER-DEVICE program cost under
    SPMD (flops of a 2M^3 matmul sharded 8-ways comes back as 2M^3/8);
  * collective ops in ``compiled.as_text()`` carry the RESULT type but not
    inline operand types (``%ar = f32[1024,1024]{1,0} all-reduce(%dot)``),
    so operand bytes are derived from the result type + group size:
        all-reduce / all-to-all / collective-permute: operand = result
        all-gather:      operand = result / group     (gather dim grows)
        reduce-scatter:  operand = result * group     (scatter dim shrinks)

Two byte totals are kept:
  * ``operand`` — the assignment-literal "sum of operand sizes";
  * ``wire``    — per-chip link traffic under ring algorithms
    (all-reduce 2x(g-1)/g, all-gather/reduce-scatter/all-to-all (g-1)/g of
    the full payload, permute 1x) — used for the roofline collective term.

Hardware constants (TPU v5e, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (fixed by the assignment).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_RESULT_RE = re.compile(
    r"=\s+(\(?[a-z0-9_\[\]{},\s]*?\)?)\s+("
    + "|".join(_COLLECTIVES)
    + r")(-start)?\("
)
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_LIST_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,\s]+)\}")


def _shape_list_bytes(type_str: str) -> float:
    total = 0.0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str, default: int) -> int:
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        return max(1, int(m.group(2)))
    m = _LIST_GROUPS_RE.search(line)
    if m:
        return max(1, len(m.group(1).split(",")))
    return default


def collective_bytes(hlo_text: str, *, default_group: int = 1) -> Dict[str, dict]:
    """Per-opcode {operand, wire, count} byte totals for one chip's program."""
    out = {k: {"operand": 0.0, "wire": 0.0, "count": 0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _RESULT_RE.search(line)
        if not m:
            continue
        result_bytes = _shape_list_bytes(m.group(1))
        op = m.group(2)
        is_start = m.group(3) == "-start"
        if is_start and op in ("all-gather", "collective-permute", "all-reduce"):
            # -start results are (operand, result[, ...]) tuples; the true
            # output is the largest-or-equal entry — take result as half for
            # ag (operand+output) conservatively handled below.
            shapes = [_shape_list_bytes(s) for s in re.findall(r"[a-z0-9]+\[[0-9,]*\]", m.group(1))]
            if op == "all-gather" and len(shapes) >= 2:
                result_bytes = max(shapes)
            elif shapes:
                result_bytes = shapes[-1]
        g = _group_size(line, default_group)
        if op == "all-gather":
            operand = result_bytes / g
            wire = result_bytes * (g - 1) / g
        elif op == "reduce-scatter":
            operand = result_bytes * g
            wire = operand * (g - 1) / g
        elif op == "all-reduce":
            operand = result_bytes
            wire = 2.0 * result_bytes * (g - 1) / g
        elif op == "all-to-all":
            operand = result_bytes
            wire = result_bytes * (g - 1) / g
        else:  # collective-permute
            operand = result_bytes
            wire = result_bytes
        out[op]["operand"] += operand
        out[op]["wire"] += wire
        out[op]["count"] += 1
    return out


@dataclasses.dataclass
class Roofline:
    """Three-term roofline for one compiled cell.  All inputs are PER-CHIP
    (cost_analysis semantics verified above); times are seconds per step."""

    flops: float  # per-chip HLO FLOPs
    hbm_bytes: float  # per-chip HLO bytes accessed
    coll_wire_bytes: float  # per-chip ICI traffic (ring model)
    coll_operand_bytes: float  # assignment-literal operand sum
    chips: int
    trips: int = 1  # scan-trip multiplier (microbatch loop bodies count once)
    model_flops: float = 0.0  # global 6*N*D useful-work reference
    coll_detail: dict = dataclasses.field(default_factory=dict)

    @property
    def t_compute(self) -> float:
        return self.trips * self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.trips * self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        # v5e 2D torus: collectives stream over ~3 usable link-pairs per
        # chip for ring schedules on one axis; keep 1 link (worst case,
        # conservative) — noted in EXPERIMENTS.md.
        return self.trips * self.coll_wire_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / global HLO_FLOPs — remat/redundancy waste detector."""
        total = self.trips * self.flops * self.chips
        return (self.model_flops / total) if (self.model_flops and total) else 0.0

    @property
    def step_time(self) -> float:
        """Optimistic perfect-overlap bound: max of the three terms."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def roofline_fraction(self) -> float:
        """Useful FLOPs over chip-seconds at peak under the step_time bound
        (the MFU-style number §Perf hillclimbs)."""
        if not self.model_flops:
            return 0.0
        t = self.step_time
        return self.model_flops / (self.chips * PEAK_FLOPS * t) if t else 0.0

    def row(self) -> dict:
        return {
            "flops_per_chip": self.trips * self.flops,
            "hbm_bytes_per_chip": self.trips * self.hbm_bytes,
            "coll_wire_bytes_per_chip": self.trips * self.coll_wire_bytes,
            "coll_operand_bytes_per_chip": self.trips * self.coll_operand_bytes,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_ratio,
            "step_time_s": self.step_time,
            "roofline_fraction": self.roofline_fraction,
        }


def analyze(compiled, *, chips: int, trips: int = 1, model_flops: float = 0.0) -> Roofline:
    cost = compiled.cost_analysis()
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    detail = collective_bytes(compiled.as_text())
    wire = sum(v["wire"] for v in detail.values())
    operand = sum(v["operand"] for v in detail.values())
    return Roofline(flops=flops, hbm_bytes=hbm, coll_wire_bytes=wire,
                    coll_operand_bytes=operand, chips=chips, trips=trips,
                    model_flops=model_flops, coll_detail=detail)
