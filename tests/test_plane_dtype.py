"""Compressed particle planes (DESIGN.md §14): the plane-dtype axis.

Contract under test:

  1. **quantise/compress algebra** — ``quantise_plane`` is idempotent, an
     elided no-op at f32 (the structural identical-program gates depend on
     it), and passes int states through untouched; ``compress_plane`` is a
     lossless narrowing of quantised operands.
  2. **spec surface** — every spec validates ``plane_dtype`` at
     construction; ``Resampler.quantise`` exposes the grid.
  3. **cross-dtype step contract** — the bf16 fused step equals the
     composed oracle on quantised inputs; int states keep their dtype.
  4. **precision-bug sweep** (the satellites) — dtype-aware floors in
     ``log_weights_from_linear``/ESS at bf16/f16; ``bias_variance`` K=1;
     ragged-tail transaction counting; error-feedback residual carrying
     the wire-cast error.
  5. **byte model** — memmodel and the §2.4 transaction model both report
     ≥ 1.8× fewer modelled bytes/transactions for the weight/CDF plane at
     bf16 words, while analyzer launch budgets stay unchanged.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.metrics import (
    bias_variance,
    effective_sample_size,
    log_weights_from_linear,
)
from repro.core.spec import MegopolisSpec, spec_for_backend
from repro.core.transactions import (
    declared_transaction_bound,
    measured_transaction_stats,
    transactions_per_group,
)
from repro.kernels.common import (
    PLANE_DTYPES,
    TILE,
    compress_plane,
    plane_itemsize,
    quantise_plane,
    state_itemsize,
)

N = 2 * TILE


# ------------------------------------------------ 1. quantise/compress algebra
def test_quantise_plane_identity_at_f32():
    x = jax.random.normal(jax.random.PRNGKey(0), (64,))
    np.testing.assert_array_equal(np.asarray(quantise_plane(x, "float32")),
                                  np.asarray(x))
    # The f32 path must be ELIDED from the jaxpr — a same-dtype convert
    # would break the benches' structural identical-program gates.
    jaxpr = str(jax.make_jaxpr(lambda a: quantise_plane(a, "float32"))(x))
    assert "convert_element_type" not in jaxpr


@pytest.mark.parametrize("dtype", ("bfloat16", "float16"))
def test_quantise_plane_idempotent(dtype):
    x = jax.random.normal(jax.random.PRNGKey(1), (256,))
    q1 = quantise_plane(x, dtype)
    assert q1.dtype == x.dtype
    np.testing.assert_array_equal(np.asarray(quantise_plane(q1, dtype)),
                                  np.asarray(q1))
    # compress is a LOSSLESS narrowing of the quantised plane
    wire = compress_plane(q1, dtype)
    assert wire.dtype == jnp.dtype(dtype)
    np.testing.assert_array_equal(np.asarray(wire.astype(x.dtype)),
                                  np.asarray(q1))


def test_quantise_plane_int_passthrough():
    xi = jnp.arange(32, dtype=jnp.int32)
    assert quantise_plane(xi, "bfloat16") is xi
    assert compress_plane(xi, "bfloat16").dtype == jnp.int32
    assert state_itemsize(xi, "bfloat16") == 4
    assert state_itemsize(jnp.zeros((4,), jnp.float32), "bfloat16") == 2


def test_plane_itemsize_values():
    assert [plane_itemsize(d) for d in PLANE_DTYPES] == [4, 2, 2]


# ----------------------------------------------------------- 2. spec surface
def test_spec_rejects_unknown_plane_dtype():
    with pytest.raises(ValueError, match="plane_dtype"):
        MegopolisSpec(plane_dtype="float64")
    with pytest.raises(ValueError, match="plane_dtype"):
        spec_for_backend("systematic", "reference", plane_dtype="int8")


def test_resampler_quantise_matches_helper():
    r = spec_for_backend("megopolis", "reference", plane_dtype="bfloat16").build()
    x = jax.random.normal(jax.random.PRNGKey(2), (128,))
    np.testing.assert_array_equal(np.asarray(r.quantise(x)),
                                  np.asarray(quantise_plane(x, "bfloat16")))


# ------------------------------------------------- 3. cross-dtype step contract
@pytest.mark.parametrize("name", ("megopolis", "systematic"))
def test_step_noop_branch_passes_quantised_state(name, base_key):
    """thr=0.0 never fires: the compressed step hands back the QUANTISED
    particles (the value its resident planes hold), identity ancestors."""
    r = spec_for_backend(name, "pallas_interpret",
                        plane_dtype="bfloat16").build()
    lw = jax.random.normal(jax.random.PRNGKey(3), (N,)) * 2.0
    p = jax.random.normal(jax.random.PRNGKey(4), (N, 4))
    p_out, anc, stats = r.step(base_key, lw, p, 0.0)
    np.testing.assert_array_equal(np.asarray(anc), np.arange(N))
    np.testing.assert_array_equal(np.asarray(p_out), np.asarray(r.quantise(p)))
    assert float(stats.log_evidence_incr) == 0.0


def test_apply_int_state_keeps_dtype_at_bf16(base_key):
    r = spec_for_backend("megopolis", "pallas_interpret",
                        plane_dtype="bfloat16").build()
    w = jax.random.uniform(jax.random.PRNGKey(5), (N,)) + 1e-3
    pi = jax.random.randint(jax.random.PRNGKey(6), (N, 3), 0, 1 << 20)
    got_p, got_a = r.apply(base_key, w, pi)
    assert got_p.dtype == pi.dtype
    np.testing.assert_array_equal(np.asarray(got_p),
                                  np.asarray(jnp.take(pi, got_a, axis=0)))


# ------------------------------------------------- 4. precision-bug sweep
@pytest.mark.parametrize("dtype", ("bfloat16", "float16"))
def test_log_weights_floor_is_dtype_aware(dtype):
    """The 1e-30 floor is BELOW f16's min normal (~6.1e-5): flushed to zero
    it would reintroduce the -inf it guards against.  The floor must sit in
    each dtype's normal range."""
    w = jnp.array([0.0, 1.0], dtype)
    lw = log_weights_from_linear(w)
    assert bool(jnp.all(jnp.isfinite(lw)))
    # and the floored value itself must survive a round-trip in-dtype
    floor = jnp.exp(lw[0].astype(jnp.float32))
    assert float(floor.astype(dtype)) > 0.0


@pytest.mark.parametrize("dtype", ("bfloat16", "float16"))
def test_ess_guard_is_dtype_aware(dtype):
    """ESS's Σw² guard must not flush to zero in half dtypes: all-zero
    weights still yield a finite ESS."""
    lw = jnp.full((64,), -jnp.inf).astype(dtype)
    ess = effective_sample_size(lw)
    assert bool(jnp.isfinite(ess))


def test_bias_variance_single_run_is_finite():
    """K=1: eq. (17)'s k-1 denominator is 0 — the defined limit is var=0
    (deviations identically zero), mse degrading to bias², never nan."""
    w = jnp.array([0.5, 0.3, 0.2], jnp.float32)
    off = jnp.array([[2, 1, 0]], jnp.int32)
    var, bias_sq, mse = bias_variance(off, w)
    assert float(var) == 0.0
    assert np.isfinite(float(bias_sq)) and np.isfinite(float(mse))
    assert float(mse) == pytest.approx(float(bias_sq))


def test_transactions_count_ragged_tail():
    """A tail group narrower than the warp still issues transactions; it is
    padded with the last lane's index, never dropped and never widened."""
    idx = np.arange(48)  # 1.5 warps of perfectly coalesced reads
    per = transactions_per_group(idx, group=32, word_bytes=4, segment_bytes=32)
    assert per.shape == (2,)
    assert list(per) == [4, 2]  # lanes 32..47 span exactly 2 segments
    # same stream, no tail: unchanged
    assert list(transactions_per_group(idx[:32], group=32)) == [4]


def test_compression_residual_carries_cast_error():
    """Error feedback must track what was SENT, not what was masked: the
    wire cast of a small dense tensor drops mass that has to re-enter the
    residual or the optimiser drifts a bf16-ulp every step."""
    from repro.optim.compression import CompressionConfig, compress_and_correct

    cfg = CompressionConfig(min_size=4096, wire_dtype="bfloat16")
    g = {"w": jnp.full((8,), 1.0 / 3.0, jnp.float32)}  # not on the bf16 grid
    r0 = {"w": jnp.zeros((8,), jnp.float32)}
    wire, resid = compress_and_correct(cfg, g, r0)
    assert wire["w"].dtype == jnp.bfloat16
    exact = g["w"] - wire["w"].astype(jnp.float32)
    np.testing.assert_array_equal(np.asarray(resid["w"]), np.asarray(exact))
    assert float(jnp.max(jnp.abs(resid["w"]))) > 0.0
    # the top-k branch carries the same cast error
    big = {"w": jnp.full((8192,), 1.0 / 3.0, jnp.float32)}
    rb = {"w": jnp.zeros((8192,), jnp.float32)}
    wire_b, resid_b = compress_and_correct(
        CompressionConfig(ratio=0.5, min_size=16), big, rb
    )
    exact_b = big["w"] - wire_b["w"].astype(jnp.float32)
    np.testing.assert_array_equal(np.asarray(resid_b["w"]), np.asarray(exact_b))


# ------------------------------------------------------------- 5. byte model
def test_memmodel_weight_plane_halves_at_bf16():
    """The acceptance gate: ≥ 1.8× fewer modelled bytes per step for the
    weight plane at 2-byte words (exactly 2× here — ancestors stay i32)."""
    from repro.launch.memmodel import resample_step_bytes, smc_step_bytes

    for n in (1 << 10, 1 << 16):
        a32 = resample_step_bytes(n, 4, fused=True, weight_bytes=4)
        a16 = resample_step_bytes(n, 4, fused=True, weight_bytes=2)
        assert a32["weights"] / a16["weights"] >= 1.8
        s32 = smc_step_bytes(n, 4, fused=False, weight_bytes=4)
        s16 = smc_step_bytes(n, 4, fused=False, weight_bytes=2)
        assert s32["log_weights"] / s16["log_weights"] >= 1.8
        assert s32["weights_normalised"] / s16["weights_normalised"] >= 1.8
        assert s16["ancestors_i32"] == s32["ancestors_i32"]  # never compresses


def test_transaction_model_halves_at_bf16_words():
    """§2.4 at word_bytes=2: Megopolis' exact-4 becomes exact-2 (the warp's
    128 bytes span half the 32-byte segments), every declared bound word-
    scales, and measured stays within declared."""
    s32 = measured_transaction_stats("megopolis", word_bytes=4)
    s16 = measured_transaction_stats("megopolis", word_bytes=2)
    assert s32["max"] == s32["exact"] == 4
    assert s16["max"] == s16["exact"] == 2
    assert s32["max"] / s16["max"] >= 1.8
    assert declared_transaction_bound("megopolis", word_bytes=2) == 2
    for name in ("metropolis", "metropolis_c1", "metropolis_c2"):
        st = measured_transaction_stats(name, word_bytes=2)
        assert st["max"] <= st["bound"]


def test_analyzer_budgets_unchanged_across_dtype_axis():
    """Compression narrows words; it must never change a cell's launch
    budget, add a host cond or an HBM ancestor round-trip."""
    from repro.analysis.contracts import audit_matrix

    reps = list(audit_matrix(
        families=("megopolis",), backends=("pallas_interpret",),
        entries=("apply", "step"), plane_dtypes=("float32", "bfloat16"),
    ))
    assert len(reps) == 4
    by_cell = {r.cell: r for r in reps}
    for entry in ("apply", "step"):
        f32 = by_cell[f"megopolis/pallas_interpret/{entry}"]
        bf16 = by_cell[f"megopolis/pallas_interpret/{entry}@bfloat16"]
        assert f32.ok and bf16.ok, (f32.violations, bf16.violations)
        assert bf16.launches == f32.launches
        assert bf16.max_launches == f32.max_launches
