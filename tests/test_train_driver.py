"""Training-driver fault tolerance: resume-exactness, heartbeat,
compressed-DP mode convergence."""

import json

import numpy as np

from repro.launch.train import TrainRun, run


def test_resume_reproduces_uninterrupted_run(tmp_path):
    """Train 8 steps straight vs 4 + checkpoint + resume 4: identical loss
    trajectory (exact-resume invariant: data position + state restore)."""
    base = dict(arch="qwen3-0.6b", smoke=True, global_batch=4, seq_len=32)
    full = run(TrainRun(steps=8, ckpt_dir=str(tmp_path / "a"), ckpt_every=100, **base))

    rdir = str(tmp_path / "b")
    first = run(TrainRun(steps=4, ckpt_dir=rdir, ckpt_every=4, **base))
    second = run(TrainRun(steps=8, ckpt_dir=rdir, ckpt_every=100, resume=True, **base))
    got = first["losses"] + second["losses"]
    # rtol: XLA-CPU matmul reductions are load-dependent (threadpool work
    # splitting), so even identical replays drift per step — and with the
    # learnable token stream the drift compounds through real gradients
    # (observed up to ~7e-3 over 8 steps on a loaded CI box).  The check is
    # that the resumed trajectory tracks the uninterrupted one: a state
    # re-init jumps back to the random-init loss (~3% off) and a wrong
    # restore breaks by whole units.
    np.testing.assert_allclose(got, full["losses"], rtol=1e-2)


def test_heartbeat_written(tmp_path):
    run(TrainRun(arch="qwen3-0.6b", steps=3, smoke=True, global_batch=4,
                 seq_len=32, ckpt_dir=str(tmp_path)))
    hb = [json.loads(line) for line in open(tmp_path / "heartbeat.json")]
    assert [r["step"] for r in hb] == [0, 1, 2]
    assert all(np.isfinite(r["loss"]) and r["step_time_s"] > 0 for r in hb)


def test_loss_decreases(tmp_path):
    out = run(TrainRun(arch="mamba2-1.3b", steps=20, smoke=True, global_batch=8,
                       seq_len=32))
    assert out["losses"][-1] < out["losses"][0]


def test_compressed_dp_mode_still_learns():
    out = run(TrainRun(arch="qwen3-0.6b", steps=20, smoke=True, global_batch=8,
                       seq_len=32, compress=True))
    assert out["losses"][-1] < out["losses"][0]
