"""Fused SMC step for the prefix-sum family — ONE pallas_call (DESIGN.md §12).

The composed prefix-sum path is the family's launch-count worst case: a
block-scan launch (three for residual), plus a search launch, plus host-side
normalise/ESS/branch glue.  The fused step folds the WHOLE composition into
a single grid=(1,) kernel over resident arrays:

  log-weights → (m, ESS, logZ incr) prelude → exp(lw - m) → in-kernel tile
  scan (``prefix_sum.scan_tiles``, bit-identical to the scan kernel) →
  draw scaling → full-array bisection (``search._bisect_any``) → slot select
  (residual) → identity-or-selection commit → state gather.

Randomness placement keeps the family's host/kernel split (ops.py): the
KEY-dependent part of every draw — ``uniform(key, (n,))`` or the scalar
``uniform(key, ())`` — is drawn OUTSIDE with ``jax.random`` exactly as
``kind_draws`` does, while the CDF-dependent SCALE (``total`` or
``total / n``) is applied in-kernel.  Because the in-kernel CDF is
bit-identical to the scan kernel's and the scaling expressions are the
same f32 ops, every draw — and therefore every ancestor — matches the
composed path bitwise.

Residency: everything (log-weights, draw bases, CDFs, state planes) is
VMEM-resident, so the family's usual CDF cap applies (checked in ops.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import gather_state_full, step_stats
from repro.kernels.prefix_sum.prefix_sum import LANES, SUBLANES, scan_tiles
from repro.kernels.prefix_sum.search import _bisect_any


def _full_lane_ids(rows: int) -> jnp.ndarray:
    """Flat row-major particle index of every lane of the whole (rows, 128)
    array — the full-array analogue of ``tile_lane_ids``."""
    row = lax.broadcasted_iota(jnp.int32, (rows, LANES), 0)
    col = lax.broadcasted_iota(jnp.int32, (rows, LANES), 1)
    return row * LANES + col


def _make_kernel_step(n_total: int, rows: int, kind: str):
    def _kernel(u0_ref, thr_ref, lw_ref, ubase_ref, planes_ref,
                k_ref, out_ref, stats_ref):
        lw_flat = lw_ref[...].astype(jnp.float32).reshape(n_total)
        m, ess_norm, incr, maxw, deg = step_stats(lw_flat, n_total)
        do = ess_norm < thr_ref[0]
        stats_ref[0] = ess_norm
        stats_ref[1] = jnp.where(do, incr, jnp.float32(0.0))
        stats_ref[2] = jnp.where(do, jnp.float32(1.0), jnp.float32(0.0))
        stats_ref[3] = maxw

        # Normalised weights re-land on the plane-dtype grid (the composed
        # path quantises at the public ``apply`` boundary); a no-op at f32.
        # The §16 degenerate substitution precedes the requantise, exactly
        # as ``normalise_log_weights`` orders it on the host.
        w2d = jnp.exp(lw_ref[...].astype(jnp.float32) - m)
        w2d = jnp.where(deg, jnp.float32(1.0 / n_total), w2d)
        w2d = w2d.astype(lw_ref.dtype).astype(jnp.float32)
        slots = _full_lane_ids(rows)

        if kind == "residual":
            # the three-scan residual composition, in-value (ops._residual_tpu_fused)
            total = scan_tiles(w2d).reshape(n_total)[-1]
            wn = w2d / total
            counts = jnp.floor(jnp.float32(n_total) * wn)
            n_det = jnp.sum(counts.reshape(n_total)).astype(jnp.int32)
            resid = jnp.float32(n_total) * wn - counts
            cc_flat = scan_tiles(counts).reshape(n_total)
            c_flat = scan_tiles(resid).reshape(n_total)
            u2d = ubase_ref[...] * c_flat[-1]
            det = _bisect_any(cc_flat, slots.astype(c_flat.dtype), "right", n_total)
            rnd = _bisect_any(c_flat, u2d, "right", n_total)
            k = jnp.where(slots < n_det, det, rnd)
        else:
            c_flat = scan_tiles(w2d).reshape(n_total)
            total = c_flat[-1]
            if kind == "multinomial":
                u2d, side = ubase_ref[...] * total, "right"
            elif kind in ("systematic", "improved_systematic"):
                idx = slots.astype(c_flat.dtype)
                u2d, side = (idx + u0_ref[0]) * (total / n_total), "left"
            else:  # stratified
                idx = slots.astype(c_flat.dtype)
                u2d, side = (idx + ubase_ref[...]) * (total / n_total), "left"
            k = _bisect_any(c_flat, u2d, side, n_total)

        k_sel = jnp.where(do, k, slots)
        k_ref[...] = k_sel
        out_ref[...] = gather_state_full(planes_ref[...], k_sel)

    return _kernel


@functools.partial(jax.jit, static_argnames=("kind", "interpret"))
def prefix_pallas_step(
    log_weights2d: jnp.ndarray,
    planes: jnp.ndarray,
    ubase2d: jnp.ndarray,
    u0: jnp.ndarray,
    thr: jnp.ndarray,
    *,
    kind: str,
    interpret: bool = True,
):
    """Fused SMC-step pallas_call for one prefix-sum kind.  ``ubase2d``:
    the key-only uniform base draws reshaped (R, 128) (zeros for the
    systematic pair); ``u0``: f32[1] scalar base (zeros unless systematic).
    Returns ``(int32[R, 128], [d_pad, R, 128], f32[4] = (ess_norm, incr,
    resampled, max_weight))``."""
    rows, lanes = log_weights2d.shape
    assert lanes == LANES and rows % SUBLANES == 0
    d_pad = planes.shape[0]
    assert planes.shape[1:] == (rows, lanes)
    assert ubase2d.shape == (rows, lanes)
    n_total = rows * lanes

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # scalar draw base + f32 ESS threshold
        grid=(1,),
        in_specs=[
            pl.BlockSpec((rows, LANES), lambda i, u0, thr: (0, 0)),
            pl.BlockSpec((rows, LANES), lambda i, u0, thr: (0, 0)),
            pl.BlockSpec((d_pad, rows, LANES), lambda i, u0, thr: (0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((rows, LANES), lambda i, u0, thr: (0, 0)),
            pl.BlockSpec((d_pad, rows, LANES), lambda i, u0, thr: (0, 0, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
    )
    return pl.pallas_call(
        _make_kernel_step(n_total, rows, kind),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((rows, lanes), jnp.int32),
            jax.ShapeDtypeStruct((d_pad, rows, lanes), planes.dtype),
            jax.ShapeDtypeStruct((4,), jnp.float32),
        ],
        interpret=interpret,
    )(u0, thr, log_weights2d, ubase2d, planes)
