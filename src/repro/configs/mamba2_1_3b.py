"""Mamba2 1.3B [arXiv:2405.21060] — pure SSM (SSD), attention-free.

48L  d_model=2048  (attn-free, d_ff=0)  vocab=50280 (padded to 50304 =
393*128 for clean 16-way TP of the embedding/lm_head — standard vocab
padding, cf. GPT-NeoX)  ssm_state=128.  Attention-free -> long_500k runs;
decode state is O(d_inner * ssm_state) per layer, constant in context.
"""

from repro.configs import ArchSpec
from repro.models import ModelConfig

ARCH = ArchSpec(
    name="mamba2-1.3b",
    family="ssm",
    source="arXiv:2405.21060",
    model=ModelConfig(
        name="mamba2-1.3b",
        num_layers=48,
        d_model=2048,
        num_heads=32,  # unused (attn-free); keeps head_dim derivation happy
        num_kv_heads=32,
        d_ff=0,  # mamba blocks carry no MLP
        vocab_size=50304,  # 50280 padded to a multiple of 128*16
        layer_pattern=("mamba",),
        ssm_state=128,
        ssm_expand=2,
        ssm_head_dim=64,
        long_context_ok=True,
    ),
    smoke=ModelConfig(
        name="mamba2-smoke",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=0,
        vocab_size=512,
        layer_pattern=("mamba",),
        ssm_state=8,
        ssm_head_dim=16,
        ssm_chunk=4,
        remat=False,
    ),
    microbatches=16,
    notes="SSD (state-space duality); vocab padded 50280 -> 50304",
)
