"""JSONL event sink for the benchmark harness (DESIGN.md §15, §16).

One event per line — ``{"event": <name>, "ts": <unix seconds>, ...fields}``
— appended so concurrent suites interleave without clobbering each other.
``benchmarks/run.py`` emits ``suite_start``/``suite_end``/``run_end`` events
here and CI uploads the file as the observability artifact; anything that
reads it gets an ordered, replayable record of what a bench run actually
did (the "flight recorder" half of the subsystem name).

Crash consistency (§16): the sink may buffer (``buffer_size > 1``) to
amortise the open/append per event, but a flight recorder that loses its
tail on a crash is useless — so every sink registers an ``atexit`` flush,
is a context manager (``close()`` on exit, normal OR abnormal), and
``flush()`` is idempotent/re-entrant.  The default ``buffer_size=1``
keeps the historical write-through behaviour byte for byte.
"""

from __future__ import annotations

import atexit
import json
import os
import time


class JsonlSink:
    """Append-only JSONL event writer.  Values must be JSON-serialisable;
    non-serialisable values are stringified rather than dropped, so an odd
    numpy scalar can never kill a bench run.

    ``buffer_size=1`` (default) writes through on every ``emit``;
    larger sizes batch lines and flush when the buffer fills, on
    ``flush()``/``close()``/context exit, and at interpreter exit
    (``atexit``) — abnormal exits keep their recorded tail.
    """

    def __init__(self, path: str, *, buffer_size: int = 1):
        if isinstance(buffer_size, bool) or not isinstance(buffer_size, int) \
                or buffer_size < 1:
            raise ValueError(
                f"JsonlSink.buffer_size must be a positive int; got {buffer_size!r}"
            )
        self.path = path
        self.buffer_size = buffer_size
        self._buffer: list = []
        self._closed = False
        parent = os.path.dirname(path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        atexit.register(self.flush)

    def emit(self, event: str, **fields) -> None:
        if self._closed:
            raise ValueError(f"JsonlSink({self.path!r}) is closed")
        record = {"event": event, "ts": round(time.time(), 3)}
        for k, v in fields.items():
            try:
                json.dumps(v)
            except (TypeError, ValueError):
                v = str(v)
            record[k] = v
        self._buffer.append(json.dumps(record))
        if len(self._buffer) >= self.buffer_size:
            self.flush()

    def flush(self) -> None:
        """Drain the buffer to disk (one append, fsync'd).  Idempotent —
        safe from ``atexit`` after an explicit ``close()``."""
        if not self._buffer:
            return
        lines, self._buffer = self._buffer, []
        with open(self.path, "a") as f:
            f.write("\n".join(lines) + "\n")
            f.flush()
            os.fsync(f.fileno())

    def close(self) -> None:
        """Flush and seal the sink; further ``emit`` calls raise."""
        self.flush()
        self._closed = True

    def __enter__(self) -> "JsonlSink":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        # Abnormal exit included: the recorded tail always lands on disk.
        self.close()
