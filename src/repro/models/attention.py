"""GQA attention: training/prefill (q-chunked, exact) + cached decode.

Supports RoPE, qk-norm (Qwen3/Chameleon), sliding windows (Gemma3 local
layers, H2O-Danube, Llama4 chunked-local), and grouped KV heads.  The
query-chunked formulation keeps the per-layer score temp at
``B * H * chunk * S`` (exact softmax per chunk — chunking over q only needs
no running rescale) and unrolls as a python loop so the dry-run's
``cost_analysis`` counts every FLOP (DESIGN.md §6.4).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, init_linear, init_rmsnorm, linear, rmsnorm
from repro.models.partitioning import logical

NEG_INF = -1e30


def init_attention(key, cfg):
    ks = jax.random.split(key, 6)
    hd = cfg.head_dim
    p = {
        "wq": init_linear(ks[0], cfg.d_model, cfg.num_heads * hd),
        "wk": init_linear(ks[1], cfg.d_model, cfg.num_kv_heads * hd),
        "wv": init_linear(ks[2], cfg.d_model, cfg.num_kv_heads * hd),
        "wo": init_linear(ks[3], cfg.num_heads * hd, cfg.d_model),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(hd)
        p["k_norm"] = init_rmsnorm(hd)
    return p


def _project_qkv(p, cfg, x, positions):
    b, s, _ = x.shape
    hd = cfg.head_dim
    q = linear(p["wq"], x, x.dtype).reshape(b, s, cfg.num_heads, hd)
    k = linear(p["wk"], x, x.dtype).reshape(b, s, cfg.num_kv_heads, hd)
    v = linear(p["wv"], x, x.dtype).reshape(b, s, cfg.num_kv_heads, hd)
    # logical constraints (launch/steps.py rules): "heads" -> 'model' when
    # num_heads % tp == 0, else None + "q_seq" -> 'model' (sequence-TP
    # attention, e.g. llama4's 40 heads on 16-way TP); "kv_heads" -> 'model'
    # only when kv heads divide tp (else replicated, Megatron-GQA style).
    q = logical(q, "batch", "q_seq", "heads", "head_dim")
    k = logical(k, "batch", None, "kv_heads", "head_dim")
    v = logical(v, "batch", None, "kv_heads", "head_dim")
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _scores_mask(q_pos, k_pos, window: int):
    """(..., q, k) additive mask: causal + optional sliding window."""
    causal = q_pos[:, None] >= k_pos[None, :]
    if window > 0:
        causal &= (q_pos[:, None] - k_pos[None, :]) < window
    return jnp.where(causal, 0.0, NEG_INF)


def _sdpa(q, k, v, mask, dtype):
    """q (b,qs,Hq,hd), k/v (b,ks,Hkv,hd), mask (qs,ks) additive f32.

    KV heads are expanded to the full head count before the einsums: the
    flat-head layout keeps every contraction GSPMD-shardable (the grouped
    (Hkv, g) reshape does NOT factor when Hq is tp-sharded but Hkv < tp,
    and GSPMD silently replicates).  FLOPs are identical; the expansion is
    a broadcast the compiler fuses.
    """
    b, qs, hq, hd = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    k = logical(k, "batch", "kv_seq", "heads", "head_dim")
    v = logical(v, "batch", "kv_seq", "heads", "head_dim")
    scores = jnp.einsum("bqhd,bshd->bhqs", q, k, preferred_element_type=jnp.float32)
    scores = scores * (hd**-0.5) + mask
    scores = logical(scores, "batch", "heads", "q_seq", "kv_seq")
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqs,bshd->bqhd", probs.astype(dtype), v)
    return out.reshape(b, qs, hq * hd)


def attention(p, cfg, x, positions, *, window: int = 0, q_chunk: int = 4096):
    """Exact causal (optionally windowed) attention; returns (out, (k, v)).

    Sliding-window layers are BANDED: each q chunk only sees the k range
    ``[chunk_lo - window + 1, chunk_hi)`` — compute and score-buffer size
    drop from O(S^2) to O(S * window), which is what makes gemma3's 62-layer
    5:1-SWA stack fit and is counted as real FLOP savings in §Roofline.
    """
    b, s, _ = x.shape
    q, k, v = _project_qkv(p, cfg, x, positions)
    k_pos = positions[0] if positions.ndim == 2 else positions  # (s,)

    if window > 0:
        # banded chunks: cap the chunk at >= 4096 so a 62-layer SWA stack
        # doesn't unroll into thousands of attention blocks (compile cost);
        # the k-span per chunk stays O(chunk + window) — still sub-quadratic
        q_chunk = min(q_chunk, max(window, 4096))
    chunks = []
    n_chunks = max(1, (s + q_chunk - 1) // q_chunk)
    for ci in range(n_chunks):
        lo = ci * q_chunk
        hi = min(s, lo + q_chunk)
        klo = max(0, lo - window + 1) if window > 0 else 0
        mask = _scores_mask(k_pos[lo:hi], k_pos[klo:hi], window)
        chunks.append(_sdpa(q[:, lo:hi], k[:, klo:hi], v[:, klo:hi], mask, x.dtype))
    out = jnp.concatenate(chunks, axis=1) if len(chunks) > 1 else chunks[0]
    out = logical(out, "batch", "q_seq", "attn_out")
    return linear(p["wo"], out, x.dtype), (k, v)


def decode_attention(p, cfg, x, cache_kv, pos, *, window: int = 0):
    """One-token decode: x (b,1,D), ring cache k/v (b,L,Hkv,hd), pos scalar.

    The cache is a ring of length ``L``: position ``p`` lives in slot
    ``p % L`` (for full-attention layers L = max_seq so the ring is the
    plain cache; for sliding-window layers L = window so memory stays
    O(window) even at 500k context).  Slot ``j`` therefore holds absolute
    position ``pos - ((pos - j) mod L)`` — masked when negative or outside
    the window.  Returns (out (b,1,D), updated cache).
    """
    b = x.shape[0]
    k_cache, v_cache = cache_kv
    ring = k_cache.shape[1]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, k_new, v_new = _project_qkv(p, cfg, x, positions)
    slot = jnp.mod(pos, ring)
    k_cache = jax.lax.dynamic_update_slice(
        k_cache, k_new.astype(k_cache.dtype), (0, slot, 0, 0)
    )
    v_cache = jax.lax.dynamic_update_slice(
        v_cache, v_new.astype(v_cache.dtype), (0, slot, 0, 0)
    )
    k_cache = logical(k_cache, "batch", "kv_seq", "kv_heads", "head_dim")
    v_cache = logical(v_cache, "batch", "kv_seq", "kv_heads", "head_dim")

    j = jnp.arange(ring, dtype=jnp.int32)
    k_pos = pos - jnp.mod(pos - j, ring)  # absolute position held by slot j
    valid = k_pos >= 0
    if window > 0:
        valid &= (pos - k_pos) < window
    mask = jnp.where(valid, 0.0, NEG_INF)[None, :]  # (1, L)
    out = _sdpa(q, k_cache.astype(x.dtype), v_cache.astype(x.dtype), mask, x.dtype)
    return linear(p["wo"], out, x.dtype), (k_cache, v_cache)
