"""Gradient compression with error feedback (distributed-optimization trick).

Top-k magnitude sparsification per tensor with an error-feedback residual
(Stich et al. / Karimireddy et al.): the un-transmitted mass is carried to
the next step, which keeps convergence unaffected while cutting DP
all-reduce bytes by ``1/ratio``.

Implementation notes for TPU/XLA:
  * top-k over the flattened tensor via ``jax.lax.top_k`` (sorted network on
    TPU, no host sync);
  * the compressed representation stays DENSE (a masked tensor): on TPU the
    win is *collective bytes* and we realise it by all-reducing in a lower
    dtype after masking (values -> bf16/f16) rather than exchanging index
    lists, which would lower to unfavourable gathers on ICI.  The roofline
    collective term reflects that choice.
  * small tensors (< 4096 elements: norms, biases) are left dense f32 —
    indices would cost more than the payload.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    ratio: float = 0.01  # keep top 1% of entries
    min_size: int = 4096  # tensors smaller than this stay dense
    wire_dtype: str = "bfloat16"  # dtype of the masked all-reduce payload


def compress_init(params):
    """Error-feedback residual buffers (f32, zero-initialised)."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _topk_mask(x: jnp.ndarray, k: int) -> jnp.ndarray:
    flat = jnp.abs(x.reshape(-1))
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return (jnp.abs(x) >= thresh).astype(x.dtype)


def compress_and_correct(cfg: CompressionConfig, grads, residuals):
    """Sparsify ``grads + residuals``; returns (wire_grads, new_residuals).

    ``wire_grads`` is what enters the DP all-reduce (masked, cast to
    ``wire_dtype``); ``new_residuals`` holds the feedback error in f32.
    """
    wire_dtype = jnp.dtype(cfg.wire_dtype)

    def one(g, r):
        acc = g.astype(jnp.float32) + r
        if acc.size < cfg.min_size:
            sent = acc.astype(wire_dtype)
            # The wire cast itself drops mass; error feedback must carry
            # the cast error too or small dense tensors drift every step.
            return sent, acc - sent.astype(jnp.float32)
        k = max(1, int(acc.size * cfg.ratio))
        mask = _topk_mask(acc, k)
        sent = (acc * mask).astype(wire_dtype)
        return sent, acc - sent.astype(jnp.float32)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(residuals)
    pairs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    wire = treedef.unflatten([p[0] for p in pairs])
    resid = treedef.unflatten([p[1] for p in pairs])
    return wire, resid
