"""Static contract auditor quality gate (DESIGN.md §13).

Three layers:

  1. **pass unit tests** — each jaxpr-level pass (launch counting, taint,
     RNG lint, VMEM pricing) on minimal synthetic programs;
  2. **fixtures** — every deliberately-broken program in
     ``repro.analysis.fixtures`` is caught by exactly its intended pass
     (a checker that has never caught anything checks nothing);
  3. **the real stack** — the full family × backend × entry matrix, the
     residency-edge footprints, the consumer programs and the §2.4
     transaction table are all clean, and the CLI round-trips a JSON
     report with exit 0.
"""

import json

import jax
import jax.numpy as jnp
import pytest
from jax.experimental import pallas as pl

from repro.analysis import (
    ancestor_roundtrips,
    audit_jaxpr,
    audit_matrix,
    auto_reference_rng,
    count_pallas_calls,
    count_primitive,
    kernel_footprints,
    rng_findings,
    trace_cell,
    vmem_findings,
)
from repro.analysis.__main__ import main as analysis_main
from repro.analysis.contracts import (
    Contract,
    audit_large_n_footprints,
    cell_contract,
)
from repro.analysis.fixtures import FIXTURES, audit_fixtures, selftest
from repro.core.spec import (
    BACKENDS,
    ENTRY_POINTS,
    contract_cells,
    launch_budget,
    list_resamplers,
)
from repro.core.transactions import (
    MEGOPOLIS_EXACT,
    declared_transaction_bound,
    measured_transaction_stats,
)

N = 2048


# ------------------------------------------------------------ 1. the passes
def _copy_launch(x):
    return pl.pallas_call(
        lambda x_ref, o_ref: o_ref.__setitem__(..., x_ref[...]),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=True,
    )(x)


def test_count_pallas_calls_nested_in_scan():
    def f(x):
        def body(c, _):
            return _copy_launch(c), None

        out, _ = jax.lax.scan(body, x, None, length=3)
        return _copy_launch(out)

    jaxpr = jax.make_jaxpr(f)(jnp.zeros((N,), jnp.float32))
    assert count_pallas_calls(jaxpr) == 2  # static launch SITES, not trips


def test_count_primitive_kernel_internal_cond_excluded():
    """pl.when lowers to a cond INSIDE the kernel jaxpr; the host-side
    census must not charge it (the §12 rule is about HOST branching)."""
    jaxpr = trace_cell("megopolis", "pallas_interpret", "step")
    assert count_primitive(jaxpr, "cond", into_kernels=False) == 0
    assert count_primitive(jaxpr, "cond", into_kernels=True) > 0


def test_taint_flags_kernel_derived_gather_only():
    def bad(x, state):
        idx = _copy_launch(jnp.zeros((N,), jnp.int32))
        return jnp.take(state, idx, axis=0) + x[:, None]

    def clean(x, state):
        idx = jnp.arange(N)  # host-derived indices: allowed
        _ = _copy_launch(x)
        return jnp.take(state, idx, axis=0)

    args = (jnp.zeros((N,), jnp.float32), jnp.zeros((N, 4), jnp.float32))
    assert ancestor_roundtrips(jax.make_jaxpr(bad)(*args))
    assert not ancestor_roundtrips(jax.make_jaxpr(clean)(*args))


def test_rng_lint_key_reuse_and_clean_split():
    def reused(key):
        return jax.random.uniform(key, (4,)) + jax.random.normal(key, (4,))

    def clean(key):
        k1, k2 = jax.random.split(key)
        return jax.random.uniform(k1, (4,)) + jax.random.normal(k2, (4,))

    key = jax.random.PRNGKey(0)
    assert any(
        f.code == "key-reuse" for f in rng_findings(jax.make_jaxpr(reused)(key))
    )
    assert not rng_findings(jax.make_jaxpr(clean)(key))


def test_rng_lint_fold_in_distinct_data_is_idiom():
    def folds(key):
        ka = jax.random.fold_in(key, 0)
        kb = jax.random.fold_in(key, 1)
        return jax.random.uniform(ka, (4,)) + jax.random.uniform(kb, (4,))

    def folds_same(key):
        ka = jax.random.fold_in(key, 7)
        kb = jax.random.fold_in(key, 7)
        return jax.random.uniform(ka, (4,)) + jax.random.uniform(kb, (4,))

    key = jax.random.PRNGKey(0)
    assert not rng_findings(jax.make_jaxpr(folds)(key))
    assert any(
        f.code == "key-reuse"
        for f in rng_findings(jax.make_jaxpr(folds_same)(key))
    )


def test_rng_lint_loop_invariant_key():
    def loopkey(key, xs):
        def body(c, x):
            return c + jax.random.uniform(key, ()), None  # same draw each trip

        out, _ = jax.lax.scan(body, 0.0, xs)
        return out

    def loopfold(key, xs):
        def body(c, x):
            k = jax.random.fold_in(key, c.astype(jnp.int32))  # varies per trip
            return c + jax.random.uniform(k, ()), None

        out, _ = jax.lax.scan(body, jnp.float32(0.0), xs)
        return out

    key, xs = jax.random.PRNGKey(0), jnp.arange(3.0)
    assert any(
        f.code == "loop-invariant-key"
        for f in rng_findings(jax.make_jaxpr(loopkey)(key, xs))
    )
    assert not rng_findings(jax.make_jaxpr(loopfold)(key, xs))


def test_vmem_footprint_and_budget():
    jaxpr = jax.make_jaxpr(_copy_launch)(jnp.zeros((N,), jnp.float32))
    (fp,) = kernel_footprints(jaxpr)
    assert fp.vmem_bytes == 2 * N * 4  # input block + output block
    assert fp.within_budget
    assert not vmem_findings(jaxpr)
    assert vmem_findings(jaxpr, budget_bytes=N)  # tightened budget fires


# ------------------------------------------------------------- 2. fixtures
@pytest.mark.parametrize("name", sorted(FIXTURES))
def test_fixture_caught_by_its_pass(name):
    results = {n: (expected, rep) for n, expected, rep in audit_fixtures()}
    expected, rep = results[name]
    assert not rep.ok, f"fixture {name} should violate its contract"
    markers = {
        "launches": "launches exceed",
        "census": "ancestor-roundtrip",
        "rng": "[rng:",
        "vmem": "[vmem:",
    }
    assert any(markers[expected] in v for v in rep.violations), rep.violations
    for other, marker in markers.items():
        if other != expected:
            assert not any(marker in v for v in rep.violations), (
                f"fixture {name} also tripped the {other} pass: {rep.violations}"
            )


def test_fixture_selftest_clean():
    assert selftest() == []


# ---------------------------------------------------- 2b. pass 7 (§16)
def test_guard_audit_healthy_cell_is_clean():
    from repro.analysis.guards import audit_guard_cell

    rep = audit_guard_cell("megopolis", "pallas_interpret")
    assert rep["ok"], rep["violations"]
    assert rep["flag_jaxpr_match"]
    assert rep["launches_off"] == rep["launches_recover"]
    assert rep["clean_bit_identical"]
    assert rep["degenerate_recovered"]


def test_guard_audit_leaky_fixture_trips_every_check():
    from repro.analysis.fixtures import leaky_guard
    from repro.analysis.guards import compare_guard_traces

    rep = compare_guard_traces(
        "fixture:leaky_guard", *leaky_guard(), concrete=True
    )
    assert not rep["ok"]
    assert not rep["flag_jaxpr_match"]
    assert rep["launches_recover"] != rep["launches_off"]
    assert not rep["degenerate_recovered"]


# ------------------------------------------------------------ 3. the stack
def test_contract_table_covers_registry():
    cells = list(contract_cells())
    names = list_resamplers()
    assert len(cells) == len(names) * len(BACKENDS) * len(ENTRY_POINTS)
    for name in names:
        for backend in ("reference", "xla"):
            assert launch_budget(name, backend, "step") == 0
        assert launch_budget(name, "pallas", "step") == 1  # §12: fused
    with pytest.raises(KeyError):
        launch_budget("nonesuch", "pallas", "step")


def test_full_matrix_is_clean():
    """Every (family, backend, entry) cell honours its declared contract —
    the tentpole gate, on real traces of the whole registry."""
    bad = [rep for rep in audit_matrix() if not rep.ok]
    assert not bad, [(r.cell, r.violations) for r in bad]


def test_interpret_matches_pallas_launch_counts():
    for name in list_resamplers():
        for entry in ("apply", "step"):
            ji = trace_cell(name, "pallas_interpret", entry)
            jp = trace_cell(name, "pallas", entry)
            assert count_pallas_calls(ji) == count_pallas_calls(jp)


def test_residency_edge_footprints_within_budget():
    bad = [rep for rep in audit_large_n_footprints() if not rep.ok]
    assert not bad, [(r.cell, r.violations) for r in bad]
    reps = list(audit_large_n_footprints(families=("megopolis",)))
    assert reps and all(rep.footprints for rep in reps)


def test_auto_reference_rng_sweep():
    """The adaptive-iteration reference paths are RNG-clean, except the
    documented Megopolis identical-split — which must appear as a WAIVED
    finding, not vanish."""
    rows = {cell: (kept, waived) for cell, kept, waived in auto_reference_rng()}
    for cell, (kept, waived) in rows.items():
        assert not kept, (cell, [str(f) for f in kept])
    assert len(rows["megopolis/reference/auto"][1]) == 1
    assert not rows["metropolis/reference/auto"][1]


def test_tightened_contract_reports_violation():
    jaxpr = trace_cell("megopolis", "pallas_interpret", "step")
    rep = audit_jaxpr("megopolis/tight", jaxpr, Contract(max_launches=0))
    assert not rep.ok and "exceed the declared budget" in rep.violations[0]
    assert cell_contract("megopolis", "pallas_interpret", "step").max_launches == 1


def test_transaction_model_matches_paper_claims():
    stats = measured_transaction_stats("megopolis")
    assert stats["max"] == stats["mean"] == MEGOPOLIS_EXACT  # §2.4 equality
    for name in ("metropolis", "metropolis_c1", "metropolis_c2"):
        s = measured_transaction_stats(name)
        assert s["max"] <= s["bound"] == declared_transaction_bound(name)
    assert declared_transaction_bound("megopolis") == MEGOPOLIS_EXACT


# ------------------------------------------------------------------- CLI
def test_cli_selftest_exits_zero(capsys):
    assert analysis_main(["--selftest"]) == 0
    assert "selftest: OK" in capsys.readouterr().out


def test_cli_check_writes_report(tmp_path, capsys):
    out = tmp_path / "report.json"
    rc = analysis_main(
        [
            "--check",
            "--families", "megopolis",
            "--backends", "pallas_interpret",
            "--entries", "call,step",
            "--no-consumers", "--no-large-n", "--no-transactions",
            "--json", str(out),
        ]
    )
    assert rc == 0
    report = json.loads(out.read_text())
    # 2 entries × the default (float32, bfloat16) plane-dtype axis
    assert report["ok"] and report["matrix_cells"] == 4
    cells = {c["cell"] for c in report["matrix"]}
    assert "megopolis/pallas_interpret/step" in cells
    assert "megopolis/pallas_interpret/step@bfloat16" in cells
    assert "OK" in capsys.readouterr().out


def test_cli_check_plane_dtypes_flag(tmp_path):
    out = tmp_path / "report.json"
    rc = analysis_main(
        [
            "--check",
            "--families", "megopolis",
            "--backends", "pallas_interpret",
            "--entries", "call,step",
            "--plane-dtypes", "float32",
            "--no-consumers", "--no-large-n", "--no-transactions",
            "--json", str(out),
        ]
    )
    assert rc == 0
    report = json.loads(out.read_text())
    assert report["matrix_cells"] == 2


def test_cli_check_nonzero_on_violation(monkeypatch):
    import repro.analysis.report as report_mod

    broken = {
        "matrix": [],
        "matrix_cells": 0,
        "matrix_violations": [
            {"cell": "x/pallas/step", "violations": ["2 launches exceed 1"]}
        ],
        "ok": False,
    }
    monkeypatch.setattr(report_mod, "build_report", lambda **kw: broken)
    assert analysis_main(["--check", "--no-consumers"]) == 1