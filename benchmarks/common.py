"""Shared benchmark harness: timing, CSV output, resampler sweeps.

CPU timing caveat (DESIGN.md §6.3): this container has no TPU, so absolute
times are CPU wall-times of the jitted pure-JAX implementations.  The
paper's *orderings* (Megopolis vs Metropolis vs C1/C2 trends across N, y,
partition size) reproduce; the absolute GPU speedups do not transfer to a
CPU and are additionally modelled analytically in transactions_bench.py.
"""

from __future__ import annotations

import csv
import os
import time
from typing import Callable, Iterable

import jax
import jax.numpy as jnp
import numpy as np

OUT_DIR = os.environ.get("BENCH_OUT", os.path.join(os.path.dirname(__file__), "out"))


def ensure_out() -> str:
    os.makedirs(OUT_DIR, exist_ok=True)
    return OUT_DIR


def time_fn(fn: Callable, *args, warmup: int = 2, repeats: int = 5) -> float:
    """Median wall seconds of ``fn(*args)`` post-jit-warmup."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def write_csv(name: str, rows: list[dict]) -> str:
    path = os.path.join(ensure_out(), name)
    if rows:
        with open(path, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
            w.writeheader()
            w.writerows(rows)
    return path


def print_table(rows: list[dict], cols: Iterable[str] | None = None):
    if not rows:
        return
    cols = list(cols or rows[0].keys())
    widths = {c: max(len(c), *(len(_fmt(r.get(c))) for r in rows)) for c in cols}
    print("  ".join(c.ljust(widths[c]) for c in cols))
    for r in rows:
        print("  ".join(_fmt(r.get(c)).ljust(widths[c]) for c in cols))


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


def offsprings_for(resampler_fn, key, weights, runs: int, **kwargs) -> jnp.ndarray:
    """int32[runs, N] offspring matrix over ``runs`` Monte Carlo resamples."""
    n = weights.shape[0]

    @jax.jit
    def one(k):
        anc = resampler_fn(k, weights, **kwargs)
        return jnp.bincount(anc, length=n)

    keys = jax.random.split(key, runs)
    return jax.lax.map(one, keys)
