"""Shared kernel utilities: counter-based hash RNG + tile flat-roll.

Both are defined ONCE here and imported by the Pallas kernel bodies *and*
the ``ref.py`` oracles so kernel-vs-ref comparisons are bit-exact.

RNG rationale (DESIGN.md §2): the paper pays coalesced loads/stores for
CURAND XORWOW state.  A counter-based hash (murmur3 finalizer over
``(seed, lane, iteration)``) is stateless — zero memory traffic — and is
TPU-friendly (integer mul/xor/shift on the VPU).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.resilience.errors import VmemBudgetExceeded

# NOTE: all scalar constants below are *numpy* scalars so they inline as
# jaxpr literals — Pallas kernel bodies may not close over device constants.
_GOLDEN = np.uint32(0x9E3779B9)
LANES = 128
SUBLANES = 8
_LANE = LANES
_SUBLANES = SUBLANES
TILE = SUBLANES * LANES  # 1024 particles per (8,128) f32 VMEM tile


def tile_lane_ids(t) -> jnp.ndarray:
    """Global particle index of every lane of tile ``t``: int32[8, 128] with
    flat row-major value ``t * 1024 + row * 128 + col`` — the ONE lane->
    particle map every kernel body shares."""
    row = lax.broadcasted_iota(jnp.int32, (SUBLANES, LANES), 0)
    col = lax.broadcasted_iota(jnp.int32, (SUBLANES, LANES), 1)
    return t * TILE + row * LANES + col

# Residency budget for kernels that keep a whole f32[N] array VMEM-resident
# (the Metropolis/rejection random gather, the search kernel's CDF): ~4 MB,
# comfortably inside a 16 MB VMEM core.  ONE definition — DESIGN.md §2
# cites it, three ops modules enforce it.  The budget is BYTES underneath
# (MAX_VMEM_PARTICLE_BYTES): compressed planes (DESIGN.md §14) double the
# admissible N because a bf16/f16 word is half an f32 word.
MAX_VMEM_PARTICLES = 1 << 20
MAX_VMEM_PARTICLE_BYTES = 4 * MAX_VMEM_PARTICLES

# ---------------------------------------------------------------------------
# Compressed particle planes (DESIGN.md §14)
#
# The ``plane_dtype`` spec axis compresses what the fused path MOVES — the
# weight/CDF tiles and the float state planes — while every kernel body
# upcasts its loads so selection arithmetic, RNG, ESS/log-evidence stats and
# bisection boundaries stay f32 on-chip.  ``quantise_plane`` is the ONE
# rounding point (idempotent, applied at the Resampler entry for every
# backend); ``compress_plane`` is the lossless wire-narrowing the ops
# wrappers apply to already-quantised operands.
# ---------------------------------------------------------------------------

#: Spec-level names for the plane-compression axis.  float16 is experimental:
#: its 5-bit exponent underflows genuinely small weights (min normal ~6.1e-5)
#: so only bf16 (f32 exponent range) is quality-gated.
PLANE_DTYPES = ("float32", "bfloat16", "float16")


def canonical_plane_dtype(plane_dtype) -> jnp.dtype:
    """Validate and canonicalise a ``plane_dtype`` spec value to a dtype."""
    if plane_dtype is None:
        return jnp.dtype(jnp.float32)
    name = (
        plane_dtype if isinstance(plane_dtype, str) else jnp.dtype(plane_dtype).name
    )
    if name not in PLANE_DTYPES:
        raise ValueError(
            f"plane_dtype must be one of {PLANE_DTYPES}; got {plane_dtype!r}"
        )
    return jnp.dtype(name)


def plane_itemsize(plane_dtype) -> int:
    """Bytes per compressed-plane word (4, 2, 2)."""
    return canonical_plane_dtype(plane_dtype).itemsize


def quantise_plane(x: jnp.ndarray, plane_dtype) -> jnp.ndarray:
    """Round ``x`` onto the ``plane_dtype`` grid, keeping its own dtype.

    Identity for f32 planes (a same-dtype convert is elided from the
    jaxpr, preserving the structural identical-program gates) and for
    NON-float arrays (int particle states pass through untouched).
    Idempotent: ``quantise(quantise(x)) == quantise(x)`` bitwise, which is
    what makes the ops-layer ``compress_plane`` narrowing lossless.
    """
    x = jnp.asarray(x)
    if not jnp.issubdtype(x.dtype, jnp.floating):
        return x
    dt = canonical_plane_dtype(plane_dtype)
    return x.astype(dt).astype(x.dtype)


def compress_plane(x: jnp.ndarray, plane_dtype) -> jnp.ndarray:
    """Narrow an (already quantised) float plane to the wire dtype the
    kernel DMAs.  Non-float planes (int state) keep their dtype — the
    compression axis only ever touches float planes."""
    x = jnp.asarray(x)
    if not jnp.issubdtype(x.dtype, jnp.floating):
        return x
    return x.astype(canonical_plane_dtype(plane_dtype))

# ---------------------------------------------------------------------------
# Fused resample+gather state layout (DESIGN.md §11)
#
# The fused ``apply`` kernels keep the particle STATE resident in VMEM as a
# stack of flat (R, 128) planes — one plane per (padded) state component —
# so the post-selection copy ``x[k]`` is an in-register gather, never an
# HBM index round-trip.  Helpers below are shared by every family's fused
# kernel AND its wrapper so pack/gather/unpack can never disagree.
# ---------------------------------------------------------------------------

# Plane-stack padding granularity: state planes are padded to whole sublane
# groups so every per-tile state copy ([d_pad, 8, 128] block) is an integral
# number of (8, 128) VMEM tiles with full-stride DMAs on hardware.  A scalar
# state (state_dim == 1) is exempt — it degenerates to the weights' own
# (R, 128) layout and needs no padding.
STATE_PLANE_TILE = SUBLANES

# Resident-state budget in f32 words (n * d_pad): ~8 MB, alongside at most
# ~4 MB of resident weights (MAX_VMEM_PARTICLES) still inside a 16 MB core.
# Bytes underneath (MAX_VMEM_STATE_BYTES): compressed planes double the edge.
MAX_VMEM_STATE = 2 * MAX_VMEM_PARTICLES
MAX_VMEM_STATE_BYTES = 4 * MAX_VMEM_STATE


# Static per-launch footprint budget (DESIGN.md §13, pass 4): the analyzer
# prices every pallas_call's VMEM-resident bytes straight off its traced
# BlockSpecs — whole-array operands + per-grid-step blocks + vmem scratch —
# and checks the total against the residency budgets above.  The slack term
# covers what the word budgets deliberately exclude: grid-blocked operand
# windows, scratch accumulators and f32 output tiles.
VMEM_FOOTPRINT_SLACK_BYTES = 2 << 20


def vmem_budget_bytes() -> int:
    """Static VMEM byte budget for ONE kernel launch.

    Every plane the word budgets admit may be resident at most TWICE —
    pallas kernels take inputs and outputs as separate refs, so a fused
    step at the residency edge holds state in + state out plus a few
    weight planes (measured worst case: the prefix-family fused step at
    N*pad_state_dim == MAX_VMEM_STATE costs 19.0 MiB).  At the defaults
    this is 2 * (4 MB + 8 MB) + 2 MB = 26 MB inside a 32 MB core."""
    return 2 * 4 * (MAX_VMEM_PARTICLES + MAX_VMEM_STATE) + VMEM_FOOTPRINT_SLACK_BYTES


def block_bytes(shape, dtype) -> int:
    """Resident bytes of one kernel operand/scratch block."""
    size = 1
    for s in shape:
        size *= int(s)
    return size * np.dtype(dtype).itemsize


def pad_state_dim(state_dim: int) -> int:
    """Padded plane count for a ``state_dim``-component particle state."""
    if state_dim <= 1:
        return 1
    return -(-state_dim // STATE_PLANE_TILE) * STATE_PLANE_TILE


def check_state_resident(n: int, state_dim: int, who: str, itemsize: int = 4):
    """Raise when the fused kernels' resident plane stack exceeds the VMEM
    state budget: ``n * pad_state_dim(state_dim) * itemsize`` bytes against
    ``MAX_VMEM_STATE_BYTES``.  At the f32 default this is the historical
    word cap ``n * d_pad <= MAX_VMEM_STATE``; compressed planes
    (``itemsize == 2``) double the residency edge (DESIGN.md §14)."""
    d_pad = pad_state_dim(state_dim)
    if n * d_pad * itemsize > MAX_VMEM_STATE_BYTES:
        raise VmemBudgetExceeded(
            f"{who} keeps the whole particle state VMEM-resident and caps "
            f"N * pad_state_dim(state_dim) * itemsize at {MAX_VMEM_STATE_BYTES} "
            f"bytes (got N={n}, state_dim={state_dim}, itemsize={itemsize} -> "
            f"{n * d_pad * itemsize}). Use apply on the reference/xla backend "
            "(index + XLA gather) above this size."
        )


def state_dim_of(particles: jnp.ndarray, n: int, who: str, lead: int = 1) -> int:
    """Flattened state component count of ``particles``, validating that the
    particle axis (``lead``-th axis: 1 = ``[N, ...]``, 2 = ``[B, N, ...]``)
    matches ``n``.  The ONE lead-axis/state-dim check every fused ops
    wrapper shares."""
    if particles.ndim < lead or particles.shape[lead - 1] != n:
        raise ValueError(
            f"{who}: particles must carry the particle axis at position "
            f"{lead - 1} ({'[B, N, ...]' if lead == 2 else '[N, ...]'}); got "
            f"{particles.shape} for N={n}"
        )
    d = 1
    for s in particles.shape[lead:]:
        d *= s
    return d


def state_itemsize(particles: jnp.ndarray, plane_dtype) -> int:
    """Resident bytes per state word under the compression axis: the plane
    dtype's width for float states, the state's own width otherwise (int
    states never compress)."""
    if jnp.issubdtype(jnp.asarray(particles).dtype, jnp.floating):
        return plane_itemsize(plane_dtype)
    return jnp.dtype(particles.dtype).itemsize


def run_fused_bank(launch, weights: jnp.ndarray, particles: jnp.ndarray, who: str,
                   plane_dtype="float32"):
    """Shared bank scaffolding for every family's fused apply launch:
    residency check, per-row plane pack (+ §14 wire narrowing),
    ``launch(w3, planes4d) -> (k3, out4d)``, per-row unpack.  Returns
    ``(particles'[B, N, ...], ancestors int32[B, N])``."""
    import jax

    bsz, n = weights.shape
    check_state_resident(n, state_dim_of(particles, n, who, lead=2), who,
                         itemsize=state_itemsize(particles, plane_dtype))
    w3 = compress_plane(weights.reshape(bsz, n // LANES, LANES), plane_dtype)
    planes = compress_plane(
        jax.vmap(lambda p: pack_state_planes(p)[0])(particles), plane_dtype
    )
    k3, out = launch(w3, planes)
    state_shape = particles.shape[2:]
    out_rows = jax.vmap(lambda o: unpack_state_planes(o, state_shape))(
        out.astype(particles.dtype)
    )
    return out_rows, k3.reshape(bsz, n)


def pack_state_planes(particles: jnp.ndarray):
    """``[N]`` or ``[N, ...]`` particles -> ``[d_pad, N // 128, 128]`` plane
    stack (zero-padded), plus the trailing state shape for ``unpack``.

    Plane ``d`` holds component ``d`` of every particle in the SAME flat
    row-major (R, 128) layout the weight kernels use, so ``tile_lane_ids``
    indexes state exactly like it indexes weights.
    """
    n = particles.shape[0]
    state_shape = particles.shape[1:]
    d = 1
    for s in state_shape:
        d *= s
    d_pad = pad_state_dim(d)
    flat = particles.reshape(n, d).T  # [d, N]
    if d_pad != d:
        flat = jnp.concatenate(
            [flat, jnp.zeros((d_pad - d, n), flat.dtype)], axis=0
        )
    return flat.reshape(d_pad, n // LANES, LANES), state_shape


def unpack_state_planes(planes: jnp.ndarray, state_shape) -> jnp.ndarray:
    """Invert ``pack_state_planes``: ``[d_pad, R, 128]`` -> ``[N, *shape]``."""
    d_pad = planes.shape[0]
    n = planes.shape[-2] * planes.shape[-1]
    d = 1
    for s in state_shape:
        d *= s
    out = planes.reshape(d_pad, n)[:d].T  # [N, d]
    return out.reshape((n,) + tuple(state_shape))


def gather_state(planes: jnp.ndarray, k_global: jnp.ndarray) -> jnp.ndarray:
    """In-register state copy: ``out[:, i] = planes[:, k_global[i]]``.

    ``planes``: the resident ``[d_pad, rows, 128]`` plane-stack VALUE;
    ``k_global``: int32[8, 128] ancestor ids of one output tile.  Returns
    the gathered ``[d_pad, 8, 128]`` state block — the tile the fused
    kernels write straight to the output ref (Alg. 5's state copy, fused)."""
    d_pad, rows, lanes = planes.shape
    flat = planes.reshape(d_pad, rows * lanes)
    return jnp.take(flat, k_global.reshape(-1), axis=1).reshape(
        d_pad, SUBLANES, LANES
    )


def step_stats(lw_flat: jnp.ndarray, n_total: int):
    """Fused-step prelude statistics from a resident flat log-weight vector:
    ``(m, ess_norm, log_evidence_incr, max_weight, degenerate)``.

    Mirrors ``repro.core.metrics`` term for term — guarded shift-by-max
    (``normalise_log_weights``), ``(Σw)²/max(Σw², 1e-30)`` over the SAME
    flat [N] reduction shape (``effective_sample_size``), the
    ``m + log(Σw) - log(N)`` decomposition (``log_mean_weight``), and
    ``max(w)/max(Σw, 1e-30)`` (``max_normalised_weight``).  Kernel bodies
    MUST reshape their (rows, 128) log-weight block to flat [N] before
    calling: a 2-D reduction changes the f32 summation tree and breaks
    bit-parity with the host helpers.

    ``degenerate`` is the §16 collapsed-bank flag (``~isfinite(max)``:
    all-``-inf``, any nan/+inf — ``metrics.degenerate_log_weights``).  Where
    it is set, ESS and max-weight are computed from the SAME uniform-``1/N``
    fallback bank ``normalise_log_weights`` substitutes on the host, so the
    on-chip trigger stays bit-identical to the composed oracle; ``incr``
    keeps the raw ``log_mean_weight`` decomposition (``-inf``/nan there is
    the truthful evidence of a dead bank, and the step's where-select zeroes
    it on the untriggered branch exactly as the host does).
    """
    m_raw = jnp.max(lw_flat)
    deg = ~jnp.isfinite(m_raw)
    m = jnp.where(deg, jnp.zeros_like(m_raw), m_raw)
    w_raw = jnp.exp(lw_flat - m)
    incr = (m + jnp.log(jnp.sum(w_raw))) - jnp.log(jnp.float32(n_total))
    w = jnp.where(deg, jnp.full_like(w_raw, 1.0 / n_total), w_raw)
    s1 = jnp.sum(w)
    s2 = jnp.sum(w * w)
    ess = jnp.square(s1) / jnp.maximum(s2, 1e-30)
    ess_norm = ess / jnp.float32(n_total)
    maxw = jnp.max(w) / jnp.maximum(s1, 1e-30)
    return m, ess_norm, incr, maxw, deg


def step_select(do, k_new: jnp.ndarray, t) -> jnp.ndarray:
    """The fused step's on-chip resample branch for one output tile: the
    freshly selected ancestors when the ESS trigger fired, else the identity
    permutation (``tile_lane_ids``) that makes the state copy a no-op."""
    return jnp.where(do, k_new, tile_lane_ids(t))


def gather_state_full(planes: jnp.ndarray, k_global: jnp.ndarray) -> jnp.ndarray:
    """Whole-array variant of ``gather_state`` for single-grid-step kernels
    (the prefix-sum fused step): gathers ALL rows at once, returning a full
    ``[d_pad, rows, 128]`` block for a ``k_global`` of shape (rows, 128)."""
    d_pad, rows, lanes = planes.shape
    flat = planes.reshape(d_pad, rows * lanes)
    return jnp.take(flat, k_global.reshape(-1), axis=1).reshape(d_pad, rows, lanes)


def run_step_bank(launch, log_weights: jnp.ndarray, particles: jnp.ndarray, who: str,
                  plane_dtype="float32"):
    """Bank scaffolding for every family's fused STEP launch — the step
    analogue of ``run_fused_bank``: residency check, per-row plane pack,
    ``launch(lw3, planes4d) -> (k3, out4d, stats4)`` with ``stats4`` =
    f32[B, 4] rows of (ess_norm, log_evidence_incr, resampled, max_weight)
    — the in-kernel StepStats vector of DESIGN.md §15 — then per-row
    unpack.  Returns ``(particles'[B, N, ...], ancestors int32[B, N],
    stats f32[B, 4])``."""
    import jax

    bsz, n = log_weights.shape
    check_state_resident(n, state_dim_of(particles, n, who, lead=2), who,
                         itemsize=state_itemsize(particles, plane_dtype))
    lw3 = compress_plane(log_weights.reshape(bsz, n // LANES, LANES), plane_dtype)
    planes = compress_plane(
        jax.vmap(lambda p: pack_state_planes(p)[0])(particles), plane_dtype
    )
    k3, out, stats = launch(lw3, planes)
    state_shape = particles.shape[2:]
    out_rows = jax.vmap(lambda o: unpack_state_planes(o, state_shape))(
        out.astype(particles.dtype)
    )
    return out_rows, k3.reshape(bsz, n), stats


def check_tile_aligned(n: int, who: str):
    """Raise unless N is whole (8, 128) f32 VMEM tiles."""
    if n % TILE != 0:
        raise ValueError(f"{who} requires N % {TILE} == 0; got {n}")


def check_vmem_resident(
    n: int,
    who: str,
    what: str = "weight array",
    remedy: str = "Use megopolis_tpu (streams tiles at any N).",
    itemsize: int = 4,
):
    """Raise when a whole-array-resident kernel exceeds the VMEM budget
    (``n * itemsize`` bytes against ``MAX_VMEM_PARTICLE_BYTES``; the f32
    default reproduces the historical ``n <= MAX_VMEM_PARTICLES`` cap)."""
    if n * itemsize > MAX_VMEM_PARTICLE_BYTES:
        raise VmemBudgetExceeded(
            f"{who} keeps the whole {what} VMEM-resident and caps N * itemsize "
            f"at {MAX_VMEM_PARTICLE_BYTES} bytes — the scaling wall the "
            f"paper's coalescing removes. {remedy}"
        )


def murmur3_fmix(x: jnp.ndarray) -> jnp.ndarray:
    """murmur3 32-bit finalizer; full-avalanche integer hash."""
    x = x.astype(jnp.uint32)
    x = x ^ (x >> np.uint32(16))
    x = x * np.uint32(0x85EBCA6B)
    x = x ^ (x >> np.uint32(13))
    x = x * np.uint32(0xC2B2AE35)
    x = x ^ (x >> np.uint32(16))
    return x


def hash_bits(seed, lane_index, iteration) -> jnp.ndarray:
    """uint32 stream indexed by (seed, lane, iteration) — order-free."""
    if isinstance(iteration, (int, np.integer)):
        # wrap in Python ints to avoid numpy overflow RuntimeWarnings
        inc = np.uint32((int(iteration) * int(_GOLDEN)) & 0xFFFFFFFF)
    else:
        inc = jnp.asarray(iteration).astype(jnp.uint32) * _GOLDEN
    if isinstance(seed, (int, np.integer)) and isinstance(inc, np.uint32):
        s = np.uint32((int(seed) + int(inc)) & 0xFFFFFFFF)
    else:
        s = _as_u32(seed) + inc
    return murmur3_fmix(murmur3_fmix(s) ^ (lane_index.astype(jnp.uint32) * _GOLDEN))


def _as_u32(x):
    if isinstance(x, (int, np.integer)):
        return np.uint32(x)
    return jnp.asarray(x).astype(jnp.uint32)


def hash_uniform(seed, lane_index, iteration, dtype=jnp.float32) -> jnp.ndarray:
    """U[0,1) with 24 bits of mantissa entropy."""
    bits = hash_bits(seed, lane_index, iteration)
    return (bits >> np.uint32(8)).astype(dtype) * (1.0 / (1 << 24))


def hash_randint(seed, lane_index, iteration, bound) -> jnp.ndarray:
    """uint32 in [0, bound) via modulo (bias < 2^-20 for bound <= 2^12)."""
    return (hash_bits(seed, lane_index, iteration) % _as_u32(bound)).astype(jnp.int32)


def flat_roll(x: jnp.ndarray, shift) -> jnp.ndarray:
    """Roll a (rows, 128) tile by ``shift`` in FLAT row-major order:
    ``out.flat[p] = x.flat[(p + shift) % size]``.

    Decomposed into two row-rolls + two lane-rolls + a lane-mask select so
    every constituent op is a register-level vector rotate (the in-VMEM
    analogue of the paper's intra-segment wrap, Alg. 5 line 10).
    """
    rows, lanes = x.shape
    shift = jnp.asarray(shift) % (rows * lanes)
    a = shift // lanes
    b = shift % lanes
    hi = jnp.roll(x, -a, axis=0)  # rows shifted by floor(shift/lanes)
    lo = jnp.roll(x, -(a + 1), axis=0)  # .. and one further for wrapped lanes
    hi = jnp.roll(hi, -b, axis=1)
    lo = jnp.roll(lo, -b, axis=1)
    col = lax.broadcasted_iota(jnp.int32, (rows, lanes), 1)
    return jnp.where(col < (lanes - b).astype(jnp.int32), hi, lo)


def key_to_seed(key) -> jnp.ndarray:
    """Derive a uint32 seed from a JAX PRNG key (stable, documented)."""
    import jax

    data = jax.random.key_data(key).astype(jnp.uint32)
    return murmur3_fmix(data[..., 0] ^ (data[..., 1] * _GOLDEN))
