"""Megopolis resampling (paper Algorithm 5) — reference JAX implementation.

The key structural idea: the ``B`` random comparison indices are drawn ONCE,
globally, as offsets ``o[b] ~ U{0, N-1}`` shared by all particles.  At
iteration ``b`` particle ``i`` compares its current ancestor ``k`` against

    j = (aligned(i) + aligned(o[b]) + (i + o[b]) mod S) mod N

where ``S`` is the coalescing segment size (32 on the paper's GPU warps;
1024 = one (8,128) f32 VMEM tile for the TPU kernel in
``repro.kernels.megopolis``).  For each fixed ``o[b]`` the map ``i -> j`` is
a segment-aligned global rotation — a bijection — so every particle is
exposed exactly once per iteration, which is what drives Megopolis' lower
offspring variance (paper §6.1).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

DEFAULT_SEGMENT = 32  # paper-faithful warp size; TPU kernel uses 1024.


def megopolis_indices(i: jnp.ndarray, offset, segment: int, n: int) -> jnp.ndarray:
    """The Megopolis comparison-index map (Alg. 5 lines 7-11), vectorised.

    Exposed separately so the Pallas kernel's ``ref.py``, the distributed
    shard_map version, and property tests all share one definition.
    """
    i_aligned = i - (i % segment)
    o_aligned = offset - (offset % segment)
    o_unaligned = (i + offset) % segment
    return (i_aligned + o_aligned + o_unaligned) % n


def megopolis(
    key: jax.Array,
    weights: jnp.ndarray,
    num_iters: int,
    *,
    segment: int = DEFAULT_SEGMENT,
) -> jnp.ndarray:
    """Resample; returns int32 ancestor indices (paper Algorithm 5).

    Args:
      key: PRNG key.
      weights: ``f32[N]`` unnormalised, non-negative particle weights.
      num_iters: ``B`` — accept/reject iterations (see ``select_iterations``).
      segment: coalescing segment size ``S``; any ``S >= 1`` is valid
        (Proposition 1 needs only bijectivity + uniformity, both independent
        of ``S``).
    """
    n = weights.shape[0]
    key_off, key_u = jax.random.split(key)
    offsets = jax.random.randint(key_off, (num_iters,), 0, n)
    i = jnp.arange(n, dtype=jnp.int32)

    def body(b, k):
        j = megopolis_indices(i, offsets[b], segment, n).astype(jnp.int32)
        u = jax.random.uniform(jax.random.fold_in(key_u, b), (n,), weights.dtype)
        # u <= w[j] / w[k]  <=>  u * w[k] <= w[j]   (division-free, w >= 0)
        accept = u * weights[k] <= weights[j]
        return jnp.where(accept, j, k)

    return jax.lax.fori_loop(0, num_iters, body, i)
