"""Analyzer report as a benchmark artifact (DESIGN.md §13).

Not a timing suite: the paper's speed argument is *counted*, so the
trajectory JSON should carry the counts — launch sites per matrix cell,
static VMEM footprints at the residency edge, and the §2.4 transactions
per warp-iteration — alongside the wall-times the other suites measure.
Writes ``BENCH_analysis.json`` for ``benchmarks.run --json`` to fold in;
exits non-zero if any contract is violated, so a regression fails the
perf lane too, not just the dedicated contracts lane.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

from benchmarks.common import ensure_out, print_table


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--backends", default="pallas_interpret,pallas",
                    help="comma-separated backends to audit (launch counts "
                         "are identical across the pallas pair)")
    args = ap.parse_args(argv)
    backends = tuple(b for b in args.backends.split(",") if b)

    from repro.analysis.contracts import audit_large_n_footprints, audit_matrix
    from repro.analysis.report import transaction_report

    cells = []
    for rep in audit_matrix(backends=backends):
        name, backend, entry = rep.cell.split("/")
        cells.append(
            {
                "family": name,
                "backend": backend,
                "entry": entry,
                "launches": rep.launches,
                "budget": rep.max_launches,
                "ok": rep.ok,
            }
        )

    footprints = []
    for rep in audit_large_n_footprints():
        footprints.append(
            {
                "cell": rep.cell,
                "vmem_bytes": max((fp.vmem_bytes for fp in rep.footprints), default=0),
                "budget_bytes": rep.footprints[0].budget_bytes if rep.footprints else None,
                "ok": rep.ok,
            }
        )

    tx = transaction_report()

    # Per-family launch summary over the fused entries — the headline table.
    rows = []
    for fam in sorted({c["family"] for c in cells}):
        fam_cells = [c for c in cells if c["family"] == fam]
        rows.append(
            {
                "family": fam,
                "call": next(c["launches"] for c in fam_cells if c["entry"] == "call"),
                "apply": next(c["launches"] for c in fam_cells if c["entry"] == "apply"),
                "step": next(c["launches"] for c in fam_cells if c["entry"] == "step"),
                "tx_max": tx.get(fam, {}).get("max"),
                "tx_bound": tx.get(fam, {}).get("bound"),
                "ok": all(c["ok"] for c in fam_cells),
            }
        )
    print_table(rows, cols=["family", "call", "apply", "step", "tx_max", "tx_bound", "ok"])

    ok = (
        all(c["ok"] for c in cells)
        and all(f["ok"] for f in footprints)
        and all(v["ok"] for v in tx.values())
    )
    payload = {
        "ok": ok,
        "cells": cells,
        "large_n_footprints": footprints,
        "transactions": tx,
    }
    path = os.path.join(ensure_out(), "BENCH_analysis.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    print(f"wrote {path}")
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()