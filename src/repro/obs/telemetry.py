"""``Telemetry`` — the scan-carried trajectory record (DESIGN.md §15).

Consumers accept ``telemetry: bool = False`` and, when asked, return a
``Telemetry`` alongside their usual outputs:

- ``run_filter`` / ``run_filter_bank``   → ``steps`` holds one ``StepStats``
  per observation (``[T]`` per field; banks ``[S, T]``, matching the
  estimate layout).
- ``run_smc_sampler`` / ``_bank``        → ``steps`` per temperature, plus
  ``accept`` (RWM/MALA acceptance rate per temperature) and ``betas`` (the
  adaptive β ladder actually visited).
- ``smc_decode``                         → ``steps`` per generated token.

The record is built from values the scans ALREADY compute — enabling it
adds zero kernel launches and must not perturb the ancestor-stream jaxpr
(analyzer pass 6 audits exactly this).  When off, consumers return their
historical shapes and the record is structurally absent from the trace:
the flag is Python-static, so disabled telemetry is not an empty pytree
in the jaxpr — it is nothing at all.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax.numpy as jnp

from repro.obs.stats import StepStats


class Telemetry(NamedTuple):
    steps: StepStats
    accept: Optional[jnp.ndarray] = None
    betas: Optional[jnp.ndarray] = None
