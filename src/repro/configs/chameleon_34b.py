"""Chameleon 34B [arXiv:2405.09818] — early-fusion VLM, VQ image tokens.

48L  d_model=8192  64H (GQA kv=8, head_dim=128)  d_ff=22016  vocab=65536.
Early fusion: text + VQ image tokens share one stream; the VQ-GAN image
tokenizer is a STUB per the assignment — ``input_specs()`` provides
precomputed patch/token embeddings -> ``embeds_input=True``.  Chameleon's
qk-norm (their key stability fix) is on.  Full attention -> long_500k
skipped.
"""

from repro.configs import ArchSpec
from repro.models import ModelConfig

ARCH = ArchSpec(
    name="chameleon-34b",
    family="vlm",
    source="arXiv:2405.09818",
    model=ModelConfig(
        name="chameleon-34b",
        num_layers=48,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=22016,
        vocab_size=65536,
        mlp_type="swiglu",
        qk_norm=True,
        layer_pattern=("attn",),
        rope_theta=10_000.0,
        embeds_input=True,
        long_context_ok=False,
    ),
    smoke=ModelConfig(
        name="chameleon-smoke",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=256,
        vocab_size=512,
        mlp_type="swiglu",
        qk_norm=True,
        layer_pattern=("attn",),
        embeds_input=True,
        remat=False,
    ),
    microbatches=16,
    moment_dtype="bfloat16",
    notes="early-fusion VLM backbone; VQ frontend stubbed; qk-norm",
)
