"""Paper Fig. 7: MSE and execution time of C1/C2 across partition sizes
{128, 256, 512, 1024, 2048} vs the Megopolis reference lines, at the
largest N with y = 4 (weights concentrated — the degeneracy regime)."""

from __future__ import annotations

import argparse
import functools

import jax

from benchmarks.common import offsprings_for, print_table, time_fn, write_csv
from repro.core import get_resampler
from repro.core.iterations import gaussian_weight_iterations
from repro.core.metrics import bias_variance
from repro.core.weightgen import gaussian_weights

PARTITIONS = (128, 256, 512, 1024, 2048)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--y", type=float, default=4.0)
    args = ap.parse_args(argv)
    n = 1 << (22 if args.full else 14)
    runs = 256 if args.full else 16
    b = gaussian_weight_iterations(args.y, 0.01)
    key = jax.random.PRNGKey(11)
    w = gaussian_weights(key, n, args.y)

    rows = []
    for algo in ("megopolis", "metropolis_c1", "metropolis_c2"):
        sizes = (0,) if algo == "megopolis" else PARTITIONS
        for ps in sizes:
            kw = {} if algo == "megopolis" else {"partition_size_bytes": ps}
            fn = get_resampler(algo)
            off = offsprings_for(fn, jax.random.fold_in(key, 1), w, runs,
                                 num_iters=b, **kw)
            var, bias_sq, total = bias_variance(off, w)
            jit_fn = jax.jit(functools.partial(fn, num_iters=b, **kw))
            t = time_fn(lambda k: jit_fn(k, w), jax.random.PRNGKey(5))
            rows.append({"algo": algo, "partition_bytes": ps, "B": b,
                         "mse_over_n": float(total) / n, "time_s": t})
    write_csv("fig7.csv", rows)
    print_table(rows)
    mego = next(r for r in rows if r["algo"] == "megopolis")
    worst_c1 = max(r["mse_over_n"] for r in rows if r["algo"] == "metropolis_c1")
    print(f"\nC1 worst-partition MSE is {worst_c1 / mego['mse_over_n']:.1f}x Megopolis "
          f"(paper reports ~15x at PS=128, y=4)")


if __name__ == "__main__":
    main()
