"""Per-architecture smoke tests (assignment requirement f): each assigned
arch instantiates its REDUCED same-family config and runs one forward +
one train-ish step on CPU, asserting output shapes and no NaNs.  FULL
configs are exercised only via the dry-run (no allocation here)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, applicable_shapes, get_arch
from repro.models import decode_step, forward, init_params, loss_fn, prefill


def _smoke_cfg(arch_id):
    arch = get_arch(arch_id)
    return dataclasses.replace(arch.smoke, dtype=jnp.float32, remat=False)


def _inputs(cfg, key, b=2, s=8):
    if cfg.embeds_input:
        return jax.random.normal(key, (b, s, cfg.d_model), jnp.float32)
    return jax.random.randint(key, (b, s), 0, cfg.vocab_size, jnp.int32)


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_forward_shapes_and_finite(arch_id):
    cfg = _smoke_cfg(arch_id)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    x = _inputs(cfg, jax.random.fold_in(key, 1))
    h = forward(params, cfg, x)
    assert h.shape == (2, 8, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(h))), f"{arch_id}: non-finite hidden states"


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_train_step(arch_id):
    """One loss+grad step: finite loss, finite grads, loss decreases after
    a plain SGD step (learning signal exists)."""
    cfg = _smoke_cfg(arch_id)
    key = jax.random.PRNGKey(1)
    params = init_params(key, cfg)
    batch = {
        "inputs": _inputs(cfg, jax.random.fold_in(key, 2)),
        "targets": jax.random.randint(jax.random.fold_in(key, 3), (2, 8), 0,
                                      cfg.vocab_size, jnp.int32),
    }
    loss, grads = jax.value_and_grad(lambda p: loss_fn(p, cfg, batch))(params)
    assert bool(jnp.isfinite(loss)), f"{arch_id}: NaN loss"
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, f"{arch_id}: degenerate grads"
    params2 = jax.tree.map(lambda p, g: p - 0.5 * g, params, grads)
    loss2 = loss_fn(params2, cfg, batch)
    assert float(loss2) < float(loss), f"{arch_id}: no learning signal"


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_decode_step(arch_id):
    """decode_32k/long_500k cells lower serve_step — its smoke equivalent:
    prefill then one cached decode step; logits finite, caches update."""
    cfg = _smoke_cfg(arch_id)
    key = jax.random.PRNGKey(2)
    params = init_params(key, cfg)
    prompt = _inputs(cfg, jax.random.fold_in(key, 1), b=2, s=6)
    logits, caches = prefill(params, cfg, prompt, max_seq=10)
    assert logits.shape == (2, cfg.vocab_size)
    if cfg.embeds_input:
        tok = jax.random.normal(jax.random.fold_in(key, 4), (2, 1, cfg.d_model), jnp.float32)
    else:
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    logits2, caches2 = decode_step(params, cfg, tok, caches, jnp.int32(6))
    assert logits2.shape == (2, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits2))), f"{arch_id}: non-finite decode logits"


def test_exact_assigned_configs():
    """The FULL configs carry the exact published dimensions."""
    expect = {
        "nemotron_4_15b": (32, 6144, 48, 8, 24576, 256000),
        "gemma3_27b": (62, 5376, 32, 16, 21504, 262144),
        "h2o_danube_3_4b": (24, 3840, 32, 8, 10240, 32000),
        "qwen3_0_6b": (28, 1024, 16, 8, 3072, 151936),
        "dbrx_132b": (40, 6144, 48, 8, 10752, 100352),
        "llama4_maverick_400b_a17b": (48, 5120, 40, 8, 8192, 202048),
        "musicgen_large": (48, 2048, 32, 32, 8192, 2048),
        "chameleon_34b": (48, 8192, 64, 8, 22016, 65536),
        "zamba2_2_7b": (54, 2560, 32, 32, 10240, 32000),
        "mamba2_1_3b": (48, 2048, 32, 32, 0, 50304),  # vocab padded 50280->50304
    }
    for arch_id, (nl, d, h, kv, ff, v) in expect.items():
        m = get_arch(arch_id).model
        assert (m.num_layers, m.d_model, m.num_heads, m.num_kv_heads, m.d_ff,
                m.vocab_size) == (nl, d, h, kv, ff, v), arch_id


def test_moe_param_counts_match_published():
    assert abs(get_arch("dbrx_132b").model.num_params() / 1e9 - 132) < 3
    llama4 = get_arch("llama4_maverick_400b_a17b").model
    assert abs(llama4.num_params() / 1e9 - 400) < 8
    assert abs(llama4.num_active_params() / 1e9 - 17) < 2


def test_shape_applicability():
    for arch_id in ARCH_IDS:
        shapes = applicable_shapes(get_arch(arch_id))
        assert "train_4k" in shapes and "decode_32k" in shapes
    assert "long_500k" not in applicable_shapes(get_arch("qwen3_0_6b"))
    assert "long_500k" in applicable_shapes(get_arch("mamba2_1_3b"))
    assert "long_500k" in applicable_shapes(get_arch("gemma3_27b"))
