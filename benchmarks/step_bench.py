"""Fused SMC step (`Resampler.step`) vs the composed chain (DESIGN.md §12).

    PYTHONPATH=src:. python benchmarks/step_bench.py [--quick|--smoke]

Three result surfaces per (family × backend) cell:

  * **wall time** — ``step`` vs the normalise → ESS → branch → ``apply``
    composition, both jitted, chained under ``lax.scan`` (the consumer
    pattern).  On reference/xla ``step`` IS the composition (bit-identical
    oracle) so those cells pin "no slower" STRUCTURALLY — identical jaxpr
    ⇒ identical program ⇒ identical wall time, deterministically.
    ``pallas_interpret`` walls are reported but not perf-gated (interpret
    mode is a Python-level simulator; see EXPERIMENTS.md §Fused-gather).
  * **launch count** — pallas_call count in the traced step vs the traced
    composition on the pallas backend: the tentpole claim is step == 1 for
    EVERY family, vs 1 (Metropolis family) / 2 (prefix kinds) / 4
    (residual) kernel launches plus host normalise/ESS/branch glue for the
    composition.
  * **parity + HBM model** — every cell asserts ``step`` == composition
    bit-exactly (the CI perf-smoke gate: fails on mismatch, never on
    timing), and ``launch/memmodel.smc_step_bytes`` reports the analytic
    per-step byte win (8N/row: normalised weights + ancestors).

Writes ``out/step_bench.csv`` + ``out/BENCH_step.json`` (folded into
``benchmarks/run.py --json`` trajectories).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import OUT_DIR, ensure_out, print_table, write_csv
from repro.core.metrics import (
    degenerate_log_weights,
    effective_sample_size,
    log_mean_weight,
    max_normalised_weight,
    normalise_log_weights,
    unique_ancestor_count,
)
from repro.obs.stats import stats_from_vector
from repro.analysis import count_pallas_calls as _count_pallas_calls
from repro.core.spec import spec_for_backend
from repro.kernels.common import plane_itemsize
from repro.launch.memmodel import smc_step_bytes

#: The DESIGN.md §14 compression axis swept by default.
PLANE_DTYPES = ("float32", "bfloat16")

FAMILIES = (
    "megopolis",
    "metropolis",
    "metropolis_c1",
    "metropolis_c2",
    "rejection",
    "multinomial",
    "systematic",
    "improved_systematic",
    "stratified",
    "residual",
)
BACKENDS = ("reference", "xla", "pallas_interpret")
# CPU cells held to the structural no-slower gate: step IS the composition.
TIMED_GATE_BACKENDS = ("reference", "xla")
THRESHOLD = 0.5


def _composed(r, key, log_w, particles, thr):
    # Quantise at the boundary first — the value the fused step's in-kernel
    # requantise matches (DESIGN.md §14); ``r.apply`` re-lands the
    # normalised weights on the same grid.  Identity at f32, so the f32
    # structural no-slower gate still sees the identical jaxpr.  Mirrors
    # the public ``Resampler.step`` wrapper op-for-op, INCLUDING the §15
    # StepStats composition (stats4 stack + sort-based survivor count).
    log_w = r.quantise(log_w)
    particles = r.quantise(particles)
    n = log_w.shape[-1]
    ess_n = effective_sample_size(log_w) / jnp.float32(n)
    do = ess_n < thr
    w = normalise_log_weights(log_w)
    p_res, a_res = r.apply(key, w, particles)
    ancestors = jnp.where(do, a_res, jnp.arange(n, dtype=jnp.int32))
    p_out = jnp.where(do, p_res, particles)
    incr = jnp.where(do, log_mean_weight(log_w), jnp.float32(0.0))
    stats4 = jnp.stack([
        ess_n,
        incr,
        jnp.where(do, jnp.float32(1.0), jnp.float32(0.0)),
        max_normalised_weight(log_w),
    ])
    return p_out, ancestors, stats_from_vector(
        stats4, unique_ancestor_count(ancestors),
        degenerate_log_weights(log_w)
    )


def _time_pair(fused, unfused, *args, repeats: int):
    """Best-of-``repeats`` wall seconds, interleaved with alternating order
    (same harness as fused_gather_bench: fixed order skews ~10% on this
    CPU from cache position bias)."""
    for _ in range(2):
        jax.block_until_ready(fused(*args))
        jax.block_until_ready(unfused(*args))
    t_f, t_u = [], []
    for i in range(repeats):
        first, second = (fused, unfused) if i % 2 == 0 else (unfused, fused)
        t0 = time.perf_counter()
        jax.block_until_ready(first(*args))
        t1 = time.perf_counter()
        jax.block_until_ready(second(*args))
        t2 = time.perf_counter()
        if i % 2 == 0:
            t_f.append(t1 - t0)
            t_u.append(t2 - t1)
        else:
            t_u.append(t1 - t0)
            t_f.append(t2 - t1)
    return float(np.min(t_f)), float(np.min(t_u))


def _cell(name, backend, *, n, state_dim, num_iters, max_iters, repeats,
          chain: int, plane_dtype: str = "float32"):
    r = spec_for_backend(name, backend, num_iters=num_iters,
                         max_iters=max_iters, plane_dtype=plane_dtype).build()
    key = jax.random.PRNGKey(7)
    lw = jax.random.normal(jax.random.PRNGKey(1), (n,)) * 2.0
    p = jax.random.normal(jax.random.PRNGKey(2), (n, state_dim))
    keys = jax.random.split(key, chain)

    # Timed surface: a chain of full SMC steps under one jitted lax.scan,
    # each step's particles feeding the next (the filter/sampler pattern).
    def fused_chain(p0):
        return jax.lax.scan(
            lambda q, k: (r.step(k, lw, q, THRESHOLD)[0], None), p0, keys
        )[0]

    def composed_chain(p0):
        return jax.lax.scan(
            lambda q, k: (_composed(r, k, lw, q, THRESHOLD)[0], None), p0, keys
        )[0]

    fused = jax.jit(fused_chain)
    composed = jax.jit(composed_chain)

    # Parity first — the CI gate (bit-exact: particles, ancestors, and
    # every StepStats leaf).
    got = r.step(key, lw, p, THRESHOLD)
    want = _composed(r, key, lw, p, THRESHOLD)
    for g, e in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(want)):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(e))

    # Structural no-slower on the composition backends: identical jaxpr ⇒
    # identical program (wall clocks on this shared CPU box swing ±30%, so
    # a timing gate would only measure the scheduler).  f32 cells only —
    # the compressed fused step folds the plane casts into one kernel the
    # composition necessarily spells out as separate convert ops.
    perf_gated = backend in TIMED_GATE_BACKENDS and plane_dtype == "float32"
    identical_program = False
    if perf_gated:
        identical_program = str(jax.make_jaxpr(fused_chain)(p)) == str(
            jax.make_jaxpr(composed_chain)(p)
        )

    # Launch counts on the kernel backend — the tentpole claim.
    launches_step = launches_composed = None
    if backend == "pallas_interpret":
        launches_step = _count_pallas_calls(
            jax.make_jaxpr(lambda k: r.step(k, lw, p, THRESHOLD))(key).jaxpr
        )
        launches_composed = _count_pallas_calls(
            jax.make_jaxpr(lambda k: _composed(r, k, lw, p, THRESHOLD))(key).jaxpr
        )

    t_fused, t_composed = _time_pair(fused, composed, p, repeats=repeats)
    t_fused, t_composed = t_fused / chain, t_composed / chain
    wb = plane_itemsize(plane_dtype)
    return {
        "family": name,
        "backend": backend,
        "plane_dtype": plane_dtype,
        "n": n,
        "step_ms": t_fused * 1e3,
        "composed_ms": t_composed * 1e3,
        "speedup": t_composed / t_fused,
        "launches_step": launches_step,
        "launches_composed": launches_composed,
        "model_bytes_step": smc_step_bytes(
            n, state_dim, fused=True, state_bytes=wb, weight_bytes=wb)["total"],
        "model_bytes_composed": smc_step_bytes(
            n, state_dim, fused=False, state_bytes=wb, weight_bytes=wb)["total"],
        "parity": True,
        "perf_gated": perf_gated,
        "identical_program": identical_program,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="CI scale")
    ap.add_argument("--smoke", action="store_true",
                    help="toy sizes, parity gate only (the perf-smoke CI job)")
    ap.add_argument("--n", type=int, default=None)
    ap.add_argument("--dtypes", type=lambda v: tuple(x for x in v.split(",") if x),
                    default=PLANE_DTYPES,
                    help="comma-separated plane dtypes to sweep "
                         "(default: float32,bfloat16)")
    args = ap.parse_args(argv)

    if args.smoke:
        n, num_iters, max_iters, repeats, chain = 2048, 4, 16, 1, 2
    elif args.quick:
        n, num_iters, max_iters, repeats, chain = 4096, 16, 32, 21, 8
    else:
        n, num_iters, max_iters, repeats, chain = 8192, 16, 64, 25, 12
    if args.n:
        n = args.n

    rows = []
    for dtype in args.dtypes:
        for name in FAMILIES:
            for backend in BACKENDS:
                rows.append(_cell(name, backend, n=n, state_dim=4,
                                  num_iters=num_iters, max_iters=max_iters,
                                  repeats=repeats, chain=chain,
                                  plane_dtype=dtype))
                msg = (f"[step] {name}/{backend}@{dtype}: "
                       f"step {rows[-1]['step_ms']:.2f}ms "
                       f"composed {rows[-1]['composed_ms']:.2f}ms")
                if rows[-1]["launches_step"] is not None:
                    msg += (f" launches {rows[-1]['launches_composed']}"
                            f"→{rows[-1]['launches_step']}")
                print(msg)

    print_table(rows, cols=["family", "backend", "plane_dtype", "step_ms",
                            "composed_ms", "speedup", "launches_step",
                            "launches_composed"])
    write_csv("step_bench.csv", rows)
    ensure_out()
    with open(os.path.join(OUT_DIR, "BENCH_step.json"), "w") as f:
        json.dump({"config": {"n": n, "num_iters": num_iters,
                              "max_iters": max_iters, "repeats": repeats,
                              "chain": chain, "threshold": THRESHOLD,
                              "smoke": args.smoke,
                              "plane_dtypes": list(args.dtypes)},
                   "rows": rows}, f, indent=2)

    # The single-launch gate on every kernel cell, and the structural
    # no-slower gate on every composition cell — both deterministic, so
    # they run in --smoke too.
    bad_launch = [r for r in rows if r["launches_step"] not in (None, 1)]
    if bad_launch:
        print("FAILED single-launch gate:",
              [(r["family"], r["launches_step"]) for r in bad_launch])
        raise SystemExit(1)
    not_identical = [r for r in rows
                     if r["perf_gated"] and not r["identical_program"]]
    if not_identical:
        print("FAILED structural no-slower gate:",
              [(r["family"], r["backend"]) for r in not_identical])
        raise SystemExit(1)
    n_kernel = sum(1 for r in rows if r["launches_step"] == 1)
    print(f"step_bench: all parity cells bit-exact; {n_kernel} kernel cells "
          "single-launch; all f32 composition cells identical-program")


if __name__ == "__main__":
    main()
