"""Traced contracts for the stack's resampler consumers (DESIGN.md §13).

The matrix audit proves each entry point honest in isolation; this module
proves the *consumers* kept their promises after composition — the §11/§12
claims ("one fused launch per filter step", "no host branch around the
resampler", "ancestors never round-trip through HBM") are re-derived from
the consumers' own jaxprs instead of being grepped out of their source.

Covered programs, each traced on the ``pallas_interpret`` Megopolis spec
(interpret mode shares launch structure with compiled pallas, so the audit
runs on any host):

  * ``pf.ParticleFilter.step`` / ``step_conditional`` and the scan drivers
    ``run_filter`` / ``run_filter_bank`` (conditional SIR);
  * ``ais.run_smc_sampler`` / ``run_smc_sampler_bank`` plus the
    adaptive-schedule + MALA variant (the widest sampler code path);
  * ``smc.decode.smc_decode`` — the one consumer whose contract *allows*
    ancestor-indexed gathers: the mixed-dtype KV cache cannot ride the f32
    plane stack, so the cache gather is priced and allowed, not forbidden.

``auto_reference_rng`` additionally sweeps the adaptive-``num_iters``
reference paths (never kernel-traceable — 'auto' needs concrete weights)
through the RNG lint.  Megopolis' documented deliberate deviation — the
wrapper and the kernel derive the SAME offsets split so injected offsets
reproduce the auto stream bit-for-bit — is waived, not hidden: the waiver
reason lands in the report.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.analysis.contracts import (
    AUDIT_N,
    Contract,
    Waiver,
    audit_jaxpr,
)
from repro.analysis.rng import rng_findings
from repro.analysis.walker import Finding
from repro.core.spec import spec_for_backend

#: Backend every consumer is audited on (launch structure == 'pallas').
AUDIT_BACKEND = "pallas_interpret"

#: Direct (iterate-and-compare) families whose reference path supports the
#: adaptive iteration rule; swept by ``auto_reference_rng``.
AUTO_FAMILIES = ("megopolis", "metropolis", "metropolis_c1", "metropolis_c2")

MEGOPOLIS_AUTO_WAIVER = Waiver(
    code="key-reuse",
    match="random_split, random_split",
    reason=(
        "megopolis 'auto' reference: the wrapper splits the key for the "
        "offsets draw and megopolis() re-splits identically BY DESIGN, so "
        "injecting the drawn offsets reproduces the same derivation "
        "(documented in core/resamplers/megopolis.py; changing either "
        "split would change the golden streams)"
    ),
)


def _spec():
    return spec_for_backend("megopolis", AUDIT_BACKEND)


def _pf(conditional: bool):
    from repro.pf.filter import ParticleFilter
    from repro.pf.models import ungm

    return ParticleFilter(
        model=ungm(),
        num_particles=AUDIT_N,
        resampler=_spec(),
        ess_threshold=0.5 if conditional else None,
    )


def _trace_pf_step():
    pf = _pf(conditional=False)
    key = jax.random.PRNGKey(0)
    x = jnp.zeros((AUDIT_N,), jnp.float32)
    return jax.make_jaxpr(lambda k, p, z: pf.step(k, p, z, 1.0))(key, x, 0.5)


def _trace_pf_step_conditional():
    pf = _pf(conditional=True)
    key = jax.random.PRNGKey(0)
    x = jnp.zeros((AUDIT_N,), jnp.float32)
    lw = jnp.zeros((AUDIT_N,), jnp.float32)
    return jax.make_jaxpr(
        lambda k, p, w, z: pf.step_conditional(k, p, w, z, 1.0)
    )(key, x, lw, 0.5)


def _trace_run_filter():
    from repro.pf.filter import run_filter

    pf = _pf(conditional=True)
    key = jax.random.PRNGKey(0)
    obs = jnp.zeros((5,), jnp.float32)
    return jax.make_jaxpr(lambda k, z: run_filter(k, pf, z))(key, obs)


def _trace_run_filter_bank():
    from repro.pf.filter import run_filter_bank

    pf = _pf(conditional=True)
    key = jax.random.PRNGKey(0)
    obs = jnp.zeros((3, 5), jnp.float32)
    return jax.make_jaxpr(lambda k, z: run_filter_bank(k, pf, z))(key, obs)


def _ais_cfg(**overrides):
    from repro.ais.sampler import SMCSamplerConfig

    base = dict(num_particles=AUDIT_N, num_temps=4, resampler=_spec())
    return SMCSamplerConfig(**(base | overrides))


def _trace_ais():
    from repro.ais.sampler import run_smc_sampler
    from repro.ais.targets import gaussian_mixture

    target, cfg = gaussian_mixture(), _ais_cfg()
    return jax.make_jaxpr(lambda k: run_smc_sampler(k, target, cfg))(
        jax.random.PRNGKey(0)
    )


def _trace_ais_bank():
    from repro.ais.sampler import run_smc_sampler_bank
    from repro.ais.targets import gaussian_mixture

    target, cfg = gaussian_mixture(), _ais_cfg()
    return jax.make_jaxpr(
        lambda k: run_smc_sampler_bank(k, target, cfg, num_scenarios=3)
    )(jax.random.PRNGKey(0))


def _trace_ais_adaptive_mala():
    from repro.ais.sampler import run_smc_sampler
    from repro.ais.targets import gaussian_mixture

    target = gaussian_mixture()
    cfg = _ais_cfg(schedule="adaptive", move="mala")
    return jax.make_jaxpr(lambda k: run_smc_sampler(k, target, cfg))(
        jax.random.PRNGKey(0)
    )


#: Decode needs N % 1024 == 0 on the kernel backends.
DECODE_PARTICLES = 1024


def _trace_decode():
    """Trace ``smc_decode`` end-to-end over abstract model params — the
    transformer weights are ``jax.eval_shape`` phantoms, so the audit never
    materialises the model."""
    from repro.configs import get_arch
    from repro.models import init_params, prefill
    from repro.smc.decode import SMCDecodeConfig, smc_decode

    cfg = dataclasses.replace(
        get_arch("qwen3-0.6b").smoke, dtype=jnp.float32, remat=False
    )
    smc_cfg = SMCDecodeConfig(
        num_particles=DECODE_PARTICLES, max_new_tokens=3, resampler=_spec()
    )
    prompt_len, max_seq = 4, 4 + smc_cfg.max_new_tokens
    prompts = jnp.zeros((DECODE_PARTICLES, prompt_len), jnp.int32)
    params = jax.eval_shape(lambda k: init_params(k, cfg), jax.random.PRNGKey(0))
    caches = jax.eval_shape(
        lambda p: prefill(p, cfg, prompts, max_seq=max_seq)[1], params
    )
    first = jnp.zeros((DECODE_PARTICLES,), jnp.int32)

    def fn(p, c, ft, k):
        tokens, log_w, _ = smc_decode(
            p, cfg, smc_cfg, c, ft, prompt_len - 1, k
        )
        return tokens, log_w

    return jax.make_jaxpr(fn)(params, caches, first, jax.random.PRNGKey(1))


#: name -> (trace fn, contract).  Launch budgets are per static launch
#: *site*: every consumer funnels resampling through ONE fused step/apply
#: launch inside its scan body (DESIGN.md §11-§12).
CONSUMER_CONTRACTS = {
    "pf.step": (_trace_pf_step, Contract(max_launches=1)),
    "pf.step_conditional": (_trace_pf_step_conditional, Contract(max_launches=1)),
    "pf.run_filter": (_trace_run_filter, Contract(max_launches=1)),
    "pf.run_filter_bank": (_trace_run_filter_bank, Contract(max_launches=1)),
    "ais.run_smc_sampler": (_trace_ais, Contract(max_launches=1)),
    "ais.run_smc_sampler_bank": (_trace_ais_bank, Contract(max_launches=1)),
    "ais.adaptive_mala": (_trace_ais_adaptive_mala, Contract(max_launches=1)),
    "smc.decode": (_trace_decode, Contract(max_launches=1, allow_tainted_gather=True)),
}


def audit_consumers(names=None, *, include_decode: bool = True):
    """Trace + audit each consumer program; yields CellReports."""
    selected = names or CONSUMER_CONTRACTS
    for name in selected:
        if name == "smc.decode" and not include_decode and names is None:
            continue
        tracer, contract = CONSUMER_CONTRACTS[name]
        yield audit_jaxpr(name, tracer(), contract)


def auto_reference_rng(families=AUTO_FAMILIES):
    """RNG-lint the adaptive-iteration reference paths; yields
    ``(cell, kept findings, waived)`` triples."""
    key = jax.random.PRNGKey(0)
    w = jnp.full((AUDIT_N,), 1.0 / AUDIT_N, jnp.float32)
    for name in families:
        resampler = spec_for_backend(name, "reference", num_iters="auto").build()
        jaxpr = jax.make_jaxpr(lambda k, ww: resampler(k, ww))(key, w)
        found = rng_findings(jaxpr)
        kept, waived = [], []
        for f in found:
            if name == "megopolis" and MEGOPOLIS_AUTO_WAIVER.covers(f):
                waived.append(
                    {"finding": f.as_dict(), "reason": MEGOPOLIS_AUTO_WAIVER.reason}
                )
            else:
                kept.append(f)
        yield f"{name}/reference/auto", kept, waived


def auto_reference_findings() -> list[Finding]:
    """Flat list of unwaived findings from the 'auto' reference sweep."""
    out: list[Finding] = []
    for _, kept, _ in auto_reference_rng():
        out.extend(kept)
    return out