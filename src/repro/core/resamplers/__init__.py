"""Resampling algorithms (the paper's Algorithms 2-5, 7, 8 + extras).

Every resampler shares one signature::

    ancestors = resampler(key, weights, **kwargs)   # int32[N]

``ancestors[i]`` is the index of the particle replacing particle ``i``
(the paper's ancestor formulation).  Offspring counts are
``jnp.bincount(ancestors, length=N)``.  Weights need NOT be normalised for
the Metropolis family (only ratios are used) nor for the prefix-sum family
(the running total is used as the upper edge).

Every resampler also has a batched entry point (DESIGN.md §4)::

    ancestors = get_resampler_batch(name)(key, weights, **kwargs)  # int32[B, N]

over ``weights[B, N]`` — row ``b`` is bit-identical to the single-population
call with key ``jax.random.split(key, B)[b]`` (see ``batched.py``).

Both string lookups are legacy shims: the typed spec API in
``repro.core.spec`` (DESIGN.md §9) is the primary surface —
``spec_from_name(name, **hyperparams).build()`` returns a ``Resampler``
whose ``__call__`` / ``.batch`` bake the hyperparameters in.  Backend
dispatch is uniform: every family here also runs on the Pallas kernel
lane (``backend='pallas_interpret' | 'pallas'``, DESIGN.md §2 kernel
matrix), gated bit-exactly by ``tests/test_backend_parity.py``.
"""

from repro.core.resamplers.batched import (
    batch_rows,
    batch_via_vmap,
    split_batch_keys,
)
from repro.core.resamplers.megopolis import megopolis, megopolis_batch
from repro.core.resamplers.metropolis import (
    metropolis,
    metropolis_batch,
    metropolis_c1,
    metropolis_c1_batch,
    metropolis_c2,
    metropolis_c2_batch,
)
from repro.core.resamplers.prefix_sum import (
    multinomial,
    multinomial_batch,
    systematic,
    systematic_batch,
    improved_systematic,
    improved_systematic_batch,
    stratified,
    stratified_batch,
    residual,
    residual_batch,
)
from repro.core.resamplers.rejection import rejection, rejection_batch

# The typed spec API (DESIGN.md §9) owns the ONE name-keyed family table;
# the legacy string lookups below are thin shims over it.
from repro.core.spec import (  # noqa: F401,E402
    MegopolisSpec,
    MetropolisC1Spec,
    MetropolisC2Spec,
    MetropolisSpec,
    PrefixSumSpec,
    RejectionSpec,
    Resampler,
    ResamplerSpec,
    coerce_spec,
    get_resampler,
    get_resampler_batch,
    list_resamplers,
    spec_for_backend,
    spec_from_name,
)
