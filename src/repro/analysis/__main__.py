"""CLI for the static contract auditor.

    python -m repro.analysis --check [--json PATH]
                             [--families megopolis,...]
                             [--backends pallas_interpret,...]
                             [--no-consumers] [--no-transactions]
                             [--no-telemetry] [--no-resilience]
    python -m repro.analysis --selftest

``--check`` exits non-zero on any unwaived violation; ``--selftest``
verifies every analyzer pass still catches its bad fixture.
"""

from __future__ import annotations

import argparse
import json
import sys


def _csv(value):
    return tuple(v for v in value.split(",") if v) or None


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Audit the resampler matrix against its static contracts.",
    )
    ap.add_argument("--check", action="store_true",
                    help="run the full audit; non-zero exit on violation")
    ap.add_argument("--selftest", action="store_true",
                    help="verify each pass catches its bad fixture")
    ap.add_argument("--json", metavar="PATH",
                    help="write the full machine-readable report to PATH")
    ap.add_argument("--families", type=_csv, default=None,
                    help="comma-separated registry names (default: all)")
    ap.add_argument("--backends", type=_csv, default=None,
                    help="comma-separated backends (default: all)")
    ap.add_argument("--entries", type=_csv, default=None,
                    help="comma-separated entry points (default: all)")
    ap.add_argument("--plane-dtypes", type=_csv, default=None,
                    help="comma-separated plane dtypes for the §14 "
                         "compression axis (default: float32,bfloat16)")
    ap.add_argument("--no-consumers", action="store_true",
                    help="skip the consumer-program audits")
    ap.add_argument("--no-large-n", action="store_true",
                    help="skip the residency-edge footprint pricing")
    ap.add_argument("--no-transactions", action="store_true",
                    help="skip the §2.4 transaction pricing")
    ap.add_argument("--no-telemetry", action="store_true",
                    help="skip the §15 telemetry-neutrality pass")
    ap.add_argument("--no-resilience", action="store_true",
                    help="skip the §16 guard-neutrality pass")
    args = ap.parse_args(argv)

    if not (args.check or args.selftest):
        ap.print_help()
        return 2

    rc = 0
    if args.selftest:
        from repro.analysis.fixtures import selftest

        problems = selftest()
        for p in problems:
            print(f"selftest: {p}", file=sys.stderr)
        print(f"selftest: {'OK' if not problems else 'FAILED'}")
        rc = max(rc, 1 if problems else 0)

    if args.check:
        from repro.analysis.report import build_report, summarise

        kw = {}
        if args.plane_dtypes is not None:
            kw["plane_dtypes"] = args.plane_dtypes
        report = build_report(
            families=args.families,
            backends=args.backends,
            entries=args.entries,
            consumers=not args.no_consumers,
            large_n=not args.no_large_n,
            transactions=not args.no_transactions,
            telemetry=not args.no_telemetry,
            resilience=not args.no_resilience,
            **kw,
        )
        if args.json:
            with open(args.json, "w") as fh:
                json.dump(report, fh, indent=2, sort_keys=True)
            print(f"report written to {args.json}")
        print(summarise(report))
        rc = max(rc, 0 if report["ok"] else 1)

    return rc


if __name__ == "__main__":
    sys.exit(main())