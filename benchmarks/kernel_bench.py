"""Kernel micro-benchmark: Pallas Megopolis (interpret mode on CPU) vs the
bit-exact jnp oracle across sizes; validates exact equality and times the
jitted oracle (interpret-mode timing is not a TPU number — the dry-run
roofline covers performance, DESIGN.md §6.3)."""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from benchmarks.common import print_table, time_fn, write_csv
from repro.core.weightgen import gaussian_weights
from repro.kernels.common import TILE
from repro.kernels.megopolis.ops import megopolis_tpu
from repro.kernels.megopolis.ref import megopolis_ref


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", type=int, nargs="*", default=[4096, 16384, 65536])
    ap.add_argument("--iters", type=int, default=16)
    args = ap.parse_args(argv)

    rows = []
    for n in args.sizes:
        key = jax.random.PRNGKey(n)
        w = gaussian_weights(jax.random.fold_in(key, 9), n, 2.0)
        anc_k = megopolis_tpu(key, w, args.iters, interpret=True)
        # oracle: same offsets/seed derivation as the ops wrapper
        from repro.kernels.common import key_to_seed
        key_off, key_seed = jax.random.split(key)
        offsets = jax.random.randint(key_off, (args.iters,), 0, n, dtype=jnp.int32)
        seed = key_to_seed(key_seed).reshape(1)
        anc_r = megopolis_ref(w, offsets, seed, num_iters=args.iters)
        exact = bool(jnp.all(anc_k == anc_r))
        t_ref = time_fn(
            jax.jit(lambda w_, o_, s_: megopolis_ref(w_, o_, s_, num_iters=args.iters)),
            w, offsets, seed)
        rows.append({"n": n, "B": args.iters, "kernel_matches_ref": exact,
                     "ref_time_s": t_ref, "tile": TILE})
        assert exact, f"kernel/ref mismatch at n={n}"
    write_csv("kernel_bench.csv", rows)
    print_table(rows)


if __name__ == "__main__":
    main()
