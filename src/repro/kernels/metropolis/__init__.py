from repro.kernels.metropolis.ops import metropolis_tpu  # noqa: F401
from repro.kernels.metropolis.ref import metropolis_ref  # noqa: F401
