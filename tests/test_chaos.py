"""DESIGN.md §16 — the deterministic chaos matrix.

Every cell seeds a PRNG-keyed fault (``repro.resilience.faults``) into a
guarded entry and proves the §16 contract: the step either RECOVERS
(finite outputs, in-range ancestors, degenerate evidence) or raises the
TYPED error — never silent garbage.  Faults are pure functions of their
key, so a red cell replays bit-for-bit.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.spec import spec_for_backend
from repro.kernels.common import TILE
from repro.resilience import (
    CorruptAncestorsError,
    FAULT_CLASSES,
    all_nan_bank,
    bitflip_states,
    inject_inf_weights,
    inject_nan_weights,
    poison_ancestors,
    record_resilience_events,
    validate_ancestors,
)

N = 2 * TILE
BACKENDS = ("reference", "xla", "pallas_interpret")
#: One iterate-and-compare family, the bounded-loop family, and two
#: prefix-sum kinds — the §12 kernel-shape spread at chaos-matrix cost.
FAMILIES = ("megopolis", "rejection", "systematic", "residual")
#: Collapse signatures (non-finite max): the guard must fire.
COLLAPSED = ("all_nan", "all_neg_inf")
#: Concentrated-but-finite signatures: legal posteriors, guard must NOT fire.
CONCENTRATED = ("one_hot", "near_collapse")


def _build(name, backend, guard="recover"):
    return spec_for_backend(name, backend, num_iters=8, max_iters=24,
                            guard=guard).build()


# ------------------------------------------------ injector determinism
def test_injectors_are_deterministic():
    key = jax.random.PRNGKey(123)
    w = jnp.ones((N,), jnp.float32)
    for inj in (inject_nan_weights, inject_inf_weights):
        np.testing.assert_array_equal(
            np.asarray(inj(key, w)), np.asarray(inj(key, w))
        )
    np.testing.assert_array_equal(
        np.asarray(bitflip_states(key, w)), np.asarray(bitflip_states(key, w))
    )
    a = jnp.arange(N, dtype=jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(poison_ancestors(key, a, N)),
        np.asarray(poison_ancestors(key, a, N)),
    )


def test_injectors_actually_corrupt():
    key = jax.random.PRNGKey(7)
    w = jnp.ones((N,), jnp.float32)
    assert bool(jnp.any(jnp.isnan(inject_nan_weights(key, w))))
    assert bool(jnp.any(jnp.isinf(inject_inf_weights(key, w))))
    flipped = bitflip_states(key, w, rate=0.5)
    assert int(jnp.sum(flipped != w)) > 0
    bad = poison_ancestors(key, jnp.arange(N, dtype=jnp.int32), N, rate=0.5)
    assert bool(jnp.any((bad < 0) | (bad >= N)))


def test_validate_ancestors_tripwire():
    a = jnp.arange(N, dtype=jnp.int32)
    assert validate_ancestors(a, N) is a
    bad = poison_ancestors(jax.random.PRNGKey(0), a, N, rate=0.1)
    with pytest.raises(CorruptAncestorsError, match="out-of-range"):
        validate_ancestors(bad, N)


# --------------------------------------------------- the chaos matrix
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("name", FAMILIES)
@pytest.mark.parametrize("fault", sorted(FAULT_CLASSES))
def test_chaos_matrix_recovers_every_cell(fault, name, backend, base_key):
    """fault × family × backend: the guarded step never emits garbage."""
    lw = FAULT_CLASSES[fault](N)
    p = jax.random.normal(jax.random.PRNGKey(9), (N, 2))
    r = _build(name, backend)
    p_out, anc, stats = r.step(base_key, lw, p, 2.0)
    anc = np.asarray(anc)
    assert (anc >= 0).all() and (anc < N).all()
    assert np.isfinite(np.asarray(p_out)).all()
    assert np.isfinite(np.asarray(stats.ess_norm))
    assert np.isfinite(np.asarray(stats.max_weight))
    assert bool(np.asarray(stats.degenerate)) == (fault in COLLAPSED)
    if fault in COLLAPSED:
        # recovered = the uniform-bank resample: every stat is exact
        assert float(np.asarray(stats.ess_norm)) == 1.0
        assert float(np.asarray(stats.log_evidence_incr)) == 0.0
    validate_ancestors(anc, N)


@pytest.mark.parametrize("fault", sorted(COLLAPSED))
def test_chaos_recovery_agrees_across_backends(fault, base_key):
    """Recovery reduces a collapsed bank to the uniform (all-zeros) bank,
    so it inherits the §12 parity structure: xla is bit-identical to
    reference, and EVERY backend's recovered step is bit-identical to
    that same backend's clean uniform-bank step (the pallas kernels have
    their own RNG layout, so cross-surface equality is per-backend)."""
    lw = FAULT_CLASSES[fault](N)
    zeros = jnp.zeros((N,), jnp.float32)
    p = jax.random.normal(jax.random.PRNGKey(10), (N, 2))
    outs = {}
    for b in BACKENDS:
        r = _build("megopolis", b)
        outs[b] = r.step(base_key, lw, p, 2.0)
        clean = r.step(base_key, zeros, p, 2.0)
        # recovered == same backend's uniform-bank step (degenerate flag
        # aside, which truthfully differs)
        for g, e in zip(jax.tree_util.tree_leaves(outs[b])[:-1],
                        jax.tree_util.tree_leaves(clean)[:-1]):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(e))
    for a, b in zip(jax.tree_util.tree_leaves(outs["reference"]),
                    jax.tree_util.tree_leaves(outs["xla"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("name", ("megopolis", "systematic"))
def test_chaos_sprinkled_nan_weights(name, base_key):
    """Partial corruption: a NaN-sprinkled bank is degenerate (any NaN
    poisons the normaliser) and must recover like a collapsed one."""
    kf, kw = jax.random.split(jax.random.PRNGKey(11))
    lw = inject_nan_weights(kf, jax.random.normal(kw, (N,)), rate=0.05)
    p = jax.random.normal(jax.random.PRNGKey(12), (N,))
    r = _build(name, "reference")
    p_out, anc, stats = r.step(base_key, lw, p, 2.0)
    assert bool(np.asarray(stats.degenerate))
    assert np.isfinite(np.asarray(p_out)).all()
    validate_ancestors(np.asarray(anc), N)


def test_chaos_bitflipped_states_still_resample(base_key):
    """Bit-flips in the STATE planes (not the weights): selection is
    driven by clean weights, so the step must complete with in-range
    ancestors — corrupted state values pass through by design (state is
    data, the resampler only routes it)."""
    lw = jax.random.normal(jax.random.PRNGKey(13), (N,))
    p = bitflip_states(jax.random.PRNGKey(14),
                       jax.random.normal(jax.random.PRNGKey(15), (N, 2)),
                       rate=0.01)
    r = _build("megopolis", "pallas_interpret")
    p_out, anc, stats = r.step(base_key, lw, p, 2.0)
    validate_ancestors(np.asarray(anc), N)
    assert not bool(np.asarray(stats.degenerate))
    # routing only: every output row is SOME input row, bit for bit
    np.testing.assert_array_equal(
        np.asarray(p_out), np.asarray(p)[np.asarray(anc)]
    )


def test_chaos_emits_fault_evidence(base_key):
    """A chaos cell run under the recorder leaves structured evidence:
    the guard_degenerate event carries the family/backend/entry cell."""
    r = _build("rejection", "reference")
    p = jax.random.normal(jax.random.PRNGKey(16), (N,))
    events = []
    with record_resilience_events(events):
        r.step(base_key, all_nan_bank(N), p, 2.0)
    jax.effects_barrier()
    kinds = [e["kind"] for e in events]
    assert "guard_degenerate" in kinds
    ev = events[kinds.index("guard_degenerate")]
    assert ev["family"] == "rejection"
    assert ev["backend"] == "reference"
    assert ev["entry"] == "step"
