"""DESIGN.md §16 — degeneracy guards, fallback chains, crash consistency.

Four sections:

1. guard policy — spec validation, flag/recover semantics on the public
   entries, event evidence through the trace-time-static recorder;
2. backend fallback — the demotion ladder on a host without the
   accelerator, typed error taxonomy, exhaustion;
3. sink crash consistency — buffered JSONL flush on normal AND abnormal
   exit (satellite S2);
4. checkpointed scans — chunk ≡ monolith bit-identity, kill-and-resume
   through ``run_filter`` / ``run_smc_sampler`` (satellite S4).
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.metrics import (
    degenerate_log_weights,
    degenerate_weights,
    effective_sample_size,
    normalise_log_weights,
)
from repro.core.spec import MegopolisSpec, PrefixSumSpec, spec_for_backend
from repro.kernels.common import TILE
from repro.obs.sink import JsonlSink
from repro.resilience import (
    BackendUnavailable,
    CheckpointPolicy,
    CorruptAncestorsError,
    GUARD_POLICIES,
    InjectedCrash,
    KernelLoweringError,
    ResilienceError,
    VmemBudgetExceeded,
    build_with_fallback,
    classify_backend_error,
    checkpointed_scan,
    record_resilience_events,
)
from repro.resilience.fallback import DEFAULT_LADDER, _ladder_for

N = 2 * TILE
BACKENDS = ("reference", "xla", "pallas_interpret")


def _build(name, backend, guard="off", plane_dtype="float32"):
    return spec_for_backend(name, backend, num_iters=8, max_iters=24,
                            plane_dtype=plane_dtype, guard=guard).build()


# ------------------------------------------------------- 1. guard policy
def test_guard_policies_vocabulary():
    assert GUARD_POLICIES == ("off", "flag", "recover")
    for g in GUARD_POLICIES:
        assert MegopolisSpec(guard=g).guard == g


def test_bad_guard_policy_raises_with_hint():
    with pytest.raises(ValueError, match="recover"):
        MegopolisSpec(guard="recovr")
    with pytest.raises(ValueError, match="guard"):
        PrefixSumSpec(kind="systematic", guard="on")


def test_metrics_degenerate_predicates():
    n = 8
    assert bool(degenerate_log_weights(jnp.full((n,), -jnp.inf)))
    assert bool(degenerate_log_weights(jnp.full((n,), jnp.nan)))
    assert bool(degenerate_log_weights(jnp.zeros((n,)).at[3].set(jnp.inf)))
    # one-hot has a finite max: NOT degenerate (mass on one particle is a
    # legal, if collapsed, posterior).
    assert not bool(
        degenerate_log_weights(jnp.full((n,), -jnp.inf).at[2].set(0.0))
    )
    assert bool(degenerate_weights(jnp.zeros((n,))))
    assert bool(degenerate_weights(jnp.ones((n,)).at[0].set(jnp.nan)))
    assert not bool(degenerate_weights(jnp.ones((n,))))


def test_normalise_log_weights_uniform_fallback():
    """Satellite S1: a fully collapsed bank normalises to the exact uniform
    bank (ESS = N), identically for every degenerate signature."""
    for bad in (jnp.full((N,), -jnp.inf), jnp.full((N,), jnp.nan)):
        w = normalise_log_weights(bad)
        np.testing.assert_array_equal(
            np.asarray(w), np.full((N,), 1.0 / N, np.float32)
        )
        assert float(effective_sample_size(bad)) == float(N)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("name", ("megopolis", "rejection", "systematic"))
def test_recover_weights_entries_equal_uniform(name, backend, base_key):
    """§16 recover on the weights entries: a degenerate linear-weight bank
    resamples EXACTLY like the uniform bank — same key, same backend, bit
    for bit — across ``__call__``/``apply``/``apply_rows``."""
    r = _build(name, backend, guard="recover")
    w_uni = jnp.full((N,), 1.0 / N, jnp.float32)
    p = jax.random.normal(jax.random.PRNGKey(3), (N, 2))
    for w_bad in (
        jnp.zeros((N,), jnp.float32),
        jnp.full((N,), jnp.nan, jnp.float32),
        w_uni.at[5].set(jnp.inf),
    ):
        np.testing.assert_array_equal(
            np.asarray(r(base_key, w_bad)), np.asarray(r(base_key, w_uni))
        )
        got = r.apply(base_key, w_bad, p)
        exp = r.apply(base_key, w_uni, p)
        np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(exp[0]))
        np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(exp[1]))
    # bank form: one poisoned row recovers, clean rows stay untouched
    keys = jax.random.split(base_key, 2)
    w_bank = jnp.stack([jnp.full((N,), jnp.nan, jnp.float32), w_uni])
    p_bank = jax.random.normal(jax.random.PRNGKey(4), (2, N, 2))
    got = r.apply_rows(keys, w_bank, p_bank)
    exp = r.apply_rows(keys, jnp.stack([w_uni, w_uni]), p_bank)
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(exp[1]))


@pytest.mark.parametrize("backend", BACKENDS)
def test_recover_step_resamples_collapsed_bank(backend, base_key):
    """A collapsed log-weight bank under recover: the step substitutes the
    uniform bank pre-dispatch, so with a forcing threshold it RESAMPLES —
    finite stats, in-range ancestors, degenerate=True, incr = 0."""
    r = _build("megopolis", backend, guard="recover")
    p = jax.random.normal(jax.random.PRNGKey(5), (N, 2))
    for bad in (jnp.full((N,), jnp.nan), jnp.full((N,), -jnp.inf)):
        p_out, anc, stats = r.step(base_key, bad, p, 2.0)
        anc = np.asarray(anc)
        assert bool(np.asarray(stats.degenerate))
        assert float(np.asarray(stats.resampled)) == 1.0
        assert float(np.asarray(stats.ess_norm)) == 1.0
        assert float(np.asarray(stats.log_evidence_incr)) == 0.0
        assert (anc >= 0).all() and (anc < N).all()
        assert np.isfinite(np.asarray(p_out)).all()
        # the recovered step IS the uniform-bank step, bit for bit
        exp = r.step(base_key, jnp.zeros((N,)), p, 2.0)
        for g, e in zip(jax.tree_util.tree_leaves((p_out, anc, stats))[:-1],
                        jax.tree_util.tree_leaves(exp)[:-1]):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(e))


def test_flag_policy_composes_degenerate_without_recovery(base_key):
    """'flag' keeps the unguarded computation (garbage in, garbage out is
    allowed) but the StepStats degenerate bit still reports the collapse."""
    r = _build("systematic", "reference", guard="flag")
    p = jax.random.normal(jax.random.PRNGKey(6), (N,))
    _, _, stats = r.step(base_key, jnp.full((N,), jnp.nan), p, 2.0)
    assert bool(np.asarray(stats.degenerate))
    _, _, stats = r.step(base_key, jnp.zeros((N,)), p, 2.0)
    assert not bool(np.asarray(stats.degenerate))


def test_guard_events_recorded_only_inside_context(base_key):
    """The recorder is trace-time static: events flow only for programs
    traced inside ``record_resilience_events``, and only for calls that
    actually saw a collapsed bank."""
    r = _build("megopolis", "reference", guard="flag")
    p = jax.random.normal(jax.random.PRNGKey(7), (N,))
    bad = jnp.full((N,), jnp.nan)

    events = []
    with record_resilience_events(events):
        r.step(base_key, bad, p, 2.0)
        r.step(base_key, jnp.zeros((N,)), p, 2.0)  # clean: silent
    jax.effects_barrier()
    assert [e["kind"] for e in events] == ["guard_degenerate"]
    assert events[0]["family"] == "megopolis"
    assert events[0]["entry"] == "step"
    assert events[0]["policy"] == "flag"
    assert events[0]["degenerate_rows"] == 1

    # outside the context: structurally silent
    events2 = []
    r.step(base_key, bad, p, 2.0)
    jax.effects_barrier()
    assert events2 == []


def test_guard_events_reach_jsonl_sink(tmp_path, base_key):
    """End to end: guard evidence lands in the obs JSONL flight recorder."""
    path = os.path.join(str(tmp_path), "resilience.jsonl")
    r = _build("megopolis", "reference", guard="recover")
    p = jax.random.normal(jax.random.PRNGKey(8), (N,))
    with JsonlSink(path) as sink:
        with record_resilience_events(sink):
            r.step(base_key, jnp.full((N,), -jnp.inf), p, 2.0)
            jax.effects_barrier()
    lines = [json.loads(l) for l in open(path)]
    assert [l["event"] for l in lines] == ["guard_degenerate"]
    assert lines[0]["policy"] == "recover"


# --------------------------------------------------- 2. backend fallback
def test_error_taxonomy():
    assert issubclass(KernelLoweringError, (ResilienceError, RuntimeError))
    assert issubclass(VmemBudgetExceeded, (ResilienceError, ValueError))
    assert issubclass(BackendUnavailable, (ResilienceError, RuntimeError))
    assert issubclass(CorruptAncestorsError, (ResilienceError, ValueError))
    assert issubclass(InjectedCrash, (ResilienceError, RuntimeError))


def test_classify_backend_error():
    assert isinstance(
        classify_backend_error(ValueError("state exceeds the VMEM budget")),
        VmemBudgetExceeded,
    )
    assert isinstance(
        classify_backend_error(RuntimeError("Mosaic lowering failed")),
        KernelLoweringError,
    )
    wrapped = classify_backend_error(TypeError("something else entirely"))
    assert isinstance(wrapped, KernelLoweringError)
    assert isinstance(wrapped.__cause__, TypeError)
    already = VmemBudgetExceeded("x")
    assert classify_backend_error(already) is already


def test_ladder_for_starts_at_spec_backend():
    assert _ladder_for("pallas", None) == DEFAULT_LADDER
    assert _ladder_for("xla", None) == ("xla", "reference")
    assert _ladder_for("reference", None) == ("reference",)
    assert _ladder_for("pallas", ("xla",)) == ("xla",)


def test_fallback_demotes_pallas_on_cpu_host():
    """The headline chain: a compiled-pallas spec on a host without the
    accelerator demotes (with structured evidence) to the first rung that
    can actually run — pallas_interpret."""
    events = []
    spec = spec_for_backend("megopolis", "pallas", num_iters=8)
    r = build_with_fallback(spec, recorder=events)
    assert r.spec.backend == "pallas_interpret"
    assert [e["kind"] for e in events] == ["backend_demotion"]
    assert events[0]["backend"] == "pallas"
    assert events[0]["to_backend"] == "pallas_interpret"
    assert events[0]["error_type"] in (
        "KernelLoweringError", "VmemBudgetExceeded"
    )
    # and the demoted resampler is live
    anc = r(jax.random.PRNGKey(0), jnp.full((N,), 1.0 / N))
    assert anc.shape == (N,)


def test_fallback_first_rung_healthy_is_silent():
    events = []
    spec = spec_for_backend("systematic", "xla")
    r = build_with_fallback(spec, recorder=events)
    assert r.spec.backend == "xla"
    assert events == []


def test_fallback_exhaustion_raises_typed_error():
    spec = spec_for_backend("megopolis", "pallas", num_iters=8)
    with pytest.raises(BackendUnavailable) as ei:
        build_with_fallback(spec, ladder=("pallas",))
    assert len(ei.value.failures) == 1
    backend, cause = ei.value.failures[0]
    assert backend == "pallas"
    assert isinstance(cause, ResilienceError)


def test_build_resilient_on_spec():
    r = spec_for_backend("stratified", "pallas").build_resilient()
    assert r.spec.backend == "pallas_interpret"


# --------------------------------------- 3. sink crash consistency (S2)
def test_sink_buffered_flush_on_close(tmp_path):
    path = os.path.join(str(tmp_path), "buffered.jsonl")
    sink = JsonlSink(path, buffer_size=100)
    sink.emit("a", x=1)
    sink.emit("b", x=2)
    assert not os.path.exists(path)  # still buffered
    sink.flush()
    assert [json.loads(l)["event"] for l in open(path)] == ["a", "b"]
    sink.emit("c")
    sink.close()
    assert [json.loads(l)["event"] for l in open(path)] == ["a", "b", "c"]
    with pytest.raises(ValueError, match="closed"):
        sink.emit("d")


def test_sink_flushes_on_abnormal_exit(tmp_path):
    """The §16 point: an exception inside the context must not lose the
    buffered tail."""
    path = os.path.join(str(tmp_path), "crash.jsonl")
    with pytest.raises(RuntimeError, match="boom"):
        with JsonlSink(path, buffer_size=1000) as sink:
            sink.emit("before_crash", step=1)
            raise RuntimeError("boom")
    lines = [json.loads(l) for l in open(path)]
    assert [l["event"] for l in lines] == ["before_crash"]


def test_sink_writethrough_default_unchanged(tmp_path):
    path = os.path.join(str(tmp_path), "wt.jsonl")
    sink = JsonlSink(path)
    sink.emit("now")
    assert [json.loads(l)["event"] for l in open(path)] == ["now"]


def test_sink_rejects_bad_buffer_size(tmp_path):
    with pytest.raises(ValueError):
        JsonlSink(os.path.join(str(tmp_path), "x.jsonl"), buffer_size=0)


# --------------------------------------------- 4. checkpointed runs (S4)
def _toy_scan_parts():
    def body(carry, x):
        carry = carry * 1.000001 + jnp.sin(x)
        return carry, jnp.stack([carry, carry * 2.0])

    init = jnp.float32(0.25)
    xs = jnp.linspace(0.0, 3.0, 11, dtype=jnp.float32)
    return body, init, xs


def test_checkpointed_scan_matches_monolith(tmp_path):
    body, init, xs = _toy_scan_parts()
    c0, ys0 = jax.lax.scan(body, init, xs)
    pol = CheckpointPolicy(directory=str(tmp_path / "ck"), every=4)
    c1, ys1 = checkpointed_scan(body, init, xs, pol)
    np.testing.assert_array_equal(np.asarray(c0), np.asarray(c1))
    np.testing.assert_array_equal(np.asarray(ys0), np.asarray(ys1))
    assert checkpointed_scan(body, init, xs, None)[1].shape == ys0.shape


def test_checkpointed_scan_kill_and_resume(tmp_path):
    body, init, xs = _toy_scan_parts()
    c0, ys0 = jax.lax.scan(body, init, xs)
    d = str(tmp_path / "ck")
    with pytest.raises(InjectedCrash):
        checkpointed_scan(
            body, init, xs, CheckpointPolicy(directory=d, every=3,
                                             fail_after=6)
        )
    # the crash left a durable snapshot; resume completes bit-identically
    c1, ys1 = checkpointed_scan(
        body, init, xs, CheckpointPolicy(directory=d, every=3)
    )
    np.testing.assert_array_equal(np.asarray(c0), np.asarray(c1))
    np.testing.assert_array_equal(np.asarray(ys0), np.asarray(ys1))


def test_checkpoint_policy_validation(tmp_path):
    with pytest.raises(ValueError):
        CheckpointPolicy(directory="")
    with pytest.raises(ValueError):
        CheckpointPolicy(directory=str(tmp_path), every=0)


def _small_filter(ess_threshold=0.5):
    from repro.pf.filter import ParticleFilter
    from repro.pf.models import ungm

    spec = spec_for_backend("systematic", "reference")
    return ParticleFilter(model=ungm(), num_particles=256, resampler=spec,
                          ess_threshold=ess_threshold)


def test_run_filter_kill_and_resume_bit_identical(tmp_path, base_key):
    """Satellite S4: kill ``run_filter`` at a snapshot boundary mid-scan,
    resume, and get bit-identical estimates AND telemetry."""
    from repro.pf.filter import run_filter

    pf = _small_filter()
    obs = jax.random.normal(jax.random.PRNGKey(21), (12,))
    est0, tel0 = run_filter(base_key, pf, obs, telemetry=True)

    d = str(tmp_path / "pfck")
    with pytest.raises(InjectedCrash):
        run_filter(base_key, pf, obs, telemetry=True,
                   checkpoint=CheckpointPolicy(directory=d, every=4,
                                               fail_after=8))
    est1, tel1 = run_filter(base_key, pf, obs, telemetry=True,
                            checkpoint=CheckpointPolicy(directory=d, every=4))
    np.testing.assert_array_equal(np.asarray(est0), np.asarray(est1))
    for a, b in zip(jax.tree_util.tree_leaves(tel0),
                    jax.tree_util.tree_leaves(tel1)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_run_smc_sampler_checkpoint_resume(tmp_path, base_key):
    from repro.ais.sampler import SMCSamplerConfig, run_smc_sampler
    from repro.ais.targets import isotropic_gaussian

    target = isotropic_gaussian()
    cfg = SMCSamplerConfig(num_particles=256, num_temps=8,
                           resampler="systematic")
    res0 = run_smc_sampler(base_key, target, cfg)

    d = str(tmp_path / "aisck")
    with pytest.raises(InjectedCrash):
        run_smc_sampler(base_key, target, cfg,
                        checkpoint=CheckpointPolicy(directory=d, every=3,
                                                    fail_after=3))
    res1 = run_smc_sampler(base_key, target, cfg,
                           checkpoint=CheckpointPolicy(directory=d, every=3))
    for k in res0:
        np.testing.assert_array_equal(np.asarray(res0[k]),
                                      np.asarray(res1[k]))
