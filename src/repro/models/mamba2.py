"""Mamba2 / SSD (state-space duality) block — chunked train + recurrent decode.

Follows the ``ssd_minimal`` formulation of the Mamba2 paper (arXiv:2405.21060):
intra-chunk quadratic attention-like einsums + inter-chunk state recurrence.
The recurrence runs as ``lax.associative_scan`` (log-depth, fully unrolled in
HLO so the dry-run's cost_analysis counts it — DESIGN.md §6.4).

Block layout (G=1 state group), with SEPARATE input projections so each
lands on a clean tensor-parallel partition (z/x/dt sharded over heads on the
``model`` axis; the small B/C state projections replicated):

    z  = x W_z   (d_inner, gate)        x_in = x W_x  (d_inner)
    B  = x W_b   (N)                    C    = x W_c  (N)
    dt = x W_dt  (heads)
    causal depthwise conv (width 4) on x_in / B / C separately
    SSD over heads with per-head decay A; gated RMSNorm; out_proj
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import init_linear, init_rmsnorm, linear, rmsnorm
from repro.models.partitioning import logical

CONV_WIDTH = 4


def mamba_dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    heads = d_inner // cfg.ssm_head_dim
    return d_inner, heads, cfg.ssm_state


def init_mamba(key, cfg):
    d = cfg.d_model
    d_inner, heads, n = mamba_dims(cfg)
    ks = jax.random.split(key, 10)
    return {
        "in_z": init_linear(ks[0], d, d_inner),
        "in_x": init_linear(ks[1], d, d_inner),
        "in_b": init_linear(ks[2], d, n),
        "in_c": init_linear(ks[3], d, n),
        "in_dt": init_linear(ks[4], d, heads),
        "conv_x": {"w": jax.random.normal(ks[5], (CONV_WIDTH, d_inner), jnp.float32) * 0.2,
                   "b": jnp.zeros((d_inner,), jnp.float32)},
        "conv_b": {"w": jax.random.normal(ks[6], (CONV_WIDTH, n), jnp.float32) * 0.2,
                   "b": jnp.zeros((n,), jnp.float32)},
        "conv_c": {"w": jax.random.normal(ks[7], (CONV_WIDTH, n), jnp.float32) * 0.2,
                   "b": jnp.zeros((n,), jnp.float32)},
        "a_log": jnp.log(jnp.linspace(1.0, float(heads), heads, dtype=jnp.float32)),
        "d_skip": jnp.ones((heads,), jnp.float32),
        "dt_bias": jnp.zeros((heads,), jnp.float32),
        "norm": init_rmsnorm(d_inner),
        "out_proj": init_linear(ks[8], d_inner, d),
    }


def _segsum(x):
    """(..., l) -> (..., l, l) lower-tri cumulative segment sums."""
    sl = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    d = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((sl, sl), bool))
    return jnp.where(mask, d, -jnp.inf)


def _ssd_chunked(x, log_da, b_ssm, c_ssm, chunk: int):
    """x (b,s,h,p) pre-scaled by dt; log_da (b,s,h); b/c (b,s,n).
    Returns y (b,s,h,p) f32 and final state (b,h,p,n) f32."""
    bsz, s, h, p = x.shape
    n = b_ssm.shape[-1]
    assert s % chunk == 0, (s, chunk)
    c = s // chunk
    xc = x.reshape(bsz, c, chunk, h, p)
    ac = log_da.reshape(bsz, c, chunk, h).transpose(0, 3, 1, 2)  # (b,h,c,l)
    bc = b_ssm.reshape(bsz, c, chunk, n)
    cc = c_ssm.reshape(bsz, c, chunk, n)

    a_cum = jnp.cumsum(ac, axis=-1)  # (b,h,c,l)

    # 1. intra-chunk (diagonal blocks)
    decay = jnp.exp(_segsum(ac))  # (b,h,c,l,l)
    y_diag = jnp.einsum(
        "bcln,bcsn,bhcls,bcshp->bclhp", cc, bc, decay, xc, preferred_element_type=jnp.float32
    )

    # 2. per-chunk input -> end-of-chunk state
    decay_states = jnp.exp(a_cum[..., -1:] - a_cum)  # (b,h,c,l)
    states = jnp.einsum(
        "bcln,bhcl,bclhp->bchpn", bc, decay_states, xc, preferred_element_type=jnp.float32
    )

    # 3. inter-chunk recurrence H_{c+1} = H_c * exp(sum a_c) + states_c
    #    (associative scan -> log-depth, fully unrolled HLO)
    chunk_decay = jnp.exp(a_cum[..., -1]).transpose(0, 2, 1)  # (b,c,h)

    def combine(lhs, rhs):
        a1, s1 = lhs
        a2, s2 = rhs
        return a1 * a2, s1 * a2[..., None, None] + s2

    _, s_scan = jax.lax.associative_scan(
        combine, (chunk_decay, states.astype(jnp.float32)), axis=1
    )
    final_state = s_scan[:, -1]  # (b,h,p,n)
    h_prev = jnp.concatenate([jnp.zeros_like(s_scan[:, :1]), s_scan[:, :-1]], axis=1)

    # 4. carried state -> output contribution
    state_decay_out = jnp.exp(a_cum)  # (b,h,c,l)
    y_off = jnp.einsum(
        "bcln,bchpn,bhcl->bclhp", cc, h_prev, state_decay_out, preferred_element_type=jnp.float32
    )

    return (y_diag + y_off).reshape(bsz, s, h, p), final_state


def _causal_conv(seq, conv_p):
    """Depthwise causal conv, width CONV_WIDTH.  seq (b,s,c)."""
    w, b = conv_p["w"], conv_p["b"]
    pad = jnp.pad(seq, ((0, 0), (CONV_WIDTH - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + seq.shape[1]] * w[i][None, None, :].astype(seq.dtype)
        for i in range(CONV_WIDTH)
    )
    return jax.nn.silu(out + b.astype(seq.dtype))


def mamba_block(p, cfg, x, *, chunk: int = 256):
    """Training/prefill forward.  x (b,s,D) -> (y (b,s,D), cache)."""
    bsz, s, _ = x.shape
    d_inner, heads, n = mamba_dims(cfg)
    z = logical(linear(p["in_z"], x, x.dtype), "batch", "seq", "d_inner")
    xin_raw = logical(linear(p["in_x"], x, x.dtype), "batch", "seq", "d_inner")
    b_raw = logical(linear(p["in_b"], x, x.dtype), "batch", "seq", None)
    c_raw = logical(linear(p["in_c"], x, x.dtype), "batch", "seq", None)
    dt = logical(linear(p["in_dt"], x, jnp.float32), "batch", "seq", "ssm_heads")

    xin = _causal_conv(xin_raw, p["conv_x"])
    b_ssm = _causal_conv(b_raw, p["conv_b"])
    c_ssm = _causal_conv(c_raw, p["conv_c"])

    dt = jax.nn.softplus(dt + p["dt_bias"])  # (b,s,h)
    a = -jnp.exp(p["a_log"])  # (h,)
    log_da = dt * a
    xh = xin.reshape(bsz, s, heads, cfg.ssm_head_dim)
    xh = logical(xh, "batch", "seq", "ssm_heads", None)
    x_scaled = xh.astype(jnp.float32) * dt[..., None]

    # pad seq to a chunk multiple with identity steps (decay exp(0)=1, zero
    # input) — state- and output-exact, then slice back
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        def zpad(a):
            return jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))

        x_scaled, log_da = zpad(x_scaled), zpad(log_da)
        b_pad, c_pad = zpad(b_ssm.astype(jnp.float32)), zpad(c_ssm.astype(jnp.float32))
    else:
        b_pad, c_pad = b_ssm.astype(jnp.float32), c_ssm.astype(jnp.float32)

    y, final_state = _ssd_chunked(x_scaled, log_da, b_pad, c_pad, chunk)
    y = y[:, :s]
    y = y + xh.astype(jnp.float32) * p["d_skip"][None, None, :, None]
    y = logical(y.reshape(bsz, s, d_inner).astype(x.dtype), "batch", "seq", "d_inner")
    y = rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    take = CONV_WIDTH - 1
    cache = {
        "conv_x": xin_raw[:, -take:, :].astype(x.dtype),
        "conv_b": b_raw[:, -take:, :].astype(x.dtype),
        "conv_c": c_raw[:, -take:, :].astype(x.dtype),
        "ssm": final_state,
    }
    return linear(p["out_proj"], y, x.dtype), cache


def init_mamba_cache(cfg, batch: int, dtype):
    d_inner, heads, n = mamba_dims(cfg)
    take = CONV_WIDTH - 1
    return {
        "conv_x": jnp.zeros((batch, take, d_inner), dtype),
        "conv_b": jnp.zeros((batch, take, n), dtype),
        "conv_c": jnp.zeros((batch, take, n), dtype),
        "ssm": jnp.zeros((batch, heads, cfg.ssm_head_dim, n), jnp.float32),
    }


def _conv_step(window, conv_p):
    """window (b,W,c) -> conv output at the last position (b,c)."""
    w = conv_p["w"].astype(window.dtype)
    return jax.nn.silu(jnp.einsum("bwc,wc->bc", window, w) + conv_p["b"].astype(window.dtype))


def mamba_decode_step(p, cfg, x, cache):
    """One-token decode.  x (b,1,D) -> (y (b,1,D), cache')."""
    bsz = x.shape[0]
    d_inner, heads, n = mamba_dims(cfg)
    z = linear(p["in_z"], x, x.dtype)
    xin_raw = linear(p["in_x"], x, x.dtype)
    b_raw = linear(p["in_b"], x, x.dtype)
    c_raw = linear(p["in_c"], x, x.dtype)
    dt = linear(p["in_dt"], x, jnp.float32)

    win_x = jnp.concatenate([cache["conv_x"], xin_raw], axis=1)
    win_b = jnp.concatenate([cache["conv_b"], b_raw], axis=1)
    win_c = jnp.concatenate([cache["conv_c"], c_raw], axis=1)
    xin = _conv_step(win_x, p["conv_x"])
    b_ssm = _conv_step(win_b, p["conv_b"])
    c_ssm = _conv_step(win_c, p["conv_c"])

    dt = jax.nn.softplus(dt[:, 0] + p["dt_bias"])  # (b,h)
    a = -jnp.exp(p["a_log"])
    da = jnp.exp(dt * a)  # (b,h)
    xh = xin.reshape(bsz, heads, cfg.ssm_head_dim).astype(jnp.float32)
    bx = jnp.einsum("bhp,bn->bhpn", xh * dt[..., None], b_ssm.astype(jnp.float32))
    ssm = cache["ssm"] * da[..., None, None] + bx
    y = jnp.einsum("bhpn,bn->bhp", ssm, c_ssm.astype(jnp.float32))
    y = y + xh * p["d_skip"][None, :, None]
    y = y.reshape(bsz, 1, d_inner).astype(x.dtype)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    cache = {"conv_x": win_x[:, 1:], "conv_b": win_b[:, 1:], "conv_c": win_c[:, 1:], "ssm": ssm}
    return linear(p["out_proj"], y, x.dtype), cache
