"""Mixture-of-Experts: sort-based top-k dispatch (MegaBlocks-lite).

Dispatch avoids the GShard dense one-hot einsum (whose FLOPs scale with
``T * E * C`` and would swamp the roofline accounting) in favour of
sort + bounded-capacity scatter/gather:

  1. router logits -> top-k (expert, gate) per token;
  2. flatten (T*k) assignments, argsort by expert id;
  3. position-within-expert via exclusive counts; drop beyond capacity
     ``C = ceil(T * k / E) * capacity_factor`` (standard token dropping);
  4. scatter tokens into an (E, C, D) buffer, grouped-GEMM both MLP
     matmuls as ``(E,C,D) x (E,D,F)`` einsums, gather back weighted by the
     gate.

With experts sharded over the ``model`` axis this lowers to an all-to-all
of the (E, C, D) buffer (expert parallelism).  DBRX (16e top-4) and
Llama4-Maverick (128e top-1 + shared expert) both route through here.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import partitioning
from repro.models.layers import init_linear, linear
from repro.models.mlp import init_mlp, mlp


def init_moe(key, cfg):
    ks = jax.random.split(key, 4)
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    p = {
        "router": init_linear(ks[0], d, e),
        "w1": {"w": jax.random.normal(ks[1], (e, d, f), jnp.float32) * (d**-0.5)},
        "w3": {"w": jax.random.normal(ks[3], (e, d, f), jnp.float32) * (d**-0.5)},
        "w2": {"w": jax.random.normal(ks[2], (e, f, d), jnp.float32) * (f**-0.5)},
    }
    if cfg.num_shared_experts:
        p["shared"] = init_mlp(
            jax.random.fold_in(key, 7), d, cfg.d_ff * cfg.num_shared_experts, cfg.mlp_type
        )
    return p


def moe(p, cfg, x, *, capacity_factor: float = 1.25):
    """x: (B, S, D) -> (B, S, D).  Token-dropping top-k routing.

    Under a partitioning-rules context with a tensor-parallel axis that
    divides num_experts, dispatch runs through ``moe_sharded`` (shard_map
    expert parallelism); otherwise the single-device sort-based path below.
    """
    if partitioning.tp_size() > 1 and cfg.num_experts % partitioning.tp_size() == 0:
        return moe_sharded(p, cfg, x, capacity_factor=capacity_factor)
    return _moe_local(p, cfg, x, capacity_factor=capacity_factor)


def _moe_local(p, cfg, x, *, capacity_factor: float = 1.25):
    b, s, d = x.shape
    t = b * s
    e, k = cfg.num_experts, cfg.top_k
    x2 = x.reshape(t, d)

    logits = linear(p["router"], x2, jnp.float32)  # (T, E) in f32
    gates, eids = jax.lax.top_k(logits, k)  # (T, k)
    gates = jax.nn.softmax(gates, axis=-1).astype(x.dtype)

    flat_e = eids.reshape(t * k)  # expert of assignment a
    flat_tok = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    flat_gate = gates.reshape(t * k)

    order = jnp.argsort(flat_e)  # group assignments by expert
    e_sorted = flat_e[order]
    tok_sorted = flat_tok[order]
    gate_sorted = flat_gate[order]

    counts = jnp.bincount(flat_e, length=e)  # tokens per expert
    starts = jnp.cumsum(counts) - counts  # exclusive prefix
    pos = jnp.arange(t * k, dtype=jnp.int32) - starts[e_sorted]  # slot in expert

    # decode-sized batches (few tokens) dispatch DROPLESS: capacity-based
    # token dropping is a throughput/memory trade for training-scale T, but
    # at decode it makes cached serving diverge from the full forward
    capacity = (t * k if t <= 256
                else int(max(1, (t * k + e - 1) // e) * capacity_factor))
    keep = pos < capacity

    # scatter into (E, C, D); dropped tokens contribute nothing
    buf = jnp.zeros((e, capacity, d), x.dtype)
    safe_pos = jnp.where(keep, pos, 0)
    buf = buf.at[e_sorted, safe_pos].add(
        jnp.where(keep[:, None], x2[tok_sorted], 0).astype(x.dtype)
    )

    # grouped GEMMs (expert-parallel under pjit: E sharded over 'model')
    h = jnp.einsum("ecd,edf->ecf", buf, p["w1"]["w"].astype(x.dtype))
    g = jnp.einsum("ecd,edf->ecf", buf, p["w3"]["w"].astype(x.dtype))
    h = jax.nn.silu(g) * h
    y = jnp.einsum("ecf,efd->ecd", h, p["w2"]["w"].astype(x.dtype))

    # gather back + weighted combine over the k assignments
    y_tok = y[e_sorted, safe_pos] * jnp.where(keep, gate_sorted, 0)[:, None]
    out = jnp.zeros((t, d), x.dtype).at[tok_sorted].add(y_tok)

    if cfg.num_shared_experts:
        out = out + mlp(p["shared"], x2, cfg.mlp_type)
    return out.reshape(b, s, d)


def moe_sharded(p, cfg, x, *, capacity_factor: float = 1.25):
    """Expert-parallel MoE dispatch, shard_map over ('model', 'data').

    Design (DESIGN.md §5): activations are replicated across 'model' (the
    TP invariant at block entry), experts are sharded across 'model'.  Each
    shard routes the SAME local-DP tokens, keeps only the assignments that
    land on ITS experts, grouped-GEMMs them, and the combine is one
    ``psum`` over 'model' — byte-identical to the all-reduce a dense TP MLP
    needs, so expert parallelism costs no extra collective class (no
    all-to-all on the ICI).  Token dropping per expert matches the local
    path: capacity = ceil(t*k/E)*factor.

    Expert weights are additionally sharded over 'data' on their d/f dim
    (2-D expert sharding) and are contracted SHARDED: the grouped GEMMs run
    on the local d- (resp. f-) slice and the partial products psum over
    'data'.  Unlike FSDP weight-gathering this never materialises a full
    expert tensor (132 GiB-arch fits 16 GiB chips) and the wire cost scales
    with the per-microbatch activations, not the weights.

    GSPMD cannot shard the sort-based dispatch (data-dependent scatter
    destinations force replication — measured 64 GiB/chip buffers on dbrx);
    shard_map states the locality explicitly.
    """
    st = partitioning._current()
    mesh, bax = st["mesh"], st["map"].get("batch")
    e, k = cfg.num_experts, cfg.top_k
    tp = int(mesh.shape["model"])
    e_loc = e // tp
    d_model, d_ff = cfg.d_model, cfg.d_ff
    # FSDP dim of the expert weights spans every non-'model' axis
    # (hierarchical pod+data on the multi-pod mesh)
    f_axes = tuple(a for a in mesh.axis_names if a != "model")
    dp = 1
    for a in f_axes:
        dp *= int(mesh.shape[a])
    f_axes = f_axes if len(f_axes) > 1 else (f_axes[0] if f_axes else None)
    shard2d = dp > 1 and d_model % dp == 0 and d_ff % dp == 0
    w_spec = P("model", f_axes, None) if shard2d else P("model", None, None)

    def local(router_w, w1, w3, w2, x_loc):
        b, s, d = x_loc.shape
        t = b * s
        x2 = x_loc.reshape(t, d)
        logits = (x2.astype(jnp.float32) @ router_w.astype(jnp.float32))  # (t, E)
        gates, eids = jax.lax.top_k(logits, k)
        gates = jax.nn.softmax(gates, axis=-1).astype(x_loc.dtype)

        my_lo = jax.lax.axis_index("model").astype(jnp.int32) * e_loc
        flat_e = eids.reshape(t * k).astype(jnp.int32)
        flat_tok = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
        flat_gate = gates.reshape(t * k)
        mine = (flat_e >= my_lo) & (flat_e < my_lo + e_loc)
        local_e = jnp.where(mine, flat_e - my_lo, e_loc)  # e_loc = drop bucket

        order = jnp.argsort(local_e)
        e_sorted = local_e[order]
        tok_sorted = flat_tok[order]
        gate_sorted = flat_gate[order]
        counts = jnp.bincount(local_e, length=e_loc + 1)
        starts = jnp.cumsum(counts) - counts
        pos = jnp.arange(t * k, dtype=jnp.int32) - starts[e_sorted].astype(jnp.int32)

        capacity = (t * k if t <= 256  # dropless at decode (see _moe_local)
                    else int(max(1, -(-t * k // e)) * capacity_factor))
        keep = (pos < capacity) & (e_sorted < e_loc)
        safe_e = jnp.minimum(e_sorted, e_loc - 1)
        safe_pos = jnp.where(keep, pos, 0)

        buf = jnp.zeros((e_loc, capacity, d), x_loc.dtype)
        buf = buf.at[safe_e, safe_pos].add(
            jnp.where(keep[:, None], x2[tok_sorted], 0).astype(x_loc.dtype)
        )
        if shard2d:
            # 2-D contraction: slice the FULL-d token buffer down to this
            # fsdp-shard's d-slice, partial-GEMM against the local weight
            # slice, reduce-scatter the partial products so each shard lands
            # exactly the f-slice its w2 slice needs (half the wire of an
            # all-reduce), then psum the final d-space product.
            d_loc = d_model // dp
            di = jax.lax.axis_index(f_axes) * d_loc
            buf_d = jax.lax.dynamic_slice_in_dim(buf, di, d_loc, axis=2)
            h = jax.lax.psum_scatter(
                jnp.einsum("ecd,edf->ecf", buf_d, w1), f_axes,
                scatter_dimension=2, tiled=True)
            g = jax.lax.psum_scatter(
                jnp.einsum("ecd,edf->ecf", buf_d, w3), f_axes,
                scatter_dimension=2, tiled=True)
            y = jax.lax.psum(jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * h, w2), f_axes)
        else:
            h = jnp.einsum("ecd,edf->ecf", buf, w1)
            g = jnp.einsum("ecd,edf->ecf", buf, w3)
            y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * h, w2)
        y_tok = y[safe_e, safe_pos] * jnp.where(keep, gate_sorted, 0)[:, None]
        out = jnp.zeros((t, d), x_loc.dtype).at[tok_sorted].add(y_tok)
        out = jax.lax.psum(out, "model")  # the TP-MLP all-reduce equivalent
        return out.reshape(b, s, d)

    fn = jax.shard_map(
        local,
        mesh=mesh,
        in_specs=(P(), w_spec, w_spec, w_spec, P(bax, None, None)),
        out_specs=P(bax, None, None),
        check_vma=False,
    )
    # cast to compute dtype BEFORE the shard_map boundary (sharded cast)
    out = fn(p["router"]["w"], p["w1"]["w"].astype(x.dtype),
             p["w3"]["w"].astype(x.dtype), p["w2"]["w"].astype(x.dtype), x)
    if cfg.num_shared_experts:
        b, s, d = x.shape
        out = out + mlp(p["shared"], x.reshape(b * s, d), cfg.mlp_type).reshape(b, s, d)
    return out


def aux_load_balance_loss(p, cfg, x):
    """Switch-style auxiliary loss (f_i * P_i * E); optional in training."""
    b, s, d = x.shape
    logits = linear(p["router"], x.reshape(-1, d), jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top1 = jnp.argmax(logits, axis=-1)
    f = jnp.bincount(top1, length=cfg.num_experts) / logits.shape[0]
    return cfg.num_experts * jnp.sum(f * jnp.mean(probs, axis=0))
