"""Pallas kernel validation (interpret mode): bit-exact vs ref.py oracles,
shape/dtype sweeps, and statistical quality parity with repro.core."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import megopolis as core_megopolis
from repro.core import select_iterations
from repro.core.metrics import mse, offspring_counts
from repro.core.resamplers.batched import split_batch_keys
from repro.core.weightgen import gaussian_weights
from repro.kernels import megopolis_tpu, metropolis_tpu, prefix_sum_tpu
from repro.kernels.common import TILE, flat_roll, hash_uniform, key_to_seed
from repro.kernels.megopolis.megopolis import megopolis_pallas
from repro.kernels.megopolis.ref import megopolis_ref
from repro.kernels.metropolis.c1c2 import metropolis_c1_pallas, metropolis_c2_pallas
from repro.kernels.metropolis.metropolis import metropolis_pallas
from repro.kernels.metropolis.ops import metropolis_tpu_batch
from repro.kernels.metropolis.ref import metropolis_c1_ref, metropolis_c2_ref, metropolis_ref
from repro.kernels.prefix_sum.ops import prefix_resample_tpu, searchsorted_tpu
from repro.kernels.prefix_sum.ref import prefix_resample_ref, prefix_sum_ref, prefix_sum_tiled_ref
from repro.kernels.rejection.ops import rejection_tpu, rejection_tpu_batch
from repro.kernels.rejection.ref import rejection_ref


# ---------------------------------------------------------------- flat_roll
@pytest.mark.parametrize("rows", [8, 16])
@pytest.mark.parametrize("shift", [0, 1, 127, 128, 129, 1000, 1023, 1024])
def test_flat_roll_matches_numpy(rows, shift):
    x = jnp.arange(rows * 128, dtype=jnp.float32).reshape(rows, 128)
    got = np.asarray(flat_roll(x, shift)).reshape(-1)
    want = np.roll(np.asarray(x).reshape(-1), -shift)
    np.testing.assert_array_equal(got, want)


def test_hash_uniform_statistics():
    """The stateless RNG must be uniform enough for accept/reject tests."""
    i = jnp.arange(1 << 16)
    u = np.asarray(hash_uniform(jnp.uint32(123), i, 7))
    assert 0.0 <= u.min() and u.max() < 1.0
    assert abs(u.mean() - 0.5) < 0.01
    assert abs(np.quantile(u, 0.25) - 0.25) < 0.01
    # iteration decorrelation
    u2 = np.asarray(hash_uniform(jnp.uint32(123), i, 8))
    assert abs(np.corrcoef(u, u2)[0, 1]) < 0.02


# ---------------------------------------------------------- megopolis kernel
@pytest.mark.parametrize("n_tiles", [1, 2, 5])
@pytest.mark.parametrize("num_iters", [1, 7, 24])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_megopolis_kernel_matches_ref(n_tiles, num_iters, dtype, base_key):
    n = n_tiles * TILE
    w = (jax.random.uniform(jax.random.fold_in(base_key, n_tiles), (n,)) + 1e-3).astype(dtype)
    offsets = jax.random.randint(jax.random.fold_in(base_key, 77), (num_iters,), 0, n, jnp.int32)
    seed = key_to_seed(jax.random.fold_in(base_key, 99)).reshape(1)
    got = megopolis_pallas(
        w.reshape(-1, 128), offsets, seed, num_iters=num_iters, interpret=True
    ).reshape(n)
    want = megopolis_ref(w, offsets, seed, num_iters=num_iters)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_megopolis_tpu_public_api(base_key):
    n = 4 * TILE
    w = jax.random.uniform(base_key, (n,)) + 1e-3
    a = megopolis_tpu(base_key, w, 16)
    assert a.shape == (n,) and a.dtype == jnp.int32
    assert bool(jnp.all((a >= 0) & (a < n)))
    with pytest.raises(ValueError):
        megopolis_tpu(base_key, w[: n - 3], 16)


def test_megopolis_kernel_quality_parity(base_key):
    """Kernel (SEG=1024, hash RNG) must match core megopolis (SEG=32,
    jax.random) in MSE on the paper's weight family — DESIGN.md §2."""
    n = 4 * TILE
    w = gaussian_weights(jax.random.PRNGKey(3), n, y=2.0)
    num_iters = int(select_iterations(w, 0.01))
    k_runs = 24
    o_kern, o_core = [], []
    for t in range(k_runs):
        kk = jax.random.fold_in(base_key, 500 + t)
        o_kern.append(np.asarray(offspring_counts(megopolis_tpu(kk, w, num_iters), n)))
        o_core.append(np.asarray(offspring_counts(core_megopolis(kk, w, num_iters), n)))
    m_kern = float(mse(jnp.asarray(np.stack(o_kern)), w)) / n
    m_core = float(mse(jnp.asarray(np.stack(o_core)), w)) / n
    assert abs(m_kern - m_core) < 0.4 * m_core, (m_kern, m_core)


# --------------------------------------------------------- metropolis kernel
@pytest.mark.parametrize("n_tiles", [1, 3])
@pytest.mark.parametrize("num_iters", [1, 16])
def test_metropolis_kernel_matches_ref(n_tiles, num_iters, base_key):
    n = n_tiles * TILE
    w = jax.random.uniform(jax.random.fold_in(base_key, 5), (n,)) + 1e-3
    seed = key_to_seed(jax.random.fold_in(base_key, 6)).reshape(1)
    got = metropolis_pallas(w.reshape(-1, 128), seed, num_iters=num_iters, interpret=True)
    want = metropolis_ref(w, seed, num_iters=num_iters)
    np.testing.assert_array_equal(np.asarray(got).reshape(-1), np.asarray(want))


def test_metropolis_tpu_vmem_cap(base_key):
    from repro.kernels.metropolis.ops import MAX_VMEM_PARTICLES

    w = jnp.ones((MAX_VMEM_PARTICLES + TILE,))
    with pytest.raises(ValueError, match="VMEM"):
        metropolis_tpu(base_key, w, 4)


@pytest.mark.parametrize("bsz", [1, 3])
def test_metropolis_batch_kernel_rows_match_single(bsz, base_key):
    """Row b of the [B, R, 128] launch == single kernel with split key b."""
    n = 2 * TILE
    w = jax.random.uniform(jax.random.fold_in(base_key, 21), (bsz, n)) + 1e-3
    got = metropolis_tpu_batch(base_key, w, 6)
    keys = split_batch_keys(base_key, bsz)
    want = jnp.stack([metropolis_tpu(keys[b], w[b], 6) for b in range(bsz)])
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ------------------------------------------------------------ C1/C2 kernels
@pytest.mark.parametrize("n_tiles", [2, 4])
@pytest.mark.parametrize("num_iters", [1, 9])
def test_c1_kernel_matches_ref(n_tiles, num_iters, base_key):
    n = n_tiles * TILE
    w = jax.random.uniform(jax.random.fold_in(base_key, 31), (n,)) + 1e-3
    p = jax.random.randint(jax.random.fold_in(base_key, 32), (n_tiles,), 0, n_tiles, jnp.int32)
    seed = key_to_seed(jax.random.fold_in(base_key, 33)).reshape(1)
    got = metropolis_c1_pallas(
        w.reshape(-1, 128), p, seed, num_iters=num_iters, interpret=True
    ).reshape(n)
    want = metropolis_c1_ref(w, p, seed, num_iters=num_iters)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("n_tiles", [2, 4])
@pytest.mark.parametrize("num_iters", [1, 9])
def test_c2_kernel_matches_ref(n_tiles, num_iters, base_key):
    n = n_tiles * TILE
    w = jax.random.uniform(jax.random.fold_in(base_key, 41), (n,)) + 1e-3
    p = jax.random.randint(
        jax.random.fold_in(base_key, 42), (n_tiles * num_iters,), 0, n_tiles, jnp.int32
    )
    seed = key_to_seed(jax.random.fold_in(base_key, 43)).reshape(1)
    got = metropolis_c2_pallas(
        w.reshape(-1, 128), p, seed, num_iters=num_iters, interpret=True
    ).reshape(n)
    want = metropolis_c2_ref(w, p, seed, num_iters=num_iters)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_c1_proposals_stay_in_partition(base_key):
    """C1's defining constraint: every ancestor that moved lies in its
    tile's single partition tile (Alg. 3's locality, tile-granular)."""
    n = 4 * TILE
    n_tiles = 4
    w = jax.random.uniform(jax.random.fold_in(base_key, 51), (n,)) + 1e-3
    p = jax.random.randint(jax.random.fold_in(base_key, 52), (n_tiles,), 0, n_tiles, jnp.int32)
    seed = key_to_seed(jax.random.fold_in(base_key, 53)).reshape(1)
    a = np.asarray(
        metropolis_c1_pallas(w.reshape(-1, 128), p, seed, num_iters=16, interpret=True)
    ).reshape(n)
    i = np.arange(n)
    moved = a != i
    a_tile = a // TILE
    want_tile = np.asarray(p)[i // TILE]
    assert np.all(a_tile[moved] == want_tile[moved])


# ---------------------------------------------------------- rejection kernel
@pytest.mark.parametrize("n_tiles", [1, 3])
@pytest.mark.parametrize("max_iters", [1, 24])
def test_rejection_kernel_matches_ref(n_tiles, max_iters, base_key):
    n = n_tiles * TILE
    w = jax.random.uniform(jax.random.fold_in(base_key, 61), (n,)) + 1e-3
    got = rejection_tpu(base_key, w, max_iters=max_iters)
    want = rejection_ref(w, key_to_seed(base_key).reshape(1), max_iters=max_iters)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_rejection_batch_kernel_rows_match_single(base_key):
    n = 2 * TILE
    w = jax.random.uniform(jax.random.fold_in(base_key, 62), (3, n)) + 1e-3
    got = rejection_tpu_batch(base_key, w, max_iters=16)
    keys = split_batch_keys(base_key, 3)
    want = jnp.stack([rejection_tpu(keys[b], w[b], max_iters=16) for b in range(3)])
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_rejection_kernel_unbiased_in_expectation(base_key):
    """Offspring mean tracks N*w/sum(w) (rejection is unbiased; cap rarely
    binds at these weights)."""
    n = 2 * TILE
    w = gaussian_weights(jax.random.PRNGKey(5), n, y=1.0)
    runs = 24
    offs = []
    for t in range(runs):
        a = rejection_tpu(jax.random.fold_in(base_key, 600 + t), w, max_iters=64)
        offs.append(np.asarray(offspring_counts(a, n)))
    mean_off = np.stack(offs).mean(axis=0)
    want = n * np.asarray(w / jnp.sum(w))
    # noisy at K=24: check correlation + overall scale rather than per-particle
    assert np.corrcoef(mean_off, want)[0, 1] > 0.95
    np.testing.assert_allclose(mean_off.sum(), n, rtol=1e-6)


# --------------------------------------------------------- prefix sum kernel
@pytest.mark.parametrize("n_tiles", [1, 2, 7])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_prefix_sum_matches_ref(n_tiles, dtype, base_key):
    n = n_tiles * TILE
    x = jax.random.uniform(base_key, (n,), jnp.float32).astype(dtype)
    got = prefix_sum_tpu(x)
    want = prefix_sum_ref(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_prefix_sum_tiled_ref_bit_exact(base_key):
    """The tiled oracle replays the kernel's carry arithmetic bit-for-bit
    (the plain-cumsum oracle is only close)."""
    n = 5 * TILE
    x = jax.random.uniform(base_key, (n,), jnp.float32)
    got = np.asarray(prefix_sum_tpu(x))
    want = np.asarray(prefix_sum_tiled_ref(x))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("side", ["left", "right"])
def test_searchsorted_kernel_matches_jnp(side, base_key):
    n = 2 * TILE
    c = jnp.sort(jax.random.uniform(jax.random.fold_in(base_key, 71), (n,))) * 100.0
    u = jax.random.uniform(jax.random.fold_in(base_key, 72), (n,)) * 110.0 - 5.0
    got = searchsorted_tpu(c, u, side=side)
    want = jnp.minimum(jnp.searchsorted(c, u, side=side), n - 1).astype(jnp.int32)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize(
    "kind", ["multinomial", "systematic", "improved_systematic", "stratified", "residual"]
)
def test_prefix_resample_kernel_matches_ref(kind, base_key):
    n = 3 * TILE
    w = jax.random.uniform(jax.random.fold_in(base_key, 73), (n,)) + 1e-3
    got = prefix_resample_tpu(base_key, w, kind)
    want = prefix_resample_ref(base_key, w, kind=kind)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_prefix_resample_improved_systematic_equals_systematic(base_key):
    """Alg. 8's walk == searchsorted-left systematic; on the kernel lane the
    two kinds share the search kernel by construction — pin it."""
    n = 2 * TILE
    w = jax.random.uniform(jax.random.fold_in(base_key, 74), (n,)) + 1e-3
    a = prefix_resample_tpu(base_key, w, "systematic")
    b = prefix_resample_tpu(base_key, w, "improved_systematic")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_prefix_sum_f32_instability_story(base_key):
    """Reproduce the paper's §1 motivation: f32 prefix sums over many
    similar weights drift from the f64 truth as N grows."""
    n = 64 * TILE
    x = jax.random.uniform(base_key, (n,), jnp.float32) + 0.5
    f32 = np.asarray(prefix_sum_tpu(x))[-1]
    f64 = np.cumsum(np.asarray(x, np.float64))[-1]
    rel = abs(f32 - f64) / f64
    assert rel > 0  # measurable drift exists
    assert rel < 1e-4  # but bounded at this N (grows with N)
