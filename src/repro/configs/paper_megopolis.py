"""The paper's own experimental configuration (§5-§7).

Used by the benchmark harness so every figure/table reproduction reads its
settings from one place.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class PaperConfig:
    # §5: weight generation
    y_values: tuple = (0.0, 1.0, 2.0, 3.0, 4.0)  # Gaussian-likelihood (eq. 12)
    gamma_alphas: tuple = (0.5, 2.0, 3.0, 10.0, 50.0)  # Gamma (eq. 13)
    particle_range: tuple = tuple(2**e for e in range(6, 23))  # 2^6 .. 2^22
    num_weight_sequences: int = 16
    monte_carlo_runs: int = 256  # K
    epsilon: float = 0.01  # error bound for B (eq. 3)
    # §6.4: C1/C2 partition sweep
    partition_sizes: tuple = (128, 256, 512, 1024, 2048)  # bytes
    # §7: end-to-end UNGM benchmark
    e2e_particles: int = 2**20
    e2e_time_steps: int = 100
    e2e_trajectories: int = 16
    e2e_mc_runs: int = 50
    e2e_b_values: tuple = (5, 7, 10, 15, 20, 25, 30, 40)
    e2e_b_compare: tuple = (16, 32, 64)  # Table 2
    e2e_epsilon: float = 0.1

    # CI-scale variant: same structure, laptop-runnable sizes.  Full paper
    # sizes are available behind --full in benchmarks.
    @staticmethod
    def ci():
        return PaperConfig(
            particle_range=tuple(2**e for e in range(6, 17)),
            num_weight_sequences=4,
            monte_carlo_runs=32,
            e2e_particles=2**14,
            e2e_trajectories=2,
            e2e_mc_runs=4,
        )


PAPER = PaperConfig()
