"""``StepStats`` — the fixed per-step SMC diagnostic record (DESIGN.md §15).

One record per fused-step decision, with identical semantics on every
backend:

- ``ess_norm``           f32, ESS/N of the UNNORMALISED input log-weights —
  the resample trigger (``ess_norm < threshold``).
- ``log_evidence_incr``  f32, ``log(mean(exp(log_w)))`` when the step
  resampled, else 0.0 (the evidence ledger only advances on resamples).
- ``resampled``          f32, 1.0 when the trigger fired else 0.0 — float
  so the record stays a single homogeneous stats vector in kernel SMEM and
  stacks cleanly under ``lax.scan``.
- ``max_weight``         f32, largest normalised weight ``max(w)/Σw`` — the
  weight-degeneracy diagnostic complementing ESS.
- ``survivors``          int32, number of DISTINCT ancestors (identity
  ancestors ⇒ N; full collapse ⇒ 1) — the Murray–Lee–Jacob unique-particle
  count, composed from the ancestor vector by the public wrapper (sort-based,
  never a scatter: see ``core.metrics.unique_ancestor_count``).
- ``degenerate``         bool, the §16 collapsed-bank flag — True when the
  input log-weight bank carried no usable information (all ``-inf``, any
  nan/±inf: ``core.metrics.degenerate_log_weights``) and the normalisation
  substituted the uniform fallback bank.  Composed host-side from the raw
  log-weights by the public wrapper, identically on every backend.

The first four fields are the kernel SMEM stats vector (f32[4], in that
order); ``survivors`` and ``degenerate`` are appended host-side from the
values the same launch consumed/returned.  ``NamedTuple`` ⇒ automatically a
pytree: records scan, vmap and stack like any array bundle.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp


class StepStats(NamedTuple):
    ess_norm: jnp.ndarray
    log_evidence_incr: jnp.ndarray
    resampled: jnp.ndarray
    max_weight: jnp.ndarray
    survivors: jnp.ndarray
    degenerate: jnp.ndarray


def stats_from_vector(
    stats4: jnp.ndarray, survivors: jnp.ndarray, degenerate: jnp.ndarray = None
) -> StepStats:
    """Unpack a kernel stats vector ``f32[..., 4]`` (row layout above) plus a
    host-composed survivor count (and degenerate flag) into a ``StepStats``
    record.  Batched inputs (``[B, 4]`` + ``[B]``) yield a batched record.
    ``degenerate`` defaults to all-False in the shape of ``survivors`` for
    callers that pre-date the §16 guard layer."""
    if degenerate is None:
        degenerate = jnp.zeros(jnp.shape(survivors), jnp.bool_)
    return StepStats(
        ess_norm=stats4[..., 0],
        log_evidence_incr=stats4[..., 1],
        resampled=stats4[..., 2],
        max_weight=stats4[..., 3],
        survivors=survivors,
        degenerate=degenerate,
    )
