"""Pure-jnp bit-exact oracle for the Metropolis Pallas kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import hash_bits, hash_uniform


@functools.partial(jax.jit, static_argnames=("num_iters",))
def metropolis_ref(
    weights: jnp.ndarray,
    seed: jnp.ndarray,
    *,
    num_iters: int,
) -> jnp.ndarray:
    n = weights.shape[0]
    i = jnp.arange(n, dtype=jnp.int32)
    seed = jnp.asarray(seed).reshape(-1)[0]

    def body(b, state):
        k, wk = state
        j = (hash_bits(seed, i, b) % jnp.uint32(n)).astype(jnp.int32)
        w_j = weights[j]
        u = hash_uniform(seed, i + n, b, dtype=weights.dtype)
        accept = u * wk <= w_j
        return jnp.where(accept, j, k), jnp.where(accept, w_j, wk)

    k, _ = jax.lax.fori_loop(0, num_iters, body, (i, weights))
    return k
