"""Feed-forward variants: SwiGLU (llama/qwen/dbrx), GeGLU (gemma),
squared-ReLU (nemotron), GELU (musicgen/chameleon-style)."""

from __future__ import annotations

import jax

from repro.models.layers import init_linear, linear

GATED = {"swiglu", "geglu"}


def init_mlp(key, d_model: int, d_ff: int, mlp_type: str):
    ks = jax.random.split(key, 3)
    p = {
        "w_in": init_linear(ks[0], d_model, d_ff),
        "w_out": init_linear(ks[1], d_ff, d_model),
    }
    if mlp_type in GATED:
        p["w_gate"] = init_linear(ks[2], d_model, d_ff)
    return p


def mlp(p, x, mlp_type: str):
    h = linear(p["w_in"], x, x.dtype)
    if mlp_type == "swiglu":
        h = jax.nn.silu(linear(p["w_gate"], x, x.dtype)) * h
    elif mlp_type == "geglu":
        h = jax.nn.gelu(linear(p["w_gate"], x, x.dtype)) * h
    elif mlp_type == "squared_relu":
        r = jax.nn.relu(h)
        h = r * r
    elif mlp_type == "gelu":
        h = jax.nn.gelu(h)
    else:
        raise ValueError(f"unknown mlp_type {mlp_type}")
    return linear(p["w_out"], h, x.dtype)
