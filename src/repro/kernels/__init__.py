"""Pallas TPU kernels for the paper's compute hot spots.

  megopolis/   — the paper's contribution with tile-coalesced access
  metropolis/  — Algs. 2-4: the random-access strawman (VMEM-resident)
                 plus the Dülger C1/C2 tile-partition variants
  rejection/   — Murray's unbiased baseline (VMEM-resident, masked loop)
  prefix_sum/  — sequential-grid block scan + coalesced binary search,
                 composed into the five prefix-sum resampler kinds

Each package ships ``ops.py`` (jit'd public wrapper) and ``ref.py``
(pure-jnp oracle, bit-exact vs the kernel in interpret mode — the parity
surface ``tests/test_backend_parity.py`` pins).
"""

from repro.kernels.megopolis.ops import (  # noqa: F401
    megopolis_tpu,
    megopolis_tpu_apply,
    megopolis_tpu_apply_batch,
    megopolis_tpu_apply_rows,
    megopolis_tpu_batch,
)
from repro.kernels.metropolis.ops import (  # noqa: F401
    metropolis_c1_tpu,
    metropolis_c1_tpu_apply,
    metropolis_c2_tpu,
    metropolis_c2_tpu_apply,
    metropolis_tpu,
    metropolis_tpu_apply,
    metropolis_tpu_apply_batch,
    metropolis_tpu_apply_rows,
    metropolis_tpu_batch,
)
from repro.kernels.prefix_sum.ops import (  # noqa: F401
    prefix_resample_tpu,
    prefix_resample_tpu_apply,
    prefix_sum_tpu,
    searchsorted_tpu,
)
from repro.kernels.rejection.ops import (  # noqa: F401
    rejection_tpu,
    rejection_tpu_apply,
    rejection_tpu_apply_batch,
    rejection_tpu_apply_rows,
    rejection_tpu_batch,
)
