"""Bench-trajectory regression gate (DESIGN.md §15, EXPERIMENTS.md §Trajectory).

    python benchmarks/trajectory.py OLD.json NEW.json [--threshold 0.25]
                                    [--advisory-wall] [--suite NAME]

Diffs two ``BENCH_<date>.json`` trajectory snapshots (the files
``benchmarks.run --json DIR`` accretes) and prints a per-suite delta
report.  Two kinds of change are graded differently:

  * **hard fields** — ``parity`` / ``identical_program`` / ``perf_gated``
    flipping to False, any ``analysis`` cell going not-ok, or a launch
    count INCREASING — always fail the gate (exit 1): these are counted
    contracts, not measurements, so there is no noise to tolerate;
  * **wall-times** — per-suite wall seconds and per-cell ms regress the
    gate only beyond ``--threshold`` (fractional; 0.25 = +25%), and
    ``--advisory-wall`` demotes even those to warnings — CPU CI boxes are
    noisy, and a wall-time on the wrong hardware should inform, not block.

Suites or cells present in only one snapshot are listed, never failed:
trajectories legitimately grow suites over time and smoke runs cover a
subset.  ``NEW`` may also be a raw per-suite payload (a
``benchmarks/out/BENCH_<suite>.json`` with ``rows``, e.g. from
``step_bench --smoke``) — pass ``--suite`` or let the filename pick the
section; this is how the perf-smoke lane compares a fresh smoke run
against the latest checked-in snapshot.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

#: section -> (cell-list key path, identity fields, hard bool fields,
#:             launch-count fields, wall-time fields)
SECTIONS = {
    "step": ("cells", ("family", "backend", "plane_dtype"),
             ("parity", "identical_program", "perf_gated"),
             ("launches_step", "launches_composed"),
             ("step_ms", "composed_ms")),
    "fused_gather": ("cells", ("family", "backend", "state_dim", "plane_dtype"),
                     ("parity", "identical_program", "perf_gated"),
                     (), ("fused_ms", "unfused_ms")),
    "ais": ("logz", ("resampler", "backend", "target"), (), (),
            ("wall_per_run_s",)),
    "analysis": ("cells", ("family", "backend", "entry"), ("ok",),
                 ("launches",), ()),
}


def _load(path: str, suite: str | None):
    """Read a snapshot; wrap a raw per-suite payload (``rows``) into
    trajectory shape so both sides diff identically."""
    with open(path) as f:
        payload = json.load(f)
    if "rows" in payload and "suite_wall_s" not in payload:
        if suite is None:
            stem = os.path.basename(path)
            for name in SECTIONS:
                if stem == f"BENCH_{name}.json":
                    suite = name
                    break
        if suite is None:
            raise SystemExit(
                f"trajectory: {path} is a raw suite payload; pass --suite "
                f"to name its section (choices: {sorted(SECTIONS)})"
            )
        key = SECTIONS[suite][0]
        payload = {"suite_wall_s": {}, suite: {key: payload["rows"]}}
    return payload


def _cells(payload: dict, section: str):
    spec = SECTIONS[section]
    sec = payload.get(section)
    if not isinstance(sec, dict):
        return {}
    out = {}
    for row in sec.get(spec[0]) or []:
        ident = tuple(row.get(f, "float32" if f == "plane_dtype" else None)
                      for f in spec[1])
        out[ident] = row
    return out


def _fmt_cell(section: str, ident) -> str:
    return f"{section}/" + "/".join(str(v) for v in ident)


def diff(old: dict, new: dict, threshold: float):
    """Returns (report lines, hard regressions, wall regressions)."""
    lines, hard, wall = [], [], []

    def wall_delta(what, o, n):
        if o is None or n is None or o <= 0:
            return
        pct = (n - o) / o * 100.0
        mark = ""
        if n > o * (1.0 + threshold):
            mark = "  << regression"
            wall.append(f"{what}: {o:.3g} -> {n:.3g} (+{pct:.1f}%)")
        lines.append(f"  {what}: {o:.3g} -> {n:.3g} ({pct:+.1f}%){mark}")

    ow, nw = old.get("suite_wall_s", {}), new.get("suite_wall_s", {})
    shared = [s for s in ow if s in nw]
    if shared:
        lines.append("suite wall-times (s):")
        for s in shared:
            wall_delta(s, ow[s], nw[s])
    for label, only in (("old", sorted(set(ow) - set(nw))),
                        ("new", sorted(set(nw) - set(ow)))):
        if only:
            lines.append(f"  suites only in {label}: {', '.join(only)}")

    for section, spec in SECTIONS.items():
        oc, nc = _cells(old, section), _cells(new, section)
        both = [k for k in oc if k in nc]
        if not (oc or nc):
            continue
        lines.append(f"{section}: {len(both)} shared cell(s), "
                     f"{len(oc) - len(both)} only-old, "
                     f"{len(nc) - len(both)} only-new")
        for ident in both:
            o, n = oc[ident], nc[ident]
            name = _fmt_cell(section, ident)
            for f in spec[2]:  # hard booleans: True -> not-True fails
                if o.get(f) is True and n.get(f) is not True:
                    msg = f"{name}: {f} regressed {o.get(f)} -> {n.get(f)}"
                    hard.append(msg)
                    lines.append(f"  {msg}  << HARD")
            for f in spec[3]:  # launch counts: any increase fails
                if (isinstance(o.get(f), int) and isinstance(n.get(f), int)
                        and n[f] > o[f]):
                    msg = f"{name}: {f} grew {o[f]} -> {n[f]}"
                    hard.append(msg)
                    lines.append(f"  {msg}  << HARD")
            for f in spec[4]:  # wall-times: thresholded
                wall_delta(f"{name}.{f}", o.get(f), n.get(f))

    o_ok = (old.get("analysis") or {}).get("ok")
    n_ok = (new.get("analysis") or {}).get("ok")
    if o_ok is True and n_ok is False:
        msg = "analysis.ok regressed True -> False"
        hard.append(msg)
        lines.append(f"  {msg}  << HARD")

    for side, payload in (("old", old), ("new", new)):
        prov = payload.get("provenance")
        if prov:
            lines.append(
                f"{side}: {payload.get('date', '?')} git {prov.get('git_sha')}"
                f" jax {prov.get('jax_version')} on {prov.get('device_kind')}"
                f" ({prov.get('platform')})"
            )
    return lines, hard, wall


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python benchmarks/trajectory.py",
        description="Diff two BENCH_<date>.json snapshots; non-zero exit "
                    "on regression.",
    )
    ap.add_argument("old", help="baseline snapshot (e.g. BENCH_2026-07-31.json)")
    ap.add_argument("new", help="candidate snapshot, or a raw BENCH_<suite>.json")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="fractional wall-time slack before a regression "
                         "(default 0.25 = +25%%)")
    ap.add_argument("--advisory-wall", action="store_true",
                    help="report wall-time regressions without failing "
                         "(for noisy CPU CI boxes)")
    ap.add_argument("--suite", default=None, choices=sorted(SECTIONS),
                    help="section name when NEW is a raw per-suite payload")
    args = ap.parse_args(argv)

    old = _load(args.old, args.suite)
    new = _load(args.new, args.suite)
    lines, hard, wall = diff(old, new, args.threshold)
    print(f"trajectory: {args.old} -> {args.new}")
    for ln in lines:
        print(ln)

    rc = 0
    if wall:
        verdict = "advisory" if args.advisory_wall else "FAIL"
        print(f"\nwall-time regressions beyond +{args.threshold:.0%} "
              f"({verdict}):")
        for w in wall:
            print(f"  {w}")
        if not args.advisory_wall:
            rc = 1
    if hard:
        print("\nHARD regressions (counted contracts, no noise tolerance):")
        for h in hard:
            print(f"  {h}")
        rc = 1
    print("\n" + ("REGRESSED" if rc else "OK"))
    return rc


if __name__ == "__main__":
    sys.exit(main())
