"""Integration test of launch/steps.py (TP+FSDP plans) on 8 virtual
devices — subprocess, same pattern as test_distributed.py."""

import os
import pathlib
import subprocess
import sys

import pytest

_PROG = pathlib.Path(__file__).parent / "_steps_prog.py"
_SRC = str(pathlib.Path(__file__).parents[1] / "src")


@pytest.mark.slow
def test_steps_plans_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, str(_PROG)], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr[-3000:]}"
    for name in ("train_step_finite", "params_updated", "decode_step", "prefill_step"):
        assert f"OK {name}" in out.stdout, out.stdout
    assert "ALL_OK" in out.stdout
