"""Unified decoder LM covering all 10 assigned architectures.

One ``ModelConfig`` + a per-layer ``layer_pattern`` of block kinds:

  * ``attn``        — global GQA attention block (+ MLP or MoE)
  * ``swa``         — sliding-window attention block (+ MLP or MoE)
  * ``mamba``       — Mamba2/SSD mixer block (no MLP; the SSM is the mixer)
  * ``shared_attn`` — Zamba2-style block whose attention+MLP params are
                      SHARED across all such layers (stored once)

Pre-norm residual wiring throughout.  Layers run as an unrolled python loop
(cost_analysis honesty, DESIGN.md §6.4) with optional per-layer remat for
training.  ``param_pspecs`` emits the Megatron-style TP sharding tree used
by the dry-run and launchers.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import keystr_simple
from repro.models.attention import attention, decode_attention, init_attention
from repro.models.layers import embed, init_embedding, init_linear, init_rmsnorm, linear, rmsnorm
from repro.models.mamba2 import (
    init_mamba,
    init_mamba_cache,
    mamba_block,
    mamba_decode_step,
)
from repro.models.mlp import GATED, init_mlp, mlp
from repro.models.moe import init_moe, moe
from repro.models.partitioning import logical


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    mlp_type: str = "swiglu"
    qk_norm: bool = False
    layer_pattern: Tuple[str, ...] = ("attn",)  # cycled over num_layers
    window: int = 0  # sliding window for "swa" layers
    # MoE (applies to attn/swa layers when num_experts > 0)
    num_experts: int = 0
    top_k: int = 0
    num_shared_experts: int = 0
    moe_layer_period: int = 1  # MoE every k-th layer (llama4: 2); dense between
    d_ff_dense: int = 0  # FFN width of the NON-MoE layers (0 -> d_ff)
    # SSM (mamba layers)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    # misc
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    dtype: Any = jnp.bfloat16
    # decode KV/conv cache storage dtype (None -> dtype).  fp8 (e4m3) halves
    # the decode memory-roofline term; K/V magnitudes are O(1) post-norm so
    # no scale bookkeeping is needed (§Perf hillclimb option).
    cache_dtype: Any = None
    remat: bool = True
    loss_chunk: int = 1024
    q_chunk: int = 4096
    embeds_input: bool = False  # modality-frontend stub (musicgen)
    long_context_ok: bool = False  # eligible for long_500k (sub-quadratic)

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        pat = self.layer_pattern
        return tuple(pat[i % len(pat)] for i in range(self.num_layers))

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    def is_moe_layer(self, i: int) -> bool:
        """Interleaved MoE: layer ``i`` routes through experts when the MoE
        period hits (llama4-style alternation); period 1 = every layer."""
        return self.is_moe and (i % self.moe_layer_period == self.moe_layer_period - 1)

    @property
    def ff_dense(self) -> int:
        return self.d_ff_dense or self.d_ff

    def num_params(self) -> int:
        """Total parameter count (used for MODEL_FLOPS = 6*N*D)."""
        return sum(int(x.size) for x in jax.tree.leaves(_shapes_only(self)))

    def num_active_params(self) -> int:
        """Active params per token (MoE: top_k of num_experts + shared)."""
        if not self.is_moe:
            return self.num_params()
        total = 0
        for leaf_path, x in _named_shapes(self):
            if "/w1/" in leaf_path or "/w2/" in leaf_path or "/w3/" in leaf_path:
                total += int(x.size * self.top_k / self.num_experts)
            else:
                total += int(x.size)
        return total


def _shapes_only(cfg: ModelConfig):
    return jax.eval_shape(lambda k: init_params(k, cfg), jax.random.PRNGKey(0))


def _named_shapes(cfg: ModelConfig):
    shapes = _shapes_only(cfg)
    flat, _ = jax.tree_util.tree_flatten_with_path(shapes)
    for path, leaf in flat:
        yield keystr_simple(path), leaf


# --------------------------------------------------------------------- init
def _init_block(key, cfg: ModelConfig, kind: str, layer_idx: int = -1):
    if kind == "mamba":
        return {"norm": init_rmsnorm(cfg.d_model), "mamba": init_mamba(key, cfg)}
    k1, k2 = jax.random.split(key)
    p = {
        "norm1": init_rmsnorm(cfg.d_model),
        "attn": init_attention(k1, cfg),
        "norm2": init_rmsnorm(cfg.d_model),
    }
    if layer_idx >= 0 and cfg.is_moe_layer(layer_idx):
        p["moe"] = init_moe(k2, cfg)
    else:
        p["mlp"] = init_mlp(k2, cfg.d_model, cfg.ff_dense, cfg.mlp_type)
    return p


def init_params(key, cfg: ModelConfig):
    keys = jax.random.split(key, cfg.num_layers + 4)
    params: dict = {"final_norm": init_rmsnorm(cfg.d_model)}
    if not cfg.embeds_input:
        params["embed"] = init_embedding(keys[-1], cfg.vocab_size, cfg.d_model)
    params["lm_head"] = init_linear(keys[-2], cfg.d_model, cfg.vocab_size)
    kinds = cfg.layer_kinds
    layers = []
    for i, kind in enumerate(kinds):
        if kind == "shared_attn":
            layers.append({})  # params live in params["shared"]
        else:
            layers.append(_init_block(keys[i], cfg, kind, i))
    params["layers"] = layers
    if "shared_attn" in kinds:
        params["shared"] = _init_block(keys[-3], cfg, "attn")
    return params


# ------------------------------------------------------------------ forward
def _block_forward(p, cfg: ModelConfig, kind: str, x, positions):
    if kind == "mamba":
        h, _ = mamba_block(p["mamba"], cfg, rmsnorm(p["norm"], x, cfg.norm_eps),
                           chunk=cfg.ssm_chunk)
        return x + h
    window = cfg.window if kind == "swa" else 0
    a, _ = attention(p["attn"], cfg, rmsnorm(p["norm1"], x, cfg.norm_eps), positions,
                     window=window, q_chunk=cfg.q_chunk)
    x = x + a
    h = rmsnorm(p["norm2"], x, cfg.norm_eps)
    if "moe" in p:
        return x + moe(p["moe"], cfg, h)
    return x + mlp(p["mlp"], h, cfg.mlp_type)


def forward(params, cfg: ModelConfig, inputs, positions=None):
    """Trunk + final norm.  ``inputs``: int tokens (B,S) or embeds (B,S,D).
    Returns hidden states (B,S,D) in cfg.dtype."""
    if cfg.embeds_input:
        x = inputs.astype(cfg.dtype)
    else:
        x = embed(params["embed"], inputs, cfg.dtype)
    x = logical(x, "batch", "seq", "embed")
    b, s = x.shape[0], x.shape[1]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    kinds = cfg.layer_kinds
    for i, kind in enumerate(kinds):
        p = params["shared"] if kind == "shared_attn" else params["layers"][i]

        def run(p_, x_):
            return logical(_block_forward(p_, cfg, kind, x_, positions),
                           "batch", "seq", "embed")

        if cfg.remat:
            run = jax.checkpoint(run)
        x = run(p, x)
    return rmsnorm(params["final_norm"], x, cfg.norm_eps)


def logits_fn(params, cfg: ModelConfig, h):
    return linear(params["lm_head"], h, cfg.dtype)


def loss_fn(params, cfg: ModelConfig, batch):
    """Chunked-vocab cross entropy.  batch: {"inputs", "targets"(B,S)}.
    Sequence-chunked so the (chunk, V) logits temp stays bounded."""
    h = forward(params, cfg, batch["inputs"])
    b, s, _ = h.shape
    targets = batch["targets"]
    chunk = min(cfg.loss_chunk, s)
    n_chunks = (s + chunk - 1) // chunk
    total = jnp.zeros((), jnp.float32)
    count = jnp.zeros((), jnp.float32)
    for ci in range(n_chunks):
        lo, hi = ci * chunk, min(s, (ci + 1) * chunk)
        logits = logits_fn(params, cfg, h[:, lo:hi]).astype(jnp.float32)
        logits = logical(logits, "batch", "seq", "vocab")
        tgt = targets[:, lo:hi]
        lse = jax.nn.logsumexp(logits, axis=-1)
        true = jnp.take_along_axis(logits, tgt[..., None], axis=-1)[..., 0]
        mask = (tgt >= 0).astype(jnp.float32)
        total = total + jnp.sum((lse - true) * mask)
        count = count + jnp.sum(mask)
    return total / jnp.maximum(count, 1.0)


# ------------------------------------------------------------------ serving
def init_cache(cfg: ModelConfig, batch: int, max_seq: int):
    """Per-layer decode caches.  Window layers get O(window) rings."""
    caches = []
    hd = cfg.head_dim
    cdt = cfg.cache_dtype or cfg.dtype
    for kind in cfg.layer_kinds:
        if kind == "mamba":
            # SSM/conv states stay in compute dtype (recurrence precision)
            caches.append(init_mamba_cache(cfg, batch, cfg.dtype))
        else:
            ring = max_seq if (kind != "swa" or cfg.window == 0) else min(max_seq, cfg.window)
            kv = (
                jnp.zeros((batch, ring, cfg.num_kv_heads, hd), cdt),
                jnp.zeros((batch, ring, cfg.num_kv_heads, hd), cdt),
            )
            caches.append({"kv": kv})
    return caches


def prefill(params, cfg: ModelConfig, inputs, max_seq: int):
    """Full-sequence forward that also populates decode caches.
    Returns (logits_last (B,V), caches)."""
    if cfg.embeds_input:
        x = inputs.astype(cfg.dtype)
    else:
        x = embed(params["embed"], inputs, cfg.dtype)
    x = logical(x, "batch", "seq", "embed")
    b, s = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    caches = []
    for i, kind in enumerate(cfg.layer_kinds):
        p = params["shared"] if kind == "shared_attn" else params["layers"][i]
        if kind == "mamba":
            h, cache = mamba_block(p["mamba"], cfg, rmsnorm(p["norm"], x, cfg.norm_eps),
                                   chunk=cfg.ssm_chunk)
            x = x + h
            caches.append(cache)
        else:
            window = cfg.window if kind == "swa" else 0
            a, (k, v) = attention(p["attn"], cfg, rmsnorm(p["norm1"], x, cfg.norm_eps),
                                  positions, window=window, q_chunk=cfg.q_chunk)
            x = x + a
            h2 = rmsnorm(p["norm2"], x, cfg.norm_eps)
            x = x + (moe(p["moe"], cfg, h2) if "moe" in p else mlp(p["mlp"], h2, cfg.mlp_type))
            ring = max_seq if (kind != "swa" or cfg.window == 0) else min(max_seq, cfg.window)
            caches.append({"kv": _ring_from_prefill(k, v, ring, max_seq, cfg)})
    h = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return logits_fn(params, cfg, h[:, -1]), caches


def _ring_from_prefill(k, v, ring: int, max_seq: int, cfg: ModelConfig):
    """Place prefill K/V (positions 0..s-1) into a ring cache of length
    ``ring`` padded out to serve up to ``max_seq`` total positions."""
    b, s = k.shape[0], k.shape[1]
    cdt = cfg.cache_dtype or cfg.dtype
    kc = jnp.zeros((b, ring, cfg.num_kv_heads, cfg.head_dim), cdt)
    vc = jnp.zeros_like(kc)
    take = min(s, ring)
    pos = jnp.arange(s - take, s, dtype=jnp.int32)
    slots = jnp.mod(pos, ring)
    kc = kc.at[:, slots].set(k[:, -take:].astype(cdt))
    vc = vc.at[:, slots].set(v[:, -take:].astype(cdt))
    return kc, vc


def decode_step(params, cfg: ModelConfig, inputs, caches, pos):
    """One decode step.  ``inputs``: int tokens (B,1) or embeds (B,1,D);
    ``pos``: scalar int32 (current position).  Returns (logits (B,V), caches')."""
    if cfg.embeds_input:
        x = inputs.astype(cfg.dtype)
    else:
        x = embed(params["embed"], inputs, cfg.dtype)
    x = logical(x, "batch", "seq", "embed")

    new_caches = []
    for i, kind in enumerate(cfg.layer_kinds):
        p = params["shared"] if kind == "shared_attn" else params["layers"][i]
        if kind == "mamba":
            h, cache = mamba_decode_step(
                p["mamba"], cfg, rmsnorm(p["norm"], x, cfg.norm_eps), caches[i]
            )
            x = x + h
            new_caches.append(cache)
        else:
            window = cfg.window if kind == "swa" else 0
            a, kv = decode_attention(p["attn"], cfg, rmsnorm(p["norm1"], x, cfg.norm_eps),
                                     caches[i]["kv"], pos, window=window)
            x = x + a
            h2 = rmsnorm(p["norm2"], x, cfg.norm_eps)
            x = x + (moe(p["moe"], cfg, h2) if "moe" in p else mlp(p["mlp"], h2, cfg.mlp_type))
            new_caches.append({"kv": kv})
    h = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return logits_fn(params, cfg, h[:, -1]), new_caches


# ----------------------------------------------------------------- sharding
def _block_pspecs(cfg: ModelConfig, kind: str, layer_idx: int = -1):
    if kind == "mamba":
        return {
            "norm": {"scale": P()},
            "mamba": {
                "in_z": {"w": P(None, "model")},
                "in_x": {"w": P(None, "model")},
                "in_b": {"w": P(None, None)},
                "in_c": {"w": P(None, None)},
                "in_dt": {"w": P(None, "model")},
                "conv_x": {"w": P(None, "model"), "b": P("model")},
                "conv_b": {"w": P(None, None), "b": P()},
                "conv_c": {"w": P(None, None), "b": P()},
                "a_log": P("model"),
                "d_skip": P("model"),
                "dt_bias": P("model"),
                "norm": {"scale": P("model")},
                "out_proj": {"w": P("model", None)},
            },
        }
    attn = {
        "wq": {"w": P(None, "model")},
        "wk": {"w": P(None, "model")},
        "wv": {"w": P(None, "model")},
        "wo": {"w": P("model", None)},
    }
    if cfg.qk_norm:
        attn["q_norm"] = {"scale": P()}
        attn["k_norm"] = {"scale": P()}
    p = {"norm1": {"scale": P()}, "attn": attn, "norm2": {"scale": P()}}
    if layer_idx >= 0 and cfg.is_moe_layer(layer_idx):
        m = {
            "router": {"w": P(None, None)},
            "w1": {"w": P("model", None, None)},
            "w3": {"w": P("model", None, None)},
            "w2": {"w": P("model", None, None)},
        }
        if cfg.num_shared_experts:
            m["shared"] = _mlp_pspecs(cfg)
        p["moe"] = m
    else:
        p["mlp"] = _mlp_pspecs(cfg)
    return p


def _mlp_pspecs(cfg: ModelConfig):
    p = {"w_in": {"w": P(None, "model")}, "w_out": {"w": P("model", None)}}
    if cfg.mlp_type in GATED:
        p["w_gate"] = {"w": P(None, "model")}
    return p


def param_pspecs(cfg: ModelConfig):
    """PartitionSpec tree matching ``init_params`` (Megatron-style TP)."""
    specs: dict = {"final_norm": {"scale": P()}}
    if not cfg.embeds_input:
        specs["embed"] = {"table": P("model", None)}
    specs["lm_head"] = {"w": P(None, "model")}
    kinds = cfg.layer_kinds
    specs["layers"] = [
        ({} if kind == "shared_attn" else _block_pspecs(cfg, kind, i))
        for i, kind in enumerate(kinds)
    ]
    if "shared_attn" in kinds:
        specs["shared"] = _block_pspecs(cfg, "attn")
    return specs


def cache_pspecs(cfg: ModelConfig, *, batch_axis, seq_axis=None, model_axis_size: int = 16):
    """PartitionSpec tree matching ``init_cache``.

    ``batch_axis``: mesh axis (or tuple) for the batch dim — decode_32k.
    ``seq_axis``: mesh axis for the KV sequence dim — long_500k (batch=1).
    KV shards over 'model' on the heads axis when divisible (Zamba2's 32 kv
    heads), else on head_dim (GQA archs with 8 kv heads < 16-way TP).
    """
    if cfg.num_kv_heads % model_axis_size == 0:
        kv_spec = P(batch_axis, seq_axis, "model", None)
    elif cfg.head_dim % model_axis_size == 0:
        kv_spec = P(batch_axis, seq_axis, None, "model")
    else:  # e.g. danube: kv=8, head_dim=120 — neither 16-divisible
        kv_spec = P(batch_axis, seq_axis, None, None)
    specs = []
    for kind in cfg.layer_kinds:
        if kind == "mamba":
            specs.append(
                {
                    "conv_x": P(batch_axis, None, "model"),
                    "conv_b": P(batch_axis, None, None),
                    "conv_c": P(batch_axis, None, None),
                    "ssm": P(batch_axis, "model", None, None),
                }
            )
        else:
            specs.append({"kv": (kv_spec, kv_spec)})
    return specs
