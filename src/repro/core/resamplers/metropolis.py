"""Metropolis resampling and its C1/C2 variants (paper Algorithms 2-4).

These are the paper's baselines.  ``metropolis`` draws a fresh random
comparison index per (particle, iteration) — the random memory access
pattern of Fig. 2.  C1/C2 (Dülger et al.) constrain the index to a
warp-shared random partition of ``partition_size`` weights, the paper's
Fig. 3, trading a tuning parameter + quality for locality.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.resamplers.batched import batch_via_vmap

WARP = 32  # threads per warp in the paper's cost model.


def metropolis(key: jax.Array, weights: jnp.ndarray, num_iters: int) -> jnp.ndarray:
    """Paper Algorithm 2; returns int32 ancestors."""
    n = weights.shape[0]
    i = jnp.arange(n, dtype=jnp.int32)

    def body(b, k):
        kb = jax.random.fold_in(key, b)
        kj, ku = jax.random.split(kb)
        j = jax.random.randint(kj, (n,), 0, n, dtype=jnp.int32)
        u = jax.random.uniform(ku, (n,), weights.dtype)
        accept = u * weights[k] <= weights[j]
        return jnp.where(accept, j, k)

    return jax.lax.fori_loop(0, num_iters, body, i)


# Batched entry points (DESIGN.md §4): per-(row, particle, iteration)
# randomness is already counter-based, so vmap is bit-exact and fuses the
# whole bank's accept/reject loop into one launch.
metropolis_batch = batch_via_vmap(metropolis)


def _partition_geometry(n: int, partition_size_bytes: int, dtype_bytes: int = 4):
    """Paper's N_part / N_w (Algs. 3-4 lines 1-2)."""
    n_w = max(1, partition_size_bytes // dtype_bytes)  # weights per partition
    n_part = max(1, (n * dtype_bytes) // partition_size_bytes)
    return n_part, n_w


def metropolis_c1(
    key: jax.Array,
    weights: jnp.ndarray,
    num_iters: int,
    *,
    partition_size_bytes: int = 128,
    warp: int = WARP,
) -> jnp.ndarray:
    """Paper Algorithm 3: one shared partition per warp for ALL iterations."""
    n = weights.shape[0]
    n_part, n_w = _partition_geometry(n, partition_size_bytes)
    i = jnp.arange(n, dtype=jnp.int32)
    i_warp = i // warp
    n_warps = (n + warp - 1) // warp
    kp, kloop = jax.random.split(key)
    # line 6: p ~ U{0, N_part-1} shared by the warp, chosen once.
    p_warp = jax.random.randint(kp, (n_warps,), 0, n_part, dtype=jnp.int32)
    p = p_warp[i_warp]

    def body(b, k):
        kb = jax.random.fold_in(kloop, b)
        kj, ku = jax.random.split(kb)
        j = p * n_w + jax.random.randint(kj, (n,), 0, n_w, dtype=jnp.int32)
        j = jnp.minimum(j, n - 1)  # guard the ragged tail partition
        u = jax.random.uniform(ku, (n,), weights.dtype)
        accept = u * weights[k] <= weights[j]
        return jnp.where(accept, j, k)

    return jax.lax.fori_loop(0, num_iters, body, i)


def metropolis_c2(
    key: jax.Array,
    weights: jnp.ndarray,
    num_iters: int,
    *,
    partition_size_bytes: int = 128,
    warp: int = WARP,
) -> jnp.ndarray:
    """Paper Algorithm 4: a fresh warp-shared partition EVERY iteration."""
    n = weights.shape[0]
    n_part, n_w = _partition_geometry(n, partition_size_bytes)
    i = jnp.arange(n, dtype=jnp.int32)
    i_warp = i // warp
    n_warps = (n + warp - 1) // warp

    def body(b, k):
        kb = jax.random.fold_in(key, b)
        kp, kj, ku = jax.random.split(kb, 3)
        p_warp = jax.random.randint(kp, (n_warps,), 0, n_part, dtype=jnp.int32)
        p = p_warp[i_warp]
        j = p * n_w + jax.random.randint(kj, (n,), 0, n_w, dtype=jnp.int32)
        j = jnp.minimum(j, n - 1)
        u = jax.random.uniform(ku, (n,), weights.dtype)
        accept = u * weights[k] <= weights[j]
        return jnp.where(accept, j, k)

    return jax.lax.fori_loop(0, num_iters, body, i)


metropolis_c1_batch = batch_via_vmap(metropolis_c1)
metropolis_c2_batch = batch_via_vmap(metropolis_c2)
