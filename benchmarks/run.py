"""Benchmark orchestrator: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--skip NAME ...] [--json PATH]

CI scale by default (~minutes on CPU); ``--full`` restores paper sizes.
``--json PATH`` writes the per-suite wall-times (plus the ais suite's logZ
quality stats) to a machine-readable trajectory file — accrete one
``BENCH_<date>.json`` per run into the perf history (EXPERIMENTS.md §Perf;
a second run the same day gets ``-2``, ``-3``, … rather than clobbering
the first).  Every snapshot is stamped with provenance (git SHA, jax/
jaxlib versions, device kind/platform) so ``benchmarks/trajectory.py``
can attribute a delta to a code or toolchain change, and the run streams
``suite_start``/``suite_end``/``run_end`` events to the JSONL flight
recorder at ``out/events.jsonl`` (DESIGN.md §15).  The dry-run / roofline
pipeline is separate (launch/dryrun.py) because it re-initialises jax
with 512 virtual devices.
"""

from __future__ import annotations

import argparse
import difflib
import json
import os
import subprocess
import sys
import time
from datetime import date

SUITES = [
    ("transactions", "benchmarks.transactions_bench", []),
    ("kernel", "benchmarks.kernel_bench", []),
    ("fig6", "benchmarks.fig6_quality_speed", []),
    ("fig7", "benchmarks.fig7_partition_sweep", []),
    ("fig8", "benchmarks.fig8_prefix_sum", []),
    ("fig10", "benchmarks.fig10_gamma", []),
    ("table2", "benchmarks.table2_e2e_pf", []),
    ("filter_bank", "benchmarks.filter_bank_bench", ["--quick"]),
    ("ais", "benchmarks.ais_bench", ["--quick"]),
    ("smc", "benchmarks.smc_decode_bench", ["--particles", "32", "--new-tokens", "8",
                                            "--archs", "qwen3-0.6b"]),
    ("fused_gather", "benchmarks.fused_gather_bench", ["--quick"]),
    ("step", "benchmarks.step_bench", ["--quick"]),
    ("analysis", "benchmarks.analysis_bench", []),
    ("resilience", "benchmarks.resilience_bench", ["--quick"]),
]
# Suites whose CLI has no --full flag (or whose scale is pinned above).
_NO_FULL = ("transactions", "kernel", "smc", "filter_bank", "ais",
            "fused_gather", "step", "analysis", "resilience")


def _check_suite_names(names, flag: str):
    """Unknown suite names error with a difflib nearest-match hint (the
    same UX as the spec registry's KeyErrors) instead of being silently
    ignored — a typo in --skip used to run the suite anyway."""
    known = [name for name, _, _ in SUITES]
    for name in names:
        if name not in known:
            hint = difflib.get_close_matches(name, known, n=1)
            did_you_mean = f" — did you mean {hint[0]!r}?" if hint else ""
            raise SystemExit(
                f"benchmarks.run: unknown suite {name!r} in {flag}"
                f"{did_you_mean}; choices: {known}"
            )


def _ais_stats():
    """Fold the ais suite's logZ quality rows into the trajectory JSON
    (written by benchmarks.ais_bench as BENCH_ais.json)."""
    from benchmarks.common import OUT_DIR

    path = os.path.join(OUT_DIR, "BENCH_ais.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        payload = json.load(f)
    return {
        "config": payload.get("config"),
        "logz": [
            {k: r[k] for k in ("resampler", "backend", "target", "logz_bias",
                               "logz_std", "logz_rmse", "wall_per_run_s")}
            for r in payload.get("rows", [])
        ],
    }


def _fused_gather_stats():
    """Fold the fused-vs-unfused suite's rows into the trajectory JSON
    (written by benchmarks.fused_gather_bench as BENCH_fused_gather.json)."""
    from benchmarks.common import OUT_DIR

    path = os.path.join(OUT_DIR, "BENCH_fused_gather.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        payload = json.load(f)
    return {
        "config": payload.get("config"),
        "cells": [
            {k: r.get(k, "float32" if k == "plane_dtype" else None)
             for k in ("family", "backend", "state_dim", "plane_dtype",
                       "fused_ms", "unfused_ms", "speedup", "model_speedup",
                       "parity", "perf_gated", "identical_program")}
            for r in payload.get("rows", [])
        ],
    }


def _step_stats():
    """Fold the fused-step suite's rows into the trajectory JSON (written
    by benchmarks.step_bench as BENCH_step.json)."""
    from benchmarks.common import OUT_DIR

    path = os.path.join(OUT_DIR, "BENCH_step.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        payload = json.load(f)
    return {
        "config": payload.get("config"),
        "cells": [
            {k: r.get(k, "float32" if k == "plane_dtype" else None)
             for k in ("family", "backend", "plane_dtype", "step_ms",
                       "composed_ms", "speedup", "launches_step",
                       "launches_composed", "parity", "perf_gated",
                       "identical_program")}
            for r in payload.get("rows", [])
        ],
    }


def _analysis_stats():
    """Fold the static contract audit — launch counts per matrix cell and
    the modelled §2.4 transaction table — into the trajectory JSON
    (written by benchmarks.analysis_bench as BENCH_analysis.json)."""
    from benchmarks.common import OUT_DIR

    path = os.path.join(OUT_DIR, "BENCH_analysis.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        payload = json.load(f)
    return {
        "ok": payload.get("ok"),
        "cells": payload.get("cells"),
        "transactions": payload.get("transactions"),
    }


def _unique_snapshot_path(directory: str) -> str:
    """``BENCH_<date>.json`` inside ``directory``, suffixed ``-2``, ``-3``,
    … when today's snapshot already exists — a same-day re-run must accrete
    a new trajectory point, not overwrite the morning's."""
    stem = f"BENCH_{date.today().isoformat()}"
    path = os.path.join(directory, f"{stem}.json")
    k = 2
    while os.path.exists(path):
        path = os.path.join(directory, f"{stem}-{k}.json")
        k += 1
    return path


def provenance() -> dict:
    """Who/what produced this snapshot: git SHA (``unknown`` outside a
    checkout), jax/jaxlib versions, and the device the suites ran on —
    enough for trajectory.py to attribute a delta."""
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        ).stdout.strip() or "unknown"
    except (OSError, subprocess.SubprocessError):
        sha = "unknown"
    import jax
    import jaxlib

    dev = jax.devices()[0]
    return {
        "git_sha": sha,
        "jax_version": jax.__version__,
        "jaxlib_version": jaxlib.__version__,
        "device_kind": dev.device_kind,
        "platform": dev.platform,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--skip", nargs="*", default=[])
    ap.add_argument("--only", nargs="*", default=[])
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write per-suite wall-times (+ ais logZ stats) to PATH; "
                         "pass a directory to get BENCH_<date>.json inside it")
    args = ap.parse_args(argv)
    _check_suite_names(args.skip, "--skip")
    _check_suite_names(args.only, "--only")

    from benchmarks.common import ensure_out
    from repro.obs.sink import JsonlSink

    sink = JsonlSink(os.path.join(ensure_out(), "events.jsonl"))
    prov = provenance()
    sink.emit("run_start", full=args.full, **prov)

    failures = []
    suite_times = {}
    for name, module, extra in SUITES:
        if name in args.skip or (args.only and name not in args.only):
            continue
        print(f"\n======== {name} ({module}) ========")
        sink.emit("suite_start", suite=name)
        t0 = time.time()
        argv_m = list(extra) + (["--full"] if args.full and name not in _NO_FULL else [])
        try:
            mod = __import__(module, fromlist=["main"])
            mod.main(argv_m)
            suite_times[name] = time.time() - t0
            print(f"[{name}] OK in {suite_times[name]:.1f}s")
        except SystemExit as e:
            if e.code not in (0, None):
                failures.append(name)
            else:
                suite_times[name] = time.time() - t0
        except Exception:
            import traceback
            traceback.print_exc()
            failures.append(name)
        sink.emit(
            "suite_end", suite=name, ok=name not in failures,
            wall_s=round(suite_times.get(name, time.time() - t0), 3),
        )

    if args.json:
        path = args.json
        if os.path.isdir(path):
            path = _unique_snapshot_path(path)
        payload = {
            "date": date.today().isoformat(),
            "full": args.full,
            "provenance": prov,
            "suite_wall_s": {k: round(v, 3) for k, v in suite_times.items()},
            "failures": failures,
        }
        ais = _ais_stats() if "ais" in suite_times else None
        if ais:
            payload["ais"] = ais
        fused = _fused_gather_stats() if "fused_gather" in suite_times else None
        if fused:
            payload["fused_gather"] = fused
        step = _step_stats() if "step" in suite_times else None
        if step:
            payload["step"] = step
        analysis = _analysis_stats() if "analysis" in suite_times else None
        if analysis:
            payload["analysis"] = analysis
        with open(path, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"\nwrote trajectory {path}")
        sink.emit("snapshot_written", path=path)

    sink.emit("run_end", ok=not failures, failures=failures)
    if failures:
        print(f"\nFAILED suites: {failures}")
        sys.exit(1)
    print("\nall benchmark suites passed")


if __name__ == "__main__":
    main()
