from repro.data.synthetic import SyntheticLMStream, batch_specs  # noqa: F401
