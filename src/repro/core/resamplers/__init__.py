"""Resampling algorithms (the paper's Algorithms 2-5, 7, 8 + extras).

Every resampler shares one signature::

    ancestors = resampler(key, weights, **kwargs)   # int32[N]

``ancestors[i]`` is the index of the particle replacing particle ``i``
(the paper's ancestor formulation).  Offspring counts are
``jnp.bincount(ancestors, length=N)``.  Weights need NOT be normalised for
the Metropolis family (only ratios are used) nor for the prefix-sum family
(the running total is used as the upper edge).
"""

from repro.core.resamplers.megopolis import megopolis
from repro.core.resamplers.metropolis import metropolis, metropolis_c1, metropolis_c2
from repro.core.resamplers.prefix_sum import (
    multinomial,
    systematic,
    improved_systematic,
    stratified,
    residual,
)
from repro.core.resamplers.rejection import rejection

_REGISTRY = {
    "megopolis": megopolis,
    "metropolis": metropolis,
    "metropolis_c1": metropolis_c1,
    "metropolis_c2": metropolis_c2,
    "multinomial": multinomial,
    "systematic": systematic,
    "improved_systematic": improved_systematic,
    "stratified": stratified,
    "residual": residual,
    "rejection": rejection,
}


def get_resampler(name: str):
    """Look up a resampler by name; raises KeyError with choices on miss."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown resampler {name!r}; choices: {sorted(_REGISTRY)}") from None


def list_resamplers():
    return sorted(_REGISTRY)
