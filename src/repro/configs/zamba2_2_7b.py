"""Zamba2 2.7B [arXiv:2411.15242] — Mamba2 backbone + SHARED attention block.

54L  d_model=2560  32H (kv=32, head_dim=80)  d_ff=10240  vocab=32000,
ssm_state=64.  Zamba2's signature: one attention+MLP block whose params are
shared by every 6th layer position (``shared_attn`` kind stores params once
in params["shared"]).  SSM + shared-attn -> long_500k runs; the attention
layers ring-cache is bounded by max_seq (they are full attention but few —
KV shards seq over 'data' for the 500k cell).
"""

from repro.configs import ArchSpec
from repro.models import ModelConfig

ARCH = ArchSpec(
    name="zamba2-2.7b",
    family="hybrid",
    source="arXiv:2411.15242",
    model=ModelConfig(
        name="zamba2-2.7b",
        num_layers=54,
        d_model=2560,
        num_heads=32,
        num_kv_heads=32,
        d_ff=10240,
        vocab_size=32000,
        mlp_type="gelu",
        layer_pattern=("mamba", "mamba", "mamba", "mamba", "mamba", "shared_attn"),
        ssm_state=64,
        ssm_expand=2,
        ssm_head_dim=64,
        rope_theta=10_000.0,
        long_context_ok=True,
    ),
    smoke=ModelConfig(
        name="zamba2-smoke",
        num_layers=6,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=256,
        vocab_size=512,
        mlp_type="gelu",
        layer_pattern=("mamba", "mamba", "shared_attn"),
        ssm_state=8,
        ssm_head_dim=16,
        ssm_chunk=4,
        remat=False,
    ),
    microbatches=16,
    notes="Mamba2 + shared attention block (params stored once); "
          "SSM state gather is O(state) at resample time",
)
