"""Jaxpr walking primitives shared by every analysis pass (DESIGN.md §13).

The contract auditor never executes a cell — every pass works on the
traced jaxpr.  This module owns the mechanics all of them share:

  * ``iter_eqns`` — depth-first equation iteration that recurses through
    every higher-order primitive (``scan``/``while``/``cond``/``pjit``/
    ``custom_*``/``pallas_call``) by inspecting eqn params for nested
    jaxprs, so a pass never needs to know the param-name zoo;
  * ``count_pallas_calls`` — the launch counter (the generalisation of the
    walker that used to live privately in ``tests/test_step_fused.py``);
  * ``ancestor_roundtrips`` — a taint/dataflow pass that finds the HBM
    index round-trip the fused data path exists to remove: a ``gather``/
    ``scatter`` whose *index* operand derives from an integer output of a
    ``pallas_call`` (the ancestor vector leaving the chip and coming back
    as XLA gather indices).  Plain shape-indexing gathers with constant
    indices (e.g. ``key_to_seed``'s scalar picks) are NOT flagged — taint
    starts only at kernel outputs.

Higher-order invar mapping is positional and primitive-specific (pjit is
1:1; scan is consts+carry+xs; while is cond_consts+body_consts+carry;
cond is index+operands); loop carries are iterated to a fixpoint, which
terminates because taint only grows and is bounded by the carry width.
"""

from __future__ import annotations

import dataclasses
from collections import Counter
from typing import Iterator, Optional, Union

from jax.extend import core as jex_core

import jax.numpy as jnp

JaxprLike = Union[jex_core.Jaxpr, jex_core.ClosedJaxpr]

#: Primitives that read HBM through an index vector — the round-trip shape.
GATHER_PRIM_PREFIXES = ("gather", "scatter", "take")


def unwrap(jaxpr: JaxprLike) -> jex_core.Jaxpr:
    """Accept either a ``ClosedJaxpr`` (what ``jax.make_jaxpr`` returns) or
    a bare ``Jaxpr`` and hand back the bare one."""
    if isinstance(jaxpr, jex_core.ClosedJaxpr):
        return jaxpr.jaxpr
    return jaxpr


def subjaxprs(eqn) -> Iterator[tuple[str, jex_core.Jaxpr]]:
    """Yield ``(param_name, jaxpr)`` for every nested jaxpr of one eqn."""

    def of_param(name, v):
        if isinstance(v, jex_core.ClosedJaxpr):
            yield name, v.jaxpr
        elif isinstance(v, jex_core.Jaxpr):
            yield name, v
        elif isinstance(v, (tuple, list)):
            for x in v:
                yield from of_param(name, x)

    for name, v in eqn.params.items():
        yield from of_param(name, v)


def iter_eqns(jaxpr: JaxprLike, *, into_kernels: bool = True, _path: str = ""):
    """Depth-first ``(eqn, path)`` iteration through nested jaxprs.

    ``path`` is a human-readable breadcrumb ("scan/pjit") for diagnostics.
    ``into_kernels=False`` stops at ``pallas_call`` boundaries — kernel
    bodies address VMEM, so HBM-level passes must not look inside them.
    """
    for eqn in unwrap(jaxpr).eqns:
        yield eqn, _path
        if eqn.primitive.name == "pallas_call" and not into_kernels:
            continue
        child = f"{_path}/{eqn.primitive.name}" if _path else eqn.primitive.name
        for _, sub in subjaxprs(eqn):
            yield from iter_eqns(sub, into_kernels=into_kernels, _path=child)


def count_primitive(jaxpr: JaxprLike, name: str, *, into_kernels: bool = True) -> int:
    return sum(
        1 for eqn, _ in iter_eqns(jaxpr, into_kernels=into_kernels)
        if eqn.primitive.name == name
    )


def count_pallas_calls(jaxpr: JaxprLike) -> int:
    """Number of kernel launches the traced program performs (statically:
    a launch inside ``scan`` counts once — it is one launch per trace
    site, which is the contract DESIGN.md §12 states)."""
    return count_primitive(jaxpr, "pallas_call")


def pallas_call_eqns(jaxpr: JaxprLike) -> list[tuple]:
    """All ``pallas_call`` eqns with their breadcrumb paths."""
    return [
        (eqn, path) for eqn, path in iter_eqns(jaxpr)
        if eqn.primitive.name == "pallas_call"
    ]


def primitive_census(jaxpr: JaxprLike, *, into_kernels: bool = False) -> Counter:
    """Primitive-name histogram of the traced program (report payload)."""
    return Counter(
        eqn.primitive.name for eqn, _ in iter_eqns(jaxpr, into_kernels=into_kernels)
    )


# --------------------------------------------------------------- taint pass
@dataclasses.dataclass(frozen=True)
class Finding:
    """One diagnostic from a pass; ``code`` is the machine-readable id the
    contract table and the waiver list key on."""

    pass_name: str
    code: str
    where: str
    detail: str

    def as_dict(self):
        return dataclasses.asdict(self)

    def __str__(self):
        where = self.where or "<top>"
        return f"[{self.pass_name}:{self.code}] {where}: {self.detail}"


def _is_int(aval) -> bool:
    dtype = getattr(aval, "dtype", None)
    return dtype is not None and jnp.issubdtype(dtype, jnp.integer)


class _TaintScope:
    """Forward taint propagation through one (possibly nested) jaxpr.

    Taint sources are integer outputs of ``pallas_call`` (ancestor/index
    vectors leaving the kernel).  Propagation is conservative: any eqn with
    a tainted operand taints all its outputs.  Call-like primitives map
    taint positionally into their subjaxprs; loop carries run to fixpoint.
    """

    def __init__(self):
        self.findings: list[Finding] = []

    def run(self, jaxpr: jex_core.Jaxpr, tainted_in: frozenset[int], path: str = ""):
        """Returns the set of tainted *outvar positions* of ``jaxpr``."""
        tainted: set = set()
        for i, v in enumerate(jaxpr.invars):
            if i in tainted_in:
                tainted.add(v)

        def is_tainted(v):
            return (not isinstance(v, jex_core.Literal)) and v in tainted

        for eqn in jaxpr.eqns:
            name = eqn.primitive.name
            child = f"{path}/{name}" if path else name
            if name == "pallas_call":
                for ov in eqn.outvars:
                    if _is_int(ov.aval):
                        tainted.add(ov)
                continue
            if name.startswith(GATHER_PRIM_PREFIXES):
                # operand layout: (data, indices, ...updates) for both
                # gather and scatter variants — indices is invars[1].
                if len(eqn.invars) > 1 and is_tainted(eqn.invars[1]):
                    self.findings.append(
                        Finding(
                            "census",
                            "ancestor-roundtrip",
                            child,
                            f"{name} indexes HBM with indices derived from a "
                            "pallas_call output (ancestor round-trip)",
                        )
                    )
            out_taint = self._call_like(eqn, is_tainted, child)
            if out_taint is None:  # generic propagation
                if any(is_tainted(v) for v in eqn.invars):
                    out_taint = set(range(len(eqn.outvars)))
                else:
                    out_taint = set()
            for i in out_taint:
                tainted.add(eqn.outvars[i])

        return {i for i, v in enumerate(jaxpr.outvars) if is_tainted(v)}

    def _call_like(self, eqn, is_tainted, path) -> Optional[set]:
        """Map taint through a higher-order primitive; returns tainted
        outvar positions, or None if the primitive is not call-like."""
        name = eqn.primitive.name
        params = eqn.params
        in_taint = frozenset(
            i for i, v in enumerate(eqn.invars) if is_tainted(v)
        )

        if name == "scan":
            body = unwrap(params["jaxpr"])
            num_consts = params["num_consts"]
            num_carry = params["num_carry"]
            cur = set(in_taint)
            while True:  # carry feedback fixpoint (taint only grows)
                out = self.run(body, frozenset(cur), path)
                fed = {num_consts + i for i in out if i < num_carry}
                if fed <= cur:
                    break
                cur |= fed
            return out
        if name == "while":
            cond_n = params["cond_nconsts"]
            body_n = params["body_nconsts"]
            body = unwrap(params["body_jaxpr"])
            cond = unwrap(params["cond_jaxpr"])
            carry_in = frozenset(
                i - cond_n - body_n for i in in_taint if i >= cond_n + body_n
            )
            body_in = set(
                i - cond_n for i in in_taint if cond_n <= i < cond_n + body_n
            ) | {body_n + i for i in carry_in}
            while True:
                out = self.run(body, frozenset(body_in), path)
                fed = {body_n + i for i in out}
                if fed <= body_in:
                    break
                body_in |= fed
            cond_in = frozenset(i for i in in_taint if i < cond_n) | frozenset(
                cond_n + i - body_n for i in body_in if i >= body_n
            )
            self.run(cond, cond_in, path)  # findings only; no outvar mapping
            return out
        if name == "cond":
            branches = params["branches"]
            op_taint = frozenset(i - 1 for i in in_taint if i >= 1)
            out = set()
            for br in branches:
                out |= self.run(unwrap(br), op_taint, path)
            return out
        if name == "pjit" or (
            name in ("closed_call", "core_call", "remat2", "checkpoint")
            and "jaxpr" in params
        ):
            # plain 1:1 call: eqn invars/outvars map positionally
            return self.run(unwrap(params["jaxpr"]), in_taint, path)
        if "call_jaxpr" in params:  # custom_jvp_call / custom_vjp_call / xla_call
            return self.run(unwrap(params["call_jaxpr"]), in_taint, path)
        return None


def ancestor_roundtrips(jaxpr: JaxprLike) -> list[Finding]:
    """Findings for every gather/scatter whose indices derive from a
    ``pallas_call`` integer output — the ancestors-through-HBM round-trip
    forbidden on the fused data path (DESIGN.md §11/§12)."""
    scope = _TaintScope()
    inner = unwrap(jaxpr)
    scope.run(inner, frozenset())
    return scope.findings
