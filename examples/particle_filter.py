"""End-to-end SIR particle filter on the univariate nonlinear growth model
(paper §7, eqs. 22-23): tracks a simulated trajectory, reports RMSE and the
Resample Ratio (eq. 25) for Megopolis vs alternatives.

    PYTHONPATH=src python examples/particle_filter.py [--particles 16384]
"""

import argparse

import jax
import numpy as np

from repro.pf.filter import ParticleFilter, run_filter, run_filter_timed, simulate
from repro.pf.metrics import resample_ratio, rmse
from repro.pf.models import ungm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--particles", type=int, default=1 << 14)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--iters", type=int, default=30, help="B (paper §7 baseline)")
    args = ap.parse_args()

    model = ungm()
    key = jax.random.PRNGKey(42)
    k_sim, k_flt = jax.random.split(key)
    truth, obs = simulate(k_sim, model, args.steps)

    print(f"UNGM, {args.particles} particles, {args.steps} steps, B={args.iters}\n")
    print(f"{'resampler':22s} {'RMSE':>8s} {'resample ratio':>15s}")
    for name in ("megopolis", "metropolis", "metropolis_c1", "improved_systematic"):
        kw = () if "metropolis" not in name and name != "megopolis" else ()
        pf = ParticleFilter(model, args.particles, resampler=name,
                            num_iters=args.iters,
                            resampler_kwargs=((("partition_size_bytes", 128),)
                                              if name == "metropolis_c1" else ()))
        ests, times = run_filter_timed(k_flt, pf, obs)
        err = rmse(np.asarray(ests)[None], np.asarray(truth))
        print(f"{name:22s} {err:8.3f} {resample_ratio(times):15.3f}")


if __name__ == "__main__":
    main()
