"""Quickstart: the paper's algorithm in five minutes.

Resamples one degenerate weight population with Megopolis and every
comparison method, reproducing the paper's headline quality ordering, the
eq. (3) iteration selection, and the memory-transaction argument.

Resamplers are configured through the typed spec API (DESIGN.md §9): one
spec object per family, ``spec.build()`` returns the callable, and
``num_iters='auto'`` makes the no-tuning story literal — no per-algorithm
kwargs anywhere.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core import MegopolisSpec, coerce_spec, list_resamplers
from repro.core.iterations import select_iterations
from repro.core.metrics import bias_variance
from repro.core.transactions import index_streams, transactions_per_group
from repro.core.weightgen import gaussian_weights

N = 1 << 14
Y = 3.0  # weight concentration (paper eq. 12); higher = more degenerate
RUNS = 64

key = jax.random.PRNGKey(0)
weights = gaussian_weights(key, N, Y)
iters = int(select_iterations(weights, epsilon=0.01))
print(f"N={N} particles, y={Y} -> B={iters} iterations (paper eq. 3)\n")

print(f"{'resampler':22s} {'MSE/N':>10s} {'bias%':>8s}")
for name in ("megopolis", "metropolis", "metropolis_c1", "metropolis_c2",
             "multinomial", "systematic", "improved_systematic"):
    # One uniform line per family: coerce_spec applies num_iters only where
    # the family has the field (the prefix-sum methods take none).
    resample = coerce_spec(name, num_iters=iters).build()

    @jax.jit
    def one(k, resample=resample):
        return jnp.bincount(resample(k, weights), length=N)

    offs = jax.lax.map(one, jax.random.split(jax.random.fold_in(key, 1), RUNS))
    var, bias_sq, total = bias_variance(offs, weights)
    print(f"{name:22s} {float(total)/N:10.4f} {100*float(bias_sq/total):8.2f}")

# num_iters='auto' routes through eq. (3) at call time: the headline
# "no tuning parameter" claim as API — no B chosen anywhere.
auto = MegopolisSpec().build()
anc_auto = auto(jax.random.fold_in(key, 2), weights)
print(f"\nMegopolisSpec() auto-selected B at call time "
      f"(ancestors[0..5] = {anc_auto[:6].tolist()})")

# Backend dispatch lives in the spec: the same family runs the Pallas TPU
# kernel (interpret mode on CPU) from one field flip.
kernel = MegopolisSpec(num_iters=iters, segment=1024, backend="pallas_interpret").build()
anc = kernel(key, weights[: (N // 1024) * 1024])
print(f"Pallas kernel resampled {anc.shape[0]} particles "
      f"(ancestor[0..5] = {anc[:6].tolist()})")

# the paper's speed argument, counted: transactions per 32-thread warp
for algo in ("megopolis", "metropolis"):
    t = [transactions_per_group(ix).mean()
         for ix in index_streams(algo, 7, N, 4)]
    print(f"{algo:12s}: {sum(t)/len(t):5.2f} memory transactions / warp-iteration")
print(f"\navailable resamplers: {list_resamplers()}")
