"""Jittable MCMC move kernels for the SMC sampler (DESIGN.md §10).

Each kernel rejuvenates N particles IN PARALLEL against a fixed
log-density (the current tempered target π_β): particles are independent
chains, so the whole sweep is one vectorised accept/reject per step — the
same "many independent decisions on the particle axis" shape the
resamplers exploit.  Both return the mean acceptance rate, which the
sampler feeds back into a per-temperature Robbins–Monro step-size
adaptation (``adapt_step_size``).

Signatures match so the sampler dispatches by name::

    x, accept = move(key, x, log_prob, step_size, num_steps)

``step_size`` may be a traced scalar (it is carried and adapted inside the
sampler's ``lax.scan``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# Optimal-scaling acceptance targets (Roberts-Rosenthal asymptotics).
RWM_TARGET_ACCEPT = 0.234
MALA_TARGET_ACCEPT = 0.574


def random_walk_metropolis(key, x, log_prob, step_size, num_steps: int):
    """``num_steps`` RWM sweeps over x[N, d]; returns (x', mean_accept)."""

    def sweep(carry, k):
        x, lp = carry
        k_prop, k_acc = jax.random.split(k)
        prop = x + step_size * jax.random.normal(k_prop, x.shape)
        lp_prop = log_prob(prop)
        log_u = jnp.log(jax.random.uniform(k_acc, lp.shape))
        accept = log_u < lp_prop - lp
        x = jnp.where(accept[:, None], prop, x)
        lp = jnp.where(accept, lp_prop, lp)
        return (x, lp), jnp.mean(accept.astype(jnp.float32))

    keys = jax.random.split(key, num_steps)
    (x, _), accepts = jax.lax.scan(sweep, (x, log_prob(x)), keys)
    return x, jnp.mean(accepts)


def mala(key, x, log_prob, step_size, num_steps: int):
    """Metropolis-adjusted Langevin: gradient-informed proposal + exact MH
    correction.  Particles are independent, so ∇ of the summed log-density
    is the per-particle gradient — one reverse pass for the whole bank."""

    grad = jax.grad(lambda y: jnp.sum(log_prob(y)))

    def log_q(to, frm, g_frm):
        # log N(to; frm + (ε²/2)·∇logπ(frm), ε²·I), per particle
        mean = frm + 0.5 * jnp.square(step_size) * g_frm
        return -0.5 * jnp.sum(jnp.square((to - mean) / step_size), axis=-1)

    def sweep(carry, k):
        x, lp, g = carry
        k_prop, k_acc = jax.random.split(k)
        noise = jax.random.normal(k_prop, x.shape)
        prop = x + 0.5 * jnp.square(step_size) * g + step_size * noise
        lp_prop = log_prob(prop)
        g_prop = grad(prop)
        log_alpha = lp_prop - lp + log_q(x, prop, g_prop) - log_q(prop, x, g)
        log_u = jnp.log(jax.random.uniform(k_acc, lp.shape))
        accept = log_u < log_alpha
        x = jnp.where(accept[:, None], prop, x)
        lp = jnp.where(accept, lp_prop, lp)
        g = jnp.where(accept[:, None], g_prop, g)
        return (x, lp, g), jnp.mean(accept.astype(jnp.float32))

    keys = jax.random.split(key, num_steps)
    (x, _, _), accepts = jax.lax.scan(sweep, (x, log_prob(x), grad(x)), keys)
    return x, jnp.mean(accepts)


MOVES = {"rwm": random_walk_metropolis, "mala": mala}
TARGET_ACCEPT = {"rwm": RWM_TARGET_ACCEPT, "mala": MALA_TARGET_ACCEPT}


def adapt_step_size(step_size, accept, target_accept, rate: float = 0.5,
                    lo: float = 1e-4, hi: float = 1e3):
    """Robbins–Monro-style log-scale update toward the target acceptance:
    ε ← ε·exp(rate·(accept − target)), clipped to [lo, hi]."""
    return jnp.clip(step_size * jnp.exp(rate * (accept - target_accept)), lo, hi)
