import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
os.environ["XLA_FLAGS"] += (" --xla_llvm_disable_expensive_passes=true"
                            " --xla_backend_optimization_level=0")

"""§Perf hillclimb driver: lower+compile VARIANTS of a cell and report the
three roofline terms, so each hypothesis -> change -> measure iteration is
one invocation.

    python -m benchmarks.perf_hillclimb --cell qwen3-0.6b:decode_32k \
        --variant fp8_kv --variant baseline

Variants are config/plan transforms registered below; results append to
experiments/perf_iterations.jsonl.
"""

import argparse
import dataclasses
import json
import time


def _variants():
    import jax.numpy as jnp

    def baseline(arch, plan_kw):
        return arch, plan_kw

    def fp8_kv(arch, plan_kw):
        m = dataclasses.replace(arch.model, cache_dtype=jnp.float8_e4m3fn)
        return dataclasses.replace(arch, model=m), plan_kw

    def micro(n):
        def f(arch, plan_kw):
            return dataclasses.replace(arch, microbatches=n), plan_kw
        f.__name__ = f"micro{n}"
        return f

    def seg(n):  # distributed-resampler segment size (resampler cell only)
        def f(arch, plan_kw):
            plan_kw["segment"] = n
            return arch, plan_kw
        f.__name__ = f"segment{n}"
        return f

    def sched(mode):
        def f(arch, plan_kw):
            plan_kw["schedule"] = mode
            return arch, plan_kw
        f.__name__ = f"sched_{mode}"
        return f

    out = {f.__name__: f for f in (baseline, fp8_kv)}
    for n in (1, 2, 4, 8, 16, 32):
        out[f"micro{n}"] = micro(n)
    for n in (32, 1024, 4096):
        out[f"segment{n}"] = seg(n)
    for m in ("static", "dynamic"):
        out[f"sched_{m}"] = sched(m)
    return out


def run_cell_variant(cell: str, variant: str):
    import jax

    from repro.configs import SHAPES, get_arch
    from repro.launch import hlo
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import make_decode_plan, make_prefill_plan, make_train_plan

    arch_name, shape_name = cell.split(":")
    mesh = make_production_mesh()
    plan_kw = {}
    arch, plan_kw = _variants()[variant](get_arch(arch_name), plan_kw)
    shape = SHAPES[shape_name]
    maker = {"train": make_train_plan, "prefill": make_prefill_plan,
             "decode": make_decode_plan}[shape.kind]
    t0 = time.time()
    plan = maker(arch, shape, mesh)
    compiled = plan.lower().compile()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    roof = hlo.analyze(compiled, chips=mesh.devices.size, trips=plan.microbatches,
                       model_flops=mult * arch.model.num_active_params() * tokens)
    mem = compiled.memory_analysis()
    rec = {
        "cell": cell, "variant": variant, "compile_s": round(time.time() - t0, 1),
        "peak_gib": (mem.argument_size_in_bytes + mem.output_size_in_bytes
                     + mem.temp_size_in_bytes - mem.alias_size_in_bytes) / 2**30,
        **{k: v for k, v in roof.row().items()},
    }
    return rec


def run_resampler_variant(variant: str, *, n_total=16 << 20, num_iters=32):
    """The paper's own technique at chip level: lower the distributed
    Megopolis resample step on the 16x16 mesh and report its terms."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core.distributed import make_distributed_resampler
    from repro.launch import hlo
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh()
    plan_kw = {"segment": 1024, "schedule": "static"}
    _, plan_kw = _variants()[variant](None, plan_kw) if variant != "baseline" else (None, plan_kw)
    fn = make_distributed_resampler(mesh, axis_name="data", num_iters=num_iters,
                                    segment=plan_kw.get("segment", 1024),
                                    schedule=plan_kw.get("schedule", "static"))
    w = jax.ShapeDtypeStruct((n_total,), jnp.float32,
                             sharding=NamedSharding(mesh, P("data")))
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    t0 = time.time()
    import jax.random as jr
    key = jr.PRNGKey(0)  # concrete key (tiny)
    compiled = fn.lower(key, w).compile()
    roof = hlo.analyze(compiled, chips=mesh.devices.size, trips=1,
                       model_flops=float(3 * n_total * num_iters))  # cmp+mul+sel per pair
    rec = {"cell": f"dist_megopolis_N{n_total}_B{num_iters}", "variant": variant,
           "compile_s": round(time.time() - t0, 1), **roof.row()}
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", help="arch:shape, or 'resampler'")
    ap.add_argument("--variant", action="append", default=[])
    ap.add_argument("--out", default="experiments/perf_iterations.jsonl")
    args = ap.parse_args(argv)
    for v in args.variant or ["baseline"]:
        if args.cell == "resampler":
            rec = run_resampler_variant(v)
        else:
            rec = run_cell_variant(args.cell, v)
        print(json.dumps(rec, indent=1))
        os.makedirs(os.path.dirname(args.out), exist_ok=True)
        with open(args.out, "a") as f:
            f.write(json.dumps(rec) + "\n")


if __name__ == "__main__":
    main()
