"""Per-cell contract table + trace-and-audit driver (DESIGN.md §13).

A *cell* is one ``(family, backend, entry)`` triple from
``core.spec.contract_cells``; its contract bundles the declared invariants:

  * ``max_launches`` — ``core.spec.launch_budget`` (0 off the pallas
    backends; the §12 fused step is 1 for EVERY family);
  * ``allow_cond`` — host-level ``lax.cond`` is forbidden everywhere (the
    §12 rule: branching is resolved in-kernel with ``jnp.where``/
    ``pl.when``; kernel-internal predication is not counted);
  * ``allow_tainted_gather`` — the ancestors-through-HBM round-trip is
    forbidden everywhere in the resampler matrix (the §11 rule); only the
    decode consumer, whose mixed-dtype KV cache cannot ride the f32 plane
    stack, waives it (see ``consumers.py``);
  * RNG discipline — always on; deliberate deviations carry explicit
    ``Waiver`` entries with the reason in the report.

Tracing is compute-free (``jax.make_jaxpr``), so the full 320-cell matrix
audits in seconds and a 1M-particle footprint can be priced without
allocating anything.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.analysis import rng, vmem, walker
from repro.analysis.walker import Finding
from repro.core.resamplers.batched import split_batch_keys
from repro.core.spec import (
    ENTRY_POINTS,
    contract_cells,
    launch_budget,
    spec_for_backend,
)

# Audit geometry: two VMEM tiles of particles, a 3-row bank, a 4-component
# state — the same shapes the parity tests pin, kernel-legal on every cell.
AUDIT_N = 2048
AUDIT_BATCH = 3
AUDIT_STATE_DIM = 4
AUDIT_NUM_ITERS = 16
AUDIT_MAX_ITERS = 64
AUDIT_THRESHOLD = 0.5


@dataclasses.dataclass(frozen=True)
class Waiver:
    """An explicitly waived finding: ``code`` + a substring of the detail,
    with the reason recorded in the report."""

    code: str
    match: str
    reason: str

    def covers(self, finding: Finding) -> bool:
        return finding.code == self.code and (
            self.match in finding.detail or self.match in finding.where
        )


@dataclasses.dataclass(frozen=True)
class Contract:
    """Declared invariants for one traced program."""

    max_launches: int
    allow_cond: bool = False
    allow_tainted_gather: bool = False
    waivers: tuple = ()


@dataclasses.dataclass
class CellReport:
    """Audit result for one traced program against its contract."""

    cell: str
    launches: int
    max_launches: int
    cond_count: int
    tainted_gathers: int
    rng_findings: list
    vmem_over: list
    footprints: list
    waived: list
    violations: list

    @property
    def ok(self) -> bool:
        return not self.violations

    def as_dict(self):
        return {
            "cell": self.cell,
            "ok": self.ok,
            "launches": self.launches,
            "max_launches": self.max_launches,
            "cond_count": self.cond_count,
            "tainted_gathers": self.tainted_gathers,
            "rng_findings": [f.as_dict() for f in self.rng_findings],
            "vmem_over": [f.as_dict() for f in self.vmem_over],
            "vmem_bytes": [fp.vmem_bytes for fp in self.footprints],
            "waived": self.waived,
            "violations": self.violations,
        }


def audit_jaxpr(cell: str, jaxpr, contract: Contract) -> CellReport:
    """Run all jaxpr-level passes on one traced program and grade the
    result against its contract."""
    launches = walker.count_pallas_calls(jaxpr)
    cond_count = walker.count_primitive(jaxpr, "cond", into_kernels=False)
    roundtrips = walker.ancestor_roundtrips(jaxpr)
    rng_found = rng.rng_findings(jaxpr)
    footprints = vmem.kernel_footprints(jaxpr)
    vmem_over = vmem.vmem_findings(jaxpr)

    waived, violations = [], []

    def grade(findings):
        kept = []
        for f in findings:
            waiver = next((w for w in contract.waivers if w.covers(f)), None)
            if waiver is not None:
                waived.append({"finding": f.as_dict(), "reason": waiver.reason})
            else:
                kept.append(f)
        return kept

    if launches > contract.max_launches:
        violations.append(
            f"{launches} pallas_call launches exceed the declared budget "
            f"of {contract.max_launches}"
        )
    if cond_count and not contract.allow_cond:
        violations.append(
            f"{cond_count} host-level lax.cond primitive(s) on the resample "
            "path (branching must be jnp.where/pl.when, DESIGN.md §12)"
        )
    roundtrips = grade(roundtrips)
    if roundtrips and not contract.allow_tainted_gather:
        violations.extend(str(f) for f in roundtrips)
    rng_found = grade(rng_found)
    violations.extend(str(f) for f in rng_found)
    vmem_over = grade(vmem_over)
    violations.extend(str(f) for f in vmem_over)

    return CellReport(
        cell=cell,
        launches=launches,
        max_launches=contract.max_launches,
        cond_count=cond_count,
        tainted_gathers=len(roundtrips),
        rng_findings=rng_found,
        vmem_over=vmem_over,
        footprints=footprints,
        waived=waived,
        violations=violations,
    )


# ------------------------------------------------------- matrix cell tracing
def _audit_args(n=AUDIT_N, batch=AUDIT_BATCH, d=AUDIT_STATE_DIM):
    key = jax.random.PRNGKey(0)
    keys = split_batch_keys(key, batch)
    return {
        "key": key,
        "keys": keys,
        "w": jnp.full((n,), 1.0 / n, jnp.float32),
        "wb": jnp.full((batch, n), 1.0 / n, jnp.float32),
        "lw": jnp.zeros((n,), jnp.float32),
        "lwb": jnp.zeros((batch, n), jnp.float32),
        "p": jnp.zeros((n, d), jnp.float32),
        "pb": jnp.zeros((batch, n, d), jnp.float32),
    }


def entry_callable(resampler, entry: str, args: Optional[dict] = None):
    """``(fn, call_args)`` tracing one entry point of a built resampler."""
    a = _audit_args() if args is None else args
    thr = AUDIT_THRESHOLD
    table = {
        "call": (lambda k, w: resampler(k, w), (a["key"], a["w"])),
        "batch": (lambda k, w: resampler.batch(k, w), (a["key"], a["wb"])),
        "batch_rows": (
            lambda ks, w: resampler.batch_rows(ks, w),
            (a["keys"], a["wb"]),
        ),
        "apply": (
            lambda k, w, p: resampler.apply(k, w, p),
            (a["key"], a["w"], a["p"]),
        ),
        "apply_batch": (
            lambda k, w, p: resampler.apply_batch(k, w, p),
            (a["key"], a["wb"], a["pb"]),
        ),
        "apply_rows": (
            lambda ks, w, p: resampler.apply_rows(ks, w, p),
            (a["keys"], a["wb"], a["pb"]),
        ),
        "step": (
            lambda k, lw, p: resampler.step(k, lw, p, thr),
            (a["key"], a["lw"], a["p"]),
        ),
        "step_rows": (
            lambda ks, lw, p: resampler.step_rows(ks, lw, p, thr),
            (a["keys"], a["lwb"], a["pb"]),
        ),
    }
    if entry not in table:
        raise KeyError(f"unknown entry point {entry!r}; choices: {ENTRY_POINTS}")
    return table[entry]


def trace_cell(name: str, backend: str, entry: str, args: Optional[dict] = None,
               *, plane_dtype: str = "float32"):
    """Trace one matrix cell to a ClosedJaxpr (no execution)."""
    resampler = spec_for_backend(
        name, backend, num_iters=AUDIT_NUM_ITERS, max_iters=AUDIT_MAX_ITERS,
        plane_dtype=plane_dtype,
    ).build()
    fn, call_args = entry_callable(resampler, entry, args)
    return jax.make_jaxpr(fn)(*call_args)


def cell_contract(name: str, backend: str, entry: str) -> Contract:
    return Contract(max_launches=launch_budget(name, backend, entry))


def audit_matrix(families=None, backends=None, entries=None, plane_dtypes=None):
    """Trace + audit every requested matrix cell; yields CellReports.

    One shared args dict keeps tracing cheap; cells are independent, so a
    failure in one family still reports every other cell.  ``plane_dtypes``
    adds the DESIGN.md §14 compression axis (default: float32 only);
    compressed cells are named ``family/backend/entry@dtype`` and graded
    against the SAME contract — compression narrows words, never adds
    launches, host conds or HBM ancestor round-trips.
    """
    args = _audit_args()
    for dtype in plane_dtypes if plane_dtypes is not None else ("float32",):
        suffix = "" if dtype == "float32" else f"@{dtype}"
        for name, backend, entry in contract_cells(families, backends, entries):
            cell = f"{name}/{backend}/{entry}{suffix}"
            jaxpr = trace_cell(name, backend, entry, args, plane_dtype=dtype)
            yield audit_jaxpr(cell, jaxpr, cell_contract(name, backend, entry))


def audit_large_n_footprints(families=None):
    """Price the fused kernels at the residency-budget edge WITHOUT
    running them — the static complement of the ``check_state_resident``
    runtime guard.  N = 2^18 with a 4-component (pad 8) state sits exactly
    at ``N * pad_state_dim(d) == MAX_VMEM_STATE``, the largest geometry
    the runtime guards admit.  Only the single-row fused entries are
    priced: the bank paths grid over rows with the same per-step blocks."""
    n = 1 << 18
    d = 4  # pad_state_dim(4) == 8, so N * 8 == MAX_VMEM_STATE exactly
    args = _audit_args(n=n, batch=1, d=d)
    for name, backend, entry in contract_cells(
        families, backends=("pallas_interpret",), entries=("apply", "step")
    ):
        cell = f"{name}/{backend}/{entry}@N={n},d={d}"
        jaxpr = trace_cell(name, backend, entry, args)
        yield audit_jaxpr(cell, jaxpr, cell_contract(name, backend, entry))