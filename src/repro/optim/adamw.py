"""AdamW + cosine schedule + global-norm clipping, pure-JAX pytrees.

No optax dependency: at framework scale the optimizer must be shardable
(ZeRO-1 — optimizer moments sharded over the ``data`` axis) and the state
tree must be a plain pytree so it flows through ``jax.jit`` in_shardings and
the checkpoint manifest unchanged.

State layout::

    state = {"step": i32[], "mu": tree_like(params), "nu": tree_like(params)}

``opt_state_pspecs`` derives the ZeRO-1 sharding: each moment inherits the
param's PartitionSpec with the FIRST free (None) axis replaced by the
``data`` axis when the dim is divisible — parameters stay replicated across
DP, the redundant optimizer memory does not (ZeRO stage 1).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    end_lr: float = 3e-5
    warmup_steps: int = 100
    total_steps: int = 1000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0  # 0 disables
    # bf16 moments halve optimizer HBM — the difference between fitting and
    # not fitting a 400B arch on 256 x 16GB chips (configs set this per arch).
    moment_dtype: str = "float32"


def cosine_schedule(cfg: AdamWConfig, step):
    """Linear warmup -> cosine decay to ``end_lr`` (standard LM schedule)."""
    step = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
    warm = cfg.peak_lr * step / jnp.maximum(cfg.warmup_steps, 1)
    t = (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1)
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.end_lr + 0.5 * (cfg.peak_lr - cfg.end_lr) * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    """Returns (clipped_grads, pre_clip_norm)."""
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def adamw_init(params, moment_dtype=jnp.float32):
    def zeros(p):
        return jnp.zeros(p.shape, moment_dtype)

    return {
        "step": jnp.zeros((), jnp.int32),
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
    }


def adamw_update(cfg: AdamWConfig, params, grads, state):
    """One AdamW step.  Returns (params', state', metrics dict).

    Decoupled weight decay is applied to every >=2-D tensor (matrices,
    embeddings) and skipped for 1-D tensors (norms, biases, SSM vectors) —
    the standard LM heuristic.
    """
    step = state["step"] + 1
    lr = cosine_schedule(cfg, step)
    if cfg.clip_norm > 0:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        gnorm = global_norm(grads)

    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        mdt = mu.dtype
        g32 = g.astype(jnp.float32)
        mu = b1 * mu.astype(jnp.float32) + (1 - b1) * g32
        nu = b2 * nu.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
        stepv = (mu / c1) / (jnp.sqrt(nu / c2) + cfg.eps)
        if cfg.weight_decay > 0 and p.ndim >= 2:
            stepv = stepv + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * stepv).astype(p.dtype), mu.astype(mdt), nu.astype(mdt)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"step": step, "mu": new_mu, "nu": new_nu}, metrics


# ------------------------------------------------------------------- ZeRO-1
def _axis_names(ax) -> set:
    if ax is None:
        return set()
    return set(ax) if isinstance(ax, tuple) else {ax}


def _zero1_spec(spec: P, shape, data_axis, data_size: int) -> P:
    """Shard the first free dim divisible by the DP degree over ``data``
    (``data_axis`` may be an axis name or tuple of names — hierarchical
    pod+data FSDP on the multi-pod mesh)."""
    parts = list(spec) + [None] * (len(shape) - len(spec))
    wanted = _axis_names(data_axis)
    if any(_axis_names(ax) & wanted for ax in parts):
        return P(*parts)  # param already FSDP-sharded over data — inherit
    for d, (ax, dim) in enumerate(zip(parts, shape)):
        if ax is None and dim % data_size == 0 and dim >= data_size:
            parts[d] = data_axis
            return P(*parts)
    return P(*parts)


def opt_state_pspecs(param_pspecs, param_shapes, *, data_axis="data", data_size=16,
                     zero1: bool = True):
    """PartitionSpec tree for ``adamw_init`` state given the param specs.

    ``param_shapes``: tree of ShapeDtypeStruct (from ``jax.eval_shape``).
    With ``zero1=False`` moments just mirror the param specs (replicated
    over DP like the params themselves).
    """
    if zero1:
        moment = jax.tree.map(
            lambda s, sh: _zero1_spec(s, sh.shape, data_axis, data_size),
            param_pspecs,
            param_shapes,
            is_leaf=lambda x: isinstance(x, P),
        )
    else:
        moment = param_pspecs
    return {"step": P(), "mu": moment, "nu": moment}
