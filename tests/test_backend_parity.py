"""Backend-parity harness: family × backend × single/batch (the kernel
matrix's quality gate).

Every ``ResamplerSpec`` family must build and run on every backend.  Three
parity levels, each over the full matrix:

  1. **construction** — every (family, backend) pair constructs with
     kernel-legal geometry and returns valid ancestors from ``__call__``
     and ``.batch``;
  2. **xla ≡ reference** — bit-parity, single and batch (jit must not
     change the stream);
  3. **pallas_interpret ≡ kernel oracle** — bit-parity on CPU, single and
     batch, against the pure-jnp ``ref.py`` oracle composed with the SAME
     key-derivation the ops wrapper uses.  This pins both the kernel
     arithmetic and the wrapper's key/offset-derivation contract.

The §5.1 statistical gate (MSE / bias contribution per backend) lives in
``tests/test_resampler_stats.py::test_kernel_backend_statistical_parity``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.resamplers.batched import split_batch_keys
from repro.core.spec import (
    BACKENDS,
    KERNEL_PARTITION_BYTES,
    KERNEL_SEGMENT,
    MegopolisSpec,
    MetropolisC1Spec,
    MetropolisC2Spec,
    MetropolisSpec,
    PrefixSumSpec,
    RejectionSpec,
)
from repro.kernels.common import TILE, key_to_seed
from repro.kernels.megopolis.ref import megopolis_ref
from repro.kernels.metropolis.ref import metropolis_c1_ref, metropolis_c2_ref, metropolis_ref
from repro.kernels.prefix_sum.ref import prefix_resample_ref
from repro.kernels.rejection.ref import rejection_ref

N = 2 * TILE
BATCH = 3
ITERS = 8
MAX_ITERS = 24  # rejection cap in this harness

PREFIX_KINDS = ("multinomial", "systematic", "improved_systematic", "stratified", "residual")


def _spec(name: str, backend: str):
    """Kernel-legal spec for every (family, backend) cell of the matrix."""
    pallas = backend in ("pallas", "pallas_interpret")
    if name == "megopolis":
        return MegopolisSpec(
            num_iters=ITERS, segment=KERNEL_SEGMENT if pallas else 32, backend=backend
        )
    if name == "metropolis":
        return MetropolisSpec(num_iters=ITERS, backend=backend)
    if name == "metropolis_c1":
        return MetropolisC1Spec(
            num_iters=ITERS,
            partition_size_bytes=KERNEL_PARTITION_BYTES if pallas else 128,
            backend=backend,
        )
    if name == "metropolis_c2":
        return MetropolisC2Spec(
            num_iters=ITERS,
            partition_size_bytes=KERNEL_PARTITION_BYTES if pallas else 128,
            backend=backend,
        )
    if name == "rejection":
        return RejectionSpec(max_iters=MAX_ITERS, backend=backend)
    return PrefixSumSpec(kind=name, backend=backend)


FAMILIES = ("megopolis", "metropolis", "metropolis_c1", "metropolis_c2", "rejection") + (
    PREFIX_KINDS
)


# ------------------------------------------------------ kernel-oracle adapters
# Each adapter replays the ops wrapper's key derivation, then calls the
# pure-jnp ref.py oracle — the (key, weights) -> ancestors ground truth the
# pallas_interpret backend must match bit-for-bit.

def _megopolis_oracle(key, w):
    n = w.shape[0]
    key_off, key_seed = jax.random.split(key)
    offsets = jax.random.randint(key_off, (ITERS,), 0, n, dtype=jnp.int32)
    seed = key_to_seed(key_seed).reshape(1)
    return megopolis_ref(w, offsets, seed, num_iters=ITERS)


def _megopolis_oracle_batch(key, w):
    # The bank kernel's documented contract: ONE offset table bank-wide,
    # per-row RNG seeds (DESIGN.md §4).
    bsz, n = w.shape
    key_off, key_rows = jax.random.split(key)
    offsets = jax.random.randint(key_off, (ITERS,), 0, n, dtype=jnp.int32)
    seeds = key_to_seed(jax.random.split(key_rows, bsz))
    return jnp.stack(
        [megopolis_ref(w[b], offsets, seeds[b].reshape(1), num_iters=ITERS)
         for b in range(bsz)]
    )


def _metropolis_oracle(key, w):
    return metropolis_ref(w, key_to_seed(key).reshape(1), num_iters=ITERS)


def _c1_oracle(key, w):
    num_tiles = w.shape[0] // TILE
    kp, kloop = jax.random.split(key)
    partitions = jax.random.randint(kp, (num_tiles,), 0, num_tiles, dtype=jnp.int32)
    return metropolis_c1_ref(w, partitions, key_to_seed(kloop).reshape(1), num_iters=ITERS)


def _c2_oracle(key, w):
    num_tiles = w.shape[0] // TILE
    kp, kloop = jax.random.split(key)
    partitions = jax.random.randint(
        kp, (num_tiles * ITERS,), 0, num_tiles, dtype=jnp.int32
    )
    return metropolis_c2_ref(w, partitions, key_to_seed(kloop).reshape(1), num_iters=ITERS)


def _rejection_oracle(key, w):
    return rejection_ref(w, key_to_seed(key).reshape(1), max_iters=MAX_ITERS)


def _prefix_oracle(kind):
    def oracle(key, w):
        return prefix_resample_ref(key, w, kind=kind)

    return oracle


ORACLES = {
    "megopolis": _megopolis_oracle,
    "metropolis": _metropolis_oracle,
    "metropolis_c1": _c1_oracle,
    "metropolis_c2": _c2_oracle,
    "rejection": _rejection_oracle,
    **{kind: _prefix_oracle(kind) for kind in PREFIX_KINDS},
}


def _split_key_batch_oracle(single_oracle):
    """The §4 contract: row b == single with split(key, B)[b]."""

    def oracle(key, w):
        keys = split_batch_keys(key, w.shape[0])
        return jnp.stack([single_oracle(keys[b], w[b]) for b in range(w.shape[0])])

    return oracle


BATCH_ORACLES = {
    name: (_megopolis_oracle_batch if name == "megopolis"
           else _split_key_batch_oracle(ORACLES[name]))
    for name in FAMILIES
}


@pytest.fixture(scope="module")
def w_single():
    return jax.random.uniform(jax.random.PRNGKey(101), (N,)) + 1e-3


@pytest.fixture(scope="module")
def w_bank():
    return jax.random.uniform(jax.random.PRNGKey(102), (BATCH, N)) + 1e-3


# --------------------------------------------------------- 1. construction
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("name", FAMILIES)
def test_every_family_constructs_on_every_backend(name, backend):
    spec = _spec(name, backend)
    r = spec.build()
    assert r.name == name
    assert spec.backend == backend


@pytest.mark.parametrize("name", FAMILIES)
def test_pallas_interpret_returns_valid_ancestors(name, w_single, w_bank, base_key):
    r = _spec(name, "pallas_interpret").build()
    a = r(base_key, w_single)
    ab = r.batch(base_key, w_bank)
    assert a.shape == (N,) and a.dtype == jnp.int32
    assert ab.shape == (BATCH, N) and ab.dtype == jnp.int32
    assert bool(jnp.all((a >= 0) & (a < N)))
    assert bool(jnp.all((ab >= 0) & (ab < N)))


# --------------------------------------------------- 2. xla == reference
@pytest.mark.parametrize("name", FAMILIES)
def test_xla_bit_identical_to_reference(name, w_single, w_bank, base_key):
    ref = _spec(name, "reference").build()
    xla = _spec(name, "xla").build()
    np.testing.assert_array_equal(
        np.asarray(ref(base_key, w_single)), np.asarray(xla(base_key, w_single))
    )
    np.testing.assert_array_equal(
        np.asarray(ref.batch(base_key, w_bank)), np.asarray(xla.batch(base_key, w_bank))
    )


# ------------------------------------- 3. pallas_interpret == kernel oracle
@pytest.mark.parametrize("name", FAMILIES)
def test_pallas_interpret_bit_identical_to_oracle_single(name, w_single, base_key):
    r = _spec(name, "pallas_interpret").build()
    got = r(base_key, w_single)
    want = ORACLES[name](base_key, w_single)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("name", FAMILIES)
def test_pallas_interpret_bit_identical_to_oracle_batch(name, w_bank, base_key):
    r = _spec(name, "pallas_interpret").build()
    got = r.batch(base_key, w_bank)
    want = BATCH_ORACLES[name](base_key, w_bank)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# --------------------------------------------------- 'auto' batch contract
@pytest.mark.parametrize("name", ["metropolis", "metropolis_c1", "metropolis_c2"])
def test_pallas_auto_batch_resolves_eq3_per_row(name, base_key):
    """num_iters='auto' .batch must give each row ITS OWN eq. (3) count —
    bit-identical to the single call with split key b — not one bank-level
    resolve (which under-iterates concentrated rows)."""
    from repro.core.weightgen import gaussian_weights

    spec = _spec(name, "pallas_interpret").replace(num_iters="auto")
    r = spec.build()
    # rows with wildly different degeneracy -> different per-row B
    w = jnp.stack(
        [gaussian_weights(jax.random.PRNGKey(1), N, y=0.0),
         gaussian_weights(jax.random.PRNGKey(2), N, y=4.0)]
    )
    got = r.batch(base_key, w)
    keys = split_batch_keys(base_key, 2)
    want = jnp.stack([r(keys[b], w[b]) for b in range(2)])
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("name", ["metropolis", "metropolis_c1", "metropolis_c2"])
def test_pallas_auto_batch_rejects_traced_weights(name, base_key, w_bank):
    r = _spec(name, "pallas_interpret").replace(num_iters="auto").build()
    with pytest.raises(TypeError, match="concrete"):
        jax.jit(r.batch)(base_key, w_bank)


# ---------------------------------------------- oracle-independent sanity
@pytest.mark.parametrize("name", FAMILIES)
def test_pallas_interpret_offspring_track_weights(name, base_key):
    """Mean offspring must track N*w/sum(w) on the kernel lane — a ground
    truth the ref.py oracles do NOT define, so an index-map error shared by
    kernel and oracle still fails here.  Correlation (not per-particle
    tolerance) keeps the Monte Carlo cheap."""
    from repro.core.metrics import offspring_counts
    from repro.core.weightgen import gaussian_weights

    w = gaussian_weights(jax.random.PRNGKey(9), N, y=2.0)
    spec = _spec(name, "pallas_interpret")
    if hasattr(spec, "num_iters"):
        spec = spec.replace(num_iters=24)  # ~ eq. (3) at y=2
    r = spec.build()
    offs = []
    for t in range(8):
        a = r(jax.random.fold_in(base_key, 900 + t), w)
        offs.append(np.asarray(offspring_counts(a, N)))
    mean_off = np.stack(offs).mean(axis=0)
    want = N * np.asarray(w / jnp.sum(w))
    assert np.corrcoef(mean_off, want)[0, 1] > 0.8, name
    np.testing.assert_allclose(mean_off.sum(), N, rtol=1e-6)
