"""Paper Figs. 1-4 arithmetic: memory transactions per warp-iteration for
each algorithm's comparison-index stream — the paper's speed argument,
counted exactly.  Also evaluates the TPU-granularity variant (512-byte
vector rows / 4096-byte VMEM tiles) used by the kernel adaptation."""

from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import print_table, write_csv
from repro.core.transactions import index_streams, transactions_per_group

CASES = [
    ("megopolis", {}),
    ("metropolis", {}),
    ("metropolis_c1", {"partition_size_bytes": 128}),
    ("metropolis_c1", {"partition_size_bytes": 2048}),
    ("metropolis_c2", {"partition_size_bytes": 128}),
    ("metropolis_c2", {"partition_size_bytes": 2048}),
]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1 << 16)
    ap.add_argument("--iters", type=int, default=16)
    args = ap.parse_args(argv)

    rows = []
    for gran_name, group, seg in (("gpu_warp32_seg32B", 32, 32),
                                  ("tpu_row128_seg512B", 128, 512)):
        for name, params in CASES:
            per_group = []
            for ix in index_streams(name, 7, args.n, args.iters, **params):
                per_group.append(transactions_per_group(
                    ix, group=group, segment_bytes=seg))
            t = np.concatenate(per_group)
            label = name + (f"_ps{params['partition_size_bytes']}" if params else "")
            rows.append({"granularity": gran_name, "algo": label,
                         "mean_tx_per_group": float(t.mean()),
                         "max_tx_per_group": int(t.max())})
    write_csv("transactions.csv", rows)
    print_table(rows)
    gpu = {r["algo"]: r for r in rows if r["granularity"].startswith("gpu")}
    assert gpu["megopolis"]["max_tx_per_group"] <= 4 + 1, "paper: Megopolis <= 4 + alignment"
    print(f"\nMegopolis mean {gpu['megopolis']['mean_tx_per_group']:.2f} tx/warp "
          f"vs Metropolis {gpu['metropolis']['mean_tx_per_group']:.2f} "
          f"(paper: 4 vs up to 32)")


if __name__ == "__main__":
    main()
