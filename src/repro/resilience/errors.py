"""Typed error taxonomy for the resilience layer (DESIGN.md §16).

Every failure mode the fallback ladder can demote on — and every fault
the chaos suite injects — maps onto exactly one of these classes, so
consumers can catch *categories* ("any lowering problem") instead of
string-matching backend internals.  The classes multiply-inherit the
builtin the pre-taxonomy code raised (``ValueError`` for the residency
checks), so every existing ``except ValueError`` / ``pytest.raises``
site keeps working.
"""

from __future__ import annotations


class ResilienceError(Exception):
    """Base class of the §16 taxonomy: anything the resilience layer can
    classify, demote on, or deliberately inject."""


class KernelLoweringError(ResilienceError, RuntimeError):
    """A pallas kernel failed to lower/compile for the requested backend —
    the "won't run on this device" class the fallback ladder demotes on."""


class VmemBudgetExceeded(ResilienceError, ValueError):
    """A resident plane outgrew the §2 VMEM budget.  Subclasses
    ``ValueError`` because the residency checks always raised that; the
    taxonomy adds the category without breaking existing handlers."""


class BackendUnavailable(ResilienceError, RuntimeError):
    """No rung of the fallback ladder could build + probe a working
    resampler.  Carries the per-rung failures for the post-mortem."""

    def __init__(self, message: str, failures=()):
        super().__init__(message)
        #: ``[(backend, exception), ...]`` — one entry per failed rung.
        self.failures = tuple(failures)


class CorruptAncestorsError(ResilienceError, ValueError):
    """An ancestor vector failed validation (out-of-range / wrong dtype) —
    the poisoned-ancestor fault class, caught at the consumer boundary
    instead of silently mis-gathering state."""


class InjectedCrash(ResilienceError, RuntimeError):
    """The deterministic kill the crash-consistency tests schedule: raised
    by ``CheckpointPolicy(fail_after=k)`` immediately AFTER snapshot ``k``
    is durably on disk, so resume always sees a consistent checkpoint."""
