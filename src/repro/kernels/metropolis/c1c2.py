"""Metropolis-C1/C2 — Pallas TPU kernels (paper Algorithms 3-4, Dülger).

The CUDA originals constrain each warp's proposal index to a shared random
partition of ``N_w`` weights so the warp's gathers land in one cache line
(paper Fig. 3).  The TPU translation keeps that contract at tile
granularity: the partition is one aligned ``(8, 128)`` f32 VMEM tile
(``SEG = 1024`` particles = 4096 bytes), and the "warp" that shares it is
the whole tile of lanes.

  * **C1** (Alg. 3): ONE partition tile per own-tile, chosen up front and
    kept for every iteration — a scalar-prefetched table ``p[num_tiles]``
    drives the comparison BlockSpec, so the partition is fetched once per
    tile and re-used for all B sweeps (one transaction amortised over B).
  * **C2** (Alg. 4): a FRESH partition tile per (tile, iteration) — table
    ``p[num_tiles * num_iters]``, comparison block re-fetched every sweep
    (B transactions, the cost C2 pays for C1's quality pathology).

Within the partition the proposal ``j_local ~ U{0, SEG-1}`` is a random
in-VMEM gather — the analogue of the CUDA originals' random access inside
the shared-memory partition; no HBM traffic.  RNG lane layout matches the
Metropolis kernel: ``hash_bits(seed, i, b)`` proposes, ``hash_uniform(seed,
i + N, b)`` accepts.

Validated bit-exactly against ``ref.metropolis_c1_ref`` /
``ref.metropolis_c2_ref`` in interpret mode.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import (
    LANES,
    SUBLANES,
    gather_state,
    hash_bits,
    hash_uniform,
    step_select,
    step_stats,
    tile_lane_ids,
)

SEG = SUBLANES * LANES
# One (8,128) f32 VMEM tile — the kernel's partition, in bytes (Algs. 3-4
# parametrise the partition by bytes; the TPU tile is 1024 f32 = 4 KiB).
PARTITION_BYTES = SEG * 4


def _sweep_partition(t, b, p_tile, seed, w_own, w_part, k_prev, wk_prev, n_total):
    """One segment-local accept/reject sweep (Algs. 3-4 lines 7-13).

    ``w_part`` is the partition tile ``p_tile`` (already fetched by the
    BlockSpec); the proposal is a random lane of that tile."""
    i_global = tile_lane_ids(t)

    k = jnp.where(b == 0, i_global, k_prev)
    wk = jnp.where(b == 0, w_own, wk_prev)

    # j = p * N_w + U{0, N_w-1}: random access INSIDE the resident tile.
    j_local = (hash_bits(seed, i_global, b) % jnp.uint32(SEG)).astype(jnp.int32)
    w_j = jnp.take(w_part.reshape(SEG), j_local.reshape(-1), axis=0).reshape(
        SUBLANES, LANES
    )
    j_global = p_tile * SEG + j_local

    u = hash_uniform(seed, i_global + n_total, b, dtype=w_j.dtype)
    accept = u * wk <= w_j
    return jnp.where(accept, j_global, k), jnp.where(accept, w_j, wk)


def _kernel_c1(p_ref, seed_ref, w_own_ref, w_part_ref, k_ref, wk_ref):
    t = pl.program_id(0)
    b = pl.program_id(1)
    n_total = pl.num_programs(0) * SEG
    k_new, wk_new = _sweep_partition(
        t, b, p_ref[t], seed_ref[0],
        w_own_ref[...].astype(jnp.float32), w_part_ref[...].astype(jnp.float32),
        k_ref[...], wk_ref[...], n_total,
    )
    k_ref[...] = k_new
    wk_ref[...] = wk_new


def _make_kernel_c2(num_iters: int):
    def _kernel_c2(p_ref, seed_ref, w_own_ref, w_part_ref, k_ref, wk_ref):
        t = pl.program_id(0)
        b = pl.program_id(1)
        n_total = pl.num_programs(0) * SEG
        k_new, wk_new = _sweep_partition(
            t, b, p_ref[t * num_iters + b], seed_ref[0],
            w_own_ref[...].astype(jnp.float32),
            w_part_ref[...].astype(jnp.float32),
            k_ref[...], wk_ref[...], n_total,
        )
        k_ref[...] = k_new
        wk_ref[...] = wk_new

    return _kernel_c2


def _kernel_c1_fused(p_ref, seed_ref, w_own_ref, w_part_ref, planes_ref,
                     k_ref, out_ref, wk_ref):
    """Fused C1 grid step: segment-local sweep + last-iteration state copy
    (DESIGN.md §11).  The partition keeps C1's one-fetch contract; the
    state plane stack is resident because the SELECTED ancestor may live in
    any tile (``j_global`` ranges over all N across iterations)."""
    t = pl.program_id(0)
    b = pl.program_id(1)
    n_total = pl.num_programs(0) * SEG
    k_new, wk_new = _sweep_partition(
        t, b, p_ref[t], seed_ref[0],
        w_own_ref[...].astype(jnp.float32), w_part_ref[...].astype(jnp.float32),
        k_ref[...], wk_ref[...], n_total,
    )
    k_ref[...] = k_new
    wk_ref[...] = wk_new

    @pl.when(b == pl.num_programs(1) - 1)
    def _copy_state():
        out_ref[...] = gather_state(planes_ref[...], k_new)


def _make_kernel_c2_fused(num_iters: int):
    def _kernel_c2_fused(p_ref, seed_ref, w_own_ref, w_part_ref, planes_ref,
                         k_ref, out_ref, wk_ref):
        t = pl.program_id(0)
        b = pl.program_id(1)
        n_total = pl.num_programs(0) * SEG
        k_new, wk_new = _sweep_partition(
            t, b, p_ref[t * num_iters + b], seed_ref[0],
            w_own_ref[...].astype(jnp.float32),
            w_part_ref[...].astype(jnp.float32),
            k_ref[...], wk_ref[...], n_total,
        )
        k_ref[...] = k_new
        wk_ref[...] = wk_new

        @pl.when(b == pl.num_programs(1) - 1)
        def _copy_state():
            out_ref[...] = gather_state(planes_ref[...], k_new)

    return _kernel_c2_fused


def _make_kernel_step(p_at):
    """Fused STEP kernel body shared by C1 and C2 — they differ only in how
    the partition table is indexed (``p_at(p_ref, t, b)``).  The (0, 0)
    prelude latches (m, do) from a NEW resident log-weight input; the
    segment-local sweep runs on ``exp(lw - m)`` tiles and the last
    iteration commits selection or identity."""

    def _kernel_step(p_ref, seed_ref, thr_ref, lw_own_ref, lw_part_ref,
                     lw_full_ref, planes_ref, k_ref, out_ref, stats_ref,
                     wk_ref, st_ref):
        t = pl.program_id(0)
        b = pl.program_id(1)
        n_total = pl.num_programs(0) * SEG

        @pl.when((t == 0) & (b == 0))
        def _prelude():
            m, ess_norm, incr, maxw, deg = step_stats(
                lw_full_ref[...].astype(jnp.float32).reshape(n_total), n_total
            )
            do = ess_norm < thr_ref[0]
            st_ref[0] = m
            st_ref[1] = jnp.where(do, jnp.float32(1.0), jnp.float32(0.0))
            st_ref[2] = jnp.where(deg, jnp.float32(1.0), jnp.float32(0.0))
            stats_ref[0] = ess_norm
            stats_ref[1] = jnp.where(do, incr, jnp.float32(0.0))
            stats_ref[2] = jnp.where(do, jnp.float32(1.0), jnp.float32(0.0))
            stats_ref[3] = maxw

        m = st_ref[0]
        do = st_ref[1] > 0.5
        deg = st_ref[2] > 0.5
        # Normalised weights re-land on the plane-dtype grid (the composed
        # path quantises at the public ``apply`` boundary); a no-op at f32.
        # The §16 degenerate latch substitutes the uniform bank first.
        w_own = jnp.exp(lw_own_ref[...].astype(jnp.float32) - m)
        w_part = jnp.exp(lw_part_ref[...].astype(jnp.float32) - m)
        w_own = jnp.where(deg, jnp.float32(1.0 / n_total), w_own)
        w_part = jnp.where(deg, jnp.float32(1.0 / n_total), w_part)
        w_own = w_own.astype(lw_own_ref.dtype).astype(jnp.float32)
        w_part = w_part.astype(lw_part_ref.dtype).astype(jnp.float32)
        k_new, wk_new = _sweep_partition(
            t, b, p_at(p_ref, t, b), seed_ref[0],
            w_own, w_part, k_ref[...], wk_ref[...], n_total,
        )
        k_ref[...] = k_new
        wk_ref[...] = wk_new

        @pl.when(b == pl.num_programs(1) - 1)
        def _commit():
            k_sel = step_select(do, k_new, t)
            k_ref[...] = k_sel
            out_ref[...] = gather_state(planes_ref[...], k_sel)

    return _kernel_step


def _c1c2_step_call(kernel, log_weights2d, planes, partitions, seed, thr, *,
                    num_iters, part_index, interpret):
    """Shared fused-step pallas_call builder for the C1/C2 pair: the fused
    apply layout plus a resident whole-log-weight input for the prelude and
    an SMEM stats output."""
    rows, lanes = log_weights2d.shape
    assert lanes == LANES and rows % SUBLANES == 0
    d_pad = planes.shape[0]
    assert planes.shape[1:] == (rows, lanes)
    num_tiles = rows // SUBLANES

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,  # partitions + seed + f32 ESS threshold
        grid=(num_tiles, num_iters),
        in_specs=[
            pl.BlockSpec((SUBLANES, LANES), lambda t, b, p, se, r: (t, 0)),
            pl.BlockSpec((SUBLANES, LANES), part_index),
            pl.BlockSpec((rows, LANES), lambda t, b, p, se, r: (0, 0)),
            pl.BlockSpec((d_pad, rows, LANES), lambda t, b, p, se, r: (0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((SUBLANES, LANES), lambda t, b, p, se, r: (t, 0)),
            pl.BlockSpec((d_pad, SUBLANES, LANES), lambda t, b, p, se, r: (0, t, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        scratch_shapes=[
            pltpu.VMEM((SUBLANES, LANES), jnp.float32),
            pltpu.SMEM((3,), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((rows, lanes), jnp.int32),
            jax.ShapeDtypeStruct((d_pad, rows, lanes), planes.dtype),
            jax.ShapeDtypeStruct((4,), jnp.float32),
        ],
        interpret=interpret,
    )(partitions, seed, thr, log_weights2d, log_weights2d, log_weights2d, planes)


@functools.partial(jax.jit, static_argnames=("num_iters", "interpret"))
def metropolis_c1_pallas_step(
    log_weights2d: jnp.ndarray,
    planes: jnp.ndarray,
    partitions: jnp.ndarray,
    seed: jnp.ndarray,
    thr: jnp.ndarray,
    *,
    num_iters: int,
    interpret: bool = True,
):
    """Fused C1 SMC step: normalise → ESS → conditional Alg. 3 resample →
    state copy, ONE launch.  Returns ``(int32[R, 128], [d_pad, R, 128],
    f32[4] = (ess_norm, incr, resampled, max_weight))``."""
    return _c1c2_step_call(
        _make_kernel_step(lambda p, t, b: p[t]),
        log_weights2d, planes, partitions, seed, thr,
        num_iters=num_iters,
        part_index=lambda t, b, p, se, r: (p[t], 0),
        interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("num_iters", "interpret"))
def metropolis_c2_pallas_step(
    log_weights2d: jnp.ndarray,
    planes: jnp.ndarray,
    partitions: jnp.ndarray,
    seed: jnp.ndarray,
    thr: jnp.ndarray,
    *,
    num_iters: int,
    interpret: bool = True,
):
    """Fused C2 SMC step: as C1 but with a fresh partition per (t, b)
    (Alg. 4).  Returns ``(int32[R, 128], [d_pad, R, 128], f32[4])``."""
    return _c1c2_step_call(
        _make_kernel_step(lambda p, t, b: p[t * num_iters + b]),
        log_weights2d, planes, partitions, seed, thr,
        num_iters=num_iters,
        part_index=lambda t, b, p, se, r: (p[t * num_iters + b], 0),
        interpret=interpret,
    )


def _c1c2_fused_call(kernel, weights2d, planes, partitions, seed, *,
                     num_iters, part_index, interpret):
    """Shared fused pallas_call builder for the C1/C2 pair — identical
    except for the partition BlockSpec index map."""
    rows, lanes = weights2d.shape
    assert lanes == LANES and rows % SUBLANES == 0
    d_pad = planes.shape[0]
    assert planes.shape[1:] == (rows, lanes)
    num_tiles = rows // SUBLANES

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(num_tiles, num_iters),
        in_specs=[
            pl.BlockSpec((SUBLANES, LANES), lambda t, b, p, seed: (t, 0)),
            pl.BlockSpec((SUBLANES, LANES), part_index),
            pl.BlockSpec((d_pad, rows, LANES), lambda t, b, p, seed: (0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((SUBLANES, LANES), lambda t, b, p, seed: (t, 0)),
            pl.BlockSpec((d_pad, SUBLANES, LANES), lambda t, b, p, seed: (0, t, 0)),
        ],
        scratch_shapes=[pltpu.VMEM((SUBLANES, LANES), jnp.float32)],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((rows, lanes), jnp.int32),
            jax.ShapeDtypeStruct((d_pad, rows, lanes), planes.dtype),
        ],
        interpret=interpret,
    )(partitions, seed, weights2d, weights2d, planes)


@functools.partial(jax.jit, static_argnames=("num_iters", "interpret"))
def metropolis_c1_pallas_fused(
    weights2d: jnp.ndarray,
    planes: jnp.ndarray,
    partitions: jnp.ndarray,
    seed: jnp.ndarray,
    *,
    num_iters: int,
    interpret: bool = True,
):
    """Fused C1: ancestors identical to ``metropolis_c1_pallas``; returns
    ``(int32[R, 128], [d_pad, R, 128])``."""
    return _c1c2_fused_call(
        _kernel_c1_fused, weights2d, planes, partitions, seed,
        num_iters=num_iters,
        part_index=lambda t, b, p, seed: (p[t], 0),
        interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("num_iters", "interpret"))
def metropolis_c2_pallas_fused(
    weights2d: jnp.ndarray,
    planes: jnp.ndarray,
    partitions: jnp.ndarray,
    seed: jnp.ndarray,
    *,
    num_iters: int,
    interpret: bool = True,
):
    """Fused C2: ancestors identical to ``metropolis_c2_pallas``; returns
    ``(int32[R, 128], [d_pad, R, 128])``."""
    return _c1c2_fused_call(
        _make_kernel_c2_fused(num_iters), weights2d, planes, partitions, seed,
        num_iters=num_iters,
        part_index=lambda t, b, p, seed: (p[t * num_iters + b], 0),
        interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("num_iters", "interpret"))
def metropolis_c1_pallas(
    weights2d: jnp.ndarray,
    partitions: jnp.ndarray,
    seed: jnp.ndarray,
    *,
    num_iters: int,
    interpret: bool = True,
) -> jnp.ndarray:
    """``weights2d``: f32[R, 128] with R % 8 == 0; ``partitions``:
    int32[num_tiles] (one fixed partition tile per own-tile); ``seed``:
    uint32[1].  Returns int32[R, 128] ancestors."""
    rows, lanes = weights2d.shape
    assert lanes == LANES and rows % SUBLANES == 0
    num_tiles = rows // SUBLANES

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(num_tiles, num_iters),
        in_specs=[
            pl.BlockSpec((SUBLANES, LANES), lambda t, b, p, seed: (t, 0)),
            # partition block constant in b -> fetched ONCE per tile (C1's
            # whole point: one transaction amortised over all B sweeps)
            pl.BlockSpec((SUBLANES, LANES), lambda t, b, p, seed: (p[t], 0)),
        ],
        out_specs=pl.BlockSpec((SUBLANES, LANES), lambda t, b, p, seed: (t, 0)),
        scratch_shapes=[pltpu.VMEM((SUBLANES, LANES), jnp.float32)],
    )
    return pl.pallas_call(
        _kernel_c1,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((rows, lanes), jnp.int32),
        interpret=interpret,
    )(partitions, seed, weights2d, weights2d)


@functools.partial(jax.jit, static_argnames=("num_iters", "interpret"))
def metropolis_c2_pallas(
    weights2d: jnp.ndarray,
    partitions: jnp.ndarray,
    seed: jnp.ndarray,
    *,
    num_iters: int,
    interpret: bool = True,
) -> jnp.ndarray:
    """``partitions``: int32[num_tiles * num_iters], row-major by tile —
    ``partitions[t * num_iters + b]`` is tile t's partition at iteration b
    (a fresh fetch per sweep, Alg. 4's cost).  Returns int32[R, 128]."""
    rows, lanes = weights2d.shape
    assert lanes == LANES and rows % SUBLANES == 0
    num_tiles = rows // SUBLANES

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(num_tiles, num_iters),
        in_specs=[
            pl.BlockSpec((SUBLANES, LANES), lambda t, b, p, seed: (t, 0)),
            # fresh partition block EVERY (t, b) grid step
            pl.BlockSpec(
                (SUBLANES, LANES), lambda t, b, p, seed: (p[t * num_iters + b], 0)
            ),
        ],
        out_specs=pl.BlockSpec((SUBLANES, LANES), lambda t, b, p, seed: (t, 0)),
        scratch_shapes=[pltpu.VMEM((SUBLANES, LANES), jnp.float32)],
    )
    return pl.pallas_call(
        _make_kernel_c2(num_iters),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((rows, lanes), jnp.int32),
        interpret=interpret,
    )(partitions, seed, weights2d, weights2d)
