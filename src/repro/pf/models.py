"""Benchmark state-space models.

``ungm`` is the univariate nonlinear growth model of the paper's §7
(eqs. 22-23; Gordon/Kitagawa/Arulampalam standard):

    x_t = x_{t-1}/2 + 25 x_{t-1} / (1 + x_{t-1}^2) + 8 cos(1.2 t) + v,
    z_t = x_t^2 / 20 + n,            v ~ N(0, 10),  n ~ N(0, 1).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.pf.filter import StateSpaceModel

_SIGMA_V2 = 10.0  # process-noise variance (paper: sigma_v^2 = 10)
_SIGMA_N2 = 1.0  # measurement-noise variance (paper: sigma_n^2 = 1)


def _transition(key, x, t):
    v = jax.random.normal(key, x.shape, x.dtype) * jnp.sqrt(_SIGMA_V2)
    return x / 2.0 + 25.0 * x / (1.0 + x**2) + 8.0 * jnp.cos(1.2 * t) + v


def _observe(key, x, t):
    n = jax.random.normal(key, x.shape, x.dtype) * jnp.sqrt(_SIGMA_N2)
    return x**2 / 20.0 + n


def _likelihood(z, x, t):
    # p(z | x) up to a constant; normalisation is irrelevant to resampling
    # (the Metropolis family explicitly tolerates unnormalised weights).
    resid = z - x**2 / 20.0
    return jnp.exp(-0.5 * resid**2 / _SIGMA_N2)


def _init(key, n):
    return jax.random.normal(key, (n,)) * jnp.sqrt(_SIGMA_V2)


def ungm() -> StateSpaceModel:
    return StateSpaceModel(
        transition=_transition,
        observe=_observe,
        likelihood=_likelihood,
        init=_init,
        name="ungm",
    )


# ---------------------------------------------------------------- scenarios
def ungm_theta(amp: float = 8.0, obs_var: float = _SIGMA_N2) -> dict:
    """One scenario's parameters for ``ungm_family``: forcing amplitude
    (the paper's fixed 8 cos(1.2 t) term) and measurement-noise variance."""
    return {"amp": jnp.float32(amp), "obs_var": jnp.float32(obs_var)}


def _transition_theta(key, x, t, theta):
    v = jax.random.normal(key, x.shape, x.dtype) * jnp.sqrt(_SIGMA_V2)
    return x / 2.0 + 25.0 * x / (1.0 + x**2) + theta["amp"] * jnp.cos(1.2 * t) + v


def _observe_theta(key, x, t, theta):
    n = jax.random.normal(key, x.shape, x.dtype) * jnp.sqrt(theta["obs_var"])
    return x**2 / 20.0 + n


def _likelihood_theta(z, x, t, theta):
    resid = z - x**2 / 20.0
    return jnp.exp(-0.5 * resid**2 / theta["obs_var"])


def ungm_family() -> StateSpaceModel:
    """UNGM with per-scenario parameters (trailing ``theta`` pytree arg) —
    the scenario-axis model for ``run_filter_bank``: one bank runs S
    differently-forced / differently-noised UNGM instances at once.
    ``theta == ungm_theta()`` reproduces ``ungm`` exactly."""
    return StateSpaceModel(
        transition=_transition_theta,
        observe=_observe_theta,
        likelihood=_likelihood_theta,
        init=_init,
        name="ungm-family",
    )
