"""Statistical validation of the paper's quality claims (§6.1, §6.3).

Small-N, multi-run Monte Carlo on CPU:
  * Megopolis MSE  <  Metropolis MSE            (Fig. 6 MSE rows)
  * Megopolis bias contribution ~ Metropolis's  (Fig. 6 bias rows)
  * C1-PS128 MSE  >>  Megopolis MSE             (Fig. 7 / §6.4)
  * segment size {32, 128, 1024} leaves Megopolis quality unchanged
    (the TPU adaptation argument in DESIGN.md §2)
  * unbiased baselines (multinomial/systematic) have ~zero bias contribution
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    megopolis,
    metropolis,
    metropolis_c1,
    multinomial,
    select_iterations,
    systematic,
)
from repro.core.metrics import bias_contribution, bias_variance, mse, offspring_counts
from repro.core.weightgen import gaussian_weights

N = 1024
K = 48  # Monte Carlo runs per weight sequence


def _offsprings(fn, key, w, num_iters, k_runs=K, **kw):
    outs = []
    jfn = jax.jit(lambda kk: offspring_counts(fn(kk, w, num_iters, **kw), N))
    for t in range(k_runs):
        outs.append(np.asarray(jfn(jax.random.fold_in(key, t))))
    return jnp.asarray(np.stack(outs))


@pytest.fixture(scope="module")
def weights():
    return gaussian_weights(jax.random.PRNGKey(42), N, y=2.0)


@pytest.fixture(scope="module")
def num_iters(weights):
    return int(select_iterations(weights, 0.01))


def test_megopolis_mse_below_metropolis(weights, num_iters):
    key = jax.random.PRNGKey(7)
    o_mego = _offsprings(megopolis, key, weights, num_iters)
    o_metr = _offsprings(metropolis, key, weights, num_iters)
    mse_mego = float(mse(o_mego, weights)) / N
    mse_metr = float(mse(o_metr, weights)) / N
    # Paper Tables 3-4 @ y=2: Megopolis ~0.52, Metropolis ~1.00.
    assert mse_mego < mse_metr, (mse_mego, mse_metr)
    assert mse_mego < 0.8, mse_mego
    assert 0.8 < mse_metr < 1.3, mse_metr


def test_megopolis_bias_matches_metropolis(weights, num_iters):
    key = jax.random.PRNGKey(8)
    b_mego = float(bias_contribution(_offsprings(megopolis, key, weights, num_iters), weights))
    b_metr = float(bias_contribution(_offsprings(metropolis, key, weights, num_iters), weights))
    # Both should be small and comparable (paper: bias contribution of
    # Megopolis == Metropolis).
    assert b_mego < 0.2
    assert abs(b_mego - b_metr) < 0.15, (b_mego, b_metr)


def test_c1_small_partition_inflates_mse(weights, num_iters):
    key = jax.random.PRNGKey(9)
    mse_c1 = float(mse(_offsprings(metropolis_c1, key, weights, num_iters), weights)) / N
    mse_mego = float(mse(_offsprings(megopolis, key, weights, num_iters), weights)) / N
    # Paper Table 5 @ y=2: C1-PS128 ~3.2 vs Megopolis ~0.52 (6x).
    assert mse_c1 > 2.0 * mse_mego, (mse_c1, mse_mego)


def test_segment_size_invariance(weights, num_iters):
    """TPU adaptation: S in {32,128,1024} must not change quality."""
    key = jax.random.PRNGKey(10)
    stats = {}
    for seg in (32, 128, 1024):
        o = _offsprings(megopolis, key, weights, num_iters, segment=seg)
        stats[seg] = (float(mse(o, weights)) / N, float(bias_contribution(o, weights)))
    base_mse = stats[32][0]
    for seg, (m, b) in stats.items():
        assert abs(m - base_mse) < 0.35 * base_mse, stats
        assert b < 0.2, stats


def test_unbiased_baselines_have_low_bias(weights):
    key = jax.random.PRNGKey(11)
    for fn in (multinomial, systematic):
        o = _offsprings(fn, key, weights, 0)
        var, bias_sq, total = bias_variance(o, weights)
        assert float(bias_sq / total) < 0.05, fn.__name__


def test_systematic_lowest_variance(weights):
    """Paper §6.5: systematic < multinomial in MSE; Megopolis in between."""
    key = jax.random.PRNGKey(12)
    num_iters = int(select_iterations(weights, 0.01))
    m_sys = float(mse(_offsprings(systematic, key, weights, 0), weights))
    m_mult = float(mse(_offsprings(multinomial, key, weights, 0), weights))
    m_mego = float(mse(_offsprings(megopolis, key, weights, num_iters), weights))
    assert m_sys < m_mego < m_mult, (m_sys, m_mego, m_mult)


# ------------------------------------------------- kernel-lane quality gate
# §5.1 metrics recomputed per backend: every family's pallas_interpret lane
# must match its geometry-matched reference lane in MSE (and stay low-bias
# where the algorithm is unbiased).  This is what makes kernel quality
# GATED, not assumed — the bit-parity harness (test_backend_parity.py) pins
# arithmetic, this pins statistics.

KN = 2048  # kernel-aligned N (2 VMEM tiles)
KK = 16


def _spec_offsprings(spec, key, w, k_runs=KK):
    r = spec.build()
    outs = []
    for t in range(k_runs):
        outs.append(np.asarray(offspring_counts(r(jax.random.fold_in(key, t), w), KN)))
    return jnp.asarray(np.stack(outs))


@pytest.fixture(scope="module")
def kweights():
    return gaussian_weights(jax.random.PRNGKey(43), KN, y=2.0)


def _kernel_vs_reference_specs(kweights):
    from repro.core.spec import (
        KERNEL_PARTITION_BYTES,
        KERNEL_SEGMENT,
        MegopolisSpec,
        MetropolisC1Spec,
        MetropolisC2Spec,
        MetropolisSpec,
        PrefixSumSpec,
        RejectionSpec,
    )

    b = int(select_iterations(kweights, 0.01))
    pairs = {
        "megopolis": (
            MegopolisSpec(num_iters=b, segment=KERNEL_SEGMENT, backend="pallas_interpret"),
            MegopolisSpec(num_iters=b, segment=KERNEL_SEGMENT),
        ),
        "metropolis": (
            MetropolisSpec(num_iters=b, backend="pallas_interpret"),
            MetropolisSpec(num_iters=b),
        ),
        # geometry-matched reference: the kernel shares its partition at
        # TILE granularity (1024 lanes), so the reference warp must match —
        # warp=32 at the same partition bytes is a finer sharing unit with
        # materially lower variance (the Fig. 7 granularity effect).
        "metropolis_c1": (
            MetropolisC1Spec(
                num_iters=b, partition_size_bytes=KERNEL_PARTITION_BYTES,
                backend="pallas_interpret",
            ),
            MetropolisC1Spec(
                num_iters=b, partition_size_bytes=KERNEL_PARTITION_BYTES,
                warp=KERNEL_SEGMENT,
            ),
        ),
        "metropolis_c2": (
            MetropolisC2Spec(
                num_iters=b, partition_size_bytes=KERNEL_PARTITION_BYTES,
                backend="pallas_interpret",
            ),
            MetropolisC2Spec(
                num_iters=b, partition_size_bytes=KERNEL_PARTITION_BYTES,
                warp=KERNEL_SEGMENT,
            ),
        ),
        "rejection": (
            RejectionSpec(max_iters=64, backend="pallas_interpret"),
            RejectionSpec(max_iters=64),
        ),
    }
    for kind in ("multinomial", "systematic", "improved_systematic", "stratified", "residual"):
        pairs[kind] = (
            PrefixSumSpec(kind=kind, backend="pallas_interpret"),
            PrefixSumSpec(kind=kind),
        )
    return pairs


@pytest.mark.parametrize(
    "family",
    [
        "megopolis",
        "metropolis",
        "metropolis_c1",
        "metropolis_c2",
        "rejection",
        "multinomial",
        "systematic",
        "improved_systematic",
        "stratified",
        "residual",
    ],
)
def test_kernel_backend_statistical_parity(family, kweights):
    kernel_spec, ref_spec = _kernel_vs_reference_specs(kweights)[family]
    key = jax.random.PRNGKey(14)
    o_kern = _spec_offsprings(kernel_spec, key, kweights)
    o_ref = _spec_offsprings(ref_spec, jax.random.fold_in(key, 999), kweights)
    m_kern = float(mse(o_kern, kweights)) / KN
    m_ref = float(mse(o_ref, kweights)) / KN
    assert abs(m_kern - m_ref) < 0.4 * m_ref, (family, m_kern, m_ref)
    # bias gate where the algorithm is (near-)unbiased
    if family in ("rejection", "multinomial", "systematic", "improved_systematic",
                  "stratified", "residual"):
        b_kern = float(bias_contribution(o_kern, kweights))
        assert b_kern < 0.1, (family, b_kern)
    else:
        b_kern = float(bias_contribution(o_kern, kweights))
        assert b_kern < 0.25, (family, b_kern)


# ------------------------------------------ compressed-plane quality gate
# DESIGN.md §14: packing weight/state tiles as bf16 moves the OPERANDS onto
# a coarser grid but leaves selection arithmetic f32 on-chip, so each
# kernel lane's statistics must sit in the same band as its f32 lane.

@pytest.mark.parametrize(
    "family",
    [
        "megopolis",
        "metropolis",
        "metropolis_c1",
        "metropolis_c2",
        "rejection",
        "multinomial",
        "systematic",
        "improved_systematic",
        "stratified",
        "residual",
    ],
)
def test_bf16_plane_statistical_parity(family, kweights):
    import dataclasses

    kernel_spec, _ = _kernel_vs_reference_specs(kweights)[family]
    bf16_spec = dataclasses.replace(kernel_spec, plane_dtype="bfloat16")
    key = jax.random.PRNGKey(15)
    o_bf16 = _spec_offsprings(bf16_spec, key, kweights)
    o_f32 = _spec_offsprings(kernel_spec, key, kweights)
    m_bf16 = float(mse(o_bf16, kweights)) / KN
    m_f32 = float(mse(o_f32, kweights)) / KN
    assert abs(m_bf16 - m_f32) < 0.4 * m_f32, (family, m_bf16, m_f32)
    b_bf16 = float(bias_contribution(o_bf16, kweights))
    limit = 0.1 if family in ("rejection", "multinomial", "systematic",
                              "improved_systematic", "stratified",
                              "residual") else 0.25
    assert b_bf16 < limit, (family, b_bf16)
