"""Render EXPERIMENTS.md §Dry-run / §Roofline markdown from the sweep
records (reads the incremental JSONL so partial sweeps render too).

    python -m benchmarks.dryrun_summary --in experiments/dryrun.jsonl
"""

from __future__ import annotations

import argparse
import json

from repro.configs import SHAPES, get_arch
from repro.launch.hlo import HBM_BW, PEAK_FLOPS
from repro.launch.memmodel import traffic_serve_bytes, traffic_train_bytes


def load(path: str) -> list[dict]:
    rows = {}
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            rows[(r["arch"], r["shape"], r["mesh"])] = r  # last write wins
    return list(rows.values())


def adjusted_terms(r: dict) -> dict:
    """Fusion-aware memory term (DESIGN.md §6.6) computed post-hoc: the
    recorded 'bytes accessed' is pre-fusion op-I/O (~30x HBM traffic)."""
    arch = get_arch(r["arch"])
    shape = SHAPES[r["shape"]]
    multi = r["mesh"].startswith("2x")
    dp = 32 if multi else 16
    micro = max(1, min(16, shape.global_batch // dp)) if shape.kind == "train" else 1
    if shape.kind == "train":
        adj_bytes = traffic_train_bytes(arch.model, global_batch=shape.global_batch,
                                        seq=shape.seq_len, micro=micro, dp=dp, tp=16)
    else:
        adj_bytes = traffic_serve_bytes(arch.model, batch=shape.global_batch,
                                        seq=shape.seq_len, dp=dp, tp=16,
                                        kind=shape.kind)
    ro = r["roofline"]
    t_mem_adj = adj_bytes / HBM_BW
    t_step_adj = max(ro["t_compute_s"], t_mem_adj, ro["t_collective_s"])
    terms = {"compute": ro["t_compute_s"], "memory": t_mem_adj,
             "collective": ro["t_collective_s"]}
    frac = (ro["model_flops"] / (r["chips"] * PEAK_FLOPS * t_step_adj)
            if ro.get("model_flops") and t_step_adj else 0.0)
    return {"t_mem_adj_s": t_mem_adj, "bottleneck_adj": max(terms, key=terms.get),
            "roofline_frac_adj": frac}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--in", dest="inp", default="experiments/dryrun.jsonl")
    args = ap.parse_args(argv)
    rows = load(args.inp)
    ok = [r for r in rows if r.get("ok")]
    fail = [r for r in rows if not r.get("ok")]
    print(f"records: {len(rows)} ({len(ok)} ok, {len(fail)} failed)\n")

    print("| arch | shape | mesh | kind | peak GiB/dev (backend) | TPU-proj GiB | "
          "t_comp ms | t_mem ms (raw) | t_mem ms (adj) | t_coll ms | bottleneck(adj) "
          "| useful | frac (raw) | frac (adj) |")
    print("|" + "---|" * 14)
    for r in sorted(ok, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        ro = r["roofline"]
        adj = adjusted_terms(r)
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['kind']} "
              f"| {r['bytes_per_device']['peak_estimate']/2**30:.1f} "
              f"| {r['hbm_projected']['total']/2**30:.1f} "
              f"| {ro['t_compute_s']*1e3:.2f} | {ro['t_memory_s']*1e3:.2f} "
              f"| {adj['t_mem_adj_s']*1e3:.2f} "
              f"| {ro['t_collective_s']*1e3:.2f} | {adj['bottleneck_adj']} "
              f"| {ro['useful_flops_ratio']:.3f} | {ro['roofline_fraction']:.4f} "
              f"| {adj['roofline_frac_adj']:.4f} |")
    if fail:
        print("\nfailed cells:")
        for r in fail:
            print(f"  {r['arch']} x {r['shape']} [{r['mesh']}]: {r.get('error','')[:100]}")


if __name__ == "__main__":
    main()
