"""Sharding assembly: param FSDP transform + per-cell state shardings.

``param_pspecs`` (models/transformer.py) gives the Megatron TP layout.
``fsdp_pspecs`` then shards each tensor's FIRST free divisible dim over the
``data`` axis (2-D sharding, MaxText-style ``fsdp``), which is what lets a
400B-param arch fit 16 GB/chip HBM at 256 chips: params split over all 256
chips instead of 16.  XLA SPMD inserts the per-layer all-gather
automatically; with remat the re-gather in the backward pass is the
standard FSDP traffic pattern.
"""

from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import ModelConfig, init_params, param_pspecs


def _with_fsdp(spec: P, shape, fsdp_axis: str, fsdp_size: int) -> P:
    parts = list(spec) + [None] * (len(shape) - len(spec))
    if len(shape) < 2:  # keep small vectors (norms, biases) replicated
        return P(*parts)
    for d, (ax, dim) in enumerate(zip(parts, shape)):
        if ax is None and dim % fsdp_size == 0 and dim >= fsdp_size:
            parts[d] = fsdp_axis
            return P(*parts)
    return P(*parts)


def fsdp_pspecs(pspec_tree, shape_tree, *, fsdp_axis: str = "data", fsdp_size: int = 16):
    return jax.tree.map(
        lambda s, sh: _with_fsdp(s, sh.shape, fsdp_axis, fsdp_size),
        pspec_tree,
        shape_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def fsdp_axes(mesh):
    """FSDP shards over every non-'model' axis — hierarchical across pods
    on the multi-pod mesh (2x the param/optimizer shards; what lets the
    400B arch fit 16 GiB chips at 2 pods, EXPERIMENTS.md §Dry-run)."""
    axes = tuple(a for a in mesh.axis_names if a != "model")
    size = 1
    for a in axes:
        size *= int(mesh.shape[a])
    return (axes if len(axes) > 1 else axes[0]), size


def model_pspecs(cfg: ModelConfig, mesh, *, fsdp: bool = True):
    """Final param PartitionSpec tree for ``mesh`` (TP + optional FSDP)."""
    specs = param_pspecs(cfg)
    if fsdp and "data" in mesh.axis_names:
        shapes = jax.eval_shape(lambda k: init_params(k, cfg), jax.random.PRNGKey(0))
        axes, size = fsdp_axes(mesh)
        specs = fsdp_pspecs(specs, shapes, fsdp_axis=axes, fsdp_size=size)
    return specs


def named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree, is_leaf=lambda x: isinstance(x, P)
    )
