"""Megopolis resampling — Pallas TPU kernel (the paper's Alg. 5, TPU-native).

Memory-access contract (DESIGN.md §2):

  * particle weights live in HBM as ``f32[R, 128]`` (R = N/128 rows);
  * the coalescing segment is one (8, 128) f32 VMEM tile (SEG = 1024
    particles, the TPU analogue of the paper's 32-thread warp segment);
  * grid = (num_tiles, B), iteration axis innermost.  For grid step
    (t, b) the *comparison* block index is computed from a scalar-prefetched
    offset table: ``(t + o[b] // SEG) mod num_tiles`` — so every load the
    kernel ever issues is a whole, aligned, contiguous tile (the paper's
    Fig. 4b "wrapped sequential" pattern, 0 wasted words);
  * the intra-segment wrap ``(i + o[b]) mod SEG`` is a register-level flat
    roll of the tile — no extra memory traffic;
  * per-(particle, iteration) uniforms come from a stateless counter hash
    (no CURAND state loads/stores — beyond-paper win, see EXPERIMENTS §Perf);
  * the current ancestor's weight ``w[k]`` is carried by VALUE in a VMEM
    scratch accumulator (never re-fetched), exactly like the register-carried
    ``w_k`` in the CUDA original.

Validated in ``interpret=True`` mode bit-exactly against ``ref.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import TILE, flat_roll, hash_uniform

SUBLANES = 8
LANES = 128
SEG = TILE  # 1024 particles = one (8,128) f32 tile


def _kernel(offsets_ref, seed_ref, w_own_ref, w_cmp_ref, k_ref, wk_ref):
    """Grid step (t, b): one accept/reject sweep of tile t at iteration b."""
    t = pl.program_id(0)
    b = pl.program_id(1)
    o = offsets_ref[b]
    seed = seed_ref[0]

    row = lax.broadcasted_iota(jnp.int32, (SUBLANES, LANES), 0)
    col = lax.broadcasted_iota(jnp.int32, (SUBLANES, LANES), 1)
    lane = row * LANES + col  # position p within the tile
    i_global = t * SEG + lane  # particle index (Alg. 5 line 5)

    @pl.when(b == 0)
    def _init():
        k_ref[...] = i_global  # k <- i           (Alg. 5 line 6)
        wk_ref[...] = w_own_ref[...]  # w[k] by value (register carry)

    n_total = pl.num_programs(0) * SEG
    # j = i_aligned + o_aligned + (i + o) mod SEG   (Alg. 5 lines 7-11)
    # block fetch already applied i_aligned + o_aligned; flat-roll applies
    # the intra-segment wrap.
    w_j = flat_roll(w_cmp_ref[...], o % SEG)
    o_aligned = o - (o % SEG)
    j_global = (t * SEG + o_aligned + (i_global + o) % SEG) % n_total

    u = hash_uniform(seed, i_global, b, dtype=w_j.dtype)
    accept = u * wk_ref[...] <= w_j  # u <= w[j]/w[k]  (line 13)
    k_ref[...] = jnp.where(accept, j_global, k_ref[...])
    wk_ref[...] = jnp.where(accept, w_j, wk_ref[...])


@functools.partial(jax.jit, static_argnames=("num_iters", "interpret"))
def megopolis_pallas(
    weights2d: jnp.ndarray,
    offsets: jnp.ndarray,
    seed: jnp.ndarray,
    *,
    num_iters: int,
    interpret: bool = True,
) -> jnp.ndarray:
    """Raw pallas_call. ``weights2d``: f32[R, 128] with R % 8 == 0;
    ``offsets``: int32[B]; ``seed``: uint32[1].  Returns int32[R, 128]."""
    rows, lanes = weights2d.shape
    assert lanes == LANES and rows % SUBLANES == 0
    num_tiles = rows // SUBLANES

    def _cmp_index(t, b, offs, seed):
        # aligned block chosen by the shared offset (wraps mod num_tiles)
        return (t + offs[b] // SEG) % num_tiles, 0

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # offsets + seed live in SMEM, prefetched
        grid=(num_tiles, num_iters),
        in_specs=[
            # own tile: block index constant in b -> fetched once per t
            pl.BlockSpec((SUBLANES, LANES), lambda t, b, offs, seed: (t, 0)),
            pl.BlockSpec((SUBLANES, LANES), _cmp_index),
        ],
        out_specs=pl.BlockSpec((SUBLANES, LANES), lambda t, b, offs, seed: (t, 0)),
        scratch_shapes=[pltpu.VMEM((SUBLANES, LANES), weights2d.dtype)],
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((rows, lanes), jnp.int32),
        interpret=interpret,
    )(offsets, seed, weights2d, weights2d)
