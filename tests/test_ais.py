"""Adaptive SMC sampler (repro/ais, DESIGN.md §10): logZ quality gate,
schedule properties, move kernels, and the §4 bank bit-identity contract.

The headline gate: ``run_smc_sampler`` must recover the ANALYTIC log
normalising constant of the closed-form targets for every resampler
family on both the reference and the interpret-mode kernel backends —
the first test in the repo that scores resampling quality against ground
truth rather than against another resampler.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ais import (
    SMCSamplerConfig,
    banana,
    conditional_ess,
    correlated_gaussian,
    gaussian_family,
    gaussian_mixture,
    gaussian_theta,
    geometric_schedule,
    isotropic_gaussian,
    logistic_regression,
    mala,
    next_temperature,
    random_walk_metropolis,
    run_smc_sampler,
    run_smc_sampler_bank,
)
from repro.core.metrics import effective_sample_size
from repro.core.spec import (
    KERNEL_SEGMENT,
    MegopolisSpec,
    MetropolisSpec,
    spec_for_backend,
)

# Kernel tile contract: pallas backends need N % 1024 == 0.
N = 1024

FAMILIES = ("megopolis", "metropolis", "rejection", "systematic")


# ----------------------------------------------------------- logZ quality gate

@pytest.mark.parametrize("backend", ("reference", "pallas_interpret"))
@pytest.mark.parametrize("family", FAMILIES)
def test_logz_recovers_analytic_truth(family, backend):
    """Every resampler family, on the reference AND the interpret-mode
    kernel backend, must anneal to the analytic logZ of the Gaussian and
    mixture targets within the rtol gate."""
    temps = 12 if backend == "reference" else 8
    cfg = SMCSamplerConfig(num_particles=N, num_temps=temps,
                           resampler=spec_for_backend(family, backend))
    for target in (isotropic_gaussian(dim=2), gaussian_mixture()):
        out = jax.jit(lambda k, t=target: run_smc_sampler(k, t, cfg))(
            jax.random.PRNGKey(0)
        )
        np.testing.assert_allclose(
            float(out["log_z"]), target.log_z, rtol=0.1, atol=0.1,
            err_msg=f"{family}/{backend} missed logZ on {target.name}",
        )
        assert float(np.asarray(out["betas"])[-1]) == 1.0
        a = np.asarray(out["particles"])
        assert a.shape == (N, target.dim) and np.all(np.isfinite(a))


@pytest.mark.parametrize("backend", ("reference", "pallas_interpret"))
@pytest.mark.parametrize("family", FAMILIES)
def test_logz_recovers_analytic_truth_bf16_planes(family, backend):
    """DESIGN.md §14 quality gate: with the weight/state tiles compressed
    to bf16 the sampler must still anneal to the analytic logZ within the
    SAME rtol gate as the f32 lanes — selection stays f32 on-chip, only
    the stored operands coarsen."""
    temps = 12 if backend == "reference" else 8
    cfg = SMCSamplerConfig(
        num_particles=N, num_temps=temps,
        resampler=spec_for_backend(family, backend, plane_dtype="bfloat16"),
    )
    target = isotropic_gaussian(dim=2)
    out = jax.jit(lambda k: run_smc_sampler(k, target, cfg))(jax.random.PRNGKey(0))
    np.testing.assert_allclose(
        float(out["log_z"]), target.log_z, rtol=0.1, atol=0.1,
        err_msg=f"{family}/{backend}@bfloat16 missed logZ on {target.name}",
    )
    assert np.all(np.isfinite(np.asarray(out["particles"])))


def test_logz_on_banana_and_correlated():
    """The non-Gaussian closed forms (volume-preserving shear, correlated
    precision) hold too — the analytic-logZ story is not Gaussian-only."""
    cfg = SMCSamplerConfig(num_particles=N, num_temps=16, resampler="systematic")
    for target in (banana(), correlated_gaussian()):
        out = jax.jit(lambda k, t=target: run_smc_sampler(k, t, cfg))(
            jax.random.PRNGKey(1)
        )
        np.testing.assert_allclose(float(out["log_z"]), target.log_z,
                                   rtol=0.1, atol=0.15)


def test_adaptive_schedule_and_mala_recover_logz():
    """The adaptive (CESS-bisection) ladder and the MALA move kernel are
    drop-in quality-equivalent on the analytic target."""
    target = isotropic_gaussian(dim=2)
    for kw in ({"schedule": "adaptive"}, {"move": "mala"}):
        cfg = SMCSamplerConfig(num_particles=N, num_temps=16,
                               resampler="systematic", **kw)
        out = jax.jit(lambda k: run_smc_sampler(k, target, cfg))(
            jax.random.PRNGKey(2)
        )
        np.testing.assert_allclose(float(out["log_z"]), target.log_z,
                                   rtol=0.1, atol=0.1)
        assert float(np.asarray(out["betas"])[-1]) == 1.0


def test_logistic_regression_target_runs():
    """The no-analytic-logZ end of the spectrum: finite estimate, finite
    particles, schedule completes."""
    target = logistic_regression(num_data=32, dim=3)
    cfg = SMCSamplerConfig(num_particles=256, num_temps=10, resampler="systematic")
    out = jax.jit(lambda k: run_smc_sampler(k, target, cfg))(jax.random.PRNGKey(3))
    assert np.isfinite(float(out["log_z"]))
    assert np.all(np.isfinite(np.asarray(out["particles"])))
    assert out["particles"].shape == (256, 3)


# ------------------------------------------------------- bank bit-identity (§4)

@pytest.mark.parametrize("schedule", ("geometric", "adaptive"))
def test_bank_rows_bit_identical_to_single(schedule):
    """run_smc_sampler_bank row b == run_smc_sampler with split key b and
    theta row b — every output leaf, bit-for-bit (the DESIGN.md §4
    contract, same as run_filter_bank)."""
    fam = gaussian_family(dim=2)
    scenarios = [gaussian_theta(mean=0.5 * s, sigma=1.0 + 0.25 * s) for s in range(3)]
    thetas = jax.tree.map(lambda *xs: jnp.stack(xs), *scenarios)
    cfg = SMCSamplerConfig(num_particles=256, num_temps=8,
                           resampler="megopolis", schedule=schedule)
    key = jax.random.PRNGKey(7)
    bank = jax.jit(lambda k: run_smc_sampler_bank(k, fam, cfg, thetas=thetas))(key)
    keys = jax.random.split(key, 3)
    for b in range(3):
        th = jax.tree.map(lambda leaf: leaf[b], thetas)
        single = jax.jit(lambda k: run_smc_sampler(k, fam, cfg, theta=th))(keys[b])
        for name, leaf in single.items():
            np.testing.assert_array_equal(
                np.asarray(bank[name][b]), np.asarray(leaf),
                err_msg=f"bank row {b} diverged from single call on {name!r}",
            )


def test_bank_iid_repeats_bit_identical_on_kernel_backend():
    """The num_scenarios (Monte-Carlo repeats) path, with the resampling
    stage on the interpret-mode kernel: still bit-identical per row."""
    target = isotropic_gaussian(dim=2)
    spec = MegopolisSpec(num_iters=16, segment=KERNEL_SEGMENT,
                         backend="pallas_interpret")
    cfg = SMCSamplerConfig(num_particles=N, num_temps=6, resampler=spec)
    key = jax.random.PRNGKey(11)
    bank = jax.jit(lambda k: run_smc_sampler_bank(k, target, cfg, num_scenarios=2))(key)
    keys = jax.random.split(key, 2)
    single = jax.jit(lambda k: run_smc_sampler(k, target, cfg))(keys[1])
    for name, leaf in single.items():
        np.testing.assert_array_equal(np.asarray(bank[name][1]), np.asarray(leaf))


def test_bank_argument_validation():
    target = isotropic_gaussian(dim=2)
    cfg = SMCSamplerConfig(num_particles=64, num_temps=2, resampler="systematic")
    with pytest.raises(ValueError, match="thetas.*or.*num_scenarios"):
        run_smc_sampler_bank(jax.random.PRNGKey(0), target, cfg)
    thetas = jax.tree.map(lambda *xs: jnp.stack(xs),
                          *[gaussian_theta(0.0), gaussian_theta(1.0)])
    with pytest.raises(ValueError, match="disagrees"):
        run_smc_sampler_bank(jax.random.PRNGKey(0), gaussian_family(), cfg,
                             thetas=thetas, num_scenarios=3)


# ----------------------------------------------------------------- schedules

def test_geometric_schedule_shape_and_endpoint():
    betas = np.asarray(geometric_schedule(16, beta_min=1e-2))
    assert betas.shape == (16,)
    assert np.all(np.diff(betas) > 0)
    assert betas[-1] == 1.0
    assert betas[0] == pytest.approx(1e-2 ** (1 - 1 / 16))
    with pytest.raises(ValueError, match="num_temps"):
        geometric_schedule(0)
    with pytest.raises(ValueError, match="beta_min"):
        geometric_schedule(8, beta_min=1.5)


def test_conditional_ess_is_n_at_zero_step():
    """CESS is measured against the CURRENT weights, so a zero incremental
    step always scores N — even when the accumulated weights are already
    degenerate.  This is what makes the bisection step strictly positive."""
    log_w = jnp.asarray([0.0, -50.0, -50.0, -50.0])
    cess = float(conditional_ess(log_w, jnp.zeros(4)))
    assert cess == pytest.approx(4.0)


def test_sampler_config_validation():
    with pytest.raises(ValueError, match="did you mean 'adaptive'"):
        SMCSamplerConfig(num_particles=8, schedule="adaptve")
    with pytest.raises(ValueError, match="did you mean 'mala'"):
        SMCSamplerConfig(num_particles=8, move="malla")
    with pytest.raises(ValueError, match="ess_threshold"):
        SMCSamplerConfig(num_particles=8, ess_threshold=0.0)
    with pytest.raises(ValueError, match="num_temps"):
        SMCSamplerConfig(num_particles=8, num_temps=0)
    with pytest.raises(ValueError, match="target_cess"):
        SMCSamplerConfig(num_particles=8, target_cess=1.0)
    with pytest.raises(ValueError, match="num_move_steps"):
        SMCSamplerConfig(num_particles=8, num_move_steps=0)
    # spec coercion: a typed spec rides through untouched; a name picks up
    # num_iters only where the family has the field
    spec = MetropolisSpec(num_iters=4)
    assert SMCSamplerConfig(num_particles=8, resampler=spec).resampler_spec() is spec
    assert SMCSamplerConfig(num_particles=8, resampler="megopolis",
                            num_iters=9).resampler_spec().num_iters == 9
    assert SMCSamplerConfig(num_particles=8,
                            resampler="systematic").resampler_spec().name == "systematic"


# ------------------------------------------------------------------ move kernels

@pytest.mark.parametrize("move", (random_walk_metropolis, mala))
def test_moves_preserve_gaussian_invariant_distribution(move):
    """A long chain of sweeps against a standard normal keeps first/second
    moments (the kernels are π-invariant MH corrections, not heuristics)."""
    def log_prob(x):
        return -0.5 * jnp.sum(jnp.square(x), axis=-1)

    x0 = jax.random.normal(jax.random.PRNGKey(0), (2048, 2))
    x, accept = jax.jit(
        lambda k, x: move(k, x, log_prob, jnp.float32(0.8), 20)
    )(jax.random.PRNGKey(1), x0)
    a = np.asarray(x)
    assert 0.05 < float(accept) <= 1.0
    assert abs(a.mean()) < 0.1
    assert abs(a.std() - 1.0) < 0.1


# ------------------------------------------------- ESS helper (the dedup hoist)

def test_effective_sample_size_shared_helper():
    """One ESS implementation (core/metrics.py) serves decode, the filter
    diagnostic, and the sampler."""
    from repro.pf.filter import ParticleFilter, run_filter, simulate
    from repro.pf.models import ungm
    from repro.smc import ess as decode_ess

    assert decode_ess is effective_sample_size
    assert float(effective_sample_size(jnp.zeros(10))) == pytest.approx(10.0)
    concentrated = jnp.log(jnp.asarray([1e-8] * 9 + [1.0]))
    assert float(effective_sample_size(concentrated)) == pytest.approx(1.0, abs=1e-3)
    # batched axis semantics (the bank path)
    batch = jnp.stack([jnp.zeros(8), jnp.log(jnp.asarray([1e-9] * 7 + [1.0]))])
    got = np.asarray(effective_sample_size(batch, axis=-1))
    np.testing.assert_allclose(got, [8.0, 1.0], atol=1e-3)
    # the filter's opt-in ESS diagnostic rides the same helper
    model = ungm()
    _, obs = simulate(jax.random.PRNGKey(0), model, 5)
    pf = ParticleFilter(model, 128, resampler="systematic")
    ests, ess_hist = run_filter(jax.random.PRNGKey(1), pf, obs, with_ess=True)
    assert ests.shape == (5,) and ess_hist.shape == (5,)
    assert np.all(np.asarray(ess_hist) > 0) and np.all(np.asarray(ess_hist) <= 1.0)


# ------------------------------------- adaptive-schedule property test (hypothesis)

def _check_adaptive_ladder(seed: int, scale: float, target: float):
    """For a random tilt/weight profile the ESS-bisection ladder is strictly
    increasing, reaches exactly 1.0, and every intermediate step realises a
    conditional ESS within tolerance of the target fraction."""
    k = jax.random.PRNGKey(seed)
    n = 256
    delta = scale * jax.random.normal(k, (n,))
    log_w = 0.5 * jax.random.normal(jax.random.fold_in(k, 1), (n,))
    beta = 0.0
    for _ in range(500):
        nxt = float(next_temperature(log_w, delta, beta, target))
        assert nxt > beta, "schedule must be strictly increasing"
        assert nxt <= 1.0
        cess = float(conditional_ess(log_w, (nxt - beta) * delta)) / n
        # bisection invariant: realised CESS never below target (up to tol)
        assert cess >= target - 1e-3
        if nxt < 1.0:
            # and not meaningfully above it either — the step is maximal
            assert cess <= target + 0.1
        beta = nxt
        if beta == 1.0:
            break
    assert beta == 1.0, "schedule must reach the target temperature"


try:
    from hypothesis import given, settings, strategies as st

    @given(seed=st.integers(0, 2**30), scale=st.floats(0.1, 16.0),
           target=st.sampled_from([0.75, 0.9, 0.95]))
    @settings(max_examples=25, deadline=None)
    def test_adaptive_temperatures_increase_and_hit_target_cess(seed, scale, target):
        _check_adaptive_ladder(seed, scale, target)

except ImportError:
    # hypothesis absent (CI installs it): exercise the same property over a
    # pinned profile grid instead of skipping the invariant entirely.
    @pytest.mark.parametrize("seed,scale,target",
                             [(0, 0.1, 0.9), (1, 4.0, 0.75), (2, 16.0, 0.95),
                              (3, 8.0, 0.9)])
    def test_adaptive_temperatures_increase_and_hit_target_cess(seed, scale, target):
        _check_adaptive_ladder(seed, scale, target)
