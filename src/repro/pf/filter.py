"""SIR / bootstrap particle filter (paper Algorithms 1 and 6).

The modified SIR filter (Alg. 6) drops weight normalisation — the
Metropolis-family resamplers only use weight *ratios* — and estimates the
state as the post-resampling particle mean (uniform weights).

Three execution modes:
  * ``run_filter``: fully jitted ``lax.scan`` over time steps (production).
  * ``run_filter_bank``: S independent filters — a SCENARIO axis of
    observation streams, model parameters and keys — under ONE jitted scan
    whose resampling step is a single batched launch (DESIGN.md §4).
  * ``run_filter_timed``: per-stage host timing (predict+update / resample /
    estimate) for the paper's Resample-Ratio metric (eq. 25).

Model callables take ``(key, x, t)``; scenario-parameterised models take a
trailing ``theta`` pytree (``(key, x, t, theta)``), enabling per-scenario
dynamics in the bank (see ``repro.pf.models.ungm_family``).
"""

from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Callable, Optional, Union

import jax
import jax.numpy as jnp

from repro.core.metrics import (
    degenerate_weights,
    effective_sample_size,
    log_mean_weight,
    log_weights_from_linear,
    max_normalised_weight,
    normalise_log_weights,
    unique_ancestor_count,
)
from repro.core.resamplers.batched import split_batch_keys
from repro.core.spec import ResamplerSpec, coerce_spec
from repro.obs.stats import StepStats
from repro.obs.telemetry import Telemetry


@dataclasses.dataclass(frozen=True)
class StateSpaceModel:
    transition: Callable  # (key, x[N], t) -> x[N]
    observe: Callable  # (key, x[], t) -> z[]       (for ground-truth sim)
    likelihood: Callable  # (z, x[N], t) -> w[N]       (unnormalised)
    init: Callable  # (key, n) -> x[N]
    name: str = "model"


@dataclasses.dataclass(frozen=True)
class ParticleFilter:
    """SIR filter config.  ``resampler`` is a registry name or a typed
    ``ResamplerSpec`` (DESIGN.md §9); a spec carries its own hyperparameters
    and backend, so combining one with ``num_iters`` / ``resampler_kwargs``
    raises.  The spec resolves (and validates) eagerly at construction."""

    model: StateSpaceModel
    num_particles: int
    resampler: Union[str, ResamplerSpec] = "megopolis"
    # B for string-named resamplers; None defaults to 30, the fixed
    # application prior of paper §7.  Must stay unset when ``resampler`` is
    # already a spec (the spec carries its own count).
    num_iters: Union[int, str, None] = None
    # None (default) keeps Alg. 6's unconditional per-step resample.  A
    # float in [0, 1] switches the filter to classic conditional SIR: carry
    # log-weights across steps and resample only when the normalised ESS
    # drops below the threshold — one fused ``Resampler.step`` launch per
    # time step on kernel backends (DESIGN.md §12).
    ess_threshold: Optional[float] = None
    resampler_kwargs: tuple = ()  # deprecated: pre-spec hyperparameter channel

    def __post_init__(self):
        if self.ess_threshold is not None and not 0.0 <= self.ess_threshold <= 1.0:
            raise ValueError(
                "ParticleFilter.ess_threshold must be in [0, 1] (a normalised "
                f"ESS fraction) or None for Alg. 6; got {self.ess_threshold}"
            )
        if isinstance(self.resampler, ResamplerSpec):
            if self.resampler_kwargs:
                raise ValueError(
                    "ParticleFilter: pass hyperparameters inside the ResamplerSpec, "
                    "not via the deprecated resampler_kwargs tuple"
                )
            if self.num_iters is not None:
                raise ValueError(
                    "ParticleFilter: num_iters is ignored when resampler is a "
                    "ResamplerSpec — set it inside the spec "
                    "(e.g. MegopolisSpec(num_iters=...))"
                )
            spec = self.resampler
        else:
            if self.resampler_kwargs:
                warnings.warn(
                    "ParticleFilter.resampler_kwargs is deprecated; pass a "
                    "ResamplerSpec as `resampler` instead (e.g. "
                    "MetropolisC1Spec(num_iters=30, partition_size_bytes=128))",
                    DeprecationWarning,
                    stacklevel=3,
                )
            iters = 30 if self.num_iters is None else self.num_iters
            spec = coerce_spec(self.resampler, num_iters=iters)
            spec = spec.replace(**dict(self.resampler_kwargs))
        object.__setattr__(self, "_built", spec.build())

    @property
    def spec(self) -> ResamplerSpec:
        """The resolved resampler spec this filter runs."""
        return self._built.spec

    def step(self, key, particles, z, t, theta=None):
        """One SIR step (Alg. 6): returns
        ``(particles', estimate, weights, ancestors)``.

        Stage 2 runs the FUSED resample+gather path (``Resampler.apply``,
        DESIGN.md §11): on kernel backends the ancestor indices never
        round-trip through HBM — the kernel selects the ancestor and copies
        its state in VMEM; on reference/xla the same call is the classic
        index-then-gather composition, bit-identically.  The ancestors are
        the launch's own int32 output (telemetry composes survivor counts
        from them, DESIGN.md §15); callers that drop them compile the
        pre-telemetry program unchanged."""
        k_pred, k_res = jax.random.split(key)
        # Stage 1: predict + update
        x = _call(self.model.transition, k_pred, particles, t, theta=theta)
        w = _call(self.model.likelihood, z, x, t, theta=theta)
        # Stage 2: fused resample + ancestor gather
        x_bar, ancestors = self._built.apply(k_res, w, x)
        # Stage 3: estimate (uniform post-resampling weights)
        return x_bar, jnp.mean(x_bar), w, ancestors

    def step_conditional(self, key, particles, log_w, z, t, theta=None):
        """One conditional-SIR step (classic ESS-triggered SIR, DESIGN.md
        §12): returns ``(particles', log_w', estimate, stats)`` with
        ``stats`` the step's ``StepStats`` record (DESIGN.md §15).

        Log-weights accumulate across steps; stage 2 is the FUSED
        ``Resampler.step`` — normalise, ESS, the resample-or-not branch and
        the state copy in ONE launch on kernel backends.  The estimate is
        the weighted posterior mean over the PRE-resample weights (the
        conditional filter's weights are not uniform after a skipped
        resample, so the Alg. 6 plain mean would be biased)."""
        k_pred, k_res = jax.random.split(key)
        # Stage 1: predict + update (log-weight accumulation)
        x = _call(self.model.transition, k_pred, particles, t, theta=theta)
        w = _call(self.model.likelihood, z, x, t, theta=theta)
        log_w = log_w + log_weights_from_linear(w)
        # Stage 3 first: the estimate consumes the pre-resample weights
        wn = normalise_log_weights(log_w)
        est = jnp.sum(wn * x) / jnp.sum(wn)
        # Stage 2: fused normalise → ESS → conditional resample → gather
        x_bar, _, stats = self._built.step(
            k_res, log_w, x, self.ess_threshold
        )
        log_w = jnp.where(
            stats.ess_norm < self.ess_threshold, jnp.zeros_like(log_w), log_w
        )
        return x_bar, log_w, est, stats


def _call(fn, *args, theta=None):
    """Invoke a model callable, appending ``theta`` only when given — keeps
    the plain ``(key, x, t)`` model API untouched."""
    return fn(*args) if theta is None else fn(*args, theta)


def simulate(key, model: StateSpaceModel, num_steps: int, theta=None):
    """Ground-truth trajectory + observations."""

    def body(carry, t):
        x, k = carry
        k, k1, k2 = jax.random.split(k, 3)
        x = _call(model.transition, k1, x, t, theta=theta)
        z = _call(model.observe, k2, x, t, theta=theta)
        return (x, k), (x, z)

    k0, key = jax.random.split(key)
    x0 = model.init(k0, 1)[0]
    _, (xs, zs) = jax.lax.scan(body, (x0, key), jnp.arange(1, num_steps + 1, dtype=jnp.float32))
    return xs, zs


def _alg6_step_stats(w: jnp.ndarray, ancestors: jnp.ndarray,
                     axis: int = -1) -> StepStats:
    """Compose the ``StepStats`` record of an UNCONDITIONAL (Alg. 6) step
    from the values the step already produced: the resample always fires
    (``resampled ≡ 1``), so the evidence increment is unconditionally
    ``log_mean_weight``.  Uses the same ``core.metrics`` helpers the fused
    step kernels mirror, so the record means the same thing in both filter
    modes.  Batched inputs (``[S, N]`` weights + ``[S, N]`` ancestors)
    yield batched ``[S]`` records."""
    lw = log_weights_from_linear(w)
    n = w.shape[axis]
    return StepStats(
        ess_norm=effective_sample_size(lw, axis=axis) / jnp.float32(n),
        log_evidence_incr=log_mean_weight(lw, axis=axis),
        resampled=jnp.ones(w.shape[:-1], jnp.float32),
        max_weight=max_normalised_weight(lw, axis=axis),
        survivors=unique_ancestor_count(ancestors, axis=axis),
        degenerate=degenerate_weights(w, axis=axis),
    )


def run_filter(key, pf: ParticleFilter, observations: jnp.ndarray, theta=None,
               telemetry: bool = False, with_ess: bool = False,
               checkpoint=None):
    """Jitted scan over time; returns estimates f32[T].

    ``checkpoint`` (a ``repro.resilience.CheckpointPolicy``) makes the run
    crash-consistent: the time scan executes in snapshot-period chunks of
    the SAME jitted body, durably persisting the scan carry + outputs after
    each chunk and resuming from the latest snapshot — estimates and
    telemetry stay bit-identical to the monolithic scan (DESIGN.md §16).

    ``telemetry=True`` additionally returns a ``Telemetry`` record whose
    ``steps`` field holds one ``StepStats`` per time step (every field
    f32/int32[T] — DESIGN.md §15): the resample trigger diagnostics
    (ess_norm, max_weight), the evidence ledger (log_evidence_incr), and
    the degeneracy counters (resampled, survivors).  With the default
    ``pf.ess_threshold=None`` (Alg. 6, unconditional resample) the stats
    are composed from the values the step already computes; with a
    threshold set the filter runs classic conditional SIR
    (``step_conditional``) and the record IS the fused step's own output —
    still one ``Resampler.step`` launch per time step on kernel backends
    (DESIGN.md §12).  Telemetry never changes the computation: same launch
    counts, bit-identical estimates (analyzer pass 6); disabled, it is
    structurally absent from the jaxpr.

    ``with_ess=True`` is the DEPRECATED pre-telemetry diagnostic: it still
    returns the old ``(estimates, ess_norm[T])`` pair (bit-identical to
    ``Telemetry.steps.ess_norm``) with a ``DeprecationWarning``.

    Peak-memory note (DESIGN.md §11): the resample stage is the fused
    ``Resampler.apply`` (or ``Resampler.step``), so the scan body's live
    set at the resample boundary is the in/out particle buffers only — no
    int32 ancestor vector, and (unless telemetry asks for it) no weight
    buffer escapes the step into the scan's stacked outputs.  The
    accounting lives in ``launch/memmodel.py::resample_step_bytes``.
    """
    if with_ess:
        if telemetry:
            raise ValueError(
                "run_filter: pass telemetry=True OR the deprecated "
                "with_ess=True, not both"
            )
        warnings.warn(
            "run_filter(with_ess=True) is deprecated; use telemetry=True and "
            "read Telemetry.steps.ess_norm (DESIGN.md §15)",
            DeprecationWarning,
            stacklevel=2,
        )
    conditional = pf.ess_threshold is not None
    record = telemetry or with_ess

    def body(carry, inp):
        particles, log_w, k = carry
        t, z = inp
        k, ks = jax.random.split(k)
        if conditional:
            particles, log_w, est, stats = pf.step_conditional(
                ks, particles, log_w, z, t, theta=theta
            )
            out = (est, stats) if record else est
            return (particles, log_w, k), out
        particles, est, w, ancestors = pf.step(ks, particles, z, t, theta=theta)
        if not record:
            # Don't thread the pre-resample weight buffer into the scan
            # outputs when nobody consumes it — the diagnostic is opt-in.
            return (particles, log_w, k), est
        return (particles, log_w, k), (est, _alg6_step_stats(w, ancestors))

    k0, key = jax.random.split(key)
    particles = pf.model.init(k0, pf.num_particles)
    log_w0 = jnp.zeros((pf.num_particles,), jnp.float32)
    ts = jnp.arange(1, observations.shape[0] + 1, dtype=jnp.float32)
    if checkpoint is None:
        _, out = jax.lax.scan(body, (particles, log_w0, key), (ts, observations))
    else:
        from repro.resilience.checkpointing import checkpointed_scan

        _, out = checkpointed_scan(
            body, (particles, log_w0, key), (ts, observations), checkpoint
        )
    if not record:
        return out
    ests, steps = out
    if with_ess:
        return ests, steps.ess_norm
    return ests, Telemetry(steps=steps)


def run_filter_bank(key, pf: ParticleFilter, observations: jnp.ndarray, thetas=None,
                    telemetry: bool = False):
    """Run S independent filters in ONE jitted scan; returns estimates f32[S, T].

    ``telemetry=True`` additionally returns a ``Telemetry`` record with one
    ``StepStats`` per scenario per step (every field ``[S, T]``, matching
    the estimate layout); row ``s`` is bit-identical to the single filter's
    record.  Off (the default), the record is structurally absent from the
    jaxpr (DESIGN.md §15).

    The scenario axis (DESIGN.md §4): ``observations`` is ``[S, T]`` — one
    observation stream per scenario; ``thetas`` (optional) is a pytree whose
    leaves carry a leading ``[S]`` axis of per-scenario model parameters.
    ``key`` is split once along the scenario axis (the batched-API key
    contract), so row ``s`` of the result is bit-identical to
    ``run_filter(split(key, S)[s], pf, observations[s], thetas[s])`` — a
    bank is a drop-in replacement for the naive Python loop of S filters,
    at one device launch per pipeline stage instead of S.

    Every stage is batched: predict/update via vmap over the scenario axis,
    resampling via the registry's batched path (one launch over the whole
    ``[S, N]`` weight bank).  With ``pf.ess_threshold`` set the bank runs
    conditional SIR: the resample stage is ONE ``Resampler.step_rows``
    launch and each scenario takes its OWN resample-or-not branch on-chip
    (DESIGN.md §12) — row ``s`` still bit-identical to the single filter.
    """
    num_s = observations.shape[0]
    resampler = pf._built
    conditional = pf.ess_threshold is not None
    keys = split_batch_keys(key, num_s)

    def init_one(k):
        k0, kc = jax.random.split(k)
        return pf.model.init(k0, pf.num_particles), kc

    particles, carry_keys = jax.vmap(init_one)(keys)

    theta_axes = None if thetas is None else jax.tree.map(lambda _: 0, thetas)

    def body(carry, inp):
        xs, log_w, ks = carry  # [S, N] particles/log-weights, [S] key chain
        t, zs = inp  # scalar step, [S] observations
        step = jax.vmap(jax.random.split)(ks)
        ks_next, step_keys = step[:, 0], step[:, 1]
        pr = jax.vmap(jax.random.split)(step_keys)
        k_pred, k_res = pr[:, 0], pr[:, 1]
        # Stage 1 (batched): predict + update
        x = jax.vmap(
            lambda k, xr, th: _call(pf.model.transition, k, xr, t, theta=th),
            in_axes=(0, 0, theta_axes),
        )(k_pred, xs, thetas)
        w = jax.vmap(
            lambda z, xr, th: _call(pf.model.likelihood, z, xr, t, theta=th),
            in_axes=(0, 0, theta_axes),
        )(zs, x, thetas)
        if conditional:
            # Conditional SIR: accumulate log-weights, estimate from the
            # pre-resample posterior, then ONE fused step_rows launch —
            # stage arithmetic mirrors step_conditional row for row.
            log_w = log_w + log_weights_from_linear(w)
            wn = normalise_log_weights(log_w, axis=-1)
            est = jnp.sum(wn * x, axis=1) / jnp.sum(wn, axis=1)
            x_bar, _, stats = resampler.step_rows(
                k_res, log_w, x, pf.ess_threshold
            )
            log_w = jnp.where(
                (stats.ess_norm < pf.ess_threshold)[:, None], 0.0, log_w
            )
            out = (est, stats) if telemetry else est
            return (x_bar, log_w, ks_next), out
        # Stage 2: ONE batched FUSED resample+gather launch for the whole
        # bank (Resampler.apply_rows, DESIGN.md §11) — on the batch-grid
        # kernel families this is a single fused launch per step
        x_bar, ancestors = resampler.apply_rows(k_res, w, x)
        # Stage 3 (batched): estimate
        est = jnp.mean(x_bar, axis=1)
        out = (est, _alg6_step_stats(w, ancestors)) if telemetry else est
        return (x_bar, log_w, ks_next), out

    log_w0 = jnp.zeros((num_s, pf.num_particles), jnp.float32)
    ts = jnp.arange(1, observations.shape[1] + 1, dtype=jnp.float32)
    _, out = jax.lax.scan(body, (particles, log_w0, carry_keys), (ts, observations.T))
    if not telemetry:
        return out.T
    ests, steps = out
    # Scan stacks time first ([T, S] per field); transpose to the [S, T]
    # estimate layout so row s is the single filter's trajectory.
    return ests.T, Telemetry(steps=jax.tree.map(jnp.transpose, steps))


def run_filter_timed(key, pf: ParticleFilter, observations, warmup: int = 2):
    """Per-stage wall timing for the Resample-Ratio metric (paper eq. 25).

    Stages are jitted separately and block_until_ready'd so the split is
    honest; the first ``warmup`` steps are excluded (compile time).
    """
    model = pf.model

    @jax.jit
    def stage1(k, x, z, t):
        x = model.transition(k, x, t)
        return x, model.likelihood(z, x, t)

    @jax.jit
    def stage2(k, x, w):
        x_bar, _ = pf._built.apply(k, w, x)
        return x_bar

    @jax.jit
    def stage3(x):
        return jnp.mean(x)

    k0, key = jax.random.split(key)
    particles = model.init(k0, pf.num_particles)
    times = {"predict_update": 0.0, "resample": 0.0, "estimate": 0.0}
    ests = []
    for i, z in enumerate(observations):
        key, k1, k2 = jax.random.split(key, 3)
        t = jnp.float32(i + 1)
        t0 = time.perf_counter()
        x, w = stage1(k1, particles, z, t)
        jax.block_until_ready(w)
        t1 = time.perf_counter()
        particles = stage2(k2, x, w)
        jax.block_until_ready(particles)
        t2 = time.perf_counter()
        est = stage3(particles)
        jax.block_until_ready(est)
        t3 = time.perf_counter()
        if i >= warmup:
            times["predict_update"] += t1 - t0
            times["resample"] += t2 - t1
            times["estimate"] += t3 - t2
        ests.append(float(est))
    return jnp.asarray(ests), times
