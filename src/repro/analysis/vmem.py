"""Static VMEM estimator (DESIGN.md §13, pass 4).

The dynamic residency guards (``check_state_resident`` /
``check_vmem_resident``) fire at wrapper level from N and state_dim; this
pass prices the launch itself: for every traced ``pallas_call`` it sums
the resident bytes of each kernel operand straight off the kernel jaxpr's
input avals — whole-array VMEM operands, per-grid-step blocks, and
``vmem``-space scratch — skipping ``smem`` scalars, and checks the total
against ``kernels.common.vmem_budget_bytes()``.  Because it works on the
trace, it can price a 1M-particle launch without allocating anything.
"""

from __future__ import annotations

import dataclasses

from repro.analysis.walker import Finding, JaxprLike, pallas_call_eqns
from repro.kernels.common import block_bytes, vmem_budget_bytes


@dataclasses.dataclass(frozen=True)
class KernelFootprint:
    """Resident footprint of one traced ``pallas_call``."""

    path: str
    grid: tuple
    vmem_bytes: int
    smem_bytes: int
    blocks: tuple  # (shape, dtype-name, space) per kernel operand
    budget_bytes: int

    @property
    def within_budget(self) -> bool:
        return self.vmem_bytes <= self.budget_bytes

    def as_dict(self):
        d = dataclasses.asdict(self)
        d["within_budget"] = self.within_budget
        d["blocks"] = [list(b) for b in self.blocks]
        d["grid"] = list(self.grid)
        return d


def _memory_space(aval) -> str:
    """'smem' / 'vmem' for explicitly-placed refs; blocked operands carry
    no memory_space on their block avals and default to VMEM."""
    space = getattr(aval, "memory_space", None)
    if space is None:
        return "vmem"
    return str(space).lower().strip("<>")


def kernel_footprints(jaxpr: JaxprLike, budget_bytes: int | None = None):
    """Price every ``pallas_call`` in a traced program.

    The kernel jaxpr's invars are exactly the refs the kernel touches —
    scalar-prefetch operands, input blocks, output blocks and scratch —
    each carrying the post-BlockSpec *block* shape, which is precisely
    what stays VMEM-resident per grid step.
    """
    budget = vmem_budget_bytes() if budget_bytes is None else budget_bytes
    out = []
    for eqn, path in pallas_call_eqns(jaxpr):
        kernel = eqn.params["jaxpr"]
        grid_mapping = eqn.params.get("grid_mapping")
        grid = tuple(int(g) for g in getattr(grid_mapping, "grid", ()) or ())
        vmem = smem = 0
        blocks = []
        for v in kernel.invars:
            aval = v.aval
            shape = tuple(int(s) for s in getattr(aval, "shape", ()))
            space = _memory_space(aval)
            nbytes = block_bytes(shape, aval.dtype)
            if "smem" in space:
                smem += nbytes
            else:
                vmem += nbytes
            blocks.append((shape, str(aval.dtype), space))
        out.append(
            KernelFootprint(
                path=path,
                grid=grid,
                vmem_bytes=vmem,
                smem_bytes=smem,
                blocks=tuple(blocks),
                budget_bytes=budget,
            )
        )
    return out


def vmem_findings(jaxpr: JaxprLike, budget_bytes: int | None = None) -> list[Finding]:
    """Findings for every launch whose static footprint exceeds budget."""
    findings = []
    for fp in kernel_footprints(jaxpr, budget_bytes):
        if not fp.within_budget:
            findings.append(
                Finding(
                    "vmem",
                    "over-budget",
                    fp.path,
                    f"kernel keeps {fp.vmem_bytes} bytes VMEM-resident "
                    f"(budget {fp.budget_bytes}; grid {fp.grid or '()'}; "
                    f"{len(fp.blocks)} blocks)",
                )
            )
    return findings
