from repro.kernels.metropolis.ops import (  # noqa: F401
    metropolis_c1_tpu,
    metropolis_c2_tpu,
    metropolis_tpu,
    metropolis_tpu_batch,
)
from repro.kernels.metropolis.ref import (  # noqa: F401
    metropolis_c1_ref,
    metropolis_c2_ref,
    metropolis_ref,
)
