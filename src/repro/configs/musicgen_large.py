"""MusicGen-Large [arXiv:2306.05284] — decoder-only over EnCodec tokens.

48L  d_model=2048  32H (kv=32 -> MHA, head_dim=64)  d_ff=8192  vocab=2048.
The EnCodec frontend is a STUB per the assignment: ``input_specs()``
provides precomputed frame embeddings (B, S, D) -> ``embeds_input=True``.
Token-level decode (SMC particle decoding) still emits codebook ids from
the 2048-way lm_head.  Pure full attention -> long_500k skipped.
"""

from repro.configs import ArchSpec
from repro.models import ModelConfig

ARCH = ArchSpec(
    name="musicgen-large",
    family="audio",
    source="arXiv:2306.05284",
    model=ModelConfig(
        name="musicgen-large",
        num_layers=48,
        d_model=2048,
        num_heads=32,
        num_kv_heads=32,
        d_ff=8192,
        vocab_size=2048,
        mlp_type="gelu",  # MusicGen uses plain GELU MLP
        layer_pattern=("attn",),
        rope_theta=10_000.0,
        embeds_input=True,
        long_context_ok=False,
    ),
    smoke=ModelConfig(
        name="musicgen-smoke",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=256,
        vocab_size=64,
        mlp_type="gelu",
        layer_pattern=("attn",),
        embeds_input=True,
        remat=False,
    ),
    microbatches=16,
    notes="audio backbone only; EnCodec frame embeddings stubbed at input",
)
