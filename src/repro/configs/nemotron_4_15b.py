"""Nemotron-4 15B [arXiv:2402.16819] — dense, GQA, squared-ReLU MLP.

32L  d_model=6144  48H (GQA kv=8, head_dim=128)  d_ff=24576  vocab=256000.
Pure full attention -> long_500k skipped (DESIGN.md §5).
"""

from repro.configs import ArchSpec
from repro.models import ModelConfig

ARCH = ArchSpec(
    name="nemotron-4-15b",
    family="dense",
    source="arXiv:2402.16819",
    model=ModelConfig(
        name="nemotron-4-15b",
        num_layers=32,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        head_dim=128,
        d_ff=24576,
        vocab_size=256000,
        mlp_type="squared_relu",
        layer_pattern=("attn",),
        rope_theta=10_000.0,
        long_context_ok=False,
    ),
    smoke=ModelConfig(
        name="nemotron-smoke",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=256,
        vocab_size=512,
        mlp_type="squared_relu",
        layer_pattern=("attn",),
        remat=False,
    ),
    microbatches=16,
    notes="squared-ReLU non-gated MLP; 6:1 GQA",
)
