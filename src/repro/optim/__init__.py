from repro.optim.adamw import (  # noqa: F401
    AdamWConfig,
    adamw_init,
    adamw_update,
    cosine_schedule,
    global_norm,
    clip_by_global_norm,
    opt_state_pspecs,
)
from repro.optim.compression import (  # noqa: F401
    CompressionConfig,
    compress_init,
    compress_and_correct,
)
from repro.optim.accumulation import microbatch_grads  # noqa: F401
