"""Paper Fig. 7: MSE and execution time of C1/C2 across partition sizes
{128, 256, 512, 1024, 2048} vs the Megopolis reference lines, at the
largest N with y = 4 (weights concentrated — the degeneracy regime)."""

from __future__ import annotations

import argparse

import jax

from benchmarks.common import offsprings_for, print_table, time_fn, write_csv
from repro.core import MegopolisSpec, MetropolisC1Spec, MetropolisC2Spec
from repro.core.iterations import gaussian_weight_iterations
from repro.core.metrics import bias_variance
from repro.core.weightgen import gaussian_weights

PARTITIONS = (128, 256, 512, 1024, 2048)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--y", type=float, default=4.0)
    args = ap.parse_args(argv)
    n = 1 << (22 if args.full else 14)
    runs = 256 if args.full else 16
    iters = gaussian_weight_iterations(args.y, 0.01)
    key = jax.random.PRNGKey(11)
    w = gaussian_weights(key, n, args.y)

    # The partition sweep is a spec.replace sweep (DESIGN.md §9): one
    # validated template per family, varied along its tuning axis — the
    # Megopolis reference line has no such axis, which is the point.
    templates = {
        "megopolis": MegopolisSpec(num_iters=iters),
        "metropolis_c1": MetropolisC1Spec(num_iters=iters),
        "metropolis_c2": MetropolisC2Spec(num_iters=iters),
    }
    rows = []
    for algo, template in templates.items():
        sizes = (0,) if algo == "megopolis" else PARTITIONS
        for ps in sizes:
            spec = template if ps == 0 else template.replace(partition_size_bytes=ps)
            resample = spec.build()
            off = offsprings_for(resample, jax.random.fold_in(key, 1), w, runs)
            var, bias_sq, total = bias_variance(off, w)
            t = time_fn(jax.jit(resample), jax.random.PRNGKey(5), w)
            rows.append({"algo": algo, "partition_bytes": ps, "B": iters,
                         "mse_over_n": float(total) / n, "time_s": t})
    write_csv("fig7.csv", rows)
    print_table(rows)
    mego = next(r for r in rows if r["algo"] == "megopolis")
    worst_c1 = max(r["mse_over_n"] for r in rows if r["algo"] == "metropolis_c1")
    print(f"\nC1 worst-partition MSE is {worst_c1 / mego['mse_over_n']:.1f}x Megopolis "
          f"(paper reports ~15x at PS=128, y=4)")


if __name__ == "__main__":
    main()
