"""Gradient accumulation over microbatches (memory-bound large-batch runs).

``lax.scan`` over the microbatch axis so the lowered HLO carries ONE loss/
grad body regardless of accumulation depth — peak activation memory is one
microbatch, and the dry-run's cost_analysis stays honest (the while-loop
body FLOPs are multiplied by the trip count in our roofline accounting, see
benchmarks/roofline.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def microbatch_grads(loss_fn, params, batch, num_microbatches: int):
    """Mean loss/grads of ``loss_fn(params, micro_batch)`` over microbatches.

    ``batch`` leaves are split on axis 0 into ``num_microbatches`` equal
    slices.  Returns ``(loss, grads)`` matching a full-batch call.
    """
    if num_microbatches <= 1:
        return jax.value_and_grad(loss_fn)(params, batch)

    def reshape(x):
        b = x.shape[0]
        assert b % num_microbatches == 0, (b, num_microbatches)
        return x.reshape(num_microbatches, b // num_microbatches, *x.shape[1:])

    micro = jax.tree.map(reshape, batch)
    grad_fn = jax.value_and_grad(loss_fn)

    def body(carry, mb):
        loss_acc, grads_acc = carry
        loss, grads = grad_fn(params, mb)
        grads_acc = jax.tree.map(lambda a, g: a + g.astype(a.dtype), grads_acc, grads)
        return (loss_acc + loss, grads_acc), None

    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (loss_sum, grads_sum), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32), zeros), micro)
    inv = 1.0 / num_microbatches
    return loss_sum * inv, jax.tree.map(lambda g: g * inv, grads_sum)
