"""Tempering schedules: fixed geometric + adaptive CESS bisection (DESIGN.md §10).

Two ways to walk β from 0 to 1:

* ``geometric_schedule`` — β log-spaced between ``beta_min`` and 1.  The
  classic fixed ladder; cheap, but blind to where the path actually
  deforms.
* ``next_temperature`` — the adaptive rule of Zhou, Johansen & Aston (and
  Syed et al.'s optimised-annealing line): pick the LARGEST Δβ whose
  incremental weights keep the conditional ESS at a target fraction of N,
  found by bisection inside a ``lax.while_loop`` (jittable, fixed-point
  carry, runs under vmap for the scenario bank).

The conditional ESS (``conditional_ess``) is measured against the CURRENT
normalised weights, so it equals N at Δβ = 0 regardless of how degenerate
the accumulated weights already are — which is what guarantees the
bisection always finds a strictly positive step (the hypothesis property
test in tests/test_ais.py pins exactly this).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def geometric_schedule(num_temps: int, beta_min: float = 1e-2) -> jnp.ndarray:
    """β_t = beta_min^(1 − t/T) for t = 1..T: log-spaced, ends exactly at 1."""
    if num_temps < 1:
        raise ValueError(f"geometric_schedule: num_temps must be >= 1; got {num_temps}")
    if not 0.0 < beta_min < 1.0:
        raise ValueError(f"geometric_schedule: beta_min must be in (0, 1); got {beta_min}")
    t = jnp.arange(1, num_temps + 1, dtype=jnp.float32) / num_temps
    betas = beta_min ** (1.0 - t)
    return betas.at[-1].set(1.0)  # exact endpoint, no float pow residue


def conditional_ess(log_w: jnp.ndarray, log_u: jnp.ndarray) -> jnp.ndarray:
    """CESS = N·(Σ W·u)² / Σ W·u²  with W the normalised current weights.

    ``log_w`` are the accumulated log-weights, ``log_u`` the candidate
    incremental log-weights.  Equals N when u is constant (Δβ = 0).
    """
    n = log_w.shape[-1]
    log_norm_w = log_w - jax.nn.logsumexp(log_w, axis=-1, keepdims=True)
    a = jax.nn.logsumexp(log_norm_w + log_u, axis=-1)  # log Σ W u
    b = jax.nn.logsumexp(log_norm_w + 2.0 * log_u, axis=-1)  # log Σ W u²
    return n * jnp.exp(2.0 * a - b)


def next_temperature(
    log_w: jnp.ndarray,
    delta: jnp.ndarray,
    beta_prev: jnp.ndarray,
    target_cess: float,
    *,
    tol: float = 1e-6,
    max_iters: int = 60,
) -> jnp.ndarray:
    """Largest β ∈ (beta_prev, 1] keeping CESS/N at ``target_cess``.

    ``delta[i] = log γ(x_i) − log π0(x_i)`` is the geometric-path tilt, so
    the incremental log-weight of a step to β is (β − beta_prev)·delta.
    CESS/N is 1 at β = beta_prev and (generically) decreasing in β, so the
    bisection bracket [beta_prev, 1] always contains the crossing; if even
    the full jump to 1 keeps CESS above target, returns exactly 1.0.  The
    returned β is the lower bracket end — realised CESS/N ≥ target up to
    the bisection ``tol``.
    """
    n = log_w.shape[-1]
    beta_prev = jnp.asarray(beta_prev, jnp.float32)

    def cess_frac(beta):
        return conditional_ess(log_w, (beta - beta_prev) * delta) / n

    def cond(state):
        lo, hi, it = state
        return (it < max_iters) & (hi - lo > tol)

    def body(state):
        lo, hi, it = state
        mid = 0.5 * (lo + hi)
        ok = cess_frac(mid) >= target_cess
        return jnp.where(ok, mid, lo), jnp.where(ok, hi, mid), it + 1

    lo, _, _ = jax.lax.while_loop(
        cond, body, (beta_prev, jnp.float32(1.0), jnp.int32(0))
    )
    full_ok = cess_frac(jnp.float32(1.0)) >= target_cess
    return jnp.where(full_ok, jnp.float32(1.0), lo)
