"""Data pipeline + checkpointing invariants: determinism, shard
consistency, atomic save/restore, async writer, retention, elasticity."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    CheckpointManager,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.data import SyntheticLMStream, batch_specs


# --------------------------------------------------------------------- data
def test_stream_deterministic_and_checkpointable():
    s = SyntheticLMStream(vocab_size=97, seq_len=16, global_batch=8, seed=7)
    b1 = s.batch(5)
    b2 = s.batch(5)  # same position -> identical (resume-exactness)
    np.testing.assert_array_equal(b1["inputs"], b2["inputs"])
    b3 = s.batch(6)
    assert not np.array_equal(b1["inputs"], b3["inputs"])


def test_stream_shard_consistency():
    """A shard's slice equals the corresponding rows of the global batch —
    repartitioning after elastic re-mesh is a no-op."""
    s = SyntheticLMStream(vocab_size=97, seq_len=16, global_batch=8, seed=7)
    full = s.batch(3)
    part = s.batch(3, row_lo=2, row_hi=5)
    np.testing.assert_array_equal(full["inputs"][2:5], part["inputs"])


def test_stream_targets_are_shifted_inputs():
    s = SyntheticLMStream(vocab_size=97, seq_len=16, global_batch=2, seed=7)
    b = s.batch(0)
    np.testing.assert_array_equal(b["inputs"][:, 1:], b["targets"][:, :-1])


def test_stream_jax_matches_host():
    s = SyntheticLMStream(vocab_size=97, seq_len=8, global_batch=4, seed=9)
    host = s.batch(11)
    dev = s.jax_batch(11, 0, 4)
    np.testing.assert_array_equal(host["inputs"], np.asarray(dev["inputs"]))


def test_batch_specs_shapes():
    sp = batch_specs(32, 128)
    assert sp["inputs"].shape == (32, 128) and sp["inputs"].dtype == jnp.int32
    sp_e = batch_specs(4, 8, embeds_dim=64)
    assert sp_e["inputs"].shape == (4, 8, 64)


# --------------------------------------------------------------- checkpoint
def _tree(key):
    return {"layers": [{"w": jax.random.normal(key, (4, 4))}],
            "step_scalar": jnp.float32(3.0)}


def test_save_restore_roundtrip(tmp_path):
    tree = _tree(jax.random.PRNGKey(0))
    save_checkpoint(str(tmp_path), 7, tree, extra={"next_step": 7})
    assert latest_step(str(tmp_path)) == 7
    restored, manifest = restore_checkpoint(str(tmp_path), template=tree)
    np.testing.assert_allclose(np.asarray(restored["layers"][0]["w"]),
                               np.asarray(tree["layers"][0]["w"]))
    assert manifest["extra"]["next_step"] == 7


def test_restore_detects_shape_mismatch(tmp_path):
    tree = _tree(jax.random.PRNGKey(0))
    save_checkpoint(str(tmp_path), 1, tree)
    bad = {"layers": [{"w": jnp.zeros((8, 8))}], "step_scalar": jnp.float32(0)}
    with pytest.raises(ValueError, match="shape mismatch"):
        restore_checkpoint(str(tmp_path), template=bad)


def test_no_partial_checkpoint_visible(tmp_path):
    """Atomicity: a half-written tmp dir is never selected by LATEST."""
    tree = _tree(jax.random.PRNGKey(0))
    save_checkpoint(str(tmp_path), 1, tree)
    os.makedirs(str(tmp_path / "step_00000002.tmp"))  # simulated crash mid-save
    assert latest_step(str(tmp_path)) == 1
    restored, _ = restore_checkpoint(str(tmp_path), template=tree)


def test_async_manager_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = _tree(jax.random.PRNGKey(1))
    for s in (10, 20, 30, 40):
        mgr.save_async(s, tree, extra={"next_step": s})
    mgr.wait()
    mgr.save(50, tree, extra={"next_step": 50})
    steps = sorted(d for d in os.listdir(str(tmp_path)) if d.startswith("step_"))
    assert len(steps) == 2 and steps[-1] == "step_00000050"
    assert latest_step(str(tmp_path)) == 50


def test_elastic_reshard_roundtrip(tmp_path):
    """Restore onto a different 'topology': values identical regardless of
    how the restored arrays are re-placed (pure reshard)."""
    tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    save_checkpoint(str(tmp_path), 1, tree)
    restored, _ = restore_checkpoint(str(tmp_path), template=tree)
    placed = jax.device_put(restored["w"], jax.devices()[0])
    np.testing.assert_array_equal(np.asarray(placed), np.asarray(tree["w"]))
