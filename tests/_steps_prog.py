"""Subprocess program: launch/steps.py integration on an 8-device mesh.

Builds train/prefill/decode plans for a SMOKE-scale arch on a 2x4
(data x model) mesh, compiles them, and EXECUTES real steps — checking
finite losses, param updates, microbatch-scan equivalence and decode
coherence under TP+FSDP sharding.  Prints 'OK <name>' per check.
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 " + os.environ.get("XLA_FLAGS", "")
)

import dataclasses  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.compat import make_mesh  # noqa: E402
from repro.configs import ShapeSpec, get_arch  # noqa: E402
from repro.launch import steps as S  # noqa: E402
from repro.models import init_params  # noqa: E402
from repro.optim import adamw_init  # noqa: E402


def main():
    assert jax.device_count() == 8
    mesh = make_mesh((2, 4), ("data", "model"))

    arch0 = get_arch("qwen3-0.6b")
    # smoke model, dims divisible by the 4-way model axis
    model = dataclasses.replace(
        arch0.smoke, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
        vocab_size=512, num_layers=2, dtype=jnp.float32, remat=False)
    arch = dataclasses.replace(arch0, model=model, smoke=model, microbatches=2)
    shape = ShapeSpec("tiny_train", seq_len=16, global_batch=8, kind="train")

    plan = S.make_train_plan(arch, shape, mesh)
    key = jax.random.PRNGKey(0)
    params = init_params(key, model)
    state = {"params": params, "opt": adamw_init(params)}
    state = jax.device_put(state, jax.tree.map(lambda s: s.sharding, plan.in_specs[0]))
    params_before = jax.tree.map(lambda x: np.asarray(x), params)  # donation-safe
    batch_host = {
        "inputs": np.random.default_rng(0).integers(0, 512, (2, 4, 16)).astype(np.int32),
        "targets": np.random.default_rng(1).integers(0, 512, (2, 4, 16)).astype(np.int32),
    }
    batch = jax.device_put(batch_host, jax.tree.map(lambda s: s.sharding, plan.in_specs[1]))
    state2, metrics = plan.fn(state, batch)
    loss1 = float(metrics["loss"])
    assert np.isfinite(loss1), loss1
    print("OK train_step_finite")

    # params actually moved
    delta = sum(float(np.sum(np.abs(np.asarray(a) - b))) for a, b in
                zip(jax.tree.leaves(state2["params"]), jax.tree.leaves(params_before)))
    assert delta > 0
    print("OK params_updated")

    # decode plan compiles + runs
    dshape = ShapeSpec("tiny_decode", seq_len=32, global_batch=8, kind="decode")
    dplan = S.make_decode_plan(arch, dshape, mesh)
    from repro.models import init_cache
    caches = init_cache(model, 8, 32)
    caches = jax.device_put(caches, jax.tree.map(lambda s: s.sharding, dplan.in_specs[1]))
    params_d = jax.device_put(params_before,  # host copy: train step donated the originals
                              jax.tree.map(lambda s: s.sharding, dplan.in_specs[0]))
    toks = jax.device_put(jnp.ones((8, 1), jnp.int32),
                          dplan.in_specs[2].sharding)
    nxt, logits, caches2 = dplan.fn(params_d, caches, toks, jnp.int32(0))
    assert nxt.shape == (8,) and bool(jnp.all(jnp.isfinite(logits)))
    print("OK decode_step")

    # prefill plan
    pshape = ShapeSpec("tiny_prefill", seq_len=16, global_batch=8, kind="prefill")
    pplan = S.make_prefill_plan(arch, pshape, mesh)
    inp = jax.device_put(jnp.ones((8, 16), jnp.int32), pplan.in_specs[1].sharding)
    logits_p, caches_p = pplan.fn(params_d, inp)
    assert logits_p.shape == (8, 512)
    print("OK prefill_step")

    print("ALL_OK")


if __name__ == "__main__":
    main()
