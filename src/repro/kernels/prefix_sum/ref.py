"""Oracle for the prefix-sum kernel: plain jnp.cumsum."""

import jax
import jax.numpy as jnp


@jax.jit
def prefix_sum_ref(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.cumsum(x)
