"""Jittable annealing targets with analytic logZ ground truth (DESIGN.md §10).

An adaptive-SMC sampler anneals from a NORMALISED base density π0 to an
UNNORMALISED target γ along the geometric path

    log π_β(x) = (1 − β) · log π0(x) + β · log γ(x),      β: 0 → 1,

and its output logZ estimates log ∫ γ(x) dx.  Each family here carries that
integral in closed form where one exists (``Target.log_z``), which is what
lets resampler quality be SCORED against ground truth instead of eyeballed
— the first workload in the repo with an analytic answer (EXPERIMENTS.md
§AIS; cf. Murray, Lee & Jacob on logZ bias/variance as the resampler
quality metric).

All callables are jittable and vectorised over the particle axis:
``log_base(x[N, d]) -> f32[N]``, ``log_target(x[N, d]) -> f32[N]``,
``sample_base(key, n) -> f32[n, d]``.  Scenario families (the §4 batched
engine's theta axis) take a trailing ``theta`` pytree, mirroring
``repro.pf.models.ungm_family``.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class Target:
    """One annealing problem: normalised base π0, unnormalised target γ.

    ``log_z`` is the analytic log ∫ γ when known (None otherwise — e.g. the
    logistic-regression posterior); ``log_z_fn(theta)`` is the per-scenario
    form for theta families.
    """

    dim: int
    log_base: Callable  # (x[N, d][, theta]) -> f32[N]   normalised log π0
    sample_base: Callable  # (key, n[, theta]) -> f32[n, d]
    log_target: Callable  # (x[N, d][, theta]) -> f32[N]  unnormalised log γ
    log_z: Optional[float] = None
    log_z_fn: Optional[Callable] = None  # (theta) -> f32  for theta families
    name: str = "target"


def _normal_base(dim: int, scale: float):
    """Normalised N(0, scale²·I_dim) base: (log_base, sample_base)."""
    log_norm = -0.5 * dim * np.log(2.0 * np.pi * scale**2)

    def log_base(x):
        return log_norm - 0.5 * jnp.sum(jnp.square(x / scale), axis=-1)

    def sample_base(key, n):
        return scale * jax.random.normal(key, (n, dim))

    return log_base, sample_base


def isotropic_gaussian(dim: int = 2, mean: float = 1.0, sigma: float = 1.0,
                       base_scale: float = 3.0) -> Target:
    """γ(x) = exp(−‖x − μ‖² / 2σ²); logZ = (d/2)·log(2πσ²) exactly."""
    mu = jnp.full((dim,), mean, jnp.float32)
    log_base, sample_base = _normal_base(dim, base_scale)

    def log_target(x):
        return -0.5 * jnp.sum(jnp.square((x - mu) / sigma), axis=-1)

    return Target(
        dim=dim, log_base=log_base, sample_base=sample_base,
        log_target=log_target,
        log_z=float(0.5 * dim * np.log(2.0 * np.pi * sigma**2)),
        name="isotropic_gaussian",
    )


def correlated_gaussian(dim: int = 4, rho: float = 0.7,
                        base_scale: float = 3.0) -> Target:
    """γ(x) = exp(−½ xᵀ Σ⁻¹ x), Σ_ij = ρ^|i−j|; logZ = ½·log det(2πΣ)."""
    idx = np.arange(dim)
    cov = rho ** np.abs(idx[:, None] - idx[None, :])
    prec = jnp.asarray(np.linalg.inv(cov), jnp.float32)
    sign, logdet = np.linalg.slogdet(2.0 * np.pi * cov)
    assert sign > 0
    log_base, sample_base = _normal_base(dim, base_scale)

    def log_target(x):
        return -0.5 * jnp.einsum("ni,ij,nj->n", x, prec, x)

    return Target(
        dim=dim, log_base=log_base, sample_base=sample_base,
        log_target=log_target, log_z=float(0.5 * logdet),
        name="correlated_gaussian",
    )


def gaussian_mixture(means=((-2.0, -2.0), (2.0, 2.0)), sigma: float = 1.0,
                     mass: float = 2.5, base_scale: float = 4.0) -> Target:
    """γ(x) = mass · Σ_k (1/K)·N(x; μ_k, σ²I): components normalised and
    equally weighted, so logZ = log(mass) exactly regardless of geometry."""
    mus = jnp.asarray(means, jnp.float32)  # [K, d]
    k_comp, dim = mus.shape
    log_norm = -0.5 * dim * np.log(2.0 * np.pi * sigma**2)
    log_base, sample_base = _normal_base(dim, base_scale)

    def log_target(x):
        # [N, K] component log-densities -> logsumexp over components
        d2 = jnp.sum(jnp.square(x[:, None, :] - mus[None, :, :]), axis=-1)
        comp = log_norm - 0.5 * d2 / sigma**2
        return jax.nn.logsumexp(comp, axis=-1) + jnp.log(mass / k_comp)

    return Target(
        dim=dim, log_base=log_base, sample_base=sample_base,
        log_target=log_target, log_z=float(np.log(mass)),
        name="gaussian_mixture",
    )


def banana(bend: float = 0.1, sigma1: float = 2.0,
           base_scale: float = 4.0) -> Target:
    """The 2-d banana: a unit-Jacobian shear of a product Gaussian.

    γ(x) = exp(−x₁²/2σ₁² − ½·(x₂ + b·x₁² − b·σ₁²)²).  The shear
    x₂ ↦ x₂ + b·x₁² − b·σ₁² preserves volume, so logZ = log(2π·σ₁)
    exactly even though the density is strongly non-Gaussian.
    """
    log_base, sample_base = _normal_base(2, base_scale)

    def log_target(x):
        x1, x2 = x[:, 0], x[:, 1]
        y2 = x2 + bend * jnp.square(x1) - bend * sigma1**2
        return -0.5 * jnp.square(x1 / sigma1) - 0.5 * jnp.square(y2)

    return Target(
        dim=2, log_base=log_base, sample_base=sample_base,
        log_target=log_target, log_z=float(np.log(2.0 * np.pi * sigma1)),
        name="banana",
    )


def logistic_regression(key=None, num_data: int = 64, dim: int = 4,
                        base_scale: float = 2.0) -> Target:
    """Bayesian logistic regression on synthetic data: γ(θ) = N(θ; 0, I) ·
    Π_i σ(y_i·x_iᵀθ).  No analytic logZ (``log_z=None``) — the realistic
    end of the target spectrum, scored on wall-time only."""
    key = jax.random.PRNGKey(7) if key is None else key
    kx, kw, ky = jax.random.split(key, 3)
    x_data = jax.random.normal(kx, (num_data, dim))
    w_true = jax.random.normal(kw, (dim,))
    logits = x_data @ w_true
    y = jnp.where(jax.random.uniform(ky, (num_data,)) < jax.nn.sigmoid(logits),
                  1.0, -1.0)
    log_base, sample_base = _normal_base(dim, base_scale)

    def log_target(theta):
        # prior N(0, I) + Bernoulli likelihood, both unnormalised-friendly
        prior = -0.5 * dim * jnp.log(2.0 * jnp.pi) - 0.5 * jnp.sum(
            jnp.square(theta), axis=-1)
        margins = theta @ x_data.T * y[None, :]  # [N, num_data]
        loglik = jnp.sum(jax.nn.log_sigmoid(margins), axis=-1)
        return prior + loglik

    return Target(
        dim=dim, log_base=log_base, sample_base=sample_base,
        log_target=log_target, log_z=None, name="logistic_regression",
    )


# ------------------------------------------------------------ theta families

def gaussian_family(dim: int = 2, base_scale: float = 3.0) -> Target:
    """A theta-family of isotropic Gaussians for the §4 scenario axis.

    ``theta = {'mean': f32[d], 'sigma': f32[]}`` selects the scenario;
    stack leaves with a leading [S] axis for ``run_smc_sampler_bank``
    (see ``gaussian_theta``).  logZ per scenario via ``log_z_fn(theta)``.
    """
    log_base, sample_base = _normal_base(dim, base_scale)

    def log_target(x, theta):
        return -0.5 * jnp.sum(
            jnp.square((x - theta["mean"]) / theta["sigma"]), axis=-1)

    def log_z_fn(theta):
        return 0.5 * dim * jnp.log(2.0 * jnp.pi * jnp.square(theta["sigma"]))

    return Target(
        dim=dim,
        log_base=lambda x, theta: log_base(x),
        sample_base=lambda key, n, theta: sample_base(key, n),
        log_target=log_target, log_z_fn=log_z_fn,
        name="gaussian_family",
    )


def gaussian_theta(mean, sigma: float = 1.0, dim: int = 2):
    """One scenario of ``gaussian_family`` (stack leaves for a bank)."""
    return {
        "mean": jnp.full((dim,), mean, jnp.float32),
        "sigma": jnp.asarray(sigma, jnp.float32),
    }
