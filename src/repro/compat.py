"""Compatibility shims for the pinned JAX version.

``jax.tree_util.keystr(path, simple=True, separator="/")`` only exists in
newer JAX releases; the pinned 0.4.x ``keystr`` takes the key path alone
and renders the verbose ``['a'].b[0]`` form.  Checkpoint manifests and the
partitioning tables key leaves by the SIMPLE slash-joined form (``a/b/0``),
so the formatter lives here, version-independent.
"""

from __future__ import annotations

import jax


def make_mesh(axis_shapes, axis_names, *, devices=None):
    """``jax.make_mesh`` with explicitly-Auto axis types on any JAX version.

    ``jax.sharding.AxisType`` only exists on newer JAX; on the pinned
    0.4.x every mesh axis is Auto-typed implicitly, so the kwarg is simply
    dropped there.
    """
    kwargs = {} if devices is None else {"devices": devices}
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        kwargs["axis_types"] = (axis_type.Auto,) * len(axis_names)
    return jax.make_mesh(axis_shapes, axis_names, **kwargs)


def shard_map(f, *, mesh, in_specs, out_specs, check_rep=False):
    """``jax.shard_map`` on new JAX, ``jax.experimental.shard_map`` on 0.4.x.

    The experimental form spells the replication-check kwarg ``check_rep``;
    the graduated form renamed it ``check_vma`` — normalised here.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=check_rep
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check_rep
    )


def axis_size(axis_name):
    """``lax.axis_size`` on new JAX; the classic ``psum(1, axis)`` constant
    fold (which returns a static int for a literal operand) on 0.4.x."""
    from jax import lax

    if hasattr(lax, "axis_size"):
        return lax.axis_size(axis_name)
    return lax.psum(1, axis_name)


def keystr_simple(path, separator: str = "/") -> str:
    """Render a JAX key path as simple names joined by ``separator``.

    Equivalent to ``jax.tree_util.keystr(path, simple=True,
    separator=separator)`` on new JAX, but works on any version: each
    entry contributes its bare payload (dict key, sequence index, or
    attribute name) with no quotes or brackets.
    """
    parts = []
    for entry in path:
        for attr in ("key", "idx", "name"):
            if hasattr(entry, attr):
                parts.append(str(getattr(entry, attr)))
                break
        else:  # unknown entry type: fall back to its repr, stripped
            parts.append(str(entry).strip(".[]'\""))
    return separator.join(parts)
