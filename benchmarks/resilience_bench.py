"""Resilience suite — guard overhead + chaos matrix as bench artifacts
(DESIGN.md §16).

Two tables into ``BENCH_resilience.json``:

* **guard overhead** — wall time of the fused step per guard policy on a
  clean bank (family × policy).  The §16 claim is that ``'flag'`` is the
  identical program and ``'recover'`` adds only a pre-dispatch
  ``jnp.where``, so the ratios should sit at ~1; the numbers land in the
  trajectory JSON so a regression is visible as data, not just as a
  failed analyzer pass.
* **chaos matrix** — every ``FAULT_CLASSES`` signature through every
  family's recovered step: finite outputs, in-range ancestors, the
  degenerate flag where the taxonomy demands it.  Exit code is the gate:
  non-zero if any cell emitted garbage.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import ensure_out, print_table

N = 4096
REPS = 5
FAMILIES = ("megopolis", "metropolis", "rejection", "systematic", "residual")
BACKEND = "pallas_interpret"


def _build(name, guard, backend=BACKEND):
    from repro.core.spec import spec_for_backend

    return spec_for_backend(name, backend, num_iters=16, max_iters=64,
                            guard=guard).build()


def _time_step(r, key, lw, p, thr):
    jax.block_until_ready(r.step(key, lw, p, thr))  # warm
    t0 = time.perf_counter()
    for _ in range(REPS):
        jax.block_until_ready(r.step(key, lw, p, thr))
    return (time.perf_counter() - t0) / REPS


def guard_overhead_rows(quick: bool):
    from repro.resilience import GUARD_POLICIES

    key = jax.random.PRNGKey(0)
    lw = jax.random.normal(jax.random.PRNGKey(1), (N,)) * 2.0
    p = jax.random.normal(jax.random.PRNGKey(2), (N, 2))
    rows = []
    for name in FAMILIES[:2] if quick else FAMILIES:
        times = {
            g: _time_step(_build(name, g), key, lw, p, 0.5)
            for g in GUARD_POLICIES
        }
        rows.append({
            "family": name,
            **{f"{g}_ms": round(times[g] * 1e3, 3) for g in GUARD_POLICIES},
            "flag_ratio": round(times["flag"] / times["off"], 3),
            "recover_ratio": round(times["recover"] / times["off"], 3),
        })
    return rows


def chaos_rows(quick: bool):
    from repro.resilience import FAULT_CLASSES, validate_ancestors
    from repro.resilience.errors import ResilienceError

    collapsed = ("all_nan", "all_neg_inf")
    key = jax.random.PRNGKey(3)
    p = jax.random.normal(jax.random.PRNGKey(4), (N, 2))
    rows = []
    for name in FAMILIES[:2] if quick else FAMILIES:
        r = _build(name, "recover")
        for fault, gen in sorted(FAULT_CLASSES.items()):
            status, detail = "recovered", ""
            try:
                p_out, anc, stats = r.step(key, gen(N), p, 2.0)
                validate_ancestors(np.asarray(anc), N)
                finite = bool(np.isfinite(np.asarray(p_out)).all())
                flagged = bool(np.asarray(stats.degenerate))
                ok = finite and flagged == (fault in collapsed)
                if not ok:
                    status = "garbage"
                    detail = f"finite={finite} degenerate={flagged}"
            except ResilienceError as err:
                status, detail = "typed_error", type(err).__name__
            except Exception as err:  # noqa: BLE001 — the failure IS the data
                status, detail = "untyped_error", f"{type(err).__name__}: {err}"
            rows.append({
                "family": name,
                "fault": fault,
                "status": status,
                "ok": status in ("recovered", "typed_error"),
                "detail": detail,
            })
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="two families instead of five")
    args = ap.parse_args(argv)

    overhead = guard_overhead_rows(args.quick)
    print_table(overhead)
    chaos = chaos_rows(args.quick)
    print_table(chaos, cols=["family", "fault", "status", "ok", "detail"])

    ok = all(c["ok"] for c in chaos)
    payload = {
        "ok": ok,
        "backend": BACKEND,
        "n": N,
        "guard_overhead": overhead,
        "chaos": chaos,
    }
    path = os.path.join(ensure_out(), "BENCH_resilience.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    print(f"wrote {path}")
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
