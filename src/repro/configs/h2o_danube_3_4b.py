"""H2O-Danube3 4B [arXiv:2401.16818] — llama+mistral mix with SWA.

24L  d_model=3840  32H (GQA kv=8, head_dim=120)  d_ff=10240  vocab=32000.
Sliding-window attention throughout (mistral-style, window 4096) ->
long_500k runs with O(window) ring caches.
"""

from repro.configs import ArchSpec
from repro.models import ModelConfig

ARCH = ArchSpec(
    name="h2o-danube-3-4b",
    family="dense",
    source="arXiv:2401.16818",
    model=ModelConfig(
        name="h2o-danube-3-4b",
        num_layers=24,
        d_model=3840,
        num_heads=32,
        num_kv_heads=8,
        head_dim=120,
        d_ff=10240,
        vocab_size=32000,
        mlp_type="swiglu",
        layer_pattern=("swa",),
        window=4096,
        rope_theta=10_000.0,
        long_context_ok=True,
    ),
    smoke=ModelConfig(
        name="danube-smoke",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=256,
        vocab_size=512,
        mlp_type="swiglu",
        layer_pattern=("swa",),
        window=8,
        remat=False,
    ),
    microbatches=16,
    notes="head_dim=120 (not MXU-128-aligned — see roofline notes); SWA 4096",
)
