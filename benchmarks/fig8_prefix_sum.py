"""Paper Fig. 8: Megopolis vs the unbiased prefix-sum methods — parallel
multinomial [38] and improved parallel systematic [41] — MSE, bias
contribution and execution time across N.

Also reproduces the paper's numerical-stability observation: the prefix-sum
methods' bias contribution grows with N in single precision while
Megopolis' stays flat (§6.5)."""

from __future__ import annotations

import argparse

import jax

from benchmarks.common import offsprings_for, print_table, time_fn, write_csv
from repro.core import coerce_spec
from repro.core.iterations import gaussian_weight_iterations
from repro.core.metrics import bias_variance
from repro.core.weightgen import gaussian_weights

ALGOS = ("megopolis", "multinomial", "improved_systematic")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args(argv)
    ns = [2**e for e in ((14, 18, 22) if args.full else (10, 12, 14))]
    runs = 256 if args.full else 16
    ys = (0.0, 2.0, 4.0)

    rows = []
    for n in ns:
        for y in ys:
            iters = gaussian_weight_iterations(y, 0.01)
            key = jax.random.fold_in(jax.random.PRNGKey(23), int(y * 10))
            w = gaussian_weights(key, n, y)
            for algo in ALGOS:
                # coerce_spec applies the iteration count only where the
                # family has one — no per-algorithm conditionals.
                resample = coerce_spec(algo, num_iters=iters).build()
                off = offsprings_for(resample, jax.random.fold_in(key, 1), w, runs)
                var, bias_sq, total = bias_variance(off, w)
                t = time_fn(jax.jit(resample), jax.random.PRNGKey(5), w)
                rows.append({"n": n, "y": y, "algo": algo,
                             "mse_over_n": float(total) / n,
                             "bias_contrib": float(bias_sq / max(float(total), 1e-30)),
                             "time_s": t})
    write_csv("fig8.csv", rows)
    print_table(rows)


if __name__ == "__main__":
    main()
