"""Assigned input shapes (the 4 LM-transformer cells per architecture).

``train_*`` cells lower ``train_step``; ``decode_*`` / ``long_*`` lower
``serve_step`` (one new token against a KV cache of ``seq_len``);
``prefill_*`` lowers the prefill forward.  ``long_500k`` requires
sub-quadratic attention and is skipped for pure full-attention archs
(DESIGN.md §5), per the assignment.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"
    needs_subquadratic: bool = False


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode", needs_subquadratic=True),
}


def applicable_shapes(arch) -> list[str]:
    """Shape names this arch runs (long_500k only if sub-quadratic)."""
    out = []
    for name, s in SHAPES.items():
        if s.needs_subquadratic and not arch.model.long_context_ok:
            continue
        out.append(name)
    return out
