"""Public jit'd wrapper for the Megopolis TPU kernel.

Accepts the same ``(key, weights, num_iters)`` signature as the reference
resamplers in ``repro.core``.  Alignment contract: ``N % 1024 == 0`` (one
f32 VMEM tile); production particle counts are powers of two well above
this (the paper sweeps 2^6..2^22), and the wrapper raises a clear error
otherwise rather than silently padding (padding would perturb the
uniform-offset distribution over [0, N)).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.common import (
    TILE,
    check_state_resident,
    check_vmem_resident,
    compress_plane,
    key_to_seed,
    pack_state_planes,
    plane_itemsize,
    run_fused_bank,
    run_step_bank,
    state_dim_of,
    state_itemsize,
    unpack_state_planes,
)
from repro.kernels.megopolis.megopolis import (
    LANES,
    megopolis_pallas,
    megopolis_pallas_batch,
    megopolis_pallas_fused,
    megopolis_pallas_fused_rows,
    megopolis_pallas_step,
    megopolis_pallas_step_rows,
)


def megopolis_tpu(
    key: jax.Array,
    weights: jnp.ndarray,
    num_iters: int,
    *,
    interpret: bool = True,
    plane_dtype="float32",
) -> jnp.ndarray:
    """Resample with the Pallas Megopolis kernel; returns int32[N] ancestors.

    ``interpret=True`` (default here) runs the kernel body on CPU for
    validation; pass ``interpret=False`` on real TPU hardware.
    """
    n = weights.shape[0]
    if n % TILE != 0:
        raise ValueError(
            f"megopolis_tpu requires N % {TILE} == 0 (one f32 VMEM tile); got N={n}. "
            "Use repro.core.megopolis for unaligned N."
        )
    key_off, key_seed = jax.random.split(key)
    offsets = jax.random.randint(key_off, (num_iters,), 0, n, dtype=jnp.int32)
    seed = key_to_seed(key_seed).reshape(1)
    w2 = compress_plane(weights.reshape(n // LANES, LANES), plane_dtype)
    k2 = megopolis_pallas(w2, offsets, seed, num_iters=num_iters, interpret=interpret)
    return k2.reshape(n)


def megopolis_tpu_batch(
    key: jax.Array,
    weights: jnp.ndarray,
    num_iters: int,
    *,
    interpret: bool = True,
    plane_dtype="float32",
) -> jnp.ndarray:
    """Resample a ``[B, N]`` weight bank in one kernel launch (DESIGN.md §4).

    The global offset table is drawn ONCE and shared by every row (the
    bank-level lift of Alg. 5's shared offset — one scalar-prefetch schedule
    for the whole launch); each row gets its own stateless-RNG seed, so rows
    stay statistically independent.  Returns int32[B, N] ancestors.
    """
    if weights.ndim != 2:
        raise ValueError(f"megopolis_tpu_batch expects weights[B, N]; got {weights.shape}")
    bsz, n = weights.shape
    if n % TILE != 0:
        raise ValueError(
            f"megopolis_tpu_batch requires N % {TILE} == 0 (one f32 VMEM tile); got N={n}. "
            "Use repro.core.megopolis_batch for unaligned N."
        )
    key_off, key_rows = jax.random.split(key)
    offsets = jax.random.randint(key_off, (num_iters,), 0, n, dtype=jnp.int32)
    seeds = key_to_seed(jax.random.split(key_rows, bsz))
    w3 = compress_plane(weights.reshape(bsz, n // LANES, LANES), plane_dtype)
    k3 = megopolis_pallas_batch(w3, offsets, seeds, num_iters=num_iters, interpret=interpret)
    return k3.reshape(bsz, n)


def megopolis_tpu_apply(
    key: jax.Array,
    weights: jnp.ndarray,
    particles: jnp.ndarray,
    num_iters: int,
    *,
    interpret: bool = True,
    plane_dtype="float32",
):
    """Fused resample+gather (DESIGN.md §11): ONE kernel launch selects the
    ancestors (identical stream to ``megopolis_tpu``) and copies each
    ancestor's state tile in VMEM.  ``particles``: ``[N]`` or ``[N, ...]``
    (trailing dims are the state).  Returns ``(particles' , ancestors)``."""
    n = weights.shape[0]
    if n % TILE != 0:
        raise ValueError(
            f"megopolis_tpu_apply requires N % {TILE} == 0 (one f32 VMEM tile); got N={n}."
        )
    check_state_resident(n, state_dim_of(particles, n, "megopolis_tpu_apply"),
                         "megopolis_tpu_apply",
                         itemsize=state_itemsize(particles, plane_dtype))
    key_off, key_seed = jax.random.split(key)
    offsets = jax.random.randint(key_off, (num_iters,), 0, n, dtype=jnp.int32)
    seed = key_to_seed(key_seed).reshape(1)
    w2 = compress_plane(weights.reshape(n // LANES, LANES), plane_dtype)
    planes, state_shape = pack_state_planes(particles)
    planes = compress_plane(planes, plane_dtype)
    k2, out = megopolis_pallas_fused(
        w2, planes, offsets, seed, num_iters=num_iters, interpret=interpret
    )
    out = out.astype(particles.dtype)
    return unpack_state_planes(out, state_shape), k2.reshape(n)


def megopolis_tpu_apply_batch(
    key: jax.Array,
    weights: jnp.ndarray,
    particles: jnp.ndarray,
    num_iters: int,
    *,
    interpret: bool = True,
    plane_dtype="float32",
):
    """Fused bank launch under the ``megopolis_tpu_batch`` contract: the
    offset table is drawn ONCE (same key derivation) and shared by every
    row, per-row RNG seeds.  ``particles``: ``[B, N, ...]``.  Returns
    ``(particles'[B, N, ...], ancestors int32[B, N])``."""
    if weights.ndim != 2:
        raise ValueError(
            f"megopolis_tpu_apply_batch expects weights[B, N]; got {weights.shape}"
        )
    bsz, n = weights.shape
    if n % TILE != 0:
        raise ValueError(
            f"megopolis_tpu_apply_batch requires N % {TILE} == 0; got N={n}."
        )
    key_off, key_rows = jax.random.split(key)
    offsets = jax.random.randint(key_off, (num_iters,), 0, n, dtype=jnp.int32)
    offsets2d = jnp.broadcast_to(offsets[None, :], (bsz, num_iters))
    seeds = key_to_seed(jax.random.split(key_rows, bsz))
    return _apply_rows_launch(weights, particles, offsets2d, seeds,
                              num_iters=num_iters, interpret=interpret,
                              who="megopolis_tpu_apply_batch",
                              plane_dtype=plane_dtype)


def megopolis_tpu_apply_rows(
    keys: jax.Array,
    weights: jnp.ndarray,
    particles: jnp.ndarray,
    num_iters: int,
    *,
    interpret: bool = True,
    plane_dtype="float32",
):
    """Fused bank launch over EXPLICIT per-row keys (the filter-bank path):
    each row derives its own offset table and seed exactly as the single
    ``megopolis_tpu_apply`` would, so row b is bit-identical to the single
    call with ``keys[b]`` — in ONE leading-batch-grid launch."""
    if weights.ndim != 2:
        raise ValueError(
            f"megopolis_tpu_apply_rows expects weights[B, N]; got {weights.shape}"
        )
    bsz, n = weights.shape
    if n % TILE != 0:
        raise ValueError(
            f"megopolis_tpu_apply_rows requires N % {TILE} == 0; got N={n}."
        )
    split = jax.vmap(jax.random.split)(keys)
    keys_off, keys_seed = split[:, 0], split[:, 1]
    offsets2d = jax.vmap(
        lambda k: jax.random.randint(k, (num_iters,), 0, n, dtype=jnp.int32)
    )(keys_off)
    seeds = key_to_seed(keys_seed)
    return _apply_rows_launch(weights, particles, offsets2d, seeds,
                              num_iters=num_iters, interpret=interpret,
                              who="megopolis_tpu_apply_rows",
                              plane_dtype=plane_dtype)


def _apply_rows_launch(weights, particles, offsets2d, seeds, *, num_iters,
                       interpret, who, plane_dtype="float32"):
    return run_fused_bank(
        lambda w3, planes: megopolis_pallas_fused_rows(
            w3, planes, offsets2d, seeds, num_iters=num_iters, interpret=interpret
        ),
        weights, particles, who, plane_dtype=plane_dtype,
    )


def megopolis_tpu_step(
    key: jax.Array,
    log_weights: jnp.ndarray,
    particles: jnp.ndarray,
    num_iters: int,
    ess_threshold,
    *,
    interpret: bool = True,
    plane_dtype="float32",
):
    """Fused SMC step (DESIGN.md §12): normalise → ESS → conditional
    resample → state copy in ONE kernel launch.  ``log_weights``: f32[N]
    UNNORMALISED; RNG/offset derivation is identical to
    ``megopolis_tpu_apply`` so the resample branch is bit-identical to
    ``apply(key, normalise_log_weights(log_weights), particles)``.
    Returns ``(particles', ancestors, stats f32[4])`` with ``stats`` =
    (ess_norm, log_evidence_incr, resampled, max_weight) — DESIGN.md §15."""
    n = log_weights.shape[0]
    if n % TILE != 0:
        raise ValueError(
            f"megopolis_tpu_step requires N % {TILE} == 0 (one f32 VMEM tile); got N={n}."
        )
    check_vmem_resident(n, "megopolis_tpu_step", "log-weight array",
                        remedy="Compose Resampler.step on the reference/xla backend "
                               "above this size.",
                        itemsize=plane_itemsize(plane_dtype))
    check_state_resident(n, state_dim_of(particles, n, "megopolis_tpu_step"),
                         "megopolis_tpu_step",
                         itemsize=state_itemsize(particles, plane_dtype))
    key_off, key_seed = jax.random.split(key)
    offsets = jax.random.randint(key_off, (num_iters,), 0, n, dtype=jnp.int32)
    seed = key_to_seed(key_seed).reshape(1)
    thr = jnp.asarray(ess_threshold, jnp.float32).reshape(1)
    lw2 = compress_plane(log_weights.reshape(n // LANES, LANES), plane_dtype)
    planes, state_shape = pack_state_planes(particles)
    planes = compress_plane(planes, plane_dtype)
    k2, out, stats = megopolis_pallas_step(
        lw2, planes, offsets, seed, thr, num_iters=num_iters, interpret=interpret
    )
    out = out.astype(particles.dtype)
    return unpack_state_planes(out, state_shape), k2.reshape(n), stats


def megopolis_tpu_step_rows(
    keys: jax.Array,
    log_weights: jnp.ndarray,
    particles: jnp.ndarray,
    num_iters: int,
    ess_threshold,
    *,
    interpret: bool = True,
    plane_dtype="float32",
):
    """Fused SMC-step bank over EXPLICIT per-row keys: row b is
    bit-identical to ``megopolis_tpu_step(keys[b], ...)`` — each row takes
    its own on-chip resample decision in ONE leading-batch-grid launch.
    Returns ``(particles'[B, N, ...], ancestors int32[B, N],
    stats f32[B, 4])``."""
    if log_weights.ndim != 2:
        raise ValueError(
            f"megopolis_tpu_step_rows expects log_weights[B, N]; got {log_weights.shape}"
        )
    bsz, n = log_weights.shape
    if n % TILE != 0:
        raise ValueError(
            f"megopolis_tpu_step_rows requires N % {TILE} == 0; got N={n}."
        )
    check_vmem_resident(n, "megopolis_tpu_step_rows", "log-weight array",
                        remedy="Compose Resampler.step_rows on the reference/xla "
                               "backend above this size.",
                        itemsize=plane_itemsize(plane_dtype))
    split = jax.vmap(jax.random.split)(keys)
    keys_off, keys_seed = split[:, 0], split[:, 1]
    offsets2d = jax.vmap(
        lambda k: jax.random.randint(k, (num_iters,), 0, n, dtype=jnp.int32)
    )(keys_off)
    seeds = key_to_seed(keys_seed)
    thr = jnp.asarray(ess_threshold, jnp.float32).reshape(1)
    return run_step_bank(
        lambda lw3, planes: megopolis_pallas_step_rows(
            lw3, planes, offsets2d, seeds, thr, num_iters=num_iters,
            interpret=interpret
        ),
        log_weights, particles, "megopolis_tpu_step_rows",
        plane_dtype=plane_dtype,
    )
