"""Public wrapper for the Metropolis TPU kernel (VMEM-resident strawman)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.common import TILE, key_to_seed
from repro.kernels.metropolis.metropolis import LANES, metropolis_pallas

# Weights must stay VMEM-resident for the random gather; cap N (DESIGN.md §2).
MAX_VMEM_PARTICLES = 1 << 20


def metropolis_tpu(
    key: jax.Array,
    weights: jnp.ndarray,
    num_iters: int,
    *,
    interpret: bool = True,
) -> jnp.ndarray:
    n = weights.shape[0]
    if n % TILE != 0:
        raise ValueError(f"metropolis_tpu requires N % {TILE} == 0; got {n}")
    if n > MAX_VMEM_PARTICLES:
        raise ValueError(
            f"metropolis_tpu random-gather kernel caps N at {MAX_VMEM_PARTICLES} "
            "(whole weight array must be VMEM-resident) — the scaling wall the "
            "paper's coalescing removes. Use megopolis_tpu."
        )
    seed = key_to_seed(key).reshape(1)
    w2 = weights.reshape(n // LANES, LANES)
    k2 = metropolis_pallas(w2, seed, num_iters=num_iters, interpret=interpret)
    return k2.reshape(n)
