"""Quickstart: the paper's algorithm in five minutes.

Resamples one degenerate weight population with Megopolis and every
comparison method, reproducing the paper's headline quality ordering, the
eq. (3) iteration selection, and the memory-transaction argument.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core import get_resampler, list_resamplers
from repro.core.iterations import select_iterations
from repro.core.metrics import bias_variance
from repro.core.transactions import index_streams, transactions_per_group
from repro.core.weightgen import gaussian_weights
from repro.kernels.megopolis.ops import megopolis_tpu

N = 1 << 14
Y = 3.0  # weight concentration (paper eq. 12); higher = more degenerate
RUNS = 64

key = jax.random.PRNGKey(0)
weights = gaussian_weights(key, N, Y)
b = int(select_iterations(weights, epsilon=0.01))
print(f"N={N} particles, y={Y} -> B={b} iterations (paper eq. 3)\n")

print(f"{'resampler':22s} {'MSE/N':>10s} {'bias%':>8s}")
for name in ("megopolis", "metropolis", "metropolis_c1", "metropolis_c2",
             "multinomial", "systematic", "improved_systematic"):
    fn = get_resampler(name)
    kw = {"num_iters": b} if "metropolis" in name or name == "megopolis" else {}

    @jax.jit
    def one(k):
        return jnp.bincount(fn(k, weights, **kw), length=N)

    offs = jax.lax.map(one, jax.random.split(jax.random.fold_in(key, 1), RUNS))
    var, bias_sq, total = bias_variance(offs, weights)
    print(f"{name:22s} {float(total)/N:10.4f} {100*float(bias_sq/total):8.2f}")

# the TPU kernel (interpret mode on CPU) agrees with the core algorithm
anc = megopolis_tpu(key, weights[: (N // 1024) * 1024], b)
print(f"\nPallas kernel resampled {anc.shape[0]} particles "
      f"(ancestor[0..5] = {anc[:6].tolist()})")

# the paper's speed argument, counted: transactions per 32-thread warp
for algo in ("megopolis", "metropolis"):
    t = [transactions_per_group(ix).mean()
         for ix in index_streams(algo, 7, N, 4)]
    print(f"{algo:12s}: {sum(t)/len(t):5.2f} memory transactions / warp-iteration")
print(f"\navailable resamplers: {list_resamplers()}")
