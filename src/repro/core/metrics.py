"""Resampling quality metrics (paper §5.1, eqs. 14-21).

All metrics operate on offspring vectors ``o_k[i]`` = number of offspring of
particle ``i`` in Monte Carlo run ``k`` (derived from ancestors with
``offspring_counts``).
"""

from __future__ import annotations

import jax.numpy as jnp


def _guarded_shift(log_w: jnp.ndarray, axis: int) -> jnp.ndarray:
    """Shift-by-max stabiliser, guarded against an all-``-inf`` row: a
    non-finite max would turn the shift into ``-inf - -inf = nan``.  For
    finite maxima the guard is a bitwise no-op (``where`` returns the same
    value), so every consumer keeps its exact pre-guard arithmetic."""
    m = jnp.max(log_w, axis=axis, keepdims=True)
    return jnp.where(jnp.isfinite(m), m, jnp.zeros_like(m))


def degenerate_log_weights(log_w: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    """The degenerate-bank flag (DESIGN.md §16): True where a row of
    log-weights carries NO usable information — all ``-inf`` (max is
    ``-inf``), any ``nan`` (propagates through ``max``), or any ``+inf``
    (infinite relative weight poisons every ratio).  One cheap reduction,
    shared by ``normalise_log_weights`` and the fused step kernels
    (``kernels/common.step_stats``) so host and kernel agree bit-for-bit
    on which banks are degenerate.  One-hot rows (``-inf`` everywhere but
    one finite entry) are NOT degenerate — they still rank particles."""
    return ~jnp.isfinite(jnp.max(log_w, axis=axis))


def normalise_log_weights(log_w: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    """Shift-by-max linear weights ``exp(log_w - max(log_w))`` — THE
    normalisation every log-weight consumer shares (filter, AIS sampler,
    SMC decoding, and the fused ``Resampler.step`` composition), so the
    fused kernels and the host path can never disagree on the weights a
    resampler sees.  The result is in [0, 1] with at least one exact 1.0
    for finite inputs.

    Degenerate rows (``degenerate_log_weights``: all ``-inf``, any
    ``nan``/``+inf``) come back UNIFORM ``1/N`` instead of the all-zero /
    nan planes the pre-§16 code produced: no ratio survives a degenerate
    bank, so uniform is the only defensible answer, and it keeps ESS and
    every downstream division finite on all backends bit-identically.
    For non-degenerate rows the fallback is a bitwise no-op (``where``
    returns the untouched value)."""
    n = log_w.shape[axis]
    deg = degenerate_log_weights(log_w, axis=axis)
    deg = jnp.expand_dims(deg, axis)
    w = jnp.exp(log_w - _guarded_shift(log_w, axis))
    return jnp.where(deg, jnp.full_like(w, 1.0 / n), w)


def degenerate_weights(w: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    """Linear-weight twin of ``degenerate_log_weights`` for the
    weights-typed entries (``__call__``/``apply``): a bank is degenerate
    when its total mass is not a positive finite number — all-zero rows
    (sum 0), any ``nan`` (sum nan), any ``±inf``.  True means no ratio
    ``w_i / Σw`` is defined and the §16 recover policy substitutes the
    uniform bank."""
    s = jnp.sum(w, axis=axis)
    return ~(jnp.isfinite(s) & (s > 0))


def _tiny_floor(dtype) -> float:
    """Smallest safe positive floor for guards in ``dtype``: 1e-30 where
    that is a normal number (f32, bf16 — both carry the 8-bit exponent),
    else the dtype's min normal (f16: ~6.1e-5).  Anything below min normal
    flushes to zero under XLA and a ``log``/division guard built on it
    silently reintroduces the ``-inf``/``inf`` it was meant to stop."""
    return max(float(jnp.finfo(dtype).tiny), 1e-30)


def log_weights_from_linear(w: jnp.ndarray) -> jnp.ndarray:
    """Log-weights from unnormalised linear weights, floored dtype-aware.

    The floor must stay in the input dtype's NORMAL range (``_tiny_floor``):
    1e-30 for f32/bf16, min normal for f16.  Centralised from the ad-hoc
    filter-diagnostic guard so filter/AIS/decode all floor identically."""
    return jnp.log(jnp.maximum(w, _tiny_floor(w.dtype)))


def effective_sample_size(log_w: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    """ESS = (Σw)² / Σw² from log-weights, shift-by-max stabilised.

    THE single-host ESS helper (the resampling trigger of `smc/decode.py`,
    `pf/filter.py` diagnostics, and the `ais/` sampler).  Weights need not
    be normalised — ESS depends only on ratios, the same property the
    Metropolis-family resamplers rely on.  The multi-host psum form lives
    in ``repro.core.distributed.effective_sample_size``.

    The fused step kernels (``kernels/common.step_stats``) re-derive this
    decomposition term for term over the same flat [N] reduction shape, so
    the on-chip ESS is bit-identical to this host value.
    """
    w = normalise_log_weights(log_w, axis=axis)
    s1 = jnp.sum(w, axis=axis)
    s2 = jnp.sum(w * w, axis=axis)
    # Dtype-aware guard: 1e-30 is a flush-to-zero subnormal in f16, which
    # would leave the degenerate-row division at inf (bitwise unchanged for
    # f32/bf16 inputs).
    return jnp.square(s1) / jnp.maximum(s2, _tiny_floor(s2.dtype))


def log_mean_weight(log_w: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    """``log(mean(exp(log_w)))`` via the same shift-by-max decomposition the
    fused step kernels run on-chip: ``m + log(Σ exp(log_w - m)) - log(N)``.

    This is the per-step log-evidence increment of SMC (the
    ``logsumexp(log_w) - log(N)`` of the AIS sampler, re-expressed so host
    and kernel share one exact f32 formula — a fused ``step`` adds a
    bit-identical increment)."""
    m = _guarded_shift(log_w, axis)
    s1 = jnp.sum(jnp.exp(log_w - m), axis=axis)
    n = log_w.shape[axis]
    return (jnp.squeeze(m, axis=axis) + jnp.log(s1)) - jnp.log(jnp.float32(n))


def max_normalised_weight(log_w: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    """Largest normalised weight ``max(w) / Σw`` — the degeneracy diagnostic
    complementing ESS (a population collapsing onto one particle drives this
    toward 1.0 while ESS drives toward 1/N).

    Shares the shift-by-max decomposition of ``effective_sample_size`` term
    for term; the fused step kernels (``kernels/common.step_stats``) compute
    the same ``max(w) / max(Σw, floor)`` over the same flat [N] reduction, so
    the on-chip value is bit-identical to this host value (DESIGN.md §15).
    """
    w = normalise_log_weights(log_w, axis=axis)
    s1 = jnp.sum(w, axis=axis)
    return jnp.max(w, axis=axis) / jnp.maximum(s1, _tiny_floor(s1.dtype))


def unique_ancestor_count(ancestors: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    """Survivor count: the number of DISTINCT ancestors in an int32 ancestor
    vector (Murray–Lee–Jacob's unique-particle degeneracy diagnostic).

    Sort-and-count-breaks — deliberately no ``bincount``/scatter: integer
    sort is bit-exact on every backend AND keeps the §13 census pass clean
    (a scatter indexed by a kernel's ancestor output would grade as the HBM
    round-trip the fused path forbids).  Identity ancestors count N, a fully
    collapsed population counts 1.  Works on ``[N]`` and batched ``[..., N]``
    vectors alike; returns int32."""
    s = jnp.sort(ancestors, axis=axis)
    first = jnp.ones(s.shape[:-1] + (1,), jnp.int32)
    breaks = (
        jnp.moveaxis(s, axis, -1)[..., 1:] != jnp.moveaxis(s, axis, -1)[..., :-1]
    ).astype(jnp.int32)
    return jnp.sum(jnp.concatenate([first, breaks], axis=-1), axis=-1)


def offspring_counts(ancestors: jnp.ndarray, n: int) -> jnp.ndarray:
    """o[i] = #{j : ancestors[j] == i}."""
    return jnp.bincount(ancestors, length=n)


def expected_offspring(weights: jnp.ndarray) -> jnp.ndarray:
    """N * w_i / sum(w) (the target of eq. 14)."""
    n = weights.shape[0]
    return n * weights / jnp.sum(weights)


def squared_error(offspring: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    """SE(o_k), eq. (14)."""
    return jnp.sum((offspring - expected_offspring(weights)) ** 2)


def mse(offsprings: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    """MSE over K runs, eq. (15).  ``offsprings``: int[K, N]."""
    target = expected_offspring(weights)
    return jnp.mean(jnp.sum((offsprings - target) ** 2, axis=-1))


def bias_variance(offsprings: jnp.ndarray, weights: jnp.ndarray):
    """Decomposition eqs. (16)-(20): returns (var, bias_sq, mse).

    ``offsprings``: int[K, N] over K Monte Carlo runs of one weight vector.
    """
    k = offsprings.shape[0]
    target = expected_offspring(weights)
    o_hat = jnp.mean(offsprings.astype(jnp.float32), axis=0)  # eq. 19
    # K=1 carries no variance information: eq. (17)'s k-1 denominator would
    # be 0/0 = nan.  The deviations are identically zero there, so dividing
    # by 1 instead yields the defined limit var = 0 (mse degrades to bias²).
    var = jnp.sum(jnp.sum((offsprings - o_hat) ** 2, axis=0) / max(k - 1, 1))
    bias_sq = jnp.sum((o_hat - target) ** 2)  # eq. 18
    return var, bias_sq, var + bias_sq  # eq. 16


def bias_contribution(offsprings: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    """||Bias||^2 / MSE, eq. (21)."""
    var, bias_sq, total = bias_variance(offsprings, weights)
    return bias_sq / jnp.maximum(total, 1e-30)
