from repro.smc.decode import SMCDecodeConfig, smc_decode, ess  # noqa: F401
