from repro.pf.filter import ParticleFilter, StateSpaceModel, run_filter  # noqa: F401
from repro.pf.models import ungm  # noqa: F401
from repro.pf.metrics import rmse, resample_ratio  # noqa: F401
