"""Architecture registry: 10 assigned archs + the paper's own PF config.

Each ``<id>.py`` exports an ``ArchSpec`` named ``ARCH`` with the exact
published configuration (FULL) and a reduced same-family SMOKE variant run
on CPU by tests/test_configs.py.  FULL configs are exercised only via the
dry-run (ShapeDtypeStructs, never allocated).
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

from repro.configs.shapes import SHAPES, ShapeSpec, applicable_shapes  # noqa: F401
from repro.models import ModelConfig


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    name: str
    family: str  # dense | moe | audio | vlm | hybrid | ssm
    model: ModelConfig
    smoke: ModelConfig
    source: str
    # train_4k memory knobs (per-cell overrides keyed by shape name)
    microbatches: int = 1
    moment_dtype: str = "float32"  # bf16 moments for archs that need the HBM
    notes: str = ""


ARCH_IDS = (
    "nemotron_4_15b",
    "gemma3_27b",
    "h2o_danube_3_4b",
    "qwen3_0_6b",
    "dbrx_132b",
    "llama4_maverick_400b_a17b",
    "musicgen_large",
    "chameleon_34b",
    "zamba2_2_7b",
    "mamba2_1_3b",
)

def _norm(name: str) -> str:
    return name.replace("-", "_").replace(".", "_")


_ALIASES = {_norm(i): i for i in ARCH_IDS}


def get_arch(name: str) -> ArchSpec:
    key = _ALIASES.get(_norm(name), name)
    if key not in ARCH_IDS:
        raise KeyError(f"unknown arch {name!r}; choices: {list(ARCH_IDS)}")
    return importlib.import_module(f"repro.configs.{key}").ARCH


def list_archs() -> list[str]:
    return list(ARCH_IDS)
