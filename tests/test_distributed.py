"""Distributed resampling tests — executed in a subprocess with 8 virtual
devices (the main pytest process must keep 1 device; jax locks the device
count at first init)."""

import os
import pathlib
import subprocess
import sys

import pytest

_PROG = pathlib.Path(__file__).parent / "_distributed_prog.py"
_SRC = str(pathlib.Path(__file__).parents[1] / "src")


@pytest.mark.slow
def test_distributed_megopolis_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, str(_PROG)],
        env=env,
        capture_output=True,
        text=True,
        timeout=900,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr[-3000:]}"
    assert "ALL_OK" in out.stdout, out.stdout
    for name in ("static_exactness", "dynamic_exactness", "quality_parity", "gather", "island", "ess"):
        assert f"OK {name}" in out.stdout, out.stdout
