"""Prefix-sum resamplers: the unbiased baselines of the paper's §6.5.

``multinomial`` is Algorithm 7 (Murray) — binary search over the exclusive
prefix sum is ``jnp.searchsorted``.  ``improved_systematic`` is a faithful
port of Algorithm 8 (Nicely & Wells): a local bidirectional walk starting at
``a = i``; it provably computes ``searchsorted(cumsum, u, 'left')`` (our
``systematic``), which the test-suite asserts.  ``stratified`` and
``residual`` are the classical extras (Douc & Cappé).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.resamplers.batched import batch_via_vmap


def _inclusive_cumsum(weights: jnp.ndarray) -> jnp.ndarray:
    return jnp.cumsum(weights)


def multinomial(key: jax.Array, weights: jnp.ndarray, num_iters: int = 0) -> jnp.ndarray:
    """Paper Algorithm 7.  ``num_iters`` ignored (API uniformity)."""
    del num_iters
    n = weights.shape[0]
    c = _inclusive_cumsum(weights)
    u = jax.random.uniform(key, (n,), weights.dtype) * c[-1]
    return jnp.searchsorted(c, u, side="right").astype(jnp.int32)


def systematic(key: jax.Array, weights: jnp.ndarray, num_iters: int = 0) -> jnp.ndarray:
    """Systematic resampling via searchsorted (result identical to Alg. 8)."""
    del num_iters
    n = weights.shape[0]
    c = _inclusive_cumsum(weights)
    u0 = jax.random.uniform(key, (), weights.dtype)
    u = (jnp.arange(n, dtype=weights.dtype) + u0) * (c[-1] / n)
    return jnp.searchsorted(c, u, side="left").astype(jnp.int32)


def improved_systematic(key: jax.Array, weights: jnp.ndarray, num_iters: int = 0) -> jnp.ndarray:
    """Faithful port of paper Algorithm 8 (bidirectional local walk).

    Each "thread" ``i`` starts at ``a = i`` and walks up while
    ``cumsum[i + l] < u`` then down while ``cumsum[i - l] >= u``.  On a GPU
    the walk is warp-synchronous; here each lane is an element of a vmapped
    ``lax.while_loop``.  Kept for fidelity + as a differential oracle for
    ``systematic``.
    """
    del num_iters
    n = weights.shape[0]
    c = _inclusive_cumsum(weights)
    u0 = jax.random.uniform(key, (), weights.dtype)
    u = (jnp.arange(n, dtype=weights.dtype) + u0) * (c[-1] / n)

    def walk(i, ui):
        # Phase 1 (Alg. 8 lines 8-18): a <- i + min{off >= 0 : c[i+off] >= ui}.
        def up_cond(state):
            a, off = state
            in_range = (i + off) <= (n - 1)
            return in_range & (c[jnp.minimum(i + off, n - 1)] < ui)

        def up_body(state):
            a, off = state
            return a + 1, off + 1

        a, _ = jax.lax.while_loop(up_cond, up_body, (i, jnp.int32(0)))

        # Phase 2 (lines 19-29): walk down while c[i - off] >= ui.
        def dn_cond(state):
            a2, off = state
            in_range = i >= off
            return in_range & (c[jnp.maximum(i - off, 0)] >= ui)

        def dn_body(state):
            a2, off = state
            return a2 - 1, off + 1

        a2, _ = jax.lax.while_loop(dn_cond, dn_body, (a, jnp.int32(1)))
        return jnp.clip(a2, 0, n - 1)

    return jax.vmap(walk)(jnp.arange(n, dtype=jnp.int32), u).astype(jnp.int32)


def stratified(key: jax.Array, weights: jnp.ndarray, num_iters: int = 0) -> jnp.ndarray:
    """Stratified resampling: one uniform per stratum [i/N, (i+1)/N)."""
    del num_iters
    n = weights.shape[0]
    c = _inclusive_cumsum(weights)
    u = (jnp.arange(n, dtype=weights.dtype) + jax.random.uniform(key, (n,), weights.dtype)) * (
        c[-1] / n
    )
    return jnp.searchsorted(c, u, side="left").astype(jnp.int32)


def residual(key: jax.Array, weights: jnp.ndarray, num_iters: int = 0) -> jnp.ndarray:
    """Residual resampling: deterministic floor(N w) copies + multinomial rest.

    Implemented via the equivalent "deterministic offsets into the cumsum"
    trick so it stays O(N log N) and jit-friendly.
    """
    del num_iters
    n = weights.shape[0]
    w = weights / jnp.sum(weights)
    counts = jnp.floor(n * w).astype(jnp.int32)
    n_det = jnp.sum(counts)
    resid = n * w - counts
    c = jnp.cumsum(resid)
    # Deterministic part: ancestor list where particle i appears counts[i]
    # times = searchsorted over cumsum(counts).
    cc = jnp.cumsum(counts)
    slots = jnp.arange(n, dtype=jnp.int32)
    det = jnp.searchsorted(cc, slots, side="right").astype(jnp.int32)
    # Random part fills slots >= n_det from the residual distribution.
    u = jax.random.uniform(key, (n,), weights.dtype) * c[-1]
    rnd = jnp.searchsorted(c, u, side="right").astype(jnp.int32)
    return jnp.where(slots < n_det, jnp.minimum(det, n - 1), jnp.minimum(rnd, n - 1))


# Batched entry points (DESIGN.md §4).  vmap lowers the whole family to ONE
# batched cumsum + ONE batched searchsorted (or batched bidirectional walk
# for Alg. 8) — already the single-launch form the scenario axis wants.
multinomial_batch = batch_via_vmap(multinomial)
systematic_batch = batch_via_vmap(systematic)
improved_systematic_batch = batch_via_vmap(improved_systematic)
stratified_batch = batch_via_vmap(stratified)
residual_batch = batch_via_vmap(residual)
