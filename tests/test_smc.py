"""SMC particle decoding: ESS math, resample triggering, ancestor-gather
coherence, and statistical sanity of the tempered-decoding weights."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import init_params, prefill
from repro.smc import SMCDecodeConfig, ess, smc_decode


def _setup(arch="qwen3-0.6b", n=16, prompt=4, seed=0):
    cfg = dataclasses.replace(get_arch(arch).smoke, dtype=jnp.float32, remat=False)
    key = jax.random.PRNGKey(seed)
    params = init_params(key, cfg)
    prompts = jax.random.randint(jax.random.fold_in(key, 1), (n, prompt), 0,
                                 cfg.vocab_size, jnp.int32)
    return cfg, params, prompts, key


def test_ess_bounds():
    assert abs(float(ess(jnp.zeros(10))) - 10.0) < 1e-4  # uniform -> N
    concentrated = jnp.array([0.0] + [-100.0] * 9)
    assert float(ess(concentrated)) < 1.01  # one particle -> ~1


@pytest.mark.parametrize("resampler", ["megopolis", "metropolis", "improved_systematic"])
def test_smc_decode_runs_and_is_finite(resampler):
    cfg, params, prompts, key = _setup()
    new = 12
    logits, caches = prefill(params, cfg, prompts, max_seq=4 + new)
    smc = SMCDecodeConfig(num_particles=16, max_new_tokens=new, resampler=resampler,
                          target_temp=0.5, ess_threshold=0.9)
    tokens, log_w, stats = smc_decode(params, cfg, smc, caches, prompts[:, -1],
                                      4, jax.random.fold_in(key, 2))
    assert tokens.shape == (16, new)
    assert bool(jnp.all((tokens >= 0) & (tokens < cfg.vocab_size)))
    assert bool(jnp.all(jnp.isfinite(log_w)))
    assert int(stats["num_resamples"]) >= 1  # aggressive threshold must trigger


def test_resampling_resets_weights_and_keeps_population_valid():
    cfg, params, prompts, key = _setup(n=32)
    logits, caches = prefill(params, cfg, prompts, max_seq=4 + 8)
    smc = SMCDecodeConfig(num_particles=32, max_new_tokens=8, target_temp=0.3,
                          ess_threshold=0.99)  # resample nearly every step
    tokens, log_w, stats = smc_decode(params, cfg, smc, caches, prompts[:, -1],
                                      4, jax.random.fold_in(key, 3))
    # after a resample at the last step, weights are reset to zero
    hist = np.asarray(stats["ess_history"])
    assert hist.max() <= 32.0 + 1e-3
    assert int(stats["num_resamples"]) >= 4


def test_greedy_limit_matches_argmax_decoding():
    """With temp -> 0 the proposal collapses to argmax and no weight
    spread accumulates (ESS stays N, no resamples)."""
    cfg, params, prompts, key = _setup(n=8)
    logits, caches = prefill(params, cfg, prompts, max_seq=4 + 5)
    smc = SMCDecodeConfig(num_particles=8, max_new_tokens=5,
                          proposal_temp=1e-4, target_temp=1e-4,
                          ess_threshold=0.1)
    tokens, log_w, stats = smc_decode(params, cfg, smc, caches, prompts[:, -1],
                                      4, jax.random.fold_in(key, 4))
    assert int(stats["num_resamples"]) == 0
    np.testing.assert_allclose(np.asarray(log_w), 0.0, atol=1e-3)


def test_ancestor_gather_coherence():
    """All particles forced onto one ancestor must continue identically
    afterwards (cache gather correctness): identical prompts + identical
    sampling keys per particle -> identical continuations."""
    cfg, params, _, key = _setup(n=4)
    same_prompt = jnp.tile(jnp.array([[1, 2, 3, 4]], jnp.int32), (4, 1))
    logits, caches = prefill(params, cfg, same_prompt, max_seq=4 + 6)
    smc = SMCDecodeConfig(num_particles=4, max_new_tokens=6,
                          proposal_temp=1e-4, target_temp=1e-4)
    tokens, _, _ = smc_decode(params, cfg, smc, caches, same_prompt[:, -1],
                              4, jax.random.fold_in(key, 5))
    for i in range(1, 4):
        np.testing.assert_array_equal(np.asarray(tokens[0]), np.asarray(tokens[i]))
