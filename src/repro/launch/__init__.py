# Launch layer: production meshes, dry-run compiler, train/serve drivers.
# NOTE: importing this package must NOT touch jax device state (mesh
# construction is behind functions) — dryrun.py sets XLA_FLAGS before any
# jax import and only works if nothing initialised devices earlier.
