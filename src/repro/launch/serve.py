"""Serving driver: prefill + (greedy | SMC-particle) decode.

CPU-scale entry point exercising the same model/serving code the dry-run
lowers at production shapes.  Batched requests: each request is a prompt of
token ids; SMC mode treats the batch as the particle population (the
paper's resampler running live inside the decode loop).

    python -m repro.launch.serve --arch zamba2-2.7b --smoke \
        --num-particles 64 --new-tokens 32 --resampler megopolis
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.models import init_params, prefill
from repro.smc import SMCDecodeConfig, smc_decode


def serve_once(arch_name: str, *, smoke: bool = True, num_particles: int = 64,
               prompt_len: int = 16, new_tokens: int = 32,
               resampler: str = "megopolis", seed: int = 0,
               target_temp: float = 0.7):
    arch = get_arch(arch_name)
    cfg = arch.smoke if smoke else arch.model
    cfg = dataclasses.replace(cfg, dtype=jnp.float32, remat=False)
    key = jax.random.PRNGKey(seed)
    k_param, k_prompt, k_decode = jax.random.split(key, 3)
    params = init_params(k_param, cfg)

    max_seq = prompt_len + new_tokens
    if cfg.embeds_input:
        prompts = jax.random.normal(
            k_prompt, (num_particles, prompt_len, cfg.d_model), cfg.dtype)
        first = jnp.zeros((num_particles,), jnp.int32)
    else:
        prompts = jax.random.randint(
            k_prompt, (num_particles, prompt_len), 0, cfg.vocab_size, jnp.int32)
        first = prompts[:, -1]

    t0 = time.perf_counter()
    logits, caches = prefill(params, cfg, prompts, max_seq)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    smc_cfg = SMCDecodeConfig(
        num_particles=num_particles, max_new_tokens=new_tokens,
        resampler=resampler, target_temp=target_temp)
    t0 = time.perf_counter()
    tokens, log_w, stats = smc_decode(
        params, cfg, smc_cfg, caches, first, prompt_len, k_decode)
    jax.block_until_ready(tokens)
    t_decode = time.perf_counter() - t0
    return {
        "tokens": tokens,
        "log_weights": log_w,
        "num_resamples": int(stats["num_resamples"]),
        "final_ess": float(stats["ess_history"][-1]),
        "prefill_s": t_prefill,
        "decode_s": t_decode,
        "tok_per_s": num_particles * new_tokens / t_decode,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--num-particles", type=int, default=64)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--resampler", default="megopolis")
    a = ap.parse_args(argv)
    out = serve_once(a.arch, num_particles=a.num_particles, prompt_len=a.prompt_len,
                     new_tokens=a.new_tokens, resampler=a.resampler)
    print(f"{a.arch}: decoded {a.num_particles}x{a.new_tokens} tokens; "
          f"resamples={out['num_resamples']} final_ess={out['final_ess']:.1f} "
          f"prefill={out['prefill_s']*1e3:.0f}ms decode={out['decode_s']*1e3:.0f}ms "
          f"({out['tok_per_s']:.0f} tok/s)")


if __name__ == "__main__":
    main()
