"""Paper Fig. 6: MSE/N, bias contribution, execution time and speedup vs
Megopolis for {Megopolis, Metropolis, C1-PS128, C1-PS2048, C2-PS128,
C2-PS2048} on Gaussian-likelihood weights (eq. 12), y in {0..4}.

CI scale by default (N up to 2^16, K=32); ``--full`` restores the paper's
2^22 / K=256 regime.  ``--backend`` selects the execution surface for the
WHOLE method set (the kernel matrix is complete, DESIGN.md §9): under a
pallas backend the method set uses kernel-legal geometry — Megopolis at
segment=1024, C1/C2 at partition_size_bytes=4096 (one VMEM tile each) —
and the default grid shrinks (interpret mode is a validation surface;
its absolute timings are meaningless).
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from benchmarks.common import offsprings_for, print_table, time_fn, write_csv
from repro.core import MegopolisSpec, MetropolisC1Spec, MetropolisC2Spec, MetropolisSpec
from repro.core.iterations import gaussian_weight_iterations
from repro.core.metrics import bias_variance
from repro.core.spec import BACKENDS, KERNEL_PARTITION_BYTES, KERNEL_SEGMENT
from repro.core.weightgen import gaussian_weights

# One typed spec template per competitor (DESIGN.md §9); the per-grid-point
# iteration count is a spec.replace sweep, not kwargs plumbing.
ALGOS = {
    "megopolis": MegopolisSpec(),
    "metropolis": MetropolisSpec(),
    "c1_ps128": MetropolisC1Spec(partition_size_bytes=128),
    "c1_ps2048": MetropolisC1Spec(partition_size_bytes=2048),
    "c2_ps128": MetropolisC2Spec(partition_size_bytes=128),
    "c2_ps2048": MetropolisC2Spec(partition_size_bytes=2048),
}


def algos_for_backend(backend: str) -> dict:
    """The Fig. 6 method set on ``backend``, with kernel-legal geometry."""
    if backend not in ("pallas", "pallas_interpret"):
        return {name: t.replace(backend=backend) for name, t in ALGOS.items()}
    return {
        "megopolis": MegopolisSpec(segment=KERNEL_SEGMENT, backend=backend),
        "metropolis": MetropolisSpec(backend=backend),
        "c1_ps4096": MetropolisC1Spec(
            partition_size_bytes=KERNEL_PARTITION_BYTES, backend=backend
        ),
        "c2_ps4096": MetropolisC2Spec(
            partition_size_bytes=KERNEL_PARTITION_BYTES, backend=backend
        ),
    }


def run(full: bool = False, weight_gen=gaussian_weights, grid=(0.0, 1.0, 2.0, 3.0, 4.0),
        param_name: str = "y", csv_name: str = "fig6.csv", b_for=None,
        backend: str = "reference"):
    pallas = backend in ("pallas", "pallas_interpret")
    ns = [2**e for e in ((14, 18, 22) if full else (10, 11, 12) if pallas else (10, 12, 14))]
    runs = 256 if full else 8 if pallas else 16
    seqs = 4 if full else 1
    b_for = b_for or (lambda p: gaussian_weight_iterations(p, 0.01))
    algos = algos_for_backend(backend)

    rows = []
    for n in ns:
        for p in grid:
            iters = int(b_for(p))
            for name, template in algos.items():
                resample = template.replace(num_iters=iters).build()
                mse_acc, bias_acc = 0.0, 0.0
                for s in range(seqs):
                    kw_w = jax.random.fold_in(jax.random.PRNGKey(17), int(p * 100) + s)
                    w = weight_gen(kw_w, n, p)
                    off = offsprings_for(resample, jax.random.fold_in(kw_w, 1), w, runs)
                    var, bias_sq, total = bias_variance(off, w)
                    mse_acc += float(total) / n
                    bias_acc += float(bias_sq / jnp.maximum(total, 1e-30))
                jit_fn = jax.jit(resample)
                w = weight_gen(jax.random.PRNGKey(3), n, p)
                t = time_fn(lambda k: jit_fn(k, w), jax.random.PRNGKey(5),
                            warmup=1, repeats=3)
                rows.append({
                    "n": n, param_name: p, "B": iters, "algo": name,
                    "backend": backend,
                    "mse_over_n": mse_acc / seqs,
                    "bias_contrib": bias_acc / seqs,
                    "time_s": t,
                })
    # speedup columns (relative to megopolis at same (n, p))
    base = {(r["n"], r[param_name]): r["time_s"] for r in rows if r["algo"] == "megopolis"}
    for r in rows:
        r["speedup_vs_megopolis"] = base[(r["n"], r[param_name])] / r["time_s"]
    write_csv(csv_name, rows)
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--backend", choices=BACKENDS, default="reference",
                    help="execution surface for the whole method set "
                         "(pallas_interpret validates the kernels on CPU)")
    args = ap.parse_args(argv)
    rows = run(full=args.full, backend=args.backend)
    print_table([r for r in rows if r["n"] == max(x["n"] for x in rows)])


if __name__ == "__main__":
    main()
