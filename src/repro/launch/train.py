"""Fault-tolerant training driver.

Production posture (DESIGN.md §8), all demonstrable at CPU scale:

  * **exact resume** — checkpoint manifest carries step, data-stream
    position (= the step integer, see data/synthetic.py), PRNG key and
    config fingerprint; ``--resume`` reproduces the exact loss trajectory
    of an uninterrupted run (tests/test_train_driver.py asserts this).
  * **atomic + async checkpoints** — CheckpointManager (tmp+rename, daemon
    writer, retention).
  * **heartbeat** — one JSON line per step to ``<ckpt>/heartbeat.json``
    (step, loss, step-time, wall time) for external supervisors: a stale
    heartbeat is the restart signal on a real cluster.
  * **straggler detection** — rolling median step time; steps slower than
    ``straggler_factor``x the median are logged with a z-score.  On real
    multi-host runs this feeds the supervisor that evicts the slow host;
    here it exercises the code path.
  * **elastic re-mesh** — ``--resume`` onto a different device count
    reshards the checkpoint (pure function of (ckpt, new mesh)).

Usage (CPU smoke scale)::

    python -m repro.launch.train --arch qwen3-0.6b --smoke --steps 50 \
        --ckpt-dir /tmp/run1 [--resume]
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import statistics
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import CheckpointManager, latest_step, restore_checkpoint
from repro.configs import get_arch
from repro.data import SyntheticLMStream
from repro.launch.mesh import make_local_mesh
from repro.launch.sharding import model_pspecs, named
from repro.models import init_params, loss_fn
from repro.models import partitioning
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.optim.compression import CompressionConfig, compress_and_correct, compress_init


@dataclasses.dataclass
class TrainRun:
    arch: str
    steps: int = 50
    global_batch: int = 8
    seq_len: int = 128
    smoke: bool = True
    ckpt_dir: str = ""
    ckpt_every: int = 20
    resume: bool = False
    seed: int = 0
    model_axis: int = 1
    straggler_factor: float = 3.0
    compress: bool = False  # top-k+error-feedback DP gradient compression


def _heartbeat(path: str, record: dict):
    with open(path, "a") as f:
        f.write(json.dumps(record) + "\n")


def make_step(cfg, mesh, opt_cfg, compress_cfg=None):
    pspecs = model_pspecs(cfg, mesh, fsdp=False)
    rules_kw = dict(batch="data", seq=None, embed=None, vocab="model",
                    heads=None, q_seq=None, kv_heads=None, head_dim=None,
                    kv_seq=None, attn_out=None, d_inner=None, ssm_heads=None)

    def step(state, batch):
        with partitioning.rules(mesh, **rules_kw):
            loss, grads = jax.value_and_grad(lambda p: loss_fn(p, cfg, batch))(state["params"])
            if compress_cfg is not None:
                grads, resid = compress_and_correct(compress_cfg, grads, state["resid"])
                grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
            params, opt, metrics = adamw_update(opt_cfg, state["params"], grads, state["opt"])
            metrics["loss"] = loss
            new_state = {"params": params, "opt": opt}
            if compress_cfg is not None:
                new_state["resid"] = resid
            return new_state, metrics

    return jax.jit(step, donate_argnums=(0,)), pspecs


def run(tr: TrainRun) -> dict:
    arch = get_arch(tr.arch)
    cfg = arch.smoke if tr.smoke else arch.model
    cfg = dataclasses.replace(cfg, dtype=jnp.float32, remat=False) if tr.smoke else cfg
    mesh = make_local_mesh(model=tr.model_axis)
    opt_cfg = AdamWConfig(total_steps=tr.steps, warmup_steps=max(1, tr.steps // 10))
    compress_cfg = CompressionConfig() if tr.compress else None
    step_fn, pspecs = make_step(cfg, mesh, opt_cfg, compress_cfg)

    stream = SyntheticLMStream(cfg.vocab_size, tr.seq_len, tr.global_batch, seed=tr.seed)
    key = jax.random.PRNGKey(tr.seed)

    start_step = 0
    params = init_params(key, cfg)
    state = {"params": params, "opt": adamw_init(params, jnp.dtype(opt_cfg.moment_dtype))}
    if tr.compress:
        state["resid"] = compress_init(params)

    mgr = CheckpointManager(tr.ckpt_dir, keep=3) if tr.ckpt_dir else None
    if tr.resume and tr.ckpt_dir and latest_step(tr.ckpt_dir) is not None:
        tree, manifest = restore_checkpoint(tr.ckpt_dir, template=state)
        # elastic: device_put with the CURRENT mesh's shardings (the ckpt may
        # have been written from a different topology)
        state = jax.device_put(tree, named(mesh, jax.tree.map(
            lambda _: P(), tree, is_leaf=lambda x: isinstance(x, np.ndarray))))
        start_step = int(manifest["extra"]["next_step"])
        print(f"resumed at step {start_step} from {tr.ckpt_dir}")

    hb_path = os.path.join(tr.ckpt_dir, "heartbeat.json") if tr.ckpt_dir else ""
    losses, step_times = [], []
    for s in range(start_step, tr.steps):
        t0 = time.perf_counter()
        host = stream.batch(s)
        batch = {
            "inputs": jax.device_put(host["inputs"], NamedSharding(mesh, P("data", None))),
            "targets": jax.device_put(host["targets"], NamedSharding(mesh, P("data", None))),
        }
        if cfg.embeds_input:  # modality stub: hash-embed tokens on the fly
            emb = (np.asarray(host["inputs"])[..., None] % 61 - 30).astype(np.float32)
            emb = np.broadcast_to(emb, (*host["inputs"].shape, cfg.d_model)) / 30.0
            batch["inputs"] = jax.device_put(
                jnp.asarray(emb, cfg.dtype), NamedSharding(mesh, P("data", None, None)))
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        dt = time.perf_counter() - t0
        losses.append(loss)
        step_times.append(dt)

        # ---- straggler detection (rolling median)
        if len(step_times) >= 5:
            med = statistics.median(step_times[-20:])
            if dt > tr.straggler_factor * med:
                print(f"[straggler] step {s}: {dt*1e3:.0f}ms vs median {med*1e3:.0f}ms")
        if hb_path:
            _heartbeat(hb_path, {"step": s, "loss": loss, "step_time_s": dt,
                                 "time": time.time()})
        if mgr and (s + 1) % tr.ckpt_every == 0:
            mgr.save_async(s + 1, state, extra={"next_step": s + 1, "seed": tr.seed,
                                                "arch": tr.arch, "smoke": tr.smoke})
    if mgr:
        mgr.save(tr.steps, state, extra={"next_step": tr.steps, "seed": tr.seed,
                                         "arch": tr.arch, "smoke": tr.smoke})
        mgr.wait()
    return {"losses": losses, "final_loss": losses[-1] if losses else None,
            "steps_run": len(losses)}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--compress", action="store_true")
    a = ap.parse_args(argv)
    out = run(TrainRun(arch=a.arch, steps=a.steps, global_batch=a.global_batch,
                       seq_len=a.seq_len, smoke=a.smoke, ckpt_dir=a.ckpt_dir,
                       ckpt_every=a.ckpt_every, resume=a.resume,
                       model_axis=a.model_axis, compress=a.compress))
    if out["final_loss"] is None:
        print(f"nothing to do (checkpoint already at/after --steps); 0 steps run")
    else:
        print(f"final loss: {out['final_loss']:.4f} after {out['steps_run']} steps")


if __name__ == "__main__":
    main()
