"""Iteration-count selection for Metropolis-family resamplers (paper eq. 3).

    B = ceil( log(eps) / log(1 - E(w) / max(w)) )

The paper notes computing E(w) (a sum) and max(w) (a reduction) exactly is
what Metropolis-family methods try to avoid at runtime; practitioners use a
subsample estimate or a fixed application prior (their end-to-end benchmark
uses the average of runtime-computed values, ~30).  Both modes live here.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def select_iterations(weights: jnp.ndarray, epsilon: float = 0.01) -> jnp.ndarray:
    """Exact eq. (3).  Returns an int32 scalar (traced-safe)."""
    mean_w = jnp.mean(weights)
    max_w = jnp.max(weights)
    ratio = jnp.clip(mean_w / jnp.maximum(max_w, jnp.finfo(weights.dtype).tiny), 1e-12, 1 - 1e-7)
    b = jnp.ceil(jnp.log(epsilon) / jnp.log1p(-ratio))
    return jnp.maximum(b, 1).astype(jnp.int32)


def select_iterations_subsample(
    key: jax.Array, weights: jnp.ndarray, epsilon: float = 0.01, sample: int = 4096
) -> jnp.ndarray:
    """Eq. (3) from a uniform subsample — the production-mode estimator."""
    n = weights.shape[0]
    take = min(sample, n)
    idx = jax.random.randint(key, (take,), 0, n)
    return select_iterations(weights[idx], epsilon)


def gaussian_weight_iterations(y: float, epsilon: float = 0.01) -> int:
    """Closed form for the paper's eq. (12) weight family (§6.3):
    max(w) = 1/sqrt(2*pi), E(w) = exp(-y^2/4)/sqrt(4*pi)."""
    import math

    ratio = math.exp(-(y**2) / 4.0) / math.sqrt(2.0)
    return max(1, math.ceil(math.log(epsilon) / math.log(1.0 - ratio)))
