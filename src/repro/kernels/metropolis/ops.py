"""Public wrappers for the Metropolis-family TPU kernels (Algs. 2-4).

``metropolis_tpu`` / ``metropolis_tpu_batch`` are the VMEM-resident
random-gather strawman; ``metropolis_c1_tpu`` / ``metropolis_c2_tpu`` are
the Dülger segment-local variants whose partition is one (8, 128) VMEM
tile (``c1c2.py``).  Batch contract (DESIGN.md §4): the key is split once
along the batch axis and row ``b`` is bit-identical to the single call
with ``split_batch_keys(key, B)[b]``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.resamplers.batched import split_batch_keys
from repro.kernels.common import (  # noqa: F401  (MAX_VMEM_PARTICLES re-export)
    MAX_VMEM_PARTICLES,
    TILE,
    check_state_resident,
    check_tile_aligned,
    check_vmem_resident,
    compress_plane,
    key_to_seed,
    pack_state_planes,
    plane_itemsize,
    run_fused_bank,
    state_dim_of,
    state_itemsize,
    unpack_state_planes,
)
from repro.kernels.common import run_step_bank
from repro.kernels.metropolis.c1c2 import (
    metropolis_c1_pallas,
    metropolis_c1_pallas_fused,
    metropolis_c1_pallas_step,
    metropolis_c2_pallas,
    metropolis_c2_pallas_fused,
    metropolis_c2_pallas_step,
)
from repro.kernels.metropolis.metropolis import (
    LANES,
    metropolis_pallas,
    metropolis_pallas_batch,
    metropolis_pallas_fused,
    metropolis_pallas_fused_batch,
    metropolis_pallas_step,
    metropolis_pallas_step_rows,
)


def metropolis_tpu(
    key: jax.Array,
    weights: jnp.ndarray,
    num_iters: int,
    *,
    interpret: bool = True,
    plane_dtype="float32",
) -> jnp.ndarray:
    n = weights.shape[0]
    check_tile_aligned(n, "metropolis_tpu")
    check_vmem_resident(n, "metropolis_tpu", itemsize=plane_itemsize(plane_dtype))
    seed = key_to_seed(key).reshape(1)
    w2 = compress_plane(weights.reshape(n // LANES, LANES), plane_dtype)
    k2 = metropolis_pallas(w2, seed, num_iters=num_iters, interpret=interpret)
    return k2.reshape(n)


def metropolis_tpu_batch(
    key: jax.Array,
    weights: jnp.ndarray,
    num_iters: int,
    *,
    interpret: bool = True,
    plane_dtype="float32",
) -> jnp.ndarray:
    """One ``[B, R, 128]`` launch; row b == ``metropolis_tpu(split(key,B)[b],
    weights[b])`` bit-exactly (the §4 split-key contract, held on-kernel)."""
    if weights.ndim != 2:
        raise ValueError(f"metropolis_tpu_batch expects weights[B, N]; got {weights.shape}")
    bsz, n = weights.shape
    check_tile_aligned(n, "metropolis_tpu_batch")
    check_vmem_resident(n, "metropolis_tpu_batch",
                        itemsize=plane_itemsize(plane_dtype))
    seeds = key_to_seed(split_batch_keys(key, bsz))
    w3 = compress_plane(weights.reshape(bsz, n // LANES, LANES), plane_dtype)
    k3 = metropolis_pallas_batch(w3, seeds, num_iters=num_iters, interpret=interpret)
    return k3.reshape(bsz, n)


def _pack_single(weights, particles, who, *, weights_resident: bool = True,
                 plane_dtype="float32"):
    n = weights.shape[0]
    check_tile_aligned(n, who)
    if weights_resident:  # C1/C2 only keep partition tiles resident
        check_vmem_resident(n, who, itemsize=plane_itemsize(plane_dtype))
    check_state_resident(n, state_dim_of(particles, n, who), who,
                         itemsize=state_itemsize(particles, plane_dtype))
    planes, state_shape = pack_state_planes(particles)
    w2 = compress_plane(weights.reshape(n // LANES, LANES), plane_dtype)
    return n, w2, compress_plane(planes, plane_dtype), state_shape


def metropolis_tpu_apply(
    key: jax.Array,
    weights: jnp.ndarray,
    particles: jnp.ndarray,
    num_iters: int,
    *,
    interpret: bool = True,
    plane_dtype="float32",
):
    """Fused resample+gather (DESIGN.md §11): ancestors identical to
    ``metropolis_tpu``; the state copy happens in VMEM.  Returns
    ``(particles', ancestors)``."""
    n, w2, planes, state_shape = _pack_single(
        weights, particles, "metropolis_tpu_apply", plane_dtype=plane_dtype
    )
    seed = key_to_seed(key).reshape(1)
    k2, out = metropolis_pallas_fused(
        w2, planes, seed, num_iters=num_iters, interpret=interpret
    )
    out = out.astype(particles.dtype)
    return unpack_state_planes(out, state_shape), k2.reshape(n)


def _metropolis_apply_bank(seeds, weights, particles, num_iters, *, interpret,
                           who, plane_dtype="float32"):
    n = weights.shape[1]
    check_tile_aligned(n, who)
    check_vmem_resident(n, who, itemsize=plane_itemsize(plane_dtype))
    return run_fused_bank(
        lambda w3, planes: metropolis_pallas_fused_batch(
            w3, planes, seeds, num_iters=num_iters, interpret=interpret
        ),
        weights, particles, who, plane_dtype=plane_dtype,
    )


def metropolis_tpu_apply_batch(
    key: jax.Array,
    weights: jnp.ndarray,
    particles: jnp.ndarray,
    num_iters: int,
    *,
    interpret: bool = True,
    plane_dtype="float32",
):
    """Fused ``[B, R, 128]`` bank launch under the §4 split-key contract;
    row b == ``metropolis_tpu_apply(split(key, B)[b], ...)`` bit-exactly."""
    if weights.ndim != 2:
        raise ValueError(
            f"metropolis_tpu_apply_batch expects weights[B, N]; got {weights.shape}"
        )
    seeds = key_to_seed(split_batch_keys(key, weights.shape[0]))
    return _metropolis_apply_bank(
        seeds, weights, particles, num_iters, interpret=interpret,
        who="metropolis_tpu_apply_batch", plane_dtype=plane_dtype,
    )


def metropolis_tpu_apply_rows(
    keys: jax.Array,
    weights: jnp.ndarray,
    particles: jnp.ndarray,
    num_iters: int,
    *,
    interpret: bool = True,
    plane_dtype="float32",
):
    """Fused bank launch over EXPLICIT per-row keys (the filter-bank path);
    row b == ``metropolis_tpu_apply(keys[b], ...)`` bit-exactly, in ONE
    leading-batch-grid launch."""
    if weights.ndim != 2:
        raise ValueError(
            f"metropolis_tpu_apply_rows expects weights[B, N]; got {weights.shape}"
        )
    return _metropolis_apply_bank(
        key_to_seed(keys), weights, particles, num_iters, interpret=interpret,
        who="metropolis_tpu_apply_rows", plane_dtype=plane_dtype,
    )


def metropolis_tpu_step(
    key: jax.Array,
    log_weights: jnp.ndarray,
    particles: jnp.ndarray,
    num_iters: int,
    ess_threshold,
    *,
    interpret: bool = True,
    plane_dtype="float32",
):
    """Fused SMC step (DESIGN.md §12): normalise → ESS → conditional Alg. 2
    resample → state copy in ONE launch; the resample branch is
    bit-identical to ``apply(key, normalise_log_weights(log_weights), ...)``.
    Returns ``(particles', ancestors, stats f32[4])`` with ``stats`` =
    (ess_norm, log_evidence_incr, resampled, max_weight) — DESIGN.md §15."""
    n, lw2, planes, state_shape = _pack_single(
        log_weights, particles, "metropolis_tpu_step", plane_dtype=plane_dtype
    )
    seed = key_to_seed(key).reshape(1)
    thr = jnp.asarray(ess_threshold, jnp.float32).reshape(1)
    k2, out, stats = metropolis_pallas_step(
        lw2, planes, seed, thr, num_iters=num_iters, interpret=interpret
    )
    out = out.astype(particles.dtype)
    return unpack_state_planes(out, state_shape), k2.reshape(n), stats


def metropolis_tpu_step_rows(
    keys: jax.Array,
    log_weights: jnp.ndarray,
    particles: jnp.ndarray,
    num_iters: int,
    ess_threshold,
    *,
    interpret: bool = True,
    plane_dtype="float32",
):
    """Fused SMC-step bank over EXPLICIT per-row keys; row b ==
    ``metropolis_tpu_step(keys[b], ...)`` bit-exactly, ONE launch.
    Returns ``(particles'[B, N, ...], ancestors, stats f32[B, 4])``."""
    if log_weights.ndim != 2:
        raise ValueError(
            f"metropolis_tpu_step_rows expects log_weights[B, N]; got {log_weights.shape}"
        )
    n = log_weights.shape[1]
    check_tile_aligned(n, "metropolis_tpu_step_rows")
    check_vmem_resident(n, "metropolis_tpu_step_rows",
                        itemsize=plane_itemsize(plane_dtype))
    seeds = key_to_seed(keys)
    thr = jnp.asarray(ess_threshold, jnp.float32).reshape(1)
    return run_step_bank(
        lambda lw3, planes: metropolis_pallas_step_rows(
            lw3, planes, seeds, thr, num_iters=num_iters, interpret=interpret
        ),
        log_weights, particles, "metropolis_tpu_step_rows",
        plane_dtype=plane_dtype,
    )


def metropolis_c1_tpu(
    key: jax.Array,
    weights: jnp.ndarray,
    num_iters: int,
    *,
    interpret: bool = True,
    plane_dtype="float32",
) -> jnp.ndarray:
    """Alg. 3 at tile granularity: ONE partition tile per own-tile, kept for
    all iterations.  Key split mirrors the reference ``metropolis_c1``:
    partition choice from the first subkey, accept/reject stream from the
    second."""
    n = weights.shape[0]
    check_tile_aligned(n, "metropolis_c1_tpu")
    num_tiles = n // TILE
    kp, kloop = jax.random.split(key)
    partitions = jax.random.randint(kp, (num_tiles,), 0, num_tiles, dtype=jnp.int32)
    seed = key_to_seed(kloop).reshape(1)
    w2 = compress_plane(weights.reshape(n // LANES, LANES), plane_dtype)
    k2 = metropolis_c1_pallas(w2, partitions, seed, num_iters=num_iters, interpret=interpret)
    return k2.reshape(n)


def metropolis_c2_tpu(
    key: jax.Array,
    weights: jnp.ndarray,
    num_iters: int,
    *,
    interpret: bool = True,
    plane_dtype="float32",
) -> jnp.ndarray:
    """Alg. 4 at tile granularity: a FRESH partition tile per (tile,
    iteration) — table laid out row-major by tile, ``p[t * B + b]``."""
    n = weights.shape[0]
    check_tile_aligned(n, "metropolis_c2_tpu")
    num_tiles = n // TILE
    kp, kloop = jax.random.split(key)
    partitions = jax.random.randint(
        kp, (num_tiles * num_iters,), 0, num_tiles, dtype=jnp.int32
    )
    seed = key_to_seed(kloop).reshape(1)
    w2 = compress_plane(weights.reshape(n // LANES, LANES), plane_dtype)
    k2 = metropolis_c2_pallas(w2, partitions, seed, num_iters=num_iters, interpret=interpret)
    return k2.reshape(n)


def metropolis_c1_tpu_apply(
    key: jax.Array,
    weights: jnp.ndarray,
    particles: jnp.ndarray,
    num_iters: int,
    *,
    interpret: bool = True,
    plane_dtype="float32",
):
    """Fused C1 resample+gather; same key split as ``metropolis_c1_tpu``.
    Returns ``(particles', ancestors)``."""
    n, w2, planes, state_shape = _pack_single(
        weights, particles, "metropolis_c1_tpu_apply", weights_resident=False,
        plane_dtype=plane_dtype,
    )
    num_tiles = n // TILE
    kp, kloop = jax.random.split(key)
    partitions = jax.random.randint(kp, (num_tiles,), 0, num_tiles, dtype=jnp.int32)
    seed = key_to_seed(kloop).reshape(1)
    k2, out = metropolis_c1_pallas_fused(
        w2, planes, partitions, seed, num_iters=num_iters, interpret=interpret
    )
    out = out.astype(particles.dtype)
    return unpack_state_planes(out, state_shape), k2.reshape(n)


def metropolis_c2_tpu_apply(
    key: jax.Array,
    weights: jnp.ndarray,
    particles: jnp.ndarray,
    num_iters: int,
    *,
    interpret: bool = True,
    plane_dtype="float32",
):
    """Fused C2 resample+gather; same key split as ``metropolis_c2_tpu``.
    Returns ``(particles', ancestors)``."""
    n, w2, planes, state_shape = _pack_single(
        weights, particles, "metropolis_c2_tpu_apply", weights_resident=False,
        plane_dtype=plane_dtype,
    )
    num_tiles = n // TILE
    kp, kloop = jax.random.split(key)
    partitions = jax.random.randint(
        kp, (num_tiles * num_iters,), 0, num_tiles, dtype=jnp.int32
    )
    seed = key_to_seed(kloop).reshape(1)
    k2, out = metropolis_c2_pallas_fused(
        w2, planes, partitions, seed, num_iters=num_iters, interpret=interpret
    )
    out = out.astype(particles.dtype)
    return unpack_state_planes(out, state_shape), k2.reshape(n)


def metropolis_c1_tpu_step(
    key: jax.Array,
    log_weights: jnp.ndarray,
    particles: jnp.ndarray,
    num_iters: int,
    ess_threshold,
    *,
    interpret: bool = True,
    plane_dtype="float32",
):
    """Fused C1 SMC step; same key split as ``metropolis_c1_tpu``.  Unlike
    the C1 apply form, the step prelude needs the WHOLE log-weight array
    resident (the ESS reduction), so the VMEM particle cap applies here.
    Returns ``(particles', ancestors, stats f32[4])``."""
    n, lw2, planes, state_shape = _pack_single(
        log_weights, particles, "metropolis_c1_tpu_step", plane_dtype=plane_dtype
    )
    num_tiles = n // TILE
    kp, kloop = jax.random.split(key)
    partitions = jax.random.randint(kp, (num_tiles,), 0, num_tiles, dtype=jnp.int32)
    seed = key_to_seed(kloop).reshape(1)
    thr = jnp.asarray(ess_threshold, jnp.float32).reshape(1)
    k2, out, stats = metropolis_c1_pallas_step(
        lw2, planes, partitions, seed, thr, num_iters=num_iters, interpret=interpret
    )
    out = out.astype(particles.dtype)
    return unpack_state_planes(out, state_shape), k2.reshape(n), stats


def metropolis_c2_tpu_step(
    key: jax.Array,
    log_weights: jnp.ndarray,
    particles: jnp.ndarray,
    num_iters: int,
    ess_threshold,
    *,
    interpret: bool = True,
    plane_dtype="float32",
):
    """Fused C2 SMC step; same key split as ``metropolis_c2_tpu``; the
    whole-log-weight residency cap applies as for the C1 step.
    Returns ``(particles', ancestors, stats f32[4])``."""
    n, lw2, planes, state_shape = _pack_single(
        log_weights, particles, "metropolis_c2_tpu_step", plane_dtype=plane_dtype
    )
    num_tiles = n // TILE
    kp, kloop = jax.random.split(key)
    partitions = jax.random.randint(
        kp, (num_tiles * num_iters,), 0, num_tiles, dtype=jnp.int32
    )
    seed = key_to_seed(kloop).reshape(1)
    thr = jnp.asarray(ess_threshold, jnp.float32).reshape(1)
    k2, out, stats = metropolis_c2_pallas_step(
        lw2, planes, partitions, seed, thr, num_iters=num_iters, interpret=interpret
    )
    out = out.astype(particles.dtype)
    return unpack_state_planes(out, state_shape), k2.reshape(n), stats
